//! Quickstart: train a 2-layer RGCN with HiFuse on a small synthetic
//! heterogeneous graph, in seconds, on the self-contained sim backend:
//!
//!     cargo run --release --example quickstart
//!
//! No artifacts, no Python: the default `SimBackend` interprets every
//! stage module with reference semantics. (To run the same program on the
//! PJRT engine instead: `make artifacts`, build with `--features pjrt`,
//! and swap `SimBackend::builtin` for `Engine::load`.)
//!
//! This walks the whole public API surface: generate a graph, open a
//! backend, build a `Trainer`, train, inspect metrics — then do the same
//! epoch data-parallel over two backend replicas (`ReplicaGroup`).

use std::sync::Arc;

use hifuse::coordinator::{
    prepare_graph_layout, OptConfig, ReplicaGroup, TrainCfg, Trainer, DEFAULT_ROUND,
};
use hifuse::graph::datasets::tiny_graph;
use hifuse::models::ModelKind;
use hifuse::runtime::{ExecBackend, ResidentStore, SimBackend};

fn main() -> anyhow::Result<()> {
    // 1. An execution backend over the built-in `tiny` profile. One module
    //    dispatch ≙ one "CUDA kernel launch" of the paper. `threads` drives
    //    both the CPU stages and the sim kernels' row parallelism.
    let cfg = TrainCfg { epochs: 8, batch_size: 8, fanout: 3, ..Default::default() };
    let eng = SimBackend::builtin_threaded("tiny", cfg.threads)?;
    println!("profile {} loaded ({} modules)", eng.profile(), eng.manifest().modules.len());

    // 2. A small synthetic heterogeneous graph (3 vertex types, 6 edge
    //    relations, learnable class-centroid features).
    let mut graph = tiny_graph(1);
    println!("{}", graph.stats_row("tiny"));

    // 3. Full HiFuse execution: type-major features, merged aggregation,
    //    CPU-parallel edge-index selection, pipelined CPU/GPU stages.
    let opt = OptConfig::hifuse();
    prepare_graph_layout(&mut graph, &opt);
    let mut trainer = Trainer::new(&eng, &graph, ModelKind::Rgcn, opt, cfg)?;

    // 4. Train and watch the loss fall and the kernel counter stay small.
    for epoch in 0..cfg.epochs as u64 {
        let m = trainer.train_epoch(epoch)?;
        println!(
            "epoch {epoch} | loss {:.4} | acc {:.2} | kernels/epoch {} | wall {:?}",
            m.loss, m.acc, m.kernels_total, m.wall
        );
    }

    // 5. Device-resident feature cache (DESIGN.md §7): pin the hottest
    //    quarter of every vertex type on the device; batches then upload
    //    only the miss rows and assemble the slab with the feature_gather
    //    kernel. Same loss bytes, strictly less H2D traffic.
    let eng2 = SimBackend::builtin_threaded("tiny", cfg.threads)?;
    let mut cached = Trainer::new(&eng2, &graph, ModelKind::Rgcn, opt, cfg)?;
    let store = Arc::new(ResidentStore::build(&graph, 0.25, eng2.cst("CSLOTS"), cfg.seed));
    println!(
        "cache: {} rows resident at frac 0.25 ({} slot capacity)",
        store.rows_cached(),
        store.cslots()
    );
    cached.attach_cache(store)?;
    let mut plain = Trainer::new(&eng, &graph, ModelKind::Rgcn, opt, cfg)?;
    for epoch in 0..2u64 {
        let c = cached.train_epoch(epoch)?;
        let p = plain.train_epoch(epoch)?;
        assert_eq!(c.loss, p.loss, "cache changed the trajectory");
        println!(
            "cached epoch {epoch} | loss {:.4} (= uncached) | hit rate {:.2} | h2d {} vs {} bytes",
            c.loss,
            c.cache_hit_rate(),
            c.h2d_bytes,
            p.h2d_bytes,
        );
    }

    // 6. Data-parallel replicas (DESIGN.md §4): two backends, each with its
    //    own arena/counters, splitting one thread budget; mini-batches fan
    //    out per round and gradients merge in a fixed order, so the
    //    trajectory is bit-identical for ANY replica count.
    let mut group = ReplicaGroup::builtin(
        "tiny",
        2,
        std::time::Duration::ZERO,
        &graph,
        ModelKind::Rgcn,
        opt,
        cfg,
        DEFAULT_ROUND,
    )?;
    for epoch in 0..2u64 {
        let m = group.train_epoch(epoch)?;
        let per_rep: Vec<String> =
            m.per_replica.iter().map(|r| r.kernels_total.to_string()).collect();
        println!(
            "replicas=2 epoch {epoch} | loss {:.4} | acc {:.2} | kernels {} ({} per replica)",
            m.group.loss,
            m.group.acc,
            m.group.kernels_total,
            per_rep.join("+"),
        );
    }
    Ok(())
}
