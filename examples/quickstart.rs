//! Quickstart: train a 2-layer RGCN with HiFuse on a small synthetic
//! heterogeneous graph, in ~a minute on the `tiny` profile.
//!
//!     make artifacts
//!     cargo run --release --example quickstart
//!
//! This walks the whole public API surface: generate a graph, open the
//! AOT artifact profile, build a `Trainer`, train, inspect metrics.

use hifuse::coordinator::{prepare_graph_layout, OptConfig, TrainCfg, Trainer};
use hifuse::graph::datasets::tiny_graph;
use hifuse::models::ModelKind;
use hifuse::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // 1. The AOT artifacts (L1 Pallas kernels + L2 JAX modules, lowered to
    //    HLO text by `make artifacts`) — Python never runs from here on.
    let eng = Engine::load(std::path::Path::new("artifacts/tiny"))?;
    println!("profile {} loaded ({} modules)", eng.profile(), eng.manifest.modules.len());

    // 2. A small synthetic heterogeneous graph (3 vertex types, 6 edge
    //    relations, learnable class-centroid features).
    let mut graph = tiny_graph(1);
    println!("{}", graph.stats_row("tiny"));

    // 3. Full HiFuse execution: type-major features, merged aggregation,
    //    CPU-parallel edge-index selection, pipelined CPU/GPU stages.
    let opt = OptConfig::hifuse();
    prepare_graph_layout(&mut graph, &opt);
    let cfg = TrainCfg { epochs: 8, batch_size: 8, fanout: 3, ..Default::default() };
    let mut trainer = Trainer::new(&eng, &graph, ModelKind::Rgcn, opt, cfg)?;

    // 4. Train and watch the loss fall and the kernel counter stay small.
    for epoch in 0..cfg.epochs as u64 {
        let m = trainer.train_epoch(epoch)?;
        println!(
            "epoch {epoch} | loss {:.4} | acc {:.2} | kernels/epoch {} | wall {:?}",
            m.loss, m.acc, m.kernels_total, m.wall
        );
    }
    Ok(())
}
