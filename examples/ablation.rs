//! Ablation walk-through (a fast, single-dataset rendition of the paper's
//! Fig. 9): run the optimization ladder base -> R -> R+M -> R+O+P ->
//! HiFuse (-> HiFuse+stacked extension) on RGCN/aifb and print the
//! incremental speedups. Runs on the self-contained sim backend:
//!
//!     cargo run --release --example ablation

use hifuse::coordinator::{prepare_graph_layout, OptConfig, TrainCfg, Trainer};
use hifuse::graph::datasets::{generate, spec_by_name};
use hifuse::models::step::Dims;
use hifuse::models::ModelKind;
use hifuse::runtime::SimBackend;

fn main() -> anyhow::Result<()> {
    let cfg = TrainCfg { epochs: 1, batch_size: 48, fanout: 4, ..Default::default() };
    let eng = SimBackend::builtin_threaded("bench", cfg.threads)?;
    let d = Dims::from_backend(&eng);
    let spec = spec_by_name("aifb").unwrap();

    let mut ladder = OptConfig::ablation_ladder();
    ladder.push(("HiFuse+S", OptConfig::parse("hifuse+stacked").unwrap()));

    let mut base_wall = None;
    println!("{:10} | {:>10} | {:>8} | {:>8} | {:>7}", "config", "wall (ms)", "kernels", "speedup", "loss");
    for (name, opt) in ladder {
        let mut graph = generate(&spec, d.f, 1.0, 42);
        prepare_graph_layout(&mut graph, &opt);
        let mut tr = Trainer::new(&eng, &graph, ModelKind::Rgcn, opt, cfg)?;
        tr.train_epoch(0)?; // warm-up epoch: compiles every module used
        let m = tr.train_epoch(1)?;
        let wall = m.wall.as_secs_f64() * 1e3;
        let base = *base_wall.get_or_insert(wall);
        println!(
            "{name:10} | {wall:>10.1} | {:>8} | {:>7.2}x | {:>7.4}",
            m.kernels_total,
            base / wall,
            m.loss
        );
    }
    Ok(())
}
