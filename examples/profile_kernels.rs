//! Kernel profiling walk-through (the paper's Fig. 3 methodology): run one
//! baseline RGCN mini-batch with full event logging, print the kernel
//! timeline head and the roofline classification, and write
//! results/profile_timeline.csv + results/profile_roofline.csv.
//!
//!     cargo run --release --example profile_kernels
//!
//! Runs on the self-contained sim backend (no artifacts, no Python).

use hifuse::coordinator::{prepare_graph_layout, CpuProducer, OptConfig, TrainCfg, Trainer};
use hifuse::graph::datasets::{generate, spec_by_name};
use hifuse::models::step::Dims;
use hifuse::models::ModelKind;
use hifuse::perf;
use hifuse::report;
use hifuse::runtime::{ExecBackend, SimBackend};
use hifuse::sampler::SamplerCfg;
use hifuse::util::{Rng, WorkerPool};

fn main() -> anyhow::Result<()> {
    let cfg = TrainCfg { epochs: 1, batch_size: 64, fanout: 4, ..Default::default() };
    let eng = SimBackend::builtin_threaded("bench", cfg.threads)?;
    let d = Dims::from_backend(&eng);
    let peaks = perf::calibrate(&eng)?;
    println!(
        "peaks: {:.1} GFLOP/s | {:.1} GB/s | dispatch {:.0} us | knee AI {:.2}",
        peaks.gflops,
        peaks.membw_gbs,
        peaks.dispatch_us,
        peaks.gflops / peaks.membw_gbs
    );

    // Baseline RGCN on the am schema (the paper's Fig. 3 workload),
    // node/edge-scaled for a quick run — the kernel *structure* per batch
    // is scale-independent.
    let spec = spec_by_name("am").unwrap();
    let mut graph = generate(&spec, d.f, 0.02, 7);
    let opt = OptConfig::baseline();
    prepare_graph_layout(&mut graph, &opt);
    let mut tr = Trainer::new(&eng, &graph, ModelKind::Rgcn, opt, cfg)?;

    // Warm up compile caches, then profile exactly one batch — through a
    // persistent producer, so the measured window starts at real batch
    // preparation rather than scratch construction.
    let scfg = SamplerCfg { batch_size: 64, fanout: 4, layers: 2, ns: d.ns, ep: d.ep };
    let mut producer = CpuProducer::new(&graph, scfg, d, opt, WorkerPool::new(1), Rng::new(1));
    let prep = producer.produce(0, 0);
    tr.compute_batch(prep)?;
    eng.reset_counters(true);
    let prep = producer.produce(0, 1);
    tr.compute_batch(prep)?;

    let counters = eng.counters().borrow();
    println!("\none baseline batch = {} kernel launches", counters.total());
    println!("first 12 timeline events:");
    println!("{:>10} {:>9} {:24} {:15}", "t (us)", "dur (us)", "module", "stage");
    for e in counters.events.iter().take(12) {
        println!(
            "{:>10.1} {:>9.1} {:24} {:15}",
            e.t_start.as_secs_f64() * 1e6,
            e.dur.as_secs_f64() * 1e6,
            e.module,
            e.stage.name()
        );
    }

    let rows = perf::roofline_rows(&counters.events, &d, &peaks);
    let mem_bound = rows.iter().filter(|r| r.memory_bound).count();
    println!(
        "\nroofline: {}/{} dispatches are memory-bound (paper Fig. 3b: most are)",
        mem_bound,
        rows.len()
    );

    let timeline: Vec<Vec<String>> = counters
        .events
        .iter()
        .map(|e| {
            vec![
                format!("{:.1}", e.t_start.as_secs_f64() * 1e6),
                format!("{:.1}", e.dur.as_secs_f64() * 1e6),
                e.module.to_string(),
                e.stage.name().to_string(),
                e.bytes_in.to_string(),
                e.bytes_out.to_string(),
            ]
        })
        .collect();
    let p1 = report::write_csv(
        "profile_timeline.csv",
        &["t_us", "dur_us", "module", "stage", "bytes_in", "bytes_out"],
        &timeline,
    )?;
    let roof: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.module.to_string(),
                r.stage.name().to_string(),
                format!("{:.4}", r.ai),
                format!("{:.3}", r.achieved_gflops),
                format!("{:.2}", r.compute_pct),
                format!("{:.2}", r.memory_pct),
                r.memory_bound.to_string(),
            ]
        })
        .collect();
    let p2 = report::write_csv(
        "profile_roofline.csv",
        &["module", "stage", "ai", "gflops", "compute_pct", "memory_pct", "memory_bound"],
        &roof,
    )?;
    println!("wrote {p1:?} and {p2:?}");
    Ok(())
}
