//! End-to-end driver (the repo's required E2E validation): train RGCN on
//! the full-scale synthetic **aifb** dataset (7,262 vertices / 48,810
//! edges / 104 relations) for a few hundred mini-batch steps with the full
//! HiFuse execution mode, logging the loss curve, then run one baseline
//! epoch for a direct wall-clock comparison and one data-parallel
//! two-replica epoch (whose counters must sum to the group totals).
//!
//!     cargo run --release --example e2e_train
//!
//! Runs on the self-contained sim backend (no artifacts, no Python).
//! Outputs: results/e2e_loss.csv (step-level loss curve), stdout summary.
//! The run is recorded in EXPERIMENTS.md §E2E.

use hifuse::coordinator::{
    prepare_graph_layout, OptConfig, ReplicaGroup, TrainCfg, Trainer, DEFAULT_ROUND,
};
use hifuse::graph::datasets::{generate, spec_by_name};
use hifuse::models::step::Dims;
use hifuse::models::ModelKind;
use hifuse::report;
use hifuse::runtime::SimBackend;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::var("E2E_EPOCHS").ok().and_then(|s| s.parse().ok()).unwrap_or(60);
    let cfg =
        TrainCfg { epochs, batch_size: 48, fanout: 4, lr: 0.08, seed: 42, threads: 4, producers: 0 };
    let eng = SimBackend::builtin_threaded("bench", cfg.threads)?;
    let d = Dims::from_backend(&eng);

    let spec = spec_by_name("aifb").unwrap();
    let mut graph = generate(&spec, d.f, 1.0, 42);
    println!("{}", graph.stats_row("aifb"));
    let opt = OptConfig::hifuse();
    prepare_graph_layout(&mut graph, &opt);
    let mut tr = Trainer::new(&eng, &graph, ModelKind::Rgcn, opt, cfg)?;
    let batches = graph.train_idx.len().div_ceil(cfg.batch_size);
    println!(
        "training RGCN/aifb with HiFuse: {epochs} epochs x {batches} batches = {} steps",
        epochs * batches
    );

    let mut rows: Vec<Vec<String>> = Vec::new();
    let t0 = std::time::Instant::now();
    let mut hifuse_epoch_wall = std::time::Duration::ZERO;
    for epoch in 0..epochs as u64 {
        let m = tr.train_epoch(epoch)?;
        hifuse_epoch_wall = m.wall;
        rows.push(vec![
            epoch.to_string(),
            format!("{:.6}", m.loss),
            format!("{:.4}", m.acc),
            format!("{:.1}", m.wall.as_secs_f64() * 1e3),
            m.kernels_total.to_string(),
        ]);
        if epoch % 5 == 0 || epoch as usize == epochs - 1 {
            println!(
                "epoch {epoch:>3} | loss {:.4} | train-acc {:.3} | {:>7.1} ms/epoch | {} kernels",
                m.loss,
                m.acc,
                m.wall.as_secs_f64() * 1e3,
                m.kernels_total
            );
        }
    }
    let total = t0.elapsed();
    let path = report::write_csv("e2e_loss.csv", &["epoch", "loss", "acc", "wall_ms", "kernels"], &rows)?;
    println!("loss curve -> {path:?}  (total {total:?})");

    // Sanity: the loss must actually have decreased.
    let first: f64 = rows.first().unwrap()[1].parse()?;
    let last: f64 = rows.last().unwrap()[1].parse()?;
    anyhow::ensure!(last < first, "loss did not decrease: {first} -> {last}");
    println!("loss {first:.4} -> {last:.4}  ✓ decreasing");

    // One baseline epoch for the headline comparison.
    let base = OptConfig::baseline();
    prepare_graph_layout(&mut graph, &base);
    let mut tr_base = Trainer::new(&eng, &graph, ModelKind::Rgcn, base, cfg)?;
    let mb = tr_base.train_epoch(0)?;
    println!(
        "baseline epoch: {:>7.1} ms, {} kernels  => HiFuse speedup {:.2}x, kernel reduction {:.1}%",
        mb.wall.as_secs_f64() * 1e3,
        mb.kernels_total,
        mb.wall.as_secs_f64() / hifuse_epoch_wall.as_secs_f64(),
        100.0 * (1.0 - rows.last().unwrap()[4].parse::<f64>()? / mb.kernels_total as f64)
    );

    // One data-parallel epoch over two replica backends (DESIGN.md §4):
    // same HiFuse plan, batches fanned out per round, gradients merged in
    // fixed order. The group totals must be exactly the per-replica sums.
    prepare_graph_layout(&mut graph, &opt);
    let mut group = ReplicaGroup::builtin(
        "bench",
        2,
        std::time::Duration::ZERO,
        &graph,
        ModelKind::Rgcn,
        opt,
        cfg,
        DEFAULT_ROUND,
    )?;
    let mr = group.train_epoch(0)?;
    // Independent witness (the per-replica -> group sum is true by
    // construction): the single-backend HiFuse run's epoch-0 kernel count,
    // recorded in rows[0], came from the same batches and plans, so the
    // two-replica epoch 0 must dispatch exactly as many kernels.
    let reference: usize = rows.first().unwrap()[4].parse()?;
    anyhow::ensure!(
        mr.group.kernels_total == reference,
        "replica kernel total {} != single-backend epoch-0 total {reference}",
        mr.group.kernels_total
    );
    println!(
        "replicas=2 epoch: {:>7.1} ms, loss {:.4}, {} kernels ({} per replica)",
        mr.group.wall.as_secs_f64() * 1e3,
        mr.group.loss,
        mr.group.kernels_total,
        mr.per_replica
            .iter()
            .map(|r| r.kernels_total.to_string())
            .collect::<Vec<_>>()
            .join("+"),
    );
    Ok(())
}
