//! Parameter checkpointing: a small self-describing binary format (no
//! serde offline). Layout: magic, version, the five dims, then each
//! parameter tensor as little-endian f32, in a fixed order.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Params;

const MAGIC: &[u8; 8] = b"HIFUSEck";
const VERSION: u32 = 1;

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    write_u32(w, xs.len() as u32)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = read_u32(r)? as usize;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Save trainable parameters to `path`.
pub fn save(params: &Params, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_u32(&mut w, VERSION)?;
    for d in [params.rpad, params.f, params.h, params.c] {
        write_u32(&mut w, d as u32)?;
    }
    for t in [&params.w0, &params.w1, &params.a_src0, &params.a_dst0, &params.a_src1,
              &params.a_dst1] {
        write_f32s(&mut w, t)?;
    }
    Ok(())
}

/// Load parameters from `path`; dims must match the running profile.
pub fn load(path: &Path) -> Result<Params> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut r = std::io::BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a hifuse checkpoint");
    }
    let ver = read_u32(&mut r)?;
    if ver != VERSION {
        bail!("{path:?}: unsupported checkpoint version {ver}");
    }
    let rpad = read_u32(&mut r)? as usize;
    let fdim = read_u32(&mut r)? as usize;
    let h = read_u32(&mut r)? as usize;
    let c = read_u32(&mut r)? as usize;
    let mut p = Params::init(rpad, fdim, h, c, 0);
    p.w0 = read_f32s(&mut r)?;
    p.w1 = read_f32s(&mut r)?;
    p.a_src0 = read_f32s(&mut r)?;
    p.a_dst0 = read_f32s(&mut r)?;
    p.a_src1 = read_f32s(&mut r)?;
    p.a_dst1 = read_f32s(&mut r)?;
    for (name, t, want) in [
        ("w0", p.w0.len(), rpad * fdim * h),
        ("w1", p.w1.len(), rpad * h * c),
        ("a_src0", p.a_src0.len(), rpad * h),
        ("a_dst0", p.a_dst0.len(), rpad * h),
        ("a_src1", p.a_src1.len(), rpad * c),
        ("a_dst1", p.a_dst1.len(), rpad * c),
    ] {
        if t != want {
            bail!("{path:?}: tensor {name} has {t} elements, expected {want}");
        }
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_preserves_every_tensor() {
        let p = Params::init(4, 8, 16, 4, 123);
        let path = std::env::temp_dir().join("hifuse_ckpt_test.bin");
        save(&p, &path).unwrap();
        let q = load(&path).unwrap();
        // Bitwise equality of the full parameter set: every tensor and
        // every dim (the serve path's --load-ckpt contract — a served
        // checkpoint predicts exactly what the trainer would).
        assert_eq!(p.w0, q.w0);
        assert_eq!(p.w1, q.w1);
        assert_eq!(p.a_src0, q.a_src0);
        assert_eq!(p.a_dst0, q.a_dst0);
        assert_eq!(p.a_src1, q.a_src1);
        assert_eq!(p.a_dst1, q.a_dst1);
        assert_eq!((q.rpad, q.f, q.h, q.c), (4, 8, 16, 4));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage_files() {
        let path = std::env::temp_dir().join("hifuse_ckpt_garbage.bin");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncated_files() {
        let p = Params::init(2, 4, 8, 2, 7);
        let path = std::env::temp_dir().join("hifuse_ckpt_trunc.bin");
        save(&p, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
