//! Parameter checkpointing: a small self-describing binary format (no
//! serde offline). Layout: magic, version, a mid-epoch resume cursor
//! (v2), the five dims, each parameter tensor as little-endian f32 in a
//! fixed order, then a CRC32 trailer over everything before it (v2).
//!
//! Crash consistency (DESIGN.md §9): [`save_at`] writes the whole image to
//! a `*.tmp` sibling, fsyncs, and renames it into place — a crash mid-save
//! leaves either the old checkpoint or the new one, never a torn file —
//! and [`load`] validates magic, version, per-tensor lengths (before any
//! allocation sized from file bytes), and the CRC, returning a typed
//! [`CheckpointError`] instead of panicking on any malformed input.
//! Version-1 files (no cursor, no CRC) still load.

use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

use super::Params;

const MAGIC: &[u8; 8] = b"HIFUSEck";
const VERSION: u32 = 2;

/// Everything that can be wrong with a checkpoint file, as data — callers
/// (and the negative tests) match on the variant via
/// `err.downcast_ref::<CheckpointError>()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CheckpointError {
    /// The file does not start with the checkpoint magic.
    BadMagic,
    /// Recognized magic but a version this build cannot read.
    UnsupportedVersion(u32),
    /// The file ends before the named field is complete.
    Truncated { what: &'static str },
    /// A tensor's stored length disagrees with the stored dims.
    ShapeMismatch { name: &'static str, got: usize, want: usize },
    /// The CRC32 trailer does not match the file contents.
    CrcMismatch { stored: u32, computed: u32 },
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::BadMagic => write!(f, "not a hifuse checkpoint (bad magic)"),
            CheckpointError::UnsupportedVersion(v) => {
                write!(f, "unsupported checkpoint version {v}")
            }
            CheckpointError::Truncated { what } => {
                write!(f, "checkpoint truncated while reading {what}")
            }
            CheckpointError::ShapeMismatch { name, got, want } => {
                write!(f, "tensor {name} has {got} elements, expected {want}")
            }
            CheckpointError::CrcMismatch { stored, computed } => {
                write!(f, "checkpoint CRC mismatch (stored {stored:#010x}, computed {computed:#010x})")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

/// Mid-epoch resume cursor: the training position a checkpoint captured —
/// the first `batch` batches of epoch `epoch` are already applied to the
/// saved parameters, so resuming runs `train_epoch_range(epoch, batch, ..)`
/// and then the remaining epochs (DESIGN.md §9).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Cursor {
    pub epoch: u64,
    pub batch: u64,
}

fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    push_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked little-endian reader over the checkpoint image; every
/// out-of-bounds read is a typed [`CheckpointError::Truncated`].
struct Reader<'a> {
    data: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], CheckpointError> {
        let end = self.at.checked_add(n).ok_or(CheckpointError::Truncated { what })?;
        if end > self.data.len() {
            return Err(CheckpointError::Truncated { what });
        }
        let s = &self.data[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, CheckpointError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, CheckpointError> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().expect("eight bytes")))
    }

    /// Read one tensor, validating its stored length against `want`
    /// *before* allocating anything sized from file bytes.
    fn f32s(&mut self, name: &'static str, want: usize) -> Result<Vec<f32>, CheckpointError> {
        let got = self.u32(name)? as usize;
        if got != want {
            return Err(CheckpointError::ShapeMismatch { name, got, want });
        }
        let bytes = self.take(got * 4, name)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

fn encode(params: &Params, cursor: Cursor) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    push_u32(&mut out, VERSION);
    out.extend_from_slice(&cursor.epoch.to_le_bytes());
    out.extend_from_slice(&cursor.batch.to_le_bytes());
    for d in [params.rpad, params.f, params.h, params.c] {
        push_u32(&mut out, d as u32);
    }
    for t in [&params.w0, &params.w1, &params.a_src0, &params.a_dst0, &params.a_src1,
              &params.a_dst1] {
        push_f32s(&mut out, t);
    }
    let crc = crc32(&out);
    push_u32(&mut out, crc);
    out
}

/// Save trainable parameters to `path` (cursor = start of epoch 0; use
/// [`save_at`] to record a mid-training position).
pub fn save(params: &Params, path: &Path) -> Result<()> {
    save_at(params, Cursor::default(), path)
}

/// Crash-consistently save parameters plus a resume cursor: the image goes
/// to `<path>.tmp`, is fsynced, and is renamed over `path` — readers see
/// the old file or the new file, never a partial write.
pub fn save_at(params: &Params, cursor: Cursor, path: &Path) -> Result<()> {
    let image = encode(params, cursor);
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    let write = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
        std::io::Write::write_all(&mut f, &image)?;
        f.sync_all()?;
        Ok(())
    })();
    if let Err(e) = write {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("renaming {tmp:?} into {path:?}"));
    }
    Ok(())
}

/// Load parameters from `path`; dims must match the running profile.
///
/// This is also the serve plane's hot-refresh loader (DESIGN.md §10):
/// `serving::serve_churn` runs every `--refresh-at` checkpoint through it
/// *before* the drive starts, and because each failure mode below is an
/// error — not a panic, and never a partially-applied parameter set — a
/// corrupt refresh leaves the old model serving, demoted to a
/// `failed_refreshes` count. Refresh atomicity is pinned by
/// `tests/churn_matrix.rs`.
pub fn load(path: &Path) -> Result<Params> {
    Ok(load_with_cursor(path)?.0)
}

/// Load parameters plus the resume cursor (v1 files report the default
/// cursor). Every malformed input — wrong magic or version, truncation
/// anywhere, tensor/dim disagreement, CRC mismatch — is a typed
/// [`CheckpointError`] wrapped with the path, never a panic.
pub fn load_with_cursor(path: &Path) -> Result<(Params, Cursor)> {
    let data = std::fs::read(path).with_context(|| format!("opening {path:?}"))?;
    decode(&data).with_context(|| format!("loading checkpoint {path:?}"))
}

/// Offline audit report from [`inspect`] (`repro verify-ckpt`, DESIGN.md
/// §11): everything the file claims about itself plus the derived params
/// digest — produced without loading a graph or a backend.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InspectReport {
    /// Format version of the file (1 or 2).
    pub version: u32,
    /// `true` iff the file carries — and passed — a CRC32 trailer (v2+).
    pub crc_checked: bool,
    /// Resume cursor (v1 files report the default).
    pub cursor: Cursor,
    /// The stored dims `(rpad, f, h, c)`.
    pub dims: (usize, usize, usize, usize),
    /// Name and element count of each parameter tensor, in file order.
    pub tensors: Vec<(&'static str, usize)>,
    /// FNV-1a digest over every tensor in checkpoint order — the same
    /// digest `repro train` prints, so a saved checkpoint can be matched
    /// to the run that produced it.
    pub params_digest: u64,
    /// Total image size in bytes.
    pub bytes: usize,
}

/// Audit a checkpoint without touching graph or backend state: run the
/// exact validation [`load`] runs (magic, version, truncation, per-tensor
/// shapes, CRC) and report the header, shape table, and params digest.
/// Any corruption is the same typed [`CheckpointError`] a load would hit.
pub fn inspect(path: &Path) -> Result<InspectReport> {
    let data = std::fs::read(path).with_context(|| format!("opening {path:?}"))?;
    let bytes = data.len();
    let (p, cursor) = decode(&data).with_context(|| format!("auditing checkpoint {path:?}"))?;
    // decode() validated the header, so the version field is present.
    let version = u32::from_le_bytes(
        data[MAGIC.len()..MAGIC.len() + 4].try_into().expect("four version bytes"),
    );
    Ok(InspectReport {
        version,
        crc_checked: version >= 2,
        cursor,
        dims: (p.rpad, p.f, p.h, p.c),
        tensors: vec![
            ("w0", p.w0.len()),
            ("w1", p.w1.len()),
            ("a_src0", p.a_src0.len()),
            ("a_dst0", p.a_dst0.len()),
            ("a_src1", p.a_src1.len()),
            ("a_dst1", p.a_dst1.len()),
        ],
        params_digest: p.digest(),
        bytes,
    })
}

fn decode(data: &[u8]) -> Result<(Params, Cursor)> {
    let mut r = Reader { data, at: 0 };
    if r.take(MAGIC.len(), "magic").map_err(anyhow::Error::new)? != MAGIC {
        return Err(CheckpointError::BadMagic.into());
    }
    let ver = r.u32("version")?;
    if ver != 1 && ver != VERSION {
        return Err(CheckpointError::UnsupportedVersion(ver).into());
    }
    if ver >= 2 {
        // The CRC trailer covers every byte before it; verify up front so
        // a bit-flipped image fails as corrupt, not as some downstream
        // shape error.
        if data.len() < 4 {
            return Err(CheckpointError::Truncated { what: "crc trailer" }.into());
        }
        let body = &data[..data.len() - 4];
        let stored = u32::from_le_bytes(data[data.len() - 4..].try_into().expect("four bytes"));
        let computed = crc32(body);
        if stored != computed {
            return Err(CheckpointError::CrcMismatch { stored, computed }.into());
        }
        r.data = body;
    }
    let cursor = if ver >= 2 {
        Cursor { epoch: r.u64("cursor epoch")?, batch: r.u64("cursor batch")? }
    } else {
        Cursor::default()
    };
    let rpad = r.u32("dim rpad")? as usize;
    let fdim = r.u32("dim f")? as usize;
    let h = r.u32("dim h")? as usize;
    let c = r.u32("dim c")? as usize;
    let mut p = Params::init(rpad, fdim, h, c, 0);
    p.w0 = r.f32s("w0", rpad * fdim * h)?;
    p.w1 = r.f32s("w1", rpad * h * c)?;
    p.a_src0 = r.f32s("a_src0", rpad * h)?;
    p.a_dst0 = r.f32s("a_dst0", rpad * h)?;
    p.a_src1 = r.f32s("a_src1", rpad * c)?;
    p.a_dst1 = r.f32s("a_dst1", rpad * c)?;
    Ok((p, cursor))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_preserves_every_tensor() {
        let p = Params::init(4, 8, 16, 4, 123);
        let path = std::env::temp_dir().join("hifuse_ckpt_test.bin");
        save(&p, &path).unwrap();
        let q = load(&path).unwrap();
        // Bitwise equality of the full parameter set: every tensor and
        // every dim (the serve path's --load-ckpt contract — a served
        // checkpoint predicts exactly what the trainer would).
        assert_eq!(p.w0, q.w0);
        assert_eq!(p.w1, q.w1);
        assert_eq!(p.a_src0, q.a_src0);
        assert_eq!(p.a_dst0, q.a_dst0);
        assert_eq!(p.a_src1, q.a_src1);
        assert_eq!(p.a_dst1, q.a_dst1);
        assert_eq!((q.rpad, q.f, q.h, q.c), (4, 8, 16, 4));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn cursor_roundtrips_and_tmp_never_lingers() {
        let p = Params::init(2, 4, 8, 2, 9);
        let path = std::env::temp_dir().join("hifuse_ckpt_cursor.bin");
        save_at(&p, Cursor { epoch: 3, batch: 7 }, &path).unwrap();
        let (_, cur) = load_with_cursor(&path).unwrap();
        assert_eq!(cur, Cursor { epoch: 3, batch: 7 });
        let mut tmp = path.as_os_str().to_os_string();
        tmp.push(".tmp");
        assert!(!std::path::Path::new(&tmp).exists(), "atomic save left its tmp file");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn inspect_reports_header_shapes_and_digest() {
        let p = Params::init(2, 4, 8, 2, 31);
        let path = std::env::temp_dir().join("hifuse_ckpt_inspect.bin");
        save_at(&p, Cursor { epoch: 1, batch: 5 }, &path).unwrap();
        let r = inspect(&path).unwrap();
        assert_eq!(r.version, 2);
        assert!(r.crc_checked);
        assert_eq!(r.cursor, Cursor { epoch: 1, batch: 5 });
        assert_eq!(r.dims, (2, 4, 8, 2));
        assert_eq!(r.tensors[0], ("w0", 2 * 4 * 8));
        assert_eq!(r.tensors.len(), 6);
        assert_eq!(r.params_digest, p.digest(), "inspect digest == live params digest");
        assert_eq!(r.bytes, std::fs::read(&path).unwrap().len());

        // A flipped bit inside a tensor must fail the audit, typed.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x04;
        std::fs::write(&path, &bytes).unwrap();
        let err = inspect(&path).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<CheckpointError>(),
                Some(CheckpointError::CrcMismatch { .. })
            ),
            "expected CRC mismatch, got {err:#}"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage_files() {
        let path = std::env::temp_dir().join("hifuse_ckpt_garbage.bin");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        let err = load(&path).unwrap_err();
        assert_eq!(err.downcast_ref::<CheckpointError>(), Some(&CheckpointError::BadMagic));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncated_files() {
        let p = Params::init(2, 4, 8, 2, 7);
        let path = std::env::temp_dir().join("hifuse_ckpt_trunc.bin");
        save(&p, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        for cut in [bytes.len() / 2, 10, 3] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            let err = load(&path).unwrap_err();
            assert!(
                err.downcast_ref::<CheckpointError>().is_some(),
                "cut at {cut}: expected a typed checkpoint error, got {err:#}"
            );
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bit_flips_via_crc() {
        let p = Params::init(2, 4, 8, 2, 11);
        let path = std::env::temp_dir().join("hifuse_ckpt_bitflip.bin");
        save(&p, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<CheckpointError>(),
                Some(CheckpointError::CrcMismatch { .. })
            ),
            "expected CRC mismatch, got {err:#}"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_unsupported_version() {
        let path = std::env::temp_dir().join("hifuse_ckpt_badver.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert_eq!(
            err.downcast_ref::<CheckpointError>(),
            Some(&CheckpointError::UnsupportedVersion(99))
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_shape_mismatch_without_allocating_from_length_field() {
        // Hand-build a v1 image (no CRC shielding the tamper) whose w0
        // length field claims far more elements than the dims allow; the
        // loader must fail typed — before trusting the length.
        let path = std::env::temp_dir().join("hifuse_ckpt_shape.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        for d in [2u32, 4, 8, 2] {
            bytes.extend_from_slice(&d.to_le_bytes());
        }
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd w0 length
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<CheckpointError>(),
                Some(CheckpointError::ShapeMismatch { name: "w0", .. })
            ),
            "expected w0 shape mismatch, got {err:#}"
        );
        std::fs::remove_file(path).ok();
    }
}
