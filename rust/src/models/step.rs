//! The training-step executor: chains AOT modules according to the active
//! execution plan (DESIGN.md §3).
//!
//! * **Baseline ("PyG")**: per-relation projection + per-relation
//!   aggregation dispatches, semantic-graph build on "GPU".
//! * **HiFuse**: merged aggregation (single Pallas launch per layer,
//!   Algorithm 1), selection already done on CPU, optionally stacked
//!   projection (extension).
//!
//! Both plans compute the *same* gradients (integration-tested against each
//! other and against jax.grad via the Python composition test), so every
//! performance comparison is apples-to-apples.

use anyhow::Result;

use crate::coordinator::ablation::OptConfig;
use crate::graph::HeteroGraph;
use crate::models::{ModelKind, Params};
use crate::runtime::{Arg, DevBuf, ExecBackend, Phase, Stage};
use crate::sampler::RelEdges;
use crate::util::{tensor, HostTensor};

/// Profile dims, read once from the manifest.
#[derive(Clone, Copy, Debug)]
pub struct Dims {
    pub ns: usize,
    pub ep: usize,
    pub rpad: usize,
    pub tpad: usize,
    pub f: usize,
    pub h: usize,
    pub c: usize,
    pub elp: usize,
}

impl Dims {
    pub fn from_backend<B: ExecBackend>(eng: &B) -> Dims {
        Dims {
            ns: eng.cst("NS"),
            ep: eng.cst("EP"),
            rpad: eng.cst("RPAD"),
            tpad: eng.cst("TPAD"),
            f: eng.cst("F"),
            h: eng.cst("H"),
            c: eng.cst("C"),
            elp: eng.cst("ELP"),
        }
    }

    /// Aggregation feature width of layer `l` (l0 -> H, l1 -> C).
    pub fn fd(&self, l: usize) -> usize {
        if l == 0 {
            self.h
        } else {
            self.c
        }
    }
}

/// Graph-schema tensors shared by every batch.
#[derive(Clone, Debug)]
pub struct SchemaTensors {
    pub src_type: Vec<usize>,
    pub dst_type: Vec<usize>,
    /// `[RPAD]` i32 src types (stacked-projection gather index).
    pub src_type_i32: HostTensor,
    /// `[RPAD]` i32 dst types (semantic-fusion segment ids).
    pub dst_type_i32: HostTensor,
    pub target_type: usize,
    pub n_rel: usize,
}

pub fn schema_tensors(g: &HeteroGraph, d: &Dims) -> SchemaTensors {
    assert!(g.n_relations() <= d.rpad, "schema exceeds RPAD");
    assert!(g.n_types() <= d.tpad, "schema exceeds TPAD");
    let mut src_type = vec![0usize; d.rpad];
    let mut dst_type = vec![0usize; d.rpad];
    for (r, rel) in g.relations.iter().enumerate() {
        src_type[r] = rel.src_type;
        dst_type[r] = rel.dst_type;
    }
    SchemaTensors {
        src_type_i32: HostTensor::i32(src_type.iter().map(|&t| t as i32).collect(), &[d.rpad]),
        dst_type_i32: HostTensor::i32(dst_type.iter().map(|&t| t as i32).collect(), &[d.rpad]),
        src_type,
        dst_type,
        target_type: g.target_type,
        n_rel: g.n_relations(),
    }
}

/// One layer's edges in every padded form the modules need.
#[derive(Clone, Debug)]
pub struct LayerEdges {
    /// Per relation: (`[EP]` src, `[EP]` dst, `[EP]` valid); padded zeros.
    pub per_rel: Vec<(HostTensor, HostTensor, HostTensor)>,
    /// Relations with at least one edge this layer.
    pub live: Vec<usize>,
    /// Merged `[RPAD, EP]` tensors (the Pallas kernel inputs).
    pub src: HostTensor,
    pub dst: HostTensor,
    pub valid: HostTensor,
}

/// Pad per-relation edge lists (selection output) into module tensors.
pub fn pad_layer_edges(rels: &[RelEdges], d: &Dims) -> LayerEdges {
    assert!(rels.len() <= d.rpad);
    let mut merged_src = vec![0i32; d.rpad * d.ep];
    let mut merged_dst = vec![0i32; d.rpad * d.ep];
    let mut merged_valid = vec![0.0f32; d.rpad * d.ep];
    let mut per_rel = Vec::with_capacity(d.rpad);
    let mut live = Vec::new();
    for r in 0..d.rpad {
        let (mut s, mut t, mut v) = (vec![0i32; d.ep], vec![0i32; d.ep], vec![0.0f32; d.ep]);
        if let Some(e) = rels.get(r) {
            assert!(e.len() <= d.ep, "relation {r} exceeds EP after selection");
            if !e.is_empty() {
                live.push(r);
            }
            for i in 0..e.len() {
                s[i] = e.src[i] as i32;
                t[i] = e.dst[i] as i32;
                v[i] = 1.0;
            }
        }
        merged_src[r * d.ep..r * d.ep + d.ep].copy_from_slice(&s);
        merged_dst[r * d.ep..r * d.ep + d.ep].copy_from_slice(&t);
        merged_valid[r * d.ep..r * d.ep + d.ep].copy_from_slice(&v);
        per_rel.push((
            HostTensor::i32(s, &[d.ep]),
            HostTensor::i32(t, &[d.ep]),
            HostTensor::f32(v, &[d.ep]),
        ));
    }
    LayerEdges {
        per_rel,
        live,
        src: HostTensor::i32(merged_src, &[d.rpad, d.ep]),
        dst: HostTensor::i32(merged_dst, &[d.rpad, d.ep]),
        valid: HostTensor::f32(merged_valid, &[d.rpad, d.ep]),
    }
}

/// A fully prepared batch: everything `train_step` needs.
pub struct BatchData {
    /// `[TPAD, NS, F]` raw features.
    pub xs: HostTensor,
    pub labels: HostTensor,
    pub seed_mask: HostTensor,
    pub n_seed: usize,
    pub layers: Vec<LayerEdges>,
}

pub struct StepResult {
    pub loss: f32,
    pub ncorrect: f32,
    pub n_seed: usize,
}

// --------------------------------------------------------------------------
// host tensor helpers
// --------------------------------------------------------------------------

/// Copy type slab `t` (`[NS, F]`) out of a `[TPAD, NS, F]` tensor.
fn slab(h: &HostTensor, t: usize, ns: usize, f: usize) -> Result<HostTensor> {
    let d = h.as_f32()?;
    Ok(HostTensor::f32(d[t * ns * f..(t + 1) * ns * f].to_vec(), &[ns, f]))
}

/// View relation `r`'s `[NS, Fd]` block of a `[RPAD, NS, Fd]` stack.
fn stack_block(stack: &[f32], r: usize, ns: usize, fd: usize) -> &[f32] {
    &stack[r * ns * fd..(r + 1) * ns * fd]
}

/// An activation that is either host-resident (per-relation plans need to
/// slice it) or still on the device (the merged plan chains it straight
/// into the next dispatch — §Perf #5).
enum Stack<D> {
    Host(HostTensor),
    Dev(D),
}

impl<D: DevBuf> Stack<D> {
    fn as_arg(&self) -> Arg<'_, D> {
        match self {
            Stack::Host(h) => Arg::Host(h),
            Stack::Dev(d) => Arg::Dev(d),
        }
    }

    fn as_host(&self) -> &HostTensor {
        match self {
            Stack::Host(h) => h,
            Stack::Dev(_) => panic!("activation unexpectedly device-resident"),
        }
    }
}

struct LayerFwd<D> {
    /// `[RPAD, NS, Fd]` projected source features (zeros for dead rels),
    /// kept as a tensor so dispatches borrow it without cloning.
    pstack: HostTensor,
    /// RGAT only: projected destination features.
    pstack_dst: Option<HostTensor>,
    /// `[RPAD, NS, Fd]` aggregated features.
    astack: Stack<D>,
    /// `[TPAD, NS, Fd]` fused output.
    hout: HostTensor,
}

// --------------------------------------------------------------------------
// device-resident step state (DESIGN.md §7)
// --------------------------------------------------------------------------

/// Device-resident trainable parameters: the authoritative copy when the
/// step runs fully on-device. In single-trainer mode they persist across
/// batches and are updated in place by [`StepExecutor::opt_step`]; the host
/// [`Params`] only rematerializes at sync points ([`StepExecutor::sync_params`]).
pub struct DevParams<B: ExecBackend> {
    /// `[RPAD, F, H]` layer-0 projection weights.
    pub w0: B::Dev,
    /// `[RPAD, H, C]` layer-1 projection weights.
    pub w1: B::Dev,
    /// RGAT attention vectors `(a_src0, a_dst0, a_src1, a_dst1)`. `None`
    /// for RGCN: its attention vectors receive zero gradients, and
    /// `x - lr*0.0 == x` bitwise, so the host copies stay authoritative.
    pub att: Option<(B::Dev, B::Dev, B::Dev, B::Dev)>,
}

/// Device-resident schema constants plus reusable read-only seeds for the
/// device-resident step: uploaded once per schema (warm-up, not per batch).
pub struct DevSchema<B: ExecBackend> {
    /// `[RPAD]` i32 source types (stacked-projection gather index).
    pub src_type: B::Dev,
    /// `[RPAD]` i32 destination types (fusion segment ids).
    pub dst_type: B::Dev,
    /// Scalar i32 target type (`head_full` / `slab_pick` input).
    pub tgt: B::Dev,
    /// Learning rate pinned when the schema was built;
    /// [`StepExecutor::opt_step`] asserts bitwise agreement with its caller.
    pub lr_val: f32,
    /// Scalar f32 learning rate (the `sgd_*` module input).
    lr: B::Dev,
    /// `[TPAD, NS, F]` / `[TPAD, NS, H]` all-zero accumulator seeds for
    /// `proj_resident_bwd_*`. Uploaded with `valid_elems = 0` — a zeroed
    /// device allocation transfers nothing — and read-only, so one buffer
    /// serves every batch.
    zero_acc0: B::Dev,
    zero_acc1: B::Dev,
}

/// One batch's gradients, all device-resident. RGCN fills only the `_src`
/// weight slots; RGAT fills all eight (src/dst endpoint passes plus the
/// four attention vectors).
pub struct DevGrads<B: ExecBackend> {
    pub dw0_src: Option<B::Dev>,
    pub dw0_dst: Option<B::Dev>,
    pub dw1_src: Option<B::Dev>,
    pub dw1_dst: Option<B::Dev>,
    pub da_src0: Option<B::Dev>,
    pub da_dst0: Option<B::Dev>,
    pub da_src1: Option<B::Dev>,
    pub da_dst1: Option<B::Dev>,
}

impl<B: ExecBackend> DevGrads<B> {
    pub fn empty() -> Self {
        DevGrads {
            dw0_src: None,
            dw0_dst: None,
            dw1_src: None,
            dw1_dst: None,
            da_src0: None,
            da_dst0: None,
            da_src1: None,
            da_dst1: None,
        }
    }
}

/// One layer's merged edge tensors on the device (per-batch uploads — the
/// legitimate per-batch H2D traffic alongside the feature channel).
pub struct DevLayerEdges<B: ExecBackend> {
    pub src: B::Dev,
    pub dst: B::Dev,
    pub valid: B::Dev,
}

/// A batch fully staged on the device: the unit
/// [`StepExecutor::train_step_dev`] / [`StepExecutor::forward_step_dev`]
/// consume without touching host feature memory again.
pub struct DevBatch<B: ExecBackend> {
    /// `[TPAD, NS, F]` feature slab (cache-gather output or full upload).
    pub xs: B::Dev,
    pub labels: B::Dev,
    pub seed_mask: B::Dev,
    pub n_seed: usize,
    pub layers: Vec<DevLayerEdges<B>>,
}

/// Forward activations of one layer, all device-resident.
struct DevLayerFwd<B: ExecBackend> {
    pstack: B::Dev,
    /// RGAT only.
    pstack_dst: Option<B::Dev>,
    astack: B::Dev,
    hout: B::Dev,
}

// --------------------------------------------------------------------------
// the step executor
// --------------------------------------------------------------------------

/// Chains module dispatches on any [`ExecBackend`]: the same plans, counts,
/// and gradients whether the backend interprets (sim) or executes compiled
/// HLO (PJRT).
pub struct StepExecutor<'e, B: ExecBackend> {
    pub eng: &'e B,
    pub d: Dims,
    pub model: ModelKind,
    pub opt: OptConfig,
}

impl<'e, B: ExecBackend> StepExecutor<'e, B> {
    pub fn new(eng: &'e B, model: ModelKind, opt: OptConfig) -> Self {
        let d = Dims::from_backend(eng);
        StepExecutor { eng, d, model, opt }
    }

    fn proj_name(l: usize, bwd: bool, stacked: bool) -> &'static str {
        match (l, bwd, stacked) {
            (0, false, false) => "proj_fwd_l0",
            (1, false, false) => "proj_fwd_l1",
            (0, true, false) => "proj_bwd_l0",
            (1, true, false) => "proj_bwd_l1",
            (0, false, true) => "proj_stacked_fwd_l0",
            (1, false, true) => "proj_stacked_fwd_l1",
            (0, true, true) => "proj_stacked_bwd_l0",
            (1, true, true) => "proj_stacked_bwd_l1",
            _ => unreachable!(),
        }
    }

    fn agg_name(&self, l: usize, bwd: bool) -> &'static str {
        let merged = self.opt.merge;
        match (self.model, merged, l, bwd) {
            (ModelKind::Rgcn, false, 0, false) => "agg_mean_fwd_h",
            (ModelKind::Rgcn, false, 1, false) => "agg_mean_fwd_c",
            (ModelKind::Rgcn, false, 0, true) => "agg_mean_bwd_h",
            (ModelKind::Rgcn, false, 1, true) => "agg_mean_bwd_c",
            (ModelKind::Rgcn, true, 0, false) => "agg_merged_fwd_h",
            (ModelKind::Rgcn, true, 1, false) => "agg_merged_fwd_c",
            (ModelKind::Rgcn, true, 0, true) => "agg_merged_bwd_h",
            (ModelKind::Rgcn, true, 1, true) => "agg_merged_bwd_c",
            (ModelKind::Rgat, false, 0, false) => "att_agg_fwd_h",
            (ModelKind::Rgat, false, 1, false) => "att_agg_fwd_c",
            (ModelKind::Rgat, false, 0, true) => "att_agg_bwd_h",
            (ModelKind::Rgat, false, 1, true) => "att_agg_bwd_c",
            (ModelKind::Rgat, true, 0, false) => "att_merged_fwd_h",
            (ModelKind::Rgat, true, 1, false) => "att_merged_fwd_c",
            (ModelKind::Rgat, true, 0, true) => "att_merged_bwd_h",
            (ModelKind::Rgat, true, 1, true) => "att_merged_bwd_c",
            _ => unreachable!("2-layer model"),
        }
    }

    /// Per-relation weight tensor `[Fin, Fout]`.
    fn w_tensor(&self, params: &Params, l: usize, r: usize) -> HostTensor {
        let (fin, fout) = if l == 0 { (self.d.f, self.d.h) } else { (self.d.h, self.d.c) };
        HostTensor::f32(params.w_rel(l, r).to_vec(), &[fin, fout])
    }

    fn w_full(&self, params: &Params, l: usize) -> HostTensor {
        let (fin, fout) = if l == 0 { (self.d.f, self.d.h) } else { (self.d.h, self.d.c) };
        let w = if l == 0 { &params.w0 } else { &params.w1 };
        HostTensor::f32(w.clone(), &[self.d.rpad, fin, fout])
    }

    fn att_vecs(&self, params: &Params, l: usize) -> (HostTensor, HostTensor) {
        let fd = self.d.fd(l);
        let (s, t) = if l == 0 {
            (&params.a_src0, &params.a_dst0)
        } else {
            (&params.a_src1, &params.a_dst1)
        };
        (
            HostTensor::f32(s.clone(), &[self.d.rpad, fd]),
            HostTensor::f32(t.clone(), &[self.d.rpad, fd]),
        )
    }

    /// Project one endpoint slab stack: per-relation dispatches (baseline &
    /// paper-HiFuse) or one stacked dispatch (extension). `types` selects
    /// src or dst endpoint typing. Returns the `[RPAD, NS, Fd]` stack.
    fn project(
        &self,
        l: usize,
        hin: &HostTensor,
        params: &Params,
        schema: &SchemaTensors,
        edges: &LayerEdges,
        types: &[usize],
        types_i32: &HostTensor,
    ) -> Result<HostTensor> {
        let (d, eng) = (&self.d, self.eng);
        let fd = d.fd(l);
        if self.opt.stacked_proj {
            let w = self.w_full(params, l);
            let out = eng.run(
                Self::proj_name(l, false, true),
                Stage::Projection,
                Phase::Fwd,
                &[hin, &w, types_i32],
            )?;
            return Ok(out.into_iter().next().unwrap());
        }
        let _ = schema;
        let mut pstack = vec![0.0f32; d.rpad * d.ns * fd];
        for &r in &edges.live {
            let x = slab(hin, types[r], d.ns, if l == 0 { d.f } else { d.h })?;
            let w = self.w_tensor(params, l, r);
            let out = eng.run(
                Self::proj_name(l, false, false),
                Stage::Projection,
                Phase::Fwd,
                &[&x, &w],
            )?;
            let y = out.into_iter().next().unwrap();
            pstack[r * d.ns * fd..(r + 1) * d.ns * fd].copy_from_slice(y.as_f32()?);
            eng.recycle(y);
        }
        Ok(HostTensor::f32(pstack, &[d.rpad, d.ns, fd]))
    }

    fn layer_forward(
        &self,
        l: usize,
        hin: &HostTensor,
        params: &Params,
        schema: &SchemaTensors,
        edges: &LayerEdges,
    ) -> Result<LayerFwd<B::Dev>> {
        let (d, eng) = (&self.d, self.eng);
        let fd = d.fd(l);

        let pstack = self.project(l, hin, params, schema, edges, &schema.src_type,
            &schema.src_type_i32)?;
        let pstack_dst = if self.model == ModelKind::Rgat {
            Some(self.project(l, hin, params, schema, edges, &schema.dst_type,
                &schema.dst_type_i32)?)
        } else {
            None
        };

        let astack = if self.opt.merge {
            match self.model {
                ModelKind::Rgcn => {
                    // Device-resident: the merged aggregation output feeds
                    // fusion directly without a host round-trip (§Perf #5).
                    Stack::Dev(eng.run_dev(
                        self.agg_name(l, false),
                        Stage::Aggregation,
                        Phase::Fwd,
                        &[
                            Arg::Host(&pstack),
                            Arg::Host(&edges.src),
                            Arg::Host(&edges.dst),
                            Arg::Host(&edges.valid),
                        ],
                    )?)
                }
                ModelKind::Rgat => {
                    let pdst = pstack_dst.as_ref().unwrap();
                    let (a_s, a_d) = self.att_vecs(params, l);
                    Stack::Dev(eng.run_dev(
                        self.agg_name(l, false),
                        Stage::Aggregation,
                        Phase::Fwd,
                        &[
                            Arg::Host(&pstack),
                            Arg::Host(pdst),
                            Arg::Host(&a_s),
                            Arg::Host(&a_d),
                            Arg::Host(&edges.src),
                            Arg::Host(&edges.dst),
                            Arg::Host(&edges.valid),
                        ],
                    )?)
                }
            }
        } else {
            let pstack_f = pstack.as_f32()?;
            let mut astack = vec![0.0f32; d.rpad * d.ns * fd];
            for &r in &edges.live {
                let feat =
                    HostTensor::f32(stack_block(pstack_f, r, d.ns, fd).to_vec(), &[d.ns, fd]);
                let (src, dst, valid) = &edges.per_rel[r];
                let out = match self.model {
                    ModelKind::Rgcn => eng.run(
                        self.agg_name(l, false),
                        Stage::Aggregation,
                        Phase::Fwd,
                        &[&feat, src, dst, valid],
                    )?,
                    ModelKind::Rgat => {
                        let pd = pstack_dst.as_ref().unwrap().as_f32()?;
                        let fdst =
                            HostTensor::f32(stack_block(pd, r, d.ns, fd).to_vec(), &[d.ns, fd]);
                        let (a_s, a_d) = self.att_vecs(params, l);
                        let asl =
                            HostTensor::f32(a_s.as_f32()?[r * fd..(r + 1) * fd].to_vec(), &[fd]);
                        let adl =
                            HostTensor::f32(a_d.as_f32()?[r * fd..(r + 1) * fd].to_vec(), &[fd]);
                        eng.run(
                            self.agg_name(l, false),
                            Stage::Aggregation,
                            Phase::Fwd,
                            &[&feat, &fdst, &asl, &adl, src, dst, valid],
                        )?
                    }
                };
                let y = out.into_iter().next().unwrap();
                astack[r * d.ns * fd..(r + 1) * d.ns * fd].copy_from_slice(y.as_f32()?);
                eng.recycle(y);
            }
            Stack::Host(HostTensor::f32(astack, &[d.rpad, d.ns, fd]))
        };

        let fuse_name = if l == 0 { "fuse_relu_fwd_h" } else { "fuse_lin_fwd_c" };
        let hout = eng
            .run_dev(
                fuse_name,
                Stage::Fusion,
                Phase::Fwd,
                &[Arg::Host(&schema.dst_type_i32), astack.as_arg()],
            )?
            .into_host()?;

        Ok(LayerFwd { pstack, pstack_dst, astack, hout })
    }

    /// Backward through one layer: consumes `dhout`, returns `dhin` and
    /// fills this layer's weight gradients.
    #[allow(clippy::too_many_arguments)]
    fn layer_backward(
        &self,
        l: usize,
        hin: &HostTensor,
        fwd: &LayerFwd<B::Dev>,
        dhout: &HostTensor,
        params: &Params,
        grads: &mut Params,
        schema: &SchemaTensors,
        edges: &LayerEdges,
    ) -> Result<HostTensor> {
        let (d, eng) = (&self.d, self.eng);
        let fd = d.fd(l);
        let fin = if l == 0 { d.f } else { d.h };

        let fuse_name = if l == 0 { "fuse_relu_bwd_h" } else { "fuse_lin_bwd_c" };
        // Merged plan: fusion backward and (RGCN) aggregation backward chain
        // device-resident; only the final dp comes back to the host for
        // per-relation projection slicing (§Perf #5).
        let da: Stack<B::Dev> = if self.opt.merge {
            Stack::Dev(eng.run_dev(
                fuse_name,
                Stage::Fusion,
                Phase::Bwd,
                &[Arg::Host(&schema.dst_type_i32), fwd.astack.as_arg(), Arg::Host(dhout)],
            )?)
        } else {
            Stack::Host(
                eng.run(
                    fuse_name,
                    Stage::Fusion,
                    Phase::Bwd,
                    &[&schema.dst_type_i32, fwd.astack.as_host(), dhout],
                )?
                .into_iter()
                .next()
                .unwrap(),
            )
        };

        // --- aggregation backward: dp (and attention grads for RGAT).
        // `dp`/`dp_dst` are dispatch outputs in merged mode (recycled after
        // projection backward) and executor-assembled stacks otherwise.
        let (dp, dp_dst): (HostTensor, Option<HostTensor>) = if self.opt.merge {
            match self.model {
                ModelKind::Rgcn => {
                    let dp_dev = eng.run_dev(
                        self.agg_name(l, true),
                        Stage::Aggregation,
                        Phase::Bwd,
                        &[
                            Arg::Host(&edges.src),
                            Arg::Host(&edges.dst),
                            Arg::Host(&edges.valid),
                            da.as_arg(),
                        ],
                    )?;
                    self.recycle_stack(da);
                    (dp_dev.into_host()?, None)
                }
                ModelKind::Rgat => {
                    // The attention VJP module is multi-output, so its da
                    // input must be host-resident.
                    let da_host = match da {
                        Stack::Dev(dev) => dev.into_host()?,
                        Stack::Host(h) => h,
                    };
                    let pdst = fwd.pstack_dst.as_ref().unwrap();
                    let (a_s, a_d) = self.att_vecs(params, l);
                    let mut out = eng
                        .run(
                            self.agg_name(l, true),
                            Stage::Aggregation,
                            Phase::Bwd,
                            &[&fwd.pstack, pdst, &a_s, &a_d, &edges.src, &edges.dst,
                                &edges.valid, &da_host],
                        )?
                        .into_iter();
                    eng.recycle(da_host);
                    let dfs = out.next().unwrap();
                    let dfd = out.next().unwrap();
                    let das = out.next().unwrap();
                    let dad = out.next().unwrap();
                    self.store_att_grads(l, grads, das.as_f32()?, dad.as_f32()?);
                    eng.recycle(das);
                    eng.recycle(dad);
                    (dfs, Some(dfd))
                }
            }
        } else {
            let pstack_f = fwd.pstack.as_f32()?;
            let mut dp = vec![0.0f32; d.rpad * d.ns * fd];
            let mut dpd = vec![0.0f32; d.rpad * d.ns * fd];
            let da_flat = da.as_host().as_f32()?;
            for &r in &edges.live {
                let da_r =
                    HostTensor::f32(stack_block(da_flat, r, d.ns, fd).to_vec(), &[d.ns, fd]);
                let (src, dst, valid) = &edges.per_rel[r];
                match self.model {
                    ModelKind::Rgcn => {
                        let feat = HostTensor::f32(
                            stack_block(pstack_f, r, d.ns, fd).to_vec(),
                            &[d.ns, fd],
                        );
                        let out = eng.run(
                            self.agg_name(l, true),
                            Stage::Aggregation,
                            Phase::Bwd,
                            &[&feat, src, dst, valid, &da_r],
                        )?;
                        let g = out.into_iter().next().unwrap();
                        dp[r * d.ns * fd..(r + 1) * d.ns * fd].copy_from_slice(g.as_f32()?);
                        eng.recycle(g);
                    }
                    ModelKind::Rgat => {
                        let feat = HostTensor::f32(
                            stack_block(pstack_f, r, d.ns, fd).to_vec(),
                            &[d.ns, fd],
                        );
                        let pdall = fwd.pstack_dst.as_ref().unwrap().as_f32()?;
                        let fdst = HostTensor::f32(
                            stack_block(pdall, r, d.ns, fd).to_vec(),
                            &[d.ns, fd],
                        );
                        let (a_s_all, a_d_all) = self.att_vecs(params, l);
                        let asl = HostTensor::f32(
                            a_s_all.as_f32()?[r * fd..(r + 1) * fd].to_vec(),
                            &[fd],
                        );
                        let adl = HostTensor::f32(
                            a_d_all.as_f32()?[r * fd..(r + 1) * fd].to_vec(),
                            &[fd],
                        );
                        let mut out = eng
                            .run(
                                self.agg_name(l, true),
                                Stage::Aggregation,
                                Phase::Bwd,
                                &[&feat, &fdst, &asl, &adl, src, dst, valid, &da_r],
                            )?
                            .into_iter();
                        let dfs = out.next().unwrap();
                        let dfd = out.next().unwrap();
                        let das = out.next().unwrap();
                        let dad = out.next().unwrap();
                        dp[r * d.ns * fd..(r + 1) * d.ns * fd].copy_from_slice(dfs.as_f32()?);
                        dpd[r * d.ns * fd..(r + 1) * d.ns * fd]
                            .copy_from_slice(dfd.as_f32()?);
                        let (gs, gd) = self.att_grad_slices(l, grads);
                        gs[r * fd..(r + 1) * fd].copy_from_slice(das.as_f32()?);
                        gd[r * fd..(r + 1) * fd].copy_from_slice(dad.as_f32()?);
                        eng.recycle(dfs);
                        eng.recycle(dfd);
                        eng.recycle(das);
                        eng.recycle(dad);
                    }
                }
            }
            self.recycle_stack(da);
            (
                HostTensor::f32(dp, &[d.rpad, d.ns, fd]),
                (self.model == ModelKind::Rgat)
                    .then_some(HostTensor::f32(dpd, &[d.rpad, d.ns, fd])),
            )
        };

        // --- projection backward: dhin + dW.
        let mut dhin = vec![0.0f32; d.tpad * d.ns * fin];
        self.project_backward(l, hin, params, grads, schema, edges, &dp, &schema.src_type,
            &schema.src_type_i32, &mut dhin, false)?;
        if let Some(dpd) = &dp_dst {
            self.project_backward(l, hin, params, grads, schema, edges, dpd,
                &schema.dst_type, &schema.dst_type_i32, &mut dhin, true)?;
        }
        // Merged-mode dp tensors are dispatch outputs: hand them back.
        if self.opt.merge {
            eng.recycle(dp);
            if let Some(t) = dp_dst {
                eng.recycle(t);
            }
        }
        Ok(HostTensor::f32(dhin, &[d.tpad, d.ns, fin]))
    }

    /// Recycle a consumed activation that is known to be a dispatch output
    /// (device-resident buffers always are; host ones only when the caller
    /// knows their provenance).
    fn recycle_stack(&self, s: Stack<B::Dev>) {
        match s {
            Stack::Host(h) => self.eng.recycle(h),
            Stack::Dev(dv) => self.eng.recycle_dev(dv),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn project_backward(
        &self,
        l: usize,
        hin: &HostTensor,
        params: &Params,
        grads: &mut Params,
        schema: &SchemaTensors,
        edges: &LayerEdges,
        dp: &HostTensor,
        types: &[usize],
        types_i32: &HostTensor,
        dhin: &mut [f32],
        accumulate_w: bool,
    ) -> Result<()> {
        let (d, eng) = (&self.d, self.eng);
        let fd = d.fd(l);
        let fin = if l == 0 { d.f } else { d.h };
        if self.opt.stacked_proj {
            let w = self.w_full(params, l);
            let mut out = eng
                .run(
                    Self::proj_name(l, true, true),
                    Stage::Projection,
                    Phase::Bwd,
                    &[hin, &w, types_i32, dp],
                )?
                .into_iter();
            let dxs = out.next().unwrap();
            let dw = out.next().unwrap();
            tensor::add_assign(dhin, dxs.as_f32()?);
            let gw = if l == 0 { &mut grads.w0 } else { &mut grads.w1 };
            tensor::add_assign(gw, dw.as_f32()?);
            eng.recycle(dxs);
            eng.recycle(dw);
            return Ok(());
        }
        let _ = schema;
        let dp_f = dp.as_f32()?;
        for &r in &edges.live {
            let x = slab(hin, types[r], d.ns, fin)?;
            let w = self.w_tensor(params, l, r);
            let dy = HostTensor::f32(stack_block(dp_f, r, d.ns, fd).to_vec(), &[d.ns, fd]);
            let mut out = eng
                .run(Self::proj_name(l, true, false), Stage::Projection, Phase::Bwd,
                    &[&x, &w, &dy])?
                .into_iter();
            let dx = out.next().unwrap();
            let dw = out.next().unwrap();
            let t = types[r];
            tensor::add_assign(&mut dhin[t * d.ns * fin..(t + 1) * d.ns * fin], dx.as_f32()?);
            let gw = if l == 0 { &mut grads.w0 } else { &mut grads.w1 };
            let gw_r = &mut gw[r * fin * fd..(r + 1) * fin * fd];
            if accumulate_w {
                tensor::add_assign(gw_r, dw.as_f32()?);
            } else {
                gw_r.copy_from_slice(dw.as_f32()?);
            }
            eng.recycle(dx);
            eng.recycle(dw);
        }
        Ok(())
    }

    fn store_att_grads(&self, l: usize, grads: &mut Params, das: &[f32], dad: &[f32]) {
        let (gs, gd) = self.att_grad_slices(l, grads);
        gs.copy_from_slice(das);
        gd.copy_from_slice(dad);
    }

    fn att_grad_slices<'g>(
        &self,
        l: usize,
        grads: &'g mut Params,
    ) -> (&'g mut [f32], &'g mut [f32]) {
        if l == 0 {
            (&mut grads.a_src0, &mut grads.a_dst0)
        } else {
            (&mut grads.a_src1, &mut grads.a_dst1)
        }
    }

    /// Hand a consumed layer's buffers back to the backend. Only dispatch
    /// outputs are recycled: the non-stacked projection stack and the
    /// non-merged aggregation stack are executor-assembled, so they are
    /// dropped normally (recycling them would grow the pool unboundedly).
    fn recycle_layer(&self, l: LayerFwd<B::Dev>) {
        if self.opt.stacked_proj {
            self.eng.recycle(l.pstack);
            if let Some(p) = l.pstack_dst {
                self.eng.recycle(p);
            }
        }
        if let Stack::Dev(dv) = l.astack {
            self.eng.recycle_dev(dv);
        }
        self.eng.recycle(l.hout);
    }

    /// Forward + loss + backward, **without** the parameter update: returns
    /// the step result and the raw gradients. This is the unit the
    /// data-parallel replica path all-reduces (DESIGN.md §4) — gradients are
    /// bitwise-deterministic in (`params`, `batch`), independent of thread
    /// count, so summing them in a fixed order is replica-count-invariant.
    pub fn grad_step(
        &self,
        params: &Params,
        schema: &SchemaTensors,
        batch: &BatchData,
    ) -> Result<(StepResult, Params)> {
        let (d, eng) = (&self.d, self.eng);
        assert_eq!(batch.layers.len(), 2, "2-layer model");

        // ---- forward
        let l0 = self.layer_forward(0, &batch.xs, params, schema, &batch.layers[0])?;
        let l1 = self.layer_forward(1, &l0.hout, params, schema, &batch.layers[1])?;

        // ---- head (loss + dlogits + accuracy in one dispatch)
        let logits = slab(&l1.hout, schema.target_type, d.ns, d.c)?;
        let mut out = eng
            .run("head", Stage::Head, Phase::Fwd,
                &[&logits, &batch.labels, &batch.seed_mask])?
            .into_iter();
        let loss = out.next().unwrap().scalar()?;
        let dlogits = out.next().unwrap();
        let ncorrect = out.next().unwrap().scalar()?;

        // ---- backward
        let mut grads = params.zeros_like();
        let mut dh2 = vec![0.0f32; d.tpad * d.ns * d.c];
        let t = schema.target_type;
        dh2[t * d.ns * d.c..(t + 1) * d.ns * d.c].copy_from_slice(dlogits.as_f32()?);
        eng.recycle(dlogits);
        let dh2 = HostTensor::f32(dh2, &[d.tpad, d.ns, d.c]);

        let dh1 = self.layer_backward(1, &l0.hout, &l1, &dh2, params, &mut grads, schema,
            &batch.layers[1])?;
        let _dx = self.layer_backward(0, &batch.xs, &l0, &dh1, params, &mut grads, schema,
            &batch.layers[0])?;
        self.recycle_layer(l1);
        self.recycle_layer(l0);

        Ok((StepResult { loss, ncorrect, n_seed: batch.n_seed }, grads))
    }

    /// Run one full training step (forward, loss, backward, SGD update).
    pub fn train_step(
        &self,
        params: &mut Params,
        schema: &SchemaTensors,
        batch: &BatchData,
        lr: f32,
    ) -> Result<StepResult> {
        let (res, grads) = self.grad_step(params, schema, batch)?;
        params.sgd(&grads, lr);
        Ok(res)
    }

    /// Forward-only pass returning (loss, ncorrect) — evaluation helper.
    pub fn eval_step(
        &self,
        params: &Params,
        schema: &SchemaTensors,
        batch: &BatchData,
    ) -> Result<StepResult> {
        let (d, eng) = (&self.d, self.eng);
        let l0 = self.layer_forward(0, &batch.xs, params, schema, &batch.layers[0])?;
        let l1 = self.layer_forward(1, &l0.hout, params, schema, &batch.layers[1])?;
        let logits = slab(&l1.hout, schema.target_type, d.ns, d.c)?;
        let mut out = eng
            .run("head", Stage::Head, Phase::Fwd,
                &[&logits, &batch.labels, &batch.seed_mask])?
            .into_iter();
        let loss = out.next().unwrap().scalar()?;
        if let Some(dl) = out.next() {
            eng.recycle(dl);
        }
        let ncorrect = out.next().unwrap().scalar()?;
        self.recycle_layer(l1);
        self.recycle_layer(l0);
        Ok(StepResult { loss, ncorrect, n_seed: batch.n_seed })
    }

    /// Inference forward: the forward half of [`StepExecutor::grad_step`]
    /// with no head dispatch, no labels, and no optimizer state — the unit
    /// the serving path runs per coalesced batch (DESIGN.md §8). Returns
    /// the target-type `[NS, C]` logits; the readback is charged to the
    /// dispatch log as D2H traffic (`Counters::d2h_bytes`), which is the
    /// serve path's whole device→host footprint per batch.
    ///
    /// Like `grad_step`, the output is bitwise-deterministic in
    /// (`params`, `batch`) for any thread count, which is what makes
    /// per-request predictions invariant under `--replicas`/`--producers`/
    /// `--threads`/pipeline (pinned by `tests/serve_parity.rs`).
    pub fn forward_step(
        &self,
        params: &Params,
        schema: &SchemaTensors,
        batch: &BatchData,
    ) -> Result<HostTensor> {
        let (d, eng) = (&self.d, self.eng);
        assert_eq!(batch.layers.len(), 2, "2-layer model");
        let l0 = self.layer_forward(0, &batch.xs, params, schema, &batch.layers[0])?;
        let l1 = self.layer_forward(1, &l0.hout, params, schema, &batch.layers[1])?;
        let logits = slab(&l1.hout, schema.target_type, d.ns, d.c)?;
        eng.counters().borrow_mut().add_d2h(logits.size_bytes() as u64);
        self.recycle_layer(l1);
        self.recycle_layer(l0);
        Ok(logits)
    }

    // ----------------------------------------------------------------------
    // device-resident step (DESIGN.md §7): activations, parameters, and
    // gradients chain as DevBufs; only the idx/edge uploads (H2D) and the
    // loss/metric scalars or serve logits (D2H) cross the PCIe boundary.
    // Every dispatch reuses the host-staged modules' math, so trajectories
    // are bitwise identical to the hifuse+stacked host path
    // (tests/residency.rs).
    // ----------------------------------------------------------------------

    /// The device-resident step requires the merged + stacked plan: its
    /// modules only exist in that configuration.
    fn assert_dev_plan(&self) {
        assert!(
            self.opt.merge && self.opt.stacked_proj,
            "device-resident step requires merge + stacked_proj"
        );
    }

    /// Upload schema constants and zero-accumulator seeds (once per
    /// schema/learning-rate, warm-up traffic).
    pub fn make_dev_schema(&self, schema: &SchemaTensors, lr: f32) -> Result<DevSchema<B>> {
        let (d, eng) = (&self.d, self.eng);
        let tgt = HostTensor::scalar_i32(schema.target_type as i32);
        let lrt = HostTensor::scalar_f32(lr);
        let z0 = HostTensor::zeros_f32(&[d.tpad, d.ns, d.f]);
        let z1 = HostTensor::zeros_f32(&[d.tpad, d.ns, d.h]);
        Ok(DevSchema {
            src_type: eng.upload(&schema.src_type_i32, d.rpad)?,
            dst_type: eng.upload(&schema.dst_type_i32, d.rpad)?,
            tgt: eng.upload(&tgt, 1)?,
            lr_val: lr,
            lr: eng.upload(&lrt, 1)?,
            zero_acc0: eng.upload(&z0, 0)?,
            zero_acc1: eng.upload(&z1, 0)?,
        })
    }

    /// Place the full parameter set on the device (H2D, once at warm-up).
    pub fn upload_params(&self, params: &Params) -> Result<DevParams<B>> {
        self.upload_params_impl(params, false)
    }

    /// [`StepExecutor::upload_params`] over the modeled replica interconnect
    /// (the per-round parameter broadcast of the data-parallel path —
    /// counted in `Counters::p2p_bytes`).
    ///
    /// Also the serve plane's lane param swap primitive (DESIGN.md §10):
    /// a device-resident lane crossing a hot-refresh boundary recycles its
    /// staged set ([`StepExecutor::recycle_dev_params`] — the buffers drop
    /// back into the arena, so a swap allocates nothing in steady state)
    /// and re-stages the new parameters through this call. The p2p charge
    /// makes refresh traffic visible in the same counter the training
    /// broadcast uses.
    pub fn upload_params_peer(&self, params: &Params) -> Result<DevParams<B>> {
        self.upload_params_impl(params, true)
    }

    fn upload_params_impl(&self, params: &Params, peer: bool) -> Result<DevParams<B>> {
        let d = &self.d;
        let up = |t: HostTensor| {
            let n = t.len();
            if peer {
                self.eng.upload_peer(&t, n)
            } else {
                self.eng.upload(&t, n)
            }
        };
        let w0 = up(HostTensor::f32(params.w0.clone(), &[d.rpad, d.f, d.h]))?;
        let w1 = up(HostTensor::f32(params.w1.clone(), &[d.rpad, d.h, d.c]))?;
        let att = if self.model == ModelKind::Rgat {
            Some((
                up(HostTensor::f32(params.a_src0.clone(), &[d.rpad, d.h]))?,
                up(HostTensor::f32(params.a_dst0.clone(), &[d.rpad, d.h]))?,
                up(HostTensor::f32(params.a_src1.clone(), &[d.rpad, d.c]))?,
                up(HostTensor::f32(params.a_dst1.clone(), &[d.rpad, d.c]))?,
            ))
        } else {
            None
        };
        Ok(DevParams { w0, w1, att })
    }

    /// Stage a prepared batch on the device. `xs` carries the feature slab
    /// when the caller already produced it there (the cache-gather path);
    /// otherwise the full host slab uploads here — the one site that
    /// charges feature bytes to H2D on the cache-off path.
    pub fn upload_batch(&self, batch: &BatchData, xs: Option<B::Dev>) -> Result<DevBatch<B>> {
        let eng = self.eng;
        let xs = match xs {
            Some(dv) => dv,
            None => eng.upload(&batch.xs, batch.xs.len())?,
        };
        let mut layers = Vec::with_capacity(batch.layers.len());
        for e in &batch.layers {
            layers.push(DevLayerEdges {
                src: eng.upload(&e.src, e.src.len())?,
                dst: eng.upload(&e.dst, e.dst.len())?,
                valid: eng.upload(&e.valid, e.valid.len())?,
            });
        }
        Ok(DevBatch {
            xs,
            labels: eng.upload(&batch.labels, batch.labels.len())?,
            seed_mask: eng.upload(&batch.seed_mask, batch.seed_mask.len())?,
            n_seed: batch.n_seed,
            layers,
        })
    }

    pub fn recycle_batch(&self, b: DevBatch<B>) {
        let eng = self.eng;
        eng.recycle_dev(b.xs);
        eng.recycle_dev(b.labels);
        eng.recycle_dev(b.seed_mask);
        for e in b.layers {
            eng.recycle_dev(e.src);
            eng.recycle_dev(e.dst);
            eng.recycle_dev(e.valid);
        }
    }

    pub fn recycle_dev_params(&self, p: DevParams<B>) {
        let eng = self.eng;
        eng.recycle_dev(p.w0);
        eng.recycle_dev(p.w1);
        if let Some((a, b, c, dd)) = p.att {
            eng.recycle_dev(a);
            eng.recycle_dev(b);
            eng.recycle_dev(c);
            eng.recycle_dev(dd);
        }
    }

    pub fn recycle_dev_schema(&self, s: DevSchema<B>) {
        let eng = self.eng;
        eng.recycle_dev(s.src_type);
        eng.recycle_dev(s.dst_type);
        eng.recycle_dev(s.tgt);
        eng.recycle_dev(s.lr);
        eng.recycle_dev(s.zero_acc0);
        eng.recycle_dev(s.zero_acc1);
    }

    fn dev_w<'p>(&self, dp: &'p DevParams<B>, l: usize) -> &'p B::Dev {
        if l == 0 {
            &dp.w0
        } else {
            &dp.w1
        }
    }

    fn dev_att<'p>(&self, dp: &'p DevParams<B>, l: usize) -> (&'p B::Dev, &'p B::Dev) {
        match dp.att.as_ref() {
            Some((s0, d0, s1, d1)) => {
                if l == 0 {
                    (s0, d0)
                } else {
                    (s1, d1)
                }
            }
            None => panic!("RGAT device params missing attention vectors"),
        }
    }

    fn zero_acc<'s>(&self, ds: &'s DevSchema<B>, l: usize) -> &'s B::Dev {
        if l == 0 {
            &ds.zero_acc0
        } else {
            &ds.zero_acc1
        }
    }

    fn layer_forward_dev(
        &self,
        l: usize,
        hin: &B::Dev,
        dp: &DevParams<B>,
        ds: &DevSchema<B>,
        edges: &DevLayerEdges<B>,
    ) -> Result<DevLayerFwd<B>> {
        let eng = self.eng;
        let w = self.dev_w(dp, l);
        let pstack = eng.run_dev(
            Self::proj_name(l, false, true),
            Stage::Projection,
            Phase::Fwd,
            &[Arg::Dev(hin), Arg::Dev(w), Arg::Dev(&ds.src_type)],
        )?;
        let (pstack_dst, astack) = match self.model {
            ModelKind::Rgcn => {
                let a = eng.run_dev(
                    self.agg_name(l, false),
                    Stage::Aggregation,
                    Phase::Fwd,
                    &[
                        Arg::Dev(&pstack),
                        Arg::Dev(&edges.src),
                        Arg::Dev(&edges.dst),
                        Arg::Dev(&edges.valid),
                    ],
                )?;
                (None, a)
            }
            ModelKind::Rgat => {
                let pdst = eng.run_dev(
                    Self::proj_name(l, false, true),
                    Stage::Projection,
                    Phase::Fwd,
                    &[Arg::Dev(hin), Arg::Dev(w), Arg::Dev(&ds.dst_type)],
                )?;
                let (a_s, a_d) = self.dev_att(dp, l);
                let a = eng.run_dev(
                    self.agg_name(l, false),
                    Stage::Aggregation,
                    Phase::Fwd,
                    &[
                        Arg::Dev(&pstack),
                        Arg::Dev(&pdst),
                        Arg::Dev(a_s),
                        Arg::Dev(a_d),
                        Arg::Dev(&edges.src),
                        Arg::Dev(&edges.dst),
                        Arg::Dev(&edges.valid),
                    ],
                )?;
                (Some(pdst), a)
            }
        };
        let fuse_name = if l == 0 { "fuse_relu_fwd_h" } else { "fuse_lin_fwd_c" };
        let hout = eng.run_dev(
            fuse_name,
            Stage::Fusion,
            Phase::Fwd,
            &[Arg::Dev(&ds.dst_type), Arg::Dev(&astack)],
        )?;
        Ok(DevLayerFwd { pstack, pstack_dst, astack, hout })
    }

    /// Backward through one layer, fully on-device: consumes `dhout`
    /// (borrowed; caller recycles), fills this layer's slots in `grads`,
    /// returns the device-resident `dhin`.
    #[allow(clippy::too_many_arguments)]
    fn layer_backward_dev(
        &self,
        l: usize,
        hin: &B::Dev,
        fwd: &DevLayerFwd<B>,
        dhout: &B::Dev,
        dp: &DevParams<B>,
        ds: &DevSchema<B>,
        edges: &DevLayerEdges<B>,
        grads: &mut DevGrads<B>,
    ) -> Result<B::Dev> {
        let eng = self.eng;
        let fuse_name = if l == 0 { "fuse_relu_bwd_h" } else { "fuse_lin_bwd_c" };
        let da = eng.run_dev(
            fuse_name,
            Stage::Fusion,
            Phase::Bwd,
            &[Arg::Dev(&ds.dst_type), Arg::Dev(&fwd.astack), Arg::Dev(dhout)],
        )?;
        let resident_name =
            if l == 0 { "proj_resident_bwd_l0" } else { "proj_resident_bwd_l1" };
        let w = self.dev_w(dp, l);
        match self.model {
            ModelKind::Rgcn => {
                let dpg = eng.run_dev(
                    self.agg_name(l, true),
                    Stage::Aggregation,
                    Phase::Bwd,
                    &[
                        Arg::Dev(&edges.src),
                        Arg::Dev(&edges.dst),
                        Arg::Dev(&edges.valid),
                        Arg::Dev(&da),
                    ],
                )?;
                eng.recycle_dev(da);
                let mut out = eng
                    .run_dev_multi(
                        resident_name,
                        Stage::Projection,
                        Phase::Bwd,
                        &[
                            Arg::Dev(hin),
                            Arg::Dev(w),
                            Arg::Dev(&ds.src_type),
                            Arg::Dev(&dpg),
                            Arg::Dev(self.zero_acc(ds, l)),
                        ],
                    )?
                    .into_iter();
                let dhin = out.next().unwrap();
                let dw = out.next().unwrap();
                eng.recycle_dev(dpg);
                let slot = if l == 0 { &mut grads.dw0_src } else { &mut grads.dw1_src };
                *slot = Some(dw);
                Ok(dhin)
            }
            ModelKind::Rgat => {
                let (a_s, a_d) = self.dev_att(dp, l);
                let mut out = eng
                    .run_dev_multi(
                        self.agg_name(l, true),
                        Stage::Aggregation,
                        Phase::Bwd,
                        &[
                            Arg::Dev(&fwd.pstack),
                            Arg::Dev(fwd.pstack_dst.as_ref().unwrap()),
                            Arg::Dev(a_s),
                            Arg::Dev(a_d),
                            Arg::Dev(&edges.src),
                            Arg::Dev(&edges.dst),
                            Arg::Dev(&edges.valid),
                            Arg::Dev(&da),
                        ],
                    )?
                    .into_iter();
                let dfs = out.next().unwrap();
                let dfd = out.next().unwrap();
                let das = out.next().unwrap();
                let dad = out.next().unwrap();
                eng.recycle_dev(da);
                // Two endpoint passes chain through the resident
                // accumulator: src seeds from zeros, dst folds on top —
                // the exact `add_assign` order of the host executor.
                let mut src_out = eng
                    .run_dev_multi(
                        resident_name,
                        Stage::Projection,
                        Phase::Bwd,
                        &[
                            Arg::Dev(hin),
                            Arg::Dev(w),
                            Arg::Dev(&ds.src_type),
                            Arg::Dev(&dfs),
                            Arg::Dev(self.zero_acc(ds, l)),
                        ],
                    )?
                    .into_iter();
                let dhin_src = src_out.next().unwrap();
                let dw_src = src_out.next().unwrap();
                eng.recycle_dev(dfs);
                let mut dst_out = eng
                    .run_dev_multi(
                        resident_name,
                        Stage::Projection,
                        Phase::Bwd,
                        &[
                            Arg::Dev(hin),
                            Arg::Dev(w),
                            Arg::Dev(&ds.dst_type),
                            Arg::Dev(&dfd),
                            Arg::Dev(&dhin_src),
                        ],
                    )?
                    .into_iter();
                let dhin = dst_out.next().unwrap();
                let dw_dst = dst_out.next().unwrap();
                eng.recycle_dev(dfd);
                eng.recycle_dev(dhin_src);
                if l == 0 {
                    grads.dw0_src = Some(dw_src);
                    grads.dw0_dst = Some(dw_dst);
                    grads.da_src0 = Some(das);
                    grads.da_dst0 = Some(dad);
                } else {
                    grads.dw1_src = Some(dw_src);
                    grads.dw1_dst = Some(dw_dst);
                    grads.da_src1 = Some(das);
                    grads.da_dst1 = Some(dad);
                }
                Ok(dhin)
            }
        }
    }

    fn recycle_layer_dev(&self, l: DevLayerFwd<B>) {
        let eng = self.eng;
        eng.recycle_dev(l.pstack);
        if let Some(p) = l.pstack_dst {
            eng.recycle_dev(p);
        }
        eng.recycle_dev(l.astack);
        eng.recycle_dev(l.hout);
    }

    /// Fetch a device scalar (loss / ncorrect): the 4-byte D2H reads that
    /// are the training path's entire per-batch device→host traffic.
    fn fetch_scalar(&self, d: B::Dev) -> Result<f32> {
        let t = self.eng.fetch(d)?;
        let v = t.scalar()?;
        self.eng.recycle(t);
        Ok(v)
    }

    /// Device-resident forward + loss + backward: the analogue of
    /// [`StepExecutor::grad_step`] with gradients left on the device in
    /// `grads` (for [`StepExecutor::opt_step`] or
    /// [`StepExecutor::fetch_grads_peer`]).
    pub fn grad_step_dev(
        &self,
        dp: &DevParams<B>,
        ds: &DevSchema<B>,
        batch: &DevBatch<B>,
        grads: &mut DevGrads<B>,
    ) -> Result<StepResult> {
        self.assert_dev_plan();
        let eng = self.eng;
        assert_eq!(batch.layers.len(), 2, "2-layer model");

        let l0 = self.layer_forward_dev(0, &batch.xs, dp, ds, &batch.layers[0])?;
        let l1 = self.layer_forward_dev(1, &l0.hout, dp, ds, &batch.layers[1])?;

        let mut out = eng
            .run_dev_multi(
                "head_full",
                Stage::Head,
                Phase::Fwd,
                &[
                    Arg::Dev(&l1.hout),
                    Arg::Dev(&batch.labels),
                    Arg::Dev(&batch.seed_mask),
                    Arg::Dev(&ds.tgt),
                ],
            )?
            .into_iter();
        let loss = self.fetch_scalar(out.next().unwrap())?;
        let dh2 = out.next().unwrap();
        let ncorrect = self.fetch_scalar(out.next().unwrap())?;

        let dh1 = self.layer_backward_dev(1, &l0.hout, &l1, &dh2, dp, ds, &batch.layers[1],
            grads)?;
        eng.recycle_dev(dh2);
        let dx = self.layer_backward_dev(0, &batch.xs, &l0, &dh1, dp, ds, &batch.layers[0],
            grads)?;
        eng.recycle_dev(dh1);
        eng.recycle_dev(dx);
        self.recycle_layer_dev(l1);
        self.recycle_layer_dev(l0);

        Ok(StepResult { loss, ncorrect, n_seed: batch.n_seed })
    }

    /// Apply one fused on-device SGD dispatch, swapping the parameter
    /// handles in place and consuming the gradients. `lr` must be bitwise
    /// the rate pinned in `ds` — it rides in as a resident scalar, so a
    /// drifting caller would silently train at the stale rate.
    pub fn opt_step(
        &self,
        dp: &mut DevParams<B>,
        ds: &DevSchema<B>,
        grads: DevGrads<B>,
        lr: f32,
    ) -> Result<()> {
        let eng = self.eng;
        assert_eq!(
            lr.to_bits(),
            ds.lr_val.to_bits(),
            "opt_step lr {lr} differs from the DevSchema rate {}",
            ds.lr_val
        );
        match self.model {
            ModelKind::Rgcn => {
                let dw0 = grads.dw0_src.expect("missing layer-0 weight gradient");
                let dw1 = grads.dw1_src.expect("missing layer-1 weight gradient");
                let mut out = eng
                    .run_dev_multi(
                        "sgd_rgcn",
                        Stage::Head,
                        Phase::Bwd,
                        &[
                            Arg::Dev(&dp.w0),
                            Arg::Dev(&dp.w1),
                            Arg::Dev(&dw0),
                            Arg::Dev(&dw1),
                            Arg::Dev(&ds.lr),
                        ],
                    )?
                    .into_iter();
                let nw0 = out.next().unwrap();
                let nw1 = out.next().unwrap();
                eng.recycle_dev(std::mem::replace(&mut dp.w0, nw0));
                eng.recycle_dev(std::mem::replace(&mut dp.w1, nw1));
                eng.recycle_dev(dw0);
                eng.recycle_dev(dw1);
            }
            ModelKind::Rgat => {
                let dw0s = grads.dw0_src.expect("missing dw0_src");
                let dw0d = grads.dw0_dst.expect("missing dw0_dst");
                let dw1s = grads.dw1_src.expect("missing dw1_src");
                let dw1d = grads.dw1_dst.expect("missing dw1_dst");
                let das0 = grads.da_src0.expect("missing da_src0");
                let dad0 = grads.da_dst0.expect("missing da_dst0");
                let das1 = grads.da_src1.expect("missing da_src1");
                let dad1 = grads.da_dst1.expect("missing da_dst1");
                let outs = {
                    let (a_s0, a_d0, a_s1, a_d1) = match dp.att.as_ref() {
                        Some((a, b, c, dd)) => (a, b, c, dd),
                        None => panic!("RGAT device params missing attention vectors"),
                    };
                    eng.run_dev_multi(
                        "sgd_rgat",
                        Stage::Head,
                        Phase::Bwd,
                        &[
                            Arg::Dev(&dp.w0),
                            Arg::Dev(&dp.w1),
                            Arg::Dev(a_s0),
                            Arg::Dev(a_d0),
                            Arg::Dev(a_s1),
                            Arg::Dev(a_d1),
                            Arg::Dev(&dw0s),
                            Arg::Dev(&dw0d),
                            Arg::Dev(&dw1s),
                            Arg::Dev(&dw1d),
                            Arg::Dev(&das0),
                            Arg::Dev(&dad0),
                            Arg::Dev(&das1),
                            Arg::Dev(&dad1),
                            Arg::Dev(&ds.lr),
                        ],
                    )?
                };
                let mut out = outs.into_iter();
                let nw0 = out.next().unwrap();
                let nw1 = out.next().unwrap();
                let na_s0 = out.next().unwrap();
                let na_d0 = out.next().unwrap();
                let na_s1 = out.next().unwrap();
                let na_d1 = out.next().unwrap();
                eng.recycle_dev(std::mem::replace(&mut dp.w0, nw0));
                eng.recycle_dev(std::mem::replace(&mut dp.w1, nw1));
                let att = dp.att.as_mut().unwrap();
                eng.recycle_dev(std::mem::replace(&mut att.0, na_s0));
                eng.recycle_dev(std::mem::replace(&mut att.1, na_d0));
                eng.recycle_dev(std::mem::replace(&mut att.2, na_s1));
                eng.recycle_dev(std::mem::replace(&mut att.3, na_d1));
                for g in [dw0s, dw0d, dw1s, dw1d, das0, dad0, das1, dad1] {
                    eng.recycle_dev(g);
                }
            }
        }
        Ok(())
    }

    /// One full device-resident training step: forward, loss, backward, and
    /// the fused on-device SGD update.
    pub fn train_step_dev(
        &self,
        dp: &mut DevParams<B>,
        ds: &DevSchema<B>,
        batch: &DevBatch<B>,
        lr: f32,
    ) -> Result<StepResult> {
        let mut grads = DevGrads::empty();
        let res = self.grad_step_dev(dp, ds, batch, &mut grads)?;
        self.opt_step(dp, ds, grads, lr)?;
        Ok(res)
    }

    /// Pull one batch's device gradients into a host [`Params`] over the
    /// modeled replica interconnect, reproducing the host executor's
    /// accumulation order exactly: weight gradients fold `src` then `dst`
    /// into zero-initialized buffers, attention gradients copy — so the
    /// all-reduce input is bitwise the host path's.
    pub fn fetch_grads_peer(&self, grads: DevGrads<B>, like: &Params) -> Result<Params> {
        let eng = self.eng;
        let mut g = like.zeros_like();
        let mut add = |dst: &mut [f32], dev: Option<B::Dev>| -> Result<()> {
            if let Some(dv) = dev {
                let t = eng.fetch_peer(dv)?;
                tensor::add_assign(dst, t.as_f32()?);
                eng.recycle(t);
            }
            Ok(())
        };
        add(&mut g.w0, grads.dw0_src)?;
        add(&mut g.w0, grads.dw0_dst)?;
        add(&mut g.w1, grads.dw1_src)?;
        add(&mut g.w1, grads.dw1_dst)?;
        let mut copy = |dst: &mut [f32], dev: Option<B::Dev>| -> Result<()> {
            if let Some(dv) = dev {
                let t = eng.fetch_peer(dv)?;
                dst.copy_from_slice(t.as_f32()?);
                eng.recycle(t);
            }
            Ok(())
        };
        copy(&mut g.a_src0, grads.da_src0)?;
        copy(&mut g.a_dst0, grads.da_dst0)?;
        copy(&mut g.a_src1, grads.da_src1)?;
        copy(&mut g.a_dst1, grads.da_dst1)?;
        Ok(g)
    }

    /// Read the authoritative device parameters back into `host` (sync
    /// points: checkpoint save, evaluation handoff). Counted as D2H — this
    /// is a legitimate, non-steady-state boundary crossing. RGCN attention
    /// vectors have no device copy and keep their host values, which the
    /// host trajectory also never moves (`x - lr*0.0 == x` bitwise).
    pub fn sync_params(&self, dp: &DevParams<B>, host: &mut Params) -> Result<()> {
        let eng = self.eng;
        let read = |dv: &B::Dev, dst: &mut [f32]| -> Result<()> {
            eng.counters().borrow_mut().add_d2h(dv.size_bytes() as u64);
            let t = dv.to_host()?;
            dst.copy_from_slice(t.as_f32()?);
            Ok(())
        };
        read(&dp.w0, &mut host.w0)?;
        read(&dp.w1, &mut host.w1)?;
        if let Some((s0, d0, s1, d1)) = dp.att.as_ref() {
            read(s0, &mut host.a_src0)?;
            read(d0, &mut host.a_dst0)?;
            read(s1, &mut host.a_src1)?;
            read(d1, &mut host.a_dst1)?;
        }
        Ok(())
    }

    /// Device-resident evaluation: forward + `head_full`, reading back only
    /// the loss/accuracy scalars (the gradient output is discarded
    /// on-device).
    pub fn eval_step_dev(
        &self,
        dp: &DevParams<B>,
        ds: &DevSchema<B>,
        batch: &DevBatch<B>,
    ) -> Result<StepResult> {
        self.assert_dev_plan();
        let eng = self.eng;
        let l0 = self.layer_forward_dev(0, &batch.xs, dp, ds, &batch.layers[0])?;
        let l1 = self.layer_forward_dev(1, &l0.hout, dp, ds, &batch.layers[1])?;
        let mut out = eng
            .run_dev_multi(
                "head_full",
                Stage::Head,
                Phase::Fwd,
                &[
                    Arg::Dev(&l1.hout),
                    Arg::Dev(&batch.labels),
                    Arg::Dev(&batch.seed_mask),
                    Arg::Dev(&ds.tgt),
                ],
            )?
            .into_iter();
        let loss = self.fetch_scalar(out.next().unwrap())?;
        eng.recycle_dev(out.next().unwrap());
        let ncorrect = self.fetch_scalar(out.next().unwrap())?;
        self.recycle_layer_dev(l1);
        self.recycle_layer_dev(l0);
        Ok(StepResult { loss, ncorrect, n_seed: batch.n_seed })
    }

    /// Device-resident inference forward: the serve-path unit. The
    /// target-type logits are extracted on-device (`slab_pick`) and fetched
    /// as the batch's only D2H transfer — bitwise identical to the host
    /// [`StepExecutor::forward_step`] slab copy.
    pub fn forward_step_dev(
        &self,
        dp: &DevParams<B>,
        ds: &DevSchema<B>,
        batch: &DevBatch<B>,
    ) -> Result<HostTensor> {
        self.assert_dev_plan();
        let eng = self.eng;
        assert_eq!(batch.layers.len(), 2, "2-layer model");
        let l0 = self.layer_forward_dev(0, &batch.xs, dp, ds, &batch.layers[0])?;
        let l1 = self.layer_forward_dev(1, &l0.hout, dp, ds, &batch.layers[1])?;
        let logits_dev = eng.run_dev(
            "slab_pick",
            Stage::Head,
            Phase::Fwd,
            &[Arg::Dev(&l1.hout), Arg::Dev(&ds.tgt)],
        )?;
        self.recycle_layer_dev(l1);
        self.recycle_layer_dev(l0);
        eng.fetch(logits_dev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::RelEdges;

    fn dims() -> Dims {
        Dims { ns: 4, ep: 3, rpad: 3, tpad: 2, f: 2, h: 3, c: 2, elp: 9 }
    }

    #[test]
    fn pad_layer_edges_builds_consistent_tensors() {
        let d = dims();
        let rels = vec![
            RelEdges { src: vec![1, 2], dst: vec![0, 3] },
            RelEdges::default(),
            RelEdges { src: vec![3], dst: vec![1] },
        ];
        let le = pad_layer_edges(&rels, &d);
        assert_eq!(le.live, vec![0, 2]);
        let (s0, d0, v0) = &le.per_rel[0];
        assert_eq!(s0.as_i32().unwrap(), &[1, 2, 0]);
        assert_eq!(d0.as_i32().unwrap(), &[0, 3, 0]);
        assert_eq!(v0.as_f32().unwrap(), &[1.0, 1.0, 0.0]);
        // Merged rows mirror per-rel rows.
        let ms = le.src.as_i32().unwrap();
        assert_eq!(&ms[0..3], s0.as_i32().unwrap());
        assert_eq!(&ms[6..9], le.per_rel[2].0.as_i32().unwrap());
        let mv = le.valid.as_f32().unwrap();
        assert_eq!(&mv[3..6], &[0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "exceeds EP")]
    fn pad_layer_edges_rejects_overflow() {
        let d = dims();
        let rels = vec![RelEdges { src: vec![0, 1, 2, 3], dst: vec![0, 1, 2, 3] }];
        pad_layer_edges(&rels, &d);
    }

    #[test]
    fn dims_fd_maps_layers() {
        let d = dims();
        assert_eq!(d.fd(0), 3);
        assert_eq!(d.fd(1), 2);
    }
}
