//! The training-step executor: chains AOT modules according to the active
//! execution plan (DESIGN.md §3).
//!
//! * **Baseline ("PyG")**: per-relation projection + per-relation
//!   aggregation dispatches, semantic-graph build on "GPU".
//! * **HiFuse**: merged aggregation (single Pallas launch per layer,
//!   Algorithm 1), selection already done on CPU, optionally stacked
//!   projection (extension).
//!
//! Both plans compute the *same* gradients (integration-tested against each
//! other and against jax.grad via the Python composition test), so every
//! performance comparison is apples-to-apples.

use anyhow::Result;

use crate::coordinator::ablation::OptConfig;
use crate::graph::HeteroGraph;
use crate::models::{ModelKind, Params};
use crate::runtime::{Arg, DevBuf, ExecBackend, Phase, Stage};
use crate::sampler::RelEdges;
use crate::util::{tensor, HostTensor};

/// Profile dims, read once from the manifest.
#[derive(Clone, Copy, Debug)]
pub struct Dims {
    pub ns: usize,
    pub ep: usize,
    pub rpad: usize,
    pub tpad: usize,
    pub f: usize,
    pub h: usize,
    pub c: usize,
    pub elp: usize,
}

impl Dims {
    pub fn from_backend<B: ExecBackend>(eng: &B) -> Dims {
        Dims {
            ns: eng.cst("NS"),
            ep: eng.cst("EP"),
            rpad: eng.cst("RPAD"),
            tpad: eng.cst("TPAD"),
            f: eng.cst("F"),
            h: eng.cst("H"),
            c: eng.cst("C"),
            elp: eng.cst("ELP"),
        }
    }

    /// Aggregation feature width of layer `l` (l0 -> H, l1 -> C).
    pub fn fd(&self, l: usize) -> usize {
        if l == 0 {
            self.h
        } else {
            self.c
        }
    }
}

/// Graph-schema tensors shared by every batch.
#[derive(Clone, Debug)]
pub struct SchemaTensors {
    pub src_type: Vec<usize>,
    pub dst_type: Vec<usize>,
    /// `[RPAD]` i32 src types (stacked-projection gather index).
    pub src_type_i32: HostTensor,
    /// `[RPAD]` i32 dst types (semantic-fusion segment ids).
    pub dst_type_i32: HostTensor,
    pub target_type: usize,
    pub n_rel: usize,
}

pub fn schema_tensors(g: &HeteroGraph, d: &Dims) -> SchemaTensors {
    assert!(g.n_relations() <= d.rpad, "schema exceeds RPAD");
    assert!(g.n_types() <= d.tpad, "schema exceeds TPAD");
    let mut src_type = vec![0usize; d.rpad];
    let mut dst_type = vec![0usize; d.rpad];
    for (r, rel) in g.relations.iter().enumerate() {
        src_type[r] = rel.src_type;
        dst_type[r] = rel.dst_type;
    }
    SchemaTensors {
        src_type_i32: HostTensor::i32(src_type.iter().map(|&t| t as i32).collect(), &[d.rpad]),
        dst_type_i32: HostTensor::i32(dst_type.iter().map(|&t| t as i32).collect(), &[d.rpad]),
        src_type,
        dst_type,
        target_type: g.target_type,
        n_rel: g.n_relations(),
    }
}

/// One layer's edges in every padded form the modules need.
#[derive(Clone, Debug)]
pub struct LayerEdges {
    /// Per relation: (`[EP]` src, `[EP]` dst, `[EP]` valid); padded zeros.
    pub per_rel: Vec<(HostTensor, HostTensor, HostTensor)>,
    /// Relations with at least one edge this layer.
    pub live: Vec<usize>,
    /// Merged `[RPAD, EP]` tensors (the Pallas kernel inputs).
    pub src: HostTensor,
    pub dst: HostTensor,
    pub valid: HostTensor,
}

/// Pad per-relation edge lists (selection output) into module tensors.
pub fn pad_layer_edges(rels: &[RelEdges], d: &Dims) -> LayerEdges {
    assert!(rels.len() <= d.rpad);
    let mut merged_src = vec![0i32; d.rpad * d.ep];
    let mut merged_dst = vec![0i32; d.rpad * d.ep];
    let mut merged_valid = vec![0.0f32; d.rpad * d.ep];
    let mut per_rel = Vec::with_capacity(d.rpad);
    let mut live = Vec::new();
    for r in 0..d.rpad {
        let (mut s, mut t, mut v) = (vec![0i32; d.ep], vec![0i32; d.ep], vec![0.0f32; d.ep]);
        if let Some(e) = rels.get(r) {
            assert!(e.len() <= d.ep, "relation {r} exceeds EP after selection");
            if !e.is_empty() {
                live.push(r);
            }
            for i in 0..e.len() {
                s[i] = e.src[i] as i32;
                t[i] = e.dst[i] as i32;
                v[i] = 1.0;
            }
        }
        merged_src[r * d.ep..r * d.ep + d.ep].copy_from_slice(&s);
        merged_dst[r * d.ep..r * d.ep + d.ep].copy_from_slice(&t);
        merged_valid[r * d.ep..r * d.ep + d.ep].copy_from_slice(&v);
        per_rel.push((
            HostTensor::i32(s, &[d.ep]),
            HostTensor::i32(t, &[d.ep]),
            HostTensor::f32(v, &[d.ep]),
        ));
    }
    LayerEdges {
        per_rel,
        live,
        src: HostTensor::i32(merged_src, &[d.rpad, d.ep]),
        dst: HostTensor::i32(merged_dst, &[d.rpad, d.ep]),
        valid: HostTensor::f32(merged_valid, &[d.rpad, d.ep]),
    }
}

/// A fully prepared batch: everything `train_step` needs.
pub struct BatchData {
    /// `[TPAD, NS, F]` raw features.
    pub xs: HostTensor,
    pub labels: HostTensor,
    pub seed_mask: HostTensor,
    pub n_seed: usize,
    pub layers: Vec<LayerEdges>,
}

pub struct StepResult {
    pub loss: f32,
    pub ncorrect: f32,
    pub n_seed: usize,
}

// --------------------------------------------------------------------------
// host tensor helpers
// --------------------------------------------------------------------------

/// Copy type slab `t` (`[NS, F]`) out of a `[TPAD, NS, F]` tensor.
fn slab(h: &HostTensor, t: usize, ns: usize, f: usize) -> Result<HostTensor> {
    let d = h.as_f32()?;
    Ok(HostTensor::f32(d[t * ns * f..(t + 1) * ns * f].to_vec(), &[ns, f]))
}

/// View relation `r`'s `[NS, Fd]` block of a `[RPAD, NS, Fd]` stack.
fn stack_block(stack: &[f32], r: usize, ns: usize, fd: usize) -> &[f32] {
    &stack[r * ns * fd..(r + 1) * ns * fd]
}

/// An activation that is either host-resident (per-relation plans need to
/// slice it) or still on the device (the merged plan chains it straight
/// into the next dispatch — §Perf #5).
enum Stack<D> {
    Host(HostTensor),
    Dev(D),
}

impl<D: DevBuf> Stack<D> {
    fn as_arg(&self) -> Arg<'_, D> {
        match self {
            Stack::Host(h) => Arg::Host(h),
            Stack::Dev(d) => Arg::Dev(d),
        }
    }

    fn as_host(&self) -> &HostTensor {
        match self {
            Stack::Host(h) => h,
            Stack::Dev(_) => panic!("activation unexpectedly device-resident"),
        }
    }
}

struct LayerFwd<D> {
    /// `[RPAD, NS, Fd]` projected source features (zeros for dead rels),
    /// kept as a tensor so dispatches borrow it without cloning.
    pstack: HostTensor,
    /// RGAT only: projected destination features.
    pstack_dst: Option<HostTensor>,
    /// `[RPAD, NS, Fd]` aggregated features.
    astack: Stack<D>,
    /// `[TPAD, NS, Fd]` fused output.
    hout: HostTensor,
}

// --------------------------------------------------------------------------
// the step executor
// --------------------------------------------------------------------------

/// Chains module dispatches on any [`ExecBackend`]: the same plans, counts,
/// and gradients whether the backend interprets (sim) or executes compiled
/// HLO (PJRT).
pub struct StepExecutor<'e, B: ExecBackend> {
    pub eng: &'e B,
    pub d: Dims,
    pub model: ModelKind,
    pub opt: OptConfig,
}

impl<'e, B: ExecBackend> StepExecutor<'e, B> {
    pub fn new(eng: &'e B, model: ModelKind, opt: OptConfig) -> Self {
        let d = Dims::from_backend(eng);
        StepExecutor { eng, d, model, opt }
    }

    fn proj_name(l: usize, bwd: bool, stacked: bool) -> &'static str {
        match (l, bwd, stacked) {
            (0, false, false) => "proj_fwd_l0",
            (1, false, false) => "proj_fwd_l1",
            (0, true, false) => "proj_bwd_l0",
            (1, true, false) => "proj_bwd_l1",
            (0, false, true) => "proj_stacked_fwd_l0",
            (1, false, true) => "proj_stacked_fwd_l1",
            (0, true, true) => "proj_stacked_bwd_l0",
            (1, true, true) => "proj_stacked_bwd_l1",
            _ => unreachable!(),
        }
    }

    fn agg_name(&self, l: usize, bwd: bool) -> &'static str {
        let merged = self.opt.merge;
        match (self.model, merged, l, bwd) {
            (ModelKind::Rgcn, false, 0, false) => "agg_mean_fwd_h",
            (ModelKind::Rgcn, false, 1, false) => "agg_mean_fwd_c",
            (ModelKind::Rgcn, false, 0, true) => "agg_mean_bwd_h",
            (ModelKind::Rgcn, false, 1, true) => "agg_mean_bwd_c",
            (ModelKind::Rgcn, true, 0, false) => "agg_merged_fwd_h",
            (ModelKind::Rgcn, true, 1, false) => "agg_merged_fwd_c",
            (ModelKind::Rgcn, true, 0, true) => "agg_merged_bwd_h",
            (ModelKind::Rgcn, true, 1, true) => "agg_merged_bwd_c",
            (ModelKind::Rgat, false, 0, false) => "att_agg_fwd_h",
            (ModelKind::Rgat, false, 1, false) => "att_agg_fwd_c",
            (ModelKind::Rgat, false, 0, true) => "att_agg_bwd_h",
            (ModelKind::Rgat, false, 1, true) => "att_agg_bwd_c",
            (ModelKind::Rgat, true, 0, false) => "att_merged_fwd_h",
            (ModelKind::Rgat, true, 1, false) => "att_merged_fwd_c",
            (ModelKind::Rgat, true, 0, true) => "att_merged_bwd_h",
            (ModelKind::Rgat, true, 1, true) => "att_merged_bwd_c",
            _ => unreachable!("2-layer model"),
        }
    }

    /// Per-relation weight tensor `[Fin, Fout]`.
    fn w_tensor(&self, params: &Params, l: usize, r: usize) -> HostTensor {
        let (fin, fout) = if l == 0 { (self.d.f, self.d.h) } else { (self.d.h, self.d.c) };
        HostTensor::f32(params.w_rel(l, r).to_vec(), &[fin, fout])
    }

    fn w_full(&self, params: &Params, l: usize) -> HostTensor {
        let (fin, fout) = if l == 0 { (self.d.f, self.d.h) } else { (self.d.h, self.d.c) };
        let w = if l == 0 { &params.w0 } else { &params.w1 };
        HostTensor::f32(w.clone(), &[self.d.rpad, fin, fout])
    }

    fn att_vecs(&self, params: &Params, l: usize) -> (HostTensor, HostTensor) {
        let fd = self.d.fd(l);
        let (s, t) = if l == 0 {
            (&params.a_src0, &params.a_dst0)
        } else {
            (&params.a_src1, &params.a_dst1)
        };
        (
            HostTensor::f32(s.clone(), &[self.d.rpad, fd]),
            HostTensor::f32(t.clone(), &[self.d.rpad, fd]),
        )
    }

    /// Project one endpoint slab stack: per-relation dispatches (baseline &
    /// paper-HiFuse) or one stacked dispatch (extension). `types` selects
    /// src or dst endpoint typing. Returns the `[RPAD, NS, Fd]` stack.
    fn project(
        &self,
        l: usize,
        hin: &HostTensor,
        params: &Params,
        schema: &SchemaTensors,
        edges: &LayerEdges,
        types: &[usize],
        types_i32: &HostTensor,
    ) -> Result<HostTensor> {
        let (d, eng) = (&self.d, self.eng);
        let fd = d.fd(l);
        if self.opt.stacked_proj {
            let w = self.w_full(params, l);
            let out = eng.run(
                Self::proj_name(l, false, true),
                Stage::Projection,
                Phase::Fwd,
                &[hin, &w, types_i32],
            )?;
            return Ok(out.into_iter().next().unwrap());
        }
        let _ = schema;
        let mut pstack = vec![0.0f32; d.rpad * d.ns * fd];
        for &r in &edges.live {
            let x = slab(hin, types[r], d.ns, if l == 0 { d.f } else { d.h })?;
            let w = self.w_tensor(params, l, r);
            let out = eng.run(
                Self::proj_name(l, false, false),
                Stage::Projection,
                Phase::Fwd,
                &[&x, &w],
            )?;
            let y = out.into_iter().next().unwrap();
            pstack[r * d.ns * fd..(r + 1) * d.ns * fd].copy_from_slice(y.as_f32()?);
            eng.recycle(y);
        }
        Ok(HostTensor::f32(pstack, &[d.rpad, d.ns, fd]))
    }

    fn layer_forward(
        &self,
        l: usize,
        hin: &HostTensor,
        params: &Params,
        schema: &SchemaTensors,
        edges: &LayerEdges,
    ) -> Result<LayerFwd<B::Dev>> {
        let (d, eng) = (&self.d, self.eng);
        let fd = d.fd(l);

        let pstack = self.project(l, hin, params, schema, edges, &schema.src_type,
            &schema.src_type_i32)?;
        let pstack_dst = if self.model == ModelKind::Rgat {
            Some(self.project(l, hin, params, schema, edges, &schema.dst_type,
                &schema.dst_type_i32)?)
        } else {
            None
        };

        let astack = if self.opt.merge {
            match self.model {
                ModelKind::Rgcn => {
                    // Device-resident: the merged aggregation output feeds
                    // fusion directly without a host round-trip (§Perf #5).
                    Stack::Dev(eng.run_dev(
                        self.agg_name(l, false),
                        Stage::Aggregation,
                        Phase::Fwd,
                        &[
                            Arg::Host(&pstack),
                            Arg::Host(&edges.src),
                            Arg::Host(&edges.dst),
                            Arg::Host(&edges.valid),
                        ],
                    )?)
                }
                ModelKind::Rgat => {
                    let pdst = pstack_dst.as_ref().unwrap();
                    let (a_s, a_d) = self.att_vecs(params, l);
                    Stack::Dev(eng.run_dev(
                        self.agg_name(l, false),
                        Stage::Aggregation,
                        Phase::Fwd,
                        &[
                            Arg::Host(&pstack),
                            Arg::Host(pdst),
                            Arg::Host(&a_s),
                            Arg::Host(&a_d),
                            Arg::Host(&edges.src),
                            Arg::Host(&edges.dst),
                            Arg::Host(&edges.valid),
                        ],
                    )?)
                }
            }
        } else {
            let pstack_f = pstack.as_f32()?;
            let mut astack = vec![0.0f32; d.rpad * d.ns * fd];
            for &r in &edges.live {
                let feat =
                    HostTensor::f32(stack_block(pstack_f, r, d.ns, fd).to_vec(), &[d.ns, fd]);
                let (src, dst, valid) = &edges.per_rel[r];
                let out = match self.model {
                    ModelKind::Rgcn => eng.run(
                        self.agg_name(l, false),
                        Stage::Aggregation,
                        Phase::Fwd,
                        &[&feat, src, dst, valid],
                    )?,
                    ModelKind::Rgat => {
                        let pd = pstack_dst.as_ref().unwrap().as_f32()?;
                        let fdst =
                            HostTensor::f32(stack_block(pd, r, d.ns, fd).to_vec(), &[d.ns, fd]);
                        let (a_s, a_d) = self.att_vecs(params, l);
                        let asl =
                            HostTensor::f32(a_s.as_f32()?[r * fd..(r + 1) * fd].to_vec(), &[fd]);
                        let adl =
                            HostTensor::f32(a_d.as_f32()?[r * fd..(r + 1) * fd].to_vec(), &[fd]);
                        eng.run(
                            self.agg_name(l, false),
                            Stage::Aggregation,
                            Phase::Fwd,
                            &[&feat, &fdst, &asl, &adl, src, dst, valid],
                        )?
                    }
                };
                let y = out.into_iter().next().unwrap();
                astack[r * d.ns * fd..(r + 1) * d.ns * fd].copy_from_slice(y.as_f32()?);
                eng.recycle(y);
            }
            Stack::Host(HostTensor::f32(astack, &[d.rpad, d.ns, fd]))
        };

        let fuse_name = if l == 0 { "fuse_relu_fwd_h" } else { "fuse_lin_fwd_c" };
        let hout = eng
            .run_dev(
                fuse_name,
                Stage::Fusion,
                Phase::Fwd,
                &[Arg::Host(&schema.dst_type_i32), astack.as_arg()],
            )?
            .into_host()?;

        Ok(LayerFwd { pstack, pstack_dst, astack, hout })
    }

    /// Backward through one layer: consumes `dhout`, returns `dhin` and
    /// fills this layer's weight gradients.
    #[allow(clippy::too_many_arguments)]
    fn layer_backward(
        &self,
        l: usize,
        hin: &HostTensor,
        fwd: &LayerFwd<B::Dev>,
        dhout: &HostTensor,
        params: &Params,
        grads: &mut Params,
        schema: &SchemaTensors,
        edges: &LayerEdges,
    ) -> Result<HostTensor> {
        let (d, eng) = (&self.d, self.eng);
        let fd = d.fd(l);
        let fin = if l == 0 { d.f } else { d.h };

        let fuse_name = if l == 0 { "fuse_relu_bwd_h" } else { "fuse_lin_bwd_c" };
        // Merged plan: fusion backward and (RGCN) aggregation backward chain
        // device-resident; only the final dp comes back to the host for
        // per-relation projection slicing (§Perf #5).
        let da: Stack<B::Dev> = if self.opt.merge {
            Stack::Dev(eng.run_dev(
                fuse_name,
                Stage::Fusion,
                Phase::Bwd,
                &[Arg::Host(&schema.dst_type_i32), fwd.astack.as_arg(), Arg::Host(dhout)],
            )?)
        } else {
            Stack::Host(
                eng.run(
                    fuse_name,
                    Stage::Fusion,
                    Phase::Bwd,
                    &[&schema.dst_type_i32, fwd.astack.as_host(), dhout],
                )?
                .into_iter()
                .next()
                .unwrap(),
            )
        };

        // --- aggregation backward: dp (and attention grads for RGAT).
        // `dp`/`dp_dst` are dispatch outputs in merged mode (recycled after
        // projection backward) and executor-assembled stacks otherwise.
        let (dp, dp_dst): (HostTensor, Option<HostTensor>) = if self.opt.merge {
            match self.model {
                ModelKind::Rgcn => {
                    let dp_dev = eng.run_dev(
                        self.agg_name(l, true),
                        Stage::Aggregation,
                        Phase::Bwd,
                        &[
                            Arg::Host(&edges.src),
                            Arg::Host(&edges.dst),
                            Arg::Host(&edges.valid),
                            da.as_arg(),
                        ],
                    )?;
                    self.recycle_stack(da);
                    (dp_dev.into_host()?, None)
                }
                ModelKind::Rgat => {
                    // The attention VJP module is multi-output, so its da
                    // input must be host-resident.
                    let da_host = match da {
                        Stack::Dev(dev) => dev.into_host()?,
                        Stack::Host(h) => h,
                    };
                    let pdst = fwd.pstack_dst.as_ref().unwrap();
                    let (a_s, a_d) = self.att_vecs(params, l);
                    let mut out = eng
                        .run(
                            self.agg_name(l, true),
                            Stage::Aggregation,
                            Phase::Bwd,
                            &[&fwd.pstack, pdst, &a_s, &a_d, &edges.src, &edges.dst,
                                &edges.valid, &da_host],
                        )?
                        .into_iter();
                    eng.recycle(da_host);
                    let dfs = out.next().unwrap();
                    let dfd = out.next().unwrap();
                    let das = out.next().unwrap();
                    let dad = out.next().unwrap();
                    self.store_att_grads(l, grads, das.as_f32()?, dad.as_f32()?);
                    eng.recycle(das);
                    eng.recycle(dad);
                    (dfs, Some(dfd))
                }
            }
        } else {
            let pstack_f = fwd.pstack.as_f32()?;
            let mut dp = vec![0.0f32; d.rpad * d.ns * fd];
            let mut dpd = vec![0.0f32; d.rpad * d.ns * fd];
            let da_flat = da.as_host().as_f32()?;
            for &r in &edges.live {
                let da_r =
                    HostTensor::f32(stack_block(da_flat, r, d.ns, fd).to_vec(), &[d.ns, fd]);
                let (src, dst, valid) = &edges.per_rel[r];
                match self.model {
                    ModelKind::Rgcn => {
                        let feat = HostTensor::f32(
                            stack_block(pstack_f, r, d.ns, fd).to_vec(),
                            &[d.ns, fd],
                        );
                        let out = eng.run(
                            self.agg_name(l, true),
                            Stage::Aggregation,
                            Phase::Bwd,
                            &[&feat, src, dst, valid, &da_r],
                        )?;
                        let g = out.into_iter().next().unwrap();
                        dp[r * d.ns * fd..(r + 1) * d.ns * fd].copy_from_slice(g.as_f32()?);
                        eng.recycle(g);
                    }
                    ModelKind::Rgat => {
                        let feat = HostTensor::f32(
                            stack_block(pstack_f, r, d.ns, fd).to_vec(),
                            &[d.ns, fd],
                        );
                        let pdall = fwd.pstack_dst.as_ref().unwrap().as_f32()?;
                        let fdst = HostTensor::f32(
                            stack_block(pdall, r, d.ns, fd).to_vec(),
                            &[d.ns, fd],
                        );
                        let (a_s_all, a_d_all) = self.att_vecs(params, l);
                        let asl = HostTensor::f32(
                            a_s_all.as_f32()?[r * fd..(r + 1) * fd].to_vec(),
                            &[fd],
                        );
                        let adl = HostTensor::f32(
                            a_d_all.as_f32()?[r * fd..(r + 1) * fd].to_vec(),
                            &[fd],
                        );
                        let mut out = eng
                            .run(
                                self.agg_name(l, true),
                                Stage::Aggregation,
                                Phase::Bwd,
                                &[&feat, &fdst, &asl, &adl, src, dst, valid, &da_r],
                            )?
                            .into_iter();
                        let dfs = out.next().unwrap();
                        let dfd = out.next().unwrap();
                        let das = out.next().unwrap();
                        let dad = out.next().unwrap();
                        dp[r * d.ns * fd..(r + 1) * d.ns * fd].copy_from_slice(dfs.as_f32()?);
                        dpd[r * d.ns * fd..(r + 1) * d.ns * fd]
                            .copy_from_slice(dfd.as_f32()?);
                        let (gs, gd) = self.att_grad_slices(l, grads);
                        gs[r * fd..(r + 1) * fd].copy_from_slice(das.as_f32()?);
                        gd[r * fd..(r + 1) * fd].copy_from_slice(dad.as_f32()?);
                        eng.recycle(dfs);
                        eng.recycle(dfd);
                        eng.recycle(das);
                        eng.recycle(dad);
                    }
                }
            }
            self.recycle_stack(da);
            (
                HostTensor::f32(dp, &[d.rpad, d.ns, fd]),
                (self.model == ModelKind::Rgat)
                    .then_some(HostTensor::f32(dpd, &[d.rpad, d.ns, fd])),
            )
        };

        // --- projection backward: dhin + dW.
        let mut dhin = vec![0.0f32; d.tpad * d.ns * fin];
        self.project_backward(l, hin, params, grads, schema, edges, &dp, &schema.src_type,
            &schema.src_type_i32, &mut dhin, false)?;
        if let Some(dpd) = &dp_dst {
            self.project_backward(l, hin, params, grads, schema, edges, dpd,
                &schema.dst_type, &schema.dst_type_i32, &mut dhin, true)?;
        }
        // Merged-mode dp tensors are dispatch outputs: hand them back.
        if self.opt.merge {
            eng.recycle(dp);
            if let Some(t) = dp_dst {
                eng.recycle(t);
            }
        }
        Ok(HostTensor::f32(dhin, &[d.tpad, d.ns, fin]))
    }

    /// Recycle a consumed activation that is known to be a dispatch output
    /// (device-resident buffers always are; host ones only when the caller
    /// knows their provenance).
    fn recycle_stack(&self, s: Stack<B::Dev>) {
        match s {
            Stack::Host(h) => self.eng.recycle(h),
            Stack::Dev(dv) => self.eng.recycle_dev(dv),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn project_backward(
        &self,
        l: usize,
        hin: &HostTensor,
        params: &Params,
        grads: &mut Params,
        schema: &SchemaTensors,
        edges: &LayerEdges,
        dp: &HostTensor,
        types: &[usize],
        types_i32: &HostTensor,
        dhin: &mut [f32],
        accumulate_w: bool,
    ) -> Result<()> {
        let (d, eng) = (&self.d, self.eng);
        let fd = d.fd(l);
        let fin = if l == 0 { d.f } else { d.h };
        if self.opt.stacked_proj {
            let w = self.w_full(params, l);
            let mut out = eng
                .run(
                    Self::proj_name(l, true, true),
                    Stage::Projection,
                    Phase::Bwd,
                    &[hin, &w, types_i32, dp],
                )?
                .into_iter();
            let dxs = out.next().unwrap();
            let dw = out.next().unwrap();
            tensor::add_assign(dhin, dxs.as_f32()?);
            let gw = if l == 0 { &mut grads.w0 } else { &mut grads.w1 };
            tensor::add_assign(gw, dw.as_f32()?);
            eng.recycle(dxs);
            eng.recycle(dw);
            return Ok(());
        }
        let _ = schema;
        let dp_f = dp.as_f32()?;
        for &r in &edges.live {
            let x = slab(hin, types[r], d.ns, fin)?;
            let w = self.w_tensor(params, l, r);
            let dy = HostTensor::f32(stack_block(dp_f, r, d.ns, fd).to_vec(), &[d.ns, fd]);
            let mut out = eng
                .run(Self::proj_name(l, true, false), Stage::Projection, Phase::Bwd,
                    &[&x, &w, &dy])?
                .into_iter();
            let dx = out.next().unwrap();
            let dw = out.next().unwrap();
            let t = types[r];
            tensor::add_assign(&mut dhin[t * d.ns * fin..(t + 1) * d.ns * fin], dx.as_f32()?);
            let gw = if l == 0 { &mut grads.w0 } else { &mut grads.w1 };
            let gw_r = &mut gw[r * fin * fd..(r + 1) * fin * fd];
            if accumulate_w {
                tensor::add_assign(gw_r, dw.as_f32()?);
            } else {
                gw_r.copy_from_slice(dw.as_f32()?);
            }
            eng.recycle(dx);
            eng.recycle(dw);
        }
        Ok(())
    }

    fn store_att_grads(&self, l: usize, grads: &mut Params, das: &[f32], dad: &[f32]) {
        let (gs, gd) = self.att_grad_slices(l, grads);
        gs.copy_from_slice(das);
        gd.copy_from_slice(dad);
    }

    fn att_grad_slices<'g>(
        &self,
        l: usize,
        grads: &'g mut Params,
    ) -> (&'g mut [f32], &'g mut [f32]) {
        if l == 0 {
            (&mut grads.a_src0, &mut grads.a_dst0)
        } else {
            (&mut grads.a_src1, &mut grads.a_dst1)
        }
    }

    /// Hand a consumed layer's buffers back to the backend. Only dispatch
    /// outputs are recycled: the non-stacked projection stack and the
    /// non-merged aggregation stack are executor-assembled, so they are
    /// dropped normally (recycling them would grow the pool unboundedly).
    fn recycle_layer(&self, l: LayerFwd<B::Dev>) {
        if self.opt.stacked_proj {
            self.eng.recycle(l.pstack);
            if let Some(p) = l.pstack_dst {
                self.eng.recycle(p);
            }
        }
        if let Stack::Dev(dv) = l.astack {
            self.eng.recycle_dev(dv);
        }
        self.eng.recycle(l.hout);
    }

    /// Forward + loss + backward, **without** the parameter update: returns
    /// the step result and the raw gradients. This is the unit the
    /// data-parallel replica path all-reduces (DESIGN.md §4) — gradients are
    /// bitwise-deterministic in (`params`, `batch`), independent of thread
    /// count, so summing them in a fixed order is replica-count-invariant.
    pub fn grad_step(
        &self,
        params: &Params,
        schema: &SchemaTensors,
        batch: &BatchData,
    ) -> Result<(StepResult, Params)> {
        let (d, eng) = (&self.d, self.eng);
        assert_eq!(batch.layers.len(), 2, "2-layer model");

        // ---- forward
        let l0 = self.layer_forward(0, &batch.xs, params, schema, &batch.layers[0])?;
        let l1 = self.layer_forward(1, &l0.hout, params, schema, &batch.layers[1])?;

        // ---- head (loss + dlogits + accuracy in one dispatch)
        let logits = slab(&l1.hout, schema.target_type, d.ns, d.c)?;
        let mut out = eng
            .run("head", Stage::Head, Phase::Fwd,
                &[&logits, &batch.labels, &batch.seed_mask])?
            .into_iter();
        let loss = out.next().unwrap().scalar()?;
        let dlogits = out.next().unwrap();
        let ncorrect = out.next().unwrap().scalar()?;

        // ---- backward
        let mut grads = params.zeros_like();
        let mut dh2 = vec![0.0f32; d.tpad * d.ns * d.c];
        let t = schema.target_type;
        dh2[t * d.ns * d.c..(t + 1) * d.ns * d.c].copy_from_slice(dlogits.as_f32()?);
        eng.recycle(dlogits);
        let dh2 = HostTensor::f32(dh2, &[d.tpad, d.ns, d.c]);

        let dh1 = self.layer_backward(1, &l0.hout, &l1, &dh2, params, &mut grads, schema,
            &batch.layers[1])?;
        let _dx = self.layer_backward(0, &batch.xs, &l0, &dh1, params, &mut grads, schema,
            &batch.layers[0])?;
        self.recycle_layer(l1);
        self.recycle_layer(l0);

        Ok((StepResult { loss, ncorrect, n_seed: batch.n_seed }, grads))
    }

    /// Run one full training step (forward, loss, backward, SGD update).
    pub fn train_step(
        &self,
        params: &mut Params,
        schema: &SchemaTensors,
        batch: &BatchData,
        lr: f32,
    ) -> Result<StepResult> {
        let (res, grads) = self.grad_step(params, schema, batch)?;
        params.sgd(&grads, lr);
        Ok(res)
    }

    /// Forward-only pass returning (loss, ncorrect) — evaluation helper.
    pub fn eval_step(
        &self,
        params: &Params,
        schema: &SchemaTensors,
        batch: &BatchData,
    ) -> Result<StepResult> {
        let (d, eng) = (&self.d, self.eng);
        let l0 = self.layer_forward(0, &batch.xs, params, schema, &batch.layers[0])?;
        let l1 = self.layer_forward(1, &l0.hout, params, schema, &batch.layers[1])?;
        let logits = slab(&l1.hout, schema.target_type, d.ns, d.c)?;
        let mut out = eng
            .run("head", Stage::Head, Phase::Fwd,
                &[&logits, &batch.labels, &batch.seed_mask])?
            .into_iter();
        let loss = out.next().unwrap().scalar()?;
        if let Some(dl) = out.next() {
            eng.recycle(dl);
        }
        let ncorrect = out.next().unwrap().scalar()?;
        self.recycle_layer(l1);
        self.recycle_layer(l0);
        Ok(StepResult { loss, ncorrect, n_seed: batch.n_seed })
    }

    /// Inference forward: the forward half of [`StepExecutor::grad_step`]
    /// with no head dispatch, no labels, and no optimizer state — the unit
    /// the serving path runs per coalesced batch (DESIGN.md §8). Returns
    /// the target-type `[NS, C]` logits; the readback is charged to the
    /// dispatch log as D2H traffic (`Counters::d2h_bytes`), which is the
    /// serve path's whole device→host footprint per batch.
    ///
    /// Like `grad_step`, the output is bitwise-deterministic in
    /// (`params`, `batch`) for any thread count, which is what makes
    /// per-request predictions invariant under `--replicas`/`--producers`/
    /// `--threads`/pipeline (pinned by `tests/serve_parity.rs`).
    pub fn forward_step(
        &self,
        params: &Params,
        schema: &SchemaTensors,
        batch: &BatchData,
    ) -> Result<HostTensor> {
        let (d, eng) = (&self.d, self.eng);
        assert_eq!(batch.layers.len(), 2, "2-layer model");
        let l0 = self.layer_forward(0, &batch.xs, params, schema, &batch.layers[0])?;
        let l1 = self.layer_forward(1, &l0.hout, params, schema, &batch.layers[1])?;
        let logits = slab(&l1.hout, schema.target_type, d.ns, d.c)?;
        eng.counters().borrow_mut().add_d2h(logits.size_bytes() as u64);
        self.recycle_layer(l1);
        self.recycle_layer(l0);
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::RelEdges;

    fn dims() -> Dims {
        Dims { ns: 4, ep: 3, rpad: 3, tpad: 2, f: 2, h: 3, c: 2, elp: 9 }
    }

    #[test]
    fn pad_layer_edges_builds_consistent_tensors() {
        let d = dims();
        let rels = vec![
            RelEdges { src: vec![1, 2], dst: vec![0, 3] },
            RelEdges::default(),
            RelEdges { src: vec![3], dst: vec![1] },
        ];
        let le = pad_layer_edges(&rels, &d);
        assert_eq!(le.live, vec![0, 2]);
        let (s0, d0, v0) = &le.per_rel[0];
        assert_eq!(s0.as_i32().unwrap(), &[1, 2, 0]);
        assert_eq!(d0.as_i32().unwrap(), &[0, 3, 0]);
        assert_eq!(v0.as_f32().unwrap(), &[1.0, 1.0, 0.0]);
        // Merged rows mirror per-rel rows.
        let ms = le.src.as_i32().unwrap();
        assert_eq!(&ms[0..3], s0.as_i32().unwrap());
        assert_eq!(&ms[6..9], le.per_rel[2].0.as_i32().unwrap());
        let mv = le.valid.as_f32().unwrap();
        assert_eq!(&mv[3..6], &[0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "exceeds EP")]
    fn pad_layer_edges_rejects_overflow() {
        let d = dims();
        let rels = vec![RelEdges { src: vec![0, 1, 2, 3], dst: vec![0, 1, 2, 3] }];
        pad_layer_edges(&rels, &d);
    }

    #[test]
    fn dims_fd_maps_layers() {
        let d = dims();
        assert_eq!(d.fd(0), 3);
        assert_eq!(d.fd(1), 2);
    }
}
