//! Model layer: RGCN / RGAT parameters, the per-step execution engine
//! (baseline vs HiFuse plans), and the analytic kernel-count model.

pub mod checkpoint;
pub mod plan;
pub mod step;

use crate::util::{tensor, Rng};

/// The two HGNN models the paper evaluates (§5.1): RGCN (simple
/// architecture, mean aggregation) and RGAT (complex architecture,
/// per-relation attention aggregation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Rgcn,
    Rgat,
}

impl ModelKind {
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Rgcn => "rgcn",
            ModelKind::Rgat => "rgat",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rgcn" => Some(ModelKind::Rgcn),
            "rgat" => Some(ModelKind::Rgat),
            _ => None,
        }
    }
}

/// Host-resident trainable parameters (padded to RPAD relations; dead
/// relations receive zero gradients and never move).
///
/// The SGD update runs host-side in the host-staged execution modes
/// (identical cost, so it cancels out of every comparison; DESIGN.md §5).
/// The device-resident mode instead dispatches the fused `sgd_rgcn` /
/// `sgd_rgat` modules and keeps the authoritative copy on-device; this
/// struct then only materializes at checkpoint/eval sync points
/// (DESIGN.md §7).
#[derive(Clone, Debug)]
pub struct Params {
    pub rpad: usize,
    pub f: usize,
    pub h: usize,
    pub c: usize,
    /// `[RPAD, F, H]` layer-0 per-relation projection.
    pub w0: Vec<f32>,
    /// `[RPAD, H, C]` layer-1 per-relation projection.
    pub w1: Vec<f32>,
    /// RGAT attention vectors, `[RPAD, H]` and `[RPAD, C]`.
    pub a_src0: Vec<f32>,
    pub a_dst0: Vec<f32>,
    pub a_src1: Vec<f32>,
    pub a_dst1: Vec<f32>,
}

impl Params {
    /// Glorot-ish init, deterministic in `seed`.
    pub fn init(rpad: usize, f: usize, h: usize, c: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x9A1A_77);
        let mut mat = |n: usize, fin: usize, fout: usize| -> Vec<f32> {
            let s = (2.0 / (fin + fout) as f32).sqrt();
            (0..n).map(|_| rng.normal() * s).collect()
        };
        Params {
            rpad,
            f,
            h,
            c,
            w0: mat(rpad * f * h, f, h),
            w1: mat(rpad * h * c, h, c),
            a_src0: mat(rpad * h, h, 1),
            a_dst0: mat(rpad * h, h, 1),
            a_src1: mat(rpad * c, c, 1),
            a_dst1: mat(rpad * c, c, 1),
        }
    }

    pub fn zeros_like(&self) -> Self {
        Params {
            rpad: self.rpad,
            f: self.f,
            h: self.h,
            c: self.c,
            w0: vec![0.0; self.w0.len()],
            w1: vec![0.0; self.w1.len()],
            a_src0: vec![0.0; self.a_src0.len()],
            a_dst0: vec![0.0; self.a_dst0.len()],
            a_src1: vec![0.0; self.a_src1.len()],
            a_dst1: vec![0.0; self.a_dst1.len()],
        }
    }

    /// `self += g`, elementwise over every parameter tensor — the reduction
    /// primitive of the replica all-reduce (DESIGN.md §4). Chaining
    /// `add_assign` in a fixed order is what keeps the merged gradient
    /// bitwise independent of how batches were distributed over replicas.
    pub fn add_assign(&mut self, g: &Params) {
        let pairs: [(&mut Vec<f32>, &Vec<f32>); 6] = [
            (&mut self.w0, &g.w0),
            (&mut self.w1, &g.w1),
            (&mut self.a_src0, &g.a_src0),
            (&mut self.a_dst0, &g.a_dst0),
            (&mut self.a_src1, &g.a_src1),
            (&mut self.a_dst1, &g.a_dst1),
        ];
        for (a, b) in pairs {
            // Hard assert: zip would silently truncate on a shape mismatch,
            // turning a caller bug into a wrong gradient with no diagnostic.
            assert_eq!(a.len(), b.len(), "Params::add_assign shape mismatch");
            for (x, y) in a.iter_mut().zip(b.iter()) {
                *x += y;
            }
        }
    }

    /// `self -= lr * g`.
    pub fn sgd(&mut self, g: &Params, lr: f32) {
        tensor::sgd_step(&mut self.w0, &g.w0, lr);
        tensor::sgd_step(&mut self.w1, &g.w1, lr);
        tensor::sgd_step(&mut self.a_src0, &g.a_src0, lr);
        tensor::sgd_step(&mut self.a_dst0, &g.a_dst0, lr);
        tensor::sgd_step(&mut self.a_src1, &g.a_src1, lr);
        tensor::sgd_step(&mut self.a_dst1, &g.a_dst1, lr);
    }

    /// Slice of `w{layer}` for relation `r`.
    pub fn w_rel(&self, layer: usize, r: usize) -> &[f32] {
        match layer {
            0 => &self.w0[r * self.f * self.h..(r + 1) * self.f * self.h],
            1 => &self.w1[r * self.h * self.c..(r + 1) * self.h * self.c],
            _ => panic!("2-layer model"),
        }
    }

    pub fn l2_norm(&self) -> f32 {
        let s: f32 = [&self.w0, &self.w1, &self.a_src0, &self.a_dst0, &self.a_src1, &self.a_dst1]
            .iter()
            .flat_map(|v| v.iter())
            .map(|x| x * x)
            .sum();
        s.sqrt()
    }

    /// Order-sensitive FNV-1a digest over every tensor's bit patterns, in
    /// the fixed order `w0, w1, a_src0, a_dst0, a_src1, a_dst1` — the same
    /// order the checkpoint codec serializes. Two parameter sets digest
    /// equal iff they are bitwise equal, so `repro train`'s per-epoch
    /// `params digest` line, `repro verify-ckpt`, and the replica
    /// cross-lane audit (DESIGN.md §11) are all one-grep comparable.
    pub fn digest(&self) -> u64 {
        let mut h = crate::util::digest::FNV_OFFSET;
        for t in [&self.w0, &self.w1, &self.a_src0, &self.a_dst0, &self.a_src1, &self.a_dst1] {
            h = crate::util::fnv1a_extend(h, t);
        }
        h
    }

    /// `true` iff every element of every tensor is finite — the
    /// `--audit-every` parameter scan (a NaN/Inf gradient that reached the
    /// optimizer spreads here, and nowhere cheaper to catch post-apply).
    pub fn is_finite(&self) -> bool {
        [&self.w0, &self.w1, &self.a_src0, &self.a_dst0, &self.a_src1, &self.a_dst1]
            .iter()
            .all(|t| t.iter().all(|x| x.is_finite()))
    }

    /// Copy `other`'s values into `self`, reusing every existing
    /// allocation (`Vec::clone_from` keeps capacity) — the rollback
    /// snapshot/restore primitive, allocation-free once the snapshot
    /// exists.
    pub fn copy_from(&mut self, other: &Params) {
        self.rpad = other.rpad;
        self.f = other.f;
        self.h = other.h;
        self.c = other.c;
        self.w0.clone_from(&other.w0);
        self.w1.clone_from(&other.w1);
        self.a_src0.clone_from(&other.a_src0);
        self.a_dst0.clone_from(&other.a_dst0);
        self.a_src1.clone_from(&other.a_src1);
        self.a_dst1.clone_from(&other.a_dst1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic_and_scaled() {
        let a = Params::init(4, 8, 16, 4, 1);
        let b = Params::init(4, 8, 16, 4, 1);
        assert_eq!(a.w0, b.w0);
        let c = Params::init(4, 8, 16, 4, 2);
        assert_ne!(a.w0, c.w0);
        // Glorot scale keeps values small.
        assert!(a.w0.iter().all(|x| x.abs() < 2.0));
        assert_eq!(a.w0.len(), 4 * 8 * 16);
        assert_eq!(a.w1.len(), 4 * 16 * 4);
    }

    #[test]
    fn add_assign_sums_every_tensor() {
        let mut a = Params::init(2, 4, 8, 2, 3);
        let before = a.clone();
        let b = Params::init(2, 4, 8, 2, 5);
        a.add_assign(&b);
        for ((x, y), z) in a.w0.iter().zip(&before.w0).zip(&b.w0) {
            assert_eq!(*x, *y + *z);
        }
        for ((x, y), z) in a.a_dst1.iter().zip(&before.a_dst1).zip(&b.a_dst1) {
            assert_eq!(*x, *y + *z);
        }
    }

    #[test]
    fn sgd_moves_parameters() {
        let mut p = Params::init(2, 4, 8, 2, 3);
        let before = p.w0.clone();
        let mut g = p.zeros_like();
        g.w0.iter_mut().for_each(|x| *x = 1.0);
        p.sgd(&g, 0.1);
        for (a, b) in p.w0.iter().zip(&before) {
            assert!((a - (b - 0.1)).abs() < 1e-6);
        }
        // Untouched params stay put.
        assert_eq!(p.a_src0, Params::init(2, 4, 8, 2, 3).a_src0);
    }

    #[test]
    fn digest_finiteness_and_copy_from_track_bit_identity() {
        let p = Params::init(2, 4, 8, 2, 3);
        let q = Params::init(2, 4, 8, 2, 3);
        assert_eq!(p.digest(), q.digest(), "equal params must digest equal");
        let mut r = p.clone();
        r.a_dst1[0] = f32::from_bits(r.a_dst1[0].to_bits() ^ 1);
        assert_ne!(p.digest(), r.digest(), "one flipped bit must move the digest");
        assert!(p.is_finite());
        r.w1[3] = f32::NAN;
        assert!(!r.is_finite());
        // copy_from restores bit identity without reallocating.
        let cap = r.w0.capacity();
        r.copy_from(&p);
        assert_eq!(r.digest(), p.digest());
        assert!(r.is_finite());
        assert_eq!(r.w0.capacity(), cap, "copy_from must reuse the allocation");
    }

    #[test]
    fn w_rel_slices_are_disjoint_and_cover() {
        let p = Params::init(3, 2, 5, 4, 7);
        let total: usize = (0..3).map(|r| p.w_rel(0, r).len()).sum();
        assert_eq!(total, p.w0.len());
        assert_eq!(p.w_rel(1, 2).len(), 5 * 4);
    }
}
