//! Analytic kernel-count model: exactly how many dispatches each execution
//! plan issues per training step, by stage and phase.
//!
//! Tests assert that the *measured* counts from `runtime::Counters` equal
//! these predictions, which pins down the execution plans and makes the
//! Fig. 8 / Fig. 11 reduction ratios auditable.

use crate::coordinator::ablation::OptConfig;
use crate::models::ModelKind;
use crate::runtime::{Phase, Stage};

/// Per-(stage, phase) dispatch counts for one training step.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StepCounts {
    pub semantic_fwd: usize,
    pub proj_fwd: usize,
    pub proj_bwd: usize,
    pub agg_fwd: usize,
    pub agg_bwd: usize,
    pub fuse_fwd: usize,
    pub fuse_bwd: usize,
    pub head: usize,
    /// Fused on-device optimizer dispatch (`sgd_rgcn`/`sgd_rgat`, issued at
    /// (Head, Bwd)); 1 in device-resident mode, 0 in the host-staged modes
    /// (where the SGD update is host arithmetic, not a dispatch).
    pub opt_step: usize,
}

impl StepCounts {
    pub fn total(&self) -> usize {
        self.semantic_fwd
            + self.proj_fwd
            + self.proj_bwd
            + self.agg_fwd
            + self.agg_bwd
            + self.fuse_fwd
            + self.fuse_bwd
            + self.head
            + self.opt_step
    }

    pub fn forward_total(&self) -> usize {
        self.semantic_fwd + self.proj_fwd + self.agg_fwd + self.fuse_fwd + self.head
    }

    pub fn get(&self, stage: Stage, phase: Phase) -> usize {
        match (stage, phase) {
            // The feature-gather dispatch exists only with --cache-frac > 0,
            // which is off in every ladder mode this model predicts.
            (Stage::Collection, _) => 0,
            (Stage::SemanticBuild, Phase::Fwd) => self.semantic_fwd,
            (Stage::SemanticBuild, Phase::Bwd) => 0,
            (Stage::Projection, Phase::Fwd) => self.proj_fwd,
            (Stage::Projection, Phase::Bwd) => self.proj_bwd,
            (Stage::Aggregation, Phase::Fwd) => self.agg_fwd,
            (Stage::Aggregation, Phase::Bwd) => self.agg_bwd,
            (Stage::Fusion, Phase::Fwd) => self.fuse_fwd,
            (Stage::Fusion, Phase::Bwd) => self.fuse_bwd,
            (Stage::Head, Phase::Fwd) => self.head,
            (Stage::Head, Phase::Bwd) => self.opt_step,
            (Stage::Calib, _) => 0,
        }
    }
}

/// Expected dispatches for one training step.
///
/// `n_rel` is the schema relation count (Algorithm 2 loops over all of
/// them); `live` is the number of relations with >= 1 sampled edge in each
/// layer (only those get projection/aggregation work — PyG skips empty
/// edge types too).
pub fn expected_counts(model: ModelKind, opt: &OptConfig, n_rel: usize, live: &[usize]) -> StepCounts {
    let layers = live.len();
    let live_sum: usize = live.iter().sum();
    let mut c = StepCounts::default();

    // Semantic-graph build: on "GPU" only when not offloaded; one
    // compare+index_select dispatch per relation per layer (Algorithm 2).
    c.semantic_fwd = if opt.offload { 0 } else { layers * n_rel };

    // Feature projection. RGAT projects both endpoint slabs (src & dst).
    let proj_factor = match model {
        ModelKind::Rgcn => 1,
        ModelKind::Rgat => 2,
    };
    if opt.stacked_proj {
        c.proj_fwd = layers * proj_factor;
        c.proj_bwd = layers * proj_factor;
    } else {
        c.proj_fwd = live_sum * proj_factor;
        c.proj_bwd = live_sum * proj_factor;
    }

    // Neighbor aggregation: merged = 1 launch/layer, else 1 per live
    // relation per layer. (Backward mirrors forward; for RGAT the merged
    // backward is the single VJP module.)
    if opt.merge {
        c.agg_fwd = layers;
        c.agg_bwd = layers;
    } else {
        c.agg_fwd = live_sum;
        c.agg_bwd = live_sum;
    }

    c.fuse_fwd = layers;
    c.fuse_bwd = layers;
    // Head: one dispatch either way — `head` on the host-staged plans,
    // `head_full` (on-device slab extract + dlogits scatter) when resident.
    c.head = 1;
    // Device-resident mode adds exactly one dispatch per step: the fused
    // on-device SGD. Every other stage keeps its fully-merged count (the
    // resident backward modules replace their host-staged counterparts 1:1).
    c.opt_step = usize::from(opt.dev_resident);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ablation::OptConfig;

    #[test]
    fn baseline_rgcn_counts() {
        // 2 layers, 10 schema relations, 8 and 6 live.
        let c = expected_counts(ModelKind::Rgcn, &OptConfig::baseline(), 10, &[8, 6]);
        assert_eq!(c.semantic_fwd, 20);
        assert_eq!(c.proj_fwd, 14);
        assert_eq!(c.agg_fwd, 14);
        assert_eq!(c.fuse_fwd, 2);
        assert_eq!(c.head, 1);
        assert_eq!(c.total(), 20 + 14 + 14 + 14 + 14 + 2 + 2 + 1);
    }

    #[test]
    fn hifuse_rgcn_counts() {
        let c = expected_counts(ModelKind::Rgcn, &OptConfig::hifuse(), 10, &[8, 6]);
        assert_eq!(c.semantic_fwd, 0);
        assert_eq!(c.agg_fwd, 2);
        assert_eq!(c.agg_bwd, 2);
        // HiFuse keeps per-relation projection (paper-faithful).
        assert_eq!(c.proj_fwd, 14);
    }

    #[test]
    fn stacked_extension_collapses_projection() {
        let mut opt = OptConfig::hifuse();
        opt.stacked_proj = true;
        let c = expected_counts(ModelKind::Rgcn, &opt, 10, &[8, 6]);
        assert_eq!(c.proj_fwd, 2);
        let r = expected_counts(ModelKind::Rgat, &opt, 10, &[8, 6]);
        assert_eq!(r.proj_fwd, 4); // src + dst per layer
    }

    #[test]
    fn resident_adds_exactly_the_optimizer_dispatch() {
        let stacked = OptConfig { stacked_proj: true, ..OptConfig::hifuse() };
        for model in [ModelKind::Rgcn, ModelKind::Rgat] {
            let host = expected_counts(model, &stacked, 10, &[8, 6]);
            let dev = expected_counts(model, &OptConfig::resident(), 10, &[8, 6]);
            assert_eq!(dev.total(), host.total() + 1, "{model:?}");
            assert_eq!(dev.get(Stage::Head, Phase::Bwd), 1);
            assert_eq!(host.get(Stage::Head, Phase::Bwd), 0);
        }
        // Absolute per-batch dispatch counts the residency suite pins.
        assert_eq!(expected_counts(ModelKind::Rgcn, &OptConfig::resident(), 10, &[8, 6]).total(), 14);
        assert_eq!(expected_counts(ModelKind::Rgat, &OptConfig::resident(), 10, &[8, 6]).total(), 18);
    }

    #[test]
    fn reduction_ratio_in_paper_band_for_rgcn() {
        // With R ~ 100 live relations per layer, HiFuse should cut kernel
        // count by roughly half vs baseline (paper: 43.6%-73.2%).
        let base = expected_counts(ModelKind::Rgcn, &OptConfig::baseline(), 104, &[104, 104]);
        let hf = expected_counts(ModelKind::Rgcn, &OptConfig::hifuse(), 104, &[104, 104]);
        let red = 1.0 - hf.total() as f64 / base.total() as f64;
        assert!(red > 0.40 && red < 0.80, "reduction {red}");
    }

    #[test]
    fn rgat_reduction_smaller_than_rgcn() {
        // The paper observes RGAT's reduction ratio is smaller because of
        // the extra attention-side kernels.
        let red = |m| {
            let b = expected_counts(m, &OptConfig::baseline(), 100, &[100, 100]);
            let h = expected_counts(m, &OptConfig::hifuse(), 100, &[100, 100]);
            1.0 - h.total() as f64 / b.total() as f64
        };
        assert!(red(ModelKind::Rgat) < red(ModelKind::Rgcn));
    }
}
