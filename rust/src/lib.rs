//! # HiFuse-RS
//!
//! Reproduction of *"Accelerating Mini-batch HGNN Training by Reducing CUDA
//! Kernels"* (Wu et al., 2024) as a three-layer Rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: heterogeneous graph store,
//!   synthetic RDF-style dataset generators, mini-batch neighbor sampler,
//!   CPU-offloaded parallel edge-index selection (the paper's Algorithm 2),
//!   execution planner (PyG-style baseline vs HiFuse), asynchronous
//!   CPU/GPU pipeline, metrics and roofline accounting.
//! * **L2** — JAX stage functions AOT-lowered to HLO text (`python/compile`),
//!   loaded and executed here through the PJRT C API (`runtime`).
//! * **L1** — Pallas kernels for the merged neighbor aggregation
//!   (`python/compile/kernels`), the paper's key data-side optimization.
//!
//! Python never runs on the training path: `make artifacts` emits the HLO
//! modules once, then the `repro` binary is self-contained.
//!
//! See `DESIGN.md` for the substitution table (T4 GPU -> CPU PJRT, CUDA
//! kernel launch -> PJRT dispatch) and the per-experiment index.

pub mod config;
pub mod coordinator;
pub mod graph;
pub mod models;
pub mod perf;
pub mod report;
pub mod runtime;
pub mod sampler;
pub mod semantic;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
