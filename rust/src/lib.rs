//! # HiFuse-RS
//!
//! Reproduction of *"Accelerating Mini-batch HGNN Training by Reducing CUDA
//! Kernels"* (Wu et al., 2024) as a three-layer Rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: heterogeneous graph store,
//!   synthetic RDF-style dataset generators, mini-batch neighbor sampler,
//!   CPU-offloaded parallel edge-index selection (the paper's Algorithm 2),
//!   execution planner (PyG-style baseline vs HiFuse), asynchronous
//!   CPU/GPU pipeline, data-parallel replica training
//!   ([`coordinator::ReplicaGroup`], bit-identical for any replica count),
//!   online inference serving ([`serving`]: request coalescing +
//!   deterministic trace replay over forward-only replica lanes),
//!   metrics and roofline accounting.
//! * **L2** — the stage-module interface (`runtime::Manifest`), executed by
//!   a pluggable [`runtime::ExecBackend`]: the pure-Rust
//!   `runtime::SimBackend` (default — interprets every module with the
//!   reference semantics of `python/compile/kernels/ref.py`, zero
//!   artifacts) or the PJRT engine over AOT-lowered HLO text
//!   (`--features pjrt` + `make artifacts`).
//! * **L1** — Pallas kernels for the merged neighbor aggregation
//!   (`python/compile/kernels`), the paper's key data-side optimization,
//!   mirrored 1:1 by the sim interpreter.
//!
//! Python never runs on the training path; with the default backend it
//! never runs at all — `cargo test` and `repro train` are self-contained.
//!
//! One backend dispatch ≙ one "CUDA kernel launch" of the paper, so kernel
//! counts and stage breakdowns (Figs. 7–11) mean the same thing on every
//! backend. See `DESIGN.md` for the substitution table and the design
//! rationale behind each subsystem; `EXPERIMENTS.md` logs the perf-pass
//! findings those docs cite.
//!
//! # Quickstart
//!
//! The whole training path is generic over [`runtime::ExecBackend`]; the
//! built-in `tiny` profile makes the sim backend self-contained:
//!
//! ```
//! use hifuse::coordinator::{prepare_graph_layout, OptConfig, TrainCfg, Trainer};
//! use hifuse::graph::datasets::tiny_graph;
//! use hifuse::models::ModelKind;
//! use hifuse::runtime::{ExecBackend, SimBackend};
//!
//! // One dispatch on any backend ≙ one "CUDA kernel launch" of the paper.
//! let eng = SimBackend::builtin("tiny")?;
//! let opt = OptConfig::hifuse();
//! let mut graph = tiny_graph(1);
//! prepare_graph_layout(&mut graph, &opt);
//! let cfg = TrainCfg { epochs: 1, batch_size: 8, fanout: 3, ..Default::default() };
//! let mut trainer = Trainer::new(&eng, &graph, ModelKind::Rgcn, opt, cfg)?;
//! let metrics = trainer.train_epoch(0)?;
//! assert!(metrics.kernels_total > 0);
//! assert_eq!(metrics.kernels_total, eng.counters().borrow().total());
//! # Ok::<(), anyhow::Error>(())
//! ```

// The reference interpreter is deliberately written as explicit index
// loops mirroring ref.py; these two lints fight that style.
#![allow(clippy::too_many_arguments, clippy::needless_range_loop)]

pub mod config;
pub mod coordinator;
pub mod graph;
pub mod models;
pub mod perf;
pub mod report;
pub mod runtime;
pub mod sampler;
pub mod semantic;
pub mod serving;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
