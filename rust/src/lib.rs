//! # HiFuse-RS
//!
//! Reproduction of *"Accelerating Mini-batch HGNN Training by Reducing CUDA
//! Kernels"* (Wu et al., 2024) as a three-layer Rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: heterogeneous graph store,
//!   synthetic RDF-style dataset generators, mini-batch neighbor sampler,
//!   CPU-offloaded parallel edge-index selection (the paper's Algorithm 2),
//!   execution planner (PyG-style baseline vs HiFuse), asynchronous
//!   CPU/GPU pipeline, metrics and roofline accounting.
//! * **L2** — the stage-module interface (`runtime::Manifest`), executed by
//!   a pluggable [`runtime::ExecBackend`]: the pure-Rust
//!   `runtime::SimBackend` (default — interprets every module with the
//!   reference semantics of `python/compile/kernels/ref.py`, zero
//!   artifacts) or the PJRT engine over AOT-lowered HLO text
//!   (`--features pjrt` + `make artifacts`).
//! * **L1** — Pallas kernels for the merged neighbor aggregation
//!   (`python/compile/kernels`), the paper's key data-side optimization,
//!   mirrored 1:1 by the sim interpreter.
//!
//! Python never runs on the training path; with the default backend it
//! never runs at all — `cargo test` and `repro train` are self-contained.
//!
//! One backend dispatch ≙ one "CUDA kernel launch" of the paper, so kernel
//! counts and stage breakdowns (Figs. 7–11) mean the same thing on every
//! backend. See `DESIGN.md` for the substitution table.

// The reference interpreter is deliberately written as explicit index
// loops mirroring ref.py; these two lints fight that style.
#![allow(clippy::too_many_arguments, clippy::needless_range_loop)]

pub mod config;
pub mod coordinator;
pub mod graph;
pub mod models;
pub mod perf;
pub mod report;
pub mod runtime;
pub mod sampler;
pub mod semantic;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
