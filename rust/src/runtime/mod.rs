//! Execution runtime: the [`ExecBackend`] trait every training-path
//! dispatch goes through, plus its implementations — the pure-Rust
//! [`SimBackend`] (default: interprets every manifest module with the
//! reference semantics of `python/compile/kernels/ref.py`) and the PJRT
//! `Engine` (`--features pjrt`: loads AOT HLO artifacts and executes them
//! through the PJRT C API).
//!
//! The paper's claim is about *counting and reducing kernel dispatches*
//! (DESIGN.md §1), so the backend contract is exactly the dispatch surface:
//! `run` / `run_dev` execute one module (one "CUDA kernel launch"),
//! shape/dtype-check its arguments against the manifest, and record the
//! launch in [`Counters`]. Kernel counts and per-stage breakdowns therefore
//! mean the same thing on every backend; only per-dispatch wall time is
//! substrate-specific, and both backends expose a measured launch overhead
//! via [`ExecBackend::measure_dispatch_overhead`].

pub mod arena;
pub mod cache;
pub mod counters;
pub mod manifest;
pub mod sim;

#[cfg(feature = "pjrt")]
pub mod literal;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use arena::{Arena, ArenaStats};
pub use cache::{CacheHandle, ResidentStore};
pub use counters::{Counters, CpuStageTimes, Event, Phase, Stage, STAGES};
pub use manifest::{DType, Manifest, ModuleSpec};
#[cfg(feature = "pjrt")]
pub use pjrt::{DevTensor, Engine};
pub use sim::{SimBackend, SimDev};

use std::cell::RefCell;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::util::{FaultPlan, HostTensor};

/// A module argument: host data (uploaded per dispatch) or an output buffer
/// from a previous dispatch kept resident on the backend's device — the
/// analogue of leaving an intermediate tensor on the GPU instead of
/// round-tripping it through host memory (EXPERIMENTS.md §Perf #5).
pub enum Arg<'a, D> {
    Host(&'a HostTensor),
    Dev(&'a D),
}

/// A backend's device-resident tensor: declared dtype/shape metadata (for
/// shape checks and byte accounting without touching the data) plus an
/// explicit host round-trip.
pub trait DevBuf {
    fn dtype(&self) -> DType;
    fn shape(&self) -> &[usize];
    /// Copy back to host (only when the coordinator actually needs values).
    fn to_host(&self) -> Result<HostTensor>;
    /// Consume the device buffer into a host tensor. Backends whose
    /// "device" memory *is* host memory (the sim backend) override this to
    /// hand the storage over without a copy.
    fn into_host(self) -> Result<HostTensor>
    where
        Self: Sized,
    {
        self.to_host()
    }
    fn size_bytes(&self) -> usize {
        self.shape().iter().product::<usize>() * 4
    }
}

/// The execution-backend contract: everything the coordinator, the step
/// executor, the perf calibrator, and the benches need from a "device".
///
/// Implementations must type-check every dispatch against the manifest (use
/// [`check_args`]) and record every non-calibration dispatch in the
/// [`Counters`] returned by [`ExecBackend::counters`] — the paper's entire
/// evaluation (Figs. 7–11) is derived from that log.
pub trait ExecBackend {
    /// The backend's device-resident tensor type.
    type Dev: DevBuf;

    /// The artifact/interface manifest this backend executes against.
    fn manifest(&self) -> &Manifest;

    /// Dispatch accounting (counts, stage/phase breakdowns, event log).
    fn counters(&self) -> &RefCell<Counters>;

    /// Dispatch a module with host-resident arguments; returns host outputs.
    fn run(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[&HostTensor],
    ) -> Result<Vec<HostTensor>>;

    /// Dispatch a **single-output** module keeping the result device-
    /// resident; args may mix host tensors and buffers from previous
    /// dispatches. The merged-aggregation / fusion chain of the HiFuse plan
    /// uses this to avoid host round-trips for its intermediates.
    fn run_dev(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[Arg<'_, Self::Dev>],
    ) -> Result<Self::Dev>;

    /// [`ExecBackend::run_dev`] for **multi-output** modules: every return
    /// stays device-resident. The device-resident step uses this for the
    /// backward dispatches that produce several gradients at once
    /// (`head_full`, `att_merged_bwd`, `proj_resident_bwd_*`, `sgd_*`).
    /// Backends that only support the single-output dev path bail (the
    /// default).
    fn run_dev_multi(
        &self,
        name: &'static str,
        _stage: Stage,
        _phase: Phase,
        _args: &[Arg<'_, Self::Dev>],
    ) -> Result<Vec<Self::Dev>> {
        bail!("{name}: backend does not support multi-output device dispatch");
    }

    /// Read a device buffer back to host as an explicit D2H copy outside any
    /// dispatch, counting its full byte size toward [`Counters::d2h_bytes`].
    /// The device-resident step uses this for the loss/metric scalars and
    /// the serve-path logits — the only values that legitimately cross the
    /// PCIe boundary at steady state (`tests/residency.rs`).
    fn fetch(&self, d: Self::Dev) -> Result<HostTensor> {
        self.counters().borrow_mut().add_d2h(d.size_bytes() as u64);
        d.into_host()
    }

    /// [`ExecBackend::fetch`] over the modeled replica interconnect
    /// (NVLink/NCCL rather than PCIe): counts toward
    /// [`Counters::p2p_bytes`], not `d2h_bytes`. The data-parallel replica
    /// path uses this to pull per-batch gradients off each lane for the
    /// host-side all-reduce.
    fn fetch_peer(&self, d: Self::Dev) -> Result<HostTensor> {
        self.counters().borrow_mut().add_p2p(d.size_bytes() as u64);
        d.into_host()
    }

    /// [`ExecBackend::upload`] over the modeled replica interconnect:
    /// counts `valid_elems * 4` toward [`Counters::p2p_bytes`], not
    /// `h2d_bytes`. The replica path uses this for the per-round parameter
    /// broadcast. Backends without an interconnect model bail (the default).
    fn upload_peer(&self, t: &HostTensor, valid_elems: usize) -> Result<Self::Dev> {
        let _ = valid_elems;
        bail!(
            "backend does not support peer upload (tensor shape {:?})",
            t.shape()
        );
    }

    /// Profile name (e.g. "tiny", "bench").
    fn profile(&self) -> &str {
        &self.manifest().profile
    }

    /// Profile constant (NS, EP, RPAD, ...); panics if missing.
    fn cst(&self, name: &str) -> usize {
        self.manifest().cst(name)
    }

    /// Prepare modules ahead of a measurement window (the PJRT engine
    /// compiles them; the sim backend just validates the names).
    fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.manifest().module(n)?;
        }
        Ok(())
    }

    /// Reset counters for a fresh measurement window.
    fn reset_counters(&self, keep_events: bool) {
        let mut c = self.counters().borrow_mut();
        *c = Counters::new(keep_events);
        c.reset();
    }

    /// Attach a deterministic fault-injection plan (DESIGN.md §9). The
    /// backend consults it for [`FaultSite::Dispatch`](crate::util::FaultSite)
    /// entries at the cursor set by [`ExecBackend::fault_cursor`] and
    /// performs a bounded retry-with-backoff, counting each simulated
    /// failure in [`Counters::dispatch_retries`]. Backends without
    /// injection support ignore the plan (the default).
    fn set_fault_plan(&self, _plan: Arc<FaultPlan>) {}

    /// Address the next dispatches at `(epoch, seq)` for fault injection.
    /// Called by the coordinator before each batch's kernel chain; a
    /// planned dispatch fault fires on the first dispatch after the cursor
    /// moves, so retries are counted once per addressed batch. No-op
    /// without an attached plan (the default implementation is empty).
    fn fault_cursor(&self, _epoch: u64, _seq: u64) {}

    /// Arm or disarm the backend-side integrity guard (DESIGN.md §11).
    /// With the guard on, a planned `wire!` corruption of an upload payload
    /// is caught by the transfer-level checksum and the payload is re-sent
    /// clean ([`Counters::integrity_retransmits`]); with it off the
    /// corrupted payload lands silently. No-op on backends without fault
    /// injection (the default implementation is empty).
    fn set_integrity_guard(&self, _on: bool) {}

    /// Place a host tensor on the device as an explicit H2D copy outside
    /// any dispatch, transferring only the leading `valid_elems` elements —
    /// the static-shape analogue of a partial `cudaMemcpyH2D` into a
    /// preallocated device buffer. The returned buffer carries `t`'s full
    /// declared shape (elements past `valid_elems` are device garbage the
    /// caller must never address), and only `valid_elems * 4` bytes count
    /// toward [`Counters::h2d_bytes`]. The feature cache uses this for the
    /// per-batch miss-row upload and the one-time resident store
    /// (DESIGN.md §7).
    fn upload(&self, t: &HostTensor, valid_elems: usize) -> Result<Self::Dev>;

    /// Hand a consumed dispatch output back to the backend for storage
    /// reuse (the sim backend recycles it through its buffer arena;
    /// backends without a pool ignore it). Callers that copy a result out
    /// and would otherwise drop the tensor should route it here so
    /// steady-state dispatch allocations stay ~0.
    fn recycle(&self, _t: HostTensor) {}

    /// [`ExecBackend::recycle`] for a device-resident buffer.
    fn recycle_dev(&self, _d: Self::Dev) {}

    /// Measure the fixed per-dispatch overhead (the "kernel launch cost"):
    /// median wall time of the cheapest always-present module (`head`) over
    /// `n` dispatches.
    fn measure_dispatch_overhead(&self, n: usize) -> Result<Duration> {
        let ns = self.cst("NS");
        let c = self.cst("C");
        let logits = HostTensor::zeros_f32(&[ns, c]);
        let labels = HostTensor::i32(vec![0; ns], &[ns]);
        let mask = HostTensor::f32(vec![1.0; ns], &[ns]);
        self.run("head", Stage::Calib, Phase::Fwd, &[&logits, &labels, &mask])?; // warm
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            let t0 = Instant::now();
            self.run("head", Stage::Calib, Phase::Fwd, &[&logits, &labels, &mask])?;
            samples.push(t0.elapsed());
        }
        samples.sort();
        Ok(samples[samples.len() / 2])
    }
}

pub(crate) fn host_dtype(t: &HostTensor) -> DType {
    match t {
        HostTensor::F32(..) => DType::F32,
        HostTensor::I32(..) => DType::I32,
    }
}

/// Pre-dispatch interface check shared by every backend: arity, dtype and
/// shape of each argument against the manifest, so a profile mismatch fails
/// loudly at the call site. Returns the host-upload byte count
/// (device-resident args transfer nothing).
pub fn check_args<D: DevBuf>(name: &str, spec: &ModuleSpec, args: &[Arg<'_, D>]) -> Result<usize> {
    if args.len() != spec.args.len() {
        bail!("{name}: expected {} args, got {}", spec.args.len(), args.len());
    }
    let mut bytes_in = 0;
    for (a, s) in args.iter().zip(&spec.args) {
        let (dt, shape, nbytes): (DType, &[usize], usize) = match a {
            Arg::Host(h) => (host_dtype(h), h.shape(), h.size_bytes()),
            Arg::Dev(d) => (d.dtype(), d.shape(), 0), // already on device: no transfer
        };
        if dt != s.dtype || shape != s.shape.as_slice() {
            bail!(
                "{name}: arg {:?} expects {}{:?}, got {}{shape:?}",
                s.name,
                s.dtype.name(),
                s.shape,
                dt.name()
            );
        }
        bytes_in += nbytes;
    }
    Ok(bytes_in)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;
    use std::path::PathBuf;

    fn spec2() -> ModuleSpec {
        ModuleSpec {
            name: "m".into(),
            args: vec![
                TensorSpec { name: "x".into(), dtype: DType::F32, shape: vec![2, 3] },
                TensorSpec { name: "i".into(), dtype: DType::I32, shape: vec![4] },
            ],
            rets: vec![],
            file: PathBuf::from("m.hlo.txt"),
        }
    }

    #[test]
    fn check_args_accepts_matching_and_counts_bytes() {
        let s = spec2();
        let x = HostTensor::zeros_f32(&[2, 3]);
        let i = HostTensor::i32(vec![0; 4], &[4]);
        let args: Vec<Arg<'_, SimDev>> = vec![Arg::Host(&x), Arg::Host(&i)];
        assert_eq!(check_args("m", &s, &args).unwrap(), 6 * 4 + 4 * 4);
    }

    #[test]
    fn check_args_rejects_arity_shape_dtype() {
        let s = spec2();
        let x = HostTensor::zeros_f32(&[2, 3]);
        let bad_shape = HostTensor::i32(vec![0; 3], &[3]);
        let bad_dtype = HostTensor::zeros_f32(&[4]);
        let i = HostTensor::i32(vec![0; 4], &[4]);
        let a1: Vec<Arg<'_, SimDev>> = vec![Arg::Host(&x)];
        assert!(check_args("m", &s, &a1).is_err());
        let a2: Vec<Arg<'_, SimDev>> = vec![Arg::Host(&x), Arg::Host(&bad_shape)];
        let err = check_args("m", &s, &a2).unwrap_err().to_string();
        assert!(err.contains("expects"), "{err}");
        let a3: Vec<Arg<'_, SimDev>> = vec![Arg::Host(&x), Arg::Host(&bad_dtype)];
        assert!(check_args("m", &s, &a3).is_err());
    }
}
