//! PJRT runtime: load AOT artifacts, execute them, count every dispatch.
//!
//! This is the "GPU" of the reproduction (DESIGN.md §2): the `xla` crate's
//! CPU PJRT client stands in for the T4, one executable dispatch stands in
//! for one CUDA kernel launch, and the per-dispatch fixed overhead (real,
//! measured by [`Engine::measure_dispatch_overhead`]) plays the role of the
//! CUDA launch overhead the paper optimizes away.
//!
//! `PjRtClient` is `!Send` (Rc internally), so the `Engine` lives on the
//! coordinator's compute thread; pipeline producer threads never touch it.

pub mod counters;
pub mod literal;
pub mod manifest;

pub use counters::{Counters, Event, Phase, Stage, STAGES};
pub use manifest::{DType, Manifest, ModuleSpec};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::HostTensor;

/// A module argument: host data (uploaded per dispatch) or an output buffer
/// from a previous dispatch kept resident on the device — the CPU-PJRT
/// analogue of leaving an intermediate tensor on the GPU instead of
/// round-tripping it through host memory (EXPERIMENTS.md §Perf #5).
pub enum Arg<'a> {
    Host(&'a HostTensor),
    Dev(&'a DevTensor),
}

/// A device-resident tensor: a PJRT buffer plus its declared interface spec
/// (used for shape checks and byte accounting without touching the data).
pub struct DevTensor {
    pub buf: xla::PjRtBuffer,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl DevTensor {
    pub fn size_bytes(&self) -> usize {
        self.shape.iter().product::<usize>() * 4
    }

    /// Copy back to host (only when the coordinator actually needs values).
    pub fn to_host(&self) -> Result<HostTensor> {
        literal::from_literal(&self.buf.to_literal_sync()?)
    }
}

/// Compiled-module cache + dispatch accounting over one PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    pub counters: RefCell<Counters>,
    /// Optional simulated extra launch overhead added (busy-wait) per
    /// dispatch, to emulate a configurable CUDA-launch cost on top of the
    /// real PJRT dispatch overhead. Default zero: the real overhead is
    /// already representative.
    pub extra_launch_overhead: Duration,
}

impl Engine {
    /// Open a profile directory (e.g. `artifacts/tiny`). Modules compile
    /// lazily on first dispatch; `warmup` precompiles a given list.
    pub fn load(profile_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(profile_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            counters: RefCell::new(Counters::new(false)),
            extra_launch_overhead: Duration::ZERO,
        })
    }

    pub fn profile(&self) -> &str {
        &self.manifest.profile
    }

    pub fn cst(&self, name: &str) -> usize {
        self.manifest.cst(name)
    }

    /// Precompile modules (keeps compile time out of measurement windows).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.module(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text for {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling module {name}"))?,
        );
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Dispatch a module: shape/dtype-check args against the manifest,
    /// upload, execute, download, record the launch.
    pub fn run(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let arg_refs: Vec<Arg> = args.iter().map(|a| Arg::Host(a)).collect();
        let (replica, spec, t0, bytes_in) = self.dispatch(name, &arg_refs)?;
        // Single-output modules come back as one array buffer; multi-output
        // modules as one tuple buffer to decompose (return_tuple=False in
        // aot.py gives the former whenever possible).
        let outs: Vec<HostTensor> = if spec.rets.len() == 1 {
            vec![literal::from_literal(&replica[0].to_literal_sync()?)?]
        } else {
            let parts = replica[0].to_literal_sync()?.to_tuple()?;
            if parts.len() != spec.rets.len() {
                bail!("{name}: expected {} returns, got {}", spec.rets.len(), parts.len());
            }
            parts.iter().map(literal::from_literal).collect::<Result<_>>()?
        };
        let dur = t0.elapsed();
        let bytes_out: usize = outs.iter().map(|t| t.size_bytes()).sum();
        self.counters
            .borrow_mut()
            .record(name, stage, phase, dur, bytes_in, bytes_out);
        Ok(outs)
    }

    /// Dispatch a **single-output** module keeping the result on the
    /// device; args may mix host tensors and buffers from previous
    /// dispatches (which then never round-trip through the host). The
    /// merged-aggregation / fusion chain of the HiFuse plan uses this to
    /// keep its 16 MB intermediates device-resident (§Perf #5).
    pub fn run_dev(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[Arg],
    ) -> Result<DevTensor> {
        let (mut replica, spec, t0, bytes_in) = self.dispatch(name, args)?;
        if spec.rets.len() != 1 || replica.len() != 1 {
            bail!("{name}: run_dev requires a single-output module");
        }
        let r = &spec.rets[0];
        let out = DevTensor { buf: replica.swap_remove(0), dtype: r.dtype, shape: r.shape.clone() };
        let dur = t0.elapsed();
        let bytes_out = out.size_bytes();
        self.counters
            .borrow_mut()
            .record(name, stage, phase, dur, bytes_in, bytes_out);
        Ok(out)
    }

    /// Shared dispatch core: type-check, upload host args
    /// (`buffer_from_host_buffer` + `execute_b` — the Literal-based
    /// `execute` leaks its internally-created device buffers,
    /// ~0.5 MB/dispatch measured, EXPERIMENTS.md §Perf #2), execute, apply
    /// the optional simulated launch overhead.
    fn dispatch(
        &self,
        name: &'static str,
        args: &[Arg],
    ) -> Result<(Vec<xla::PjRtBuffer>, ModuleSpec, Instant, usize)> {
        let spec = self.manifest.module(name)?.clone();
        if args.len() != spec.args.len() {
            bail!("{name}: expected {} args, got {}", spec.args.len(), args.len());
        }
        let mut bytes_in = 0;
        for (a, s) in args.iter().zip(&spec.args) {
            let (dt, shape, nbytes): (&str, &[usize], usize) = match a {
                Arg::Host(h) => (h.dtype_str(), h.shape(), h.size_bytes()),
                Arg::Dev(d) => (
                    match d.dtype {
                        DType::F32 => "f32",
                        DType::I32 => "i32",
                    },
                    &d.shape,
                    0, // already on device: no transfer
                ),
            };
            let want = match s.dtype {
                DType::F32 => "f32",
                DType::I32 => "i32",
            };
            if dt != want || shape != s.shape.as_slice() {
                bail!(
                    "{name}: arg {:?} expects {want}{:?}, got {dt}{shape:?}",
                    s.name,
                    s.shape
                );
            }
            bytes_in += nbytes;
        }
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        // Own the uploaded buffers; borrow the device-resident ones.
        let mut uploads: Vec<xla::PjRtBuffer> = Vec::new();
        for a in args {
            if let Arg::Host(h) = a {
                let b = match h {
                    HostTensor::F32(d, s) => self.client.buffer_from_host_buffer(d, s, None),
                    HostTensor::I32(d, s) => self.client.buffer_from_host_buffer(d, s, None),
                }?;
                uploads.push(b);
            }
        }
        let mut up_it = uploads.iter();
        let in_bufs: Vec<&xla::PjRtBuffer> = args
            .iter()
            .map(|a| match a {
                Arg::Host(_) => up_it.next().unwrap(),
                Arg::Dev(d) => &d.buf,
            })
            .collect();
        let mut bufs = exe.execute_b::<&xla::PjRtBuffer>(&in_bufs)?;
        let replica = bufs.swap_remove(0);
        if !self.extra_launch_overhead.is_zero() {
            let spin = Instant::now();
            while spin.elapsed() < self.extra_launch_overhead {
                std::hint::spin_loop();
            }
        }
        Ok((replica, spec, t0, bytes_in))
    }

    /// Measure the fixed per-dispatch overhead (the "kernel launch cost"):
    /// median wall time of the cheapest module in the profile over `n`
    /// dispatches.
    pub fn measure_dispatch_overhead(&self, n: usize) -> Result<Duration> {
        let ns = self.cst("NS");
        let c = self.cst("C");
        // head is the smallest always-present module; its compute is tiny.
        let logits = HostTensor::zeros_f32(&[ns, c]);
        let labels = HostTensor::i32(vec![0; ns], &[ns]);
        let mask = HostTensor::f32(vec![1.0; ns], &[ns]);
        self.run("head", Stage::Calib, Phase::Fwd, &[&logits, &labels, &mask])?; // warm
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            let t0 = Instant::now();
            self.run("head", Stage::Calib, Phase::Fwd, &[&logits, &labels, &mask])?;
            samples.push(t0.elapsed());
        }
        samples.sort();
        Ok(samples[samples.len() / 2])
    }

    /// Reset counters for a fresh measurement window.
    pub fn reset_counters(&self, keep_events: bool) {
        let mut c = self.counters.borrow_mut();
        *c = Counters::new(keep_events);
        c.reset();
    }
}
