//! Kernel-launch accounting and the dispatch event log.
//!
//! One PJRT executable dispatch ≙ one "CUDA kernel launch" of the paper
//! (DESIGN.md §1). Everything the paper's evaluation counts — Fig. 8
//! (kernels per epoch), Fig. 11 (per-stage reduction), Fig. 3a (timeline) —
//! is derived from this log, so counts are *measured*, not modeled.

use std::time::Duration;

/// Which pipeline stage issued a dispatch (paper's stage taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Stage {
    /// Feature collection on device (the `feature_gather` cache-assembly
    /// dispatch — only present with `--cache-frac` > 0, DESIGN.md §7).
    Collection,
    /// Semantic graph build (edge index selection on "GPU" — baseline only).
    SemanticBuild,
    /// Feature projection.
    Projection,
    /// Neighbor aggregation (the scatter/gather kernels).
    Aggregation,
    /// Semantic fusion.
    Fusion,
    /// Loss/accuracy head.
    Head,
    /// Calibration / microbenchmarks (excluded from epoch counts).
    Calib,
}

pub const STAGES: [Stage; 6] = [
    Stage::Collection,
    Stage::SemanticBuild,
    Stage::Projection,
    Stage::Aggregation,
    Stage::Fusion,
    Stage::Head,
];

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Collection => "collection",
            Stage::SemanticBuild => "semantic_build",
            Stage::Projection => "projection",
            Stage::Aggregation => "aggregation",
            Stage::Fusion => "fusion",
            Stage::Head => "head",
            Stage::Calib => "calib",
        }
    }
}

/// Forward or backward half of the training step (Fig. 11 reports the
/// forward pass only).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    Fwd,
    Bwd,
}

/// Wall time of the three **CPU producer stages** per batch (the host-side
/// counterpart of [`Counters::time_by_stage`]): mini-batch sampling, CPU
/// edge-index selection, feature collection. Summed per epoch into
/// `EpochMetrics` and exported by the bench harness, so the paper's Table 1
/// CPU column can be broken down by stage.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CpuStageTimes {
    pub sample: Duration,
    pub select: Duration,
    pub collect: Duration,
}

impl CpuStageTimes {
    pub fn total(&self) -> Duration {
        self.sample + self.select + self.collect
    }

    /// `(stage name, duration)` rows, in pipeline order — the CPU analogue
    /// of the per-stage dispatch-time table.
    pub fn by_stage(&self) -> [(&'static str, Duration); 3] {
        [("sample", self.sample), ("select", self.select), ("collect", self.collect)]
    }
}

impl std::ops::AddAssign for CpuStageTimes {
    fn add_assign(&mut self, o: CpuStageTimes) {
        self.sample += o.sample;
        self.select += o.select;
        self.collect += o.collect;
    }
}

/// One dispatch event (Fig. 3a timeline row).
#[derive(Clone, Debug)]
pub struct Event {
    pub module: &'static str,
    pub stage: Stage,
    pub phase: Phase,
    /// Start offset since counter reset.
    pub t_start: Duration,
    pub dur: Duration,
    pub bytes_in: usize,
    pub bytes_out: usize,
}

/// Dispatch counters + event log. Owned by the `Engine`; reset per
/// measurement window (epoch / batch).
#[derive(Debug, Default)]
pub struct Counters {
    pub events: Vec<Event>,
    /// Log full events (timeline benches) or just counts (training loops).
    pub keep_events: bool,
    counts: std::collections::HashMap<(Stage, Phase), usize>,
    stage_time: std::collections::HashMap<Stage, Duration>,
    pub gpu_time: Duration,
    /// Cumulative host→device transfer bytes since the last reset: every
    /// non-calibration dispatch's host-argument uploads (the per-event
    /// `bytes_in`) plus explicit transfers recorded via
    /// [`Counters::add_h2d`] — the feature channel the cache shrinks
    /// ([`ExecBackend::upload`](super::ExecBackend::upload) partial copies,
    /// and the modeled full-slab shipment on the cache-off path;
    /// DESIGN.md §7). Comparisons between cache modes are meaningful
    /// because the dispatch-argument term is identical in both (the step
    /// executor's inputs don't change); the explicit feature-channel term
    /// is the differential.
    pub h2d_bytes: u64,
    /// Cumulative device→host transfer bytes since the last reset: outputs
    /// of host-returning (`run`) dispatches plus explicit readbacks
    /// recorded via [`Counters::add_d2h`] (the device-resident path's
    /// loss/metric/logit fetches). `run_dev`/`run_dev_multi` results stay
    /// device-resident and contribute nothing until a caller fetches them.
    pub d2h_bytes: u64,
    /// Cumulative **device↔device interconnect** bytes since the last
    /// reset (the modeled NVLink/NCCL channel of the data-parallel replica
    /// path): per-round parameter broadcasts
    /// ([`ExecBackend::upload_peer`](super::ExecBackend::upload_peer)) and
    /// per-batch gradient reductions
    /// ([`ExecBackend::fetch_peer`](super::ExecBackend::fetch_peer)).
    /// Deliberately separate from `h2d_bytes`/`d2h_bytes`: replica
    /// synchronization does not cross the PCIe boundary the residency
    /// contract pins (`tests/residency.rs`), so it must not pollute those
    /// counters. 0 on every single-backend run.
    pub p2p_bytes: u64,
    /// Batch-slot feature reads served by the device-resident cache
    /// (recorded by `assemble_batch` alongside the gather dispatch).
    pub cache_hits: u64,
    /// Batch-slot feature reads that had to be gathered on the CPU and
    /// uploaded (the miss rows of the gather dispatch).
    pub cache_misses: u64,
    /// Transient dispatch failures absorbed by the backend's bounded
    /// retry-with-backoff (DESIGN.md §9). Only injected faults produce
    /// these today (the sim backend cannot fail spontaneously), so under a
    /// `--fault-spec` this equals the number of planned dispatch failures
    /// actually exercised; it is 0 on every fault-free run.
    pub dispatch_retries: u64,
    /// Data-integrity violations detected since the last reset (DESIGN.md
    /// §11): non-finite loss/gradient scans tripped by the per-batch
    /// `--guard`, digest mismatches found by `--audit-every` parameter /
    /// cache-slab / cross-lane audits, and corrupted-payload detections on
    /// the upload channel. Like `dispatch_retries`, only injected faults
    /// (`flip!`/`nan!`/`wire!`) produce these today, so under a fault spec
    /// this equals the number of corruptions actually caught; 0 on every
    /// clean run.
    pub integrity_violations: u64,
    /// Corrupted H2D/p2p payloads the guarded upload path dropped and
    /// re-sent clean (the `wire!` site's recovery action). Always ≤
    /// `integrity_violations`; 0 when the guard is off (corruption then
    /// lands silently) or no wire faults fired.
    pub integrity_retransmits: u64,
    /// Snapshot of the backend's buffer-arena traffic (cumulative since
    /// backend construction; refreshed by the sim backend on every
    /// dispatch, all-zero on backends without an arena).
    pub arena: super::ArenaStats,
    epoch_start: Option<std::time::Instant>,
}

impl Counters {
    pub fn new(keep_events: bool) -> Self {
        Counters { keep_events, ..Default::default() }
    }

    pub fn reset(&mut self) {
        self.events.clear();
        self.counts.clear();
        self.stage_time.clear();
        self.gpu_time = Duration::ZERO;
        self.h2d_bytes = 0;
        self.d2h_bytes = 0;
        self.p2p_bytes = 0;
        self.cache_hits = 0;
        self.cache_misses = 0;
        self.dispatch_retries = 0;
        self.integrity_violations = 0;
        self.integrity_retransmits = 0;
        self.epoch_start = Some(std::time::Instant::now());
    }

    /// Record an explicit host→device transfer that happened outside a
    /// dispatch's argument uploads (e.g. the partial miss-row copy of
    /// [`ExecBackend::upload`](super::ExecBackend::upload), or the modeled
    /// per-batch slab shipment of the cache-off feature channel).
    pub fn add_h2d(&mut self, bytes: u64) {
        self.h2d_bytes += bytes;
    }

    /// Record an explicit device→host transfer (outputs of host-returning
    /// dispatches, and the device-resident path's scalar/logit fetches).
    pub fn add_d2h(&mut self, bytes: u64) {
        self.d2h_bytes += bytes;
    }

    /// Record an explicit device↔device interconnect transfer (replica
    /// parameter broadcast / gradient reduction — never PCIe).
    pub fn add_p2p(&mut self, bytes: u64) {
        self.p2p_bytes += bytes;
    }

    /// Record one batch's cache hit/miss split (feature rows served from
    /// the device-resident store vs gathered on CPU and uploaded).
    pub fn add_cache(&mut self, hits: u64, misses: u64) {
        self.cache_hits += hits;
        self.cache_misses += misses;
    }

    /// Fraction of batch-slot feature reads served by the resident cache
    /// since the last reset (0.0 when the cache never ran).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    pub fn record(
        &mut self,
        module: &'static str,
        stage: Stage,
        phase: Phase,
        dur: Duration,
        bytes_in: usize,
        bytes_out: usize,
    ) {
        if stage != Stage::Calib {
            *self.counts.entry((stage, phase)).or_insert(0) += 1;
            *self.stage_time.entry(stage).or_insert(Duration::ZERO) += dur;
            self.gpu_time += dur;
            self.h2d_bytes += bytes_in as u64;
        }
        if self.keep_events {
            let t_start = self
                .epoch_start
                .map(|s| s.elapsed().saturating_sub(dur))
                .unwrap_or_default();
            self.events.push(Event { module, stage, phase, t_start, dur, bytes_in, bytes_out });
        }
    }

    /// Total dispatches ("kernel launches") excluding calibration.
    pub fn total(&self) -> usize {
        self.counts.values().sum()
    }

    pub fn count(&self, stage: Stage) -> usize {
        self.counts
            .iter()
            .filter(|((s, _), _)| *s == stage)
            .map(|(_, c)| c)
            .sum()
    }

    pub fn count_phase(&self, stage: Stage, phase: Phase) -> usize {
        self.counts.get(&(stage, phase)).copied().unwrap_or(0)
    }

    pub fn by_stage(&self) -> Vec<(Stage, usize)> {
        STAGES.iter().map(|&s| (s, self.count(s))).collect()
    }

    /// Accumulated dispatch ("GPU") time per stage, epoch counts only.
    pub fn time_by_stage(&self) -> Vec<(Stage, Duration)> {
        STAGES
            .iter()
            .map(|&s| (s, self.stage_time.get(&s).copied().unwrap_or(Duration::ZERO)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_by_stage_and_phase() {
        let mut c = Counters::new(false);
        c.reset();
        c.record("a", Stage::Aggregation, Phase::Fwd, Duration::from_micros(5), 10, 10);
        c.record("a", Stage::Aggregation, Phase::Bwd, Duration::from_micros(5), 10, 10);
        c.record("p", Stage::Projection, Phase::Fwd, Duration::from_micros(2), 4, 4);
        assert_eq!(c.total(), 3);
        assert_eq!(c.count(Stage::Aggregation), 2);
        assert_eq!(c.count_phase(Stage::Aggregation, Phase::Fwd), 1);
        assert_eq!(c.gpu_time, Duration::from_micros(12));
        let times = c.time_by_stage();
        assert!(times.contains(&(Stage::Aggregation, Duration::from_micros(10))));
        assert!(times.contains(&(Stage::Projection, Duration::from_micros(2))));
        assert!(times.contains(&(Stage::Head, Duration::ZERO)));
    }

    #[test]
    fn calib_excluded_from_counts() {
        let mut c = Counters::new(false);
        c.reset();
        c.record("x", Stage::Calib, Phase::Fwd, Duration::from_micros(50), 1, 1);
        assert_eq!(c.total(), 0);
        assert_eq!(c.gpu_time, Duration::ZERO);
        assert_eq!(c.h2d_bytes, 0, "calib uploads must not count as h2d");
    }

    #[test]
    fn h2d_accumulates_dispatch_args_and_explicit_transfers() {
        let mut c = Counters::new(false);
        c.reset();
        c.record("a", Stage::Projection, Phase::Fwd, Duration::from_micros(1), 100, 40);
        assert_eq!(c.h2d_bytes, 100);
        c.add_h2d(28);
        c.add_d2h(40);
        c.add_p2p(64);
        assert_eq!(c.h2d_bytes, 128);
        assert_eq!(c.d2h_bytes, 40);
        assert_eq!(c.p2p_bytes, 64, "peer traffic is its own channel");
        c.reset();
        assert_eq!((c.h2d_bytes, c.d2h_bytes, c.p2p_bytes), (0, 0, 0));
    }

    #[test]
    fn cache_hit_rate_is_guarded_and_resets() {
        let mut c = Counters::new(false);
        c.reset();
        assert_eq!(c.cache_hit_rate(), 0.0);
        c.add_cache(3, 1);
        assert_eq!((c.cache_hits, c.cache_misses), (3, 1));
        assert!((c.cache_hit_rate() - 0.75).abs() < 1e-12);
        c.reset();
        assert_eq!((c.cache_hits, c.cache_misses), (0, 0));
    }

    #[test]
    fn integrity_counters_reset_with_the_window() {
        let mut c = Counters::new(false);
        c.reset();
        c.integrity_violations += 3;
        c.integrity_retransmits += 1;
        assert_eq!((c.integrity_violations, c.integrity_retransmits), (3, 1));
        c.reset();
        assert_eq!((c.integrity_violations, c.integrity_retransmits), (0, 0));
    }

    #[test]
    fn cpu_stage_times_sum_and_accumulate() {
        let mut a = CpuStageTimes {
            sample: Duration::from_micros(3),
            select: Duration::from_micros(2),
            collect: Duration::from_micros(1),
        };
        assert_eq!(a.total(), Duration::from_micros(6));
        a += CpuStageTimes { sample: Duration::from_micros(1), ..Default::default() };
        assert_eq!(a.sample, Duration::from_micros(4));
        assert_eq!(a.by_stage()[0], ("sample", Duration::from_micros(4)));
        assert_eq!(a.by_stage()[2], ("collect", Duration::from_micros(1)));
    }

    #[test]
    fn events_kept_only_when_enabled() {
        let mut on = Counters::new(true);
        on.reset();
        on.record("m", Stage::Head, Phase::Fwd, Duration::from_micros(1), 2, 2);
        assert_eq!(on.events.len(), 1);
        let mut off = Counters::new(false);
        off.reset();
        off.record("m", Stage::Head, Phase::Fwd, Duration::from_micros(1), 2, 2);
        assert!(off.events.is_empty());
        assert_eq!(off.total(), 1);
    }
}
