//! Size-classed scratch-buffer arena for the dispatch hot path.
//!
//! Every Sim dispatch used to allocate a fresh `Vec` per operand scratch
//! and per result; the HGNN-training characterization literature (and the
//! paper's own CPU-stage profiling) identifies exactly this allocation
//! churn as a dominant host-side cost. The arena replaces it with checkout
//! / reclaim over power-of-two size classes:
//!
//! * `take_f32` / `take_i32` — check out a zeroed buffer of the exact
//!   requested length, reusing a recycled buffer of the same class when
//!   one is free (a **hit**) and heap-allocating otherwise (a **miss**).
//! * `put_f32` / `put_i32` / [`Arena::reclaim`] — return a buffer (or a
//!   whole [`HostTensor`], the "into-pooled" path) for reuse.
//!
//! After a warm-up step every buffer the training step needs exists in the
//! pool, so steady-state misses — i.e. real allocations per step — are ~0;
//! [`ArenaStats`] exports hit/miss/byte counters through the dispatch
//! [`Counters`](super::Counters) so tests and the bench harness can assert
//! exactly that. Buffers shorter than [`MIN_POOLED`] elements are not worth
//! recycling (scalars, tiny index vectors) and bypass the pool untracked.

use std::collections::HashMap;

use crate::util::HostTensor;

/// Buffers below this element count bypass the pool (plain allocation).
pub const MIN_POOLED: usize = 64;

/// Cumulative arena traffic counters (since backend construction).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Checkouts served from a recycled buffer (no allocation).
    pub hits: u64,
    /// Checkouts that had to heap-allocate a new buffer.
    pub misses: u64,
    /// Bytes handed back for reuse via the put/reclaim path.
    pub bytes_recycled: u64,
    /// Bytes newly allocated by misses.
    pub bytes_allocated: u64,
}

impl std::ops::AddAssign for ArenaStats {
    /// Sum traffic counters — used to aggregate per-replica arenas into the
    /// group totals (DESIGN.md §4).
    fn add_assign(&mut self, o: ArenaStats) {
        self.hits += o.hits;
        self.misses += o.misses;
        self.bytes_recycled += o.bytes_recycled;
        self.bytes_allocated += o.bytes_allocated;
    }
}

/// The pool proper: free lists keyed by power-of-two capacity class.
#[derive(Debug, Default)]
pub struct Arena {
    f32s: HashMap<usize, Vec<Vec<f32>>>,
    i32s: HashMap<usize, Vec<Vec<i32>>>,
    stats: ArenaStats,
}

/// Capacity class a checkout of `len` elements is served from.
fn class_of(len: usize) -> usize {
    len.next_power_of_two().max(MIN_POOLED)
}

impl Arena {
    pub fn new() -> Self {
        Arena::default()
    }

    pub fn stats(&self) -> ArenaStats {
        self.stats
    }

    /// Check out a zeroed f32 buffer of exactly `len` elements.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        if len < MIN_POOLED {
            return vec![0.0; len];
        }
        let class = class_of(len);
        if let Some(mut v) = self.f32s.get_mut(&class).and_then(|l| l.pop()) {
            self.stats.hits += 1;
            v.clear();
            v.resize(len, 0.0);
            return v;
        }
        self.stats.misses += 1;
        self.stats.bytes_allocated += (class * 4) as u64;
        let mut v = Vec::with_capacity(class);
        v.resize(len, 0.0);
        v
    }

    /// Check out a zeroed i32 buffer of exactly `len` elements.
    pub fn take_i32(&mut self, len: usize) -> Vec<i32> {
        if len < MIN_POOLED {
            return vec![0; len];
        }
        let class = class_of(len);
        if let Some(mut v) = self.i32s.get_mut(&class).and_then(|l| l.pop()) {
            self.stats.hits += 1;
            v.clear();
            v.resize(len, 0);
            return v;
        }
        self.stats.misses += 1;
        self.stats.bytes_allocated += (class * 4) as u64;
        let mut v = Vec::with_capacity(class);
        v.resize(len, 0);
        v
    }

    /// Return a buffer for reuse. Classified by capacity rounded *down* to
    /// a power of two, so a future `take` of that class never reallocates.
    pub fn put_f32(&mut self, v: Vec<f32>) {
        let cap = v.capacity();
        if cap < MIN_POOLED {
            return; // tiny buffers are cheaper to reallocate than to track
        }
        let class = prev_power_of_two(cap);
        self.stats.bytes_recycled += (cap * 4) as u64;
        self.f32s.entry(class).or_default().push(v);
    }

    pub fn put_i32(&mut self, v: Vec<i32>) {
        let cap = v.capacity();
        if cap < MIN_POOLED {
            return;
        }
        let class = prev_power_of_two(cap);
        self.stats.bytes_recycled += (cap * 4) as u64;
        self.i32s.entry(class).or_default().push(v);
    }

    /// The into-pooled path for [`HostTensor`]: consume a tensor and
    /// recycle its storage.
    pub fn reclaim(&mut self, t: HostTensor) {
        match t {
            HostTensor::F32(v, _) => self.put_f32(v),
            HostTensor::I32(v, _) => self.put_i32(v),
        }
    }

    /// The from-pooled path: a zeroed f32 [`HostTensor`] of `shape` backed
    /// by pooled storage.
    pub fn host_f32(&mut self, shape: &[usize]) -> HostTensor {
        let v = self.take_f32(shape.iter().product());
        HostTensor::f32(v, shape)
    }

    pub fn host_i32(&mut self, shape: &[usize]) -> HostTensor {
        let v = self.take_i32(shape.iter().product());
        HostTensor::i32(v, shape)
    }
}

fn prev_power_of_two(n: usize) -> usize {
    debug_assert!(n > 0);
    let npot = n.next_power_of_two();
    if npot == n {
        n
    } else {
        npot / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_add_assign_sums_fields() {
        let mut a = ArenaStats { hits: 1, misses: 2, bytes_recycled: 3, bytes_allocated: 4 };
        let b = ArenaStats { hits: 10, misses: 20, bytes_recycled: 30, bytes_allocated: 40 };
        a += b;
        assert_eq!(
            a,
            ArenaStats { hits: 11, misses: 22, bytes_recycled: 33, bytes_allocated: 44 }
        );
    }

    #[test]
    fn take_put_take_hits() {
        let mut a = Arena::new();
        let v = a.take_f32(100);
        assert_eq!(v.len(), 100);
        assert!(v.iter().all(|&x| x == 0.0));
        assert_eq!(a.stats().misses, 1);
        a.put_f32(v);
        let w = a.take_f32(70); // same class (128)
        assert_eq!(w.len(), 70);
        assert_eq!(a.stats().hits, 1);
        assert_eq!(a.stats().misses, 1);
    }

    #[test]
    fn recycled_buffers_come_back_zeroed() {
        let mut a = Arena::new();
        let mut v = a.take_f32(64);
        v.iter_mut().for_each(|x| *x = 7.0);
        a.put_f32(v);
        let w = a.take_f32(64);
        assert!(w.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn tiny_buffers_bypass_the_pool() {
        let mut a = Arena::new();
        let v = a.take_f32(3);
        assert_eq!(v.len(), 3);
        assert_eq!(a.stats().misses, 0);
        a.put_f32(v);
        assert_eq!(a.stats().bytes_recycled, 0);
    }

    #[test]
    fn i32_pool_is_independent() {
        let mut a = Arena::new();
        let v = a.take_i32(128);
        a.put_i32(v);
        let _f = a.take_f32(128); // must not steal the i32 buffer
        assert_eq!(a.stats().misses, 2);
        let w = a.take_i32(128);
        assert_eq!(w.len(), 128);
        assert_eq!(a.stats().hits, 1);
    }

    #[test]
    fn host_tensor_roundtrip_recycles() {
        let mut a = Arena::new();
        let t = a.host_f32(&[8, 16]);
        assert_eq!(t.shape(), &[8, 16]);
        a.reclaim(t);
        let _ = a.host_f32(&[16, 8]);
        assert_eq!(a.stats().hits, 1);
    }

    #[test]
    fn class_rounding_is_consistent() {
        assert_eq!(class_of(1), MIN_POOLED);
        assert_eq!(class_of(65), 128);
        assert_eq!(prev_power_of_two(128), 128);
        assert_eq!(prev_power_of_two(130), 128);
    }
}
