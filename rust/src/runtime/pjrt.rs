//! PJRT execution backend (`--features pjrt`): load AOT artifacts, execute
//! them, count every dispatch.
//!
//! This is the "GPU" of the reproduction (DESIGN.md §1): the `xla` crate's
//! CPU PJRT client stands in for the T4, one executable dispatch stands in
//! for one CUDA kernel launch, and the per-dispatch fixed overhead (real,
//! measured by [`ExecBackend::measure_dispatch_overhead`]) plays the role
//! of the CUDA launch overhead the paper optimizes away.
//!
//! `PjRtClient` is `!Send` (Rc internally), so the `Engine` lives on the
//! coordinator's compute thread; pipeline producer threads never touch it.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::{check_args, literal, Arg, Counters, DType, DevBuf, ExecBackend, Manifest, Phase, Stage};
use crate::util::HostTensor;

/// A device-resident tensor: a PJRT buffer plus its declared interface spec.
pub struct DevTensor {
    pub buf: xla::PjRtBuffer,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl DevBuf for DevTensor {
    fn dtype(&self) -> DType {
        self.dtype
    }

    fn shape(&self) -> &[usize] {
        &self.shape
    }

    fn to_host(&self) -> Result<HostTensor> {
        literal::from_literal(&self.buf.to_literal_sync()?)
    }
}

/// Compiled-module cache + dispatch accounting over one PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    counters: RefCell<Counters>,
    /// Optional simulated extra launch overhead added (busy-wait) per
    /// dispatch, to emulate a configurable CUDA-launch cost on top of the
    /// real PJRT dispatch overhead. Default zero: the real overhead is
    /// already representative.
    pub extra_launch_overhead: Duration,
}

impl Engine {
    /// Open a profile directory (e.g. `artifacts/tiny`). Modules compile
    /// lazily on first dispatch; `warmup` precompiles a given list.
    pub fn load(profile_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(profile_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            counters: RefCell::new(Counters::new(false)),
            extra_launch_overhead: Duration::ZERO,
        })
    }

    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.module(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text for {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling module {name}"))?,
        );
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Shared dispatch core: type-check, upload host args
    /// (`buffer_from_host_buffer` + `execute_b` — the Literal-based
    /// `execute` leaks its internally-created device buffers,
    /// ~0.5 MB/dispatch measured, EXPERIMENTS.md §Perf #2), execute, apply
    /// the optional simulated launch overhead.
    fn dispatch(
        &self,
        name: &'static str,
        args: &[Arg<'_, DevTensor>],
    ) -> Result<(Vec<xla::PjRtBuffer>, super::ModuleSpec, Instant, usize)> {
        let spec = self.manifest.module(name)?.clone();
        let bytes_in = check_args(name, &spec, args)?;
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        // Own the uploaded buffers; borrow the device-resident ones.
        let mut uploads: Vec<xla::PjRtBuffer> = Vec::new();
        for a in args {
            if let Arg::Host(h) = a {
                let b = match h {
                    HostTensor::F32(d, s) => self.client.buffer_from_host_buffer(d, s, None),
                    HostTensor::I32(d, s) => self.client.buffer_from_host_buffer(d, s, None),
                }?;
                uploads.push(b);
            }
        }
        let mut up_it = uploads.iter();
        let in_bufs: Vec<&xla::PjRtBuffer> = args
            .iter()
            .map(|a| match a {
                Arg::Host(_) => up_it.next().unwrap(),
                Arg::Dev(d) => &d.buf,
            })
            .collect();
        let mut bufs = exe.execute_b::<&xla::PjRtBuffer>(&in_bufs)?;
        let replica = bufs.swap_remove(0);
        if !self.extra_launch_overhead.is_zero() {
            let spin = Instant::now();
            while spin.elapsed() < self.extra_launch_overhead {
                std::hint::spin_loop();
            }
        }
        Ok((replica, spec, t0, bytes_in))
    }
}

impl ExecBackend for Engine {
    type Dev = DevTensor;

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn counters(&self) -> &RefCell<Counters> {
        &self.counters
    }

    /// Precompile modules (keeps compile time out of measurement windows).
    fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Dispatch a module: shape/dtype-check args against the manifest,
    /// upload, execute, download, record the launch.
    fn run(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let arg_refs: Vec<Arg<'_, DevTensor>> = args.iter().map(|&a| Arg::Host(a)).collect();
        let (replica, spec, t0, bytes_in) = self.dispatch(name, &arg_refs)?;
        // Single-output modules come back as one array buffer; multi-output
        // modules as one tuple buffer to decompose (return_tuple=False in
        // aot.py gives the former whenever possible).
        let outs: Vec<HostTensor> = if spec.rets.len() == 1 {
            vec![literal::from_literal(&replica[0].to_literal_sync()?)?]
        } else {
            let parts = replica[0].to_literal_sync()?.to_tuple()?;
            if parts.len() != spec.rets.len() {
                bail!("{name}: expected {} returns, got {}", spec.rets.len(), parts.len());
            }
            parts.iter().map(literal::from_literal).collect::<Result<_>>()?
        };
        let dur = t0.elapsed();
        let bytes_out: usize = outs.iter().map(|t| t.size_bytes()).sum();
        {
            let mut c = self.counters.borrow_mut();
            c.record(name, stage, phase, dur, bytes_in, bytes_out);
            if stage != Stage::Calib {
                // Host-returning dispatch: outputs cross back to the host.
                c.add_d2h(bytes_out as u64);
            }
        }
        Ok(outs)
    }

    /// Explicit H2D placement of a host tensor (feature-cache resident
    /// store / miss rows). PJRT's host-buffer copy has no partial-length
    /// form, so the whole tensor is copied; the *accounted* transfer is the
    /// valid prefix, matching the sim backend's model of a partial
    /// `cudaMemcpyH2D` into a preallocated static buffer.
    fn upload(&self, t: &HostTensor, valid_elems: usize) -> Result<DevTensor> {
        let buf = match t {
            HostTensor::F32(d, s) => self.client.buffer_from_host_buffer(d, s, None),
            HostTensor::I32(d, s) => self.client.buffer_from_host_buffer(d, s, None),
        }?;
        let valid = valid_elems.min(t.len());
        self.counters.borrow_mut().add_h2d(valid as u64 * 4);
        Ok(DevTensor {
            buf,
            dtype: super::host_dtype(t),
            shape: t.shape().to_vec(),
        })
    }

    /// Dispatch a **single-output** module keeping the result on the
    /// device; args may mix host tensors and buffers from previous
    /// dispatches (which then never round-trip through the host).
    fn run_dev(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[Arg<'_, DevTensor>],
    ) -> Result<DevTensor> {
        let (mut replica, spec, t0, bytes_in) = self.dispatch(name, args)?;
        if spec.rets.len() != 1 || replica.len() != 1 {
            bail!("{name}: run_dev requires a single-output module");
        }
        let r = &spec.rets[0];
        let out = DevTensor { buf: replica.swap_remove(0), dtype: r.dtype, shape: r.shape.clone() };
        let dur = t0.elapsed();
        let bytes_out = out.size_bytes();
        self.counters
            .borrow_mut()
            .record(name, stage, phase, dur, bytes_in, bytes_out);
        Ok(out)
    }
}
