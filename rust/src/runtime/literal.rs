//! `HostTensor` <-> `xla::Literal` bridge (the "host <-> device transfer"
//! of the CPU-PJRT substitution).

use anyhow::{bail, Result};

use crate::util::HostTensor;

/// Upload a host tensor as an XLA literal of the right shape.
pub fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        HostTensor::F32(d, s) => {
            if s.is_empty() {
                return Ok(xla::Literal::scalar(d[0]));
            }
            xla::Literal::vec1(d).reshape(&dims)?
        }
        HostTensor::I32(d, s) => {
            if s.is_empty() {
                return Ok(xla::Literal::scalar(d[0]));
            }
            xla::Literal::vec1(d).reshape(&dims)?
        }
    };
    Ok(lit)
}

/// Download an XLA literal back into a host tensor.
pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(HostTensor::F32(lit.to_vec::<f32>()?, dims)),
        xla::ElementType::S32 => Ok(HostTensor::I32(lit.to_vec::<i32>()?, dims)),
        other => bail!("unsupported element type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let lit = to_literal(&t).unwrap();
        assert_eq!(from_literal(&lit).unwrap(), t);
    }

    #[test]
    fn i32_roundtrip() {
        let t = HostTensor::i32(vec![7, -1, 0], &[3]);
        let lit = to_literal(&t).unwrap();
        assert_eq!(from_literal(&lit).unwrap(), t);
    }

    #[test]
    fn scalar_roundtrip() {
        for t in [HostTensor::scalar_i32(5), HostTensor::scalar_f32(2.5)] {
            let lit = to_literal(&t).unwrap();
            assert_eq!(from_literal(&lit).unwrap(), t);
        }
    }
}
