//! The pure-Rust execution backend: interprets every manifest module with
//! the reference semantics of `python/compile/kernels/ref.py` and
//! `python/compile/model.py`, with the same shape/dtype checking and
//! per-dispatch [`Counters`] recording as the PJRT engine.
//!
//! One interpreted module evaluation ≙ one "CUDA kernel launch" of the
//! paper, exactly like one PJRT executable dispatch — so kernel counts,
//! per-stage breakdowns (Figs. 7–11), and the gradient math are
//! bit-identical in meaning across backends. A configurable simulated
//! launch overhead (busy-wait per dispatch) plays the role of the CUDA
//! launch cost the paper optimizes away, making dispatch-bound regimes
//! reproducible deterministically on any machine with zero AOT artifacts.
//!
//! Backward formulas are the hand-derived VJPs of the reference forward
//! functions; they were validated against `jax.vjp` of the Python oracles
//! to f32 round-off, and the finite-difference tests below pin them down.

use std::cell::RefCell;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::{
    check_args, host_dtype, Arg, Counters, DType, DevBuf, ExecBackend, Manifest, ModuleSpec,
    Phase, Stage,
};
use crate::util::HostTensor;

/// LeakyReLU negative slope (ref.py `LEAKY_SLOPE`).
const LEAKY_SLOPE: f32 = 0.2;
/// Finite stand-in for -inf: keeps padded segments NaN-free (ref.py).
const NEG_INF: f32 = -1e30;
/// Softmax-denominator floor (ref.py `att_agg_ref`).
const DENOM_EPS: f32 = 1e-16;

/// The sim backend's "device-resident" tensor. There is no device, so this
/// is a host tensor that models the residency contract: chaining it into
/// the next dispatch transfers zero bytes in the accounting.
pub struct SimDev(pub(crate) HostTensor);

impl DevBuf for SimDev {
    fn dtype(&self) -> DType {
        host_dtype(&self.0)
    }

    fn shape(&self) -> &[usize] {
        self.0.shape()
    }

    fn to_host(&self) -> Result<HostTensor> {
        Ok(self.0.clone())
    }
}

/// Reference interpreter + dispatch accounting: the default backend.
pub struct SimBackend {
    manifest: Manifest,
    counters: RefCell<Counters>,
    /// Simulated per-dispatch launch overhead (busy-wait), the knob the
    /// dispatch-reduction experiments turn. Default zero.
    launch_overhead: Duration,
}

impl SimBackend {
    /// Backend over a built-in profile ("tiny" or "bench") — zero
    /// artifacts, zero Python.
    pub fn builtin(profile: &str) -> Result<SimBackend> {
        Ok(Self::new(Manifest::builtin(profile)?))
    }

    /// Backend over an on-disk artifact manifest (interface parity checks
    /// against the AOT emitter; the HLO files themselves are never read).
    pub fn load(profile_dir: &Path) -> Result<SimBackend> {
        Ok(Self::new(Manifest::load(profile_dir)?))
    }

    pub fn new(manifest: Manifest) -> SimBackend {
        SimBackend {
            manifest,
            counters: RefCell::new(Counters::new(false)),
            launch_overhead: Duration::ZERO,
        }
    }

    /// Set the simulated per-dispatch launch overhead.
    pub fn set_launch_overhead(&mut self, d: Duration) {
        self.launch_overhead = d;
    }

    pub fn launch_overhead(&self) -> Duration {
        self.launch_overhead
    }

    /// Dispatch core: check args, interpret, verify outputs against the
    /// declared returns, apply the simulated launch overhead, record.
    fn exec(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[Arg<'_, SimDev>],
    ) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.module(name)?;
        let bytes_in = check_args(name, spec, args)?;
        let t0 = Instant::now();
        let host_args: Vec<&HostTensor> = args
            .iter()
            .map(|a| match a {
                Arg::Host(h) => *h,
                Arg::Dev(d) => &d.0,
            })
            .collect();
        let outs = interpret(name, spec, &host_args)?;
        if outs.len() != spec.rets.len() {
            bail!(
                "{name}: interpreter returned {} outputs, declared {}",
                outs.len(),
                spec.rets.len()
            );
        }
        for (o, r) in outs.iter().zip(&spec.rets) {
            if host_dtype(o) != r.dtype || o.shape() != r.shape.as_slice() {
                bail!(
                    "{name}: interpreter returned {}{:?} where the manifest declares {}{:?}",
                    host_dtype(o).name(),
                    o.shape(),
                    r.dtype.name(),
                    r.shape
                );
            }
        }
        if !self.launch_overhead.is_zero() {
            let spin = Instant::now();
            while spin.elapsed() < self.launch_overhead {
                std::hint::spin_loop();
            }
        }
        let dur = t0.elapsed();
        let bytes_out: usize = outs.iter().map(|t| t.size_bytes()).sum();
        self.counters
            .borrow_mut()
            .record(name, stage, phase, dur, bytes_in, bytes_out);
        Ok(outs)
    }
}

impl ExecBackend for SimBackend {
    type Dev = SimDev;

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn counters(&self) -> &RefCell<Counters> {
        &self.counters
    }

    fn run(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let wrapped: Vec<Arg<'_, SimDev>> = args.iter().map(|&a| Arg::Host(a)).collect();
        self.exec(name, stage, phase, &wrapped)
    }

    fn run_dev(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[Arg<'_, SimDev>],
    ) -> Result<SimDev> {
        let mut outs = self.exec(name, stage, phase, args)?;
        if outs.len() != 1 {
            bail!("{name}: run_dev requires a single-output module");
        }
        Ok(SimDev(outs.swap_remove(0)))
    }
}

// --------------------------------------------------------------------------
// module dispatch
// --------------------------------------------------------------------------

/// Bounds-checked index conversion (XLA would silently clamp/drop; failing
/// loudly is strictly more informative for a reference interpreter).
fn idx(v: i32, n: usize, what: &str) -> Result<usize> {
    if v < 0 || v as usize >= n {
        bail!("{what} index {v} out of range 0..{n}");
    }
    Ok(v as usize)
}

fn interpret(name: &str, spec: &ModuleSpec, args: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    let dim = |a: usize, d: usize| spec.args[a].shape[d];
    match name {
        "edge_select" => {
            let et = args[0].as_i32()?;
            let rel = args[1].as_i32()?[0];
            let elp = et.len();
            let mut pos: Vec<i32> = Vec::with_capacity(elp);
            for (p, &t) in et.iter().enumerate() {
                if t == rel {
                    pos.push(p as i32);
                }
            }
            let count = pos.len() as i32;
            pos.resize(elp, elp as i32); // sentinel = ELP, like the HLO module
            Ok(vec![HostTensor::i32(pos, &[elp]), HostTensor::scalar_i32(count)])
        }

        n if n.starts_with("proj_stacked_fwd") => {
            let (tp, ns, fin) = (dim(0, 0), dim(0, 1), dim(0, 2));
            let (rp, fout) = (dim(1, 0), dim(1, 2));
            let xs = args[0].as_f32()?;
            let w = args[1].as_f32()?;
            let st = args[2].as_i32()?;
            let mut out = vec![0.0f32; rp * ns * fout];
            for r in 0..rp {
                let t = idx(st[r], tp, "src_type")?;
                let y = matmul(
                    &xs[t * ns * fin..(t + 1) * ns * fin],
                    &w[r * fin * fout..(r + 1) * fin * fout],
                    ns,
                    fin,
                    fout,
                );
                out[r * ns * fout..(r + 1) * ns * fout].copy_from_slice(&y);
            }
            Ok(vec![HostTensor::f32(out, &[rp, ns, fout])])
        }

        n if n.starts_with("proj_stacked_bwd") => {
            let (tp, ns, fin) = (dim(0, 0), dim(0, 1), dim(0, 2));
            let (rp, fout) = (dim(1, 0), dim(1, 2));
            let xs = args[0].as_f32()?;
            let w = args[1].as_f32()?;
            let st = args[2].as_i32()?;
            let dy = args[3].as_f32()?;
            let mut dxs = vec![0.0f32; tp * ns * fin];
            let mut dw = vec![0.0f32; rp * fin * fout];
            for r in 0..rp {
                let t = idx(st[r], tp, "src_type")?;
                let dy_r = &dy[r * ns * fout..(r + 1) * ns * fout];
                let dx = matmul_nt(dy_r, &w[r * fin * fout..(r + 1) * fin * fout], ns, fout, fin);
                for (acc, v) in dxs[t * ns * fin..(t + 1) * ns * fin].iter_mut().zip(&dx) {
                    *acc += *v;
                }
                let g = matmul_tn(&xs[t * ns * fin..(t + 1) * ns * fin], dy_r, ns, fin, fout);
                dw[r * fin * fout..(r + 1) * fin * fout].copy_from_slice(&g);
            }
            Ok(vec![
                HostTensor::f32(dxs, &[tp, ns, fin]),
                HostTensor::f32(dw, &[rp, fin, fout]),
            ])
        }

        n if n.starts_with("proj_fwd") => {
            let (ns, fin, fout) = (dim(0, 0), dim(0, 1), dim(1, 1));
            let y = matmul(args[0].as_f32()?, args[1].as_f32()?, ns, fin, fout);
            Ok(vec![HostTensor::f32(y, &[ns, fout])])
        }

        n if n.starts_with("proj_bwd") => {
            let (ns, fin, fout) = (dim(0, 0), dim(0, 1), dim(1, 1));
            let x = args[0].as_f32()?;
            let w = args[1].as_f32()?;
            let dy = args[2].as_f32()?;
            let dx = matmul_nt(dy, w, ns, fout, fin);
            let dw = matmul_tn(x, dy, ns, fin, fout);
            Ok(vec![HostTensor::f32(dx, &[ns, fin]), HostTensor::f32(dw, &[fin, fout])])
        }

        n if n.starts_with("agg_mean_fwd") => {
            let (ns, fd) = (dim(0, 0), dim(0, 1));
            let out = agg_mean(
                args[0].as_f32()?,
                args[1].as_i32()?,
                args[2].as_i32()?,
                args[3].as_f32()?,
                ns,
                fd,
            )?;
            Ok(vec![HostTensor::f32(out, &[ns, fd])])
        }

        n if n.starts_with("agg_mean_bwd") => {
            let (ns, fd) = (dim(0, 0), dim(0, 1));
            // arg 0 (feat) is unused: the mean aggregation is linear in feat.
            let out = agg_mean_bwd(
                args[1].as_i32()?,
                args[2].as_i32()?,
                args[3].as_f32()?,
                args[4].as_f32()?,
                ns,
                fd,
            )?;
            Ok(vec![HostTensor::f32(out, &[ns, fd])])
        }

        n if n.starts_with("agg_merged_fwd") => {
            let (rp, ns, fd) = (dim(0, 0), dim(0, 1), dim(0, 2));
            let ep = dim(1, 1);
            let feat = args[0].as_f32()?;
            let src = args[1].as_i32()?;
            let dst = args[2].as_i32()?;
            let valid = args[3].as_f32()?;
            let mut out = vec![0.0f32; rp * ns * fd];
            for r in 0..rp {
                let y = agg_mean(
                    &feat[r * ns * fd..(r + 1) * ns * fd],
                    &src[r * ep..(r + 1) * ep],
                    &dst[r * ep..(r + 1) * ep],
                    &valid[r * ep..(r + 1) * ep],
                    ns,
                    fd,
                )?;
                out[r * ns * fd..(r + 1) * ns * fd].copy_from_slice(&y);
            }
            Ok(vec![HostTensor::f32(out, &[rp, ns, fd])])
        }

        n if n.starts_with("agg_merged_bwd") => {
            let (rp, ep) = (dim(0, 0), dim(0, 1));
            let (ns, fd) = (dim(3, 1), dim(3, 2));
            let src = args[0].as_i32()?;
            let dst = args[1].as_i32()?;
            let valid = args[2].as_f32()?;
            let dout = args[3].as_f32()?;
            let mut out = vec![0.0f32; rp * ns * fd];
            for r in 0..rp {
                let y = agg_mean_bwd(
                    &src[r * ep..(r + 1) * ep],
                    &dst[r * ep..(r + 1) * ep],
                    &valid[r * ep..(r + 1) * ep],
                    &dout[r * ns * fd..(r + 1) * ns * fd],
                    ns,
                    fd,
                )?;
                out[r * ns * fd..(r + 1) * ns * fd].copy_from_slice(&y);
            }
            Ok(vec![HostTensor::f32(out, &[rp, ns, fd])])
        }

        n if n.starts_with("att_agg_fwd") => {
            let (ns, fd) = (dim(0, 0), dim(0, 1));
            let out = att_agg(
                args[0].as_f32()?,
                args[1].as_f32()?,
                args[2].as_f32()?,
                args[3].as_f32()?,
                args[4].as_i32()?,
                args[5].as_i32()?,
                args[6].as_f32()?,
                ns,
                fd,
            )?;
            Ok(vec![HostTensor::f32(out, &[ns, fd])])
        }

        n if n.starts_with("att_agg_bwd") => {
            let (ns, fd) = (dim(0, 0), dim(0, 1));
            let (dfs, dfd, das, dad) = att_agg_bwd(
                args[0].as_f32()?,
                args[1].as_f32()?,
                args[2].as_f32()?,
                args[3].as_f32()?,
                args[4].as_i32()?,
                args[5].as_i32()?,
                args[6].as_f32()?,
                args[7].as_f32()?,
                ns,
                fd,
            )?;
            Ok(vec![
                HostTensor::f32(dfs, &[ns, fd]),
                HostTensor::f32(dfd, &[ns, fd]),
                HostTensor::f32(das, &[fd]),
                HostTensor::f32(dad, &[fd]),
            ])
        }

        n if n.starts_with("att_merged_fwd") => {
            let (rp, ns, fd) = (dim(0, 0), dim(0, 1), dim(0, 2));
            let ep = dim(4, 1);
            let (fs, fdm) = (args[0].as_f32()?, args[1].as_f32()?);
            let (a_s, a_d) = (args[2].as_f32()?, args[3].as_f32()?);
            let (src, dst) = (args[4].as_i32()?, args[5].as_i32()?);
            let valid = args[6].as_f32()?;
            let mut out = vec![0.0f32; rp * ns * fd];
            for r in 0..rp {
                let y = att_agg(
                    &fs[r * ns * fd..(r + 1) * ns * fd],
                    &fdm[r * ns * fd..(r + 1) * ns * fd],
                    &a_s[r * fd..(r + 1) * fd],
                    &a_d[r * fd..(r + 1) * fd],
                    &src[r * ep..(r + 1) * ep],
                    &dst[r * ep..(r + 1) * ep],
                    &valid[r * ep..(r + 1) * ep],
                    ns,
                    fd,
                )?;
                out[r * ns * fd..(r + 1) * ns * fd].copy_from_slice(&y);
            }
            Ok(vec![HostTensor::f32(out, &[rp, ns, fd])])
        }

        n if n.starts_with("att_merged_bwd") => {
            let (rp, ns, fd) = (dim(0, 0), dim(0, 1), dim(0, 2));
            let ep = dim(4, 1);
            let (fs, fdm) = (args[0].as_f32()?, args[1].as_f32()?);
            let (a_s, a_d) = (args[2].as_f32()?, args[3].as_f32()?);
            let (src, dst) = (args[4].as_i32()?, args[5].as_i32()?);
            let valid = args[6].as_f32()?;
            let dout = args[7].as_f32()?;
            let mut dfs = vec![0.0f32; rp * ns * fd];
            let mut dfd = vec![0.0f32; rp * ns * fd];
            let mut das = vec![0.0f32; rp * fd];
            let mut dad = vec![0.0f32; rp * fd];
            for r in 0..rp {
                let (a, b, c, d) = att_agg_bwd(
                    &fs[r * ns * fd..(r + 1) * ns * fd],
                    &fdm[r * ns * fd..(r + 1) * ns * fd],
                    &a_s[r * fd..(r + 1) * fd],
                    &a_d[r * fd..(r + 1) * fd],
                    &src[r * ep..(r + 1) * ep],
                    &dst[r * ep..(r + 1) * ep],
                    &valid[r * ep..(r + 1) * ep],
                    &dout[r * ns * fd..(r + 1) * ns * fd],
                    ns,
                    fd,
                )?;
                dfs[r * ns * fd..(r + 1) * ns * fd].copy_from_slice(&a);
                dfd[r * ns * fd..(r + 1) * ns * fd].copy_from_slice(&b);
                das[r * fd..(r + 1) * fd].copy_from_slice(&c);
                dad[r * fd..(r + 1) * fd].copy_from_slice(&d);
            }
            Ok(vec![
                HostTensor::f32(dfs, &[rp, ns, fd]),
                HostTensor::f32(dfd, &[rp, ns, fd]),
                HostTensor::f32(das, &[rp, fd]),
                HostTensor::f32(dad, &[rp, fd]),
            ])
        }

        n if n.starts_with("fuse_relu_fwd") || n.starts_with("fuse_lin_fwd") => {
            let relu = n.starts_with("fuse_relu");
            let (rp, ns, fd) = (dim(1, 0), dim(1, 1), dim(1, 2));
            let tp = spec.rets[0].shape[0];
            let out = fuse_fwd(args[0].as_i32()?, args[1].as_f32()?, rp, ns, fd, tp, relu)?;
            Ok(vec![HostTensor::f32(out, &[tp, ns, fd])])
        }

        n if n.starts_with("fuse_relu_bwd") || n.starts_with("fuse_lin_bwd") => {
            let relu = n.starts_with("fuse_relu");
            let (rp, ns, fd) = (dim(1, 0), dim(1, 1), dim(1, 2));
            let tp = dim(2, 0);
            let out = fuse_bwd(
                args[0].as_i32()?,
                args[1].as_f32()?,
                args[2].as_f32()?,
                rp,
                ns,
                fd,
                tp,
                relu,
            )?;
            Ok(vec![HostTensor::f32(out, &[rp, ns, fd])])
        }

        "head" => {
            let (ns, c) = (dim(0, 0), dim(0, 1));
            let (loss, dlogits, ncorrect) =
                head(args[0].as_f32()?, args[1].as_i32()?, args[2].as_f32()?, ns, c);
            Ok(vec![
                HostTensor::scalar_f32(loss),
                HostTensor::f32(dlogits, &[ns, c]),
                HostTensor::scalar_f32(ncorrect),
            ])
        }

        other => bail!("SimBackend has no reference semantics for module {other:?}"),
    }
}

// --------------------------------------------------------------------------
// reference kernels (mirror ref.py / model.py exactly; see module docs)
// --------------------------------------------------------------------------

/// `out[m,n] = a[m,k] · b[k,n]`, row-major f32.
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// `out[k,n] = aᵀ[k,m] · b[m,n]` for `a: [m,k]` (the `dw = xᵀ·dy` form).
fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k * n];
    for s in 0..m {
        for i in 0..k {
            let av = a[s * k + i];
            if av == 0.0 {
                continue;
            }
            let brow = &b[s * n..(s + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// `out[m,k] = a[m,n] · bᵀ[n,k]` for `b: [k,n]` (the `dx = dy·wᵀ` form).
fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * k];
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        for j in 0..k {
            let brow = &b[j * n..(j + 1) * n];
            let mut s = 0.0f32;
            for (av, bv) in arow.iter().zip(brow) {
                s += av * bv;
            }
            out[i * k + j] = s;
        }
    }
    out
}

/// Mean-aggregate `feat[src[e]]` onto `dst[e]` (ref.py `agg_mean_ref`):
/// row j = sum of valid incoming features / max(1, valid in-degree).
fn agg_mean(
    feat: &[f32],
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    ns: usize,
    fd: usize,
) -> Result<Vec<f32>> {
    let mut sums = vec![0.0f32; ns * fd];
    let mut cnt = vec![0.0f32; ns];
    for e in 0..src.len() {
        let v = valid[e];
        if v == 0.0 {
            continue;
        }
        let s = idx(src[e], ns, "src")?;
        let d = idx(dst[e], ns, "dst")?;
        for x in 0..fd {
            sums[d * fd + x] += feat[s * fd + x] * v;
        }
        cnt[d] += v;
    }
    for j in 0..ns {
        let c = cnt[j].max(1.0);
        if c != 1.0 {
            for x in 0..fd {
                sums[j * fd + x] /= c;
            }
        }
    }
    Ok(sums)
}

/// VJP of [`agg_mean`] w.r.t. `feat` (linear, so exact):
/// `dfeat[src[e]] += valid[e] * dout[dst[e]] / max(1, degree(dst[e]))`.
fn agg_mean_bwd(
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    dout: &[f32],
    ns: usize,
    fd: usize,
) -> Result<Vec<f32>> {
    let mut cnt = vec![0.0f32; ns];
    for e in 0..src.len() {
        if valid[e] != 0.0 {
            cnt[idx(dst[e], ns, "dst")?] += valid[e];
        }
    }
    let mut dfeat = vec![0.0f32; ns * fd];
    for e in 0..src.len() {
        let v = valid[e];
        if v == 0.0 {
            continue;
        }
        let s = idx(src[e], ns, "src")?;
        let d = idx(dst[e], ns, "dst")?;
        let w = v / cnt[d].max(1.0);
        for x in 0..fd {
            dfeat[s * fd + x] += dout[d * fd + x] * w;
        }
    }
    Ok(dfeat)
}

/// GAT-style attention aggregation (ref.py `att_agg_ref`):
/// `e_ij = LeakyReLU(a_src·h_i + a_dst·h_j)`, segment-softmax over valid
/// incoming edges of j, `out_j = Σ_i α_ij h_i`.
fn att_agg(
    fs: &[f32],
    fdm: &[f32],
    a_s: &[f32],
    a_d: &[f32],
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    ns: usize,
    fd: usize,
) -> Result<Vec<f32>> {
    let fw = att_forward(fs, fdm, a_s, a_d, src, dst, valid, ns, fd)?;
    let mut out = vec![0.0f32; ns * fd];
    for e in 0..src.len() {
        let we = fw.w[e];
        if we == 0.0 {
            continue;
        }
        let s = src[e] as usize; // validated in att_forward
        let d = dst[e] as usize;
        for x in 0..fd {
            out[d * fd + x] += we * fs[s * fd + x];
        }
    }
    for j in 0..ns {
        let dn = fw.denom[j].max(DENOM_EPS);
        for x in 0..fd {
            out[j * fd + x] /= dn;
        }
    }
    Ok(out)
}

/// Shared attention-forward intermediates (recomputed in the backward, the
/// same rematerialization the AOT modules do).
struct AttForward {
    /// Pre-activation scores z_e = es[src] + ed[dst].
    z: Vec<f32>,
    /// Unnormalized softmax weights (zero for invalid edges).
    w: Vec<f32>,
    /// Per-destination softmax denominators.
    denom: Vec<f32>,
}

fn att_forward(
    fs: &[f32],
    fdm: &[f32],
    a_s: &[f32],
    a_d: &[f32],
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    ns: usize,
    fd: usize,
) -> Result<AttForward> {
    let ep = src.len();
    let mut es = vec![0.0f32; ns];
    let mut ed = vec![0.0f32; ns];
    for i in 0..ns {
        let (mut se, mut de) = (0.0f32, 0.0f32);
        for x in 0..fd {
            se += fs[i * fd + x] * a_s[x];
            de += fdm[i * fd + x] * a_d[x];
        }
        es[i] = se;
        ed[i] = de;
    }
    let mut z = vec![0.0f32; ep];
    let mut eact = vec![0.0f32; ep];
    for e in 0..ep {
        let s = idx(src[e], ns, "src")?;
        let d = idx(dst[e], ns, "dst")?;
        let ze = es[s] + ed[d];
        z[e] = ze;
        let l = if ze >= 0.0 { ze } else { LEAKY_SLOPE * ze };
        eact[e] = if valid[e] > 0.0 { l } else { NEG_INF };
    }
    let mut segmax = vec![NEG_INF; ns];
    for e in 0..ep {
        let d = dst[e] as usize;
        if eact[e] > segmax[d] {
            segmax[d] = eact[e];
        }
    }
    let mut w = vec![0.0f32; ep];
    let mut denom = vec![0.0f32; ns];
    for e in 0..ep {
        let d = dst[e] as usize;
        let we = (eact[e] - segmax[d]).exp() * valid[e];
        w[e] = we;
        denom[d] += we;
    }
    Ok(AttForward { z, w, denom })
}

/// VJP of [`att_agg`] w.r.t. (feat_src, feat_dst, a_src, a_dst); recomputes
/// the forward internally. Validated against `jax.vjp` of the oracle.
fn att_agg_bwd(
    fs: &[f32],
    fdm: &[f32],
    a_s: &[f32],
    a_d: &[f32],
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    dout: &[f32],
    ns: usize,
    fd: usize,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
    let ep = src.len();
    let fw = att_forward(fs, fdm, a_s, a_d, src, dst, valid, ns, fd)?;
    // alpha_e = w_e / max(denom, eps): the normalized attention weights.
    // Direct path: dfs[src] += alpha * dout[dst]; and the softmax pullback
    // needs dalpha_e = dout[dst] · fs[src].
    let mut dfs = vec![0.0f32; ns * fd];
    let mut alpha = vec![0.0f32; ep];
    let mut dalpha = vec![0.0f32; ep];
    for e in 0..ep {
        let d = dst[e] as usize;
        let a = fw.w[e] / fw.denom[d].max(DENOM_EPS);
        alpha[e] = a;
        if a == 0.0 {
            continue;
        }
        let s = src[e] as usize;
        let mut da = 0.0f32;
        for x in 0..fd {
            dfs[s * fd + x] += a * dout[d * fd + x];
            da += dout[d * fd + x] * fs[s * fd + x];
        }
        dalpha[e] = da;
    }
    // Softmax backward per segment: dl_e = alpha_e (dalpha_e - Σ alpha dalpha).
    let mut seg = vec![0.0f32; ns];
    for e in 0..ep {
        seg[dst[e] as usize] += alpha[e] * dalpha[e];
    }
    let mut des = vec![0.0f32; ns];
    let mut ded = vec![0.0f32; ns];
    for e in 0..ep {
        let a = alpha[e];
        if a == 0.0 {
            continue;
        }
        let d = dst[e] as usize;
        let dl = a * (dalpha[e] - seg[d]);
        let dz = dl * if fw.z[e] >= 0.0 { 1.0 } else { LEAKY_SLOPE };
        des[src[e] as usize] += dz;
        ded[d] += dz;
    }
    // Back through the score projections es = fs·a_s, ed = fd·a_d.
    let mut dfd = vec![0.0f32; ns * fd];
    let mut das = vec![0.0f32; fd];
    let mut dad = vec![0.0f32; fd];
    for i in 0..ns {
        if des[i] != 0.0 {
            for x in 0..fd {
                dfs[i * fd + x] += des[i] * a_s[x];
                das[x] += des[i] * fs[i * fd + x];
            }
        }
        if ded[i] != 0.0 {
            for x in 0..fd {
                dfd[i * fd + x] += ded[i] * a_d[x];
                dad[x] += ded[i] * fdm[i * fd + x];
            }
        }
    }
    Ok((dfs, dfd, das, dad))
}

/// Semantic fusion forward (model.py `fuse_relu` / `fuse_lin`):
/// `out[t] = act(Σ_{r: dst_type[r]=t} agg[r])`.
fn fuse_fwd(
    dst_type: &[i32],
    agg: &[f32],
    rp: usize,
    ns: usize,
    fd: usize,
    tp: usize,
    relu: bool,
) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; tp * ns * fd];
    for r in 0..rp {
        let t = idx(dst_type[r], tp, "dst_type")?;
        let srow = &agg[r * ns * fd..(r + 1) * ns * fd];
        let orow = &mut out[t * ns * fd..(t + 1) * ns * fd];
        for (o, v) in orow.iter_mut().zip(srow) {
            *o += *v;
        }
    }
    if relu {
        for v in out.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
    Ok(out)
}

/// VJP of [`fuse_fwd`] w.r.t. `agg`: `dagg[r] = dout[dst_type[r]]`, masked
/// by the recomputed ReLU support when `relu`.
fn fuse_bwd(
    dst_type: &[i32],
    agg: &[f32],
    dout: &[f32],
    rp: usize,
    ns: usize,
    fd: usize,
    tp: usize,
    relu: bool,
) -> Result<Vec<f32>> {
    let pre = if relu {
        Some(fuse_fwd(dst_type, agg, rp, ns, fd, tp, false)?)
    } else {
        None
    };
    let mut dagg = vec![0.0f32; rp * ns * fd];
    for r in 0..rp {
        let t = idx(dst_type[r], tp, "dst_type")?;
        let grow = &dout[t * ns * fd..(t + 1) * ns * fd];
        let drow = &mut dagg[r * ns * fd..(r + 1) * ns * fd];
        match &pre {
            Some(s) => {
                let srow = &s[t * ns * fd..(t + 1) * ns * fd];
                for k in 0..ns * fd {
                    drow[k] = if srow[k] > 0.0 { grow[k] } else { 0.0 };
                }
            }
            None => drow.copy_from_slice(grow),
        }
    }
    Ok(dagg)
}

/// Softmax cross-entropy head (model.py `head`): loss, dlogits, and
/// accuracy count over the seed rows, in one "dispatch".
fn head(logits: &[f32], labels: &[i32], mask: &[f32], ns: usize, c: usize) -> (f32, Vec<f32>, f32) {
    let mut z = vec![0.0f32; ns * c];
    for i in 0..ns {
        let row = &logits[i * c..(i + 1) * c];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut se = 0.0f32;
        for &l in row {
            se += (l - m).exp();
        }
        let lse = m + se.ln();
        for j in 0..c {
            z[i * c + j] = row[j] - lse;
        }
    }
    let n = mask.iter().sum::<f32>().max(1.0);
    let mut loss = 0.0f32;
    let mut dlogits = vec![0.0f32; ns * c];
    let mut ncorrect = 0.0f32;
    for i in 0..ns {
        let lab = labels[i];
        let mi = mask[i];
        for j in 0..c {
            let one = if j as i32 == lab { 1.0f32 } else { 0.0 };
            if one == 1.0 {
                loss -= z[i * c + j] * mi;
            }
            dlogits[i * c + j] = (z[i * c + j].exp() - one) * mi / n;
        }
        // argmax with first-max tie-breaking, like jnp.argmax.
        let row = &logits[i * c..(i + 1) * c];
        let mut am = 0usize;
        for j in 1..c {
            if row[j] > row[am] {
                am = j;
            }
        }
        if am as i32 == lab {
            ncorrect += mi;
        }
    }
    (loss / n, dlogits, ncorrect)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Central finite difference of `f` along coordinate `k` of `x`.
    fn fdiff(x: &mut [f32], k: usize, mut f: impl FnMut(&[f32]) -> f32) -> f32 {
        let eps = 1e-2f32;
        let x0 = x[k];
        x[k] = x0 + eps;
        let hi = f(x);
        x[k] = x0 - eps;
        let lo = f(x);
        x[k] = x0;
        (hi - lo) / (2.0 * eps)
    }

    fn close(a: f32, b: f32, tag: &str) {
        assert!((a - b).abs() < 2e-2 + 0.05 * b.abs(), "{tag}: analytic {a} vs fd {b}");
    }

    #[test]
    fn agg_mean_matches_hand_example() {
        // 2 valid edges into node 3: values 3 and 5 -> mean 4.
        let ns = 4;
        let fd = 2;
        let mut feat = vec![0.0f32; ns * fd];
        feat[0] = 3.0;
        feat[1] = 3.0;
        feat[2] = 5.0;
        feat[3] = 5.0;
        let src = vec![0, 1, 0];
        let dst = vec![3, 3, 0];
        let valid = vec![1.0, 1.0, 0.0];
        let out = agg_mean(&feat, &src, &dst, &valid, ns, fd).unwrap();
        assert_eq!(&out[3 * fd..4 * fd], &[4.0, 4.0]);
        assert!(out[..3 * fd].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn agg_mean_bwd_matches_finite_difference() {
        let mut rng = Rng::new(1);
        let (ns, fd) = (5, 3);
        let mut feat = randv(&mut rng, ns * fd);
        let src: Vec<i32> = vec![0, 1, 2, 3, 0, 2];
        let dst: Vec<i32> = vec![1, 1, 4, 0, 4, 1];
        let valid = vec![1.0, 1.0, 0.0, 1.0, 1.0, 1.0];
        let g = randv(&mut rng, ns * fd);
        let loss = |f: &[f32]| -> f32 {
            agg_mean(f, &src, &dst, &valid, ns, fd)
                .unwrap()
                .iter()
                .zip(&g)
                .map(|(o, gg)| o * gg)
                .sum()
        };
        let analytic = agg_mean_bwd(&src, &dst, &valid, &g, ns, fd).unwrap();
        for k in [0, 4, 7, ns * fd - 1] {
            let fd_ = fdiff(&mut feat, k, loss);
            close(analytic[k], fd_, &format!("agg_mean dfeat[{k}]"));
        }
    }

    #[test]
    fn att_agg_bwd_matches_finite_difference() {
        let mut rng = Rng::new(7);
        let (ns, fd) = (5, 3);
        let mut fs = randv(&mut rng, ns * fd);
        let mut fdm = randv(&mut rng, ns * fd);
        let mut a_s = randv(&mut rng, fd);
        let mut a_d = randv(&mut rng, fd);
        let src: Vec<i32> = vec![0, 1, 2, 3, 4, 1, 0];
        let dst: Vec<i32> = vec![1, 1, 1, 0, 0, 3, 2];
        let valid = vec![1.0, 1.0, 1.0, 1.0, 1.0, 0.0, 1.0];
        let g = randv(&mut rng, ns * fd);
        let (dfs, dfd, das, dad) =
            att_agg_bwd(&fs, &fdm, &a_s, &a_d, &src, &dst, &valid, &g, ns, fd).unwrap();
        macro_rules! loss_wrt {
            ($fs:expr, $fdm:expr, $as_:expr, $ad:expr) => {
                att_agg($fs, $fdm, $as_, $ad, &src, &dst, &valid, ns, fd)
                    .unwrap()
                    .iter()
                    .zip(&g)
                    .map(|(o, gg)| o * gg)
                    .sum::<f32>()
            };
        }
        for k in [0, 3, 8, ns * fd - 1] {
            let fdm2 = fdm.clone();
            let (a_s2, a_d2) = (a_s.clone(), a_d.clone());
            let fd_ = fdiff(&mut fs, k, |f| loss_wrt!(f, &fdm2, &a_s2, &a_d2));
            close(dfs[k], fd_, &format!("att dfs[{k}]"));
        }
        for k in [1, 6] {
            let fs2 = fs.clone();
            let (a_s2, a_d2) = (a_s.clone(), a_d.clone());
            let fd_ = fdiff(&mut fdm, k, |f| loss_wrt!(&fs2, f, &a_s2, &a_d2));
            close(dfd[k], fd_, &format!("att dfd[{k}]"));
        }
        for k in 0..fd {
            let (fs2, fdm2) = (fs.clone(), fdm.clone());
            let a_d2 = a_d.clone();
            let fd_ = fdiff(&mut a_s, k, |a| loss_wrt!(&fs2, &fdm2, a, &a_d2));
            close(das[k], fd_, &format!("att das[{k}]"));
            let (fs3, fdm3) = (fs.clone(), fdm.clone());
            let a_s3 = a_s.clone();
            let fd2_ = fdiff(&mut a_d, k, |a| loss_wrt!(&fs3, &fdm3, &a_s3, a));
            close(dad[k], fd2_, &format!("att dad[{k}]"));
        }
    }

    #[test]
    fn att_segments_without_valid_edges_are_zero_and_nan_free() {
        let (ns, fd) = (3, 2);
        let fs = vec![1.0f32; ns * fd];
        let fdm = vec![1.0f32; ns * fd];
        let a = vec![0.5f32; fd];
        let src = vec![0, 1];
        let dst = vec![0, 0];
        let valid = vec![0.0f32, 0.0];
        let out = att_agg(&fs, &fdm, &a, &a, &src, &dst, &valid, ns, fd).unwrap();
        assert!(out.iter().all(|v| *v == 0.0 && v.is_finite()));
        let g = vec![1.0f32; ns * fd];
        let (dfs, dfd, das, dad) =
            att_agg_bwd(&fs, &fdm, &a, &a, &src, &dst, &valid, &g, ns, fd).unwrap();
        for v in dfs.iter().chain(&dfd).chain(&das).chain(&dad) {
            assert_eq!(*v, 0.0);
        }
    }

    #[test]
    fn fuse_bwd_matches_finite_difference() {
        let mut rng = Rng::new(3);
        let (rp, ns, fd, tp) = (4, 3, 2, 3);
        let dst_type = vec![0i32, 2, 0, 1];
        let mut agg = randv(&mut rng, rp * ns * fd);
        let g = randv(&mut rng, tp * ns * fd);
        for relu in [false, true] {
            let analytic = fuse_bwd(&dst_type, &agg, &g, rp, ns, fd, tp, relu).unwrap();
            let loss = |a: &[f32]| -> f32 {
                fuse_fwd(&dst_type, a, rp, ns, fd, tp, relu)
                    .unwrap()
                    .iter()
                    .zip(&g)
                    .map(|(o, gg)| o * gg)
                    .sum()
            };
            for k in [0, 5, rp * ns * fd - 1] {
                let fd_ = fdiff(&mut agg, k, loss);
                close(analytic[k], fd_, &format!("fuse relu={relu} dagg[{k}]"));
            }
        }
    }

    #[test]
    fn head_gradient_matches_finite_difference_and_counts_accuracy() {
        let mut rng = Rng::new(9);
        let (ns, c) = (6, 4);
        let mut logits = randv(&mut rng, ns * c);
        let labels: Vec<i32> = (0..ns).map(|i| (i % c) as i32).collect();
        let mask = vec![1.0, 1.0, 0.0, 1.0, 1.0, 0.0];
        let (_, dlogits, ncorrect) = head(&logits, &labels, &mask, ns, c);
        for k in [0, 7, 13, ns * c - 1] {
            let fd_ = fdiff(&mut logits, k, |l| head(l, &labels, &mask, ns, c).0);
            close(dlogits[k], fd_, &format!("head dlogits[{k}]"));
        }
        // Accuracy: perfect logits count every masked row.
        let mut perfect = vec![0.0f32; ns * c];
        for i in 0..ns {
            perfect[i * c + labels[i] as usize] = 10.0;
        }
        let (loss, _, nc) = head(&perfect, &labels, &mask, ns, c);
        assert_eq!(nc, 4.0);
        assert!(loss < 0.01, "confident loss {loss}");
    }

    #[test]
    fn proj_bwd_matches_finite_difference() {
        let mut rng = Rng::new(5);
        let (m, k, n) = (3, 4, 2);
        let mut x = randv(&mut rng, m * k);
        let w = randv(&mut rng, k * n);
        let g = randv(&mut rng, m * n);
        let dx = matmul_nt(&g, &w, m, n, k);
        let dw = matmul_tn(&x, &g, m, k, n);
        for kk in [0, m * k - 1] {
            let fd_ = fdiff(&mut x, kk, |xx| {
                matmul(xx, &w, m, k, n).iter().zip(&g).map(|(o, gg)| o * gg).sum()
            });
            close(dx[kk], fd_, &format!("proj dx[{kk}]"));
        }
        // dw via the identity dw = xT g exactly.
        let mut dw_ref = vec![0.0f32; k * n];
        for s in 0..m {
            for i in 0..k {
                for j in 0..n {
                    dw_ref[i * n + j] += x[s * k + i] * g[s * n + j];
                }
            }
        }
        for (a, b) in dw.iter().zip(&dw_ref) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn backend_runs_builtin_modules_end_to_end() {
        let eng = SimBackend::builtin("tiny").unwrap();
        let (ns, f, h) = (eng.cst("NS"), eng.cst("F"), eng.cst("H"));
        let x = HostTensor::zeros_f32(&[ns, f]);
        let w = HostTensor::zeros_f32(&[f, h]);
        let out = eng.run("proj_fwd_l0", Stage::Calib, Phase::Fwd, &[&x, &w]).unwrap();
        assert_eq!(out[0].shape(), &[ns, h]);
        // Calib dispatches stay out of the counters.
        assert_eq!(eng.counters().borrow().total(), 0);
        let out = eng.run("proj_fwd_l0", Stage::Projection, Phase::Fwd, &[&x, &w]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(eng.counters().borrow().total(), 1);
    }

    #[test]
    fn run_dev_keeps_results_chainable_without_transfer() {
        let eng = SimBackend::builtin("tiny").unwrap();
        eng.reset_counters(true);
        let (rp, ns, h) = (eng.cst("RPAD"), eng.cst("NS"), eng.cst("H"));
        let dt = HostTensor::i32(vec![0; rp], &[rp]);
        let feat = HostTensor::zeros_f32(&[rp, ns, h]);
        let src = HostTensor::i32(vec![0; rp * eng.cst("EP")], &[rp, eng.cst("EP")]);
        let valid = HostTensor::f32(vec![0.0; rp * eng.cst("EP")], &[rp, eng.cst("EP")]);
        let dev = eng
            .run_dev(
                "agg_merged_fwd_h",
                Stage::Aggregation,
                Phase::Fwd,
                &[Arg::Host(&feat), Arg::Host(&src), Arg::Host(&src), Arg::Host(&valid)],
            )
            .unwrap();
        assert_eq!(dev.shape(), &[rp, ns, h]);
        eng.run_dev(
            "fuse_relu_fwd_h",
            Stage::Fusion,
            Phase::Fwd,
            &[Arg::Host(&dt), Arg::Dev(&dev)],
        )
        .unwrap();
        let c = eng.counters().borrow();
        assert_eq!(c.total(), 2);
        // The device-resident arg contributed zero transfer bytes: only the
        // dst_type vector was "uploaded" for the fusion dispatch.
        assert_eq!(c.events[1].bytes_in, rp * 4);
    }

    #[test]
    fn simulated_launch_overhead_slows_dispatches() {
        let mut eng = SimBackend::builtin("tiny").unwrap();
        let base = eng.measure_dispatch_overhead(5).unwrap();
        eng.set_launch_overhead(Duration::from_micros(500));
        let slow = eng.measure_dispatch_overhead(5).unwrap();
        assert!(slow > base + Duration::from_micros(300), "{base:?} -> {slow:?}");
    }
}
