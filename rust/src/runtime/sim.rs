//! The pure-Rust execution backend: interprets every manifest module with
//! the reference semantics of `python/compile/kernels/ref.py` and
//! `python/compile/model.py`, with the same shape/dtype checking and
//! per-dispatch [`Counters`] recording as the PJRT engine.
//!
//! One interpreted module evaluation ≙ one "CUDA kernel launch" of the
//! paper, exactly like one PJRT executable dispatch — so kernel counts,
//! per-stage breakdowns (Figs. 7–11), and the gradient math are
//! bit-identical in meaning across backends. A configurable simulated
//! launch overhead (busy-wait per dispatch) plays the role of the CUDA
//! launch cost the paper optimizes away, making dispatch-bound regimes
//! reproducible deterministically on any machine with zero AOT artifacts.
//!
//! Backward formulas are the hand-derived VJPs of the reference forward
//! functions; they were validated against `jax.vjp` of the Python oracles
//! to f32 round-off, and the finite-difference tests below pin them down.

use std::cell::{Cell, RefCell};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::{
    check_args, host_dtype, Arena, ArenaStats, Arg, Counters, DType, DevBuf, ExecBackend,
    Manifest, ModuleSpec, Phase, Stage,
};
use crate::util::{FaultPlan, FaultSite, HostTensor, WorkerPool, MAX_DISPATCH_RETRIES};

/// LeakyReLU negative slope (ref.py `LEAKY_SLOPE`).
const LEAKY_SLOPE: f32 = 0.2;
/// Finite stand-in for -inf: keeps padded segments NaN-free (ref.py).
const NEG_INF: f32 = -1e30;
/// Softmax-denominator floor (ref.py `att_agg_ref`).
const DENOM_EPS: f32 = 1e-16;

/// The sim backend's "device-resident" tensor. There is no device, so this
/// is a host tensor that models the residency contract: chaining it into
/// the next dispatch transfers zero bytes in the accounting.
pub struct SimDev(pub(crate) HostTensor);

impl DevBuf for SimDev {
    fn dtype(&self) -> DType {
        host_dtype(&self.0)
    }

    fn shape(&self) -> &[usize] {
        self.0.shape()
    }

    fn to_host(&self) -> Result<HostTensor> {
        Ok(self.0.clone())
    }

    fn into_host(self) -> Result<HostTensor> {
        Ok(self.0) // "device" memory is host memory: hand the storage over
    }
}

/// Reference interpreter + dispatch accounting: the default backend.
///
/// Its kernels are cache-blocked and row-parallel over the shared
/// [`WorkerPool`] (partitioned so f32 summation order — and therefore every
/// parity/VJP test — is bit-identical for any thread count), and all
/// dispatch scratch/result buffers come from a size-classed [`Arena`] so
/// steady-state allocations per training step are ~0.
pub struct SimBackend {
    manifest: Manifest,
    counters: RefCell<Counters>,
    /// Simulated per-dispatch launch overhead (busy-wait), the knob the
    /// dispatch-reduction experiments turn. Default zero.
    launch_overhead: Duration,
    /// Worker pool for intra-kernel row parallelism (`--threads`).
    pool: WorkerPool,
    /// Dispatch buffer arena (scratch + result storage reuse).
    arena: RefCell<Arena>,
    /// Attached fault-injection plan + address cursor (DESIGN.md §9).
    /// `None` (the default) keeps the per-dispatch probe to one borrow and
    /// an `Option` check — the plane is zero-cost when off.
    fault: RefCell<Option<FaultState>>,
    /// Transfer-level integrity guard (DESIGN.md §11): with it on, a
    /// planned `wire!` corruption is caught by the modeled payload checksum
    /// and re-sent clean; off, the corrupted payload lands silently.
    integrity_guard: Cell<bool>,
}

/// Where the next dispatches are addressed for injection, and whether the
/// first launch since the cursor moved is still pending.
struct FaultState {
    plan: Arc<FaultPlan>,
    epoch: u64,
    seq: u64,
    armed: bool,
    /// A planned `wire!` corruption targets the first f32 upload payload
    /// after the cursor moves (i32 index uploads are skipped: corrupting an
    /// index is a loud OOB, not silent data damage).
    wire_armed: bool,
}

impl SimBackend {
    /// Backend over a built-in profile ("tiny" or "bench") — zero
    /// artifacts, zero Python. Kernels run serially; see
    /// [`SimBackend::builtin_threaded`].
    pub fn builtin(profile: &str) -> Result<SimBackend> {
        Ok(Self::new(Manifest::builtin(profile)?, WorkerPool::default()))
    }

    /// Built-in profile with `threads` kernel workers (what `--threads`
    /// selects for the CLI, benches, and examples).
    pub fn builtin_threaded(profile: &str, threads: usize) -> Result<SimBackend> {
        Ok(Self::new(Manifest::builtin(profile)?, WorkerPool::new(threads)))
    }

    /// Backend over an on-disk artifact manifest (interface parity checks
    /// against the AOT emitter; the HLO files themselves are never read).
    pub fn load(profile_dir: &Path) -> Result<SimBackend> {
        Ok(Self::new(Manifest::load(profile_dir)?, WorkerPool::default()))
    }

    pub fn new(manifest: Manifest, pool: WorkerPool) -> SimBackend {
        SimBackend {
            manifest,
            counters: RefCell::new(Counters::new(false)),
            launch_overhead: Duration::ZERO,
            pool,
            arena: RefCell::new(Arena::new()),
            fault: RefCell::new(None),
            integrity_guard: Cell::new(false),
        }
    }

    /// Dispatch-fault probe: on the first launch after the fault cursor
    /// moved, consult the plan and absorb any planned transient failures
    /// with a bounded deterministic retry-with-backoff. Each absorbed
    /// failure counts once in [`Counters::dispatch_retries`]; the real
    /// dispatch runs exactly once afterward, so kernel counts, byte
    /// accounting, and outputs are identical to a fault-free run.
    fn fault_preflight(&self) -> Result<()> {
        let mut guard = self.fault.borrow_mut();
        let Some(f) = guard.as_mut() else { return Ok(()) };
        if !f.armed {
            return Ok(());
        }
        f.armed = false;
        let planned = f.plan.fires(FaultSite::Dispatch, f.epoch, f.seq);
        if planned == 0 {
            return Ok(());
        }
        if planned > MAX_DISPATCH_RETRIES {
            bail!(
                "dispatch at (epoch {}, seq {}) still failing after {} retries",
                f.epoch,
                f.seq,
                MAX_DISPATCH_RETRIES
            );
        }
        drop(guard);
        for attempt in 0..planned {
            // Deterministic backoff: a linearly growing busy-wait in units
            // of the simulated launch overhead (zero-length when that knob
            // is off, making the retry accounting-only).
            let backoff = self.launch_overhead * (attempt + 1);
            if !backoff.is_zero() {
                let spin = Instant::now();
                while spin.elapsed() < backoff {
                    std::hint::spin_loop();
                }
            }
            self.counters.borrow_mut().dispatch_retries += 1;
        }
        Ok(())
    }

    /// Set the simulated per-dispatch launch overhead.
    pub fn set_launch_overhead(&mut self, d: Duration) {
        self.launch_overhead = d;
    }

    pub fn launch_overhead(&self) -> Duration {
        self.launch_overhead
    }

    /// Replace the kernel worker pool.
    pub fn set_pool(&mut self, pool: WorkerPool) {
        self.pool = pool;
    }

    pub fn pool(&self) -> WorkerPool {
        self.pool
    }

    /// Cumulative buffer-arena traffic since construction.
    pub fn arena_stats(&self) -> ArenaStats {
        self.arena.borrow().stats()
    }

    fn take_f32(&self, len: usize) -> Vec<f32> {
        self.arena.borrow_mut().take_f32(len)
    }

    fn take_i32(&self, len: usize) -> Vec<i32> {
        self.arena.borrow_mut().take_i32(len)
    }

    fn reclaim_f32(&self, v: Vec<f32>) {
        self.arena.borrow_mut().put_f32(v);
    }

    /// Shared copy body of `upload` / `upload_peer`: only the channel the
    /// bytes are charged to differs between the two entry points. This is
    /// also the `wire!` injection point (DESIGN.md §11): a planned wire
    /// fault corrupts the first f32 payload transferred after the fault
    /// cursor moved — silently when the integrity guard is off, caught by
    /// the modeled payload checksum and re-sent clean
    /// ([`Counters::integrity_retransmits`]) when it is on.
    fn upload_impl(&self, t: &HostTensor, valid_elems: usize) -> Result<(SimDev, usize)> {
        let valid = valid_elems.min(t.len());
        let dev = match t {
            HostTensor::F32(d, s) => {
                let mut buf = self.take_f32(d.len());
                buf[..valid].copy_from_slice(&d[..valid]);
                self.wire_preflight(&mut buf, valid)?;
                HostTensor::f32(buf, s)
            }
            HostTensor::I32(d, s) => {
                let mut buf = self.take_i32(d.len());
                buf[..valid].copy_from_slice(&d[..valid]);
                HostTensor::i32(buf, s)
            }
        };
        Ok((SimDev(dev), valid))
    }

    /// `wire!` probe for one f32 upload payload. The first non-empty f32
    /// payload after the cursor moved consumes the arming; each planned
    /// corruption at the address then either flips one mantissa bit of one
    /// element (guard off — the silent-corruption case the digest audits
    /// exist to catch) or is detected and retransmitted clean (guard on),
    /// bailing past [`MAX_DISPATCH_RETRIES`] like the dispatch-fault path.
    fn wire_preflight(&self, buf: &mut [f32], valid: usize) -> Result<()> {
        let mut guard = self.fault.borrow_mut();
        let Some(f) = guard.as_mut() else { return Ok(()) };
        if !f.wire_armed || valid == 0 {
            return Ok(());
        }
        f.wire_armed = false;
        let planned = f.plan.fires(FaultSite::Wire, f.epoch, f.seq);
        if planned == 0 {
            return Ok(());
        }
        let h = f.plan.target_hash(FaultSite::Wire, f.epoch, f.seq);
        let (epoch, seq) = (f.epoch, f.seq);
        drop(guard);
        if !self.integrity_guard.get() {
            // Silent corruption: one mantissa bit of one payload element.
            let elem = (h % valid as u64) as usize;
            let bit = ((h >> 40) % 23) as u32;
            buf[elem] = f32::from_bits(buf[elem].to_bits() ^ (1 << bit));
            return Ok(());
        }
        if planned > MAX_DISPATCH_RETRIES {
            bail!(
                "upload payload at (epoch {epoch}, seq {seq}) still corrupt after {} retransmits",
                MAX_DISPATCH_RETRIES
            );
        }
        // Guarded: every corrupt transfer is detected (violation) and
        // re-sent (retransmit); the buffer the caller receives is clean, so
        // downstream state is bitwise identical to a fault-free run.
        let mut c = self.counters.borrow_mut();
        c.integrity_violations += planned as u64;
        c.integrity_retransmits += planned as u64;
        Ok(())
    }

    /// Dispatch core: check args, interpret, verify outputs against the
    /// declared returns, apply the simulated launch overhead, record.
    fn exec(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[Arg<'_, SimDev>],
    ) -> Result<Vec<HostTensor>> {
        self.fault_preflight()?;
        let spec = self.manifest.module(name)?;
        let bytes_in = check_args(name, spec, args)?;
        let t0 = Instant::now();
        let host_args: Vec<&HostTensor> = args
            .iter()
            .map(|a| match a {
                Arg::Host(h) => *h,
                Arg::Dev(d) => &d.0,
            })
            .collect();
        let outs = self.interpret(name, spec, &host_args)?;
        if outs.len() != spec.rets.len() {
            bail!(
                "{name}: interpreter returned {} outputs, declared {}",
                outs.len(),
                spec.rets.len()
            );
        }
        for (o, r) in outs.iter().zip(&spec.rets) {
            if host_dtype(o) != r.dtype || o.shape() != r.shape.as_slice() {
                bail!(
                    "{name}: interpreter returned {}{:?} where the manifest declares {}{:?}",
                    host_dtype(o).name(),
                    o.shape(),
                    r.dtype.name(),
                    r.shape
                );
            }
        }
        if !self.launch_overhead.is_zero() {
            let spin = Instant::now();
            while spin.elapsed() < self.launch_overhead {
                std::hint::spin_loop();
            }
        }
        let dur = t0.elapsed();
        let bytes_out: usize = outs.iter().map(|t| t.size_bytes()).sum();
        {
            let mut c = self.counters.borrow_mut();
            c.record(name, stage, phase, dur, bytes_in, bytes_out);
            c.arena = self.arena.borrow().stats();
        }
        Ok(outs)
    }
}

impl ExecBackend for SimBackend {
    type Dev = SimDev;

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn counters(&self) -> &RefCell<Counters> {
        &self.counters
    }

    fn run(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let wrapped: Vec<Arg<'_, SimDev>> = args.iter().map(|&a| Arg::Host(a)).collect();
        let outs = self.exec(name, stage, phase, &wrapped)?;
        if stage != Stage::Calib {
            // `run` returns host tensors: its outputs cross the device
            // boundary back (unlike `run_dev`, whose results stay resident).
            let bytes: usize = outs.iter().map(|t| t.size_bytes()).sum();
            self.counters.borrow_mut().add_d2h(bytes as u64);
        }
        Ok(outs)
    }

    fn run_dev(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[Arg<'_, SimDev>],
    ) -> Result<SimDev> {
        let mut outs = self.exec(name, stage, phase, args)?;
        if outs.len() != 1 {
            bail!("{name}: run_dev requires a single-output module");
        }
        Ok(SimDev(outs.swap_remove(0)))
    }

    fn run_dev_multi(
        &self,
        name: &'static str,
        stage: Stage,
        phase: Phase,
        args: &[Arg<'_, SimDev>],
    ) -> Result<Vec<SimDev>> {
        Ok(self.exec(name, stage, phase, args)?.into_iter().map(SimDev).collect())
    }

    /// Partial H2D copy into a full-shape "device" buffer: only the leading
    /// `valid_elems` elements transfer (and count). The buffer comes from
    /// the arena, whose checkouts are zeroed, so the untransferred tail is
    /// deterministically zero — callers must still never address it.
    fn upload(&self, t: &HostTensor, valid_elems: usize) -> Result<SimDev> {
        let (dev, valid) = self.upload_impl(t, valid_elems)?;
        self.counters.borrow_mut().add_h2d(valid as u64 * 4);
        Ok(dev)
    }

    /// [`ExecBackend::upload`] over the modeled replica interconnect: the
    /// same partial copy, counted in [`Counters::p2p_bytes`] instead of the
    /// PCIe channel.
    fn upload_peer(&self, t: &HostTensor, valid_elems: usize) -> Result<SimDev> {
        let (dev, valid) = self.upload_impl(t, valid_elems)?;
        self.counters.borrow_mut().add_p2p(valid as u64 * 4);
        Ok(dev)
    }

    fn recycle(&self, t: HostTensor) {
        self.arena.borrow_mut().reclaim(t);
    }

    fn recycle_dev(&self, d: SimDev) {
        self.arena.borrow_mut().reclaim(d.0);
    }

    fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        *self.fault.borrow_mut() =
            Some(FaultState { plan, epoch: 0, seq: 0, armed: false, wire_armed: false });
    }

    fn fault_cursor(&self, epoch: u64, seq: u64) {
        if let Some(f) = self.fault.borrow_mut().as_mut() {
            f.epoch = epoch;
            f.seq = seq;
            f.armed = true;
            f.wire_armed = true;
        }
    }

    fn set_integrity_guard(&self, on: bool) {
        self.integrity_guard.set(on);
    }
}

// --------------------------------------------------------------------------
// module dispatch
// --------------------------------------------------------------------------

/// Bounds-checked index conversion (XLA would silently clamp/drop; failing
/// loudly is strictly more informative for a reference interpreter).
fn idx(v: i32, n: usize, what: &str) -> Result<usize> {
    if v < 0 || v as usize >= n {
        bail!("{what} index {v} out of range 0..{n}");
    }
    Ok(v as usize)
}

impl SimBackend {
    /// Evaluate one module with reference semantics: blocked, row-parallel
    /// kernels over the shared pool, scratch and results from the arena.
    fn interpret(
        &self,
        name: &str,
        spec: &ModuleSpec,
        args: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let dim = |a: usize, d: usize| spec.args[a].shape[d];
        match name {
            "edge_select" => {
                let et = args[0].as_i32()?;
                let rel = args[1].as_i32()?[0];
                let elp = et.len();
                let mut pos = self.take_i32(elp);
                let mut count = 0usize;
                for (p, &t) in et.iter().enumerate() {
                    if t == rel {
                        pos[count] = p as i32;
                        count += 1;
                    }
                }
                for v in pos[count..].iter_mut() {
                    *v = elp as i32; // sentinel = ELP, like the HLO module
                }
                Ok(vec![HostTensor::i32(pos, &[elp]), HostTensor::scalar_i32(count as i32)])
            }

            "feature_gather" => {
                let (cslots, f) = (dim(0, 0), dim(0, 1));
                let mrows = dim(1, 0);
                let (tp, ns) = (dim(2, 0), dim(2, 1));
                let cache = args[0].as_f32()?;
                let miss = args[1].as_f32()?;
                let idxs = args[2].as_i32()?;
                let mut out = self.take_f32(tp * ns * f);
                // Pure per-slot copies partitioned by output row: bit-exact
                // for any thread count. Padding rows (idx == -1) stay at the
                // arena checkout's zeros — the same bytes the CPU collector
                // writes for unused slots.
                self.pool.try_for_row_chunks(&mut out, tp * ns, PAR_MIN_ROWS, |s0, s1, rows| {
                    for s in s0..s1 {
                        let dst = &mut rows[(s - s0) * f..(s - s0 + 1) * f];
                        let ix = idxs[s];
                        if ix >= 0 {
                            let ci = idx(ix, cslots, "cache slot")?;
                            dst.copy_from_slice(&cache[ci * f..(ci + 1) * f]);
                        } else if ix <= -2 {
                            let mi = idx(-ix - 2, mrows, "miss row")?;
                            dst.copy_from_slice(&miss[mi * f..(mi + 1) * f]);
                        }
                    }
                    Ok(())
                })?;
                Ok(vec![HostTensor::f32(out, &[tp, ns, f])])
            }

            n if n.starts_with("proj_stacked_fwd") => {
                let (tp, ns, fin) = (dim(0, 0), dim(0, 1), dim(0, 2));
                let (rp, fout) = (dim(1, 0), dim(1, 2));
                let xs = args[0].as_f32()?;
                let w = args[1].as_f32()?;
                let st = args[2].as_i32()?;
                let mut out = self.take_f32(rp * ns * fout);
                self.pool.try_for_row_chunks(&mut out, rp, 1, |r0, r1, orows| {
                    for r in r0..r1 {
                        let t = idx(st[r], tp, "src_type")?;
                        matmul_rows(
                            &xs[t * ns * fin..(t + 1) * ns * fin],
                            &w[r * fin * fout..(r + 1) * fin * fout],
                            0,
                            ns,
                            fin,
                            fout,
                            &mut orows[(r - r0) * ns * fout..(r - r0 + 1) * ns * fout],
                        );
                    }
                    Ok(())
                })?;
                Ok(vec![HostTensor::f32(out, &[rp, ns, fout])])
            }

            n if n.starts_with("proj_stacked_bwd") => {
                let (tp, ns, fin) = (dim(0, 0), dim(0, 1), dim(0, 2));
                let (rp, fout) = (dim(1, 0), dim(1, 2));
                let (dxs, dw) = self.proj_stacked_bwd_impl(
                    args[0].as_f32()?,
                    args[1].as_f32()?,
                    args[2].as_i32()?,
                    args[3].as_f32()?,
                    tp,
                    ns,
                    fin,
                    rp,
                    fout,
                )?;
                Ok(vec![
                    HostTensor::f32(dxs, &[tp, ns, fin]),
                    HostTensor::f32(dw, &[rp, fin, fout]),
                ])
            }

            n if n.starts_with("proj_resident_bwd") => {
                let (tp, ns, fin) = (dim(0, 0), dim(0, 1), dim(0, 2));
                let (rp, fout) = (dim(1, 0), dim(1, 2));
                let acc = args[4].as_f32()?;
                let (mut dxs, dw) = self.proj_stacked_bwd_impl(
                    args[0].as_f32()?,
                    args[1].as_f32()?,
                    args[2].as_i32()?,
                    args[3].as_f32()?,
                    tp,
                    ns,
                    fin,
                    rp,
                    fout,
                )?;
                // dhin = acc + dxs, mirroring the host executor's
                // `add_assign(dhin, dxs)` into the running accumulator so
                // chaining the two RGAT endpoint passes stays bit-identical
                // to the host-staged path.
                for (o, &a) in dxs.iter_mut().zip(acc) {
                    *o = a + *o;
                }
                Ok(vec![
                    HostTensor::f32(dxs, &[tp, ns, fin]),
                    HostTensor::f32(dw, &[rp, fin, fout]),
                ])
            }

            n if n.starts_with("proj_fwd") => {
                let (ns, fin, fout) = (dim(0, 0), dim(0, 1), dim(1, 1));
                let mut y = self.take_f32(ns * fout);
                matmul_into(&self.pool, args[0].as_f32()?, args[1].as_f32()?, ns, fin, fout,
                    &mut y);
                Ok(vec![HostTensor::f32(y, &[ns, fout])])
            }

            n if n.starts_with("proj_bwd") => {
                let (ns, fin, fout) = (dim(0, 0), dim(0, 1), dim(1, 1));
                let x = args[0].as_f32()?;
                let w = args[1].as_f32()?;
                let dy = args[2].as_f32()?;
                let mut dx = self.take_f32(ns * fin);
                let mut dw = self.take_f32(fin * fout);
                matmul_nt_into(&self.pool, dy, w, ns, fout, fin, &mut dx);
                matmul_tn_into(&self.pool, x, dy, ns, fin, fout, &mut dw);
                Ok(vec![HostTensor::f32(dx, &[ns, fin]), HostTensor::f32(dw, &[fin, fout])])
            }

            n if n.starts_with("agg_mean_fwd") => {
                let (ns, fd) = (dim(0, 0), dim(0, 1));
                let mut out = self.take_f32(ns * fd);
                let mut cnt = self.take_f32(ns);
                agg_mean_into(
                    args[0].as_f32()?,
                    args[1].as_i32()?,
                    args[2].as_i32()?,
                    args[3].as_f32()?,
                    ns,
                    fd,
                    &mut cnt,
                    &mut out,
                )?;
                self.reclaim_f32(cnt);
                Ok(vec![HostTensor::f32(out, &[ns, fd])])
            }

            n if n.starts_with("agg_mean_bwd") => {
                let (ns, fd) = (dim(0, 0), dim(0, 1));
                // arg 0 (feat) is unused: mean aggregation is linear in feat.
                let mut out = self.take_f32(ns * fd);
                let mut cnt = self.take_f32(ns);
                agg_mean_bwd_into(
                    args[1].as_i32()?,
                    args[2].as_i32()?,
                    args[3].as_f32()?,
                    args[4].as_f32()?,
                    ns,
                    fd,
                    &mut cnt,
                    &mut out,
                )?;
                self.reclaim_f32(cnt);
                Ok(vec![HostTensor::f32(out, &[ns, fd])])
            }

            n if n.starts_with("agg_merged_fwd") => {
                let (rp, ns, fd) = (dim(0, 0), dim(0, 1), dim(0, 2));
                let ep = dim(1, 1);
                let feat = args[0].as_f32()?;
                let src = args[1].as_i32()?;
                let dst = args[2].as_i32()?;
                let valid = args[3].as_f32()?;
                let mut out = self.take_f32(rp * ns * fd);
                let mut cnt = self.take_f32(rp * ns);
                self.pool.try_for_row_chunks2(&mut out, &mut cnt, rp, 1, |r0, r1, oc, cc| {
                    for r in r0..r1 {
                        agg_mean_into(
                            &feat[r * ns * fd..(r + 1) * ns * fd],
                            &src[r * ep..(r + 1) * ep],
                            &dst[r * ep..(r + 1) * ep],
                            &valid[r * ep..(r + 1) * ep],
                            ns,
                            fd,
                            &mut cc[(r - r0) * ns..(r - r0 + 1) * ns],
                            &mut oc[(r - r0) * ns * fd..(r - r0 + 1) * ns * fd],
                        )?;
                    }
                    Ok(())
                })?;
                self.reclaim_f32(cnt);
                Ok(vec![HostTensor::f32(out, &[rp, ns, fd])])
            }

            n if n.starts_with("agg_merged_bwd") => {
                let (rp, ep) = (dim(0, 0), dim(0, 1));
                let (ns, fd) = (dim(3, 1), dim(3, 2));
                let src = args[0].as_i32()?;
                let dst = args[1].as_i32()?;
                let valid = args[2].as_f32()?;
                let dout = args[3].as_f32()?;
                let mut out = self.take_f32(rp * ns * fd);
                let mut cnt = self.take_f32(rp * ns);
                self.pool.try_for_row_chunks2(&mut out, &mut cnt, rp, 1, |r0, r1, oc, cc| {
                    for r in r0..r1 {
                        agg_mean_bwd_into(
                            &src[r * ep..(r + 1) * ep],
                            &dst[r * ep..(r + 1) * ep],
                            &valid[r * ep..(r + 1) * ep],
                            &dout[r * ns * fd..(r + 1) * ns * fd],
                            ns,
                            fd,
                            &mut cc[(r - r0) * ns..(r - r0 + 1) * ns],
                            &mut oc[(r - r0) * ns * fd..(r - r0 + 1) * ns * fd],
                        )?;
                    }
                    Ok(())
                })?;
                self.reclaim_f32(cnt);
                Ok(vec![HostTensor::f32(out, &[rp, ns, fd])])
            }

            n if n.starts_with("att_agg_fwd") => {
                let (ns, fd) = (dim(0, 0), dim(0, 1));
                let src = args[4].as_i32()?;
                let ep = src.len();
                let mut out = self.take_f32(ns * fd);
                let mut scratch = self.take_f32(att_fwd_scratch_len(ns, ep));
                att_agg_into(
                    args[0].as_f32()?,
                    args[1].as_f32()?,
                    args[2].as_f32()?,
                    args[3].as_f32()?,
                    src,
                    args[5].as_i32()?,
                    args[6].as_f32()?,
                    ns,
                    fd,
                    &mut scratch,
                    &mut out,
                )?;
                self.reclaim_f32(scratch);
                Ok(vec![HostTensor::f32(out, &[ns, fd])])
            }

            n if n.starts_with("att_agg_bwd") => {
                let (ns, fd) = (dim(0, 0), dim(0, 1));
                let src = args[4].as_i32()?;
                let ep = src.len();
                let mut dfs = self.take_f32(ns * fd);
                let mut dfd = self.take_f32(ns * fd);
                let mut das = self.take_f32(fd);
                let mut dad = self.take_f32(fd);
                let mut scratch = self.take_f32(att_bwd_scratch_len(ns, ep));
                att_agg_bwd_into(
                    args[0].as_f32()?,
                    args[1].as_f32()?,
                    args[2].as_f32()?,
                    args[3].as_f32()?,
                    src,
                    args[5].as_i32()?,
                    args[6].as_f32()?,
                    args[7].as_f32()?,
                    ns,
                    fd,
                    &mut scratch,
                    &mut dfs,
                    &mut dfd,
                    &mut das,
                    &mut dad,
                )?;
                self.reclaim_f32(scratch);
                Ok(vec![
                    HostTensor::f32(dfs, &[ns, fd]),
                    HostTensor::f32(dfd, &[ns, fd]),
                    HostTensor::f32(das, &[fd]),
                    HostTensor::f32(dad, &[fd]),
                ])
            }

            n if n.starts_with("att_merged_fwd") => {
                let (rp, ns, fd) = (dim(0, 0), dim(0, 1), dim(0, 2));
                let ep = dim(4, 1);
                let (fs, fdm) = (args[0].as_f32()?, args[1].as_f32()?);
                let (a_s, a_d) = (args[2].as_f32()?, args[3].as_f32()?);
                let (src, dst) = (args[4].as_i32()?, args[5].as_i32()?);
                let valid = args[6].as_f32()?;
                let sw = att_fwd_scratch_len(ns, ep);
                let mut out = self.take_f32(rp * ns * fd);
                let mut scratch = self.take_f32(rp * sw);
                self.pool.try_for_row_chunks2(&mut out, &mut scratch, rp, 1,
                    |r0, r1, oc, sc| {
                        for r in r0..r1 {
                            att_agg_into(
                                &fs[r * ns * fd..(r + 1) * ns * fd],
                                &fdm[r * ns * fd..(r + 1) * ns * fd],
                                &a_s[r * fd..(r + 1) * fd],
                                &a_d[r * fd..(r + 1) * fd],
                                &src[r * ep..(r + 1) * ep],
                                &dst[r * ep..(r + 1) * ep],
                                &valid[r * ep..(r + 1) * ep],
                                ns,
                                fd,
                                &mut sc[(r - r0) * sw..(r - r0 + 1) * sw],
                                &mut oc[(r - r0) * ns * fd..(r - r0 + 1) * ns * fd],
                            )?;
                        }
                        Ok(())
                    })?;
                self.reclaim_f32(scratch);
                Ok(vec![HostTensor::f32(out, &[rp, ns, fd])])
            }

            n if n.starts_with("att_merged_bwd") => {
                let (rp, ns, fd) = (dim(0, 0), dim(0, 1), dim(0, 2));
                let ep = dim(4, 1);
                let (fs, fdm) = (args[0].as_f32()?, args[1].as_f32()?);
                let (a_s, a_d) = (args[2].as_f32()?, args[3].as_f32()?);
                let (src, dst) = (args[4].as_i32()?, args[5].as_i32()?);
                let valid = args[6].as_f32()?;
                let dout = args[7].as_f32()?;
                // Each relation's four gradients are packed into one row of
                // `packed` so a single lockstep partition covers them all:
                // [dfs ns*fd | dfd ns*fd | das fd | dad fd].
                let ow = 2 * ns * fd + 2 * fd;
                let sw = att_bwd_scratch_len(ns, ep);
                let mut packed = self.take_f32(rp * ow);
                let mut scratch = self.take_f32(rp * sw);
                self.pool.try_for_row_chunks2(&mut packed, &mut scratch, rp, 1,
                    |r0, r1, pc, sc| {
                        for r in r0..r1 {
                            let p = &mut pc[(r - r0) * ow..(r - r0 + 1) * ow];
                            let (dfs_r, rest) = p.split_at_mut(ns * fd);
                            let (dfd_r, rest) = rest.split_at_mut(ns * fd);
                            let (das_r, dad_r) = rest.split_at_mut(fd);
                            att_agg_bwd_into(
                                &fs[r * ns * fd..(r + 1) * ns * fd],
                                &fdm[r * ns * fd..(r + 1) * ns * fd],
                                &a_s[r * fd..(r + 1) * fd],
                                &a_d[r * fd..(r + 1) * fd],
                                &src[r * ep..(r + 1) * ep],
                                &dst[r * ep..(r + 1) * ep],
                                &valid[r * ep..(r + 1) * ep],
                                &dout[r * ns * fd..(r + 1) * ns * fd],
                                ns,
                                fd,
                                &mut sc[(r - r0) * sw..(r - r0 + 1) * sw],
                                dfs_r,
                                dfd_r,
                                das_r,
                                dad_r,
                            )?;
                        }
                        Ok(())
                    })?;
                self.reclaim_f32(scratch);
                let mut dfs = self.take_f32(rp * ns * fd);
                let mut dfd = self.take_f32(rp * ns * fd);
                let mut das = self.take_f32(rp * fd);
                let mut dad = self.take_f32(rp * fd);
                for r in 0..rp {
                    let p = &packed[r * ow..(r + 1) * ow];
                    dfs[r * ns * fd..(r + 1) * ns * fd].copy_from_slice(&p[..ns * fd]);
                    dfd[r * ns * fd..(r + 1) * ns * fd]
                        .copy_from_slice(&p[ns * fd..2 * ns * fd]);
                    das[r * fd..(r + 1) * fd]
                        .copy_from_slice(&p[2 * ns * fd..2 * ns * fd + fd]);
                    dad[r * fd..(r + 1) * fd].copy_from_slice(&p[2 * ns * fd + fd..]);
                }
                self.reclaim_f32(packed);
                Ok(vec![
                    HostTensor::f32(dfs, &[rp, ns, fd]),
                    HostTensor::f32(dfd, &[rp, ns, fd]),
                    HostTensor::f32(das, &[rp, fd]),
                    HostTensor::f32(dad, &[rp, fd]),
                ])
            }

            n if n.starts_with("fuse_relu_fwd") || n.starts_with("fuse_lin_fwd") => {
                let relu = n.starts_with("fuse_relu");
                let (rp, ns, fd) = (dim(1, 0), dim(1, 1), dim(1, 2));
                let tp = spec.rets[0].shape[0];
                let mut out = self.take_f32(tp * ns * fd);
                fuse_fwd_into(&self.pool, args[0].as_i32()?, args[1].as_f32()?, rp, ns, fd,
                    tp, relu, &mut out)?;
                Ok(vec![HostTensor::f32(out, &[tp, ns, fd])])
            }

            n if n.starts_with("fuse_relu_bwd") || n.starts_with("fuse_lin_bwd") => {
                let relu = n.starts_with("fuse_relu");
                let (rp, ns, fd) = (dim(1, 0), dim(1, 1), dim(1, 2));
                let tp = dim(2, 0);
                let dst_type = args[0].as_i32()?;
                let agg = args[1].as_f32()?;
                let dout = args[2].as_f32()?;
                let mut dagg = self.take_f32(rp * ns * fd);
                // ReLU support is recomputed from the stored pre-activation
                // inputs, exactly like the scalar oracle.
                let pre = if relu {
                    let mut p = self.take_f32(tp * ns * fd);
                    fuse_fwd_into(&self.pool, dst_type, agg, rp, ns, fd, tp, false, &mut p)?;
                    Some(p)
                } else {
                    None
                };
                let w = ns * fd;
                self.pool.try_for_row_chunks(&mut dagg, rp, 1, |r0, r1, dc| {
                    for r in r0..r1 {
                        let t = idx(dst_type[r], tp, "dst_type")?;
                        let grow = &dout[t * w..(t + 1) * w];
                        let drow = &mut dc[(r - r0) * w..(r - r0 + 1) * w];
                        match &pre {
                            Some(s) => {
                                let srow = &s[t * w..(t + 1) * w];
                                for k in 0..w {
                                    drow[k] = if srow[k] > 0.0 { grow[k] } else { 0.0 };
                                }
                            }
                            None => drow.copy_from_slice(grow),
                        }
                    }
                    Ok(())
                })?;
                if let Some(p) = pre {
                    self.reclaim_f32(p);
                }
                Ok(vec![HostTensor::f32(dagg, &[rp, ns, fd])])
            }

            "head" => {
                let (ns, c) = (dim(0, 0), dim(0, 1));
                let mut z = self.take_f32(ns * c);
                let mut dlogits = self.take_f32(ns * c);
                let (loss, ncorrect) = head_into(
                    args[0].as_f32()?,
                    args[1].as_i32()?,
                    args[2].as_f32()?,
                    ns,
                    c,
                    &mut z,
                    &mut dlogits,
                );
                self.reclaim_f32(z);
                Ok(vec![
                    HostTensor::scalar_f32(loss),
                    HostTensor::f32(dlogits, &[ns, c]),
                    HostTensor::scalar_f32(ncorrect),
                ])
            }

            "head_full" => {
                // Device-resident head: target-slab extraction + softmax
                // cross-entropy + dlogits scattered back into a full
                // `[TPAD, NS, C]` gradient, so the whole loss/backward seam
                // runs in one dispatch with only the two scalars ever
                // crossing back to the host (tests/residency.rs).
                let (tp, ns, c) = (dim(0, 0), dim(0, 1), dim(0, 2));
                let hout = args[0].as_f32()?;
                let labels = args[1].as_i32()?;
                let mask = args[2].as_f32()?;
                let t = idx(args[3].as_i32()?[0], tp, "target_type")?;
                let logits = &hout[t * ns * c..(t + 1) * ns * c];
                let mut z = self.take_f32(ns * c);
                let mut dlogits = self.take_f32(ns * c);
                let (loss, ncorrect) = head_into(logits, labels, mask, ns, c, &mut z,
                    &mut dlogits);
                self.reclaim_f32(z);
                // Zeroed checkout: non-target slabs stay at the exact zeros
                // the host executor writes into its dh2 staging buffer.
                let mut dh2 = self.take_f32(tp * ns * c);
                dh2[t * ns * c..(t + 1) * ns * c].copy_from_slice(&dlogits);
                self.reclaim_f32(dlogits);
                Ok(vec![
                    HostTensor::scalar_f32(loss),
                    HostTensor::f32(dh2, &[tp, ns, c]),
                    HostTensor::scalar_f32(ncorrect),
                ])
            }

            "slab_pick" => {
                // Target-type logits extraction for the serve path: the
                // device-side analogue of the host `slab()` copy.
                let (tp, ns, c) = (dim(0, 0), dim(0, 1), dim(0, 2));
                let hout = args[0].as_f32()?;
                let t = idx(args[1].as_i32()?[0], tp, "target_type")?;
                let mut out = self.take_f32(ns * c);
                out.copy_from_slice(&hout[t * ns * c..(t + 1) * ns * c]);
                Ok(vec![HostTensor::f32(out, &[ns, c])])
            }

            "sgd_rgcn" => {
                // Fused on-device SGD for the RGCN parameter set. Mirrors
                // the host optimizer bit-for-bit: gradients are accumulated
                // into zero-initialized buffers there (`0.0 + dw`), then
                // `w -= lr * g`.
                let lr = args[4].as_f32()?[0];
                let mut outs = Vec::with_capacity(2);
                for (wi, di) in [(0usize, 2usize), (1, 3)] {
                    let w = args[wi].as_f32()?;
                    let dw = args[di].as_f32()?;
                    let mut o = self.take_f32(w.len());
                    for i in 0..w.len() {
                        o[i] = w[i] - lr * (0.0 + dw[i]);
                    }
                    outs.push(HostTensor::f32(o, &spec.args[wi].shape));
                }
                Ok(outs)
            }

            "sgd_rgat" => {
                // Fused on-device SGD for the RGAT parameter set. The two
                // projection-weight gradients (src- and dst-endpoint passes)
                // fold in the host executor's order — `(0.0 + dw_src) +
                // dw_dst` — and the attention-vector gradients apply
                // directly (the host stores them by copy, not accumulation).
                let lr = args[14].as_f32()?[0];
                let mut outs = Vec::with_capacity(6);
                for (wi, dai, dbi) in [(0usize, 6usize, 7usize), (1, 8, 9)] {
                    let w = args[wi].as_f32()?;
                    let da = args[dai].as_f32()?;
                    let db = args[dbi].as_f32()?;
                    let mut o = self.take_f32(w.len());
                    for i in 0..w.len() {
                        o[i] = w[i] - lr * ((0.0 + da[i]) + db[i]);
                    }
                    outs.push(HostTensor::f32(o, &spec.args[wi].shape));
                }
                for (ai, di) in [(2usize, 10usize), (3, 11), (4, 12), (5, 13)] {
                    let a = args[ai].as_f32()?;
                    let dg = args[di].as_f32()?;
                    let mut o = self.take_f32(a.len());
                    for i in 0..a.len() {
                        o[i] = a[i] - lr * dg[i];
                    }
                    outs.push(HostTensor::f32(o, &spec.args[ai].shape));
                }
                Ok(outs)
            }

            other => bail!("SimBackend has no reference semantics for module {other:?}"),
        }
    }

    /// Shared body of `proj_stacked_bwd*` and `proj_resident_bwd*`:
    /// per-relation dx lands in scratch (relation-parallel), then is folded
    /// into the type slabs serially so the accumulation order (r ascending)
    /// stays bit-identical to the scalar oracle. Returns
    /// (`dxs [tp*ns*fin]`, `dw [rp*fin*fout]`) as arena checkouts.
    #[allow(clippy::too_many_arguments)]
    fn proj_stacked_bwd_impl(
        &self,
        xs: &[f32],
        w: &[f32],
        st: &[i32],
        dy: &[f32],
        tp: usize,
        ns: usize,
        fin: usize,
        rp: usize,
        fout: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut dxs = self.take_f32(tp * ns * fin);
        let mut dw = self.take_f32(rp * fin * fout);
        let mut dx_scratch = self.take_f32(rp * ns * fin);
        self.pool.try_for_row_chunks2(
            &mut dx_scratch,
            &mut dw,
            rp,
            1,
            |r0, r1, dxc, dwc| {
                for r in r0..r1 {
                    let t = idx(st[r], tp, "src_type")?;
                    let dy_r = &dy[r * ns * fout..(r + 1) * ns * fout];
                    matmul_nt_rows(
                        dy_r,
                        &w[r * fin * fout..(r + 1) * fin * fout],
                        fout,
                        fin,
                        0,
                        ns,
                        &mut dxc[(r - r0) * ns * fin..(r - r0 + 1) * ns * fin],
                    );
                    matmul_tn_rows(
                        &xs[t * ns * fin..(t + 1) * ns * fin],
                        dy_r,
                        ns,
                        fin,
                        fout,
                        0,
                        fin,
                        &mut dwc[(r - r0) * fin * fout..(r - r0 + 1) * fin * fout],
                    );
                }
                Ok(())
            },
        )?;
        for r in 0..rp {
            let t = st[r] as usize; // validated by the worker pass
            let dst = &mut dxs[t * ns * fin..(t + 1) * ns * fin];
            let src = &dx_scratch[r * ns * fin..(r + 1) * ns * fin];
            for (acc, v) in dst.iter_mut().zip(src) {
                *acc += *v;
            }
        }
        self.reclaim_f32(dx_scratch);
        Ok((dxs, dw))
    }
}

// --------------------------------------------------------------------------
// hot-path kernels: cache-blocked, row-parallel over the worker pool.
//
// Parallel partitioning is always by *output row*, and every element keeps
// the scalar oracle's exact accumulation sequence (ascending reduction
// index, same zero-skip), so results are bit-identical to the serial
// reference for any thread count — the invariant the parity tests pin.
// --------------------------------------------------------------------------

/// Column-tile width of the blocked matmul microkernel: one output-row
/// tile plus the matching B-row segment stay cache-resident while the k
/// loop streams over A.
const TILE_J: usize = 64;
/// Minimum output rows per worker before a kernel fans out (tiny-profile
/// shapes stay serial: spawn cost would dominate).
const PAR_MIN_ROWS: usize = 64;

/// Rows `i0..i1` of `out[m,n] = a[m,k] · b[k,n]` into `orows`
/// (`(i1-i0)*n`, pre-zeroed). Per element: p ascending, zero-skip on A.
fn matmul_rows(a: &[f32], b: &[f32], i0: usize, i1: usize, k: usize, n: usize,
    orows: &mut [f32]) {
    for i in i0..i1 {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut orows[(i - i0) * n..(i - i0 + 1) * n];
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + TILE_J).min(n);
            for (p, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n + j0..p * n + j1];
                for (o, bv) in orow[j0..j1].iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
            j0 = j1;
        }
    }
}

/// `out[m,n] = a[m,k] · b[k,n]`, rows partitioned across the pool.
fn matmul_into(pool: &WorkerPool, a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
    out: &mut [f32]) {
    pool.for_row_chunks(out, m, PAR_MIN_ROWS, |i0, i1, orows| {
        matmul_rows(a, b, i0, i1, k, n, orows)
    });
}

/// Rows `i0..i1` of `out[k,n] = aᵀ · b` for `a: [m,k]`, `b: [m,n]`
/// (the `dw = xᵀ·dy` form). Per element: s ascending, zero-skip on A.
fn matmul_tn_rows(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, i0: usize, i1: usize,
    orows: &mut [f32]) {
    for i in i0..i1 {
        let orow = &mut orows[(i - i0) * n..(i - i0 + 1) * n];
        for s in 0..m {
            let av = a[s * k + i];
            if av == 0.0 {
                continue;
            }
            let brow = &b[s * n..(s + 1) * n];
            for (o, bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

fn matmul_tn_into(pool: &WorkerPool, a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
    out: &mut [f32]) {
    pool.for_row_chunks(out, k, PAR_MIN_ROWS, |i0, i1, orows| {
        matmul_tn_rows(a, b, m, k, n, i0, i1, orows)
    });
}

/// Rows `i0..i1` of `out[m,k] = a[m,n] · bᵀ` for `b: [k,n]`
/// (the `dx = dy·wᵀ` form): dense dot products, no accumulation races.
fn matmul_nt_rows(a: &[f32], b: &[f32], n: usize, k: usize, i0: usize, i1: usize,
    orows: &mut [f32]) {
    for i in i0..i1 {
        let arow = &a[i * n..(i + 1) * n];
        let orow = &mut orows[(i - i0) * k..(i - i0 + 1) * k];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * n..(j + 1) * n];
            let mut s = 0.0f32;
            for (av, bv) in arow.iter().zip(brow) {
                s += av * bv;
            }
            *o = s;
        }
    }
}

fn matmul_nt_into(pool: &WorkerPool, a: &[f32], b: &[f32], m: usize, n: usize, k: usize,
    out: &mut [f32]) {
    pool.for_row_chunks(out, m, PAR_MIN_ROWS, |i0, i1, orows| {
        matmul_nt_rows(a, b, n, k, i0, i1, orows)
    });
}

/// Mean-aggregate `feat[src[e]]` onto `dst[e]` (ref.py `agg_mean_ref`):
/// row j = sum of valid incoming features / max(1, valid in-degree).
/// `out` (`ns*fd`) and `cnt` (`ns`) must be pre-zeroed; scatter collisions
/// keep one relation serial — merged variants parallelize across relations.
fn agg_mean_into(
    feat: &[f32],
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    ns: usize,
    fd: usize,
    cnt: &mut [f32],
    out: &mut [f32],
) -> Result<()> {
    for e in 0..src.len() {
        let v = valid[e];
        if v == 0.0 {
            continue;
        }
        let s = idx(src[e], ns, "src")?;
        let d = idx(dst[e], ns, "dst")?;
        for x in 0..fd {
            out[d * fd + x] += feat[s * fd + x] * v;
        }
        cnt[d] += v;
    }
    for j in 0..ns {
        let c = cnt[j].max(1.0);
        if c != 1.0 {
            for x in 0..fd {
                out[j * fd + x] /= c;
            }
        }
    }
    Ok(())
}

/// VJP of [`agg_mean_into`] w.r.t. `feat` (linear, so exact):
/// `dfeat[src[e]] += valid[e] * dout[dst[e]] / max(1, degree(dst[e]))`.
fn agg_mean_bwd_into(
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    dout: &[f32],
    ns: usize,
    fd: usize,
    cnt: &mut [f32],
    out: &mut [f32],
) -> Result<()> {
    for e in 0..src.len() {
        if valid[e] != 0.0 {
            cnt[idx(dst[e], ns, "dst")?] += valid[e];
        }
    }
    for e in 0..src.len() {
        let v = valid[e];
        if v == 0.0 {
            continue;
        }
        let s = idx(src[e], ns, "src")?;
        let d = idx(dst[e], ns, "dst")?;
        let w = v / cnt[d].max(1.0);
        for x in 0..fd {
            out[s * fd + x] += dout[d * fd + x] * w;
        }
    }
    Ok(())
}

/// Pooled-scratch length for one relation's attention forward:
/// `[es ns][ed ns][z ep][eact ep][w ep][segmax ns][denom ns]`.
fn att_fwd_scratch_len(ns: usize, ep: usize) -> usize {
    4 * ns + 3 * ep
}

/// Backward scratch: the forward layout plus
/// `[alpha ep][dalpha ep][seg ns][des ns][ded ns]`.
fn att_bwd_scratch_len(ns: usize, ep: usize) -> usize {
    att_fwd_scratch_len(ns, ep) + 3 * ns + 2 * ep
}

/// Attention-forward intermediates into pooled `scratch` (fwd layout,
/// pre-zeroed): the same rematerialization the AOT modules do, with zero
/// per-call allocation.
fn att_forward_into(
    fs: &[f32],
    fdm: &[f32],
    a_s: &[f32],
    a_d: &[f32],
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    ns: usize,
    fd: usize,
    scratch: &mut [f32],
) -> Result<()> {
    let ep = src.len();
    debug_assert_eq!(scratch.len(), att_fwd_scratch_len(ns, ep));
    let (es, rest) = scratch.split_at_mut(ns);
    let (ed, rest) = rest.split_at_mut(ns);
    let (z, rest) = rest.split_at_mut(ep);
    let (eact, rest) = rest.split_at_mut(ep);
    let (w, rest) = rest.split_at_mut(ep);
    let (segmax, denom) = rest.split_at_mut(ns);
    for i in 0..ns {
        let (mut se, mut de) = (0.0f32, 0.0f32);
        for x in 0..fd {
            se += fs[i * fd + x] * a_s[x];
            de += fdm[i * fd + x] * a_d[x];
        }
        es[i] = se;
        ed[i] = de;
    }
    for e in 0..ep {
        let s = idx(src[e], ns, "src")?;
        let d = idx(dst[e], ns, "dst")?;
        let ze = es[s] + ed[d];
        z[e] = ze;
        let l = if ze >= 0.0 { ze } else { LEAKY_SLOPE * ze };
        eact[e] = if valid[e] > 0.0 { l } else { NEG_INF };
    }
    for v in segmax.iter_mut() {
        *v = NEG_INF;
    }
    for e in 0..ep {
        let d = dst[e] as usize;
        if eact[e] > segmax[d] {
            segmax[d] = eact[e];
        }
    }
    for e in 0..ep {
        let d = dst[e] as usize;
        let we = (eact[e] - segmax[d]).exp() * valid[e];
        w[e] = we;
        denom[d] += we;
    }
    Ok(())
}

/// GAT-style attention aggregation (ref.py `att_agg_ref`):
/// `e_ij = LeakyReLU(a_src·h_i + a_dst·h_j)`, segment-softmax over valid
/// incoming edges of j, `out_j = Σ_i α_ij h_i`. `out` pre-zeroed.
#[allow(clippy::too_many_arguments)]
fn att_agg_into(
    fs: &[f32],
    fdm: &[f32],
    a_s: &[f32],
    a_d: &[f32],
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    ns: usize,
    fd: usize,
    scratch: &mut [f32],
    out: &mut [f32],
) -> Result<()> {
    att_forward_into(fs, fdm, a_s, a_d, src, dst, valid, ns, fd, scratch)?;
    let ep = src.len();
    let w = &scratch[2 * ns + 2 * ep..2 * ns + 3 * ep];
    let denom = &scratch[3 * ns + 3 * ep..4 * ns + 3 * ep];
    for e in 0..ep {
        let we = w[e];
        if we == 0.0 {
            continue;
        }
        let s = src[e] as usize; // validated in att_forward_into
        let d = dst[e] as usize;
        for x in 0..fd {
            out[d * fd + x] += we * fs[s * fd + x];
        }
    }
    for j in 0..ns {
        let dn = denom[j].max(DENOM_EPS);
        for x in 0..fd {
            out[j * fd + x] /= dn;
        }
    }
    Ok(())
}

/// VJP of [`att_agg_into`] w.r.t. (feat_src, feat_dst, a_src, a_dst);
/// recomputes the forward into the leading scratch region. Output slices
/// (`dfs`/`dfd`: `ns*fd`, `das`/`dad`: `fd`) must be pre-zeroed. Validated
/// against `jax.vjp` of the Python oracle (via the scalar oracle parity).
#[allow(clippy::too_many_arguments)]
fn att_agg_bwd_into(
    fs: &[f32],
    fdm: &[f32],
    a_s: &[f32],
    a_d: &[f32],
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    dout: &[f32],
    ns: usize,
    fd: usize,
    scratch: &mut [f32],
    dfs: &mut [f32],
    dfd: &mut [f32],
    das: &mut [f32],
    dad: &mut [f32],
) -> Result<()> {
    let ep = src.len();
    debug_assert_eq!(scratch.len(), att_bwd_scratch_len(ns, ep));
    let (fw, rest) = scratch.split_at_mut(att_fwd_scratch_len(ns, ep));
    att_forward_into(fs, fdm, a_s, a_d, src, dst, valid, ns, fd, fw)?;
    let z = &fw[2 * ns..2 * ns + ep];
    let w = &fw[2 * ns + 2 * ep..2 * ns + 3 * ep];
    let denom = &fw[3 * ns + 3 * ep..4 * ns + 3 * ep];
    let (alpha, rest) = rest.split_at_mut(ep);
    let (dalpha, rest) = rest.split_at_mut(ep);
    let (seg, rest) = rest.split_at_mut(ns);
    let (des, ded) = rest.split_at_mut(ns);
    // alpha_e = w_e / max(denom, eps): the normalized attention weights.
    // Direct path: dfs[src] += alpha * dout[dst]; and the softmax pullback
    // needs dalpha_e = dout[dst] · fs[src].
    for e in 0..ep {
        let d = dst[e] as usize;
        let a = w[e] / denom[d].max(DENOM_EPS);
        alpha[e] = a;
        if a == 0.0 {
            continue;
        }
        let s = src[e] as usize;
        let mut da = 0.0f32;
        for x in 0..fd {
            dfs[s * fd + x] += a * dout[d * fd + x];
            da += dout[d * fd + x] * fs[s * fd + x];
        }
        dalpha[e] = da;
    }
    // Softmax backward per segment: dl_e = alpha_e (dalpha_e - Σ alpha dalpha).
    for e in 0..ep {
        seg[dst[e] as usize] += alpha[e] * dalpha[e];
    }
    for e in 0..ep {
        let a = alpha[e];
        if a == 0.0 {
            continue;
        }
        let d = dst[e] as usize;
        let dl = a * (dalpha[e] - seg[d]);
        let dz = dl * if z[e] >= 0.0 { 1.0 } else { LEAKY_SLOPE };
        des[src[e] as usize] += dz;
        ded[d] += dz;
    }
    // Back through the score projections es = fs·a_s, ed = fd·a_d.
    for i in 0..ns {
        if des[i] != 0.0 {
            for x in 0..fd {
                dfs[i * fd + x] += des[i] * a_s[x];
                das[x] += des[i] * fs[i * fd + x];
            }
        }
        if ded[i] != 0.0 {
            for x in 0..fd {
                dfd[i * fd + x] += ded[i] * a_d[x];
                dad[x] += ded[i] * fdm[i * fd + x];
            }
        }
    }
    Ok(())
}

/// Semantic fusion forward (model.py `fuse_relu` / `fuse_lin`):
/// `out[t] = act(Σ_{r: dst_type[r]=t} agg[r])` into pre-zeroed `out`.
/// Parallelized by *destination type*: each worker owns a contiguous range
/// of output slabs and scans all relations, so per-element accumulation
/// stays in ascending-r order (bit-exact) with no scatter races.
#[allow(clippy::too_many_arguments)]
fn fuse_fwd_into(
    pool: &WorkerPool,
    dst_type: &[i32],
    agg: &[f32],
    rp: usize,
    ns: usize,
    fd: usize,
    tp: usize,
    relu: bool,
    out: &mut [f32],
) -> Result<()> {
    let w = ns * fd;
    pool.try_for_row_chunks(out, tp, 1, |t0, t1, orows| {
        for r in 0..rp {
            let t = idx(dst_type[r], tp, "dst_type")?;
            if t < t0 || t >= t1 {
                continue;
            }
            let srow = &agg[r * w..(r + 1) * w];
            let orow = &mut orows[(t - t0) * w..(t - t0 + 1) * w];
            for (o, v) in orow.iter_mut().zip(srow) {
                *o += *v;
            }
        }
        if relu {
            for v in orows.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        Ok(())
    })
}

/// Softmax cross-entropy head (model.py `head`): loss, dlogits, and
/// accuracy count over the seed rows, in one "dispatch". `z` (`ns*c`
/// scratch) and `dlogits` (`ns*c` output) come from the arena.
fn head_into(
    logits: &[f32],
    labels: &[i32],
    mask: &[f32],
    ns: usize,
    c: usize,
    z: &mut [f32],
    dlogits: &mut [f32],
) -> (f32, f32) {
    for i in 0..ns {
        let row = &logits[i * c..(i + 1) * c];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut se = 0.0f32;
        for &l in row {
            se += (l - m).exp();
        }
        let lse = m + se.ln();
        for j in 0..c {
            z[i * c + j] = row[j] - lse;
        }
    }
    let n = mask.iter().sum::<f32>().max(1.0);
    let mut loss = 0.0f32;
    let mut ncorrect = 0.0f32;
    for i in 0..ns {
        let lab = labels[i];
        let mi = mask[i];
        for j in 0..c {
            let one = if j as i32 == lab { 1.0f32 } else { 0.0 };
            if one == 1.0 {
                loss -= z[i * c + j] * mi;
            }
            dlogits[i * c + j] = (z[i * c + j].exp() - one) * mi / n;
        }
        // argmax with first-max tie-breaking, like jnp.argmax.
        let row = &logits[i * c..(i + 1) * c];
        let mut am = 0usize;
        for j in 1..c {
            if row[j] > row[am] {
                am = j;
            }
        }
        if am as i32 == lab {
            ncorrect += mi;
        }
    }
    (loss / n, ncorrect)
}

// --------------------------------------------------------------------------
// scalar oracles (test-only): the original serial reference kernels that
// mirror ref.py / model.py line-for-line. The blocked/pooled kernels above
// must match them bit-for-bit; the parity tests below enforce it.
// --------------------------------------------------------------------------

/// `out[m,n] = a[m,k] · b[k,n]`, row-major f32 (scalar oracle).
#[cfg(test)]
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// `out[k,n] = aᵀ[k,m] · b[m,n]` for `a: [m,k]` (scalar oracle).
#[cfg(test)]
fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k * n];
    for s in 0..m {
        for i in 0..k {
            let av = a[s * k + i];
            if av == 0.0 {
                continue;
            }
            let brow = &b[s * n..(s + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// `out[m,k] = a[m,n] · bᵀ[n,k]` for `b: [k,n]` (scalar oracle).
#[cfg(test)]
fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * k];
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        for j in 0..k {
            let brow = &b[j * n..(j + 1) * n];
            let mut s = 0.0f32;
            for (av, bv) in arow.iter().zip(brow) {
                s += av * bv;
            }
            out[i * k + j] = s;
        }
    }
    out
}

/// Mean aggregation, scalar oracle (allocating wrapper over the kernel).
#[cfg(test)]
fn agg_mean(
    feat: &[f32],
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    ns: usize,
    fd: usize,
) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; ns * fd];
    let mut cnt = vec![0.0f32; ns];
    agg_mean_into(feat, src, dst, valid, ns, fd, &mut cnt, &mut out)?;
    Ok(out)
}

/// Mean-aggregation VJP, scalar oracle.
#[cfg(test)]
fn agg_mean_bwd(
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    dout: &[f32],
    ns: usize,
    fd: usize,
) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; ns * fd];
    let mut cnt = vec![0.0f32; ns];
    agg_mean_bwd_into(src, dst, valid, dout, ns, fd, &mut cnt, &mut out)?;
    Ok(out)
}

/// Attention aggregation, scalar oracle.
#[cfg(test)]
#[allow(clippy::too_many_arguments)]
fn att_agg(
    fs: &[f32],
    fdm: &[f32],
    a_s: &[f32],
    a_d: &[f32],
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    ns: usize,
    fd: usize,
) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; ns * fd];
    let mut scratch = vec![0.0f32; att_fwd_scratch_len(ns, src.len())];
    att_agg_into(fs, fdm, a_s, a_d, src, dst, valid, ns, fd, &mut scratch, &mut out)?;
    Ok(out)
}

/// Attention-aggregation VJP, scalar oracle.
#[cfg(test)]
#[allow(clippy::too_many_arguments)]
fn att_agg_bwd(
    fs: &[f32],
    fdm: &[f32],
    a_s: &[f32],
    a_d: &[f32],
    src: &[i32],
    dst: &[i32],
    valid: &[f32],
    dout: &[f32],
    ns: usize,
    fd: usize,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
    let mut dfs = vec![0.0f32; ns * fd];
    let mut dfd = vec![0.0f32; ns * fd];
    let mut das = vec![0.0f32; fd];
    let mut dad = vec![0.0f32; fd];
    let mut scratch = vec![0.0f32; att_bwd_scratch_len(ns, src.len())];
    att_agg_bwd_into(
        fs, fdm, a_s, a_d, src, dst, valid, dout, ns, fd, &mut scratch, &mut dfs, &mut dfd,
        &mut das, &mut dad,
    )?;
    Ok((dfs, dfd, das, dad))
}

/// Semantic fusion forward, scalar oracle (serial over relations).
#[cfg(test)]
fn fuse_fwd(
    dst_type: &[i32],
    agg: &[f32],
    rp: usize,
    ns: usize,
    fd: usize,
    tp: usize,
    relu: bool,
) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; tp * ns * fd];
    for r in 0..rp {
        let t = idx(dst_type[r], tp, "dst_type")?;
        let srow = &agg[r * ns * fd..(r + 1) * ns * fd];
        let orow = &mut out[t * ns * fd..(t + 1) * ns * fd];
        for (o, v) in orow.iter_mut().zip(srow) {
            *o += *v;
        }
    }
    if relu {
        for v in out.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
    Ok(out)
}

/// Semantic fusion VJP, scalar oracle.
#[cfg(test)]
#[allow(clippy::too_many_arguments)]
fn fuse_bwd(
    dst_type: &[i32],
    agg: &[f32],
    dout: &[f32],
    rp: usize,
    ns: usize,
    fd: usize,
    tp: usize,
    relu: bool,
) -> Result<Vec<f32>> {
    let pre = if relu {
        Some(fuse_fwd(dst_type, agg, rp, ns, fd, tp, false)?)
    } else {
        None
    };
    let mut dagg = vec![0.0f32; rp * ns * fd];
    for r in 0..rp {
        let t = idx(dst_type[r], tp, "dst_type")?;
        let grow = &dout[t * ns * fd..(t + 1) * ns * fd];
        let drow = &mut dagg[r * ns * fd..(r + 1) * ns * fd];
        match &pre {
            Some(s) => {
                let srow = &s[t * ns * fd..(t + 1) * ns * fd];
                for k in 0..ns * fd {
                    drow[k] = if srow[k] > 0.0 { grow[k] } else { 0.0 };
                }
            }
            None => drow.copy_from_slice(grow),
        }
    }
    Ok(dagg)
}

/// Softmax cross-entropy head, scalar oracle.
#[cfg(test)]
fn head(logits: &[f32], labels: &[i32], mask: &[f32], ns: usize, c: usize) -> (f32, Vec<f32>, f32) {
    let mut z = vec![0.0f32; ns * c];
    let mut dlogits = vec![0.0f32; ns * c];
    let (loss, ncorrect) = head_into(logits, labels, mask, ns, c, &mut z, &mut dlogits);
    (loss, dlogits, ncorrect)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Central finite difference of `f` along coordinate `k` of `x`.
    fn fdiff(x: &mut [f32], k: usize, mut f: impl FnMut(&[f32]) -> f32) -> f32 {
        let eps = 1e-2f32;
        let x0 = x[k];
        x[k] = x0 + eps;
        let hi = f(x);
        x[k] = x0 - eps;
        let lo = f(x);
        x[k] = x0;
        (hi - lo) / (2.0 * eps)
    }

    fn close(a: f32, b: f32, tag: &str) {
        assert!((a - b).abs() < 2e-2 + 0.05 * b.abs(), "{tag}: analytic {a} vs fd {b}");
    }

    #[test]
    fn agg_mean_matches_hand_example() {
        // 2 valid edges into node 3: values 3 and 5 -> mean 4.
        let ns = 4;
        let fd = 2;
        let mut feat = vec![0.0f32; ns * fd];
        feat[0] = 3.0;
        feat[1] = 3.0;
        feat[2] = 5.0;
        feat[3] = 5.0;
        let src = vec![0, 1, 0];
        let dst = vec![3, 3, 0];
        let valid = vec![1.0, 1.0, 0.0];
        let out = agg_mean(&feat, &src, &dst, &valid, ns, fd).unwrap();
        assert_eq!(&out[3 * fd..4 * fd], &[4.0, 4.0]);
        assert!(out[..3 * fd].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn agg_mean_bwd_matches_finite_difference() {
        let mut rng = Rng::new(1);
        let (ns, fd) = (5, 3);
        let mut feat = randv(&mut rng, ns * fd);
        let src: Vec<i32> = vec![0, 1, 2, 3, 0, 2];
        let dst: Vec<i32> = vec![1, 1, 4, 0, 4, 1];
        let valid = vec![1.0, 1.0, 0.0, 1.0, 1.0, 1.0];
        let g = randv(&mut rng, ns * fd);
        let loss = |f: &[f32]| -> f32 {
            agg_mean(f, &src, &dst, &valid, ns, fd)
                .unwrap()
                .iter()
                .zip(&g)
                .map(|(o, gg)| o * gg)
                .sum()
        };
        let analytic = agg_mean_bwd(&src, &dst, &valid, &g, ns, fd).unwrap();
        for k in [0, 4, 7, ns * fd - 1] {
            let fd_ = fdiff(&mut feat, k, loss);
            close(analytic[k], fd_, &format!("agg_mean dfeat[{k}]"));
        }
    }

    #[test]
    fn att_agg_bwd_matches_finite_difference() {
        let mut rng = Rng::new(7);
        let (ns, fd) = (5, 3);
        let mut fs = randv(&mut rng, ns * fd);
        let mut fdm = randv(&mut rng, ns * fd);
        let mut a_s = randv(&mut rng, fd);
        let mut a_d = randv(&mut rng, fd);
        let src: Vec<i32> = vec![0, 1, 2, 3, 4, 1, 0];
        let dst: Vec<i32> = vec![1, 1, 1, 0, 0, 3, 2];
        let valid = vec![1.0, 1.0, 1.0, 1.0, 1.0, 0.0, 1.0];
        let g = randv(&mut rng, ns * fd);
        let (dfs, dfd, das, dad) =
            att_agg_bwd(&fs, &fdm, &a_s, &a_d, &src, &dst, &valid, &g, ns, fd).unwrap();
        macro_rules! loss_wrt {
            ($fs:expr, $fdm:expr, $as_:expr, $ad:expr) => {
                att_agg($fs, $fdm, $as_, $ad, &src, &dst, &valid, ns, fd)
                    .unwrap()
                    .iter()
                    .zip(&g)
                    .map(|(o, gg)| o * gg)
                    .sum::<f32>()
            };
        }
        for k in [0, 3, 8, ns * fd - 1] {
            let fdm2 = fdm.clone();
            let (a_s2, a_d2) = (a_s.clone(), a_d.clone());
            let fd_ = fdiff(&mut fs, k, |f| loss_wrt!(f, &fdm2, &a_s2, &a_d2));
            close(dfs[k], fd_, &format!("att dfs[{k}]"));
        }
        for k in [1, 6] {
            let fs2 = fs.clone();
            let (a_s2, a_d2) = (a_s.clone(), a_d.clone());
            let fd_ = fdiff(&mut fdm, k, |f| loss_wrt!(&fs2, f, &a_s2, &a_d2));
            close(dfd[k], fd_, &format!("att dfd[{k}]"));
        }
        for k in 0..fd {
            let (fs2, fdm2) = (fs.clone(), fdm.clone());
            let a_d2 = a_d.clone();
            let fd_ = fdiff(&mut a_s, k, |a| loss_wrt!(&fs2, &fdm2, a, &a_d2));
            close(das[k], fd_, &format!("att das[{k}]"));
            let (fs3, fdm3) = (fs.clone(), fdm.clone());
            let a_s3 = a_s.clone();
            let fd2_ = fdiff(&mut a_d, k, |a| loss_wrt!(&fs3, &fdm3, &a_s3, a));
            close(dad[k], fd2_, &format!("att dad[{k}]"));
        }
    }

    #[test]
    fn att_segments_without_valid_edges_are_zero_and_nan_free() {
        let (ns, fd) = (3, 2);
        let fs = vec![1.0f32; ns * fd];
        let fdm = vec![1.0f32; ns * fd];
        let a = vec![0.5f32; fd];
        let src = vec![0, 1];
        let dst = vec![0, 0];
        let valid = vec![0.0f32, 0.0];
        let out = att_agg(&fs, &fdm, &a, &a, &src, &dst, &valid, ns, fd).unwrap();
        assert!(out.iter().all(|v| *v == 0.0 && v.is_finite()));
        let g = vec![1.0f32; ns * fd];
        let (dfs, dfd, das, dad) =
            att_agg_bwd(&fs, &fdm, &a, &a, &src, &dst, &valid, &g, ns, fd).unwrap();
        for v in dfs.iter().chain(&dfd).chain(&das).chain(&dad) {
            assert_eq!(*v, 0.0);
        }
    }

    #[test]
    fn fuse_bwd_matches_finite_difference() {
        let mut rng = Rng::new(3);
        let (rp, ns, fd, tp) = (4, 3, 2, 3);
        let dst_type = vec![0i32, 2, 0, 1];
        let mut agg = randv(&mut rng, rp * ns * fd);
        let g = randv(&mut rng, tp * ns * fd);
        for relu in [false, true] {
            let analytic = fuse_bwd(&dst_type, &agg, &g, rp, ns, fd, tp, relu).unwrap();
            let loss = |a: &[f32]| -> f32 {
                fuse_fwd(&dst_type, a, rp, ns, fd, tp, relu)
                    .unwrap()
                    .iter()
                    .zip(&g)
                    .map(|(o, gg)| o * gg)
                    .sum()
            };
            for k in [0, 5, rp * ns * fd - 1] {
                let fd_ = fdiff(&mut agg, k, loss);
                close(analytic[k], fd_, &format!("fuse relu={relu} dagg[{k}]"));
            }
        }
    }

    #[test]
    fn head_gradient_matches_finite_difference_and_counts_accuracy() {
        let mut rng = Rng::new(9);
        let (ns, c) = (6, 4);
        let mut logits = randv(&mut rng, ns * c);
        let labels: Vec<i32> = (0..ns).map(|i| (i % c) as i32).collect();
        let mask = vec![1.0, 1.0, 0.0, 1.0, 1.0, 0.0];
        let (_, dlogits, ncorrect) = head(&logits, &labels, &mask, ns, c);
        for k in [0, 7, 13, ns * c - 1] {
            let fd_ = fdiff(&mut logits, k, |l| head(l, &labels, &mask, ns, c).0);
            close(dlogits[k], fd_, &format!("head dlogits[{k}]"));
        }
        // Accuracy: perfect logits count every masked row.
        let mut perfect = vec![0.0f32; ns * c];
        for i in 0..ns {
            perfect[i * c + labels[i] as usize] = 10.0;
        }
        let (loss, _, nc) = head(&perfect, &labels, &mask, ns, c);
        assert_eq!(nc, 4.0);
        assert!(loss < 0.01, "confident loss {loss}");
    }

    #[test]
    fn proj_bwd_matches_finite_difference() {
        let mut rng = Rng::new(5);
        let (m, k, n) = (3, 4, 2);
        let mut x = randv(&mut rng, m * k);
        let w = randv(&mut rng, k * n);
        let g = randv(&mut rng, m * n);
        let dx = matmul_nt(&g, &w, m, n, k);
        let dw = matmul_tn(&x, &g, m, k, n);
        for kk in [0, m * k - 1] {
            let fd_ = fdiff(&mut x, kk, |xx| {
                matmul(xx, &w, m, k, n).iter().zip(&g).map(|(o, gg)| o * gg).sum()
            });
            close(dx[kk], fd_, &format!("proj dx[{kk}]"));
        }
        // dw via the identity dw = xT g exactly.
        let mut dw_ref = vec![0.0f32; k * n];
        for s in 0..m {
            for i in 0..k {
                for j in 0..n {
                    dw_ref[i * n + j] += x[s * k + i] * g[s * n + j];
                }
            }
        }
        for (a, b) in dw.iter().zip(&dw_ref) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn backend_runs_builtin_modules_end_to_end() {
        let eng = SimBackend::builtin("tiny").unwrap();
        let (ns, f, h) = (eng.cst("NS"), eng.cst("F"), eng.cst("H"));
        let x = HostTensor::zeros_f32(&[ns, f]);
        let w = HostTensor::zeros_f32(&[f, h]);
        let out = eng.run("proj_fwd_l0", Stage::Calib, Phase::Fwd, &[&x, &w]).unwrap();
        assert_eq!(out[0].shape(), &[ns, h]);
        // Calib dispatches stay out of the counters.
        assert_eq!(eng.counters().borrow().total(), 0);
        let out = eng.run("proj_fwd_l0", Stage::Projection, Phase::Fwd, &[&x, &w]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(eng.counters().borrow().total(), 1);
    }

    #[test]
    fn run_dev_keeps_results_chainable_without_transfer() {
        let eng = SimBackend::builtin("tiny").unwrap();
        eng.reset_counters(true);
        let (rp, ns, h) = (eng.cst("RPAD"), eng.cst("NS"), eng.cst("H"));
        let dt = HostTensor::i32(vec![0; rp], &[rp]);
        let feat = HostTensor::zeros_f32(&[rp, ns, h]);
        let src = HostTensor::i32(vec![0; rp * eng.cst("EP")], &[rp, eng.cst("EP")]);
        let valid = HostTensor::f32(vec![0.0; rp * eng.cst("EP")], &[rp, eng.cst("EP")]);
        let dev = eng
            .run_dev(
                "agg_merged_fwd_h",
                Stage::Aggregation,
                Phase::Fwd,
                &[Arg::Host(&feat), Arg::Host(&src), Arg::Host(&src), Arg::Host(&valid)],
            )
            .unwrap();
        assert_eq!(dev.shape(), &[rp, ns, h]);
        eng.run_dev(
            "fuse_relu_fwd_h",
            Stage::Fusion,
            Phase::Fwd,
            &[Arg::Host(&dt), Arg::Dev(&dev)],
        )
        .unwrap();
        let c = eng.counters().borrow();
        assert_eq!(c.total(), 2);
        // The device-resident arg contributed zero transfer bytes: only the
        // dst_type vector was "uploaded" for the fusion dispatch.
        assert_eq!(c.events[1].bytes_in, rp * 4);
    }

    #[test]
    fn simulated_launch_overhead_slows_dispatches() {
        let mut eng = SimBackend::builtin("tiny").unwrap();
        let base = eng.measure_dispatch_overhead(5).unwrap();
        eng.set_launch_overhead(Duration::from_micros(500));
        let slow = eng.measure_dispatch_overhead(5).unwrap();
        assert!(slow > base + Duration::from_micros(300), "{base:?} -> {slow:?}");
    }

    fn randi(rng: &mut Rng, n: usize, below: usize) -> Vec<i32> {
        (0..n).map(|_| rng.below(below) as i32).collect()
    }

    /// Blocked + row-parallel matmuls are bit-identical to the scalar
    /// oracles on shapes that are NOT multiples of the tile / chunk sizes.
    #[test]
    fn blocked_matmuls_match_scalar_oracle_on_odd_shapes() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 31, 13), (65, 8, 66), (70, 3, 129)] {
            let mut a = randv(&mut rng, m * k);
            for i in (0..a.len()).step_by(3) {
                a[i] = 0.0; // exercise the zero-skip path
            }
            let bkn = randv(&mut rng, k * n);
            let bmn = randv(&mut rng, m * n);
            let amn = randv(&mut rng, m * n);
            for threads in [1, 3] {
                let pool = WorkerPool::new(threads);
                let mut out = vec![0.0f32; m * n];
                matmul_into(&pool, &a, &bkn, m, k, n, &mut out);
                assert_eq!(out, matmul(&a, &bkn, m, k, n), "nn {m}x{k}x{n} t{threads}");
                let mut out = vec![0.0f32; k * n];
                matmul_tn_into(&pool, &a, &bmn, m, k, n, &mut out);
                assert_eq!(out, matmul_tn(&a, &bmn, m, k, n), "tn {m}x{k}x{n} t{threads}");
                let mut out = vec![0.0f32; m * k];
                matmul_nt_into(&pool, &amn, &bkn, m, n, k, &mut out);
                assert_eq!(out, matmul_nt(&amn, &bkn, m, n, k), "nt {m}x{k}x{n} t{threads}");
            }
        }
    }

    /// Relation-parallel merged mean aggregation (fwd + VJP) equals the
    /// per-relation scalar oracle bit-for-bit on a threaded backend.
    #[test]
    fn merged_aggregation_matches_per_relation_oracle_under_threading() {
        let mut rng = Rng::new(31);
        let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
        let (rp, ns, ep, h) =
            (eng.cst("RPAD"), eng.cst("NS"), eng.cst("EP"), eng.cst("H"));
        let feat = HostTensor::f32(randv(&mut rng, rp * ns * h), &[rp, ns, h]);
        let src = HostTensor::i32(randi(&mut rng, rp * ep, ns), &[rp, ep]);
        let dst = HostTensor::i32(randi(&mut rng, rp * ep, ns), &[rp, ep]);
        let valid =
            HostTensor::f32((0..rp * ep).map(|_| rng.below(2) as f32).collect(), &[rp, ep]);
        let (f, s, d, v) = (
            feat.as_f32().unwrap(),
            src.as_i32().unwrap(),
            dst.as_i32().unwrap(),
            valid.as_f32().unwrap(),
        );
        let out = eng
            .run("agg_merged_fwd_h", Stage::Calib, Phase::Fwd, &[&feat, &src, &dst, &valid])
            .unwrap();
        let of = out[0].as_f32().unwrap();
        for r in 0..rp {
            let y = agg_mean(
                &f[r * ns * h..(r + 1) * ns * h],
                &s[r * ep..(r + 1) * ep],
                &d[r * ep..(r + 1) * ep],
                &v[r * ep..(r + 1) * ep],
                ns,
                h,
            )
            .unwrap();
            assert_eq!(&of[r * ns * h..(r + 1) * ns * h], &y[..], "agg fwd r={r}");
        }
        let dout = HostTensor::f32(randv(&mut rng, rp * ns * h), &[rp, ns, h]);
        let dof = dout.as_f32().unwrap();
        let out = eng
            .run("agg_merged_bwd_h", Stage::Calib, Phase::Bwd, &[&src, &dst, &valid, &dout])
            .unwrap();
        let ob = out[0].as_f32().unwrap();
        for r in 0..rp {
            let y = agg_mean_bwd(
                &s[r * ep..(r + 1) * ep],
                &d[r * ep..(r + 1) * ep],
                &v[r * ep..(r + 1) * ep],
                &dof[r * ns * h..(r + 1) * ns * h],
                ns,
                h,
            )
            .unwrap();
            assert_eq!(&ob[r * ns * h..(r + 1) * ns * h], &y[..], "agg bwd r={r}");
        }
    }

    /// Relation-parallel merged attention (fwd + 4-output VJP, packed rows)
    /// equals the per-relation scalar oracle bit-for-bit when threaded.
    #[test]
    fn merged_attention_matches_per_relation_oracle_under_threading() {
        let mut rng = Rng::new(37);
        let eng = SimBackend::builtin_threaded("tiny", 4).unwrap();
        let (rp, ns, ep, h) =
            (eng.cst("RPAD"), eng.cst("NS"), eng.cst("EP"), eng.cst("H"));
        let fs = HostTensor::f32(randv(&mut rng, rp * ns * h), &[rp, ns, h]);
        let fdm = HostTensor::f32(randv(&mut rng, rp * ns * h), &[rp, ns, h]);
        let a_s = HostTensor::f32(randv(&mut rng, rp * h), &[rp, h]);
        let a_d = HostTensor::f32(randv(&mut rng, rp * h), &[rp, h]);
        let src = HostTensor::i32(randi(&mut rng, rp * ep, ns), &[rp, ep]);
        let dst = HostTensor::i32(randi(&mut rng, rp * ep, ns), &[rp, ep]);
        let valid =
            HostTensor::f32((0..rp * ep).map(|_| rng.below(2) as f32).collect(), &[rp, ep]);
        let dout = HostTensor::f32(randv(&mut rng, rp * ns * h), &[rp, ns, h]);
        let args = [&fs, &fdm, &a_s, &a_d, &src, &dst, &valid];
        let out = eng.run("att_merged_fwd_h", Stage::Calib, Phase::Fwd, &args).unwrap();
        let of = out[0].as_f32().unwrap();
        let bwd_args = [&fs, &fdm, &a_s, &a_d, &src, &dst, &valid, &dout];
        let bout = eng.run("att_merged_bwd_h", Stage::Calib, Phase::Bwd, &bwd_args).unwrap();
        for r in 0..rp {
            let nf = r * ns * h..(r + 1) * ns * h;
            let fr = r * h..(r + 1) * h;
            let er = r * ep..(r + 1) * ep;
            let y = att_agg(
                &fs.as_f32().unwrap()[nf.clone()],
                &fdm.as_f32().unwrap()[nf.clone()],
                &a_s.as_f32().unwrap()[fr.clone()],
                &a_d.as_f32().unwrap()[fr.clone()],
                &src.as_i32().unwrap()[er.clone()],
                &dst.as_i32().unwrap()[er.clone()],
                &valid.as_f32().unwrap()[er.clone()],
                ns,
                h,
            )
            .unwrap();
            assert_eq!(&of[nf.clone()], &y[..], "att fwd r={r}");
            let (dfs, dfd, das, dad) = att_agg_bwd(
                &fs.as_f32().unwrap()[nf.clone()],
                &fdm.as_f32().unwrap()[nf.clone()],
                &a_s.as_f32().unwrap()[fr.clone()],
                &a_d.as_f32().unwrap()[fr.clone()],
                &src.as_i32().unwrap()[er.clone()],
                &dst.as_i32().unwrap()[er.clone()],
                &valid.as_f32().unwrap()[er.clone()],
                &dout.as_f32().unwrap()[nf.clone()],
                ns,
                h,
            )
            .unwrap();
            assert_eq!(&bout[0].as_f32().unwrap()[nf.clone()], &dfs[..], "dfs r={r}");
            assert_eq!(&bout[1].as_f32().unwrap()[nf.clone()], &dfd[..], "dfd r={r}");
            assert_eq!(&bout[2].as_f32().unwrap()[fr.clone()], &das[..], "das r={r}");
            assert_eq!(&bout[3].as_f32().unwrap()[fr.clone()], &dad[..], "dad r={r}");
        }
    }

    /// Stacked projection (fwd + bwd with its serial dx fold) and the
    /// type-parallel fusion kernels equal the scalar oracles bit-for-bit.
    #[test]
    fn stacked_projection_and_fusion_match_oracles_under_threading() {
        let mut rng = Rng::new(47);
        let eng = SimBackend::builtin_threaded("tiny", 3).unwrap();
        let (tp, ns, f, h, rp) = (
            eng.cst("TPAD"),
            eng.cst("NS"),
            eng.cst("F"),
            eng.cst("H"),
            eng.cst("RPAD"),
        );
        let xs = HostTensor::f32(randv(&mut rng, tp * ns * f), &[tp, ns, f]);
        let w = HostTensor::f32(randv(&mut rng, rp * f * h), &[rp, f, h]);
        let st = HostTensor::i32(randi(&mut rng, rp, tp), &[rp]);
        let (xsf, wf, stf) =
            (xs.as_f32().unwrap(), w.as_f32().unwrap(), st.as_i32().unwrap());
        let out = eng
            .run("proj_stacked_fwd_l0", Stage::Calib, Phase::Fwd, &[&xs, &w, &st])
            .unwrap();
        let of = out[0].as_f32().unwrap();
        for r in 0..rp {
            let t = stf[r] as usize;
            let y = matmul(
                &xsf[t * ns * f..(t + 1) * ns * f],
                &wf[r * f * h..(r + 1) * f * h],
                ns,
                f,
                h,
            );
            assert_eq!(&of[r * ns * h..(r + 1) * ns * h], &y[..], "stacked fwd r={r}");
        }
        let dy = HostTensor::f32(randv(&mut rng, rp * ns * h), &[rp, ns, h]);
        let dyf = dy.as_f32().unwrap();
        let mut outs = eng
            .run("proj_stacked_bwd_l0", Stage::Calib, Phase::Bwd, &[&xs, &w, &st, &dy])
            .unwrap()
            .into_iter();
        let dxs = outs.next().unwrap();
        let dw = outs.next().unwrap();
        let mut dxs_o = vec![0.0f32; tp * ns * f];
        let mut dw_o = vec![0.0f32; rp * f * h];
        for r in 0..rp {
            let t = stf[r] as usize;
            let dy_r = &dyf[r * ns * h..(r + 1) * ns * h];
            let dx = matmul_nt(dy_r, &wf[r * f * h..(r + 1) * f * h], ns, h, f);
            for (acc, v) in dxs_o[t * ns * f..(t + 1) * ns * f].iter_mut().zip(&dx) {
                *acc += *v;
            }
            let g = matmul_tn(&xsf[t * ns * f..(t + 1) * ns * f], dy_r, ns, f, h);
            dw_o[r * f * h..(r + 1) * f * h].copy_from_slice(&g);
        }
        assert_eq!(dxs.as_f32().unwrap(), &dxs_o[..], "stacked bwd dxs");
        assert_eq!(dw.as_f32().unwrap(), &dw_o[..], "stacked bwd dw");

        // Fusion fwd + bwd against the independent serial oracles.
        let dt = HostTensor::i32(randi(&mut rng, rp, tp), &[rp]);
        let agg_t = HostTensor::f32(randv(&mut rng, rp * ns * h), &[rp, ns, h]);
        let dtf = dt.as_i32().unwrap();
        let aggf = agg_t.as_f32().unwrap();
        let out = eng.run("fuse_relu_fwd_h", Stage::Calib, Phase::Fwd, &[&dt, &agg_t]).unwrap();
        assert_eq!(
            out[0].as_f32().unwrap(),
            &fuse_fwd(dtf, aggf, rp, ns, h, tp, true).unwrap()[..],
            "fuse fwd"
        );
        let dout = HostTensor::f32(randv(&mut rng, tp * ns * h), &[tp, ns, h]);
        let doutf = dout.as_f32().unwrap();
        let out = eng
            .run("fuse_relu_bwd_h", Stage::Calib, Phase::Bwd, &[&dt, &agg_t, &dout])
            .unwrap();
        assert_eq!(
            out[0].as_f32().unwrap(),
            &fuse_bwd(dtf, aggf, doutf, rp, ns, h, tp, true).unwrap()[..],
            "fuse bwd"
        );
    }

    /// The on-device gather assembles exactly the slab a CPU gather would:
    /// cache rows where idx >= 0, miss rows where idx <= -2, zeros at -1 —
    /// bit-identical serial vs threaded.
    #[test]
    fn feature_gather_assembles_cache_miss_and_padding_rows() {
        let mut rng = Rng::new(23);
        for threads in [1usize, 4] {
            let eng = SimBackend::builtin_threaded("tiny", threads).unwrap();
            let (cs, tp, ns, f) =
                (eng.cst("CSLOTS"), eng.cst("TPAD"), eng.cst("NS"), eng.cst("F"));
            let cache = HostTensor::f32(randv(&mut rng, cs * f), &[cs, f]);
            let miss = HostTensor::f32(randv(&mut rng, tp * ns * f), &[tp * ns, f]);
            // Mix of cache slots, miss rows and padding across the slab.
            let mut ix = vec![-1i32; tp * ns];
            for (s, v) in ix.iter_mut().enumerate() {
                *v = match s % 3 {
                    0 => (s % cs) as i32,
                    1 => -2 - ((s % (tp * ns)) as i32),
                    _ => -1,
                };
            }
            let idx_t = HostTensor::i32(ix.clone(), &[tp, ns]);
            let out = eng
                .run("feature_gather", Stage::Calib, Phase::Fwd, &[&cache, &miss, &idx_t])
                .unwrap();
            assert_eq!(out[0].shape(), &[tp, ns, f]);
            let of = out[0].as_f32().unwrap();
            let (cf, mf) = (cache.as_f32().unwrap(), miss.as_f32().unwrap());
            for (s, &v) in ix.iter().enumerate() {
                let got = &of[s * f..(s + 1) * f];
                if v >= 0 {
                    assert_eq!(got, &cf[v as usize * f..(v as usize + 1) * f], "slot {s}");
                } else if v <= -2 {
                    let m = (-v - 2) as usize;
                    assert_eq!(got, &mf[m * f..(m + 1) * f], "slot {s}");
                } else {
                    assert!(got.iter().all(|&x| x == 0.0), "padding slot {s} not zero");
                }
            }
        }
    }

    #[test]
    fn feature_gather_rejects_out_of_range_indices() {
        let eng = SimBackend::builtin("tiny").unwrap();
        let (cs, tp, ns, f) = (eng.cst("CSLOTS"), eng.cst("TPAD"), eng.cst("NS"), eng.cst("F"));
        let cache = HostTensor::zeros_f32(&[cs, f]);
        let miss = HostTensor::zeros_f32(&[tp * ns, f]);
        let mut ix = vec![-1i32; tp * ns];
        ix[0] = cs as i32; // one past the resident store
        let idx_t = HostTensor::i32(ix, &[tp, ns]);
        assert!(eng
            .run("feature_gather", Stage::Calib, Phase::Fwd, &[&cache, &miss, &idx_t])
            .is_err());
    }

    /// `upload` transfers (and counts) only the valid prefix; the tail of
    /// the full-shape device buffer is deterministically zero.
    #[test]
    fn upload_counts_partial_bytes_and_zero_fills_the_tail() {
        let eng = SimBackend::builtin("tiny").unwrap();
        eng.reset_counters(false);
        let t = HostTensor::f32(vec![7.0; 100], &[100]);
        let dev = eng.upload(&t, 30).unwrap();
        assert_eq!(eng.counters().borrow().h2d_bytes, 30 * 4);
        assert_eq!(dev.shape(), &[100]);
        let h = dev.into_host().unwrap();
        let d = h.as_f32().unwrap();
        assert!(d[..30].iter().all(|&x| x == 7.0));
        assert!(d[30..].iter().all(|&x| x == 0.0));
        eng.recycle(h);
    }

    /// Recycled dispatch outputs are reused: after the first dispatch of a
    /// module, re-running it allocates nothing new.
    #[test]
    fn arena_recycles_dispatch_buffers_to_zero_steady_state_misses() {
        let eng = SimBackend::builtin("tiny").unwrap();
        let (ns, f, h) = (eng.cst("NS"), eng.cst("F"), eng.cst("H"));
        let x = HostTensor::zeros_f32(&[ns, f]);
        let w = HostTensor::zeros_f32(&[f, h]);
        let mut outs = eng.run("proj_fwd_l0", Stage::Calib, Phase::Fwd, &[&x, &w]).unwrap();
        let warm_misses = eng.arena_stats().misses;
        assert!(warm_misses >= 1, "first dispatch should allocate");
        eng.recycle(outs.swap_remove(0));
        let _outs = eng.run("proj_fwd_l0", Stage::Calib, Phase::Fwd, &[&x, &w]).unwrap();
        let s = eng.arena_stats();
        assert_eq!(s.misses, warm_misses, "steady-state dispatch allocated: {s:?}");
        assert!(s.hits >= 1);
        assert!(s.bytes_recycled > 0);
    }
}
