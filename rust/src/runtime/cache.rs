//! Device-resident vertex-feature cache (DESIGN.md §7).
//!
//! Mini-batch HGNN training re-gathers and re-uploads the same hot vertex
//! rows batch after batch — HiHGNN and the GPU characterization study both
//! identify this cross-batch reuse as the largest untapped locality source.
//! The cache exploits it: at dataset load, a **deterministic presampling
//! pass** ranks every type's vertices by how often sampling can touch them
//! (their appearance count in the relation adjacency lists, plus train-seed
//! membership for the target type), and pins the top `--cache-frac` of each
//! type into one packed `[CSLOTS, F]` resident slab that is uploaded to the
//! device **once**. Per batch, only the rows *not* resident (the misses) are
//! gathered on the CPU and uploaded; the `feature_gather` module then
//! assembles the fused `[TPAD, NS, F]` batch slab on-device from
//! {resident slab, miss upload, scatter indices}. In `--mode resident`
//! the gather output is never read back: it stays a `DevBuf` and feeds the
//! projection directly (`assemble_batch_dev`), so per-batch H2D traffic is
//! just the scatter indices + miss rows (+ batch metadata) and the slab
//! never crosses PCIe in either direction (`tests/residency.rs`).
//!
//! Bit-exactness contract: cached rows are byte-copies of the same f32 data
//! the CPU collector would read, so for **any** `--cache-frac` the training
//! trajectory is bitwise identical to cache-off (`tests/cache_parity.rs`).
//! The store itself is immutable after construction and shared read-only —
//! one `Arc<ResidentStore>` serves every producer and every replica lane,
//! while each backend keeps its own uploaded [`CacheHandle`].

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::graph::HeteroGraph;
use crate::util::HostTensor;

use super::{DevBuf, ExecBackend};

/// The backend-agnostic half of the cache: packed hot-vertex rows plus the
/// dense vertex→cache-slot index. Immutable and `Sync`; shared via `Arc`.
pub struct ResidentStore {
    /// Packed cached feature rows, `[CSLOTS, F]`, zero-padded past
    /// `rows_cached`.
    rows: Vec<f32>,
    cslots: usize,
    f: usize,
    /// Per type: type-local vertex id → global cache slot, `-1` = absent.
    slot_of: Vec<Vec<i32>>,
    /// Rows cached per type (presampling outcome, for reporting).
    per_type: Vec<usize>,
    /// The budget fraction the store was built with.
    pub frac: f64,
}

impl ResidentStore {
    /// Deterministic presampling pass: per type, rank vertices by hotness
    /// (adjacency appearance count; `+1` train-seed bonus on the target
    /// type), break ties by a seeded hash then vertex id, and cache the top
    /// `ceil(frac · n_t)` of each type — scaled down proportionally if the
    /// summed budget exceeds the profile's `CSLOTS` capacity. The result is
    /// a pure function of `(graph, frac, cslots, seed)`.
    pub fn build(g: &HeteroGraph, frac: f64, cslots: usize, seed: u64) -> ResidentStore {
        assert!((0.0..=1.0).contains(&frac), "cache frac {frac} outside [0, 1]");
        let f = g.feat_dim;
        let n_types = g.n_types();

        // Hotness: how often a vertex appears as a sampleable source.
        let mut heat: Vec<Vec<u64>> = g.num_nodes.iter().map(|&n| vec![0u64; n]).collect();
        for rel in &g.relations {
            for &s in &rel.src_ids {
                heat[rel.src_type][s as usize] += 1;
            }
        }
        for &v in &g.train_idx {
            heat[g.target_type][v as usize] += 1; // seeds are touched every epoch
        }

        // Per-type budgets (ceil(0) = 0, so frac 0.0 caches nothing),
        // proportionally clamped to the CSLOTS capacity.
        let mut caps: Vec<usize> = g
            .num_nodes
            .iter()
            .map(|&n| ((frac * n as f64).ceil() as usize).min(n))
            .collect();
        let want: usize = caps.iter().sum();
        if want > cslots {
            let scale = cslots as f64 / want as f64;
            for c in caps.iter_mut() {
                *c = (*c as f64 * scale).floor() as usize;
            }
        }

        let mut slot_of: Vec<Vec<i32>> = g.num_nodes.iter().map(|&n| vec![-1i32; n]).collect();
        let mut rows = vec![0.0f32; cslots * f];
        let mut per_type = vec![0usize; n_types];
        let mut next_slot = 0usize;
        for t in 0..n_types {
            let mut order: Vec<u32> = (0..g.num_nodes[t] as u32).collect();
            // Rank: hotness desc, seeded-hash tiebreak, vertex id — fully
            // deterministic in (graph, seed).
            order.sort_unstable_by_key(|&v| {
                (std::cmp::Reverse(heat[t][v as usize]), tie_hash(seed, t, v), v)
            });
            for &v in order.iter().take(caps[t]) {
                if next_slot >= cslots {
                    break;
                }
                slot_of[t][v as usize] = next_slot as i32;
                g.features
                    .copy_row(t, v as usize, &mut rows[next_slot * f..(next_slot + 1) * f]);
                per_type[t] += 1;
                next_slot += 1;
            }
        }

        ResidentStore { rows, cslots, f, slot_of, per_type, frac }
    }

    /// Cache slot of `(type, vertex)`, or `-1` when not resident.
    #[inline]
    pub fn slot(&self, t: usize, v: usize) -> i32 {
        self.slot_of[t][v]
    }

    /// Total rows pinned on the device.
    pub fn rows_cached(&self) -> usize {
        self.per_type.iter().sum()
    }

    /// Rows pinned per type.
    pub fn per_type(&self) -> &[usize] {
        &self.per_type
    }

    /// Resident-store capacity (the profile's `CSLOTS`).
    pub fn cslots(&self) -> usize {
        self.cslots
    }

    /// Feature dim the rows were packed with.
    pub fn feat_dim(&self) -> usize {
        self.f
    }

    /// One cached row (tests / debugging).
    pub fn row(&self, slot: usize) -> &[f32] {
        &self.rows[slot * self.f..(slot + 1) * self.f]
    }

    /// The packed `[CSLOTS, F]` slab as a host tensor (upload staging).
    fn as_tensor(&self) -> HostTensor {
        HostTensor::f32(self.rows.clone(), &[self.cslots, self.f])
    }
}

/// A backend's handle on the shared store: the `Arc`'d row index plus this
/// backend's own device-resident upload of the packed slab. Replica lanes
/// each hold one handle over the **same** store (DESIGN.md §7).
pub struct CacheHandle<B: ExecBackend> {
    pub store: Arc<ResidentStore>,
    /// The `[CSLOTS, F]` resident slab on this backend's device.
    pub dev: B::Dev,
}

impl<B: ExecBackend> CacheHandle<B> {
    /// Upload the packed slab to `eng` (a one-time H2D transfer of the full
    /// occupied prefix — amortized over every subsequent batch), after
    /// checking the store against the backend's profile constants.
    pub fn upload(eng: &B, store: Arc<ResidentStore>) -> Result<CacheHandle<B>> {
        ensure!(
            store.cslots == eng.cst("CSLOTS"),
            "resident store capacity {} != profile CSLOTS {}",
            store.cslots,
            eng.cst("CSLOTS")
        );
        ensure!(
            store.f == eng.cst("F"),
            "resident store feature dim {} != profile F {}",
            store.f,
            eng.cst("F")
        );
        let staged = store.as_tensor();
        let dev = eng.upload(&staged, store.rows_cached() * store.f)?;
        Ok(CacheHandle { store, dev })
    }

    /// `--audit-every` slab audit (DESIGN.md §11): FNV-1a digest of the
    /// device copy's occupied prefix against the immutable host store. On a
    /// mismatch the slab is re-staged from the store (one fresh H2D upload
    /// replaces the corrupted device copy) and `Ok(false)` is returned so
    /// the caller can count the violation; a clean slab returns `Ok(true)`.
    /// Modeled as a device-side digest kernel: the readback is not charged
    /// to the D2H channel, matching the residency contract.
    pub fn verify_or_restage(&mut self, eng: &B) -> Result<bool> {
        let occupied = self.store.rows_cached() * self.store.f;
        let host = self.dev.to_host()?;
        let dev_rows = host.as_f32()?;
        let expect = crate::util::fnv1a_f32(&self.store.rows[..occupied]);
        if crate::util::fnv1a_f32(&dev_rows[..occupied]) == expect {
            return Ok(true);
        }
        let staged = self.store.as_tensor();
        self.dev = eng.upload(&staged, occupied)?;
        Ok(false)
    }
}

/// SplitMix64 of `(seed, type, vertex)` — the seeded tiebreak of the
/// presampling rank.
fn tie_hash(seed: u64, t: usize, v: u32) -> u64 {
    let mut z = seed
        .wrapping_add((t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add((v as u64 + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny_graph;
    use crate::graph::Layout;

    #[test]
    fn build_is_deterministic_in_graph_frac_seed() {
        let g = tiny_graph(7);
        let a = ResidentStore::build(&g, 0.25, 160, 42);
        let b = ResidentStore::build(&g, 0.25, 160, 42);
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.slot_of, b.slot_of);
        let c = ResidentStore::build(&g, 0.25, 160, 43);
        // A different seed may reorder ties but never the budget.
        assert_eq!(a.rows_cached(), c.rows_cached());
    }

    #[test]
    fn budget_respects_frac_and_cslots() {
        let g = tiny_graph(3);
        let none = ResidentStore::build(&g, 0.0, 160, 1);
        assert_eq!(none.rows_cached(), 0);
        let quarter = ResidentStore::build(&g, 0.25, 160, 1);
        assert!(quarter.rows_cached() > 0);
        assert!(quarter.rows_cached() < g.total_nodes());
        for (t, &n) in g.num_nodes.iter().enumerate() {
            assert!(quarter.per_type()[t] <= (0.25 * n as f64).ceil() as usize);
        }
        let full = ResidentStore::build(&g, 1.0, 160, 1);
        assert_eq!(full.rows_cached(), g.total_nodes(), "CSLOTS=160 covers tiny");
        // A capacity below the budget clamps proportionally.
        let clamped = ResidentStore::build(&g, 1.0, 64, 1);
        assert!(clamped.rows_cached() <= 64);
        assert!(clamped.rows_cached() > 0);
    }

    #[test]
    fn cached_rows_are_byte_copies_of_the_feature_store() {
        let mut g = tiny_graph(5);
        let store = ResidentStore::build(&g, 0.5, 160, 9);
        let mut row = vec![0.0f32; g.feat_dim];
        let mut seen = 0usize;
        for t in 0..g.n_types() {
            for v in 0..g.num_nodes[t] {
                let s = store.slot(t, v);
                if s < 0 {
                    continue;
                }
                g.features.copy_row(t, v, &mut row);
                assert_eq!(store.row(s as usize), &row[..], "({t},{v})");
                seen += 1;
            }
        }
        assert_eq!(seen, store.rows_cached());
        // The store outlives layout changes: rows were copied at build.
        g.features.ensure_layout(Layout::IndexMajor);
        for t in 0..g.n_types() {
            for v in 0..g.num_nodes[t] {
                let s = store.slot(t, v);
                if s >= 0 {
                    g.features.copy_row(t, v, &mut row);
                    assert_eq!(store.row(s as usize), &row[..]);
                }
            }
        }
    }

    #[test]
    fn verify_or_restage_detects_and_repairs_slab_corruption() {
        use crate::runtime::{SimBackend, SimDev};
        let g = tiny_graph(5);
        let eng = SimBackend::builtin("tiny").unwrap();
        let store = Arc::new(ResidentStore::build(&g, 0.25, eng.cst("CSLOTS"), 42));
        assert!(store.rows_cached() > 0);
        let mut handle = CacheHandle::upload(&eng, store.clone()).unwrap();
        assert!(handle.verify_or_restage(&eng).unwrap(), "fresh slab must verify clean");
        // Corrupt one mantissa bit of the device copy, as a wire fault
        // landing after the one-time staging upload would.
        let mut slab = handle.dev.to_host().unwrap().as_f32().unwrap().to_vec();
        slab[3] = f32::from_bits(slab[3].to_bits() ^ 1);
        let shape = [store.cslots(), store.feat_dim()];
        handle.dev = SimDev(HostTensor::f32(slab, &shape));
        assert!(!handle.verify_or_restage(&eng).unwrap(), "flipped bit must be caught");
        // The restage replaced the device copy with clean store bytes.
        assert!(handle.verify_or_restage(&eng).unwrap());
        let repaired = handle.dev.to_host().unwrap();
        let occupied = store.rows_cached() * store.feat_dim();
        assert_eq!(&repaired.as_f32().unwrap()[..occupied], &store.rows[..occupied]);
    }

    #[test]
    fn presampling_prefers_hot_vertices() {
        let g = tiny_graph(11);
        let store = ResidentStore::build(&g, 0.25, 160, 1);
        // Within each type, the coldest cached vertex must be at least as
        // hot as the hottest uncached one (degree-ranked contract).
        let mut heat: Vec<Vec<u64>> = g.num_nodes.iter().map(|&n| vec![0u64; n]).collect();
        for rel in &g.relations {
            for &s in &rel.src_ids {
                heat[rel.src_type][s as usize] += 1;
            }
        }
        for &v in &g.train_idx {
            heat[g.target_type][v as usize] += 1;
        }
        for t in 0..g.n_types() {
            let cached_min = (0..g.num_nodes[t])
                .filter(|&v| store.slot(t, v) >= 0)
                .map(|v| heat[t][v])
                .min();
            let uncached_max = (0..g.num_nodes[t])
                .filter(|&v| store.slot(t, v) < 0)
                .map(|v| heat[t][v])
                .max();
            if let (Some(lo), Some(hi)) = (cached_min, uncached_max) {
                assert!(lo >= hi, "type {t}: cached heat {lo} < uncached heat {hi}");
            }
        }
    }

    #[test]
    fn slots_are_unique_and_in_range() {
        let g = tiny_graph(2);
        let store = ResidentStore::build(&g, 1.0, 160, 0);
        let mut seen = std::collections::HashSet::new();
        for t in 0..g.n_types() {
            for v in 0..g.num_nodes[t] {
                let s = store.slot(t, v);
                if s >= 0 {
                    assert!((s as usize) < store.cslots());
                    assert!(seen.insert(s), "slot {s} assigned twice");
                }
            }
        }
        assert_eq!(seen.len(), store.rows_cached());
    }
}
