//! Artifact manifest parser.
//!
//! `python/compile/aot.py` writes a line-based manifest (no serde offline)
//! describing every AOT module: argument/return names, dtypes, shapes, and
//! the profile constants (NS, EP, RPAD, ...). The runtime type-checks every
//! dispatch against this, so a profile/artifact mismatch fails loudly at
//! the call site instead of inside XLA.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unknown dtype {s:?}"),
        }
    }
}

/// One declared tensor (argument or return) of a module.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    /// Empty = scalar (rank 0).
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT module: its interface and HLO file.
#[derive(Clone, Debug)]
pub struct ModuleSpec {
    pub name: String,
    pub args: Vec<TensorSpec>,
    pub rets: Vec<TensorSpec>,
    pub file: PathBuf,
}

/// A parsed profile manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub profile: String,
    pub consts: BTreeMap<String, usize>,
    pub modules: BTreeMap<String, ModuleSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`?)"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut profile = String::new();
        let mut consts = BTreeMap::new();
        let mut modules = BTreeMap::new();
        let mut cur: Option<ModuleSpec> = None;

        let parse_tensor = |parts: &[&str]| -> Result<TensorSpec> {
            let dtype = DType::parse(parts[1])?;
            let shape = if parts[2] == "-" {
                vec![]
            } else {
                parts[2]
                    .split(',')
                    .map(|d| d.parse::<usize>().context("bad dim"))
                    .collect::<Result<Vec<_>>>()?
            };
            Ok(TensorSpec { name: parts[0].to_string(), dtype, shape })
        };

        for (ln, line) in text.lines().enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.is_empty() {
                continue;
            }
            let ctx = || format!("manifest line {}: {line:?}", ln + 1);
            match parts[0] {
                "profile" => profile = parts.get(1).map(|s| s.to_string()).unwrap_or_default(),
                "const" => {
                    if parts.len() != 3 {
                        bail!("{}: malformed const", ctx());
                    }
                    consts.insert(parts[1].to_string(), parts[2].parse().with_context(ctx)?);
                }
                "module" => {
                    if cur.is_some() {
                        bail!("{}: nested module", ctx());
                    }
                    cur = Some(ModuleSpec {
                        name: parts[1].to_string(),
                        args: vec![],
                        rets: vec![],
                        file: PathBuf::new(),
                    });
                }
                "arg" => {
                    let m = cur.as_mut().with_context(ctx)?;
                    m.args.push(parse_tensor(&parts[1..]).with_context(ctx)?);
                }
                "ret" => {
                    let m = cur.as_mut().with_context(ctx)?;
                    m.rets.push(parse_tensor(&parts[1..]).with_context(ctx)?);
                }
                "file" => {
                    let m = cur.as_mut().with_context(ctx)?;
                    m.file = dir.join(parts[1]);
                }
                "end" => {
                    let m = cur.take().with_context(ctx)?;
                    if m.file.as_os_str().is_empty() {
                        bail!("{}: module {} missing file", ctx(), m.name);
                    }
                    modules.insert(m.name.clone(), m);
                }
                other => bail!("{}: unknown directive {other:?}", ctx()),
            }
        }
        if let Some(m) = cur {
            bail!("unterminated module {}", m.name);
        }
        if profile.is_empty() {
            bail!("manifest missing profile line");
        }
        Ok(Manifest { profile, consts, modules, dir: dir.to_path_buf() })
    }

    pub fn cst(&self, name: &str) -> usize {
        *self
            .consts
            .get(name)
            .unwrap_or_else(|| panic!("manifest missing const {name}"))
    }

    pub fn module(&self, name: &str) -> Result<&ModuleSpec> {
        self.modules
            .get(name)
            .with_context(|| format!("module {name:?} not in manifest (profile {})", self.profile))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
profile tiny
const NS 32
const EP 16
module proj_fwd_l0
arg x f32 32,8
arg w f32 8,16
ret out0 f32 32,16
file proj_fwd_l0.hlo.txt
end
module edge_select
arg edge_type i32 128
arg rel i32 -
ret out0 i32 128
ret out1 i32 -
file edge_select.hlo.txt
end
";

    #[test]
    fn parses_consts_and_modules() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/x")).unwrap();
        assert_eq!(m.profile, "tiny");
        assert_eq!(m.cst("NS"), 32);
        let p = m.module("proj_fwd_l0").unwrap();
        assert_eq!(p.args.len(), 2);
        assert_eq!(p.args[0].shape, vec![32, 8]);
        assert_eq!(p.rets[0].dtype, DType::F32);
        assert_eq!(p.file, Path::new("/tmp/x/proj_fwd_l0.hlo.txt"));
    }

    #[test]
    fn scalar_shape_is_empty() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/x")).unwrap();
        let e = m.module("edge_select").unwrap();
        assert!(e.args[1].shape.is_empty());
        assert_eq!(e.args[1].numel(), 1);
        assert_eq!(e.rets.len(), 2);
    }

    #[test]
    fn unknown_module_is_error() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/x")).unwrap();
        assert!(m.module("nope").is_err());
    }

    #[test]
    fn malformed_manifest_fails() {
        assert!(Manifest::parse("module a\narg x f32 3\n", Path::new("/t")).is_err()); // unterminated
        assert!(Manifest::parse("profile t\nconst NS abc\n", Path::new("/t")).is_err());
        assert!(Manifest::parse("wat 1 2\n", Path::new("/t")).is_err());
    }
}
