//! Artifact manifest parser.
//!
//! `python/compile/aot.py` writes a line-based manifest (no serde offline)
//! describing every AOT module: argument/return names, dtypes, shapes, and
//! the profile constants (NS, EP, RPAD, ...). The runtime type-checks every
//! dispatch against this, so a profile/artifact mismatch fails loudly at
//! the call site instead of inside XLA.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unknown dtype {s:?}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }
}

/// One declared tensor (argument or return) of a module.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    /// Empty = scalar (rank 0).
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT module: its interface and HLO file.
#[derive(Clone, Debug)]
pub struct ModuleSpec {
    pub name: String,
    pub args: Vec<TensorSpec>,
    pub rets: Vec<TensorSpec>,
    pub file: PathBuf,
}

/// A parsed profile manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub profile: String,
    pub consts: BTreeMap<String, usize>,
    pub modules: BTreeMap<String, ModuleSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`?)"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut profile = String::new();
        let mut consts = BTreeMap::new();
        let mut modules = BTreeMap::new();
        let mut cur: Option<ModuleSpec> = None;

        let parse_tensor = |parts: &[&str]| -> Result<TensorSpec> {
            let dtype = DType::parse(parts[1])?;
            let shape = if parts[2] == "-" {
                vec![]
            } else {
                parts[2]
                    .split(',')
                    .map(|d| d.parse::<usize>().context("bad dim"))
                    .collect::<Result<Vec<_>>>()?
            };
            Ok(TensorSpec { name: parts[0].to_string(), dtype, shape })
        };

        for (ln, line) in text.lines().enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.is_empty() {
                continue;
            }
            let ctx = || format!("manifest line {}: {line:?}", ln + 1);
            match parts[0] {
                "profile" => profile = parts.get(1).map(|s| s.to_string()).unwrap_or_default(),
                "const" => {
                    if parts.len() != 3 {
                        bail!("{}: malformed const", ctx());
                    }
                    consts.insert(parts[1].to_string(), parts[2].parse().with_context(ctx)?);
                }
                "module" => {
                    if cur.is_some() {
                        bail!("{}: nested module", ctx());
                    }
                    cur = Some(ModuleSpec {
                        name: parts[1].to_string(),
                        args: vec![],
                        rets: vec![],
                        file: PathBuf::new(),
                    });
                }
                "arg" => {
                    let m = cur.as_mut().with_context(ctx)?;
                    m.args.push(parse_tensor(&parts[1..]).with_context(ctx)?);
                }
                "ret" => {
                    let m = cur.as_mut().with_context(ctx)?;
                    m.rets.push(parse_tensor(&parts[1..]).with_context(ctx)?);
                }
                "file" => {
                    let m = cur.as_mut().with_context(ctx)?;
                    m.file = dir.join(parts[1]);
                }
                "end" => {
                    let m = cur.take().with_context(ctx)?;
                    if m.file.as_os_str().is_empty() {
                        bail!("{}: module {} missing file", ctx(), m.name);
                    }
                    modules.insert(m.name.clone(), m);
                }
                other => bail!("{}: unknown directive {other:?}", ctx()),
            }
        }
        if let Some(m) = cur {
            bail!("unterminated module {}", m.name);
        }
        if profile.is_empty() {
            bail!("manifest missing profile line");
        }
        Ok(Manifest { profile, consts, modules, dir: dir.to_path_buf() })
    }

    /// Synthesize a built-in profile manifest, mirroring the module table
    /// of `python/compile/aot.py` and the shape profiles of
    /// `python/compile/profiles.py`. The sim backend executes against these
    /// directly, so the whole stack runs with **zero** AOT artifacts; the
    /// `file` entries are placeholders that are never opened.
    pub fn builtin(profile: &str) -> Result<Manifest> {
        let base: &[(&str, usize)] = match profile {
            "tiny" => &[
                ("NS", 32),
                ("EP", 16),
                ("RPAD", 8),
                ("TPAD", 8),
                ("F", 8),
                ("H", 16),
                ("C", 4),
                // Device-resident feature-cache rows (DESIGN.md §7): covers
                // the whole tiny graph (136 vertices after the target-type
                // bump) at --cache-frac 1.0.
                ("CSLOTS", 160),
            ],
            "bench" => &[
                ("NS", 512),
                ("EP", 256),
                ("RPAD", 128),
                ("TPAD", 32),
                ("F", 32),
                ("H", 64),
                ("C", 16),
                // 8192 rows × 32 f32 = 1 MiB resident store; --cache-frac
                // budgets above this are clamped (the cap is the profile's
                // static shape, like NS/EP).
                ("CSLOTS", 8192),
            ],
            other => bail!("unknown builtin profile {other:?} (expected tiny|bench)"),
        };
        let mut consts: BTreeMap<String, usize> =
            base.iter().map(|&(k, v)| (k.to_string(), v)).collect();
        let (ns, ep, rp, tp) = (consts["NS"], consts["EP"], consts["RPAD"], consts["TPAD"]);
        let (f, h, c) = (consts["F"], consts["H"], consts["C"]);
        let cslots = consts["CSLOTS"];
        let elp = rp * ep;
        consts.insert("ELP".to_string(), elp);

        let dir = PathBuf::from(format!("<builtin:{profile}>"));
        let mut modules: BTreeMap<String, ModuleSpec> = BTreeMap::new();
        {
            const F32: DType = DType::F32;
            const I32: DType = DType::I32;
            let mut add = |name: &str,
                           args: Vec<(&str, DType, Vec<usize>)>,
                           rets: Vec<(DType, Vec<usize>)>| {
                let spec = ModuleSpec {
                    name: name.to_string(),
                    args: args
                        .into_iter()
                        .map(|(n, dtype, shape)| TensorSpec { name: n.to_string(), dtype, shape })
                        .collect(),
                    rets: rets
                        .into_iter()
                        .enumerate()
                        .map(|(i, (dtype, shape))| TensorSpec {
                            name: format!("out{i}"),
                            dtype,
                            shape,
                        })
                        .collect(),
                    file: dir.join(format!("{name}.hlo.txt")),
                };
                modules.insert(name.to_string(), spec);
            };

            // -- semantic graph build (baseline-on-GPU path) ----------------
            add(
                "edge_select",
                vec![("edge_type", I32, vec![elp]), ("rel", I32, vec![])],
                vec![(I32, vec![elp]), (I32, vec![])],
            );

            // -- on-device feature collection (cache path, DESIGN.md §7) ----
            // Assembles the fused [TPAD, NS, F] batch slab from the
            // device-resident cache rows, the (partially) uploaded miss
            // rows, and per-slot scatter indices: idx >= 0 reads cache row
            // idx, idx == -1 writes a zero padding row, idx <= -2 reads
            // miss row (-idx - 2).
            add(
                "feature_gather",
                vec![
                    ("cache", F32, vec![cslots, f]),
                    ("miss", F32, vec![tp * ns, f]),
                    ("idx", I32, vec![tp, ns]),
                ],
                vec![(F32, vec![tp, ns, f])],
            );

            // -- feature projection -----------------------------------------
            for (l, fin, fout) in [("l0", f, h), ("l1", h, c)] {
                add(
                    &format!("proj_fwd_{l}"),
                    vec![("x", F32, vec![ns, fin]), ("w", F32, vec![fin, fout])],
                    vec![(F32, vec![ns, fout])],
                );
                add(
                    &format!("proj_bwd_{l}"),
                    vec![
                        ("x", F32, vec![ns, fin]),
                        ("w", F32, vec![fin, fout]),
                        ("dy", F32, vec![ns, fout]),
                    ],
                    vec![(F32, vec![ns, fin]), (F32, vec![fin, fout])],
                );
                add(
                    &format!("proj_stacked_fwd_{l}"),
                    vec![
                        ("xs", F32, vec![tp, ns, fin]),
                        ("w", F32, vec![rp, fin, fout]),
                        ("src_type", I32, vec![rp]),
                    ],
                    vec![(F32, vec![rp, ns, fout])],
                );
                add(
                    &format!("proj_stacked_bwd_{l}"),
                    vec![
                        ("xs", F32, vec![tp, ns, fin]),
                        ("w", F32, vec![rp, fin, fout]),
                        ("src_type", I32, vec![rp]),
                        ("dy", F32, vec![rp, ns, fout]),
                    ],
                    vec![(F32, vec![tp, ns, fin]), (F32, vec![rp, fin, fout])],
                );
                // Stacked backward with a device-resident accumulator: the
                // extra `dhin_acc` input lets the two RGAT endpoint passes
                // chain on-device (dhin = acc + dxs) instead of staging the
                // partial sums on the host (DESIGN.md §7).
                add(
                    &format!("proj_resident_bwd_{l}"),
                    vec![
                        ("xs", F32, vec![tp, ns, fin]),
                        ("w", F32, vec![rp, fin, fout]),
                        ("src_type", I32, vec![rp]),
                        ("dy", F32, vec![rp, ns, fout]),
                        ("dhin_acc", F32, vec![tp, ns, fin]),
                    ],
                    vec![(F32, vec![tp, ns, fin]), (F32, vec![rp, fin, fout])],
                );
            }

            // -- neighbor aggregation (RGCN mean + RGAT attention) ----------
            for (sfx, fd) in [("h", h), ("c", c)] {
                add(
                    &format!("agg_mean_fwd_{sfx}"),
                    vec![
                        ("feat", F32, vec![ns, fd]),
                        ("src", I32, vec![ep]),
                        ("dst", I32, vec![ep]),
                        ("valid", F32, vec![ep]),
                    ],
                    vec![(F32, vec![ns, fd])],
                );
                add(
                    &format!("agg_mean_bwd_{sfx}"),
                    vec![
                        ("feat", F32, vec![ns, fd]),
                        ("src", I32, vec![ep]),
                        ("dst", I32, vec![ep]),
                        ("valid", F32, vec![ep]),
                        ("dout", F32, vec![ns, fd]),
                    ],
                    vec![(F32, vec![ns, fd])],
                );
                add(
                    &format!("agg_merged_fwd_{sfx}"),
                    vec![
                        ("feat", F32, vec![rp, ns, fd]),
                        ("src", I32, vec![rp, ep]),
                        ("dst", I32, vec![rp, ep]),
                        ("valid", F32, vec![rp, ep]),
                    ],
                    vec![(F32, vec![rp, ns, fd])],
                );
                add(
                    &format!("agg_merged_bwd_{sfx}"),
                    vec![
                        ("src", I32, vec![rp, ep]),
                        ("dst", I32, vec![rp, ep]),
                        ("valid", F32, vec![rp, ep]),
                        ("dout", F32, vec![rp, ns, fd]),
                    ],
                    vec![(F32, vec![rp, ns, fd])],
                );
                let per: Vec<(&str, DType, Vec<usize>)> = vec![
                    ("feat_src", F32, vec![ns, fd]),
                    ("feat_dst", F32, vec![ns, fd]),
                    ("a_src", F32, vec![fd]),
                    ("a_dst", F32, vec![fd]),
                    ("src", I32, vec![ep]),
                    ("dst", I32, vec![ep]),
                    ("valid", F32, vec![ep]),
                ];
                add(&format!("att_agg_fwd_{sfx}"), per.clone(), vec![(F32, vec![ns, fd])]);
                let mut per_bwd = per.clone();
                per_bwd.push(("dout", F32, vec![ns, fd]));
                add(
                    &format!("att_agg_bwd_{sfx}"),
                    per_bwd,
                    vec![
                        (F32, vec![ns, fd]),
                        (F32, vec![ns, fd]),
                        (F32, vec![fd]),
                        (F32, vec![fd]),
                    ],
                );
                let mrg: Vec<(&str, DType, Vec<usize>)> = vec![
                    ("feat_src", F32, vec![rp, ns, fd]),
                    ("feat_dst", F32, vec![rp, ns, fd]),
                    ("a_src", F32, vec![rp, fd]),
                    ("a_dst", F32, vec![rp, fd]),
                    ("src", I32, vec![rp, ep]),
                    ("dst", I32, vec![rp, ep]),
                    ("valid", F32, vec![rp, ep]),
                ];
                add(&format!("att_merged_fwd_{sfx}"), mrg.clone(), vec![(F32, vec![rp, ns, fd])]);
                let mut mrg_bwd = mrg.clone();
                mrg_bwd.push(("dout", F32, vec![rp, ns, fd]));
                add(
                    &format!("att_merged_bwd_{sfx}"),
                    mrg_bwd,
                    vec![
                        (F32, vec![rp, ns, fd]),
                        (F32, vec![rp, ns, fd]),
                        (F32, vec![rp, fd]),
                        (F32, vec![rp, fd]),
                    ],
                );
            }

            // -- semantic fusion --------------------------------------------
            add(
                "fuse_relu_fwd_h",
                vec![("dst_type", I32, vec![rp]), ("agg", F32, vec![rp, ns, h])],
                vec![(F32, vec![tp, ns, h])],
            );
            add(
                "fuse_relu_bwd_h",
                vec![
                    ("dst_type", I32, vec![rp]),
                    ("agg", F32, vec![rp, ns, h]),
                    ("dout", F32, vec![tp, ns, h]),
                ],
                vec![(F32, vec![rp, ns, h])],
            );
            add(
                "fuse_lin_fwd_c",
                vec![("dst_type", I32, vec![rp]), ("agg", F32, vec![rp, ns, c])],
                vec![(F32, vec![tp, ns, c])],
            );
            add(
                "fuse_lin_bwd_c",
                vec![
                    ("dst_type", I32, vec![rp]),
                    ("agg", F32, vec![rp, ns, c]),
                    ("dout", F32, vec![tp, ns, c]),
                ],
                vec![(F32, vec![rp, ns, c])],
            );

            // -- head --------------------------------------------------------
            add(
                "head",
                vec![
                    ("logits", F32, vec![ns, c]),
                    ("labels", I32, vec![ns]),
                    ("seed_mask", F32, vec![ns]),
                ],
                vec![(F32, vec![]), (F32, vec![ns, c]), (F32, vec![])],
            );
            // Device-resident head: takes the full fused [TPAD, NS, C]
            // output plus the target type, returns the loss/accuracy scalars
            // and the gradient already scattered into the full slab — so the
            // loss seam never stages activations on the host.
            add(
                "head_full",
                vec![
                    ("hout", F32, vec![tp, ns, c]),
                    ("labels", I32, vec![ns]),
                    ("seed_mask", F32, vec![ns]),
                    ("target_type", I32, vec![]),
                ],
                vec![(F32, vec![]), (F32, vec![tp, ns, c]), (F32, vec![])],
            );
            // Serve-path logits extraction (device-side `slab()`).
            add(
                "slab_pick",
                vec![("hout", F32, vec![tp, ns, c]), ("target_type", I32, vec![])],
                vec![(F32, vec![ns, c])],
            );

            // -- fused on-device optimizer (device-resident mode) ------------
            add(
                "sgd_rgcn",
                vec![
                    ("w0", F32, vec![rp, f, h]),
                    ("w1", F32, vec![rp, h, c]),
                    ("dw0", F32, vec![rp, f, h]),
                    ("dw1", F32, vec![rp, h, c]),
                    ("lr", F32, vec![]),
                ],
                vec![(F32, vec![rp, f, h]), (F32, vec![rp, h, c])],
            );
            add(
                "sgd_rgat",
                vec![
                    ("w0", F32, vec![rp, f, h]),
                    ("w1", F32, vec![rp, h, c]),
                    ("a_src0", F32, vec![rp, h]),
                    ("a_dst0", F32, vec![rp, h]),
                    ("a_src1", F32, vec![rp, c]),
                    ("a_dst1", F32, vec![rp, c]),
                    ("dw0_src", F32, vec![rp, f, h]),
                    ("dw0_dst", F32, vec![rp, f, h]),
                    ("dw1_src", F32, vec![rp, h, c]),
                    ("dw1_dst", F32, vec![rp, h, c]),
                    ("da_src0", F32, vec![rp, h]),
                    ("da_dst0", F32, vec![rp, h]),
                    ("da_src1", F32, vec![rp, c]),
                    ("da_dst1", F32, vec![rp, c]),
                    ("lr", F32, vec![]),
                ],
                vec![
                    (F32, vec![rp, f, h]),
                    (F32, vec![rp, h, c]),
                    (F32, vec![rp, h]),
                    (F32, vec![rp, h]),
                    (F32, vec![rp, c]),
                    (F32, vec![rp, c]),
                ],
            );
        }

        Ok(Manifest { profile: profile.to_string(), consts, modules, dir })
    }

    pub fn cst(&self, name: &str) -> usize {
        *self
            .consts
            .get(name)
            .unwrap_or_else(|| panic!("manifest missing const {name}"))
    }

    pub fn module(&self, name: &str) -> Result<&ModuleSpec> {
        self.modules
            .get(name)
            .with_context(|| format!("module {name:?} not in manifest (profile {})", self.profile))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
profile tiny
const NS 32
const EP 16
module proj_fwd_l0
arg x f32 32,8
arg w f32 8,16
ret out0 f32 32,16
file proj_fwd_l0.hlo.txt
end
module edge_select
arg edge_type i32 128
arg rel i32 -
ret out0 i32 128
ret out1 i32 -
file edge_select.hlo.txt
end
";

    #[test]
    fn parses_consts_and_modules() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/x")).unwrap();
        assert_eq!(m.profile, "tiny");
        assert_eq!(m.cst("NS"), 32);
        let p = m.module("proj_fwd_l0").unwrap();
        assert_eq!(p.args.len(), 2);
        assert_eq!(p.args[0].shape, vec![32, 8]);
        assert_eq!(p.rets[0].dtype, DType::F32);
        assert_eq!(p.file, Path::new("/tmp/x/proj_fwd_l0.hlo.txt"));
    }

    #[test]
    fn scalar_shape_is_empty() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/x")).unwrap();
        let e = m.module("edge_select").unwrap();
        assert!(e.args[1].shape.is_empty());
        assert_eq!(e.args[1].numel(), 1);
        assert_eq!(e.rets.len(), 2);
    }

    #[test]
    fn unknown_module_is_error() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/x")).unwrap();
        assert!(m.module("nope").is_err());
    }

    #[test]
    fn builtin_profiles_match_python_profiles() {
        let t = Manifest::builtin("tiny").unwrap();
        assert_eq!(t.profile, "tiny");
        assert_eq!(
            (t.cst("NS"), t.cst("EP"), t.cst("RPAD"), t.cst("TPAD")),
            (32, 16, 8, 8)
        );
        assert_eq!((t.cst("F"), t.cst("H"), t.cst("C"), t.cst("ELP")), (8, 16, 4, 128));
        assert_eq!(t.cst("CSLOTS"), 160);
        // Full module inventory: 1 select + 1 feature gather + 10 projection
        // + 16 aggregation + 4 fusion + 2 head + 1 slab pick + 2 optimizer.
        assert_eq!(t.modules.len(), 37);
        let b = Manifest::builtin("bench").unwrap();
        assert_eq!((b.cst("NS"), b.cst("RPAD"), b.cst("ELP")), (512, 128, 32768));
        assert_eq!(b.cst("CSLOTS"), 8192);
        assert_eq!(b.modules.len(), 37);
        assert!(Manifest::builtin("nope").is_err());
    }

    #[test]
    fn builtin_interfaces_are_consistent() {
        let m = Manifest::builtin("tiny").unwrap();
        let p = m.module("proj_fwd_l0").unwrap();
        assert_eq!(p.args[0].shape, vec![32, 8]);
        assert_eq!(p.args[1].shape, vec![8, 16]);
        assert_eq!(p.rets[0].shape, vec![32, 16]);
        let a = m.module("att_merged_bwd_c").unwrap();
        assert_eq!(a.args.len(), 8);
        assert_eq!(a.rets.len(), 4);
        assert_eq!(a.rets[3].shape, vec![8, 4]);
        let h = m.module("head").unwrap();
        assert_eq!(h.rets.len(), 3);
        assert!(h.rets[0].shape.is_empty());
        let e = m.module("edge_select").unwrap();
        assert_eq!(e.args[0].dtype, DType::I32);
        assert_eq!(e.args[0].shape, vec![128]);
        let g = m.module("feature_gather").unwrap();
        assert_eq!(g.args[0].shape, vec![160, 8]); // [CSLOTS, F]
        assert_eq!(g.args[1].shape, vec![8 * 32, 8]); // [TPAD*NS, F]
        assert_eq!(g.args[2].dtype, DType::I32);
        assert_eq!(g.args[2].shape, vec![8, 32]);
        assert_eq!(g.rets[0].shape, vec![8, 32, 8]);
        // Device-resident additions: accumulator-carrying projection bwd,
        // full-slab head, serve slab pick, fused optimizers.
        let pr = m.module("proj_resident_bwd_l0").unwrap();
        assert_eq!(pr.args.len(), 5);
        assert_eq!(pr.args[4].shape, vec![8, 32, 8]); // dhin_acc = [TPAD, NS, F]
        assert_eq!(pr.rets[0].shape, pr.args[4].shape);
        let hf = m.module("head_full").unwrap();
        assert_eq!(hf.args[0].shape, vec![8, 32, 4]); // [TPAD, NS, C]
        assert!(hf.args[3].shape.is_empty()); // target_type scalar
        assert_eq!(hf.rets[1].shape, vec![8, 32, 4]);
        let sp = m.module("slab_pick").unwrap();
        assert_eq!(sp.rets[0].shape, vec![32, 4]); // [NS, C]
        let sg = m.module("sgd_rgcn").unwrap();
        assert_eq!(sg.args.len(), 5);
        assert_eq!(sg.rets.len(), 2);
        let sa = m.module("sgd_rgat").unwrap();
        assert_eq!(sa.args.len(), 15);
        assert_eq!(sa.rets.len(), 6);
        assert_eq!(sa.rets[2].shape, vec![8, 16]); // a_src0' = [RPAD, H]
    }

    #[test]
    fn malformed_manifest_fails() {
        assert!(Manifest::parse("module a\narg x f32 3\n", Path::new("/t")).is_err()); // unterminated
        assert!(Manifest::parse("profile t\nconst NS abc\n", Path::new("/t")).is_err());
        assert!(Manifest::parse("wat 1 2\n", Path::new("/t")).is_err());
    }
}
