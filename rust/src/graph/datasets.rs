//! Synthetic stand-ins for the paper's four RDF benchmark datasets.
//!
//! The real aifb/mutag/bgs/am dumps are not available offline, so we
//! generate heterogeneous graphs matching **Table 2 exactly** in the
//! statistics the paper's effect depends on: total vertices, total edges,
//! number of vertex types, and number of edge relations. Degree
//! distributions are Zipf-skewed (RDF relations are heavy-tailed: a few
//! `rdf:type`-like relations carry most edges) and every relation's
//! endpoints are drawn so the target type stays reachable within two hops,
//! which is what the 2-layer mini-batch sampler needs. See DESIGN.md §2 for
//! why this substitution preserves the paper's behaviour.

use super::{relation_from_degrees, FeatureStore, HeteroGraph, Relation};
use crate::util::Rng;

/// Table 2 row + training-task parameters.
#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub nodes: usize,
    pub edges: usize,
    pub n_types: usize,
    pub n_relations: usize,
    pub num_classes: usize,
    /// Labeled seed count (train split), mirroring the small labeled sets of
    /// the RDF node-classification benchmarks.
    pub train_size: usize,
}

/// The paper's Table 2 (aifb, mutag, bgs, am).
pub const DATASETS: [DatasetSpec; 4] = [
    DatasetSpec { name: "aifb", nodes: 7_262, edges: 48_810, n_types: 7, n_relations: 104, num_classes: 4, train_size: 140 },
    DatasetSpec { name: "mutag", nodes: 27_163, edges: 148_100, n_types: 5, n_relations: 50, num_classes: 2, train_size: 272 },
    DatasetSpec { name: "bgs", nodes: 94_806, edges: 672_884, n_types: 27, n_relations: 122, num_classes: 2, train_size: 117 },
    DatasetSpec { name: "am", nodes: 1_885_136, edges: 5_668_682, n_types: 7, n_relations: 108, num_classes: 11, train_size: 802 },
];

pub fn spec_by_name(name: &str) -> Option<DatasetSpec> {
    DATASETS.iter().copied().find(|d| d.name == name)
}

/// Generate a dataset. `scale` in (0,1] shrinks nodes/edges proportionally
/// (types and relations — the variables the paper's optimizations key on —
/// are never scaled); `feat_dim` is the raw feature width.
pub fn generate(spec: &DatasetSpec, feat_dim: usize, scale: f64, seed: u64) -> HeteroGraph {
    assert!(scale > 0.0 && scale <= 1.0, "scale must be in (0,1]");
    let mut rng = Rng::new(seed ^ 0xD47A_5E7);
    let total_nodes = ((spec.nodes as f64 * scale) as usize).max(spec.n_types * 8);
    let total_edges = ((spec.edges as f64 * scale) as usize).max(spec.n_relations * 4);

    // --- vertex counts per type: Zipf-skewed (RDF dumps have one or two
    // dominant "entity"/"literal" types), with the target type mid-sized.
    let w = Rng::zipf_weights(spec.n_types, 1.0);
    let mut num_nodes: Vec<usize> = w
        .iter()
        .map(|p| ((p * total_nodes as f64) as usize).max(4))
        .collect();
    // Fix rounding drift onto the largest type.
    let drift = total_nodes as i64 - num_nodes.iter().sum::<usize>() as i64;
    num_nodes[0] = (num_nodes[0] as i64 + drift).max(4) as usize;
    // Target type: the second-largest (papers in ACM-like graphs) — large
    // enough to hold the train split.
    let target_type = 1.min(spec.n_types - 1);
    let train_size = ((spec.train_size as f64 * scale.max(0.25)) as usize).max(16);
    if num_nodes[target_type] < train_size * 2 {
        num_nodes[target_type] = train_size * 2;
    }

    // --- relation schema: (src_type, dst_type) pairs. Relation 0 is the
    // self-relation over the target type (the RGCN self-loop — RDF graphs
    // model it as `rdf:type`-style reflexive predicates and RGCNConv adds
    // W_self explicitly); a third of the rest point *into* the target type
    // (so seeds always have in-neighbors) and the remainder connect random
    // pairs — mirroring RDF schemas where the classified entity
    // participates in many predicates.
    let mut rel_pairs = Vec::with_capacity(spec.n_relations);
    rel_pairs.push((target_type, target_type));
    for r in 1..spec.n_relations {
        let dst = if r % 3 == 0 { target_type } else { rng.below(spec.n_types) };
        let src = rng.below(spec.n_types);
        rel_pairs.push((src, dst));
    }

    // --- edge counts per relation: relation 0 (self) gets exactly one edge
    // per target vertex; the rest of the budget is Zipf over relations.
    let self_edges = num_nodes[target_type];
    let budget = total_edges.saturating_sub(self_edges).max(spec.n_relations - 1);
    let rw = Rng::zipf_weights(spec.n_relations - 1, 1.05);
    let mut rel_edges = vec![self_edges];
    rel_edges.extend(rw.iter().map(|p| ((p * budget as f64) as usize).max(1)));
    let drift = total_edges as i64 - rel_edges.iter().sum::<usize>() as i64;
    rel_edges[1] = (rel_edges[1] as i64 + drift).max(1) as usize;

    // --- per-relation CSC: spread edges over destinations with light skew
    // (each destination's in-degree ~ uniform random split, matching the
    // short-tailed per-predicate degree of RDF data). Relation 0 (self) is
    // the identity: exactly one edge v -> v per target vertex.
    let mut relations = Vec::with_capacity(spec.n_relations);
    for (r, &(src, dst)) in rel_pairs.iter().enumerate() {
        let nd = num_nodes[dst];
        let ns = num_nodes[src];
        if r == 0 {
            let indptr: Vec<u32> = (0..=nd as u32).collect();
            let src_ids: Vec<u32> = (0..nd as u32).collect();
            relations.push(Relation {
                name: "self".into(),
                src_type: src,
                dst_type: dst,
                indptr,
                src_ids,
            });
            continue;
        }
        let e = rel_edges[r];
        let mut degrees = vec![0u32; nd];
        for _ in 0..e {
            // Preferential skew: 30% of edges land on the first 10% of dsts.
            let v = if rng.f64() < 0.3 { rng.below((nd / 10).max(1)) } else { rng.below(nd) };
            degrees[v] += 1;
        }
        relations.push(relation_from_degrees(
            format!("rel{r}"),
            src,
            dst,
            &degrees,
            ns,
            &mut rng,
        ));
    }

    // --- labels + learnable features (class-centroid Gaussians).
    let labels: Vec<u8> = (0..num_nodes[target_type])
        .map(|_| rng.below(spec.num_classes) as u8)
        .collect();
    let features = FeatureStore::synth(
        &num_nodes,
        feat_dim,
        target_type,
        &labels,
        spec.num_classes,
        &mut rng,
    );

    let mut train_idx: Vec<u32> = (0..num_nodes[target_type] as u32).collect();
    rng.shuffle(&mut train_idx);
    train_idx.truncate(train_size);

    HeteroGraph {
        type_names: (0..spec.n_types).map(|t| format!("type{t}")).collect(),
        num_nodes,
        relations,
        features,
        labels,
        target_type,
        num_classes: spec.num_classes,
        feat_dim,
        train_idx,
    }
}

/// A deliberately tiny graph for unit tests (fits the `tiny` AOT profile:
/// NS=32, EP=16, RPAD=8, TPAD=8).
pub fn tiny_graph(seed: u64) -> HeteroGraph {
    let spec = DatasetSpec {
        name: "tiny",
        nodes: 120,
        edges: 400,
        n_types: 3,
        n_relations: 6,
        num_classes: 3,
        train_size: 24,
    };
    generate(&spec, 8, 1.0, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_specs_are_exact() {
        let am = spec_by_name("am").unwrap();
        assert_eq!((am.nodes, am.edges, am.n_types, am.n_relations),
                   (1_885_136, 5_668_682, 7, 108));
        let bgs = spec_by_name("bgs").unwrap();
        assert_eq!((bgs.n_types, bgs.n_relations), (27, 122));
    }

    #[test]
    fn generated_counts_match_spec() {
        let spec = spec_by_name("aifb").unwrap();
        let g = generate(&spec, 16, 1.0, 7);
        assert_eq!(g.n_types(), spec.n_types);
        assert_eq!(g.n_relations(), spec.n_relations);
        // Node/edge totals match the spec up to the target-type minimum bump.
        let node_err = (g.total_nodes() as f64 - spec.nodes as f64).abs() / spec.nodes as f64;
        assert!(node_err < 0.05, "node count off by {node_err}");
        assert_eq!(g.total_edges(), spec.edges);
    }

    #[test]
    fn scaling_shrinks_but_keeps_schema() {
        let spec = spec_by_name("bgs").unwrap();
        let g = generate(&spec, 8, 0.05, 7);
        assert_eq!(g.n_types(), 27);
        assert_eq!(g.n_relations(), 122);
        assert!(g.total_nodes() < spec.nodes / 10);
        assert!(g.total_edges() < spec.edges / 10);
    }

    #[test]
    fn target_type_has_incoming_relations_and_labels() {
        let g = tiny_graph(3);
        assert!(g.relations_into(g.target_type).count() > 0);
        assert_eq!(g.labels.len(), g.num_nodes[g.target_type]);
        assert!(g.labels.iter().all(|&l| (l as usize) < g.num_classes));
        assert!(!g.train_idx.is_empty());
        for &v in &g.train_idx {
            assert!((v as usize) < g.num_nodes[g.target_type]);
        }
    }

    #[test]
    fn determinism_same_seed_same_graph() {
        let a = tiny_graph(9);
        let b = tiny_graph(9);
        assert_eq!(a.total_edges(), b.total_edges());
        for (ra, rb) in a.relations.iter().zip(&b.relations) {
            assert_eq!(ra.src_ids, rb.src_ids);
            assert_eq!(ra.indptr, rb.indptr);
        }
        assert_eq!(a.train_idx, b.train_idx);
    }

    #[test]
    fn csc_indptr_is_monotone_and_bounded() {
        let g = tiny_graph(5);
        for r in &g.relations {
            assert_eq!(r.indptr.len(), g.num_nodes[r.dst_type] + 1);
            for w in r.indptr.windows(2) {
                assert!(w[0] <= w[1]);
            }
            assert_eq!(*r.indptr.last().unwrap() as usize, r.num_edges());
            for &s in &r.src_ids {
                assert!((s as usize) < g.num_nodes[r.src_type]);
            }
        }
    }
}
