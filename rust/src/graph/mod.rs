//! Heterogeneous graph substrate: typed vertex sets, per-relation CSC
//! topology, feature storage in both layouts the paper contrasts
//! (index-major vs type-major, Fig. 4), and synthetic RDF-style dataset
//! generators matching the paper's Table 2.

pub mod datasets;
pub mod features;

pub use datasets::{DatasetSpec, DATASETS};
pub use features::{FeatureStore, Layout};

use crate::util::Rng;

/// One edge relation (semantic graph schema entry): directed edges of a
/// single type from `src_type` vertices to `dst_type` vertices, stored CSC
/// (compressed by destination) because mini-batch sampling walks *incoming*
/// neighbors of frontier vertices.
#[derive(Clone, Debug)]
pub struct Relation {
    pub name: String,
    pub src_type: usize,
    pub dst_type: usize,
    /// CSC column pointers; `len == num_nodes[dst_type] + 1`.
    pub indptr: Vec<u32>,
    /// Source vertex ids (type-local), grouped by destination.
    pub src_ids: Vec<u32>,
}

impl Relation {
    pub fn num_edges(&self) -> usize {
        self.src_ids.len()
    }

    /// Incoming neighbors (type-local src ids) of destination vertex `v`.
    #[inline]
    pub fn in_neighbors(&self, v: usize) -> &[u32] {
        &self.src_ids[self.indptr[v] as usize..self.indptr[v + 1] as usize]
    }
}

/// The heterogeneous graph: vertex types, relations, features, labels.
pub struct HeteroGraph {
    pub type_names: Vec<String>,
    /// Vertex count per type.
    pub num_nodes: Vec<usize>,
    pub relations: Vec<Relation>,
    pub features: FeatureStore,
    /// Class label per vertex of `target_type` (classification target).
    pub labels: Vec<u8>,
    pub target_type: usize,
    pub num_classes: usize,
    pub feat_dim: usize,
    /// Vertices of the target type used as training seeds.
    pub train_idx: Vec<u32>,
}

impl HeteroGraph {
    pub fn n_types(&self) -> usize {
        self.num_nodes.len()
    }

    pub fn n_relations(&self) -> usize {
        self.relations.len()
    }

    pub fn total_nodes(&self) -> usize {
        self.num_nodes.iter().sum()
    }

    pub fn total_edges(&self) -> usize {
        self.relations.iter().map(|r| r.num_edges()).sum()
    }

    /// Relations grouped by destination type (sampling hot path helper).
    pub fn relations_into(&self, dst_type: usize) -> impl Iterator<Item = (usize, &Relation)> {
        self.relations
            .iter()
            .enumerate()
            .filter(move |(_, r)| r.dst_type == dst_type)
    }

    /// One-line stats row (Table 2 regeneration).
    pub fn stats_row(&self, name: &str) -> String {
        format!(
            "{name:8} | {:>9} nodes | {:>9} edges | {:>2} types | {:>3} relations",
            self.total_nodes(),
            self.total_edges(),
            self.n_types(),
            self.n_relations()
        )
    }
}

/// Build a relation's CSC arrays from a per-destination degree plan, filling
/// sources uniformly at random (used by the synthetic generators).
pub fn relation_from_degrees(
    name: String,
    src_type: usize,
    dst_type: usize,
    degrees: &[u32],
    num_src: usize,
    rng: &mut Rng,
) -> Relation {
    let mut indptr = Vec::with_capacity(degrees.len() + 1);
    indptr.push(0u32);
    let total: u32 = degrees.iter().sum();
    let mut src_ids = Vec::with_capacity(total as usize);
    for &d in degrees {
        for _ in 0..d {
            src_ids.push(rng.below(num_src) as u32);
        }
        indptr.push(src_ids.len() as u32);
    }
    Relation { name, src_type, dst_type, indptr, src_ids }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relation_csc_invariants() {
        let mut rng = Rng::new(1);
        let degrees = vec![2, 0, 3, 1];
        let r = relation_from_degrees("t".into(), 0, 1, &degrees, 10, &mut rng);
        assert_eq!(r.num_edges(), 6);
        assert_eq!(r.indptr.len(), 5);
        assert_eq!(r.in_neighbors(0).len(), 2);
        assert_eq!(r.in_neighbors(1).len(), 0);
        assert_eq!(r.in_neighbors(2).len(), 3);
        for &s in &r.src_ids {
            assert!((s as usize) < 10);
        }
    }
}
