//! Vertex feature storage in the two layouts the paper contrasts (Fig. 4).
//!
//! * **Index-major** (Fig. 4a, the PyG-style baseline): one flat buffer over
//!   *global* vertex ids, with the types interleaved in RDF-dump order. A
//!   per-type gather therefore touches scattered cache lines across the
//!   whole buffer — the poor spatial/temporal locality the paper profiles.
//! * **Type-major** (Fig. 4b, HiFuse's reorganization): one contiguous
//!   buffer per type, ordered by type-local index. Per-type gathers stay
//!   inside a compact region (the "coalesced access" analogue on CPU is
//!   cache-line/page locality).
//!
//! Both layouts serve reads through the same API so the collector code in
//! `sampler::collect` is layout-agnostic; an ablation flag picks the layout.

use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    IndexMajor,
    TypeMajor,
}

/// Vertex features for all types, materialized in one layout at a time
/// (`ensure_layout` converts; datasets can be hundreds of MB so we avoid
/// holding both buffers unless a bench explicitly compares them).
pub struct FeatureStore {
    pub dim: usize,
    num_nodes: Vec<usize>,
    layout: Layout,
    /// Type-major: `tm[t][v*dim ..]` = features of type-local vertex v.
    tm: Vec<Vec<f32>>,
    /// Index-major: flat buffer indexed by global id * dim.
    im: Vec<f32>,
    /// Per type: type-local vertex -> global id (interleaved assignment).
    global_of: Vec<Vec<u32>>,
}

impl FeatureStore {
    /// Generate synthetic features. Target-type vertices are drawn from
    /// per-class Gaussian centroids (so the classification task is actually
    /// learnable and the E2E loss curve decreases); other types are noise.
    pub fn synth(
        num_nodes: &[usize],
        dim: usize,
        target_type: usize,
        labels: &[u8],
        num_classes: usize,
        rng: &mut Rng,
    ) -> Self {
        // Class centroids, unit-ish separation.
        let mut centroids = vec![0.0f32; num_classes * dim];
        for c in centroids.iter_mut() {
            *c = rng.normal() * 1.5;
        }
        let mut tm = Vec::with_capacity(num_nodes.len());
        for (t, &n) in num_nodes.iter().enumerate() {
            let mut buf = vec![0.0f32; n * dim];
            if t == target_type {
                for v in 0..n {
                    let cls = labels[v] as usize;
                    for d in 0..dim {
                        buf[v * dim + d] = centroids[cls * dim + d] + 0.5 * rng.normal();
                    }
                }
            } else {
                for x in buf.iter_mut() {
                    *x = rng.normal() * 0.5;
                }
            }
            tm.push(buf);
        }
        // Interleaved global-id assignment models the RDF-dump vertex order
        // the paper's Fig. 4a describes: round-robin across types.
        let global_of = interleaved_global_ids(num_nodes);
        FeatureStore {
            dim,
            num_nodes: num_nodes.to_vec(),
            layout: Layout::TypeMajor,
            tm,
            im: Vec::new(),
            global_of,
        }
    }

    pub fn layout(&self) -> Layout {
        self.layout
    }

    pub fn total_nodes(&self) -> usize {
        self.num_nodes.iter().sum()
    }

    /// Materialize the requested layout (drops the other buffer).
    pub fn ensure_layout(&mut self, want: Layout) {
        if self.layout == want && (want == Layout::TypeMajor || !self.im.is_empty()) {
            return;
        }
        match want {
            Layout::TypeMajor => {
                if self.tm.is_empty() {
                    let mut tm = Vec::with_capacity(self.num_nodes.len());
                    for (t, &n) in self.num_nodes.iter().enumerate() {
                        let mut buf = vec![0.0f32; n * self.dim];
                        for v in 0..n {
                            let g = self.global_of[t][v] as usize;
                            buf[v * self.dim..(v + 1) * self.dim]
                                .copy_from_slice(&self.im[g * self.dim..(g + 1) * self.dim]);
                        }
                        tm.push(buf);
                    }
                    self.tm = tm;
                }
                self.im = Vec::new();
            }
            Layout::IndexMajor => {
                if self.im.is_empty() {
                    let mut im = vec![0.0f32; self.total_nodes() * self.dim];
                    for (t, buf) in self.tm.iter().enumerate() {
                        for v in 0..self.num_nodes[t] {
                            let g = self.global_of[t][v] as usize;
                            im[g * self.dim..(g + 1) * self.dim]
                                .copy_from_slice(&buf[v * self.dim..(v + 1) * self.dim]);
                        }
                    }
                    self.im = im;
                }
                self.tm = Vec::new();
            }
        }
        self.layout = want;
    }

    /// Contiguous view of rows `[v0, v0 + n)` of type `t`, when the
    /// materialized layout makes them contiguous (type-major). Index-major
    /// returns `None`: global-id interleaving scatters consecutive
    /// type-local rows across the buffer, so callers must fall back to
    /// [`FeatureStore::copy_row`]. This is what lets the collector turn a
    /// run of consecutive slot ids into one `copy_from_slice`.
    #[inline]
    pub fn rows(&self, t: usize, v0: usize, n: usize) -> Option<&[f32]> {
        match self.layout {
            Layout::TypeMajor => Some(&self.tm[t][v0 * self.dim..(v0 + n) * self.dim]),
            Layout::IndexMajor => None,
        }
    }

    /// Read the feature row of type-local vertex `(t, v)` into `out`.
    /// This is the hot path of feature collection; index-major incurs the
    /// scattered global-id indirection the paper's reorganization removes.
    #[inline]
    pub fn copy_row(&self, t: usize, v: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        match self.layout {
            Layout::TypeMajor => {
                let buf = &self.tm[t];
                out.copy_from_slice(&buf[v * self.dim..(v + 1) * self.dim]);
            }
            Layout::IndexMajor => {
                let g = self.global_of[t][v] as usize;
                out.copy_from_slice(&self.im[g * self.dim..(g + 1) * self.dim]);
            }
        }
    }
}

/// Round-robin global id assignment across types (the interleaved order of
/// Fig. 4a). Types with more vertices keep receiving ids after shorter
/// types are exhausted.
fn interleaved_global_ids(num_nodes: &[usize]) -> Vec<Vec<u32>> {
    let mut out: Vec<Vec<u32>> = num_nodes.iter().map(|&n| Vec::with_capacity(n)).collect();
    let max_n = num_nodes.iter().copied().max().unwrap_or(0);
    let mut g = 0u32;
    for v in 0..max_n {
        for (t, &n) in num_nodes.iter().enumerate() {
            if v < n {
                out[t].push(g);
                g += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> FeatureStore {
        let mut rng = Rng::new(11);
        let labels = vec![0, 1, 0, 1, 1];
        FeatureStore::synth(&[5, 3, 7], 4, 0, &labels, 2, &mut rng)
    }

    #[test]
    fn interleaving_is_a_bijection() {
        let ids = interleaved_global_ids(&[3, 1, 2]);
        let mut all: Vec<u32> = ids.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
        // Round-robin: first ids go type0, type1, type2, then type0/type2...
        assert_eq!(ids[0][0], 0);
        assert_eq!(ids[1][0], 1);
        assert_eq!(ids[2][0], 2);
    }

    #[test]
    fn layouts_agree_row_for_row() {
        let mut s = store();
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        let mut expect = Vec::new();
        for t in 0..3 {
            for v in 0..[5, 3, 7][t] {
                s.copy_row(t, v, &mut a);
                expect.push((t, v, a.clone()));
            }
        }
        s.ensure_layout(Layout::IndexMajor);
        assert_eq!(s.layout(), Layout::IndexMajor);
        for (t, v, want) in &expect {
            s.copy_row(*t, *v, &mut b);
            assert_eq!(&b, want, "mismatch at ({t},{v})");
        }
        // And back again.
        s.ensure_layout(Layout::TypeMajor);
        for (t, v, want) in &expect {
            s.copy_row(*t, *v, &mut b);
            assert_eq!(&b, want, "mismatch after roundtrip at ({t},{v})");
        }
    }

    #[test]
    fn contiguous_rows_match_copy_row_and_gate_on_layout() {
        let mut s = store();
        let mut row = vec![0.0f32; 4];
        let view = s.rows(2, 1, 3).expect("type-major is contiguous");
        assert_eq!(view.len(), 3 * 4);
        for i in 0..3 {
            s.copy_row(2, 1 + i, &mut row);
            assert_eq!(&view[i * 4..(i + 1) * 4], &row[..], "row {i}");
        }
        s.ensure_layout(Layout::IndexMajor);
        assert!(s.rows(2, 1, 3).is_none(), "index-major must not claim contiguity");
    }

    #[test]
    fn target_type_features_cluster_by_class() {
        let mut rng = Rng::new(3);
        let n = 200;
        let labels: Vec<u8> = (0..n).map(|_| rng.below(2) as u8).collect();
        let s = FeatureStore::synth(&[n, 10], 8, 0, &labels, 2, &mut rng);
        // Mean intra-class distance should be well below inter-class.
        let mut row = vec![0.0f32; 8];
        let mut means = vec![vec![0.0f32; 8]; 2];
        let mut counts = [0usize; 2];
        for v in 0..n {
            s.copy_row(0, v, &mut row);
            let c = labels[v] as usize;
            for d in 0..8 {
                means[c][d] += row[d];
            }
            counts[c] += 1;
        }
        for c in 0..2 {
            for d in 0..8 {
                means[c][d] /= counts[c] as f32;
            }
        }
        let sep: f32 = means[0].iter().zip(&means[1]).map(|(a, b)| (a - b) * (a - b)).sum();
        assert!(sep > 1.0, "class centroids not separated: {sep}");
    }
}
