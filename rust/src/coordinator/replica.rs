//! Data-parallel replica training across execution-backend instances
//! (DESIGN.md §4).
//!
//! A [`ReplicaGroup`] owns `N` backends — each with its **own** dispatch
//! counters and buffer arena — and partitions each epoch's mini-batches
//! across them in fixed *rounds* of `round` consecutive batches. Every
//! batch of a round computes its gradient from the same parameter snapshot
//! (synchronous data-parallel SGD); the per-batch gradients are then merged
//! by a **deterministic fixed-order all-reduce** — summed in replica-index
//! order, which by the contiguous round partition *is* global batch order —
//! and one mean-gradient SGD/round updates the shared parameters, which the
//! next round's lanes see by re-borrowing (the "broadcast").
//!
//! **Bit-exactness contract.** PR 2 made kernel threading partition-only,
//! so a batch gradient is bitwise-deterministic in (params, batch index)
//! for *any* thread count. Round boundaries and the merge order depend only
//! on `(n_batches, round)`, never on the replica count — therefore the
//! whole training trajectory is bit-identical for any `--replicas N`
//! (pinned by `tests/replica_parity.rs`). This extends the PR 2 contract
//! from threads to replicas: replicas are a scheduling choice, not a
//! semantic one. The same holds for `--producers`
//! (`tests/producer_parity.rs`): each lane's feed delivers its schedule in
//! exact order regardless of how many sampling workers prepared it.
//!
//! **Thread budget.** The group shares one `--threads` budget: each lane
//! (CPU producers + backend kernels) gets [`replica_thread_budget`]
//! workers, so `--replicas 4 --threads 4` runs four serial lanes rather
//! than oversubscribing the host. The producer count splits the same way
//! ([`lane_producer_count`](super::lane_producer_count)).
//!
//! **Pipelining.** With `OptConfig::pipeline` on, each lane gets its own
//! multi-producer feed ([`super::pipeline`]) over its schedule —
//! [`lane_producer_count`](super::lane_producer_count) sampling workers
//! feeding a sequence-numbered reorder ring with the Fig. 6 credit-based
//! backpressure — so sampling/selection/collection overlap the lanes'
//! backend compute exactly as in single-backend pipelined training.
//! Consumed batch buffers cycle back to their producers; each lane's
//! producer arsenal persists that state across epochs, extending the
//! zero-alloc steady state to replica training (DESIGN.md §5).
//!
//! **Fault plane (DESIGN.md §9).** With an attached [`FaultPlan`], a
//! [`FaultSite::Lane`] entry addressed at `(epoch, global batch seq)` kills
//! whichever lane owns that batch *before* it consumes the batch's prepared
//! input. The dead lane's remaining slots — the tail of its current round
//! slice, and its whole slice in every later round — are absorbed by the
//! first surviving lane: preps keep flowing from the dead lane's own
//! producers (its feed stays alive), compute moves to the survivor's
//! backend, and the recovered gradients slot into the all-reduce at exactly
//! their global batch positions. Because the merge is batch-ordered for any
//! contiguous assignment, the recovered trajectory is bitwise identical to
//! the fault-free one. Producer deaths inside a lane's feed are re-derived
//! on a per-lane standby producer (same contract as the single-backend
//! pipeline); dispatch faults retry inside the backend. All of it is
//! default-off and zero-cost without an attached plan.
//!
//! **Serve churn (DESIGN.md §10).** The forward-only serve drive gets the
//! same deterministic treatment: [`FaultSite::LaneHard`] (`lane!`) entries
//! quarantine a lane mid-trace — its batches re-dispatch to the next
//! healthy lane in global batch order, it shadows a probation of batches
//! with discarded output, then re-enters the rotation — and
//! [`RefreshEvent`]s hot-swap the serving parameters at global batch
//! boundaries ([`ReplicaGroup::serve_forward_churn`],
//! [`ReplicaGroup::refresh_lane`]). Predictions stay a bitwise function of
//! (params timeline, batch index, seed set); only latency moves. Counters
//! land in [`ChurnStats`]; the all-lanes-dead state is the typed
//! [`NoHealthyLanes`] error.
//!
//! Backends must be [`Send`] (each lane thread takes exclusive ownership of
//! its backend for the round); they need **not** be `Sync`, which is what
//! lets the `RefCell`-based [`SimBackend`](crate::runtime::SimBackend)
//! participate. The `Rc`-based PJRT engine is `!Send` and stays
//! single-backend.

use std::collections::HashMap;
use std::fmt;
use std::sync::mpsc::{self, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Result};

use super::pipeline::{spawn_feed, BatchFeed, FeedSlot};
use super::{
    assemble_batch, assemble_batch_dev, lane_producer_count, sampler_cfg, AssembleScratch,
    BatchBufs, CpuProducer, EpochMetrics, OptConfig, PreparedCpu, ProducerArsenal, ProducerState,
    ProducerStats, TrainCfg, PIPELINE_DEPTH,
};
use crate::graph::HeteroGraph;
use crate::models::step::{
    schema_tensors, DevGrads, DevParams, DevSchema, Dims, SchemaTensors, StepExecutor, StepResult,
};
use crate::models::{ModelKind, Params};
use crate::runtime::{CacheHandle, CpuStageTimes, ExecBackend, ResidentStore, SimBackend};
use crate::sampler::{epoch_perm, NeighborSampler};
use crate::util::{fnv1a_f32, FaultPlan, FaultSite, HostTensor, Rng, WorkerPool};

/// Default round width (global batches per synchronous update). A constant
/// — *not* derived from the replica count — so the trajectory is invariant
/// in `--replicas` (DESIGN.md §4).
pub const DEFAULT_ROUND: usize = 4;

/// Split one shared thread budget across replicas: each lane gets
/// `max(1, total / replicas)` workers for both its CPU producer stages and
/// its backend's intra-kernel row parallelism.
pub fn replica_thread_budget(total: usize, replicas: usize) -> usize {
    (total / replicas.max(1)).max(1)
}

/// Default probation length: shadow batches a quarantined lane must
/// complete before re-admission to the serve rotation (DESIGN.md §10).
pub const DEFAULT_PROBATION: usize = 2;

/// Exact churn accounting for one serve drive (DESIGN.md §10). Every
/// counter is deterministic in (fault plan, refresh schedule, batch
/// count, lane count) — pinned by `tests/churn_matrix.rs`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChurnStats {
    /// Lanes pulled from the rotation by a [`FaultSite::LaneHard`] firing.
    pub lane_quarantines: u64,
    /// Lanes returned to the rotation after completing probation.
    pub lane_readmissions: u64,
    /// Probation batches executed with their output discarded.
    pub shadow_batches: u64,
    /// Batches moved off a just-quarantined lane to the next healthy one.
    pub lane_redispatches: u64,
    /// Hot model refreshes applied (checkpoint loaded + dims verified).
    pub refreshes: u64,
    /// Refresh attempts rejected (load error or shape mismatch); the old
    /// parameters kept serving.
    pub failed_refreshes: u64,
    /// Guarded integrity violations (non-finite logits) caught on serve
    /// lanes this drive (DESIGN.md §11).
    pub integrity_violations: u64,
    /// Serve batches recomputed after a guarded integrity violation.
    pub integrity_recomputes: u64,
}

impl ChurnStats {
    /// `true` iff the drive saw no churn at all.
    pub fn is_quiet(&self) -> bool {
        *self == ChurnStats::default()
    }
}

/// Typed error for the unservable state: every lane quarantined at once,
/// so batch `batch` has nowhere to run. Distinguishable from transient
/// dispatch failures by downcast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NoHealthyLanes {
    /// Global coalesced-batch index that could not be placed.
    pub batch: usize,
    /// Total lane count of the group (all quarantined).
    pub lanes: usize,
}

impl fmt::Display for NoHealthyLanes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "no healthy serve lanes left: all {} lanes quarantined at coalesced batch {}",
            self.lanes, self.batch
        )
    }
}

impl std::error::Error for NoHealthyLanes {}

/// One hot model refresh in a serve drive: from admitted coalesced batch
/// `at_batch` on, every lane serves `params` instead of whatever it served
/// before. Expressed in *global batch order* — not per lane, not in wall
/// time — so predictions stay a pure function of (params timeline, batch
/// index) for any lane count (DESIGN.md §10).
#[derive(Clone)]
pub struct RefreshEvent {
    /// First admitted coalesced batch served with the new parameters.
    pub at_batch: usize,
    /// The freshly loaded (verified) parameter set.
    pub params: Arc<Params>,
}

/// What a churn-aware serve drive returns: per-batch logits + service
/// times, the lane that actually executed each batch (after quarantine
/// re-dispatch — feeds the demux latency model), and the churn counters.
pub struct ServeDrive {
    /// `[NS, C]` logits plus assemble+forward wall time, in batch order.
    pub stepped: Vec<(HostTensor, Duration)>,
    /// Executing lane per batch; `bi % replicas` when no lane was ever
    /// quarantined.
    pub primary_lane: Vec<usize>,
    /// Quarantine/shadow/re-dispatch accounting (refresh counters are
    /// filled by the serving layer, which owns checkpoint loading).
    pub stats: ChurnStats,
    /// Lanes that hit 2+ guarded integrity violations this drive
    /// (DESIGN.md §11): the group remembers them and the *next* churn
    /// drive starts them quarantined (probation shadowing before
    /// re-admission), closing the loop with the §10 churn plane.
    pub suspect_lanes: Vec<usize>,
}

/// One scheduled slot of a serve lane: the global coalesced-batch index
/// plus whether this is probation shadow work (output discarded, no fault
/// cursor — shadow compute must not perturb dispatch-fault accounting).
type ServeSlot = (usize, bool);

/// A churn-resolved serve schedule: per-lane ordered slot lists, the
/// primary lane per batch, and the planner's share of the counters.
struct ChurnSchedule {
    lanes: Vec<Vec<ServeSlot>>,
    primary: Vec<usize>,
    stats: ChurnStats,
}

/// Resolve quarantine churn into a deterministic schedule, before any
/// thread is spawned (DESIGN.md §10). Pure in its arguments:
///
/// * Primary selection starts at `bi % n_lanes` and scans forward for the
///   first healthy lane. Each [`FaultSite::LaneHard`] firing at
///   `(epoch 0, seq bi)` consumes the current candidate — quarantines it
///   and re-dispatches the batch to the next healthy lane — so `x N`
///   multiplicity cascades across N successive lanes.
/// * A quarantined lane shadows every subsequent batch (same prep, same
///   seq, output discarded) until it has completed `probation` of them,
///   then re-enters the rotation from the next batch.
/// * `pre_quarantined` lanes — flagged suspect by a previous drive's
///   integrity guard (DESIGN.md §11) — start outside the rotation with a
///   full probation to shadow, exactly as if a [`FaultSite::LaneHard`]
///   entry had fired before batch 0 (counted as a quarantine, but not as
///   a re-dispatch: no batch was ever placed on them).
/// * Zero healthy lanes is the typed [`NoHealthyLanes`] error.
fn plan_churn(
    n_batches: usize,
    n_lanes: usize,
    plan: Option<&FaultPlan>,
    probation: usize,
    pre_quarantined: &[usize],
) -> Result<ChurnSchedule> {
    let hard = plan.filter(|p| p.has_site(FaultSite::LaneHard));
    let probation = probation.max(1);
    let mut lanes: Vec<Vec<ServeSlot>> = (0..n_lanes).map(|_| Vec::new()).collect();
    let mut primary = Vec::with_capacity(n_batches);
    let mut stats = ChurnStats::default();
    let mut healthy = vec![true; n_lanes];
    let mut shadow_left = vec![0usize; n_lanes];
    for &l in pre_quarantined {
        if l < n_lanes && healthy[l] {
            healthy[l] = false;
            shadow_left[l] = probation;
            stats.lane_quarantines += 1;
        }
    }
    for bi in 0..n_batches {
        // Lanes already quarantined when this batch arrives shadow it;
        // snapshot before any kill this batch triggers.
        let shadowing: Vec<usize> = (0..n_lanes).filter(|&l| !healthy[l]).collect();
        let mut kills = hard.map_or(0, |p| p.fires(FaultSite::LaneHard, 0, bi as u64));
        let mut probe = bi % n_lanes;
        let chosen = loop {
            let Some(l) = (0..n_lanes).map(|off| (probe + off) % n_lanes).find(|&l| healthy[l])
            else {
                return Err(NoHealthyLanes { batch: bi, lanes: n_lanes }.into());
            };
            if kills > 0 {
                kills -= 1;
                healthy[l] = false;
                shadow_left[l] = probation;
                stats.lane_quarantines += 1;
                stats.lane_redispatches += 1;
                probe = (l + 1) % n_lanes;
                continue;
            }
            break l;
        };
        lanes[chosen].push((bi, false));
        primary.push(chosen);
        for l in shadowing {
            lanes[l].push((bi, true));
            stats.shadow_batches += 1;
            shadow_left[l] -= 1;
            if shadow_left[l] == 0 {
                healthy[l] = true;
                stats.lane_readmissions += 1;
            }
        }
    }
    Ok(ChurnSchedule { lanes, primary, stats })
}

/// What one lane computed for its slice of a round: `(step result,
/// gradient)` per batch, in batch order — possibly cut short by an injected
/// lane fault.
struct LaneRound {
    items: Vec<(StepResult, Params)>,
    /// Offset into the round slice where a [`FaultSite::Lane`] entry killed
    /// this lane; batches from that offset on were *not* consumed from the
    /// lane's source and await failover. `None` = ran to completion.
    died_at: Option<usize>,
}

type RoundOutput = Result<LaneRound>;

/// One epoch's measurements from a replica group: the aggregated group view
/// plus each replica's own counters.
#[derive(Clone, Debug)]
pub struct ReplicaMetrics {
    /// Group totals: additive counters summed over replicas via
    /// [`EpochMetrics::absorb`]; `loss`/`acc`/`wall` computed globally.
    pub group: EpochMetrics,
    /// Per-replica counters (kernels, stage times, arena, producer pool,
    /// cpu time, batches). `loss`/`acc`/`wall` are left at their defaults
    /// here — they are properties of the group trajectory, not of a lane.
    pub per_replica: Vec<EpochMetrics>,
}

/// Synchronous data-parallel trainer over `N` backend replicas. See the
/// module docs for the round/all-reduce semantics.
pub struct ReplicaGroup<'g, B: ExecBackend> {
    pub graph: &'g HeteroGraph,
    pub model: ModelKind,
    pub opt: OptConfig,
    pub cfg: TrainCfg,
    /// The shared (broadcast) parameters; updated once per round.
    pub params: Params,
    round: usize,
    schema: SchemaTensors,
    engines: Vec<B>,
    /// Per-lane producer state (scratches + recycled buffer sets), kept
    /// across epochs for the zero-alloc steady state.
    arsenals: Vec<ProducerArsenal>,
    /// Per-replica feature-cache handles (one device upload per backend),
    /// all sharing one read-only [`ResidentStore`] (DESIGN.md §7). Empty =
    /// cache off. Aligned with `engines`.
    caches: Vec<CacheHandle<B>>,
    /// Deterministic fault-injection plan (DESIGN.md §9); `None` = off.
    fault: Option<Arc<FaultPlan>>,
    /// Per-lane hot-refreshed serving parameters ([`Self::refresh_lane`],
    /// DESIGN.md §10): `Some` overrides the shared `params` for that lane's
    /// *forward* (serve) work only — training rounds always broadcast the
    /// shared set. Aligned with `engines`.
    lane_params: Vec<Option<Params>>,
    /// Per-lane device-resident schema constants (type maps, target/LR
    /// scalars, zero-accumulator seeds), uploaded once at construction and
    /// persisted across epochs; non-empty iff `opt.dev_resident`, aligned
    /// with `engines`. The *parameters* are re-staged per round (the
    /// broadcast, counted in `Counters::p2p_bytes`) — the group stays
    /// host-authoritative so the fixed-order all-reduce and the round SGD
    /// run unchanged, bitwise (DESIGN.md §4/§7).
    dev_schemas: Vec<DevSchema<B>>,
    /// Numeric guard rails on (DESIGN.md §11): lanes digest-check their
    /// feature payloads and finite-check loss/gradients before any result
    /// enters the round merge.
    guard: bool,
    /// Group digest-audit cadence in batches; `0` = off. Audits run at the
    /// first round boundary at/past each multiple, plus epoch end.
    audit_every: u64,
    /// Shared injection budgets for the integrity corruption sites
    /// (`flip!`/`nan!`), keyed by `(site, epoch, seq)`: every lane attempt
    /// — first run, recompute, group replay — consumes from the same
    /// per-address budget, so recovery converges instead of re-poisoning
    /// itself forever. Locked only when the plan has integrity sites;
    /// empty (never allocated into) otherwise.
    consumed: Mutex<HashMap<(FaultSite, u64, u64), u32>>,
    /// Last round-boundary parameter snapshot that passed an audit (or the
    /// epoch-start state); the group rollback target. `None` until the
    /// first integrity-active epoch.
    last_good: Option<Params>,
    /// Per-global-batch `(loss, ncorrect, n_seed)` scratch for integrity
    /// epochs — replays overwrite in place and the epoch folds once in
    /// batch order, keeping the f64 metric sums bitwise identical to a
    /// fault-free run. Kept across epochs for the zero-alloc steady state.
    batch_results: Vec<(f64, f64, usize)>,
    /// Serve lanes flagged by the integrity guard (2+ violations in one
    /// drive); consumed — pre-quarantined — by the next churn drive.
    suspects: Vec<usize>,
    rng: Rng,
    d: Dims,
}

impl<'g, B: ExecBackend> ReplicaGroup<'g, B> {
    /// Build a group over pre-constructed backends (one per replica; all
    /// must share one profile). Callers construct the backends with
    /// [`replica_thread_budget`] kernel workers each so the group respects
    /// one shared `--threads` budget.
    pub fn new(
        engines: Vec<B>,
        graph: &'g HeteroGraph,
        model: ModelKind,
        opt: OptConfig,
        cfg: TrainCfg,
        round: usize,
    ) -> Result<Self> {
        ensure!(!engines.is_empty(), "replica group needs at least one backend");
        ensure!(
            engines.len() <= round.max(1),
            "{} replicas but rounds hold only {} batches: the extra lanes could \
             never receive work (clamp the replica count to the round width)",
            engines.len(),
            round.max(1)
        );
        let d = Dims::from_backend(&engines[0]);
        for e in &engines[1..] {
            ensure!(
                e.profile() == engines[0].profile(),
                "replica backends must share one profile ({} vs {})",
                e.profile(),
                engines[0].profile()
            );
        }
        assert_eq!(graph.feat_dim, d.f, "graph feature dim != profile F");
        assert!(graph.num_classes <= d.c, "dataset classes exceed profile C");
        let schema = schema_tensors(graph, &d);
        let params = Params::init(d.rpad, d.f, d.h, d.c, cfg.seed);
        let arsenals = (0..engines.len()).map(|_| ProducerArsenal::default()).collect();
        // Device-resident mode: stage each lane's schema constants once,
        // up front (warm-up traffic, before any epoch resets the counters).
        let mut dev_schemas = Vec::new();
        if opt.dev_resident {
            for e in &engines {
                dev_schemas.push(StepExecutor::new(e, model, opt).make_dev_schema(&schema, cfg.lr)?);
            }
        }
        let lane_params = (0..engines.len()).map(|_| None).collect();
        Ok(ReplicaGroup {
            graph,
            model,
            opt,
            cfg,
            params,
            round: round.max(1),
            schema,
            engines,
            arsenals,
            caches: Vec::new(),
            fault: None,
            lane_params,
            dev_schemas,
            guard: false,
            audit_every: 0,
            consumed: Mutex::new(HashMap::new()),
            last_good: None,
            batch_results: Vec::new(),
            suspects: Vec::new(),
            rng: Rng::new(cfg.seed),
            d,
        })
    }

    /// Attach a deterministic fault-injection plan (DESIGN.md §9): every
    /// replica backend consults it for dispatch faults, the lane feeds for
    /// producer deaths, and the round loop for lane failures. Additive —
    /// with the default (empty) plan behavior is bitwise identical to not
    /// calling this at all.
    pub fn set_fault_plan(&mut self, plan: Arc<FaultPlan>) {
        for e in &self.engines {
            e.set_fault_plan(plan.clone());
        }
        self.fault = Some(plan);
    }

    /// Toggle the numeric guard rails (DESIGN.md §11): lanes digest-check
    /// feature payloads and finite-check loss/gradients before any result
    /// enters the round merge, serve lanes finite-check their logits, and
    /// every backend verifies `wire!`-corrupted transfers at delivery. A
    /// guarded clean run is bitwise identical to an unguarded one.
    pub fn set_guard(&mut self, on: bool) -> Result<()> {
        ensure!(
            !(on && self.opt.dev_resident),
            "--guard needs the host-staged step: the fused device SGD cannot \
             split the gradient check from the parameter apply"
        );
        self.guard = on;
        for e in &self.engines {
            e.set_integrity_guard(on);
        }
        Ok(())
    }

    /// Set the group digest-audit cadence (DESIGN.md §11): every `n`
    /// admitted batches (checked at round boundaries, plus epoch end) the
    /// main thread audits the merged parameters and any hot-refreshed lane
    /// overrides, rolling back to the last good round-boundary snapshot on
    /// a violation. `0` = off.
    pub fn set_audit_every(&mut self, n: u64) -> Result<()> {
        ensure!(
            !(n > 0 && self.opt.dev_resident),
            "--audit-every needs the host-staged step (host-authoritative \
             parameters between rounds)"
        );
        self.audit_every = n;
        Ok(())
    }

    /// Whether any part of the integrity plane is live this run.
    fn integrity_active(&self) -> bool {
        self.guard
            || self.audit_every > 0
            || self.fault.as_ref().is_some_and(|p| p.has_integrity_site())
    }

    /// FNV-1a digest of each lane's *serving* parameter set (hot-refreshed
    /// override where installed, the shared set otherwise) — the
    /// cross-lane divergence witness: fault-free lanes either share the
    /// group digest or match the checkpoint their refresh loaded.
    pub fn lane_digests(&self) -> Vec<u64> {
        (0..self.engines.len()).map(|l| self.lane_serving_params(l).digest()).collect()
    }

    /// Pin one shared resident feature store across every replica backend:
    /// each lane gets its own device upload ([`CacheHandle`]) over the
    /// same read-only `Arc<ResidentStore>` (DESIGN.md §7). Must be called
    /// before the first epoch — recycled buffer sets are sized for the
    /// active collection mode (same contract as `Trainer::attach_cache`).
    pub fn attach_cache(&mut self, store: Arc<ResidentStore>) -> Result<()> {
        ensure!(self.caches.is_empty(), "a resident cache is already attached");
        ensure!(
            self.arsenals.iter().all(|a| a.stats == super::ProducerStats::default()),
            "attach the cache before the first epoch (buffer sets already circulate)"
        );
        for e in &self.engines {
            self.caches.push(CacheHandle::upload(e, store.clone())?);
        }
        Ok(())
    }

    /// The attached resident store, if any.
    pub fn cache_store(&self) -> Option<&Arc<ResidentStore>> {
        self.caches.first().map(|h| &h.store)
    }

    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    pub fn round(&self) -> usize {
        self.round
    }

    pub fn dims(&self) -> Dims {
        self.d
    }

    /// The per-replica backends (e.g. for arena/counter inspection).
    pub fn engines(&self) -> &[B] {
        &self.engines
    }

    /// Cumulative producer buffer-pool traffic summed over every lane's
    /// arsenal — the CPU half of the group's zero-alloc witnesses
    /// (cf. [`super::Trainer::producer_stats`]).
    pub fn producer_stats(&self) -> ProducerStats {
        let mut s = ProducerStats::default();
        for a in &self.arsenals {
            s += a.stats;
        }
        s
    }

    /// Hot-swap one forward lane's serving parameters (DESIGN.md §10):
    /// subsequent serve drives run lane `lane` against `params` instead of
    /// the group's shared set — without rebuilding the group, re-uploading
    /// caches, or draining the other lanes. Dimensions must match the
    /// group's profile; a mismatch is an error and leaves the lane
    /// untouched (refresh is atomic). Training is unaffected: the round
    /// broadcast always snapshots the shared `params`, so a refreshed lane
    /// rejoins the synchronous trajectory on the next `train_epoch`.
    pub fn refresh_lane(&mut self, lane: usize, params: &Params) -> Result<()> {
        ensure!(
            lane < self.engines.len(),
            "lane {lane} out of range ({} lanes)",
            self.engines.len()
        );
        let d = self.d;
        ensure!(
            params.rpad == d.rpad && params.f == d.f && params.h == d.h && params.c == d.c,
            "refresh params dims [rpad {}, f {}, h {}, c {}] do not match the \
             group profile [rpad {}, f {}, h {}, c {}]",
            params.rpad,
            params.f,
            params.h,
            params.c,
            d.rpad,
            d.f,
            d.h,
            d.c
        );
        self.lane_params[lane] = Some(params.clone());
        Ok(())
    }

    /// The parameters lane `lane` currently serves with: its hot-refreshed
    /// set if one is installed, the group's shared set otherwise.
    pub fn lane_serving_params(&self, lane: usize) -> &Params {
        self.lane_params.get(lane).and_then(|p| p.as_ref()).unwrap_or(&self.params)
    }
}

impl<'g> ReplicaGroup<'g, SimBackend> {
    /// Sim-backend convenience constructor holding the whole replica policy
    /// in one place: clamps `replicas` to the round width (an extra lane
    /// could never receive a batch — and by the parity contract the clamp
    /// is invisible to the numerics), splits `cfg.threads` across the lanes
    /// via [`replica_thread_budget`], and applies the simulated launch
    /// overhead to every engine. Check [`ReplicaGroup::replicas`] for the
    /// effective lane count.
    #[allow(clippy::too_many_arguments)]
    pub fn builtin(
        profile: &str,
        replicas: usize,
        launch_overhead: Duration,
        graph: &'g HeteroGraph,
        model: ModelKind,
        opt: OptConfig,
        cfg: TrainCfg,
        round: usize,
    ) -> Result<Self> {
        let n = replicas.clamp(1, round.max(1));
        let per = replica_thread_budget(cfg.threads, n);
        let engines = (0..n)
            .map(|_| {
                let mut e = SimBackend::builtin_threaded(profile, per)?;
                e.set_launch_overhead(launch_overhead);
                Ok(e)
            })
            .collect::<Result<Vec<_>>>()?;
        Self::new(engines, graph, model, opt, cfg, round)
    }
}

// `B::Dev: Sync` lets the lanes share `&CacheHandle<B>` across the scoped
// round threads (the handle is read-only; satisfied by `SimDev`).
impl<'g, B: ExecBackend + Send> ReplicaGroup<'g, B>
where
    B::Dev: Sync,
{
    /// Train one epoch: rounds of `round` batches, each round fanned out
    /// across the replica lanes and merged with the fixed-order all-reduce.
    pub fn train_epoch(&mut self, epoch: u64) -> Result<ReplicaMetrics> {
        let d = self.d;
        let opt = self.opt;
        let model = self.model;
        let cfg = self.cfg;
        let round = self.round;
        let scfg = sampler_cfg(&cfg, &d);
        let graph = self.graph;
        let n_batches = NeighborSampler::new(graph, scfg).batches_per_epoch();
        let n_lanes = self.engines.len();
        let pool = WorkerPool::new(replica_thread_budget(cfg.threads, n_lanes));
        let m_prod = lane_producer_count(&cfg, n_lanes);
        // Lane producers split the lane's thread share further, mirroring
        // the single-backend pipelined path.
        let prod_pool = WorkerPool::new(replica_thread_budget(pool.threads(), m_prod));
        let rng = self.rng.clone();
        let fault = self.fault.clone();
        let sched = lane_schedule(n_batches, round, n_lanes);

        for e in &self.engines {
            e.reset_counters(false);
        }

        // Integrity plane (DESIGN.md §11): reset the shared injection
        // budgets and refresh the rollback snapshot up front, so every
        // epoch recovers toward a known-good state. The guard and audit
        // setters reject dev_resident, so an active plane implies the
        // host-staged step.
        let integrity = !opt.dev_resident && self.integrity_active();
        if integrity {
            self.consumed.lock().expect("integrity budget lock").clear();
            match &mut self.last_good {
                Some(s) => s.copy_from(&self.params),
                None => self.last_good = Some(self.params.clone()),
            }
        }
        let audit_every = if integrity { self.audit_every } else { 0 };
        let guard = self.guard;

        let params: &mut Params = &mut self.params;
        let schema: &SchemaTensors = &self.schema;
        let engines: &mut Vec<B> = &mut self.engines;
        let arsenals: &mut Vec<ProducerArsenal> = &mut self.arsenals;
        let caches: &[CacheHandle<B>] = &self.caches;
        let consumed: &Mutex<HashMap<(FaultSite, u64, u64), u32>> = &self.consumed;
        let last_good: &mut Option<Params> = &mut self.last_good;
        let lane_overrides: &mut [Option<Params>] = &mut self.lane_params;
        // Lanes consult the shared budgets only when the plan can inject.
        let lane_consumed = match &fault {
            Some(p) if integrity && p.has_integrity_site() => Some(consumed),
            _ => None,
        };
        let dev_schemas: &[DevSchema<B>] = &self.dev_schemas;
        // One shared epoch permutation + resident-store index across every
        // lane's producers (DESIGN.md §5/§7).
        let perm = epoch_perm(graph, &rng, epoch);
        let cache_store = caches.first().map(|h| h.store.clone());

        let wall0 = Instant::now();
        let mut loss_sum = 0.0f64;
        let mut total_correct = 0.0f64;
        let mut total_seed = 0usize;
        // Integrity epochs record per-batch metrics by global index so a
        // rollback replay overwrites in place; the fold at epoch end runs
        // once in batch order, keeping the f64 sums bitwise identical to
        // the incremental fault-free accumulation.
        let mut results = std::mem::take(&mut self.batch_results);
        if integrity {
            results.clear();
            results.resize(n_batches, (0.0, 0.0, 0));
        }
        let mut audits = 0u64;
        let mut rollbacks = 0u64;
        let mut lane_tallies: Vec<LaneTally> = Vec::new();
        // Which lanes are still alive; an injected lane fault flips this
        // for the rest of the epoch (and brands the lane's metrics with a
        // failover). Fault-free runs never touch it.
        let mut alive: Vec<bool> = vec![true; n_lanes];
        let mut epoch_result: Result<()> = Ok(());

        std::thread::scope(|s| {
            // One lane per replica; in pipeline mode each lane gets its own
            // multi-producer feed streaming its schedule, in order, with
            // credit-based backpressure (see super::pipeline).
            let mut lanes: Vec<Lane<'_, '_, B>> = engines
                .iter_mut()
                .enumerate()
                .map(|(i, eng)| {
                    let src = if opt.pipeline && !sched[i].is_empty() {
                        let seeds = arsenals[i].checkout(graph, m_prod);
                        let (feed, state_rx) = spawn_feed(
                            s,
                            graph,
                            scfg,
                            d,
                            opt,
                            prod_pool,
                            &rng,
                            epoch,
                            &sched[i],
                            m_prod,
                            seeds,
                            &perm,
                            cache_store.as_ref(),
                            fault.as_ref(),
                        );
                        LaneSource::Feed { feed, state_rx, producers: m_prod }
                    } else {
                        let mut seed = arsenals[i].checkout(graph, 1).pop().expect("one seed");
                        seed.scratch.install_epoch_perm(perm.clone(), &rng, epoch);
                        LaneSource::Inline(CpuProducer::from_seed(
                            graph,
                            scfg,
                            d,
                            opt,
                            pool,
                            rng.clone(),
                            cache_store.clone(),
                            seed,
                        ))
                    };
                    // A feed-backed lane under a plan with producer deaths
                    // arms one standby producer to re-derive lost batches
                    // from `(epoch_perm, seq)`; its state checks back into
                    // the arsenal at teardown so the steady state stays
                    // zero-alloc. Off-plan runs skip it entirely. Plans
                    // with integrity corruption sites arm it too: a
                    // guarded recompute re-derives the offending batch
                    // from the same address (DESIGN.md §11).
                    let standby = match (&src, &fault) {
                        (LaneSource::Feed { .. }, Some(p))
                            if p.has_site(FaultSite::Producer)
                                || (integrity && p.has_integrity_site()) =>
                        {
                            let mut seed =
                                arsenals[i].checkout(graph, 1).pop().expect("one seed");
                            seed.scratch.install_epoch_perm(perm.clone(), &rng, epoch);
                            Some(CpuProducer::from_seed(
                                graph,
                                scfg,
                                d,
                                opt,
                                pool,
                                rng.clone(),
                                cache_store.clone(),
                                seed,
                            ))
                        }
                        _ => None,
                    };
                    Lane {
                        eng,
                        src,
                        standby,
                        fault: fault.clone(),
                        cache: caches.get(i),
                        dev_schema: dev_schemas.get(i),
                        assemble: AssembleScratch::default(),
                        pos: 0,
                        recoveries: 0,
                        guard,
                        consumed: lane_consumed,
                        recomputes: 0,
                        cpu_time: Duration::ZERO,
                        cpu_by_stage: CpuStageTimes::default(),
                        batches: 0,
                        dropped_nodes: 0,
                        dropped_edges: 0,
                    }
                })
                .collect();

            // Group-side recovery state: the next audit mark, the first
            // batch not covered by the current snapshot, and a lazily
            // armed replay producer (recovery is allowed to allocate).
            let mut snap_mark = 0usize;
            let mut next_audit = audit_every;
            let mut replayer: Option<CpuProducer<'_>> = None;

            'rounds: for r0 in (0..n_batches).step_by(round.max(1)) {
                let len = round.min(n_batches - r0);
                let split = round_split(len, n_lanes);
                let mut round_out: Vec<Option<RoundOutput>> =
                    (0..n_lanes).map(|_| None).collect();
                let psnap: &Params = params; // the round's broadcast snapshot
                std::thread::scope(|rs| {
                    let mut handles = Vec::new();
                    for (li, (lane, &(a, l))) in lanes.iter_mut().zip(&split).enumerate() {
                        if l == 0 || !alive[li] {
                            continue;
                        }
                        let batches: Vec<usize> = (r0 + a..r0 + a + l).collect();
                        handles.push((
                            li,
                            rs.spawn(move || {
                                lane.run_round(d, opt, model, schema, psnap, epoch, &batches)
                            }),
                        ));
                    }
                    for (li, h) in handles {
                        round_out[li] = Some(h.join().expect("replica lane panicked"));
                    }
                });

                // Failover (DESIGN.md §9): a lane that died this round left
                // the tail of its slice unconsumed; a lane dead from an
                // earlier round left its whole slice. The first surviving
                // lane absorbs those slots in order — preps still come from
                // the dead lane's own producers, compute moves to the
                // survivor's backend — so the merge below sees every batch
                // of the round at its global position.
                for li in 0..n_lanes {
                    let (a, l) = split[li];
                    if l == 0 {
                        continue;
                    }
                    let died_off = if alive[li] {
                        let Some(Ok(r)) = &round_out[li] else { continue };
                        let Some(k) = r.died_at else { continue };
                        alive[li] = false;
                        k
                    } else {
                        0
                    };
                    let Some(surv) = alive.iter().position(|&x| x) else {
                        epoch_result = Err(anyhow!(
                            "injected lane fault left no surviving replicas \
                             (epoch {epoch}, round at batch {r0})"
                        ));
                        break 'rounds;
                    };
                    let slots: Vec<usize> = (r0 + a + died_off..r0 + a + l).collect();
                    let recovered = {
                        let (dead, survl) = lane_pair(&mut lanes, li, surv);
                        absorb_slots(dead, survl, d, opt, model, schema, psnap, epoch, &slots)
                    };
                    match recovered {
                        Ok(items) => {
                            if let Some(Ok(r)) = &mut round_out[li] {
                                r.items.extend(items);
                            } else {
                                round_out[li] =
                                    Some(Ok(LaneRound { items, died_at: None }));
                            }
                        }
                        Err(e) => {
                            epoch_result = Err(e);
                            break 'rounds;
                        }
                    }
                }

                // Fixed-order all-reduce: lanes hold contiguous batch
                // ranges, so iterating replicas in index order and batches
                // in lane order chains the f32 sum in global batch order —
                // the same bits no matter how many lanes computed them.
                let mut gsum: Option<Params> = None;
                let mut count = 0usize;
                for (li, lane_res) in round_out.into_iter().enumerate() {
                    let Some(lane_res) = lane_res else { continue };
                    match lane_res {
                        Ok(r) => {
                            let (a, _) = split[li];
                            for (k, (res, g)) in r.items.into_iter().enumerate() {
                                if integrity {
                                    results[r0 + a + k] =
                                        (res.loss as f64, res.ncorrect as f64, res.n_seed);
                                } else {
                                    loss_sum += res.loss as f64;
                                    total_correct += res.ncorrect as f64;
                                    total_seed += res.n_seed;
                                }
                                match gsum.as_mut() {
                                    Some(acc) => acc.add_assign(&g),
                                    None => gsum = Some(g),
                                }
                                count += 1;
                            }
                        }
                        Err(e) => {
                            epoch_result = Err(e);
                            break 'rounds;
                        }
                    }
                }
                // One SGD step with the mean round gradient; the updated
                // params are re-broadcast to the next round by reborrow.
                if let Some(g) = gsum {
                    params.sgd(&g, cfg.lr / count as f32);
                }

                // Round-boundary group audit (DESIGN.md §11). The merge is
                // the only place corruption can reach the shared
                // parameters, so auditing here bounds the damage to the
                // rounds since the last good snapshot. A violation rolls
                // back and replays those rounds sequentially on lane 0 —
                // same round structure, same merge order, so a clean
                // replay is bitwise identical to the fault-free
                // trajectory. Poisoned hot-refresh lane overrides are
                // divergence the shared trajectory never sees: clear them
                // back to the shared set (a re-broadcast) and count the
                // violation.
                let done = r0 + len;
                if audit_every > 0 && (done as u64 >= next_audit || done == n_batches) {
                    audits += 1;
                    for lp in lane_overrides.iter_mut() {
                        if lp.as_ref().is_some_and(|p| !p.is_finite()) {
                            lanes[0].eng.counters().borrow_mut().integrity_violations += 1;
                            *lp = None;
                        }
                    }
                    let mut attempts = 0u32;
                    while !params.is_finite() {
                        lanes[0].eng.counters().borrow_mut().integrity_violations += 1;
                        if attempts >= 2 {
                            epoch_result = Err(anyhow!(
                                "group parameters still non-finite after 2 rollback \
                                 replays (epoch {epoch}, batch {done}): fault exceeds \
                                 the recovery budget"
                            ));
                            break 'rounds;
                        }
                        attempts += 1;
                        rollbacks += 1;
                        if replayer.is_none() {
                            // Recovery path: arming a replay producer here
                            // may allocate — the zero-alloc contract covers
                            // the fault-free steady state only.
                            let mut seed =
                                arsenals[0].checkout(graph, 1).pop().expect("one seed");
                            seed.scratch.install_epoch_perm(perm.clone(), &rng, epoch);
                            replayer = Some(CpuProducer::from_seed(
                                graph,
                                scfg,
                                d,
                                opt,
                                pool,
                                rng.clone(),
                                cache_store.clone(),
                                seed,
                            ));
                        }
                        if let Err(e) = group_rollback_replay(
                            &mut lanes[0],
                            replayer.as_mut().expect("just armed"),
                            d,
                            opt,
                            model,
                            schema,
                            params,
                            last_good.as_ref().expect("integrity epochs snapshot up front"),
                            &mut results,
                            epoch,
                            snap_mark,
                            done,
                            round,
                            cfg.lr,
                        ) {
                            epoch_result = Err(e);
                            break 'rounds;
                        }
                    }
                    last_good
                        .as_mut()
                        .expect("integrity epochs snapshot up front")
                        .copy_from(params);
                    snap_mark = done;
                    while next_audit <= done as u64 {
                        next_audit += audit_every;
                    }
                }
            }

            lane_tallies = lanes.iter().map(|l| l.tally()).collect();
            // Tear the lanes down, returning producer state to the
            // arsenals. Finishing a feed drops its channels, which
            // unblocks any producer still parked after an early exit; the
            // scope then joins the producer threads.
            for (i, lane) in lanes.into_iter().enumerate() {
                match lane.src {
                    LaneSource::Feed { feed, state_rx, producers } => {
                        arsenals[i].checkin_bufs(feed.finish());
                        for state in state_rx.iter().take(producers) {
                            arsenals[i].checkin(state);
                        }
                    }
                    LaneSource::Inline(p) => arsenals[i].checkin(p.into_state()),
                }
                if let Some(sb) = lane.standby {
                    arsenals[i].checkin(sb.into_state());
                }
            }
            if let Some(rp) = replayer {
                arsenals[0].checkin(rp.into_state());
            }
        });
        if integrity {
            for &(l, c, n) in &results {
                loss_sum += l;
                total_correct += c;
                total_seed += n;
            }
        }
        self.batch_results = results;
        epoch_result?;

        let mut per_replica: Vec<EpochMetrics> = Vec::with_capacity(n_lanes);
        for (i, (eng, t)) in engines.iter().zip(&lane_tallies).enumerate() {
            let mut pm = EpochMetrics {
                cpu_time: t.cpu_time,
                cpu_by_stage: t.cpu_by_stage,
                producer: arsenals[i].stats,
                batches: t.batches,
                dropped_nodes: t.dropped_nodes,
                dropped_edges: t.dropped_edges,
                producer_recoveries: t.recoveries as u64,
                lane_failovers: u64::from(!alive[i]),
                integrity_recomputes: t.recomputes as u64,
                ..Default::default()
            };
            pm.fill_from_counters(&eng.counters().borrow());
            per_replica.push(pm);
        }
        let mut group = EpochMetrics::default();
        for pr in &per_replica {
            group.absorb(pr);
        }
        // Group-side recovery work (audits, rollbacks) belongs to the
        // group view — no single lane performed it.
        group.audits += audits;
        group.integrity_rollbacks += rollbacks;
        group.wall = wall0.elapsed();
        group.loss = loss_sum / n_batches.max(1) as f64;
        group.acc = total_correct / total_seed.max(1) as f64;
        Ok(ReplicaMetrics { group, per_replica })
    }

    /// Forward-only, epoch-less drive of the replica lanes over a
    /// coalesced serve schedule (DESIGN.md §8): coalesced batch `i` —
    /// seed set `batches[i]` — is sampled through the serve stream
    /// ([`NeighborSampler::sample_request_into`] via
    /// [`CpuProducer::produce_request`]), assembled exactly like a
    /// training batch (same feature channel, including the resident
    /// cache), and run through `StepExecutor::forward_step` on lane
    /// `i % replicas` against the group's current (frozen) parameters. No
    /// gradients, no all-reduce, no parameter update.
    ///
    /// With `OptConfig::pipeline` on, each lane overlaps CPU batch
    /// preparation with its forward compute through a depth-bounded queue
    /// ([`PIPELINE_DEPTH`]); consumed buffers cycle back to the lane's
    /// producer and its arsenal persists across calls, extending the
    /// zero-alloc steady state to serving. Either way every prediction is
    /// a bitwise function of (params, batch index, seed set): the lane
    /// count, producer mode, and thread budget are scheduling choices,
    /// never semantic ones (pinned by `tests/serve_parity.rs`).
    ///
    /// Returns per-batch `[NS, C]` logits plus the wall service time of
    /// the assemble+forward step, in batch order. Thin wrapper over
    /// [`Self::serve_forward_churn`] with no refresh events and the
    /// default probation — bitwise identical schedules when the attached
    /// fault plan has no [`FaultSite::LaneHard`] entries.
    pub fn serve_forward(&mut self, batches: &[Vec<u32>]) -> Result<Vec<(HostTensor, Duration)>> {
        Ok(self.serve_forward_churn(batches, &[], DEFAULT_PROBATION)?.stepped)
    }

    /// [`Self::serve_forward`] under churn (DESIGN.md §10): the same
    /// forward-only drive, plus
    ///
    /// * **hot refresh** — `refreshes` (sorted here by `at_batch`) switch
    ///   every lane to new parameters as it crosses the event's global
    ///   batch boundary, so batch `bi` is served by the latest event with
    ///   `at_batch <= bi` (or the lane's base set) *regardless of which
    ///   lane runs it*; device-resident lanes re-stage their device params
    ///   at the boundary;
    /// * **quarantine** — [`FaultSite::LaneHard`] firings resolved by
    ///   [`plan_churn`] before any thread spawns: quarantined lanes leave
    ///   the rotation, their batches re-dispatch to the next healthy lane
    ///   in global batch order (bitwise-identical predictions), and they
    ///   shadow `probation` batches (output discarded, no fault cursor —
    ///   dispatch-fault accounting is churn-invariant) before re-admission.
    ///   Zero healthy lanes is the typed [`NoHealthyLanes`] error.
    pub fn serve_forward_churn(
        &mut self,
        batches: &[Vec<u32>],
        refreshes: &[RefreshEvent],
        probation: usize,
    ) -> Result<ServeDrive> {
        let d = self.d;
        let opt = self.opt;
        let model = self.model;
        let cfg = self.cfg;
        let scfg = sampler_cfg(&cfg, &d);
        let graph = self.graph;
        let n_lanes = self.engines.len();
        let pool = WorkerPool::new(replica_thread_budget(cfg.threads, n_lanes));
        let rng = self.rng.clone();
        let schema: &SchemaTensors = &self.schema;
        let params: &Params = &self.params;
        let lane_params: &[Option<Params>] = &self.lane_params;
        // Serve integrity plane (DESIGN.md §11): `nan!` entries poison the
        // admitted execution's logits at `(epoch 0, seq bi)`; the guard
        // scans them and recomputes once through the lane's own producer.
        // Budgets reset per drive so repeated drives replay identically.
        let guard = self.guard;
        let serve_consumed = match &self.fault {
            Some(p) if p.has_integrity_site() => {
                self.consumed.lock().expect("integrity budget lock").clear();
                Some(&self.consumed)
            }
            _ => None,
        };
        let fault = self.fault.clone();
        // Lanes flagged suspect by the previous drive's guard start this
        // drive quarantined (probation shadowing before re-admission).
        let pre_quarantined = std::mem::take(&mut self.suspects);
        let engines: &mut Vec<B> = &mut self.engines;
        let arsenals: &mut Vec<ProducerArsenal> = &mut self.arsenals;
        let caches: &[CacheHandle<B>] = &self.caches;
        let dev_schemas: &[DevSchema<B>] = &self.dev_schemas;
        let cache_store = caches.first().map(|h| h.store.clone());

        for ev in refreshes {
            let p = &ev.params;
            ensure!(
                p.rpad == d.rpad && p.f == d.f && p.h == d.h && p.c == d.c,
                "refresh event at batch {} has params dims [rpad {}, f {}, h {}, c {}] \
                 but the group profile is [rpad {}, f {}, h {}, c {}]",
                ev.at_batch,
                p.rpad,
                p.f,
                p.h,
                p.c,
                d.rpad,
                d.f,
                d.h,
                d.c
            );
        }
        // Boundary order is global-batch order; sort so each lane walks
        // the timeline with one monotone cursor.
        let refs: Vec<RefreshEvent> = {
            let mut v = refreshes.to_vec();
            v.sort_by_key(|r| r.at_batch);
            v
        };
        let refs: &[RefreshEvent] = &refs;

        // Quarantine churn resolved up front into per-lane slot lists — a
        // pure function of (fault plan, batch count, lane count), never of
        // thread interleaving. Without LaneHard entries this is exactly
        // the historical `bi % n_lanes` round-robin.
        let sched = plan_churn(batches.len(), n_lanes, fault.as_deref(), probation, &pre_quarantined)?;

        let mut results: Vec<Option<(HostTensor, Duration)>> =
            (0..batches.len()).map(|_| None).collect();
        let mut lane_err: Result<()> = Ok(());
        // Per-lane guarded-violation tallies, gathered at join: feeds the
        // drive stats and the suspect list for the next drive.
        let mut lane_violations: Vec<(usize, u64, u64)> = Vec::new();

        std::thread::scope(|s| {
            let mut consumers = Vec::new();
            let mut state_rxs: Vec<(usize, Receiver<ProducerState>, usize)> = Vec::new();
            for (li, (eng, lane_sched)) in engines.iter_mut().zip(&sched.lanes).enumerate() {
                if lane_sched.is_empty() {
                    continue;
                }
                let seed = arsenals[li].checkout(graph, 1).pop().expect("one seed");
                let cache = caches.get(li);
                let lane_ds = dev_schemas.get(li);
                let lane_rng = rng.clone();
                let lane_store = cache_store.clone();
                let lane_plan = fault.clone();
                // Lane base set: a prior `refresh_lane` override, else the
                // shared params. Refresh events supersede both.
                let base: &Params = lane_params[li].as_ref().unwrap_or(params);
                let (stx, srx) = mpsc::channel::<ProducerState>();
                if opt.pipeline {
                    // A guarded pipelined lane arms a standby producer for
                    // integrity recomputes — the feed producer cannot be
                    // asked to re-derive out of sequence (DESIGN.md §11).
                    let serve_standby = if guard && serve_consumed.is_some() {
                        Some(arsenals[li].checkout(graph, 1).pop().expect("one seed"))
                    } else {
                        None
                    };
                    state_rxs.push((li, srx, 1 + usize::from(serve_standby.is_some())));
                    let stx2 = stx.clone();
                    let sb_rng = rng.clone();
                    let sb_store = cache_store.clone();
                    // Depth-bounded lane queue: the producer thread stays
                    // at most PIPELINE_DEPTH batches ahead; consumed
                    // buffers return through the recycle channel. Shadow
                    // slots flow through the same queue — same prep bits,
                    // same seq — so probation exercises the full path.
                    let (tx, rx) = mpsc::sync_channel::<PreparedCpu>(PIPELINE_DEPTH);
                    let (btx, brx) = mpsc::channel::<BatchBufs>();
                    s.spawn(move || {
                        let mut p = CpuProducer::from_seed(
                            graph, scfg, d, opt, pool, lane_rng, lane_store, seed,
                        );
                        // Fixed circulating population: never fresh-allocate
                        // mid-stream because a return raced the schedule.
                        p.preallocate(PIPELINE_DEPTH + 1);
                        for &(bi, _) in lane_sched {
                            while let Ok(b) = brx.try_recv() {
                                p.reclaim(b);
                            }
                            let prep = p.produce_request(bi as u64, &batches[bi]);
                            if tx.send(prep).is_err() {
                                break; // consumer aborted
                            }
                        }
                        drop(tx);
                        let mut state = p.into_state();
                        // Keep the recycle queue alive: a return that raced
                        // this exit is recovered at arsenal check-in.
                        state.returns = Some(brx);
                        let _ = stx.send(state);
                    });
                    consumers.push((
                        li,
                        s.spawn(move || -> Result<(Vec<(usize, HostTensor, Duration)>, u64, u64)> {
                            let exec = StepExecutor::new(&*eng, model, opt);
                            let mut standby = serve_standby.map(|seed| {
                                CpuProducer::from_seed(
                                    graph, scfg, d, opt, pool, sb_rng, sb_store, seed,
                                )
                            });
                            // Device-resident serve: stage the lane's params
                            // before the batch loop; re-staged whenever a
                            // refresh boundary is crossed.
                            let mut cur: &Params = base;
                            let mut ri = 0usize;
                            let mut dev_params = match lane_ds {
                                Some(_) => Some(exec.upload_params_peer(cur)?),
                                None => None,
                            };
                            let mut assemble = AssembleScratch::default();
                            let mut out = Vec::with_capacity(lane_sched.len());
                            let mut violations = 0u64;
                            let mut recomputes = 0u64;
                            for &(bi, shadow) in lane_sched {
                                let prep = rx.recv().map_err(|_| {
                                    anyhow!("serve producer for lane {li} exited early")
                                })?;
                                let mut swapped = false;
                                while ri < refs.len() && refs[ri].at_batch <= bi {
                                    cur = &refs[ri].params;
                                    ri += 1;
                                    swapped = true;
                                }
                                if swapped {
                                    if let Some(dp) = dev_params.take() {
                                        exec.recycle_dev_params(dp);
                                    }
                                    if lane_ds.is_some() {
                                        dev_params = Some(exec.upload_params_peer(cur)?);
                                    }
                                }
                                if !shadow {
                                    eng.fault_cursor(0, bi as u64);
                                }
                                let t0 = Instant::now();
                                let (mut logits, bufs) = serve_one(
                                    &*eng,
                                    &exec,
                                    &d,
                                    schema,
                                    cur,
                                    cache,
                                    dev_params.as_ref().zip(lane_ds),
                                    &mut assemble,
                                    prep,
                                )?;
                                let mut bufs = Some(bufs);
                                if !shadow {
                                    inject_logit_nan(
                                        lane_plan.as_deref(),
                                        serve_consumed,
                                        &mut logits,
                                        bi,
                                    );
                                    if guard && !logits_finite(&logits) {
                                        violations += 1;
                                        recomputes += 1;
                                        eng.counters().borrow_mut().integrity_violations += 1;
                                        // First attempt's buffers keep the
                                        // feed credits flowing; the retry
                                        // cycles through the standby.
                                        let _ = btx.send(bufs.take().expect("first attempt"));
                                        let sb = standby
                                            .as_mut()
                                            .expect("guarded serve lanes arm a standby");
                                        let p2 = sb.produce_request(bi as u64, &batches[bi]);
                                        let (l2, b2) = serve_one(
                                            &*eng,
                                            &exec,
                                            &d,
                                            schema,
                                            cur,
                                            cache,
                                            dev_params.as_ref().zip(lane_ds),
                                            &mut assemble,
                                            p2,
                                        )?;
                                        logits = l2;
                                        sb.reclaim(b2);
                                        inject_logit_nan(
                                            lane_plan.as_deref(),
                                            serve_consumed,
                                            &mut logits,
                                            bi,
                                        );
                                        if !logits_finite(&logits) {
                                            eng.counters().borrow_mut().integrity_violations += 1;
                                            bail!(
                                                "serve batch {bi} still non-finite after a \
                                                 recompute: persistent corruption"
                                            );
                                        }
                                    }
                                    out.push((bi, logits, t0.elapsed()));
                                }
                                if let Some(b) = bufs {
                                    let _ = btx.send(b);
                                }
                            }
                            if let Some(dp) = dev_params.take() {
                                exec.recycle_dev_params(dp);
                            }
                            if let Some(sb) = standby.take() {
                                let _ = stx2.send(sb.into_state());
                            }
                            Ok((out, violations, recomputes))
                        }),
                    ));
                } else {
                    state_rxs.push((li, srx, 1));
                    consumers.push((
                        li,
                        s.spawn(move || -> Result<(Vec<(usize, HostTensor, Duration)>, u64, u64)> {
                            let mut p = CpuProducer::from_seed(
                                graph, scfg, d, opt, pool, lane_rng, lane_store, seed,
                            );
                            let exec = StepExecutor::new(&*eng, model, opt);
                            let mut cur: &Params = base;
                            let mut ri = 0usize;
                            let mut dev_params = match lane_ds {
                                Some(_) => Some(exec.upload_params_peer(cur)?),
                                None => None,
                            };
                            let mut assemble = AssembleScratch::default();
                            let mut out = Vec::with_capacity(lane_sched.len());
                            let mut violations = 0u64;
                            let mut recomputes = 0u64;
                            let mut err = None;
                            for &(bi, shadow) in lane_sched {
                                let prep = p.produce_request(bi as u64, &batches[bi]);
                                let mut swapped = false;
                                while ri < refs.len() && refs[ri].at_batch <= bi {
                                    cur = &refs[ri].params;
                                    ri += 1;
                                    swapped = true;
                                }
                                if swapped {
                                    if let Some(dp) = dev_params.take() {
                                        exec.recycle_dev_params(dp);
                                    }
                                    if lane_ds.is_some() {
                                        match exec.upload_params_peer(cur) {
                                            Ok(dp) => dev_params = Some(dp),
                                            Err(e) => {
                                                err = Some(e);
                                                break;
                                            }
                                        }
                                    }
                                }
                                if !shadow {
                                    eng.fault_cursor(0, bi as u64);
                                }
                                let t0 = Instant::now();
                                let step = serve_one(
                                    &*eng,
                                    &exec,
                                    &d,
                                    schema,
                                    cur,
                                    cache,
                                    dev_params.as_ref().zip(lane_ds),
                                    &mut assemble,
                                    prep,
                                );
                                match step {
                                    Ok((mut logits, bufs)) => {
                                        p.reclaim(bufs);
                                        if !shadow {
                                            inject_logit_nan(
                                                lane_plan.as_deref(),
                                                serve_consumed,
                                                &mut logits,
                                                bi,
                                            );
                                            if guard && !logits_finite(&logits) {
                                                violations += 1;
                                                recomputes += 1;
                                                eng.counters()
                                                    .borrow_mut()
                                                    .integrity_violations += 1;
                                                let p2 = p
                                                    .produce_request(bi as u64, &batches[bi]);
                                                match serve_one(
                                                    &*eng,
                                                    &exec,
                                                    &d,
                                                    schema,
                                                    cur,
                                                    cache,
                                                    dev_params.as_ref().zip(lane_ds),
                                                    &mut assemble,
                                                    p2,
                                                ) {
                                                    Ok((l2, b2)) => {
                                                        logits = l2;
                                                        p.reclaim(b2);
                                                        inject_logit_nan(
                                                            lane_plan.as_deref(),
                                                            serve_consumed,
                                                            &mut logits,
                                                            bi,
                                                        );
                                                        if !logits_finite(&logits) {
                                                            eng.counters()
                                                                .borrow_mut()
                                                                .integrity_violations += 1;
                                                            err = Some(anyhow!(
                                                                "serve batch {bi} still \
                                                                 non-finite after a recompute: \
                                                                 persistent corruption"
                                                            ));
                                                            break;
                                                        }
                                                    }
                                                    Err(e) => {
                                                        err = Some(e);
                                                        break;
                                                    }
                                                }
                                            }
                                            out.push((bi, logits, t0.elapsed()));
                                        }
                                    }
                                    Err(e) => {
                                        err = Some(e);
                                        break;
                                    }
                                }
                            }
                            if let Some(dp) = dev_params.take() {
                                exec.recycle_dev_params(dp);
                            }
                            let _ = stx.send(p.into_state());
                            match err {
                                Some(e) => Err(e),
                                None => Ok((out, violations, recomputes)),
                            }
                        }),
                    ));
                }
            }
            for (li, h) in consumers {
                match h.join().expect("serve lane panicked") {
                    Ok((items, violations, recomputes)) => {
                        for (bi, logits, dur) in items {
                            results[bi] = Some((logits, dur));
                        }
                        if violations > 0 || recomputes > 0 {
                            lane_violations.push((li, violations, recomputes));
                        }
                    }
                    Err(e) => lane_err = Err(e),
                }
            }
            // Recover every lane's producer state (blocking: the send
            // happens on every exit path, including consumer aborts; a
            // lane that errored before sending its standby state just
            // yields fewer items — the channel closes with the senders).
            for (li, srx, n) in state_rxs {
                for state in srx.iter().take(n) {
                    arsenals[li].checkin(state);
                }
            }
        });
        let mut stats = sched.stats;
        let mut suspect_lanes = Vec::new();
        for &(li, violations, recomputes) in &lane_violations {
            stats.integrity_violations += violations;
            stats.integrity_recomputes += recomputes;
            // Repeated guarded violations brand the lane suspect: the
            // next churn drive starts it quarantined (DESIGN.md §11).
            if violations >= 2 {
                suspect_lanes.push(li);
            }
        }
        self.suspects = suspect_lanes.clone();
        lane_err?;
        let stepped = results
            .into_iter()
            .map(|r| r.expect("serve batch missing from lane output"))
            .collect();
        Ok(ServeDrive { stepped, primary_lane: sched.primary, stats, suspect_lanes })
    }
}

/// One serve batch on one lane: assemble + forward, host-staged or
/// device-resident depending on `dev` (the lane's staged frozen parameters
/// + schema constants). Returns the `[NS, C]` logits — the serve path's
/// only per-batch D2H in device-resident mode — and the reclaimed buffers.
#[allow(clippy::too_many_arguments)]
fn serve_one<B: ExecBackend>(
    eng: &B,
    exec: &StepExecutor<'_, B>,
    d: &Dims,
    schema: &SchemaTensors,
    params: &Params,
    cache: Option<&CacheHandle<B>>,
    dev: Option<(&DevParams<B>, &DevSchema<B>)>,
    assemble: &mut AssembleScratch,
    prep: PreparedCpu,
) -> Result<(HostTensor, BatchBufs)> {
    match dev {
        Some((dp, ds)) => {
            let (batch, spent, xs_dev) =
                assemble_batch_dev(eng, d, schema, cache, assemble, prep)?;
            let dev_batch = exec.upload_batch(&batch, xs_dev)?;
            let logits = exec.forward_step_dev(dp, ds, &dev_batch)?;
            exec.recycle_batch(dev_batch);
            Ok((logits, spent.reclaim(batch)))
        }
        None => {
            let (batch, spent) = assemble_batch(eng, d, schema, cache, assemble, prep)?;
            let logits = exec.forward_step(params, schema, &batch)?;
            Ok((logits, spent.reclaim(batch)))
        }
    }
}

/// Where a lane's prepared batches come from: its multi-producer feed
/// (pipeline mode) or an inline producer it drives itself.
enum LaneSource<'g> {
    Feed { feed: BatchFeed, state_rx: Receiver<ProducerState>, producers: usize },
    Inline(CpuProducer<'g>),
}

/// One replica's execution lane: exclusive backend access plus the CPU-side
/// tallies the per-replica metrics report.
struct Lane<'e, 'g, B: ExecBackend> {
    eng: &'e mut B,
    src: LaneSource<'g>,
    /// Re-derives batches lost to injected producer deaths from
    /// `(epoch_perm, seq)` (DESIGN.md §9). Armed only for feed-backed lanes
    /// under a plan with [`FaultSite::Producer`] entries.
    standby: Option<CpuProducer<'g>>,
    /// The attached fault plan, consulted per batch for lane deaths;
    /// `None` = zero-cost fault-free path.
    fault: Option<Arc<FaultPlan>>,
    /// This replica's feature-cache handle (shared read-only store, own
    /// device upload); `None` = cache off.
    cache: Option<&'e CacheHandle<B>>,
    /// This replica's device-resident schema constants; `Some` iff
    /// `opt.dev_resident` (see [`ReplicaGroup::dev_schemas`]).
    dev_schema: Option<&'e DevSchema<B>>,
    /// Consumer-side pooled scratch for `assemble_batch`.
    assemble: AssembleScratch,
    /// Next position in this lane's schedule (feed sequence numbering).
    pos: usize,
    /// Batches re-derived on the standby after an injected producer death.
    recoveries: usize,
    /// Numeric guard rails on (DESIGN.md §11): digest-check features,
    /// finite-check loss/gradients, recompute once on a violation.
    guard: bool,
    /// Shared `(site, epoch, seq)` injection budgets, present iff the
    /// attached plan has integrity corruption sites. Every attempt — lane
    /// step, recompute, group replay — draws from the same budget.
    consumed: Option<&'e Mutex<HashMap<(FaultSite, u64, u64), u32>>>,
    /// Batches recomputed after a guarded integrity violation.
    recomputes: usize,
    cpu_time: Duration,
    cpu_by_stage: CpuStageTimes,
    batches: usize,
    dropped_nodes: usize,
    dropped_edges: usize,
}

#[derive(Clone, Copy, Default)]
struct LaneTally {
    cpu_time: Duration,
    cpu_by_stage: CpuStageTimes,
    batches: usize,
    dropped_nodes: usize,
    dropped_edges: usize,
    recoveries: usize,
    recomputes: usize,
}

impl<'e, 'g, B: ExecBackend> Lane<'e, 'g, B> {
    /// Compute gradients for this lane's slice of one round, against the
    /// round's parameter snapshot. Returns `(step result, gradient)` per
    /// batch, in batch order. Consumed buffers cycle straight back to the
    /// producers.
    #[allow(clippy::too_many_arguments)]
    fn run_round(
        &mut self,
        d: Dims,
        opt: OptConfig,
        model: ModelKind,
        schema: &SchemaTensors,
        params: &Params,
        epoch: u64,
        batches: &[usize],
    ) -> RoundOutput {
        // Integrity plane (DESIGN.md §11): the host-staged step gains the
        // inject → guard → recompute-once ladder. Device-resident lanes
        // keep the classic path — the guard/audit setters reject
        // dev_resident, and the corruption sites do not inject there.
        if self.dev_schema.is_none() && (self.guard || self.consumed.is_some()) {
            return self.run_round_integrity(d, opt, model, schema, params, epoch, batches);
        }
        let exec = StepExecutor::new(&*self.eng, model, opt);
        // Device-resident round state (DESIGN.md §7): the round's parameter
        // snapshot broadcast over the modeled interconnect (p2p), dropped
        // back into the arena when the round ends. The schema constants
        // persist across rounds and epochs ([`ReplicaGroup::dev_schemas`]).
        let mut dev_params = match self.dev_schema {
            Some(_) => Some(exec.upload_params_peer(params)?),
            None => None,
        };
        let mut out = Vec::with_capacity(batches.len());
        let mut died_at = None;
        for (off, &b) in batches.iter().enumerate() {
            // An injected lane death fires *before* the batch's prep is
            // consumed, so the failover path can pull it from this lane's
            // still-running source.
            if let Some(p) = &self.fault {
                if p.fires(FaultSite::Lane, epoch, b as u64) > 0 {
                    died_at = Some(off);
                    break;
                }
            }
            let (prep, from_standby) =
                next_prep(&mut self.src, &mut self.standby, &mut self.recoveries, epoch, b)?;
            self.cpu_time += prep.cpu_time;
            self.cpu_by_stage += prep.cpu_by_stage;
            self.dropped_nodes += prep.dropped_nodes();
            self.dropped_edges += prep.dropped_edges();
            self.batches += 1;
            self.eng.fault_cursor(epoch, b as u64);
            let (res, bufs) = if let (Some(dp), Some(ds)) = (&dev_params, self.dev_schema) {
                // Device-resident step: activations stay on-device; the
                // per-batch gradient returns over the interconnect
                // (fetch_grads_peer) in host accumulation order, so the
                // all-reduce below is bitwise unchanged.
                let (batch, spent, xs_dev) = assemble_batch_dev(
                    &*self.eng,
                    &d,
                    schema,
                    self.cache,
                    &mut self.assemble,
                    prep,
                )?;
                let dev_batch = exec.upload_batch(&batch, xs_dev)?;
                let mut grads = DevGrads::empty();
                let sres = exec.grad_step_dev(dp, ds, &dev_batch, &mut grads)?;
                exec.recycle_batch(dev_batch);
                let g = exec.fetch_grads_peer(grads, params)?;
                ((sres, g), spent.reclaim(batch))
            } else {
                let (batch, spent) = assemble_batch(
                    &*self.eng,
                    &d,
                    schema,
                    self.cache,
                    &mut self.assemble,
                    prep,
                )?;
                let res = exec.grad_step(params, schema, &batch)?;
                (res, spent.reclaim(batch))
            };
            let pos = self.pos;
            self.pos += 1;
            route_bufs(&mut self.src, &mut self.standby, pos, bufs, from_standby);
            out.push(res);
        }
        if let Some(dp) = dev_params.take() {
            exec.recycle_dev_params(dp);
        }
        Ok(LaneRound { items: out, died_at })
    }

    /// [`Self::run_round`] with the lane-side integrity ladder
    /// (DESIGN.md §11). Gradients computed here have not entered the round
    /// merge yet — the shared parameters are never at risk from a batch
    /// this path is still chewing on — so one recompute from the lane's
    /// own source is the entire lane-side recovery; rollback is the
    /// group's job, at round boundaries.
    #[allow(clippy::too_many_arguments)]
    fn run_round_integrity(
        &mut self,
        d: Dims,
        opt: OptConfig,
        model: ModelKind,
        schema: &SchemaTensors,
        params: &Params,
        epoch: u64,
        batches: &[usize],
    ) -> RoundOutput {
        let mut out = Vec::with_capacity(batches.len());
        let mut died_at = None;
        for (off, &b) in batches.iter().enumerate() {
            if let Some(p) = &self.fault {
                if p.fires(FaultSite::Lane, epoch, b as u64) > 0 {
                    died_at = Some(off);
                    break;
                }
            }
            let (prep, from_standby) =
                next_prep(&mut self.src, &mut self.standby, &mut self.recoveries, epoch, b)?;
            self.cpu_time += prep.cpu_time;
            self.cpu_by_stage += prep.cpu_by_stage;
            self.dropped_nodes += prep.dropped_nodes();
            self.dropped_edges += prep.dropped_edges();
            self.batches += 1;
            let (res, bufs) = integrity_step_host(self, d, opt, model, schema, params, epoch, b, prep)?;
            let pos = self.pos;
            self.pos += 1;
            route_bufs(&mut self.src, &mut self.standby, pos, bufs, from_standby);
            out.push(res);
        }
        Ok(LaneRound { items: out, died_at })
    }

    fn tally(&self) -> LaneTally {
        LaneTally {
            cpu_time: self.cpu_time,
            cpu_by_stage: self.cpu_by_stage,
            batches: self.batches,
            dropped_nodes: self.dropped_nodes,
            dropped_edges: self.dropped_edges,
            recoveries: self.recoveries,
            recomputes: self.recomputes,
        }
    }
}

/// Pull a lane's next scheduled prepared batch from its source,
/// re-deriving it on the standby producer when an injected producer death
/// lost the sequence number. Returns `(prep, came_from_standby)`.
fn next_prep<'g>(
    src: &mut LaneSource<'g>,
    standby: &mut Option<CpuProducer<'g>>,
    recoveries: &mut usize,
    epoch: u64,
    b: usize,
) -> Result<(PreparedCpu, bool)> {
    match src {
        LaneSource::Feed { feed, .. } => match feed.recv_next()? {
            FeedSlot::Batch(p) => Ok((p, false)),
            FeedSlot::Lost => {
                let sb = standby
                    .as_mut()
                    .expect("standby producer armed whenever producer faults are planned");
                *recoveries += 1;
                Ok((sb.produce(epoch, b), true))
            }
        },
        LaneSource::Inline(p) => Ok((p.produce(epoch, b), false)),
    }
}

/// Cycle a consumed batch's buffers back to whoever produced them: the feed
/// position's producer, the standby (for re-derived batches), or the inline
/// producer.
fn route_bufs(
    src: &mut LaneSource<'_>,
    standby: &mut Option<CpuProducer<'_>>,
    pos: usize,
    bufs: BatchBufs,
    from_standby: bool,
) {
    if from_standby {
        standby.as_mut().expect("standby produced this batch").reclaim(bufs);
        return;
    }
    match src {
        LaneSource::Feed { feed, .. } => feed.recycle(pos, bufs),
        LaneSource::Inline(p) => p.reclaim(bufs),
    }
}

/// Re-derive one batch from `(epoch_perm, seq)` for an integrity
/// recompute: inline lanes re-run their own producer (pure in the
/// address), feed-backed lanes use the standby armed at lane
/// construction.
fn reproduce<'g>(
    src: &mut LaneSource<'g>,
    standby: &mut Option<CpuProducer<'g>>,
    epoch: u64,
    b: usize,
) -> Result<PreparedCpu> {
    match src {
        LaneSource::Inline(p) => Ok(p.produce(epoch, b)),
        LaneSource::Feed { .. } => {
            let sb = standby
                .as_mut()
                .ok_or_else(|| anyhow!("integrity recompute needs the armed standby producer"))?;
            Ok(sb.produce(epoch, b))
        }
    }
}

/// Return a recompute attempt's buffers to whichever producer
/// [`reproduce`] drew them from.
fn reclaim_retry<'g>(
    src: &mut LaneSource<'g>,
    standby: &mut Option<CpuProducer<'g>>,
    bufs: BatchBufs,
) {
    match src {
        LaneSource::Inline(p) => p.reclaim(bufs),
        LaneSource::Feed { .. } => {
            standby.as_mut().expect("standby produced this retry").reclaim(bufs);
        }
    }
}

/// FNV-1a over the feature payload a `flip!` entry can corrupt: the miss
/// rows when the resident cache is on (the hit rows never leave the
/// read-only store), the full gathered matrix otherwise. `None` = nothing
/// to digest (all-hit batch).
fn lane_feature_digest(cached: bool, f: usize, prep: &PreparedCpu) -> Option<u64> {
    let c = &prep.collected;
    if cached {
        let n = c.n_miss * f;
        if n == 0 {
            return None;
        }
        Some(fnv1a_f32(&c.miss_rows.as_f32().ok()?[..n]))
    } else {
        Some(fnv1a_f32(c.xs.as_f32().ok()?))
    }
}

/// Consume one unit of the shared `(site, epoch, seq)` injection budget.
/// Returns `false` when the plan's multiplicity at that address is spent —
/// which is exactly what lets recompute and replay converge instead of
/// re-poisoning themselves forever.
fn take_budget(
    consumed: &Mutex<HashMap<(FaultSite, u64, u64), u32>>,
    site: FaultSite,
    epoch: u64,
    seq: u64,
    planned: u32,
) -> bool {
    let mut map = consumed.lock().expect("integrity budget lock");
    let used = map.entry((site, epoch, seq)).or_insert(0);
    if *used >= planned {
        return false;
    }
    *used += 1;
    true
}

/// Deterministic `flip!` corruption of a lane's feature payload
/// (DESIGN.md §11): XOR one mantissa bit of one element — finite, silent,
/// detectable only by digest. Budgeted through the shared consumed map.
fn inject_lane_flip<B: ExecBackend>(
    lane: &mut Lane<'_, '_, B>,
    f: usize,
    prep: &mut PreparedCpu,
    epoch: u64,
    seq: u64,
) {
    let Some(consumed) = lane.consumed else { return };
    let Some(plan) = lane.fault.clone() else { return };
    let planned = plan.fires(FaultSite::Flip, epoch, seq);
    if planned == 0 {
        return;
    }
    let cached = lane.cache.is_some();
    let c = &mut prep.collected;
    let payload: &mut [f32] = if cached {
        let n = c.n_miss * f;
        if n == 0 {
            return; // all-hit batch: nothing staged host-side to corrupt
        }
        match c.miss_rows.as_f32_mut() {
            Ok(s) => &mut s[..n],
            Err(_) => return,
        }
    } else {
        match c.xs.as_f32_mut() {
            Ok(s) => s,
            Err(_) => return,
        }
    };
    if payload.is_empty() || !take_budget(consumed, FaultSite::Flip, epoch, seq, planned) {
        return;
    }
    let h = plan.target_hash(FaultSite::Flip, epoch, seq);
    let i = (h % payload.len() as u64) as usize;
    let bit = ((h >> 40) % 23) as u32;
    payload[i] = f32::from_bits(payload[i].to_bits() ^ (1u32 << bit));
}

/// Deterministic `nan!` corruption of a lane's computed gradient
/// (DESIGN.md §11): one element of `w0` becomes NaN — non-finite, so the
/// guard's scan (or a later group audit) can see it. Budgeted through the
/// shared consumed map.
fn inject_lane_nan<B: ExecBackend>(
    lane: &mut Lane<'_, '_, B>,
    grads: &mut Params,
    epoch: u64,
    seq: u64,
) {
    let Some(consumed) = lane.consumed else { return };
    let Some(plan) = lane.fault.clone() else { return };
    let planned = plan.fires(FaultSite::Nan, epoch, seq);
    if planned == 0 || grads.w0.is_empty() {
        return;
    }
    if !take_budget(consumed, FaultSite::Nan, epoch, seq, planned) {
        return;
    }
    let h = plan.target_hash(FaultSite::Nan, epoch, seq);
    grads.w0[(h % grads.w0.len() as u64) as usize] = f32::NAN;
}

/// One host-staged lane attempt: inject the planned corruptions, run the
/// guard checks, compute. `Violation` means the guard refused the result
/// before it could enter the round merge — nothing shared was touched.
enum LaneAttempt {
    Clean((StepResult, Params), BatchBufs),
    Violation(BatchBufs),
}

fn lane_attempt<B: ExecBackend>(
    lane: &mut Lane<'_, '_, B>,
    d: Dims,
    opt: OptConfig,
    model: ModelKind,
    schema: &SchemaTensors,
    params: &Params,
    epoch: u64,
    b: usize,
    mut prep: PreparedCpu,
) -> Result<LaneAttempt> {
    let guard = lane.guard;
    let expect = if guard { lane_feature_digest(lane.cache.is_some(), d.f, &prep) } else { None };
    inject_lane_flip(lane, d.f, &mut prep, epoch, b as u64);
    if let Some(e) = expect {
        if lane_feature_digest(lane.cache.is_some(), d.f, &prep) != Some(e) {
            return Ok(LaneAttempt::Violation(prep.into_bufs()));
        }
    }
    lane.eng.fault_cursor(epoch, b as u64);
    let exec = StepExecutor::new(&*lane.eng, model, opt);
    let (batch, spent) =
        assemble_batch(&*lane.eng, &d, schema, lane.cache, &mut lane.assemble, prep)?;
    let (sres, mut g) = exec.grad_step(params, schema, &batch)?;
    inject_lane_nan(lane, &mut g, epoch, b as u64);
    if guard && !(sres.loss.is_finite() && g.is_finite()) {
        return Ok(LaneAttempt::Violation(spent.reclaim(batch)));
    }
    Ok(LaneAttempt::Clean((sres, g), spent.reclaim(batch)))
}

/// The lane-side integrity ladder (DESIGN.md §11): attempt the batch; on a
/// guarded violation recompute it once from the lane's own source (shared
/// budgets make a single-multiplicity fault vanish on retry); a second
/// violation at the same address is a hard error — persistent corruption,
/// not a transient. Returns the **first** attempt's buffers so the caller
/// routes them exactly as the classic path would (feed credit accounting
/// must not notice recovery); retry buffers go back to the recompute
/// source internally.
#[allow(clippy::too_many_arguments)]
fn integrity_step_host<B: ExecBackend>(
    lane: &mut Lane<'_, '_, B>,
    d: Dims,
    opt: OptConfig,
    model: ModelKind,
    schema: &SchemaTensors,
    params: &Params,
    epoch: u64,
    b: usize,
    prep: PreparedCpu,
) -> Result<((StepResult, Params), BatchBufs)> {
    let mut prep = Some(prep);
    let mut banked: Option<BatchBufs> = None;
    for attempt in 0..2u32 {
        let p = match prep.take() {
            Some(p) => p,
            None => reproduce(&mut lane.src, &mut lane.standby, epoch, b)?,
        };
        match lane_attempt(lane, d, opt, model, schema, params, epoch, b, p)? {
            LaneAttempt::Clean(res, bufs) => {
                if attempt == 0 {
                    banked = Some(bufs);
                } else {
                    reclaim_retry(&mut lane.src, &mut lane.standby, bufs);
                }
                return Ok((res, banked.expect("first attempt banked its buffers")));
            }
            LaneAttempt::Violation(bufs) => {
                if attempt == 0 {
                    banked = Some(bufs);
                } else {
                    reclaim_retry(&mut lane.src, &mut lane.standby, bufs);
                }
                lane.eng.counters().borrow_mut().integrity_violations += 1;
                if attempt == 0 {
                    lane.recomputes += 1;
                }
            }
        }
    }
    bail!(
        "lane batch (epoch {epoch}, batch {b}) failed its integrity check even after \
         a recompute: persistent corruption, not a transient"
    )
}

/// Group rollback + replay (DESIGN.md §11): restore the last good
/// round-boundary snapshot and re-run rounds `[snap_mark, upto)`
/// sequentially on one lane — same round boundaries, same batch-ordered
/// merge, same mean-gradient SGD — so a clean replay lands bitwise on the
/// fault-free trajectory (replicas are a scheduling choice, §4). Replayed
/// injections draw from the same shared budgets as the original attempts;
/// a still-planned multiplicity re-poisons the replay and the caller's
/// audit loop goes around again until the budget is spent or exhausted.
#[allow(clippy::too_many_arguments)]
fn group_rollback_replay<'g, B: ExecBackend>(
    lane: &mut Lane<'_, 'g, B>,
    replayer: &mut CpuProducer<'g>,
    d: Dims,
    opt: OptConfig,
    model: ModelKind,
    schema: &SchemaTensors,
    params: &mut Params,
    snapshot: &Params,
    results: &mut [(f64, f64, usize)],
    epoch: u64,
    snap_mark: usize,
    upto: usize,
    round: usize,
    lr: f32,
) -> Result<()> {
    params.copy_from(snapshot);
    let round = round.max(1);
    let mut r0 = snap_mark;
    while r0 < upto {
        let len = round.min(upto - r0);
        let mut gsum: Option<Params> = None;
        let mut count = 0usize;
        for b in r0..r0 + len {
            let mut done = false;
            for retry in 0..2u32 {
                let prep = replayer.produce(epoch, b);
                match lane_attempt(lane, d, opt, model, schema, params, epoch, b, prep)? {
                    LaneAttempt::Clean((sres, g), bufs) => {
                        replayer.reclaim(bufs);
                        results[b] = (sres.loss as f64, sres.ncorrect as f64, sres.n_seed);
                        match gsum.as_mut() {
                            Some(acc) => acc.add_assign(&g),
                            None => gsum = Some(g),
                        }
                        count += 1;
                        done = true;
                    }
                    LaneAttempt::Violation(bufs) => {
                        replayer.reclaim(bufs);
                        lane.eng.counters().borrow_mut().integrity_violations += 1;
                        if retry == 0 {
                            lane.recomputes += 1;
                        }
                    }
                }
                if done {
                    break;
                }
            }
            ensure!(
                done,
                "replayed batch (epoch {epoch}, batch {b}) failed its integrity check \
                 even after a recompute: persistent corruption, not a transient"
            );
        }
        if let Some(g) = gsum {
            params.sgd(&g, lr / count as f32);
        }
        r0 += len;
    }
    Ok(())
}

/// Deterministic `nan!` corruption of a serve batch's logits
/// (DESIGN.md §11), addressed at `(epoch 0, seq = coalesced batch index)`
/// and budgeted through the shared consumed map. Only the admitted
/// (non-shadow) execution of a batch injects — shadow lanes recompute the
/// same batch concurrently, and racing them for the budget would make the
/// injection site depend on thread interleaving.
fn inject_logit_nan(
    plan: Option<&FaultPlan>,
    consumed: Option<&Mutex<HashMap<(FaultSite, u64, u64), u32>>>,
    logits: &mut HostTensor,
    bi: usize,
) {
    let (Some(plan), Some(consumed)) = (plan, consumed) else { return };
    let planned = plan.fires(FaultSite::Nan, 0, bi as u64);
    if planned == 0 {
        return;
    }
    let Ok(s) = logits.as_f32_mut() else { return };
    if s.is_empty() || !take_budget(consumed, FaultSite::Nan, 0, bi as u64, planned) {
        return;
    }
    let h = plan.target_hash(FaultSite::Nan, 0, bi as u64);
    let i = (h % s.len() as u64) as usize;
    s[i] = f32::NAN;
}

/// The serve guard's scan: every logit finite.
fn logits_finite(t: &HostTensor) -> bool {
    t.as_f32().map(|s| s.iter().all(|x| x.is_finite())).unwrap_or(true)
}

/// Compute the global batches `slots` that a dead lane left behind: preps
/// come from the dead lane's own source (its producers keep streaming its
/// fixed schedule), compute runs on the surviving lane's backend against
/// the round's parameter snapshot. Gradients return in slot order so the
/// caller can splice them into the all-reduce at their global positions.
#[allow(clippy::too_many_arguments)]
fn absorb_slots<B: ExecBackend>(
    dead: &mut Lane<'_, '_, B>,
    surv: &mut Lane<'_, '_, B>,
    d: Dims,
    opt: OptConfig,
    model: ModelKind,
    schema: &SchemaTensors,
    params: &Params,
    epoch: u64,
    slots: &[usize],
) -> Result<Vec<(StepResult, Params)>> {
    let exec = StepExecutor::new(&*surv.eng, model, opt);
    // The survivor re-stages the round snapshot on *its* device for the
    // absorbed slots (its own broadcast; the dead lane's copy is gone with
    // its round thread).
    let mut dev_params = match surv.dev_schema {
        Some(_) => Some(exec.upload_params_peer(params)?),
        None => None,
    };
    let mut out = Vec::with_capacity(slots.len());
    for &b in slots {
        let (prep, from_standby) =
            next_prep(&mut dead.src, &mut dead.standby, &mut dead.recoveries, epoch, b)?;
        surv.cpu_time += prep.cpu_time;
        surv.cpu_by_stage += prep.cpu_by_stage;
        surv.dropped_nodes += prep.dropped_nodes();
        surv.dropped_edges += prep.dropped_edges();
        surv.batches += 1;
        surv.eng.fault_cursor(epoch, b as u64);
        let (res, bufs) = if let (Some(dp), Some(ds)) = (&dev_params, surv.dev_schema) {
            let (batch, spent, xs_dev) =
                assemble_batch_dev(&*surv.eng, &d, schema, surv.cache, &mut surv.assemble, prep)?;
            let dev_batch = exec.upload_batch(&batch, xs_dev)?;
            let mut grads = DevGrads::empty();
            let sres = exec.grad_step_dev(dp, ds, &dev_batch, &mut grads)?;
            exec.recycle_batch(dev_batch);
            let g = exec.fetch_grads_peer(grads, params)?;
            ((sres, g), spent.reclaim(batch))
        } else {
            let (batch, spent) =
                assemble_batch(&*surv.eng, &d, schema, surv.cache, &mut surv.assemble, prep)?;
            let res = exec.grad_step(params, schema, &batch)?;
            (res, spent.reclaim(batch))
        };
        let pos = dead.pos;
        dead.pos += 1;
        route_bufs(&mut dead.src, &mut dead.standby, pos, bufs, from_standby);
        out.push(res);
    }
    if let Some(dp) = dev_params.take() {
        exec.recycle_dev_params(dp);
    }
    Ok(out)
}

/// Disjoint `&mut` access to two distinct lanes (dead + survivor).
fn lane_pair<'a, 'e, 'g, B: ExecBackend>(
    lanes: &'a mut [Lane<'e, 'g, B>],
    i: usize,
    j: usize,
) -> (&'a mut Lane<'e, 'g, B>, &'a mut Lane<'e, 'g, B>) {
    assert_ne!(i, j, "a lane cannot absorb its own slots");
    if i < j {
        let (lo, hi) = lanes.split_at_mut(j);
        (&mut lo[i], &mut hi[0])
    } else {
        let (lo, hi) = lanes.split_at_mut(i);
        (&mut hi[0], &mut lo[j])
    }
}

/// Contiguous per-lane `(start, len)` split of a round of `len` batches,
/// balanced so no lane idles while another holds 2+ batches (the first
/// `len % lanes` lanes take one extra). Depends only on `(len, lanes)`;
/// the merge is batch-ordered for *any* contiguous split, so balancing is
/// free of trajectory effects. Lanes beyond the work get `len == 0`.
fn round_split(len: usize, lanes: usize) -> Vec<(usize, usize)> {
    let base = len / lanes.max(1);
    let extra = len % lanes.max(1);
    let mut start = 0usize;
    (0..lanes)
        .map(|i| {
            let l = base + usize::from(i < extra);
            let a = start;
            start += l;
            (a, l)
        })
        .collect()
}

/// Every lane's global batch indices for a whole epoch, in the order its
/// producers stream them (round by round, contiguous within each round).
fn lane_schedule(n_batches: usize, round: usize, lanes: usize) -> Vec<Vec<usize>> {
    let round = round.max(1);
    let mut sched = vec![Vec::new(); lanes];
    let mut r0 = 0usize;
    while r0 < n_batches {
        let len = round.min(n_batches - r0);
        for (i, (a, l)) in round_split(len, lanes).into_iter().enumerate() {
            sched[i].extend(r0 + a..r0 + a + l);
        }
        r0 += len;
    }
    sched
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_split_covers_contiguously_for_any_lane_count() {
        for len in 0..9 {
            for lanes in 1..6 {
                let split = round_split(len, lanes);
                assert_eq!(split.len(), lanes);
                let mut next = 0usize;
                for &(a, l) in &split {
                    if l > 0 {
                        assert_eq!(a, next, "len={len} lanes={lanes}");
                        next = a + l;
                    }
                }
                assert_eq!(next, len, "len={len} lanes={lanes}: not covered");
            }
        }
    }

    #[test]
    fn round_split_keeps_every_lane_busy_when_possible() {
        // No lane may idle while another holds 2+ batches (e.g. the old
        // ceil-chunking gave round_split(4, 3) = [2, 2, 0]).
        for len in 1..10 {
            for lanes in 1..=len {
                let split = round_split(len, lanes);
                assert!(
                    split.iter().all(|&(_, l)| l > 0),
                    "len={len} lanes={lanes}: idle lane in {split:?}"
                );
                let max = split.iter().map(|&(_, l)| l).max().unwrap();
                let min = split.iter().map(|&(_, l)| l).min().unwrap();
                assert!(max - min <= 1, "len={len} lanes={lanes}: unbalanced {split:?}");
            }
        }
    }

    #[test]
    fn lane_schedule_is_a_partition_in_round_order() {
        for (n, round, lanes) in [(10, 4, 2), (7, 3, 4), (5, 4, 1), (0, 4, 3), (9, 1, 2)] {
            let sched = lane_schedule(n, round, lanes);
            let mut all: Vec<usize> = sched.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..n).collect::<Vec<_>>(), "n={n} round={round} lanes={lanes}");
            // Each lane's stream is strictly increasing (producer order).
            for lane in &sched {
                assert!(lane.windows(2).all(|w| w[0] < w[1]));
            }
        }
    }

    #[test]
    fn lane_schedule_matches_per_round_splits() {
        // The producer stream must be exactly the concatenation of the
        // per-round assignments the consumer computes.
        let (n, round, lanes) = (11usize, 4usize, 3usize);
        let sched = lane_schedule(n, round, lanes);
        let mut expect = vec![Vec::new(); lanes];
        let mut r0 = 0;
        while r0 < n {
            let len = round.min(n - r0);
            for (i, (a, l)) in round_split(len, lanes).into_iter().enumerate() {
                expect[i].extend(r0 + a..r0 + a + l);
            }
            r0 += len;
        }
        assert_eq!(sched, expect);
    }

    #[test]
    fn thread_budget_splits_and_floors_at_one() {
        assert_eq!(replica_thread_budget(8, 2), 4);
        assert_eq!(replica_thread_budget(4, 4), 1);
        assert_eq!(replica_thread_budget(2, 4), 1);
        assert_eq!(replica_thread_budget(0, 0), 1);
    }

    #[test]
    fn churn_plan_without_lane_hard_is_exactly_round_robin() {
        for (n, lanes) in [(10usize, 2usize), (7, 3), (5, 1), (0, 2)] {
            let sched = plan_churn(n, lanes, None, DEFAULT_PROBATION, &[]).unwrap();
            assert!(sched.stats.is_quiet());
            assert_eq!(sched.primary, (0..n).map(|b| b % lanes).collect::<Vec<_>>());
            for (l, slots) in sched.lanes.iter().enumerate() {
                let expect: Vec<ServeSlot> =
                    (l..n).step_by(lanes).map(|bi| (bi, false)).collect();
                assert_eq!(slots, &expect, "n={n} lanes={lanes} lane {l}");
            }
        }
    }

    #[test]
    fn churn_plan_quarantines_shadows_and_readmits() {
        // `lane!` at batch 1 on 2 lanes: lane 1 (the round-robin owner of
        // batch 1) is quarantined, batch 1 re-dispatches to lane 0, lane 1
        // shadows batches 2..2+probation and then owns batch bi%2 again.
        let plan = FaultPlan::parse("lane!@0:1", 7).unwrap();
        let sched = plan_churn(6, 2, Some(&plan), 2, &[]).unwrap();
        assert_eq!(sched.primary, vec![0, 0, 0, 0, 0, 1]);
        assert_eq!(sched.lanes[0], vec![(0, false), (1, false), (2, false), (3, false), (4, false)]);
        assert_eq!(sched.lanes[1], vec![(2, true), (3, true), (5, false)]);
        let s = sched.stats;
        assert_eq!(
            (s.lane_quarantines, s.lane_readmissions, s.shadow_batches, s.lane_redispatches),
            (1, 1, 2, 1)
        );
    }

    #[test]
    fn churn_plan_cascading_kills_hit_successive_lanes() {
        // x2 multiplicity at one seq kills two successive candidates; with
        // 3 lanes one survivor remains and takes the batch.
        let plan = FaultPlan::parse("lane!@0:0x2", 7).unwrap();
        let sched = plan_churn(2, 3, Some(&plan), 1, &[]).unwrap();
        assert_eq!(sched.primary[0], 2);
        assert_eq!(sched.stats.lane_quarantines, 2);
        assert_eq!(sched.stats.lane_redispatches, 2);
        // Probation 1: both quarantined lanes shadow batch 1 and re-admit.
        assert_eq!(sched.stats.shadow_batches, 2);
        assert_eq!(sched.stats.lane_readmissions, 2);

        // The same multiplicity against 2 lanes leaves nothing healthy:
        // the typed error names the stranded batch.
        let err = plan_churn(2, 2, Some(&plan), 1, &[]).unwrap_err();
        let no = err.downcast_ref::<NoHealthyLanes>().expect("typed error");
        assert_eq!(*no, NoHealthyLanes { batch: 0, lanes: 2 });
    }

    #[test]
    fn churn_plan_pre_quarantines_suspect_lanes() {
        // A lane branded suspect by the previous drive's integrity guard
        // starts quarantined: batch 0 re-routes around it, it shadows a
        // probation and then re-enters the rotation. Counted as a
        // quarantine but not a re-dispatch (no batch was placed on it).
        let sched = plan_churn(5, 2, None, 2, &[0]).unwrap();
        assert_eq!(sched.primary, vec![1, 1, 1, 1, 0]);
        assert_eq!(sched.lanes[0], vec![(0, true), (1, true), (4, false)]);
        let s = sched.stats;
        assert_eq!(
            (s.lane_quarantines, s.lane_readmissions, s.shadow_batches, s.lane_redispatches),
            (1, 1, 2, 0)
        );
        // Out-of-range and duplicate suspects are ignored, not errors.
        let sched = plan_churn(2, 2, None, 1, &[7, 1, 1]).unwrap();
        assert_eq!(sched.stats.lane_quarantines, 1);
    }

    #[test]
    fn churn_plan_single_lane_kill_is_unservable() {
        let plan = FaultPlan::parse("lane!@0:0", 7).unwrap();
        let err = plan_churn(1, 1, Some(&plan), 1, &[]).unwrap_err();
        assert!(err.downcast_ref::<NoHealthyLanes>().is_some());
    }
}
