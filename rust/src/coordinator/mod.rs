//! The coordinator: drives mini-batch HGNN training end-to-end (Fig. 2
//! workflow), switching between the PyG-style baseline plan and HiFuse
//! optimizations per `OptConfig`, sequentially or pipelined (Fig. 6).
//!
//! The CPU side of every training path runs through a [`CpuProducer`]: a
//! worker owning a [`SamplerScratch`] and a pool of recycled [`BatchBufs`],
//! so steady-state batch preparation performs **zero heap allocations**
//! (DESIGN.md §5; pinned by `tests/producer_parity.rs`). Consumed batches
//! hand their buffers back through [`Trainer::compute_batch`] /
//! [`SpentBatch::reclaim`], closing the loop.

pub mod ablation;
pub mod pipeline;
pub mod replica;

pub use ablation::OptConfig;
pub use pipeline::PIPELINE_DEPTH;
pub use replica::{
    replica_thread_budget, ChurnStats, NoHealthyLanes, RefreshEvent, ReplicaGroup, ReplicaMetrics,
    ServeDrive, DEFAULT_PROBATION, DEFAULT_ROUND,
};

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::graph::{HeteroGraph, Layout};
use crate::models::step::{
    pad_layer_edges, schema_tensors, BatchData, DevParams, DevSchema, Dims, SchemaTensors,
    StepExecutor,
};
use crate::models::{ModelKind, Params};
use crate::runtime::{
    Arg, ArenaStats, CacheHandle, Counters, CpuStageTimes, DevBuf, ExecBackend, Phase,
    ResidentStore, Stage,
};
use crate::sampler::collect::{self, Collected};
use crate::sampler::{
    MiniBatch, NeighborSampler, RelEdges, SamplerCfg, SamplerScratch, TaggedEdges,
};
use crate::semantic;
use crate::util::{fnv1a_f32, FaultPlan, FaultSite, HostTensor, Rng, WorkerPool};

/// Training-run configuration.
#[derive(Clone, Copy, Debug)]
pub struct TrainCfg {
    pub epochs: usize,
    pub batch_size: usize,
    pub fanout: usize,
    pub lr: f32,
    pub seed: u64,
    /// CPU selection threads (the paper's OpenMP worker count).
    pub threads: usize,
    /// Sampling workers feeding the pipelined paths (`--producers`);
    /// `0` = derive from the thread budget ([`producer_count`]). The
    /// sequential path always prepares inline with one producer.
    pub producers: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            epochs: 1,
            batch_size: 64,
            fanout: 4,
            lr: 0.05,
            seed: 42,
            threads: 4,
            producers: 0,
        }
    }
}

/// Number of sampling workers the pipelined paths spawn: an explicit
/// `--producers` wins; otherwise half the `--threads` budget (at least
/// one) — each producer drives its own selection/collection chunks, so the
/// worker pool each producer gets is the budget split by this count
/// ([`replica_thread_budget`] applied to producers).
pub fn producer_count(cfg: &TrainCfg) -> usize {
    if cfg.producers > 0 {
        cfg.producers
    } else {
        (cfg.threads / 2).max(1)
    }
}

/// Per-lane producer budget under the replica fan-out: the producer count
/// splits across lanes exactly like the thread budget, flooring at one.
pub fn lane_producer_count(cfg: &TrainCfg, lanes: usize) -> usize {
    (producer_count(cfg) / lanes.max(1)).max(1)
}

/// CPU-producer buffer-pool traffic (the host-side analogue of
/// [`ArenaStats`]): `fresh` buffer-set constructions, `reused` recycled
/// sets, and `grown` produce calls that had to enlarge a pooled buffer.
/// Steady state means `fresh` and `grown` both stay flat — pinned by
/// `tests/producer_parity.rs` in the same style as the arena tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProducerStats {
    /// Buffer sets (and sampler scratches) constructed from scratch.
    pub fresh: u64,
    /// Produce calls served from the recycled pool.
    pub reused: u64,
    /// Produce calls that grew some pooled buffer's capacity.
    pub grown: u64,
}

impl ProducerStats {
    /// Allocation events (anything other than pure reuse).
    pub fn allocations(&self) -> u64 {
        self.fresh + self.grown
    }
}

impl std::ops::AddAssign for ProducerStats {
    fn add_assign(&mut self, o: ProducerStats) {
        self.fresh += o.fresh;
        self.reused += o.reused;
        self.grown += o.grown;
    }
}

/// Per-epoch measurements (feeds Tables 1/3 and Figs 7-11).
#[derive(Clone, Debug, Default)]
pub struct EpochMetrics {
    pub loss: f64,
    pub acc: f64,
    pub wall: Duration,
    /// Host-side stage time: sampling + CPU selection + collection.
    pub cpu_time: Duration,
    /// Per-stage breakdown of `cpu_time` (sample / select / collect).
    pub cpu_by_stage: CpuStageTimes,
    /// Device-side time: sum of dispatch durations ("GPU time").
    pub gpu_time: Duration,
    /// Host→device bytes over the epoch: dispatch-argument uploads plus
    /// the explicit feature channel (full collected slab with the cache
    /// off; scatter indices + miss rows with it on — DESIGN.md §7).
    pub h2d_bytes: u64,
    /// Device→host bytes (outputs of host-returning dispatches).
    pub d2h_bytes: u64,
    /// Modeled peer-interconnect bytes of the replica paths (per-round
    /// parameter broadcast + per-batch gradient collection in the
    /// device-resident mode); 0 on single-backend runs.
    pub p2p_bytes: u64,
    /// Feature-cache slot reads served by the device-resident store.
    pub cache_hits: u64,
    /// Feature-cache slot reads gathered on CPU and uploaded.
    pub cache_misses: u64,
    pub kernels_total: usize,
    pub kernels_fwd_semantic: usize,
    pub kernels_fwd_agg: usize,
    pub kernels_by_stage: Vec<(Stage, usize)>,
    /// Dispatch time per stage (the per-stage slice of `gpu_time`).
    pub time_by_stage: Vec<(Stage, Duration)>,
    /// Backend buffer-arena traffic, cumulative at epoch end (all-zero on
    /// backends without an arena). Per-epoch deltas = difference between
    /// consecutive epochs' snapshots.
    pub arena: ArenaStats,
    /// CPU-producer buffer-pool traffic, cumulative at epoch end (same
    /// snapshot semantics as `arena`): flat `fresh`/`grown` between epochs
    /// = a zero-allocation producer epoch.
    pub producer: ProducerStats,
    pub batches: usize,
    pub dropped_nodes: usize,
    pub dropped_edges: usize,
    /// Transient dispatch failures absorbed by the backend's bounded
    /// retry-with-backoff (DESIGN.md §9); 0 on every fault-free run.
    pub dispatch_retries: u64,
    /// Batches a standby producer re-derived after an injected producer
    /// death left a hole in the reorder ring (pipelined paths only).
    pub producer_recoveries: u64,
    /// Replica lanes lost mid-epoch whose remaining slots the surviving
    /// lanes absorbed (counted once per lost lane, on the group metrics).
    pub lane_failovers: u64,
    /// Data-integrity violations detected this epoch (DESIGN.md §11):
    /// guard-caught corrupt feature payloads or non-finite loss/gradients,
    /// audit-caught non-finite parameters or corrupt cache slabs, and
    /// guarded-upload retransmits. 0 on every fault-free run.
    pub integrity_violations: u64,
    /// Corrupted H2D/p2p payloads the guarded upload path dropped and
    /// re-sent clean (the `wire!` site's recovery; always ≤ violations).
    pub integrity_retransmits: u64,
    /// Batches recomputed from their `(epoch_perm, seq)` address after a
    /// pre-apply integrity violation (first rung of the recovery ladder).
    pub integrity_recomputes: u64,
    /// Rollbacks to the last-good parameter snapshot followed by a bitwise
    /// replay forward (second rung; post-apply corruption only).
    pub integrity_rollbacks: u64,
    /// Digest/finiteness audit points executed (`--audit-every`, plus the
    /// mandatory epoch-end audit of every audited epoch).
    pub audits: u64,
}

impl EpochMetrics {
    /// Copy the counter-derived fields (dispatch counts, stage breakdowns,
    /// gpu time, arena snapshot) out of a dispatch log — the single source
    /// of these fields for both the single-backend path
    /// ([`Trainer::train_epoch`]) and the per-replica metrics.
    pub fn fill_from_counters(&mut self, c: &Counters) {
        self.gpu_time = c.gpu_time;
        self.h2d_bytes = c.h2d_bytes;
        self.d2h_bytes = c.d2h_bytes;
        self.p2p_bytes = c.p2p_bytes;
        self.cache_hits = c.cache_hits;
        self.cache_misses = c.cache_misses;
        self.kernels_total = c.total();
        self.kernels_fwd_semantic = c.count_phase(Stage::SemanticBuild, Phase::Fwd);
        self.kernels_fwd_agg = c.count_phase(Stage::Aggregation, Phase::Fwd);
        self.kernels_by_stage = c.by_stage();
        self.time_by_stage = c.time_by_stage();
        self.arena = c.arena;
        self.dispatch_retries = c.dispatch_retries;
        self.integrity_violations = c.integrity_violations;
        self.integrity_retransmits = c.integrity_retransmits;
    }

    /// Fraction of batch-slot feature reads served by the resident cache
    /// this epoch (0.0 with the cache off).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Sum `other`'s **additive counter fields** into `self`: batch and
    /// kernel counts, per-stage counts/times, cpu/gpu time, arena and
    /// producer traffic, drop counters. The ratio fields (`loss`, `acc`)
    /// and `wall` are *not* merged — they are not additive across replicas;
    /// the replica group computes them from the global batch results
    /// (DESIGN.md §4).
    pub fn absorb(&mut self, other: &EpochMetrics) {
        self.cpu_time += other.cpu_time;
        self.cpu_by_stage += other.cpu_by_stage;
        self.gpu_time += other.gpu_time;
        self.h2d_bytes += other.h2d_bytes;
        self.d2h_bytes += other.d2h_bytes;
        self.p2p_bytes += other.p2p_bytes;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.kernels_total += other.kernels_total;
        self.kernels_fwd_semantic += other.kernels_fwd_semantic;
        self.kernels_fwd_agg += other.kernels_fwd_agg;
        merge_stage_vec(&mut self.kernels_by_stage, &other.kernels_by_stage);
        merge_stage_vec(&mut self.time_by_stage, &other.time_by_stage);
        self.arena += other.arena;
        self.producer += other.producer;
        self.batches += other.batches;
        self.dropped_nodes += other.dropped_nodes;
        self.dropped_edges += other.dropped_edges;
        self.dispatch_retries += other.dispatch_retries;
        self.producer_recoveries += other.producer_recoveries;
        self.lane_failovers += other.lane_failovers;
        self.integrity_violations += other.integrity_violations;
        self.integrity_retransmits += other.integrity_retransmits;
        self.integrity_recomputes += other.integrity_recomputes;
        self.integrity_rollbacks += other.integrity_rollbacks;
        self.audits += other.audits;
    }
}

/// Merge per-stage `(Stage, T)` rows by stage, preserving `into`'s order and
/// appending stages it has not seen yet.
fn merge_stage_vec<T: Copy + std::ops::AddAssign>(
    into: &mut Vec<(Stage, T)>,
    from: &[(Stage, T)],
) {
    for &(s, v) in from {
        match into.iter_mut().find(|(t, _)| *t == s) {
            Some((_, acc)) => *acc += v,
            None => into.push((s, v)),
        }
    }
}

/// CPU-side product of batch preparation (safe to build on a producer
/// thread; contains no backend handles). Retains the sampled
/// [`MiniBatch`] so the consumer can hand every buffer back to the
/// producer after the step ([`PreparedCpu::into_bufs`] /
/// [`SpentBatch::reclaim`]).
pub struct PreparedCpu {
    pub collected: Collected,
    /// The sampled mini-batch the stages consumed; on the baseline path
    /// its `tagged` lists feed the "GPU" `edge_select` dispatches.
    pub mb: MiniBatch,
    /// CPU selection output, one entry per layer, when `cpu_selected`;
    /// retained (possibly stale) otherwise so the buffers keep cycling.
    pub selected: Vec<Vec<RelEdges>>,
    /// Whether `selected` holds this batch's selection (offload path).
    pub cpu_selected: bool,
    pub cpu_time: Duration,
    pub cpu_by_stage: CpuStageTimes,
}

impl PreparedCpu {
    pub fn dropped_nodes(&self) -> usize {
        self.mb.dropped_nodes
    }

    pub fn dropped_edges(&self) -> usize {
        self.mb.dropped_edges
    }

    /// Recover the reusable buffers of a batch that will never be computed
    /// (pipeline teardown).
    pub fn into_bufs(self) -> BatchBufs {
        BatchBufs { mb: self.mb, selected: self.selected, collected: self.collected }
    }
}

/// One reusable set of producer-side buffers: everything a `produce` call
/// writes. Cycles producer → `PreparedCpu` → consumer → (reclaim) →
/// producer; a training loop in steady state owns a fixed population of
/// these and allocates nothing per batch.
pub struct BatchBufs {
    mb: MiniBatch,
    selected: Vec<Vec<RelEdges>>,
    collected: Collected,
}

impl BatchBufs {
    /// A fully-reserved buffer set: every nested vector is pre-sized to
    /// its static cap (`batch_size`/`ns`/`ep`), so the set never grows —
    /// not even on its first use — keeping [`ProducerStats::grown`] at
    /// zero deterministically. The selection buffers are only materialized
    /// when the plan selects on CPU (`offload`); the baseline path never
    /// touches them. `cached` sizes the miss-staging/scatter-index buffers
    /// for the feature-cache path the same way.
    fn new(
        d: &Dims,
        scfg: &SamplerCfg,
        n_types: usize,
        n_rel: usize,
        offload: bool,
        cached: bool,
    ) -> Self {
        let mut mb = MiniBatch::default();
        mb.reset(scfg, n_types, n_rel);
        let selected = if offload {
            (0..scfg.layers)
                .map(|_| {
                    (0..n_rel)
                        .map(|_| RelEdges {
                            src: Vec::with_capacity(scfg.ep),
                            dst: Vec::with_capacity(scfg.ep),
                        })
                        .collect()
                })
                .collect()
        } else {
            Vec::new()
        };
        BatchBufs { mb, selected, collected: Collected::new(d.tpad, d.ns, d.f, cached) }
    }

    /// Held heap capacity in elements (the `Collected` tensors are
    /// fixed-shape, so only the edge-list buffers can grow); the
    /// allocation-growth witness behind [`ProducerStats::grown`].
    fn capacity_footprint(&self) -> usize {
        self.mb.capacity_footprint()
            + self.selected.capacity()
            + self
                .selected
                .iter()
                .map(|l| {
                    l.capacity()
                        + l.iter().map(|e| e.src.capacity() + e.dst.capacity()).sum::<usize>()
                })
                .sum::<usize>()
    }
}

/// The leftover of a consumed [`PreparedCpu`] after [`assemble_batch`]
/// moved its tensors into a [`BatchData`]; [`SpentBatch::reclaim`] reunites
/// the two into a recyclable [`BatchBufs`] once the step is done. The
/// cache-path staging buffers (miss rows, scatter indices) ride along so
/// the reunited set is complete either way.
pub struct SpentBatch {
    mb: MiniBatch,
    selected: Vec<Vec<RelEdges>>,
    miss_rows: HostTensor,
    gather_idx: HostTensor,
}

impl SpentBatch {
    /// Reunite with the consumed batch's tensors. Call after the training
    /// step: `batch` must be the `BatchData` the paired `assemble_batch`
    /// returned. (On the cache path `batch.xs` is the gather dispatch's
    /// output — it replaces the producer's slab buffer, which
    /// `assemble_batch` recycled into the backend arena, keeping the
    /// circulating population fixed.)
    pub fn reclaim(self, batch: BatchData) -> BatchBufs {
        BatchBufs {
            mb: self.mb,
            selected: self.selected,
            collected: Collected {
                xs: batch.xs,
                labels: batch.labels,
                seed_mask: batch.seed_mask,
                n_seed: 0,
                miss_rows: self.miss_rows,
                gather_idx: self.gather_idx,
                n_hit: 0,
                n_miss: 0,
            },
        }
    }
}

/// The profile-capped sampler configuration a training run uses — shared
/// by `Trainer` and the replica lanes so both paths sample identical
/// batches (the bit-exactness contract depends on it).
pub(crate) fn sampler_cfg(cfg: &TrainCfg, d: &Dims) -> SamplerCfg {
    SamplerCfg {
        batch_size: cfg.batch_size,
        fanout: cfg.fanout,
        layers: 2,
        ns: d.ns,
        ep: d.ep,
    }
}

/// Materialize the feature layout an `OptConfig` requires (the paper's
/// reorganization): call before constructing a `Trainer`.
pub fn prepare_graph_layout(g: &mut HeteroGraph, opt: &OptConfig) {
    let want = if opt.reorg { Layout::TypeMajor } else { Layout::IndexMajor };
    g.features.ensure_layout(want);
}

/// A CPU batch-preparation worker: sample, (optionally) select on CPU,
/// collect — all through its own [`SamplerScratch`] and recycled
/// [`BatchBufs`], so a warmed producer allocates nothing per batch. Touches
/// no backend handles (runs on producer threads in pipeline mode).
pub struct CpuProducer<'g> {
    graph: &'g HeteroGraph,
    scfg: SamplerCfg,
    d: Dims,
    opt: OptConfig,
    pool: WorkerPool,
    rng: Rng,
    scratch: SamplerScratch,
    spare: Vec<BatchBufs>,
    /// The shared read-only resident-store index (DESIGN.md §7): with it
    /// present, collection runs the hit/miss split instead of the full
    /// slab gather. One `Arc` is shared by every producer of a run.
    cache: Option<Arc<ResidentStore>>,
    /// Buffer sets this producer has originated (its flow-control credit in
    /// pipeline mode: seeds + fresh constructions).
    owned: usize,
    pub stats: ProducerStats,
}

/// Which sampling entry a `produce` call drives: the training
/// epoch-permutation walk or the serve path's explicit coalesced seed set.
enum SampleSpec<'a> {
    Train { epoch: u64, batch_idx: usize },
    Request { batch_idx: u64, seeds: &'a [u32] },
}

/// A producer's persistent state between epochs: scratch + recycled buffer
/// sets (the [`ProducerArsenal`] hands these out and takes them back).
pub(crate) struct ProducerSeed {
    pub(crate) scratch: SamplerScratch,
    pub(crate) spare: Vec<BatchBufs>,
}

/// What a producer returns when its epoch ends: scratch, surviving buffer
/// sets, the stats it accumulated, and (pipeline mode) its recycle-channel
/// receiver — carried out so a buffer the consumer returned *after* the
/// producer's final drain is recovered by the arsenal rather than
/// destroyed with the channel (the send and the exit can race; the queue
/// survives as long as this receiver does).
pub(crate) struct ProducerState {
    pub(crate) scratch: SamplerScratch,
    pub(crate) spare: Vec<BatchBufs>,
    pub(crate) stats: ProducerStats,
    pub(crate) returns: Option<std::sync::mpsc::Receiver<BatchBufs>>,
}

impl<'g> CpuProducer<'g> {
    /// Fresh cache-less producer (new scratch, empty pool). The training
    /// paths prefer [`CpuProducer::from_seed`] to keep state across epochs
    /// (and to inherit the run's resident-store index).
    pub fn new(
        graph: &'g HeteroGraph,
        scfg: SamplerCfg,
        d: Dims,
        opt: OptConfig,
        pool: WorkerPool,
        rng: Rng,
    ) -> Self {
        let seed = ProducerSeed { scratch: SamplerScratch::new(graph), spare: Vec::new() };
        Self::from_seed(graph, scfg, d, opt, pool, rng, None, seed)
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_seed(
        graph: &'g HeteroGraph,
        scfg: SamplerCfg,
        d: Dims,
        opt: OptConfig,
        pool: WorkerPool,
        rng: Rng,
        cache: Option<Arc<ResidentStore>>,
        seed: ProducerSeed,
    ) -> Self {
        let owned = seed.spare.len();
        let mut scratch = seed.scratch;
        // Idempotent cap reservation: a scratch that sat out an epoch must
        // not grow on its first sample under this producer.
        scratch.reserve_for(graph.n_relations(), scfg.ep);
        CpuProducer {
            graph,
            scfg,
            d,
            opt,
            pool,
            rng,
            scratch,
            spare: seed.spare,
            cache,
            owned,
            stats: ProducerStats::default(),
        }
    }

    /// Return a consumed batch's buffers to the pool.
    pub fn reclaim(&mut self, bufs: BatchBufs) {
        self.spare.push(bufs);
    }

    /// Top the pool up to `target` owned buffer sets (pipeline credit).
    /// Eager construction keeps the circulating population **fixed**: a
    /// producer never fresh-allocates mid-epoch because a return raced its
    /// schedule, which is what makes the steady-state zero-alloc contract
    /// deterministic rather than timing-dependent.
    pub(crate) fn preallocate(&mut self, target: usize) {
        while self.owned < target {
            let bufs = self.fresh_bufs();
            self.spare.push(bufs);
            self.owned += 1;
            self.stats.fresh += 1;
        }
    }

    fn fresh_bufs(&self) -> BatchBufs {
        BatchBufs::new(
            &self.d,
            &self.scfg,
            self.graph.n_types(),
            self.graph.n_relations(),
            self.opt.offload,
            self.cache.is_some(),
        )
    }

    pub(crate) fn spare_is_empty(&self) -> bool {
        self.spare.is_empty()
    }

    pub(crate) fn owned(&self) -> usize {
        self.owned
    }

    pub(crate) fn into_state(self) -> ProducerState {
        ProducerState {
            scratch: self.scratch,
            spare: self.spare,
            stats: self.stats,
            returns: None,
        }
    }

    /// Prepare one batch. Serves from the recycled pool when possible; a
    /// fresh buffer set otherwise (counted in [`ProducerStats`]).
    pub fn produce(&mut self, epoch: u64, batch_idx: usize) -> PreparedCpu {
        self.produce_spec(SampleSpec::Train { epoch, batch_idx })
    }

    /// Prepare one **serve-path** batch from an explicit coalesced seed set
    /// (DESIGN.md §8): identical stage structure and buffer economy to
    /// [`CpuProducer::produce`], with sampling driven by
    /// [`NeighborSampler::sample_request_into`] — deterministic in the
    /// coalesced-batch index alone, not an (epoch, batch) pair.
    pub fn produce_request(&mut self, batch_idx: u64, seeds: &[u32]) -> PreparedCpu {
        self.produce_spec(SampleSpec::Request { batch_idx, seeds })
    }

    fn produce_spec(&mut self, spec: SampleSpec<'_>) -> PreparedCpu {
        let mut bufs = match self.spare.pop() {
            Some(b) => {
                self.stats.reused += 1;
                b
            }
            None => {
                self.stats.fresh += 1;
                self.owned += 1;
                self.fresh_bufs()
            }
        };
        let before = self.scratch.capacity_footprint() + bufs.capacity_footprint();
        let t0 = Instant::now();
        let sampler = NeighborSampler::new(self.graph, self.scfg);
        match spec {
            SampleSpec::Train { epoch, batch_idx } => {
                sampler.sample_into(&self.rng, epoch, batch_idx, &mut self.scratch, &mut bufs.mb)
            }
            SampleSpec::Request { batch_idx, seeds } => sampler.sample_request_into(
                &self.rng,
                batch_idx,
                seeds,
                &mut self.scratch,
                &mut bufs.mb,
            ),
        }
        let sample = t0.elapsed();

        let t1 = Instant::now();
        let cpu_selected = self.opt.offload;
        if cpu_selected {
            let n_rel = self.graph.n_relations();
            bufs.selected.resize_with(bufs.mb.tagged.len(), Vec::new);
            for (l, t) in bufs.mb.tagged.iter().enumerate() {
                if self.opt.parallel {
                    semantic::select_parallel_into(
                        t,
                        n_rel,
                        self.pool.threads(),
                        &mut bufs.selected[l],
                    );
                } else {
                    semantic::select_serial_into(t, n_rel, &mut bufs.selected[l]);
                }
            }
        }
        let select = t1.elapsed();

        let t2 = Instant::now();
        collect::collect_into(
            self.graph,
            &bufs.mb,
            self.d.tpad,
            self.d.ns,
            self.d.f,
            &self.pool,
            self.cache.as_deref(),
            &mut bufs.collected,
        );
        let collect_t = t2.elapsed();

        let after = self.scratch.capacity_footprint() + bufs.capacity_footprint();
        if after > before {
            self.stats.grown += 1;
        }
        let BatchBufs { mb, selected, collected } = bufs;
        PreparedCpu {
            collected,
            mb,
            selected,
            cpu_selected,
            cpu_time: t0.elapsed(),
            cpu_by_stage: CpuStageTimes { sample, select, collect: collect_t },
        }
    }
}

/// Persistent producer-side state of a training path, kept **across
/// epochs** so the zero-alloc steady state spans the whole run: returned
/// sampler scratches, the circulating buffer sets, and the cumulative
/// [`ProducerStats`].
#[derive(Default)]
pub(crate) struct ProducerArsenal {
    scratches: Vec<SamplerScratch>,
    spare: Vec<BatchBufs>,
    pub(crate) stats: ProducerStats,
}

impl ProducerArsenal {
    /// Hand out state for `n` producers: one scratch each (constructing
    /// new ones only when short — counted as `fresh`), with the pooled
    /// buffer sets dealt round-robin.
    pub(crate) fn checkout(&mut self, graph: &HeteroGraph, n: usize) -> Vec<ProducerSeed> {
        let mut seeds: Vec<ProducerSeed> = (0..n.max(1))
            .map(|_| {
                let scratch = self.scratches.pop().unwrap_or_else(|| {
                    self.stats.fresh += 1;
                    SamplerScratch::new(graph)
                });
                ProducerSeed { scratch, spare: Vec::new() }
            })
            .collect();
        let mut i = 0usize;
        while let Some(b) = self.spare.pop() {
            seeds[i % seeds.len()].spare.push(b);
            i += 1;
        }
        seeds
    }

    /// Take a finished producer's state back, recovering any buffer set
    /// still parked in its recycle channel (a consumer return that raced
    /// the producer's exit).
    pub(crate) fn checkin(&mut self, state: ProducerState) {
        let ProducerState { scratch, spare, stats, returns } = state;
        self.scratches.push(scratch);
        self.spare.extend(spare);
        self.stats += stats;
        if let Some(rx) = returns {
            while let Ok(b) = rx.try_recv() {
                self.spare.push(b);
            }
        }
    }

    /// Re-pool buffer sets that could not return to their producer (it had
    /// already finished its epoch slice).
    pub(crate) fn checkin_bufs(&mut self, bufs: Vec<BatchBufs>) {
        self.spare.extend(bufs);
    }
}

/// One-shot CPU half of batch preparation (profiling tools and tests):
/// builds a throwaway [`CpuProducer`]. The training loops keep persistent
/// producers instead — this wrapper allocates its scratch every call.
#[allow(clippy::too_many_arguments)]
pub fn prepare_cpu(
    graph: &HeteroGraph,
    scfg: SamplerCfg,
    d: &Dims,
    opt: &OptConfig,
    pool: &WorkerPool,
    rng: &Rng,
    epoch: u64,
    batch_idx: usize,
) -> PreparedCpu {
    CpuProducer::new(graph, scfg, *d, *opt, *pool, rng.clone()).produce(epoch, batch_idx)
}

/// Consumer-side pooled scratch for [`assemble_batch`] / [`gpu_select`]:
/// the padded edge-type column and the relation-id scalar are refilled in
/// place instead of being allocated per call — the last per-batch
/// allocations on the baseline device-selection path.
#[derive(Default)]
pub struct AssembleScratch {
    /// `[ELP]` i32 edge-type column (sentinel-refilled per call); lazily
    /// sized on first use, then permanent.
    et: Option<HostTensor>,
    /// Scalar i32 relation id, rewritten per relation.
    rel: Option<HostTensor>,
}

/// "GPU" edge-index selection (baseline): one `edge_select` dispatch per
/// relation per layer (the compare+index_select kernel pair), then host
/// extraction of the selected endpoints. `scratch` pools the padded type
/// column and the relation scalar across calls, so the steady state
/// allocates only the returned edge lists' growth (capacity-bounded).
pub fn gpu_select<B: ExecBackend>(
    eng: &B,
    d: &Dims,
    tagged: &TaggedEdges,
    n_rel: usize,
    scratch: &mut AssembleScratch,
) -> Result<Vec<RelEdges>> {
    // Pad the tagged type column to ELP with a sentinel (RPAD never matches
    // a real relation id) — in pooled scratch.
    let et = scratch
        .et
        .get_or_insert_with(|| HostTensor::i32(vec![d.rpad as i32; d.elp], &[d.elp]));
    {
        let e = et.as_i32_mut().expect("et scratch is i32");
        assert_eq!(e.len(), d.elp, "assemble scratch built for another profile");
        e.fill(d.rpad as i32);
        for (i, &r) in tagged.rel.iter().enumerate() {
            e[i] = r as i32;
        }
    }
    let et: &HostTensor = et;
    let rel = scratch.rel.get_or_insert_with(|| HostTensor::scalar_i32(0));
    let mut out = Vec::with_capacity(n_rel);
    for r in 0..n_rel {
        rel.as_i32_mut().expect("rel scratch is i32")[0] = r as i32;
        let mut res = eng
            .run("edge_select", Stage::SemanticBuild, Phase::Fwd, &[et, rel])?
            .into_iter();
        let pos_t = res.next().unwrap();
        let count = res.next().unwrap().scalar()? as usize;
        let pos = pos_t.as_i32()?;
        let mut e = RelEdges::default();
        for &p in &pos[..count] {
            e.src.push(tagged.src[p as usize]);
            e.dst.push(tagged.dst[p as usize]);
        }
        eng.recycle(pos_t);
        out.push(e);
    }
    Ok(out)
}

/// Device half of batch preparation, shared by [`Trainer::compute_batch`]
/// and the replica lanes: resolve per-relation edges (taking the baseline
/// `edge_select` dispatches when selection did not run on CPU), pad them
/// into module tensors, and materialize the batch features as a
/// [`BatchData`]. Also returns the [`SpentBatch`] carcass so the caller can
/// recycle the buffers after the step.
///
/// Feature channel (DESIGN.md §7): with no `cache`, the collected
/// `[TPAD, NS, F]` slab ships to the device whole every batch (recorded in
/// `Counters::h2d_bytes`). With a [`CacheHandle`], only the miss rows
/// upload (partial H2D) and the `feature_gather` dispatch assembles the
/// identical slab on-device from {resident store, miss rows, scatter
/// indices} — cutting the steady-state feature-channel H2D roughly by the
/// hit rate while the produced bytes stay bitwise equal to the cache-off
/// gather. Accounting caveat (host-staged modes only): downstream
/// dispatches still receive `xs` as a *host* argument (the step executor
/// is untouched), so those per-dispatch argument re-uploads appear in
/// `h2d_bytes` **identically in both modes** and cancel in any on-vs-off
/// comparison; the two branches below are the differential term, and the
/// gather output materializes back to host (free on the sim backend,
/// whose "device" memory is host memory). `--mode resident` closes the
/// caveat: [`assemble_batch_dev`] keeps the gather output as a `DevBuf`
/// feeding the stacked projection directly, so neither the slab nor any
/// downstream activation ever re-crosses PCIe (`tests/residency.rs`).
pub fn assemble_batch<B: ExecBackend>(
    eng: &B,
    d: &Dims,
    schema: &SchemaTensors,
    cache: Option<&CacheHandle<B>>,
    scratch: &mut AssembleScratch,
    prep: PreparedCpu,
) -> Result<(BatchData, SpentBatch)> {
    let PreparedCpu { collected, mb, selected, cpu_selected, .. } = prep;
    let layers = resolve_layers(eng, d, schema, scratch, &mb, &selected, cpu_selected)?;
    let Collected { xs, labels, seed_mask, n_seed, miss_rows, gather_idx, n_hit, n_miss } =
        collected;
    let xs = match cache {
        None => {
            // The whole collected slab ships host→device every batch (the
            // implicit upload the resident cache removes). The bytes are
            // charged by performing the upload, not by a hand-recorded
            // counter bump, so the feature channel has exactly one
            // accounting site with the same semantics as the cache path's
            // partial miss-row transfer.
            let dev = eng.upload(&xs, xs.len())?;
            eng.recycle_dev(dev);
            xs
        }
        Some(handle) => {
            // Partial H2D: only the packed miss rows transfer; the scatter
            // indices count as the gather dispatch's host argument.
            let miss_dev = eng.upload(&miss_rows, n_miss * d.f)?;
            let out = eng.run_dev(
                "feature_gather",
                Stage::Collection,
                Phase::Fwd,
                &[Arg::Dev(&handle.dev), Arg::Dev(&miss_dev), Arg::Host(&gather_idx)],
            )?;
            eng.recycle_dev(miss_dev);
            eng.counters().borrow_mut().add_cache(n_hit as u64, n_miss as u64);
            // The producer's (stale) slab buffer swaps into the arena and
            // the gather output takes its slot in the circulating set, so
            // the steady-state buffer population stays fixed.
            eng.recycle(xs);
            out.into_host()?
        }
    };
    let batch = BatchData { xs, labels, seed_mask, n_seed, layers };
    Ok((batch, SpentBatch { mb, selected, miss_rows, gather_idx }))
}

/// Shared edge-resolution half of [`assemble_batch`] /
/// [`assemble_batch_dev`]: per-relation edges (CPU-selected or via the
/// baseline `edge_select` dispatches) padded into module tensors.
fn resolve_layers<B: ExecBackend>(
    eng: &B,
    d: &Dims,
    schema: &SchemaTensors,
    scratch: &mut AssembleScratch,
    mb: &MiniBatch,
    selected: &[Vec<RelEdges>],
    cpu_selected: bool,
) -> Result<Vec<crate::models::step::LayerEdges>> {
    if cpu_selected {
        Ok(selected.iter().map(|rels| pad_layer_edges(rels, d)).collect())
    } else {
        mb.tagged
            .iter()
            .map(|t| Ok(pad_layer_edges(&gpu_select(eng, d, t, schema.n_rel, scratch)?, d)))
            .collect::<Result<Vec<_>>>()
    }
}

/// [`assemble_batch`] for the device-resident step (DESIGN.md §7): the
/// feature slab never materializes back to host. On the cache path the
/// `feature_gather` output is returned as a device buffer for
/// [`StepExecutor::upload_batch`] to adopt; on the cache-off path no
/// transfer happens here — the slab uploads inside `upload_batch`, the one
/// site that charges feature bytes to H2D in this mode. `BatchData::xs`
/// keeps the producer's host slab buffer in both cases (stale on the cache
/// path) so [`SpentBatch::reclaim`] returns a complete buffer set.
pub fn assemble_batch_dev<B: ExecBackend>(
    eng: &B,
    d: &Dims,
    schema: &SchemaTensors,
    cache: Option<&CacheHandle<B>>,
    scratch: &mut AssembleScratch,
    prep: PreparedCpu,
) -> Result<(BatchData, SpentBatch, Option<B::Dev>)> {
    let PreparedCpu { collected, mb, selected, cpu_selected, .. } = prep;
    let layers = resolve_layers(eng, d, schema, scratch, &mb, &selected, cpu_selected)?;
    let Collected { xs, labels, seed_mask, n_seed, miss_rows, gather_idx, n_hit, n_miss } =
        collected;
    let xs_dev = match cache {
        None => None,
        Some(handle) => {
            let miss_dev = eng.upload(&miss_rows, n_miss * d.f)?;
            let out = eng.run_dev(
                "feature_gather",
                Stage::Collection,
                Phase::Fwd,
                &[Arg::Dev(&handle.dev), Arg::Dev(&miss_dev), Arg::Host(&gather_idx)],
            )?;
            eng.recycle_dev(miss_dev);
            eng.counters().borrow_mut().add_cache(n_hit as u64, n_miss as u64);
            Some(out)
        }
    };
    let batch = BatchData { xs, labels, seed_mask, n_seed, layers };
    Ok((batch, SpentBatch { mb, selected, miss_rows, gather_idx }, xs_dev))
}

/// Device-authoritative training state of the device-resident mode
/// (DESIGN.md §7): the on-device parameter set plus the static per-run
/// schema constants (type maps, target scalar, LR, zero-accumulator
/// seeds). Uploaded once at [`Trainer::new`] — warm-up traffic, outside
/// the per-epoch counters — and owned for the life of the trainer; the
/// host [`Params`] only rematerializes at [`Trainer::sync_params`] points.
pub(crate) struct DevState<B: ExecBackend> {
    pub(crate) params: DevParams<B>,
    pub(crate) schema: DevSchema<B>,
}

/// Outcome of one integrity-checked batch attempt (DESIGN.md §11). Both
/// arms hand the batch's buffers back so the circulating population stays
/// fixed across recomputes. `Violation` means the guard refused to apply —
/// the parameters are untouched, so a recompute from the same
/// `(epoch_perm, seq)` address (with the injection budget now consumed)
/// reproduces the fault-free step bitwise.
pub(crate) enum Attempt {
    Clean { loss: f32, ncorrect: f32, n_seed: usize, bufs: BatchBufs },
    Violation(BatchBufs),
}

pub struct Trainer<'g, 'e, B: ExecBackend> {
    pub eng: &'e B,
    pub graph: &'g HeteroGraph,
    pub exec: StepExecutor<'e, B>,
    pub schema: SchemaTensors,
    pub params: Params,
    pub cfg: TrainCfg,
    pub opt: OptConfig,
    /// Worker pool for the CPU stages (`TrainCfg::threads`): selection
    /// across relations, collection across types. Kernel-side threading is
    /// the backend's own pool (`SimBackend::builtin_threaded`).
    pub pool: WorkerPool,
    rng: Rng,
    /// Producer state kept across epochs (scratches + recycled buffer
    /// sets), so the steady-state zero-alloc contract covers the whole run.
    pub(crate) arsenal: ProducerArsenal,
    /// Device-resident feature cache ([`Trainer::attach_cache`]); `None` =
    /// classic full-slab collection.
    pub(crate) cache: Option<CacheHandle<B>>,
    /// Consumer-side pooled scratch for [`assemble_batch`].
    assemble: AssembleScratch,
    /// Device-authoritative params + schema constants; `Some` iff
    /// `opt.dev_resident` (single-backend path — the replica lanes carry
    /// their own per-round device state).
    pub(crate) dev: Option<DevState<B>>,
    /// Deterministic fault-injection plan (DESIGN.md §9); `None` (default)
    /// keeps every probe site a single `Option` check.
    pub(crate) fault: Option<Arc<FaultPlan>>,
    /// Per-batch numeric guard (`--guard`, DESIGN.md §11): checksum the
    /// feature payload across injection, scan loss/gradients for
    /// non-finites *before* the SGD apply. Guarded-but-clean runs are
    /// bitwise identical to unguarded ones (same dispatches, same bits).
    guard: bool,
    /// Parameter/cache audit cadence in batches (`--audit-every`); 0 = off.
    /// Every audited epoch also audits at its final batch, so the snapshot
    /// carried into the next epoch is always verified-good.
    audit_every: u64,
    /// Injection attempts already made per integrity-site address
    /// `(site, epoch, seq)`: a plan multiplicity of `N` corrupts the first
    /// `N` attempts at that address, so a recompute or rollback replay
    /// re-derives *clean* data once the budget is spent — the property that
    /// makes recovery converge. Cleared each integrity epoch (replays never
    /// cross an epoch); stays unallocated on fault-free runs.
    consumed: HashMap<(FaultSite, u64, u64), u32>,
    /// Last-known-good parameter snapshot (the rollback target), refreshed
    /// at every clean audit point; `None` until the first integrity epoch.
    last_good: Option<Params>,
    /// Per-batch `(loss, ncorrect, n_seed)` of the integrity paths, folded
    /// in batch order at epoch end — replays overwrite their slot instead
    /// of double-counting, keeping the f64 accumulation order (and thus
    /// the reported loss bits) identical to the classic incremental sum.
    /// Kept across epochs so the steady state stays allocation-free.
    batch_results: Vec<(f64, f64, usize)>,
}

impl<'g, 'e, B: ExecBackend> Trainer<'g, 'e, B> {
    pub fn new(
        eng: &'e B,
        graph: &'g HeteroGraph,
        model: ModelKind,
        opt: OptConfig,
        cfg: TrainCfg,
    ) -> Result<Self> {
        let d = Dims::from_backend(eng);
        assert_eq!(graph.feat_dim, d.f, "graph feature dim != profile F");
        assert!(graph.num_classes <= d.c, "dataset classes exceed profile C");
        let schema = schema_tensors(graph, &d);
        let exec = StepExecutor::new(eng, model, opt);
        let params = Params::init(d.rpad, d.f, d.h, d.c, cfg.seed);
        // Device-resident mode stages its authoritative state up front:
        // one-time warm-up H2D, before any epoch resets the counters.
        let dev = if opt.dev_resident {
            Some(DevState {
                params: exec.upload_params(&params)?,
                schema: exec.make_dev_schema(&schema, cfg.lr)?,
            })
        } else {
            None
        };
        Ok(Trainer {
            eng,
            graph,
            exec,
            schema,
            params,
            cfg,
            opt,
            pool: WorkerPool::new(cfg.threads),
            rng: Rng::new(cfg.seed),
            arsenal: ProducerArsenal::default(),
            cache: None,
            assemble: AssembleScratch::default(),
            dev,
            fault: None,
            guard: false,
            audit_every: 0,
            consumed: HashMap::new(),
            last_good: None,
            batch_results: Vec::new(),
        })
    }

    /// Attach a deterministic fault-injection plan (DESIGN.md §9): the
    /// backend consults it for dispatch faults (bounded retry), the
    /// pipelined feed for producer deaths (missing-sequence re-derivation).
    /// The recovery contract: the trajectory stays bitwise identical to a
    /// fault-free run; only the retry/recovery counters differ.
    pub fn set_fault_plan(&mut self, plan: Arc<FaultPlan>) {
        self.eng.set_fault_plan(plan.clone());
        self.fault = Some(plan);
    }

    /// Arm the per-batch numeric guard (DESIGN.md §11): feature-payload
    /// checksums across the injection window, a non-finite scan of
    /// loss/gradients *before* the SGD apply, and clean retransmission of
    /// corrupted uploads inside the backend. A guarded-but-clean run is
    /// bitwise identical to an unguarded one — the guard only ever refuses
    /// to apply corrupt data, it never changes clean data or adds
    /// dispatches. Incompatible with the fused device-resident step, whose
    /// single SGD module cannot split the check from the apply.
    pub fn set_guard(&mut self, on: bool) -> Result<()> {
        ensure!(
            !(on && self.opt.dev_resident),
            "--guard needs the host-staged step: the fused device SGD cannot \
             split the gradient check from the parameter apply"
        );
        self.guard = on;
        self.eng.set_integrity_guard(on);
        Ok(())
    }

    /// Audit every `n` batches (plus at every audited epoch's end):
    /// parameter finiteness scan, cache-slab digest verification, and a
    /// refresh of the rollback snapshot at each clean point. `0` disables.
    pub fn set_audit_every(&mut self, n: u64) -> Result<()> {
        ensure!(
            !(n > 0 && self.opt.dev_resident),
            "--audit-every needs host-authoritative parameters \
             (disable the device-resident mode)"
        );
        self.audit_every = n;
        Ok(())
    }

    /// Whether this run needs the integrity-checked epoch loop: a guard or
    /// audit cadence is set, or the fault plan carries data-corruption
    /// sites. Everything else takes the classic loop untouched.
    pub(crate) fn integrity_active(&self) -> bool {
        self.guard
            || self.audit_every > 0
            || self.fault.as_ref().is_some_and(|p| p.has_integrity_site())
    }

    /// Reset per-epoch integrity state: clear the injection budgets (a
    /// rollback never replays across an epoch boundary) and snapshot the
    /// current parameters as the epoch's first rollback target. The
    /// snapshot reuses its allocation after the first epoch.
    pub(crate) fn begin_integrity_epoch(&mut self) {
        self.consumed.clear();
        match &mut self.last_good {
            Some(s) => s.copy_from(&self.params),
            None => self.last_good = Some(self.params.clone()),
        }
    }

    /// FNV-1a over the batch's feature payload — the full collected slab
    /// with the cache off, the packed miss rows with it on (`None` when a
    /// fully-hit cached batch ships no feature bytes at all). Models the
    /// producer-side source checksum that travels with the payload.
    fn feature_digest(&self, prep: &PreparedCpu) -> Option<u64> {
        let c = &prep.collected;
        if self.cache.is_some() {
            let n = c.n_miss * self.exec.d.f;
            if n == 0 {
                return None;
            }
            Some(fnv1a_f32(&c.miss_rows.as_f32().ok()?[..n]))
        } else {
            Some(fnv1a_f32(c.xs.as_f32().ok()?))
        }
    }

    /// `flip!` injection: silently flip one mantissa bit of the batch's
    /// feature payload — the value stays finite, so nothing downstream
    /// errors; only a checksum can tell. Budgeted per address via
    /// `consumed` so recomputes re-derive clean data. Skips (without
    /// consuming) batches whose cached payload is empty.
    fn inject_flip(&mut self, prep: &mut PreparedCpu, epoch: u64, seq: u64) {
        let Some(plan) = self.fault.clone() else { return };
        let n = plan.fires(FaultSite::Flip, epoch, seq);
        if n == 0 {
            return;
        }
        let cached = self.cache.is_some();
        let f = self.exec.d.f;
        let c = &mut prep.collected;
        let payload: &mut [f32] = if cached {
            let len = c.n_miss * f;
            if len == 0 {
                return;
            }
            match c.miss_rows.as_f32_mut() {
                Ok(s) => &mut s[..len],
                Err(_) => return,
            }
        } else {
            match c.xs.as_f32_mut() {
                Ok(s) => s,
                Err(_) => return,
            }
        };
        let used = self.consumed.entry((FaultSite::Flip, epoch, seq)).or_insert(0);
        if *used >= n {
            return;
        }
        *used += 1;
        let h = plan.target_hash(FaultSite::Flip, epoch, seq);
        let elem = (h % payload.len() as u64) as usize;
        let bit = ((h >> 40) % 23) as u32;
        payload[elem] = f32::from_bits(payload[elem].to_bits() ^ (1 << bit));
    }

    /// `nan!` injection: drop a quiet NaN into the freshly computed
    /// gradient, after the backward pass and before the guard scan / SGD
    /// apply — the "activation/gradient goes non-finite" failure class.
    /// Same per-address budget discipline as [`Self::inject_flip`].
    fn inject_nan(&mut self, grads: &mut Params, epoch: u64, seq: u64) {
        let Some(plan) = self.fault.clone() else { return };
        let n = plan.fires(FaultSite::Nan, epoch, seq);
        if n == 0 {
            return;
        }
        let used = self.consumed.entry((FaultSite::Nan, epoch, seq)).or_insert(0);
        if *used >= n {
            return;
        }
        *used += 1;
        let h = plan.target_hash(FaultSite::Nan, epoch, seq);
        let i = (h % grads.w0.len() as u64) as usize;
        grads.w0[i] = f32::NAN;
    }

    /// One integrity-checked attempt at a batch: inject (budget
    /// permitting), detect, and — only if everything is clean or the guard
    /// is off — apply the SGD update. Mirrors [`Self::compute_batch`]'s
    /// host-staged path exactly (`train_step` ≡ `grad_step` + host SGD),
    /// so a clean guarded attempt is bitwise and dispatch-count identical
    /// to the classic loop.
    pub(crate) fn attempt_batch(
        &mut self,
        mut prep: PreparedCpu,
        epoch: u64,
        seq: u64,
    ) -> Result<Attempt> {
        let expect = if self.guard { self.feature_digest(&prep) } else { None };
        self.inject_flip(&mut prep, epoch, seq);
        if let Some(e) = expect {
            if self.feature_digest(&prep) != Some(e) {
                return Ok(Attempt::Violation(prep.into_bufs()));
            }
        }
        self.eng.fault_cursor(epoch, seq);
        let d = self.exec.d;
        let (batch, spent) = assemble_batch(
            self.eng,
            &d,
            &self.schema,
            self.cache.as_ref(),
            &mut self.assemble,
            prep,
        )?;
        let (res, mut grads) = self.exec.grad_step(&self.params, &self.schema, &batch)?;
        self.inject_nan(&mut grads, epoch, seq);
        if self.guard && !(res.loss.is_finite() && grads.is_finite()) {
            return Ok(Attempt::Violation(spent.reclaim(batch)));
        }
        self.params.sgd(&grads, self.cfg.lr);
        Ok(Attempt::Clean {
            loss: res.loss,
            ncorrect: res.ncorrect,
            n_seed: res.n_seed,
            bufs: spent.reclaim(batch),
        })
    }

    /// The recovery ladder for one scheduled batch (DESIGN.md §11):
    /// attempt → recompute from `(epoch_perm, seq)` → rollback to the last
    /// good snapshot and replay forward → give up. Returns the *first*
    /// attempt's buffers for the caller to route (feed ring or inline
    /// producer); retry buffers cycle through `standby`.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_batch_recovering(
        &mut self,
        standby: &mut CpuProducer<'g>,
        results: &mut [(f64, f64, usize)],
        prep: PreparedCpu,
        epoch: u64,
        b: usize,
        first: usize,
        snap_batch: usize,
        m: &mut EpochMetrics,
    ) -> Result<BatchBufs> {
        let mut first_bufs: Option<BatchBufs> = None;
        let mut prep = Some(prep);
        let mut attempt = 0u32;
        loop {
            let p = match prep.take() {
                Some(p) => p,
                None => standby.produce(epoch, b),
            };
            let (bufs, clean) = match self.attempt_batch(p, epoch, b as u64)? {
                Attempt::Clean { loss, ncorrect, n_seed, bufs } => {
                    results[b - first] = (loss as f64, ncorrect as f64, n_seed);
                    (bufs, true)
                }
                Attempt::Violation(bufs) => (bufs, false),
            };
            if attempt == 0 {
                first_bufs = Some(bufs);
            } else {
                standby.reclaim(bufs);
            }
            if clean {
                return Ok(first_bufs.expect("first attempt banked its buffers"));
            }
            self.eng.counters().borrow_mut().integrity_violations += 1;
            match attempt {
                0 => m.integrity_recomputes += 1,
                1 => {
                    m.integrity_rollbacks += 1;
                    self.rollback_and_replay(standby, results, epoch, snap_batch, first, b, m)?;
                }
                _ => bail!(
                    "batch (epoch {epoch}, batch {b}) failed its integrity check \
                     after recompute and rollback; giving up"
                ),
            }
            attempt += 1;
        }
    }

    /// Restore the last-good snapshot and replay `[snap_batch, upto)`
    /// forward: every replayed batch re-derives from its `(epoch_perm,
    /// seq)` address — bitwise the data the feed delivered — and lands in
    /// its `results` slot, so the epoch's folded metrics are those of the
    /// uninterrupted run. A replayed batch gets one recompute; persistent
    /// corruption under replay is a hard error.
    #[allow(clippy::too_many_arguments)]
    fn rollback_and_replay(
        &mut self,
        standby: &mut CpuProducer<'g>,
        results: &mut [(f64, f64, usize)],
        epoch: u64,
        snap_batch: usize,
        first: usize,
        upto: usize,
        m: &mut EpochMetrics,
    ) -> Result<()> {
        self.params
            .copy_from(self.last_good.as_ref().expect("integrity epochs snapshot up front"));
        for rb in snap_batch..upto {
            let mut ok = false;
            for retry in 0..2u32 {
                let p = standby.produce(epoch, rb);
                match self.attempt_batch(p, epoch, rb as u64)? {
                    Attempt::Clean { loss, ncorrect, n_seed, bufs } => {
                        standby.reclaim(bufs);
                        results[rb - first] = (loss as f64, ncorrect as f64, n_seed);
                        ok = true;
                    }
                    Attempt::Violation(bufs) => {
                        standby.reclaim(bufs);
                        self.eng.counters().borrow_mut().integrity_violations += 1;
                        if retry == 0 {
                            m.integrity_recomputes += 1;
                        }
                    }
                }
                if ok {
                    break;
                }
            }
            ensure!(
                ok,
                "replayed batch (epoch {epoch}, batch {rb}) failed its \
                 integrity check twice; giving up"
            );
        }
        Ok(())
    }

    /// The periodic audit point (DESIGN.md §11): after batch `b`, when the
    /// cadence (or the epoch end) says so, verify the cache slab digest
    /// (independent restage repair), scan the parameters for non-finites —
    /// post-apply corruption that only a rollback can undo — and, once
    /// clean, refresh the rollback snapshot so later rollbacks replay from
    /// here. Two failed rollback replays abort the epoch.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn maybe_audit(
        &mut self,
        standby: &mut CpuProducer<'g>,
        results: &mut [(f64, f64, usize)],
        epoch: u64,
        first: usize,
        b: usize,
        last: usize,
        snap_batch: &mut usize,
        m: &mut EpochMetrics,
    ) -> Result<()> {
        if self.audit_every == 0 {
            return Ok(());
        }
        let done = (b + 1 - first) as u64;
        if done % self.audit_every != 0 && b + 1 != last {
            return Ok(());
        }
        m.audits += 1;
        if let Some(handle) = self.cache.as_mut() {
            if !handle.verify_or_restage(self.eng)? {
                self.eng.counters().borrow_mut().integrity_violations += 1;
            }
        }
        let mut attempts = 0u32;
        while !self.params.is_finite() {
            self.eng.counters().borrow_mut().integrity_violations += 1;
            ensure!(
                attempts < 2,
                "parameters still non-finite after {attempts} rollback \
                 replay(s) at (epoch {epoch}, batch {b}); giving up"
            );
            attempts += 1;
            m.integrity_rollbacks += 1;
            self.rollback_and_replay(standby, results, epoch, *snap_batch, first, b + 1, m)?;
        }
        match &mut self.last_good {
            Some(s) => s.copy_from(&self.params),
            None => self.last_good = Some(self.params.clone()),
        }
        *snap_batch = b + 1;
        Ok(())
    }

    /// Pin a resident feature store on this trainer's backend (DESIGN.md
    /// §7): uploads the packed slab once and switches every subsequent
    /// batch to the hit/miss collection path. Must be called before the
    /// first epoch — recycled buffer sets are sized for the active mode.
    pub fn attach_cache(&mut self, store: Arc<ResidentStore>) -> Result<()> {
        ensure!(self.cache.is_none(), "a resident cache is already attached");
        ensure!(
            self.arsenal.stats == ProducerStats::default(),
            "attach the cache before the first epoch (buffer sets already circulate)"
        );
        self.cache = Some(CacheHandle::upload(self.eng, store)?);
        Ok(())
    }

    /// The attached resident store, if any.
    pub fn cache_store(&self) -> Option<&Arc<ResidentStore>> {
        self.cache.as_ref().map(|h| &h.store)
    }

    pub fn dims(&self) -> Dims {
        self.exec.d
    }

    fn sampler_cfg(&self) -> SamplerCfg {
        sampler_cfg(&self.cfg, &self.exec.d)
    }

    /// Cumulative producer buffer-pool stats (pool hits/misses/growth),
    /// mirroring `SimBackend::arena_stats` for the CPU side.
    pub fn producer_stats(&self) -> ProducerStats {
        self.arsenal.stats
    }

    /// Device half of batch preparation + the training step itself.
    /// Returns the step result and the batch's recycled buffers — hand
    /// them back to the producer ([`CpuProducer::reclaim`]) to keep the
    /// steady state allocation-free.
    pub fn compute_batch(&mut self, prep: PreparedCpu) -> Result<(f32, f32, usize, BatchBufs)> {
        let d = self.exec.d;
        if self.opt.dev_resident {
            // Device-resident step (DESIGN.md §7): activations, gradients
            // and parameters stay on-device; only the idx/miss uploads (or
            // the cache-off slab inside `upload_batch`) cross H2D and only
            // the two head scalars cross D2H — pinned by tests/residency.rs.
            let (batch, spent, xs_dev) = assemble_batch_dev(
                self.eng,
                &d,
                &self.schema,
                self.cache.as_ref(),
                &mut self.assemble,
                prep,
            )?;
            let dev_batch = self.exec.upload_batch(&batch, xs_dev)?;
            let dev = self.dev.as_mut().expect("dev_resident mode carries device state");
            let res =
                self.exec.train_step_dev(&mut dev.params, &dev.schema, &dev_batch, self.cfg.lr)?;
            self.exec.recycle_batch(dev_batch);
            return Ok((res.loss, res.ncorrect, res.n_seed, spent.reclaim(batch)));
        }
        let (batch, spent) = assemble_batch(
            self.eng,
            &d,
            &self.schema,
            self.cache.as_ref(),
            &mut self.assemble,
            prep,
        )?;
        let res = self.exec.train_step(&mut self.params, &self.schema, &batch, self.cfg.lr)?;
        Ok((res.loss, res.ncorrect, res.n_seed, spent.reclaim(batch)))
    }

    /// Read the authoritative device parameters back into `self.params`
    /// (checkpoint/eval sync point of the device-resident mode — counted
    /// D2H); no-op in the host-staged modes, where `self.params` is always
    /// authoritative.
    pub fn sync_params(&mut self) -> Result<()> {
        if let Some(dev) = &self.dev {
            self.exec.sync_params(&dev.params, &mut self.params)?;
        }
        Ok(())
    }

    /// Train one epoch; dispatches to the pipelined loop when enabled.
    pub fn train_epoch(&mut self, epoch: u64) -> Result<EpochMetrics> {
        self.train_epoch_range(epoch, 0, usize::MAX)
    }

    /// Train the contiguous sub-range `[first, last)` of one epoch's batch
    /// schedule (`last` is clamped to the schedule length) — the mid-epoch
    /// resume primitive behind the checkpoint cursor (DESIGN.md §9).
    /// Sampling is a pure function of `(seed, epoch, batch)`, so computing
    /// batches `cursor..` of the cursor epoch after reloading params
    /// reproduces the uninterrupted trajectory bitwise;
    /// [`Trainer::train_epoch`] is the full range.
    pub fn train_epoch_range(
        &mut self,
        epoch: u64,
        first: usize,
        last: usize,
    ) -> Result<EpochMetrics> {
        let scfg = self.sampler_cfg();
        let n_batches = NeighborSampler::new(self.graph, scfg).batches_per_epoch();
        let last = last.min(n_batches);
        let first = first.min(last);
        if self.opt.pipeline {
            pipeline::train_epoch_pipelined(self, epoch, first, last)
        } else if !self.opt.dev_resident && self.integrity_active() {
            self.train_epoch_sequential_integrity(epoch, first, last)
        } else {
            self.train_epoch_sequential(epoch, first, last)
        }
    }

    fn train_epoch_sequential(
        &mut self,
        epoch: u64,
        first: usize,
        last: usize,
    ) -> Result<EpochMetrics> {
        let scfg = self.sampler_cfg();
        let d = self.exec.d;
        let graph = self.graph;
        let wall0 = Instant::now();
        let mut m = EpochMetrics { batches: last - first, ..Default::default() };
        self.eng.reset_counters(false);
        let mut total_correct = 0.0f64;
        let mut total_seed = 0usize;
        let seed = self.arsenal.checkout(graph, 1).pop().expect("one seed");
        let cache_store = self.cache.as_ref().map(|h| h.store.clone());
        let mut producer = CpuProducer::from_seed(
            graph,
            scfg,
            d,
            self.opt,
            self.pool,
            self.rng.clone(),
            cache_store,
            seed,
        );
        let mut result: Result<()> = Ok(());
        for b in first..last {
            let prep = producer.produce(epoch, b);
            m.cpu_time += prep.cpu_time;
            m.cpu_by_stage += prep.cpu_by_stage;
            m.dropped_nodes += prep.dropped_nodes();
            m.dropped_edges += prep.dropped_edges();
            self.eng.fault_cursor(epoch, b as u64);
            match self.compute_batch(prep) {
                Ok((loss, ncorrect, n_seed, bufs)) => {
                    producer.reclaim(bufs);
                    m.loss += loss as f64;
                    total_correct += ncorrect as f64;
                    total_seed += n_seed;
                }
                Err(e) => {
                    result = Err(e);
                    break;
                }
            }
        }
        self.arsenal.checkin(producer.into_state());
        result?;
        self.finish_metrics(&mut m, wall0, total_correct, total_seed);
        m.producer = self.arsenal.stats;
        Ok(m)
    }

    /// [`Self::train_epoch_sequential`] with the integrity plane armed
    /// (DESIGN.md §11): each batch runs the detect/recompute/rollback
    /// ladder, audits fire on their cadence, and per-batch results fold at
    /// epoch end so replays overwrite instead of double-count. Taken only
    /// when [`Self::integrity_active`]; the fault-free classic loop is
    /// untouched (zero extra dispatches, zero extra allocations).
    fn train_epoch_sequential_integrity(
        &mut self,
        epoch: u64,
        first: usize,
        last: usize,
    ) -> Result<EpochMetrics> {
        let scfg = self.sampler_cfg();
        let d = self.exec.d;
        let graph = self.graph;
        let wall0 = Instant::now();
        let mut m = EpochMetrics { batches: last - first, ..Default::default() };
        self.eng.reset_counters(false);
        self.begin_integrity_epoch();
        let seed = self.arsenal.checkout(graph, 1).pop().expect("one seed");
        let cache_store = self.cache.as_ref().map(|h| h.store.clone());
        let mut producer = CpuProducer::from_seed(
            graph,
            scfg,
            d,
            self.opt,
            self.pool,
            self.rng.clone(),
            cache_store,
            seed,
        );
        let mut results = std::mem::take(&mut self.batch_results);
        results.clear();
        results.resize(last - first, (0.0, 0.0, 0));
        let mut snap_batch = first;
        let mut result: Result<()> = Ok(());
        for b in first..last {
            let prep = producer.produce(epoch, b);
            m.cpu_time += prep.cpu_time;
            m.cpu_by_stage += prep.cpu_by_stage;
            m.dropped_nodes += prep.dropped_nodes();
            m.dropped_edges += prep.dropped_edges();
            match self.run_batch_recovering(
                &mut producer,
                &mut results,
                prep,
                epoch,
                b,
                first,
                snap_batch,
                &mut m,
            ) {
                Ok(bufs) => producer.reclaim(bufs),
                Err(e) => {
                    result = Err(e);
                    break;
                }
            }
            if let Err(e) = self.maybe_audit(
                &mut producer,
                &mut results,
                epoch,
                first,
                b,
                last,
                &mut snap_batch,
                &mut m,
            ) {
                result = Err(e);
                break;
            }
        }
        self.arsenal.checkin(producer.into_state());
        let mut total_correct = 0.0f64;
        let mut total_seed = 0usize;
        for &(l, c, s) in &results {
            m.loss += l;
            total_correct += c;
            total_seed += s;
        }
        self.batch_results = results;
        result?;
        self.finish_metrics(&mut m, wall0, total_correct, total_seed);
        m.producer = self.arsenal.stats;
        Ok(m)
    }

    pub(crate) fn finish_metrics(
        &self,
        m: &mut EpochMetrics,
        wall0: Instant,
        total_correct: f64,
        total_seed: usize,
    ) {
        m.wall = wall0.elapsed();
        m.loss /= m.batches.max(1) as f64;
        m.acc = total_correct / total_seed.max(1) as f64;
        m.fill_from_counters(&self.eng.counters().borrow());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_cfg_is_sane() {
        let c = TrainCfg::default();
        assert!(c.batch_size > 0 && c.lr > 0.0 && c.threads >= 1);
        assert_eq!(c.producers, 0, "default derives producers from threads");
    }

    #[test]
    fn producer_count_derives_from_threads() {
        let auto4 = TrainCfg { threads: 4, producers: 0, ..Default::default() };
        assert_eq!(producer_count(&auto4), 2);
        let auto1 = TrainCfg { threads: 1, producers: 0, ..auto4 };
        assert_eq!(producer_count(&auto1), 1);
        let explicit = TrainCfg { producers: 3, ..auto1 };
        assert_eq!(producer_count(&explicit), 3);
        assert_eq!(lane_producer_count(&explicit, 2), 1);
        let four = TrainCfg { producers: 4, ..explicit };
        assert_eq!(lane_producer_count(&four, 2), 2);
        assert_eq!(lane_producer_count(&four, 0), 4);
    }

    #[test]
    fn producer_stats_accumulate() {
        let mut a = ProducerStats { fresh: 1, reused: 2, grown: 3 };
        a += ProducerStats { fresh: 10, reused: 20, grown: 30 };
        assert_eq!(a, ProducerStats { fresh: 11, reused: 22, grown: 33 });
        assert_eq!(a.allocations(), 44);
    }

    #[test]
    fn absorb_sums_additive_fields_only() {
        let mut a = EpochMetrics {
            loss: 1.0,
            acc: 0.5,
            wall: Duration::from_millis(7),
            cpu_time: Duration::from_millis(2),
            cpu_by_stage: CpuStageTimes {
                sample: Duration::from_micros(1),
                select: Duration::from_micros(2),
                collect: Duration::from_micros(3),
            },
            gpu_time: Duration::from_millis(3),
            h2d_bytes: 100,
            d2h_bytes: 10,
            p2p_bytes: 40,
            cache_hits: 6,
            cache_misses: 2,
            kernels_total: 10,
            kernels_fwd_semantic: 1,
            kernels_fwd_agg: 2,
            kernels_by_stage: vec![(Stage::Projection, 4), (Stage::Head, 1)],
            time_by_stage: vec![(Stage::Projection, Duration::from_micros(5))],
            arena: ArenaStats { hits: 5, misses: 1, bytes_recycled: 8, bytes_allocated: 16 },
            producer: ProducerStats { fresh: 1, reused: 4, grown: 2 },
            batches: 3,
            dropped_nodes: 1,
            dropped_edges: 2,
            dispatch_retries: 2,
            producer_recoveries: 1,
            lane_failovers: 1,
            integrity_violations: 2,
            integrity_retransmits: 1,
            integrity_recomputes: 1,
            integrity_rollbacks: 1,
            audits: 2,
        };
        let b = EpochMetrics {
            loss: 9.0,
            acc: 0.9,
            wall: Duration::from_millis(9),
            cpu_time: Duration::from_millis(1),
            cpu_by_stage: CpuStageTimes {
                sample: Duration::from_micros(4),
                select: Duration::from_micros(5),
                collect: Duration::from_micros(6),
            },
            gpu_time: Duration::from_millis(1),
            h2d_bytes: 11,
            d2h_bytes: 5,
            p2p_bytes: 2,
            cache_hits: 1,
            cache_misses: 3,
            kernels_total: 5,
            kernels_fwd_semantic: 2,
            kernels_fwd_agg: 1,
            kernels_by_stage: vec![(Stage::Projection, 1), (Stage::Aggregation, 6)],
            time_by_stage: vec![(Stage::Projection, Duration::from_micros(2))],
            arena: ArenaStats { hits: 1, misses: 1, bytes_recycled: 1, bytes_allocated: 1 },
            producer: ProducerStats { fresh: 2, reused: 8, grown: 1 },
            batches: 2,
            dropped_nodes: 0,
            dropped_edges: 1,
            dispatch_retries: 3,
            producer_recoveries: 0,
            lane_failovers: 2,
            integrity_violations: 3,
            integrity_retransmits: 0,
            integrity_recomputes: 2,
            integrity_rollbacks: 0,
            audits: 1,
        };
        a.absorb(&b);
        // Additive counters sum ...
        assert_eq!(a.kernels_total, 15);
        assert_eq!(a.kernels_fwd_semantic, 3);
        assert_eq!(a.kernels_fwd_agg, 3);
        assert_eq!(a.batches, 5);
        assert_eq!(a.cpu_time, Duration::from_millis(3));
        assert_eq!(
            a.cpu_by_stage,
            CpuStageTimes {
                sample: Duration::from_micros(5),
                select: Duration::from_micros(7),
                collect: Duration::from_micros(9),
            }
        );
        assert_eq!(a.gpu_time, Duration::from_millis(4));
        assert_eq!((a.h2d_bytes, a.d2h_bytes), (111, 15));
        assert_eq!(a.p2p_bytes, 42);
        assert_eq!((a.cache_hits, a.cache_misses), (7, 5));
        assert!((a.cache_hit_rate() - 7.0 / 12.0).abs() < 1e-12);
        assert_eq!(a.arena.hits, 6);
        assert_eq!(a.arena.misses, 2);
        assert_eq!(a.producer, ProducerStats { fresh: 3, reused: 12, grown: 3 });
        assert_eq!(a.dropped_nodes, 1);
        assert_eq!(a.dropped_edges, 3);
        assert_eq!(a.dispatch_retries, 5);
        assert_eq!(a.producer_recoveries, 1);
        assert_eq!(a.lane_failovers, 3);
        assert_eq!(a.integrity_violations, 5);
        assert_eq!(a.integrity_retransmits, 1);
        assert_eq!(a.integrity_recomputes, 3);
        assert_eq!(a.integrity_rollbacks, 1);
        assert_eq!(a.audits, 3);
        // ... stage rows merge by stage, appending unseen stages ...
        assert!(a.kernels_by_stage.contains(&(Stage::Projection, 5)));
        assert!(a.kernels_by_stage.contains(&(Stage::Head, 1)));
        assert!(a.kernels_by_stage.contains(&(Stage::Aggregation, 6)));
        assert!(a.time_by_stage.contains(&(Stage::Projection, Duration::from_micros(7))));
        // ... and the non-additive fields are untouched.
        assert_eq!(a.loss, 1.0);
        assert_eq!(a.acc, 0.5);
        assert_eq!(a.wall, Duration::from_millis(7));
    }
}
