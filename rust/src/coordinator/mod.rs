//! The coordinator: drives mini-batch HGNN training end-to-end (Fig. 2
//! workflow), switching between the PyG-style baseline plan and HiFuse
//! optimizations per `OptConfig`, sequentially or pipelined (Fig. 6).

pub mod ablation;
pub mod pipeline;
pub mod replica;

pub use ablation::OptConfig;
pub use replica::{replica_thread_budget, ReplicaGroup, ReplicaMetrics, DEFAULT_ROUND};

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::graph::{HeteroGraph, Layout};
use crate::models::step::{
    pad_layer_edges, schema_tensors, BatchData, Dims, SchemaTensors, StepExecutor,
};
use crate::models::{ModelKind, Params};
use crate::runtime::{ArenaStats, Counters, ExecBackend, Phase, Stage};
use crate::sampler::{collect, MiniBatch, NeighborSampler, RelEdges, SamplerCfg, TaggedEdges};
use crate::semantic;
use crate::util::{HostTensor, Rng, WorkerPool};

/// Training-run configuration.
#[derive(Clone, Copy, Debug)]
pub struct TrainCfg {
    pub epochs: usize,
    pub batch_size: usize,
    pub fanout: usize,
    pub lr: f32,
    pub seed: u64,
    /// CPU selection threads (the paper's OpenMP worker count).
    pub threads: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg { epochs: 1, batch_size: 64, fanout: 4, lr: 0.05, seed: 42, threads: 4 }
    }
}

/// Per-epoch measurements (feeds Tables 1/3 and Figs 7-11).
#[derive(Clone, Debug, Default)]
pub struct EpochMetrics {
    pub loss: f64,
    pub acc: f64,
    pub wall: Duration,
    /// Host-side stage time: sampling + CPU selection + collection.
    pub cpu_time: Duration,
    /// Device-side time: sum of dispatch durations ("GPU time").
    pub gpu_time: Duration,
    pub kernels_total: usize,
    pub kernels_fwd_semantic: usize,
    pub kernels_fwd_agg: usize,
    pub kernels_by_stage: Vec<(Stage, usize)>,
    /// Dispatch time per stage (the per-stage slice of `gpu_time`).
    pub time_by_stage: Vec<(Stage, Duration)>,
    /// Backend buffer-arena traffic, cumulative at epoch end (all-zero on
    /// backends without an arena). Per-epoch deltas = difference between
    /// consecutive epochs' snapshots.
    pub arena: ArenaStats,
    pub batches: usize,
    pub dropped_nodes: usize,
    pub dropped_edges: usize,
}

impl EpochMetrics {
    /// Copy the counter-derived fields (dispatch counts, stage breakdowns,
    /// gpu time, arena snapshot) out of a dispatch log — the single source
    /// of these fields for both the single-backend path
    /// ([`Trainer::train_epoch`]) and the per-replica metrics.
    pub fn fill_from_counters(&mut self, c: &Counters) {
        self.gpu_time = c.gpu_time;
        self.kernels_total = c.total();
        self.kernels_fwd_semantic = c.count_phase(Stage::SemanticBuild, Phase::Fwd);
        self.kernels_fwd_agg = c.count_phase(Stage::Aggregation, Phase::Fwd);
        self.kernels_by_stage = c.by_stage();
        self.time_by_stage = c.time_by_stage();
        self.arena = c.arena;
    }

    /// Sum `other`'s **additive counter fields** into `self`: batch and
    /// kernel counts, per-stage counts/times, cpu/gpu time, arena traffic,
    /// drop counters. The ratio fields (`loss`, `acc`) and `wall` are *not*
    /// merged — they are not additive across replicas; the replica group
    /// computes them from the global batch results (DESIGN.md §4).
    pub fn absorb(&mut self, other: &EpochMetrics) {
        self.cpu_time += other.cpu_time;
        self.gpu_time += other.gpu_time;
        self.kernels_total += other.kernels_total;
        self.kernels_fwd_semantic += other.kernels_fwd_semantic;
        self.kernels_fwd_agg += other.kernels_fwd_agg;
        merge_stage_vec(&mut self.kernels_by_stage, &other.kernels_by_stage);
        merge_stage_vec(&mut self.time_by_stage, &other.time_by_stage);
        self.arena += other.arena;
        self.batches += other.batches;
        self.dropped_nodes += other.dropped_nodes;
        self.dropped_edges += other.dropped_edges;
    }
}

/// Merge per-stage `(Stage, T)` rows by stage, preserving `into`'s order and
/// appending stages it has not seen yet.
fn merge_stage_vec<T: Copy + std::ops::AddAssign>(
    into: &mut Vec<(Stage, T)>,
    from: &[(Stage, T)],
) {
    for &(s, v) in from {
        match into.iter_mut().find(|(t, _)| *t == s) {
            Some((_, acc)) => *acc += v,
            None => into.push((s, v)),
        }
    }
}

/// CPU-side product of batch preparation (safe to build on a producer
/// thread; contains no backend handles).
pub struct PreparedCpu {
    pub collected: collect::Collected,
    /// `Some` when selection ran on CPU (offload path).
    pub selected: Option<Vec<Vec<RelEdges>>>,
    /// `Some` when selection must run on "GPU" (baseline path).
    pub tagged: Option<Vec<TaggedEdges>>,
    pub cpu_time: Duration,
    pub dropped_nodes: usize,
    pub dropped_edges: usize,
}

/// The profile-capped sampler configuration a training run uses — shared
/// by `Trainer` and the replica lanes so both paths sample identical
/// batches (the bit-exactness contract depends on it).
pub(crate) fn sampler_cfg(cfg: &TrainCfg, d: &Dims) -> SamplerCfg {
    SamplerCfg {
        batch_size: cfg.batch_size,
        fanout: cfg.fanout,
        layers: 2,
        ns: d.ns,
        ep: d.ep,
    }
}

/// Materialize the feature layout an `OptConfig` requires (the paper's
/// reorganization): call before constructing a `Trainer`.
pub fn prepare_graph_layout(g: &mut HeteroGraph, opt: &OptConfig) {
    let want = if opt.reorg { Layout::TypeMajor } else { Layout::IndexMajor };
    g.features.ensure_layout(want);
}

/// CPU half of batch preparation (runs on the producer thread in pipeline
/// mode; touches no backend handles): sample, (optionally) select on CPU,
/// collect. `pool` partitions both CPU stages (selection across relations,
/// collection across types).
pub fn prepare_cpu(
    graph: &HeteroGraph,
    scfg: SamplerCfg,
    d: &Dims,
    opt: &OptConfig,
    pool: &WorkerPool,
    rng: &Rng,
    epoch: u64,
    batch_idx: usize,
) -> PreparedCpu {
    let t0 = Instant::now();
    let sampler = NeighborSampler::new(graph, scfg);
    let mb: MiniBatch = sampler.sample(rng, epoch, batch_idx);
    let n_rel = graph.n_relations();
    let selected = if opt.offload {
        Some(
            mb.tagged
                .iter()
                .map(|t| {
                    if opt.parallel {
                        semantic::select_parallel(t, n_rel, pool.threads())
                    } else {
                        semantic::select_serial(t, n_rel)
                    }
                })
                .collect::<Vec<_>>(),
        )
    } else {
        None
    };
    let collected = collect::collect(graph, &mb, d.tpad, d.ns, d.f, pool);
    PreparedCpu {
        collected,
        selected,
        tagged: if opt.offload { None } else { Some(mb.tagged) },
        cpu_time: t0.elapsed(),
        dropped_nodes: mb.dropped_nodes,
        dropped_edges: mb.dropped_edges,
    }
}

/// "GPU" edge-index selection (baseline): one `edge_select` dispatch per
/// relation per layer (the compare+index_select kernel pair), then host
/// extraction of the selected endpoints.
pub fn gpu_select<B: ExecBackend>(
    eng: &B,
    d: &Dims,
    tagged: &TaggedEdges,
    n_rel: usize,
) -> Result<Vec<RelEdges>> {
    // Pad the tagged type column to ELP with a sentinel (RPAD never matches
    // a real relation id).
    let mut et = vec![d.rpad as i32; d.elp];
    for (i, &r) in tagged.rel.iter().enumerate() {
        et[i] = r as i32;
    }
    let et = HostTensor::i32(et, &[d.elp]);
    let mut out = Vec::with_capacity(n_rel);
    for r in 0..n_rel {
        let rel = HostTensor::scalar_i32(r as i32);
        let mut res = eng
            .run("edge_select", Stage::SemanticBuild, Phase::Fwd, &[&et, &rel])?
            .into_iter();
        let pos_t = res.next().unwrap();
        let count = res.next().unwrap().scalar()? as usize;
        let pos = pos_t.as_i32()?;
        let mut e = RelEdges::default();
        for &p in &pos[..count] {
            e.src.push(tagged.src[p as usize]);
            e.dst.push(tagged.dst[p as usize]);
        }
        eng.recycle(pos_t);
        out.push(e);
    }
    Ok(out)
}

/// Device half of batch preparation, shared by [`Trainer::compute_batch`]
/// and the replica lanes: resolve per-relation edges (taking the baseline
/// `edge_select` dispatches when selection did not run on CPU), pad them
/// into module tensors, and wrap the collected features as a [`BatchData`].
pub fn assemble_batch<B: ExecBackend>(
    eng: &B,
    d: &Dims,
    schema: &SchemaTensors,
    prep: PreparedCpu,
) -> Result<BatchData> {
    let selected: Vec<Vec<RelEdges>> = match (prep.selected, prep.tagged) {
        (Some(s), _) => s,
        (None, Some(tagged)) => tagged
            .iter()
            .map(|t| gpu_select(eng, d, t, schema.n_rel))
            .collect::<Result<_>>()?,
        _ => unreachable!("prepare_cpu always sets one of selected/tagged"),
    };
    let layers = selected.iter().map(|rels| pad_layer_edges(rels, d)).collect();
    Ok(BatchData {
        xs: prep.collected.xs,
        labels: prep.collected.labels,
        seed_mask: prep.collected.seed_mask,
        n_seed: prep.collected.n_seed,
        layers,
    })
}

pub struct Trainer<'g, 'e, B: ExecBackend> {
    pub eng: &'e B,
    pub graph: &'g HeteroGraph,
    pub exec: StepExecutor<'e, B>,
    pub schema: SchemaTensors,
    pub params: Params,
    pub cfg: TrainCfg,
    pub opt: OptConfig,
    /// Worker pool for the CPU stages (`TrainCfg::threads`): selection
    /// across relations, collection across types. Kernel-side threading is
    /// the backend's own pool (`SimBackend::builtin_threaded`).
    pub pool: WorkerPool,
    rng: Rng,
}

impl<'g, 'e, B: ExecBackend> Trainer<'g, 'e, B> {
    pub fn new(
        eng: &'e B,
        graph: &'g HeteroGraph,
        model: ModelKind,
        opt: OptConfig,
        cfg: TrainCfg,
    ) -> Result<Self> {
        let d = Dims::from_backend(eng);
        assert_eq!(graph.feat_dim, d.f, "graph feature dim != profile F");
        assert!(graph.num_classes <= d.c, "dataset classes exceed profile C");
        let schema = schema_tensors(graph, &d);
        let exec = StepExecutor::new(eng, model, opt);
        let params = Params::init(d.rpad, d.f, d.h, d.c, cfg.seed);
        Ok(Trainer {
            eng,
            graph,
            exec,
            schema,
            params,
            cfg,
            opt,
            pool: WorkerPool::new(cfg.threads),
            rng: Rng::new(cfg.seed),
        })
    }

    pub fn dims(&self) -> Dims {
        self.exec.d
    }

    fn sampler_cfg(&self) -> SamplerCfg {
        sampler_cfg(&self.cfg, &self.exec.d)
    }

    /// Device half of batch preparation + the training step itself.
    pub fn compute_batch(&mut self, prep: PreparedCpu) -> Result<(f32, f32, usize)> {
        let d = self.exec.d;
        let batch = assemble_batch(self.eng, &d, &self.schema, prep)?;
        let res = self.exec.train_step(&mut self.params, &self.schema, &batch, self.cfg.lr)?;
        Ok((res.loss, res.ncorrect, res.n_seed))
    }

    /// Train one epoch; dispatches to the pipelined loop when enabled.
    pub fn train_epoch(&mut self, epoch: u64) -> Result<EpochMetrics> {
        if self.opt.pipeline {
            pipeline::train_epoch_pipelined(self, epoch)
        } else {
            self.train_epoch_sequential(epoch)
        }
    }

    fn train_epoch_sequential(&mut self, epoch: u64) -> Result<EpochMetrics> {
        let scfg = self.sampler_cfg();
        let n_batches = NeighborSampler::new(self.graph, scfg).batches_per_epoch();
        let d = self.exec.d;
        let wall0 = Instant::now();
        let mut m = EpochMetrics { batches: n_batches, ..Default::default() };
        self.eng.reset_counters(false);
        let mut total_correct = 0.0f64;
        let mut total_seed = 0usize;
        for b in 0..n_batches {
            let prep = prepare_cpu(
                self.graph, scfg, &d, &self.opt, &self.pool, &self.rng, epoch, b,
            );
            m.cpu_time += prep.cpu_time;
            m.dropped_nodes += prep.dropped_nodes;
            m.dropped_edges += prep.dropped_edges;
            let (loss, ncorrect, n_seed) = self.compute_batch(prep)?;
            m.loss += loss as f64;
            total_correct += ncorrect as f64;
            total_seed += n_seed;
        }
        self.finish_metrics(&mut m, wall0, total_correct, total_seed);
        Ok(m)
    }

    pub(crate) fn finish_metrics(
        &self,
        m: &mut EpochMetrics,
        wall0: Instant,
        total_correct: f64,
        total_seed: usize,
    ) {
        m.wall = wall0.elapsed();
        m.loss /= m.batches.max(1) as f64;
        m.acc = total_correct / total_seed.max(1) as f64;
        m.fill_from_counters(&self.eng.counters().borrow());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_cfg_is_sane() {
        let c = TrainCfg::default();
        assert!(c.batch_size > 0 && c.lr > 0.0 && c.threads >= 1);
    }

    #[test]
    fn absorb_sums_additive_fields_only() {
        let mut a = EpochMetrics {
            loss: 1.0,
            acc: 0.5,
            wall: Duration::from_millis(7),
            cpu_time: Duration::from_millis(2),
            gpu_time: Duration::from_millis(3),
            kernels_total: 10,
            kernels_fwd_semantic: 1,
            kernels_fwd_agg: 2,
            kernels_by_stage: vec![(Stage::Projection, 4), (Stage::Head, 1)],
            time_by_stage: vec![(Stage::Projection, Duration::from_micros(5))],
            arena: ArenaStats { hits: 5, misses: 1, bytes_recycled: 8, bytes_allocated: 16 },
            batches: 3,
            dropped_nodes: 1,
            dropped_edges: 2,
        };
        let b = EpochMetrics {
            loss: 9.0,
            acc: 0.9,
            wall: Duration::from_millis(9),
            cpu_time: Duration::from_millis(1),
            gpu_time: Duration::from_millis(1),
            kernels_total: 5,
            kernels_fwd_semantic: 2,
            kernels_fwd_agg: 1,
            kernels_by_stage: vec![(Stage::Projection, 1), (Stage::Aggregation, 6)],
            time_by_stage: vec![(Stage::Projection, Duration::from_micros(2))],
            arena: ArenaStats { hits: 1, misses: 1, bytes_recycled: 1, bytes_allocated: 1 },
            batches: 2,
            dropped_nodes: 0,
            dropped_edges: 1,
        };
        a.absorb(&b);
        // Additive counters sum ...
        assert_eq!(a.kernels_total, 15);
        assert_eq!(a.kernels_fwd_semantic, 3);
        assert_eq!(a.kernels_fwd_agg, 3);
        assert_eq!(a.batches, 5);
        assert_eq!(a.cpu_time, Duration::from_millis(3));
        assert_eq!(a.gpu_time, Duration::from_millis(4));
        assert_eq!(a.arena.hits, 6);
        assert_eq!(a.arena.misses, 2);
        assert_eq!(a.dropped_nodes, 1);
        assert_eq!(a.dropped_edges, 3);
        // ... stage rows merge by stage, appending unseen stages ...
        assert!(a.kernels_by_stage.contains(&(Stage::Projection, 5)));
        assert!(a.kernels_by_stage.contains(&(Stage::Head, 1)));
        assert!(a.kernels_by_stage.contains(&(Stage::Aggregation, 6)));
        assert!(a.time_by_stage.contains(&(Stage::Projection, Duration::from_micros(7))));
        // ... and the non-additive fields are untouched.
        assert_eq!(a.loss, 1.0);
        assert_eq!(a.acc, 0.5);
        assert_eq!(a.wall, Duration::from_millis(7));
    }
}
