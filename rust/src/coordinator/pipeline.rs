//! Asynchronous CPU/GPU pipeline (the paper's Fig. 6).
//!
//! A producer thread runs the CPU stages — mini-batch sampling, CPU
//! edge-index selection, feature collection — while the main thread runs
//! model computation on the execution backend. A bounded channel (depth 2)
//! provides the backpressure: the CPU may run at most two batches ahead,
//! like the paper's dedicated transfer stream feeding the compute stream.
//!
//! Backends may be `!Send` (the PJRT client is Rc-based), so compute stays
//! on the calling thread and only plain host data crosses the channel — the
//! design reason `PreparedCpu` contains no backend handles.
//!
//! The data-parallel replica path ([`super::replica`], DESIGN.md §4) fans
//! this same producer out to one bounded channel per replica lane; this
//! module remains the single-backend (depth-2, one-consumer) form.

use std::sync::mpsc::sync_channel;
use std::time::Instant;

use anyhow::Result;

use super::{prepare_cpu, EpochMetrics, PreparedCpu, Trainer};
use crate::runtime::ExecBackend;
use crate::sampler::NeighborSampler;

/// Depth of the producer->consumer channel (batches in flight).
pub const PIPELINE_DEPTH: usize = 2;

pub fn train_epoch_pipelined<B: ExecBackend>(
    tr: &mut Trainer<'_, '_, B>,
    epoch: u64,
) -> Result<EpochMetrics> {
    let scfg = tr.sampler_cfg();
    let n_batches = NeighborSampler::new(tr.graph, scfg).batches_per_epoch();
    let d = tr.exec.d;
    let opt = tr.opt;
    let pool = tr.pool;
    let rng = tr.rng.clone();
    let graph = tr.graph;

    let wall0 = Instant::now();
    tr.eng.reset_counters(false);
    let mut m = EpochMetrics { batches: n_batches, ..Default::default() };
    let mut total_correct = 0.0f64;
    let mut total_seed = 0usize;

    let mut result: Result<()> = Ok(());
    std::thread::scope(|s| {
        let (tx, rx) = sync_channel::<PreparedCpu>(PIPELINE_DEPTH);
        s.spawn(move || {
            for b in 0..n_batches {
                let prep = prepare_cpu(graph, scfg, &d, &opt, &pool, &rng, epoch, b);
                if tx.send(prep).is_err() {
                    return; // consumer bailed
                }
            }
        });
        for _ in 0..n_batches {
            let prep = match rx.recv() {
                Ok(p) => p,
                Err(_) => break,
            };
            m.cpu_time += prep.cpu_time;
            m.dropped_nodes += prep.dropped_nodes;
            m.dropped_edges += prep.dropped_edges;
            match tr.compute_batch(prep) {
                Ok((loss, ncorrect, n_seed)) => {
                    m.loss += loss as f64;
                    total_correct += ncorrect as f64;
                    total_seed += n_seed;
                }
                Err(e) => {
                    result = Err(e);
                    break; // dropping rx unblocks the producer
                }
            }
        }
        drop(rx);
    });
    result?;
    tr.finish_metrics(&mut m, wall0, total_correct, total_seed);
    Ok(m)
}
