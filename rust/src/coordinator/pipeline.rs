//! Asynchronous CPU/GPU pipeline (the paper's Fig. 6), multi-producer.
//!
//! `M` producer threads ([`producer_count`](super::producer_count), the
//! paper's "multi-threading and asynchronous pipeline" on the workflow
//! side) run the CPU stages — mini-batch sampling, CPU edge-index
//! selection, feature collection — while the consuming thread runs model
//! computation on the execution backend. Producer `p` prepares batch
//! positions `p, p+M, p+2M, ...` of the epoch's schedule; completed batches
//! arrive on one shared channel tagged with their **sequence number**, and
//! the consumer restores exact global order through a fixed-capacity
//! reorder ring — so delivery order (and therefore the training
//! trajectory, bit for bit) is identical for every producer count.
//!
//! Backpressure is **credit-based**: each producer owns at most
//! [`PIPELINE_DEPTH`] buffer sets; once they are all in flight it blocks
//! until the consumer returns one over that producer's recycle channel
//! (`BatchFeed::recycle`). The recycle channel thus doubles as flow
//! control *and* as the allocation loop-closer: in steady state a fixed
//! population of at most `M × PIPELINE_DEPTH` buffer sets (each producer
//! holds `min(PIPELINE_DEPTH, its stride length)`) circulates and the CPU
//! side allocates nothing per batch (DESIGN.md §5).
//!
//! Deadlock-freedom: a producer only blocks with `PIPELINE_DEPTH` of its
//! batches outstanding, all at earlier positions than the one it would
//! produce next; the consumer delivers positions in order, so those batches
//! are consumed (and their buffers returned) before the consumer ever waits
//! on this producer again.
//!
//! Backends may be `!Send` (the PJRT client is Rc-based), so compute stays
//! on the calling thread and only plain host data crosses the channels —
//! the design reason `PreparedCpu` contains no backend handles. The
//! data-parallel replica path ([`super::replica`], DESIGN.md §4) fans the
//! same machinery out to one feed per replica lane.
//!
//! Device residency (DESIGN.md §7) is orthogonal to the pipeline: producers
//! only ever touch host data, and the consumer's `Trainer::compute_batch`
//! carries the device-resident branch internally — in `--mode resident` the
//! consumed `PreparedCpu` is assembled straight into `DevBuf`s and the
//! buffer sets recycle exactly as in the host-staged modes
//! (`SpentBatch::reclaim` keeps the host slab alive for reuse even when the
//! device path never read it).

use std::sync::mpsc::{channel, sync_channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::Scope;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{
    producer_count, BatchBufs, CpuProducer, EpochMetrics, OptConfig, PreparedCpu, ProducerSeed,
    ProducerState, ProducerStats, Trainer,
};
use crate::graph::HeteroGraph;
use crate::models::step::Dims;
use crate::runtime::{ExecBackend, ResidentStore};
use crate::sampler::{epoch_perm, SamplerCfg};
use crate::util::{FaultPlan, FaultSite, Rng, WorkerPool};

/// Buffer sets each producer may have in flight (its flow-control credit);
/// total pipeline depth is `producers × PIPELINE_DEPTH`.
pub const PIPELINE_DEPTH: usize = 2;

/// One sequence-tagged message from a producer: a prepared batch, or the
/// tombstone a worker emits when an injected fault kills it (DESIGN.md §9).
/// Because delivery is FIFO per sender, a tombstone at position `p` also
/// proves every later position of that producer's stride is lost.
pub(crate) enum FeedMsg {
    Batch(PreparedCpu),
    Died,
}

/// What [`BatchFeed::recv_next`] delivers for one schedule position: the
/// prepared batch, or notice that its producer died first and the caller
/// must re-derive the batch from `(epoch_perm, seq)` — sampling is a pure
/// function of the schedule, so the re-derived batch is bitwise the one
/// the dead worker would have produced.
pub(crate) enum FeedSlot {
    Batch(PreparedCpu),
    Lost,
}

/// The consumer end of a multi-producer batch pipeline: receives
/// sequence-tagged batches, restores global order, and routes consumed
/// buffers back to their producers.
pub(crate) struct BatchFeed {
    rx: Receiver<(usize, FeedMsg)>,
    back: Vec<Sender<BatchBufs>>,
    /// Fixed-capacity reorder ring indexed by `position % capacity`; the
    /// credit bound keeps every in-flight position within one window.
    ring: Vec<Option<PreparedCpu>>,
    /// Producers that sent a death tombstone: every undelivered position of
    /// `back.len()`-strided producer `p` with `dead[p]` is a missing
    /// sequence number.
    dead: Vec<bool>,
    next: usize,
    leftover: Vec<BatchBufs>,
}

impl BatchFeed {
    /// Deliver the next schedule position in exact global order, buffering
    /// out-of-order arrivals in the ring. A position whose producer died
    /// before delivering it comes back as [`FeedSlot::Lost`] — the reorder
    /// ring's missing-sequence detection.
    pub(crate) fn recv_next(&mut self) -> Result<FeedSlot> {
        let cap = self.ring.len();
        if let Some(p) = self.ring[self.next % cap].take() {
            self.next += 1;
            return Ok(FeedSlot::Batch(p));
        }
        if self.dead[self.next % self.back.len()] {
            // The owner of this sequence died and nothing for it is
            // buffered; per-sender FIFO order means nothing is in flight
            // either. Report the hole instead of blocking forever.
            self.next += 1;
            return Ok(FeedSlot::Lost);
        }
        loop {
            let (pos, msg) = self.rx.recv().map_err(|_| {
                anyhow!("batch producers disconnected before position {}", self.next)
            })?;
            let p = match msg {
                FeedMsg::Batch(p) => p,
                FeedMsg::Died => {
                    self.dead[pos % self.back.len()] = true;
                    if pos == self.next {
                        self.next += 1;
                        return Ok(FeedSlot::Lost);
                    }
                    // Tombstone for a later position: keep draining — the
                    // producer that owns `next` is still alive (a dead
                    // owner would have been caught above).
                    continue;
                }
            };
            if pos == self.next {
                self.next += 1;
                return Ok(FeedSlot::Batch(p));
            }
            debug_assert!(pos > self.next, "position {pos} delivered twice");
            assert!(
                pos - self.next < cap,
                "reorder ring overflow (pos {pos}, next {}, cap {cap})",
                self.next
            );
            let slot = &mut self.ring[pos % cap];
            debug_assert!(slot.is_none(), "reorder slot collision at {pos}");
            *slot = Some(p);
        }
    }

    /// Hand a consumed batch's buffers back to the producer that prepared
    /// position `pos`; if that producer already finished its slice, keep
    /// the set for the arsenal instead.
    pub(crate) fn recycle(&mut self, pos: usize, bufs: BatchBufs) {
        if let Err(e) = self.back[pos % self.back.len()].send(bufs) {
            self.leftover.push(e.0);
        }
    }

    /// Tear the feed down, recovering the buffers of any batch that was
    /// produced but never computed (early exit on error). Dropping the
    /// returned value's channels unblocks every producer.
    pub(crate) fn finish(mut self) -> Vec<BatchBufs> {
        for slot in &mut self.ring {
            if let Some(p) = slot.take() {
                self.leftover.push(p.into_bufs());
            }
        }
        while let Ok((_, msg)) = self.rx.try_recv() {
            if let FeedMsg::Batch(p) = msg {
                self.leftover.push(p.into_bufs());
            }
        }
        self.leftover
    }
}

/// Spawn `producers` sampling workers over `batches` (an epoch schedule, in
/// delivery order) inside `scope`. `seeds` must hold exactly one
/// [`ProducerSeed`] per producer (arsenal checkout); `perm` is the epoch's
/// shared train-split permutation ([`epoch_perm`]) installed into every
/// worker's scratch — one `Arc` instead of per-producer byte-identical
/// shuffles (DESIGN.md §5). `cache` is the run's shared resident-store
/// index, if a feature cache is attached. Each worker's final state arrives
/// on the returned state channel once it exits; the caller drains it after
/// dropping/finishing the feed. `fault` is the run's injection plan, if
/// any: a worker that hits a [`FaultSite::Producer`] entry for one of its
/// batches dies there — tombstone, state surrender, thread exit — and the
/// consumer re-derives the hole (DESIGN.md §9).
#[allow(clippy::too_many_arguments)]
pub(crate) fn spawn_feed<'scope, 'env>(
    s: &'scope Scope<'scope, 'env>,
    graph: &'env HeteroGraph,
    scfg: SamplerCfg,
    d: Dims,
    opt: OptConfig,
    pool: WorkerPool,
    rng: &Rng,
    epoch: u64,
    batches: &[usize],
    producers: usize,
    seeds: Vec<ProducerSeed>,
    perm: &Arc<Vec<u32>>,
    cache: Option<&Arc<ResidentStore>>,
    fault: Option<&Arc<FaultPlan>>,
) -> (BatchFeed, Receiver<ProducerState>) {
    let m = producers.max(1);
    assert_eq!(seeds.len(), m, "one seed per producer");
    let (tx, rx) = sync_channel::<(usize, FeedMsg)>(m * PIPELINE_DEPTH);
    let (state_tx, state_rx) = channel::<ProducerState>();
    let mut back = Vec::with_capacity(m);
    for (pi, mut seed) in seeds.into_iter().enumerate() {
        seed.scratch.install_epoch_perm(perm.clone(), rng, epoch);
        let (btx, brx) = channel::<BatchBufs>();
        back.push(btx);
        // This producer's stride of the schedule: (position, batch id).
        let my: Vec<(usize, usize)> = batches
            .iter()
            .copied()
            .enumerate()
            .skip(pi)
            .step_by(m)
            .collect();
        if my.is_empty() {
            // Nothing to do (more producers than batches): return the seed
            // straight to the arsenal instead of spawning an idle worker
            // that would preallocate never-used buffer sets.
            let _ = state_tx.send(ProducerState {
                scratch: seed.scratch,
                spare: seed.spare,
                stats: ProducerStats::default(),
                returns: None,
            });
            continue;
        }
        let credit = PIPELINE_DEPTH.min(my.len());
        let tx = tx.clone();
        let state_tx = state_tx.clone();
        let rng = rng.clone();
        let cache = cache.cloned();
        let fault = fault.cloned();
        s.spawn(move || {
            let mut producer =
                CpuProducer::from_seed(graph, scfg, d, opt, pool, rng, cache, seed);
            // Full credit up front (capped at the stride length — a
            // producer never needs more sets in flight than it has
            // batches): the circulating buffer population is fixed from
            // the first batch on, so steady-state epochs are
            // deterministically allocation-free, with no race against the
            // consumer's returns.
            producer.preallocate(credit);
            for (pos, b) in my {
                if fault
                    .as_ref()
                    .is_some_and(|p| p.fires(FaultSite::Producer, epoch, b as u64) > 0)
                {
                    // Injected death before delivering `pos`: the tombstone
                    // is the missing-sequence notice (FIFO per sender makes
                    // it also cover every later stride position), and the
                    // state surrender below models the runtime reclaiming
                    // the dead worker's buffers.
                    let _ = tx.send((pos, FeedMsg::Died));
                    break;
                }
                refill(&mut producer, &brx);
                let prep = producer.produce(epoch, b);
                if tx.send((pos, FeedMsg::Batch(prep))).is_err() {
                    break; // consumer bailed
                }
            }
            // Surrender the state; the recycle receiver rides along so a
            // return that raced this exit is drained at arsenal checkin.
            let mut state = producer.into_state();
            state.returns = Some(brx);
            let _ = state_tx.send(state);
        });
    }
    // Ring capacity: every in-flight position is within `credit` of `next`
    // per producer; one producer-stride window of slack on top makes the
    // bound comfortable without masking logic errors (overflow asserts).
    let cap = m * PIPELINE_DEPTH + m;
    let feed = BatchFeed {
        rx,
        back,
        ring: (0..cap).map(|_| None).collect(),
        dead: vec![false; m],
        next: 0,
        leftover: Vec::new(),
    };
    (feed, state_rx)
}

/// Top the producer's pool up from its recycle channel. Non-blocking while
/// the producer still has credit (fewer than [`PIPELINE_DEPTH`] sets
/// originated); at full credit it blocks for a return — the pipeline's
/// backpressure. A disconnected channel (consumer gone) falls through: the
/// next `produce`+send fails and the worker exits.
fn refill(producer: &mut CpuProducer<'_>, returns: &Receiver<BatchBufs>) {
    while let Ok(b) = returns.try_recv() {
        producer.reclaim(b);
    }
    if producer.spare_is_empty() && producer.owned() >= PIPELINE_DEPTH {
        if let Ok(b) = returns.recv() {
            producer.reclaim(b);
        }
    }
}

/// Pipelined epoch over the batch sub-range `[first, last)` (the caller —
/// [`Trainer::train_epoch_range`] — has already clamped it to the epoch's
/// schedule length; the full epoch is `[0, batches_per_epoch)`).
pub fn train_epoch_pipelined<B: ExecBackend>(
    tr: &mut Trainer<'_, '_, B>,
    epoch: u64,
    first: usize,
    last: usize,
) -> Result<EpochMetrics> {
    let scfg = tr.sampler_cfg();
    let d = tr.exec.d;
    let opt = tr.opt;
    let rng = tr.rng.clone();
    let graph = tr.graph;
    let m_prod = producer_count(&tr.cfg);
    // Producers split the CPU-stage thread budget (mirroring the replica
    // lanes' split), so `--producers` never oversubscribes `--threads`.
    let pool = WorkerPool::new(super::replica_thread_budget(tr.cfg.threads, m_prod));
    let seeds = tr.arsenal.checkout(graph, m_prod);
    let batches: Vec<usize> = (first..last).collect();
    let n_batches = batches.len();
    // One shared epoch permutation + resident-store index for all workers.
    let perm = epoch_perm(graph, &rng, epoch);
    let cache_store = tr.cache.as_ref().map(|h| h.store.clone());

    let wall0 = Instant::now();
    tr.eng.reset_counters(false);
    let mut m = EpochMetrics { batches: n_batches, ..Default::default() };
    let mut total_correct = 0.0f64;
    let mut total_seed = 0usize;

    // Integrity plane (DESIGN.md §11): the consumer runs every delivered
    // batch through the detect/recompute/rollback ladder against a standby
    // producer (recomputes re-derive from `(epoch_perm, seq)`, never from
    // the feed — the ring stays aligned), and per-batch results fold at
    // epoch end so replays overwrite instead of double-count. Off (the
    // default), this block costs one branch.
    let integrity = !opt.dev_resident && tr.integrity_active();
    if integrity {
        tr.begin_integrity_epoch();
    }
    let mut results = if integrity {
        let mut r = std::mem::take(&mut tr.batch_results);
        r.clear();
        r.resize(n_batches, (0.0, 0.0, 0));
        r
    } else {
        Vec::new()
    };

    let fault = tr.fault.clone();
    let mut result: Result<()> = Ok(());
    let mut leftover: Vec<BatchBufs> = Vec::new();
    let state_rx = std::thread::scope(|s| {
        let (mut feed, state_rx) = spawn_feed(
            s,
            graph,
            scfg,
            d,
            opt,
            pool,
            &rng,
            epoch,
            &batches,
            m_prod,
            seeds,
            &perm,
            cache_store.as_ref(),
            fault.as_ref(),
        );
        // Standby producer for re-deriving batches a dead worker never
        // delivered — built lazily from an arsenal seed on the first hole,
        // so the fault-free path allocates nothing for it. The integrity
        // ladder needs it for recomputes/replays at any batch, so an
        // integrity epoch arms it up front (its scratch checks back into
        // the arsenal at teardown and is reused every epoch after).
        let mut standby: Option<CpuProducer<'_>> = None;
        if integrity {
            let mut seed =
                tr.arsenal.checkout(graph, 1).pop().expect("arsenal always deals a seed");
            seed.scratch.install_epoch_perm(perm.clone(), &rng, epoch);
            standby = Some(CpuProducer::from_seed(
                graph,
                scfg,
                d,
                opt,
                pool,
                rng.clone(),
                cache_store.clone(),
                seed,
            ));
        }
        let mut snap_batch = first;
        for pos in 0..n_batches {
            let (prep, recovered) = match feed.recv_next() {
                Ok(FeedSlot::Batch(p)) => (p, false),
                Ok(FeedSlot::Lost) => {
                    if standby.is_none() {
                        let mut seed = tr
                            .arsenal
                            .checkout(graph, 1)
                            .pop()
                            .expect("arsenal always deals a seed");
                        seed.scratch.install_epoch_perm(perm.clone(), &rng, epoch);
                        standby = Some(CpuProducer::from_seed(
                            graph,
                            scfg,
                            d,
                            opt,
                            pool,
                            rng.clone(),
                            cache_store.clone(),
                            seed,
                        ));
                    }
                    m.producer_recoveries += 1;
                    let sb = standby.as_mut().expect("standby just installed");
                    (sb.produce(epoch, batches[pos]), true)
                }
                Err(e) => {
                    result = Err(e);
                    break;
                }
            };
            m.cpu_time += prep.cpu_time;
            m.cpu_by_stage += prep.cpu_by_stage;
            m.dropped_nodes += prep.dropped_nodes();
            m.dropped_edges += prep.dropped_edges();
            if integrity {
                // The ladder owns the fault cursor and the apply; first-
                // attempt buffers come back for feed routing, retry
                // buffers cycle through the standby internally.
                let b = batches[pos];
                let sb = standby.as_mut().expect("integrity epochs arm the standby");
                match tr.run_batch_recovering(
                    sb,
                    &mut results,
                    prep,
                    epoch,
                    b,
                    first,
                    snap_batch,
                    &mut m,
                ) {
                    Ok(bufs) => {
                        if recovered {
                            sb.reclaim(bufs);
                        } else {
                            feed.recycle(pos, bufs);
                        }
                    }
                    Err(e) => {
                        result = Err(e);
                        break;
                    }
                }
                if let Err(e) = tr.maybe_audit(
                    sb,
                    &mut results,
                    epoch,
                    first,
                    b,
                    last,
                    &mut snap_batch,
                    &mut m,
                ) {
                    result = Err(e);
                    break;
                }
                continue;
            }
            tr.eng.fault_cursor(epoch, batches[pos] as u64);
            match tr.compute_batch(prep) {
                Ok((loss, ncorrect, n_seed, bufs)) => {
                    if recovered {
                        // A re-derived batch's buffers go back to the
                        // standby, not the dead worker's channel, so the
                        // recovery loop is itself allocation-free after
                        // its first batch.
                        standby.as_mut().expect("standby exists").reclaim(bufs);
                    } else {
                        feed.recycle(pos, bufs);
                    }
                    m.loss += loss as f64;
                    total_correct += ncorrect as f64;
                    total_seed += n_seed;
                }
                Err(e) => {
                    result = Err(e);
                    break;
                }
            }
        }
        if let Some(sb) = standby.take() {
            tr.arsenal.checkin(sb.into_state());
        }
        // Dropping the feed's channels unblocks the producers; the scope
        // then joins them, which flushes every state message.
        leftover = feed.finish();
        state_rx
    });
    for state in state_rx.try_iter() {
        tr.arsenal.checkin(state);
    }
    tr.arsenal.checkin_bufs(leftover);
    if integrity {
        for &(l, c, s) in &results {
            m.loss += l;
            total_correct += c;
            total_seed += s;
        }
        tr.batch_results = results;
    }
    result?;
    tr.finish_metrics(&mut m, wall0, total_correct, total_seed);
    m.producer = tr.arsenal.stats;
    Ok(m)
}
