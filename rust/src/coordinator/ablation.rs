//! Ablation configurations (the paper's Fig. 9 study) — which of HiFuse's
//! optimizations are active.

/// Optimization switches. `OptConfig::baseline()` reproduces the PyG-style
/// execution; `OptConfig::hifuse()` enables everything the paper ships.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptConfig {
    /// Reorganization (Fig. 4b): type-major feature layout for collection.
    pub reorg: bool,
    /// Merging (Alg. 1): single merged aggregation launch per layer.
    pub merge: bool,
    /// Offloading (§4.3): edge-index selection on CPU instead of GPU.
    pub offload: bool,
    /// Parallelization: multi-threaded CPU selection (implies `offload`).
    pub parallel: bool,
    /// Asynchronous pipeline (Fig. 6): CPU stages overlap GPU compute.
    pub pipeline: bool,
    /// EXTENSION (beyond the paper): merge the projection stage too, via
    /// the stacked-einsum module (DESIGN.md §3).
    pub stacked_proj: bool,
    /// EXTENSION: device-resident step (DESIGN.md §7) — activations,
    /// gradients and parameters stay on-device between dispatches; only
    /// the batch metadata crosses H2D and the head scalars (or serve
    /// logits) cross D2H. Requires `merge` + `stacked_proj` (the resident
    /// modules only exist for the fully-merged plan; enforced by
    /// `StepExecutor::assert_dev_plan`).
    pub dev_resident: bool,
}

impl OptConfig {
    /// PyG-style baseline: everything on GPU, per-relation kernels,
    /// index-major features, sequential CPU/GPU.
    pub fn baseline() -> Self {
        OptConfig {
            reorg: false,
            merge: false,
            offload: false,
            parallel: false,
            pipeline: false,
            stacked_proj: false,
            dev_resident: false,
        }
    }

    /// Full HiFuse (paper configuration).
    pub fn hifuse() -> Self {
        OptConfig {
            reorg: true,
            merge: true,
            offload: true,
            parallel: true,
            pipeline: true,
            stacked_proj: false,
            dev_resident: false,
        }
    }

    /// Device-resident step on top of the fully-merged plan
    /// (hifuse + stacked + dev_resident — DESIGN.md §7).
    pub fn resident() -> Self {
        OptConfig { stacked_proj: true, dev_resident: true, ..Self::hifuse() }
    }

    /// The Fig. 9 ablation ladder, in the paper's order:
    /// base, R, R+M, R+O+P, R+M+O+P+Pipe(=HiFuse).
    pub fn ablation_ladder() -> Vec<(&'static str, OptConfig)> {
        let base = Self::baseline();
        vec![
            ("base", base),
            ("R", OptConfig { reorg: true, ..base }),
            ("R+M", OptConfig { reorg: true, merge: true, ..base }),
            ("R+O+P", OptConfig { reorg: true, offload: true, parallel: true, ..base }),
            ("HiFuse", Self::hifuse()),
        ]
    }

    /// Parse a config name (CLI). Accepts the ladder names plus
    /// "baseline"/"hifuse"/"hifuse+stacked".
    pub fn parse(name: &str) -> Option<OptConfig> {
        match name {
            "base" | "baseline" => Some(Self::baseline()),
            "hifuse" => Some(Self::hifuse()),
            "hifuse+stacked" => Some(OptConfig { stacked_proj: true, ..Self::hifuse() }),
            "resident" => Some(Self::resident()),
            _ => Self::ablation_ladder()
                .into_iter()
                .find(|(n, _)| *n == name)
                .map(|(_, c)| c),
        }
    }

    pub fn label(&self) -> String {
        if *self == Self::baseline() {
            return "base".into();
        }
        let mut parts = Vec::new();
        if self.reorg {
            parts.push("R");
        }
        if self.merge {
            parts.push("M");
        }
        if self.offload {
            parts.push("O");
        }
        if self.parallel {
            parts.push("P");
        }
        if self.pipeline {
            parts.push("Pipe");
        }
        if self.stacked_proj {
            parts.push("S");
        }
        if self.dev_resident {
            parts.push("Dev");
        }
        parts.join("+")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_monotone_in_optimizations() {
        let ladder = OptConfig::ablation_ladder();
        assert_eq!(ladder.len(), 5);
        assert_eq!(ladder[0].1, OptConfig::baseline());
        assert_eq!(ladder[4].1, OptConfig::hifuse());
        assert!(ladder[1].1.reorg && !ladder[1].1.merge);
        assert!(ladder[2].1.merge && !ladder[2].1.offload);
        assert!(ladder[3].1.offload && ladder[3].1.parallel && !ladder[3].1.merge);
    }

    #[test]
    fn parse_roundtrip() {
        for (name, cfg) in OptConfig::ablation_ladder() {
            assert_eq!(OptConfig::parse(name), Some(cfg), "{name}");
        }
        assert!(OptConfig::parse("hifuse+stacked").unwrap().stacked_proj);
        assert!(OptConfig::parse("nope").is_none());
    }

    #[test]
    fn resident_implies_fully_merged_plan() {
        let r = OptConfig::parse("resident").unwrap();
        assert_eq!(r, OptConfig::resident());
        assert!(r.dev_resident && r.merge && r.stacked_proj);
    }

    #[test]
    fn labels_are_informative() {
        assert_eq!(OptConfig::baseline().label(), "base");
        assert_eq!(OptConfig::hifuse().label(), "R+M+O+P+Pipe");
        assert_eq!(OptConfig::resident().label(), "R+M+O+P+Pipe+S+Dev");
    }
}
