//! Request coalescing: fold an arrival stream into the static-shape
//! mini-batches the training path already runs (DESIGN.md §8).
//!
//! The coalescer is a single pass over the trace in arrival order, and its
//! decisions depend on **nothing but the trace** and two scalars
//! (`batch_size`, the window). No queue depth, lane count, or wall-clock
//! enters — which is exactly why replaying a trace reproduces the same
//! batches under any `--replicas`/`--producers`/`--threads`/pipeline
//! setting. A batch closes when (a) the next request would overflow the
//! seed capacity, (b) the batch fills exactly, or (c) the next arrival
//! falls outside the batch's coalescing window.
//!
//! The coalescer is schedule-agnostic: a closed-loop trace
//! ([`super::trace::generate_closed_loop`]) folds exactly like an
//! open-loop one — arrival ticks are arrival ticks, wherever they came
//! from. `close_tick` additionally anchors the serve plane's churn
//! boundaries: hot-refresh ticks map to the first admitted batch closing
//! at or after them, and the admission model's queue-depth accounting
//! integrates from each batch's close to its virtual departure
//! (DESIGN.md §10).

use anyhow::{ensure, Result};

use super::trace::Trace;

/// One request's span inside a coalesced batch: `len` seeds starting at
/// `offset` in [`CoalescedBatch::seeds`], belonging to trace request `req`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchMember {
    pub req: usize,
    pub offset: usize,
    pub len: usize,
}

/// A closed batch: the concatenated seed sets of its member requests plus
/// the virtual-time bracket the latency model needs.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CoalescedBatch {
    /// Member seed sets back to back (duplicates across members allowed —
    /// the sampler dedups into slots; the demux maps each position back).
    pub seeds: Vec<u32>,
    pub members: Vec<BatchMember>,
    /// Arrival tick of the first member — the tick the window opens.
    pub open_tick: u64,
    /// The tick the batch stopped accepting requests and became runnable.
    pub close_tick: u64,
}

/// Fold `trace` into batches of at most `batch_size` seeds, each batch
/// accepting arrivals for at most `window` ticks past its first member.
///
/// Close-tick semantics (all three are pure functions of the stream):
/// * window timeout → `open_tick + window` (the batch timer fires whether
///   or not anything else arrives);
/// * capacity overflow → the overflowing request's arrival tick (that is
///   when the server learns the batch cannot grow);
/// * exact fill → the filling request's arrival tick;
/// * end of stream → `open_tick + window` (an open-loop server cannot see
///   that no more requests are coming).
pub fn coalesce(trace: &Trace, batch_size: usize, window: u64) -> Result<Vec<CoalescedBatch>> {
    assert!(batch_size >= 1);
    let mut out = Vec::new();
    let mut cur: Option<CoalescedBatch> = None;
    let mut last_tick = 0u64;
    for (ri, r) in trace.requests.iter().enumerate() {
        ensure!(!r.seeds.is_empty(), "request {ri} has no seeds");
        ensure!(
            r.seeds.len() <= batch_size,
            "request {ri} carries {} seeds but batches hold at most {batch_size}",
            r.seeds.len()
        );
        ensure!(
            r.arrival_tick >= last_tick,
            "request {ri} arrives out of order (tick {} after {last_tick})",
            r.arrival_tick
        );
        last_tick = r.arrival_tick;
        if let Some(b) = &cur {
            let timeout = r.arrival_tick > b.open_tick + window;
            let overflow = b.seeds.len() + r.seeds.len() > batch_size;
            if timeout || overflow {
                let mut b = cur.take().expect("checked above");
                b.close_tick = if timeout { b.open_tick + window } else { r.arrival_tick };
                out.push(b);
            }
        }
        let b = cur.get_or_insert_with(|| CoalescedBatch {
            open_tick: r.arrival_tick,
            ..CoalescedBatch::default()
        });
        b.members.push(BatchMember { req: ri, offset: b.seeds.len(), len: r.seeds.len() });
        b.seeds.extend_from_slice(&r.seeds);
        if b.seeds.len() == batch_size {
            let mut b = cur.take().expect("just inserted");
            b.close_tick = r.arrival_tick;
            out.push(b);
        }
    }
    if let Some(mut b) = cur.take() {
        b.close_tick = b.open_tick + window;
        out.push(b);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::trace::{Request, Trace};
    use super::*;

    fn req(id: u32, tick: u64, seeds: &[u32]) -> Request {
        Request { id, arrival_tick: tick, seeds: seeds.to_vec() }
    }

    #[test]
    fn window_and_capacity_both_close_batches() {
        let t = Trace {
            requests: vec![
                req(0, 10, &[1, 2]),
                req(1, 15, &[3]),     // fits: 3 seeds, inside window
                req(2, 500, &[4]),    // outside 10+100 -> new batch
                req(3, 505, &[5, 6, 7]), // 1+3 = 4 = capacity -> exact fill
                req(4, 510, &[8, 9]), // tail batch, closed by end of stream
            ],
        };
        let bs = coalesce(&t, 4, 100).unwrap();
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[0].seeds, vec![1, 2, 3]);
        assert_eq!(bs[0].open_tick, 10);
        assert_eq!(bs[0].close_tick, 110, "closed by the window timer");
        assert_eq!(bs[1].seeds, vec![4, 5, 6, 7]);
        assert_eq!(bs[1].close_tick, 505, "closed by exact fill");
        assert_eq!(bs[2].seeds, vec![8, 9]);
        assert_eq!(bs[2].close_tick, 610, "tail closes a full window after opening");
        // Membership bookkeeping: every request appears exactly once and
        // its (offset, len) span reproduces its seed set.
        let mut seen = vec![0u32; t.requests.len()];
        for b in &bs {
            for m in &b.members {
                seen[m.req] += 1;
                assert_eq!(
                    &b.seeds[m.offset..m.offset + m.len],
                    &t.requests[m.req].seeds[..]
                );
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn overflow_closes_at_the_overflowing_arrival() {
        let t = Trace {
            requests: vec![req(0, 10, &[1, 2, 3]), req(1, 20, &[4, 5])],
        };
        let bs = coalesce(&t, 4, 1_000).unwrap();
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[0].close_tick, 20, "closed the moment the overflow arrived");
        assert_eq!(bs[1].open_tick, 20);
    }

    #[test]
    fn rejects_oversized_and_disordered_requests() {
        let t = Trace { requests: vec![req(0, 0, &[1, 2, 3, 4, 5])] };
        assert!(coalesce(&t, 4, 100).is_err());
        let t = Trace { requests: vec![req(0, 50, &[1]), req(1, 10, &[2])] };
        assert!(coalesce(&t, 4, 100).is_err());
    }
}
