//! Online inference serving (DESIGN.md §8): an open-loop request
//! front-end over the training stack's batch machinery.
//!
//! `repro serve` drives four stages, each reusing a training-path
//! subsystem rather than duplicating it:
//!
//! 1. **Arrival stream** ([`trace`]) — a seeded schedule of per-request
//!    seed-vertex sets on an integer virtual clock (1 tick = 1 µs),
//!    recordable to and replayable from a small binary codec.
//! 2. **Coalescer** ([`coalesce`]) — folds pending requests into the same
//!    static-shape mini-batches the trainer runs, purely from the stream,
//!    so batch membership is independent of all parallelism knobs.
//! 3. **Forward drive** (`ReplicaGroup::serve_forward`) — round-robins
//!    the coalesced batches over the replica lanes, sampling through
//!    `NeighborSampler::sample_request_into` and executing the
//!    `StepExecutor::forward_step` split of `grad_step`; producer
//!    arsenals, `BatchBufs` recycling, and the `--cache-frac` resident
//!    cache all carry over, so the steady state allocates nothing.
//! 4. **Demux + metrology** ([`serve`]) — maps each batch's slot rows
//!    back to per-request predictions and folds per-request latencies
//!    into a fixed-footprint [`LatencyHistogram`].
//!
//! Determinism contract: predictions and coalescing are bitwise functions
//! of `(params, trace, batch_size, window)` — pinned across
//! `--replicas`/`--producers`/`--threads`/pipeline by
//! `tests/serve_parity.rs`. Latency *values* are performance metrology
//! (each batch's measured service time replayed onto the virtual clock)
//! and are not part of the bitwise contract; the histogram's shape
//! invariants are.

pub mod coalesce;
pub mod histogram;
pub mod trace;

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

pub use coalesce::{coalesce, BatchMember, CoalescedBatch};
pub use histogram::LatencyHistogram;
pub use trace::{Request, Trace};

use crate::coordinator::ReplicaGroup;
use crate::runtime::ExecBackend;
use crate::util::HostTensor;

/// Virtual service cost of one coalesced batch inside the admission
/// model, in ticks. Admission must be a pure function of the trace — the
/// *measured* per-batch service times feeding the latency histogram are
/// wall-clock and would make the shed set nondeterministic — so
/// [`serve_bounded`] queues batches on a single virtual server at this
/// constant rate and sheds only against that model (DESIGN.md §9).
pub const VIRT_SERVICE_PER_BATCH: u64 = 50;

/// Everything one serve run produces.
pub struct ServeOutcome {
    /// Per-request `[seeds, C]` logit rows, in trace order — bitwise
    /// identical for a given (params, trace, batch_size, window) whatever
    /// the parallelism. A request shed by admission control gets a `[0, C]`
    /// placeholder (no rows were computed for it).
    pub predictions: Vec<HostTensor>,
    /// Per-request latency in virtual ticks (completion − arrival); 0 for
    /// shed requests (they never complete).
    pub latencies: Vec<u64>,
    /// The coalescing decisions (batch membership is part of the replay
    /// determinism contract).
    pub batches: Vec<CoalescedBatch>,
    pub hist: LatencyHistogram,
    /// Wall time of the forward drive (metrology only).
    pub wall: Duration,
    /// Virtual span: first arrival tick → last completion tick.
    pub span_ticks: u64,
    /// Requests shed by admission control ([`serve_bounded`]), ascending
    /// trace order. Always empty without a queue bound.
    pub shed: Vec<u32>,
    /// Peak admitted-batch backlog the admission model observed (0 without
    /// a queue bound).
    pub max_backlog: usize,
}

impl ServeOutcome {
    /// Sustained throughput on the virtual clock, requests per second.
    pub fn virtual_throughput(&self) -> f64 {
        if self.span_ticks == 0 {
            return 0.0;
        }
        self.predictions.len() as f64 * 1e6 / self.span_ticks as f64
    }
}

/// Run one serve pass: coalesce `trace`, drive the batches forward-only
/// across the group's lanes, then demultiplex predictions and account
/// per-request latency on the virtual clock.
///
/// The latency model replays each batch's measured service time onto
/// virtual time: batch `i` runs on lane `i % replicas` (mirroring
/// `serve_forward`'s schedule), starting at
/// `max(close_tick, lane_free)` and completing `service` ticks later;
/// a request's latency is its batch's completion minus its own arrival.
/// Queueing delay from lane contention is therefore visible in the
/// histogram, while the predictions stay schedule-independent.
pub fn serve<B>(
    group: &mut ReplicaGroup<B>,
    trace: &Trace,
    batch_size: usize,
    window: u64,
) -> Result<ServeOutcome>
where
    B: ExecBackend + Send,
    B::Dev: Sync,
{
    serve_bounded(group, trace, batch_size, window, None)
}

/// [`serve`] with admission control: `max_queue` bounds the virtual batch
/// queue. Every coalesced batch is offered to a single-server admission
/// model ([`VIRT_SERVICE_PER_BATCH`] ticks per batch); a batch arriving
/// while `max_queue` admitted batches are still pending is **shed whole** —
/// its requests get `[0, C]` placeholder predictions, zero latency, and a
/// shed mark in the histogram instead of a sample. The shed set is a pure
/// function of `(trace, batch_size, window, max_queue)` — independent of
/// replicas, producers, threads, and measured service times — so bounded
/// runs replay bitwise too. `None` is exactly [`serve`].
pub fn serve_bounded<B>(
    group: &mut ReplicaGroup<B>,
    trace: &Trace,
    batch_size: usize,
    window: u64,
    max_queue: Option<usize>,
) -> Result<ServeOutcome>
where
    B: ExecBackend + Send,
    B::Dev: Sync,
{
    ensure!(!trace.requests.is_empty(), "serving an empty trace");
    let batches = coalesce(trace, batch_size, window)?;

    // Admission pass: walk the batches in close order against the virtual
    // single-server queue, deciding shed/admit before any compute runs.
    let mut admitted = vec![true; batches.len()];
    let mut shed: Vec<u32> = Vec::new();
    let mut max_backlog = 0usize;
    if let Some(q) = max_queue {
        let mut pending: VecDeque<u64> = VecDeque::new();
        let mut virt_free = 0u64;
        for (bi, b) in batches.iter().enumerate() {
            while pending.front().is_some_and(|&done| done <= b.close_tick) {
                pending.pop_front();
            }
            if pending.len() >= q {
                admitted[bi] = false;
                for m in &b.members {
                    shed.push(m.req as u32);
                }
                continue;
            }
            let done = b.close_tick.max(virt_free) + VIRT_SERVICE_PER_BATCH;
            virt_free = done;
            pending.push_back(done);
            max_backlog = max_backlog.max(pending.len());
        }
        shed.sort_unstable();
    }

    let seed_sets: Vec<Vec<u32>> = batches
        .iter()
        .zip(&admitted)
        .filter(|&(_, &a)| a)
        .map(|(b, _)| b.seeds.clone())
        .collect();
    let t0 = Instant::now();
    let stepped = group.serve_forward(&seed_sets)?;
    let wall = t0.elapsed();

    let c_dim = group.dims().c;
    let n_lanes = group.replicas().max(1);
    let mut lane_free = vec![0u64; n_lanes];
    let mut predictions: Vec<Option<HostTensor>> =
        (0..trace.requests.len()).map(|_| None).collect();
    let mut latencies = vec![0u64; trace.requests.len()];
    let mut hist = LatencyHistogram::default();
    let mut last_done = 0u64;
    // Per-batch slot map, rebuilt by the same first-seen scan the
    // sampler's assign_slot performs: position i of batch.seeds lives in
    // logits row slot_idx[i].
    let mut slots: Vec<u32> = Vec::with_capacity(batch_size);
    let mut slot_idx: Vec<usize> = Vec::with_capacity(batch_size);
    // `si` indexes the admitted (served) batches — the order serve_forward
    // saw them and the index its round-robin lane schedule used.
    let mut si = 0usize;
    for (b, adm) in batches.iter().zip(&admitted) {
        if !*adm {
            for m in &b.members {
                ensure!(
                    predictions[m.req].is_none(),
                    "request {} demuxed twice",
                    m.req
                );
                predictions[m.req] = Some(HostTensor::f32(Vec::new(), &[0, c_dim]));
                hist.record_shed();
            }
            continue;
        }
        let (logits, dur) = &stepped[si];
        let lane = si % n_lanes;
        si += 1;
        let shape = logits.shape();
        ensure!(shape.len() == 2, "forward logits must be [NS, C], got {shape:?}");
        let c = shape[1];
        let rows = logits.as_f32()?;
        slots.clear();
        slot_idx.clear();
        for &s in &b.seeds {
            match slots.iter().position(|&x| x == s) {
                Some(k) => slot_idx.push(k),
                None => {
                    slot_idx.push(slots.len());
                    slots.push(s);
                }
            }
        }
        let service = (dur.as_micros() as u64).max(1);
        let start = b.close_tick.max(lane_free[lane]);
        let done = start + service;
        lane_free[lane] = done;
        last_done = last_done.max(done);
        for m in &b.members {
            let mut data = Vec::with_capacity(m.len * c);
            for k in 0..m.len {
                let slot = slot_idx[m.offset + k];
                data.extend_from_slice(&rows[slot * c..(slot + 1) * c]);
            }
            ensure!(
                predictions[m.req].is_none(),
                "request {} demuxed twice",
                m.req
            );
            predictions[m.req] = Some(HostTensor::f32(data, &[m.len, c]));
            let lat = done - trace.requests[m.req].arrival_tick;
            latencies[m.req] = lat;
            hist.record(lat);
        }
    }
    let first_arrival = trace.requests[0].arrival_tick;
    let predictions = predictions
        .into_iter()
        .enumerate()
        .map(|(i, p)| p.ok_or_else(|| anyhow::anyhow!("request {i} never coalesced")))
        .collect::<Result<Vec<_>>>()?;
    Ok(ServeOutcome {
        predictions,
        latencies,
        batches,
        hist,
        wall,
        span_ticks: last_done.saturating_sub(first_arrival),
        shed,
        max_backlog,
    })
}
