//! Online inference serving (DESIGN.md §8, §10): open- and closed-loop
//! request front-ends over the training stack's batch machinery.
//!
//! `repro serve` drives four stages, each reusing a training-path
//! subsystem rather than duplicating it:
//!
//! 1. **Arrival stream** ([`trace`]) — a seeded schedule of per-request
//!    seed-vertex sets on an integer virtual clock (1 tick = 1 µs),
//!    recordable to and replayable from a small binary codec. Open-loop
//!    (Poisson offered load) or closed-loop (`--closed-loop N` virtual
//!    clients, [`trace::generate_closed_loop`]).
//! 2. **Coalescer** ([`coalesce`]) — folds pending requests into the same
//!    static-shape mini-batches the trainer runs, purely from the stream,
//!    so batch membership is independent of all parallelism knobs.
//! 3. **Forward drive** (`ReplicaGroup::serve_forward_churn`) —
//!    round-robins the coalesced batches over the replica lanes, sampling
//!    through `NeighborSampler::sample_request_into` and executing the
//!    `StepExecutor::forward_step` split of `grad_step`; producer
//!    arsenals, `BatchBufs` recycling, and the `--cache-frac` resident
//!    cache all carry over, so the steady state allocates nothing. Under
//!    churn the drive additionally hot-swaps parameters at refresh
//!    boundaries and quarantines/re-admits lanes (DESIGN.md §10).
//! 4. **Demux + metrology** ([`serve_churn`]) — maps each batch's slot
//!    rows back to typed per-request outcomes ([`RequestOutcome`]) and
//!    folds per-request latencies into a fixed-footprint
//!    [`LatencyHistogram`].
//!
//! Determinism contract: predictions and coalescing are bitwise functions
//! of `(params timeline, trace, batch_size, window, max_queue)` — pinned
//! across `--replicas`/`--producers`/`--threads`/pipeline *and across
//! churn* (refresh, quarantine, closed-loop) by `tests/serve_parity.rs`
//! and `tests/churn_matrix.rs`. Latency *values* are performance
//! metrology (each batch's measured service time replayed onto the
//! virtual clock) and are not part of the bitwise contract; the
//! histogram's shape invariants and the admission model's queue-depth
//! accounting are.

pub mod coalesce;
pub mod histogram;
pub mod trace;

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

pub use coalesce::{coalesce, BatchMember, CoalescedBatch};
pub use histogram::LatencyHistogram;
pub use trace::{Request, Trace};

use crate::coordinator::{ChurnStats, RefreshEvent, ReplicaGroup, DEFAULT_PROBATION};
use crate::models::{checkpoint, Params};
use crate::runtime::ExecBackend;
use crate::util::HostTensor;

/// Virtual service cost of one coalesced batch inside the admission
/// model, in ticks. Admission must be a pure function of the trace — the
/// *measured* per-batch service times feeding the latency histogram are
/// wall-clock and would make the shed set nondeterministic — so
/// [`serve_churn`] queues batches on a single virtual server at this
/// constant rate and sheds only against that model (DESIGN.md §9).
pub const VIRT_SERVICE_PER_BATCH: u64 = 50;

/// What one request got out of a serve run: its logit rows, or a typed
/// shed marker. Replaces the old ambiguous `[0, C]` placeholder — a shed
/// is now distinguishable from any served prediction by construction.
#[derive(Clone, Debug, PartialEq)]
pub enum RequestOutcome {
    /// The request's `[seeds, C]` logit rows, bitwise-deterministic in
    /// (params timeline, trace, batch_size, window).
    Served(HostTensor),
    /// Dropped whole by admission control; no rows were ever computed.
    Shed,
}

impl RequestOutcome {
    /// The served logits, if any.
    pub fn served(&self) -> Option<&HostTensor> {
        match self {
            RequestOutcome::Served(t) => Some(t),
            RequestOutcome::Shed => None,
        }
    }

    /// `true` iff admission control shed this request.
    pub fn is_shed(&self) -> bool {
        matches!(self, RequestOutcome::Shed)
    }
}

/// Knobs for one [`serve_churn`] pass beyond the coalescing geometry.
#[derive(Clone, Debug, Default)]
pub struct ServeOptions {
    /// Admission bound on the virtual batch queue; `None` = never shed.
    pub max_queue: Option<usize>,
    /// Hot model refreshes: `(tick, checkpoint path)` — at the first
    /// admitted batch closing at or after `tick`, every lane switches to
    /// the checkpoint's parameters. A failed load (bad CRC, truncation,
    /// shape mismatch) is counted in [`ChurnStats::failed_refreshes`] and
    /// the old parameters keep serving — never fatal.
    pub refreshes: Vec<(u64, PathBuf)>,
    /// Shadow batches a quarantined lane must complete before re-admission
    /// (`0` is clamped to `1`); see `ReplicaGroup::serve_forward_churn`.
    pub probation: usize,
}

impl ServeOptions {
    /// Quiescent defaults: no bound, no refreshes, default probation.
    pub fn quiescent() -> Self {
        ServeOptions { max_queue: None, refreshes: Vec::new(), probation: DEFAULT_PROBATION }
    }
}

/// Everything one serve run produces.
pub struct ServeOutcome {
    /// Per-request outcomes in trace order — bitwise identical for a given
    /// (params timeline, trace, batch_size, window, max_queue) whatever
    /// the parallelism or churn.
    pub predictions: Vec<RequestOutcome>,
    /// Per-request latency in virtual ticks (completion − arrival); 0 for
    /// shed requests (they never complete).
    pub latencies: Vec<u64>,
    /// The coalescing decisions (batch membership is part of the replay
    /// determinism contract).
    pub batches: Vec<CoalescedBatch>,
    pub hist: LatencyHistogram,
    /// Wall time of the forward drive (metrology only).
    pub wall: Duration,
    /// Virtual span: first arrival tick → last completion tick.
    pub span_ticks: u64,
    /// Requests shed by admission control, ascending trace order. Always
    /// empty without a queue bound.
    pub shed: Vec<u32>,
    /// Peak admitted-batch backlog the virtual admission model observed
    /// (queued + in service). Computed for bounded *and* unbounded runs.
    pub max_backlog: usize,
    /// Time-weighted mean admitted-batch queue depth over the virtual
    /// busy span (Little's-law `L`): Σ(departure − close) / span. 0.0 for
    /// an empty span.
    pub mean_queue_depth: f64,
    /// Churn accounting: quarantines, re-admissions, shadow batches,
    /// re-dispatches, refreshes, failed refreshes, and guarded integrity
    /// violations/recomputes (DESIGN.md §11). All-zero for a quiescent
    /// run.
    pub churn: ChurnStats,
    /// Lanes flagged suspect by the integrity guard (2+ violations this
    /// pass); the group pre-quarantines them on its next churn drive.
    pub suspect_lanes: Vec<usize>,
}

impl ServeOutcome {
    /// Sustained throughput on the virtual clock, requests per second.
    pub fn virtual_throughput(&self) -> f64 {
        if self.span_ticks == 0 {
            return 0.0;
        }
        self.predictions.len() as f64 * 1e6 / self.span_ticks as f64
    }

    /// Order-sensitive FNV-1a digest over every request outcome — shed
    /// markers and the bit patterns of served logit rows — so two runs
    /// can be compared for bitwise prediction parity from their report
    /// lines alone (the CI churn smoke compares churn vs quiescent).
    pub fn prediction_digest(&self) -> Result<u64> {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for p in &self.predictions {
            match p {
                RequestOutcome::Shed => {
                    h = (h ^ 0x5EED_0DD1).wrapping_mul(PRIME);
                }
                RequestOutcome::Served(t) => {
                    h = (h ^ t.shape()[0] as u64).wrapping_mul(PRIME);
                    for &v in t.as_f32()? {
                        h = (h ^ v.to_bits() as u64).wrapping_mul(PRIME);
                    }
                }
            }
        }
        Ok(h)
    }
}

/// Run one quiescent serve pass: coalesce `trace`, drive the batches
/// forward-only across the group's lanes, then demultiplex predictions
/// and account per-request latency on the virtual clock. Equivalent to
/// [`serve_churn`] with [`ServeOptions::quiescent`].
pub fn serve<B>(
    group: &mut ReplicaGroup<B>,
    trace: &Trace,
    batch_size: usize,
    window: u64,
) -> Result<ServeOutcome>
where
    B: ExecBackend + Send,
    B::Dev: Sync,
{
    serve_bounded(group, trace, batch_size, window, None)
}

/// [`serve`] with admission control: `max_queue` bounds the virtual batch
/// queue. Every coalesced batch is offered to a single-server admission
/// model ([`VIRT_SERVICE_PER_BATCH`] ticks per batch); a batch arriving
/// while `max_queue` admitted batches are still pending is **shed whole** —
/// its requests get [`RequestOutcome::Shed`], zero latency, and a shed
/// mark in the histogram instead of a sample. The shed set is a pure
/// function of `(trace, batch_size, window, max_queue)` — independent of
/// replicas, producers, threads, and measured service times — so bounded
/// runs replay bitwise too. `None` is exactly [`serve`].
pub fn serve_bounded<B>(
    group: &mut ReplicaGroup<B>,
    trace: &Trace,
    batch_size: usize,
    window: u64,
    max_queue: Option<usize>,
) -> Result<ServeOutcome>
where
    B: ExecBackend + Send,
    B::Dev: Sync,
{
    let opts = ServeOptions { max_queue, ..ServeOptions::quiescent() };
    serve_churn(group, trace, batch_size, window, &opts)
}

/// The full churn-tolerant serve pass (DESIGN.md §10): [`serve_bounded`]
/// plus hot model refresh and lane quarantine/re-admission.
///
/// **Refresh.** Each `(tick, path)` in [`ServeOptions::refreshes`] is
/// loaded through the checkpoint codec (CRC-verified, v1/v2) *before* the
/// drive starts, mapped to the first admitted batch closing at or after
/// `tick`, and applied at that global batch boundary by every lane. A
/// load failure or profile shape mismatch increments
/// [`ChurnStats::failed_refreshes`] and the event is dropped — the old
/// parameters keep serving. The latest successful refresh is installed
/// into every lane (`ReplicaGroup::refresh_lane`) so it persists past
/// this call.
///
/// **Quarantine.** `lane!` entries in the group's attached fault plan
/// quarantine lanes mid-trace; their batches re-dispatch in global batch
/// order and predictions stay bitwise-quiescent (see
/// `ReplicaGroup::serve_forward_churn`).
///
/// **Latency model.** Batch `si` runs on its churn-resolved primary lane,
/// starting at `max(close_tick, lane_free)` and completing `service`
/// measured ticks later; a request's latency is its batch's completion
/// minus its own arrival. Queueing delay from lane contention is
/// therefore visible in the histogram, while the predictions stay
/// schedule-independent.
pub fn serve_churn<B>(
    group: &mut ReplicaGroup<B>,
    trace: &Trace,
    batch_size: usize,
    window: u64,
    opts: &ServeOptions,
) -> Result<ServeOutcome>
where
    B: ExecBackend + Send,
    B::Dev: Sync,
{
    ensure!(!trace.requests.is_empty(), "serving an empty trace");
    let batches = coalesce(trace, batch_size, window)?;

    // Admission pass: walk the batches in close order against the virtual
    // single-server queue, deciding shed/admit before any compute runs.
    // The pass always runs — backlog depth and the time-weighted mean
    // queue depth are reported for unbounded runs too; only shedding is
    // gated on the bound.
    let q = opts.max_queue.unwrap_or(usize::MAX);
    let mut admitted = vec![true; batches.len()];
    let mut shed: Vec<u32> = Vec::new();
    let mut max_backlog = 0usize;
    let mut queue_area = 0u64; // Σ (departure − close) over admitted batches
    let mut first_close: Option<u64> = None;
    let mut last_virt_done = 0u64;
    {
        let mut pending: VecDeque<u64> = VecDeque::new();
        let mut virt_free = 0u64;
        for (bi, b) in batches.iter().enumerate() {
            while pending.front().is_some_and(|&done| done <= b.close_tick) {
                pending.pop_front();
            }
            if pending.len() >= q {
                admitted[bi] = false;
                for m in &b.members {
                    shed.push(m.req as u32);
                }
                continue;
            }
            let done = b.close_tick.max(virt_free) + VIRT_SERVICE_PER_BATCH;
            virt_free = done;
            pending.push_back(done);
            max_backlog = max_backlog.max(pending.len());
            queue_area += done - b.close_tick;
            first_close.get_or_insert(b.close_tick);
            last_virt_done = done;
        }
        shed.sort_unstable();
    }
    let mean_queue_depth = match first_close {
        Some(fc) if last_virt_done > fc => queue_area as f64 / (last_virt_done - fc) as f64,
        _ => 0.0,
    };

    // Admitted-batch close ticks, in drive order: the refresh tick →
    // batch-boundary mapping and the seed sets both index this subset.
    let admitted_closes: Vec<u64> = batches
        .iter()
        .zip(&admitted)
        .filter(|&(_, &a)| a)
        .map(|(b, _)| b.close_tick)
        .collect();
    let seed_sets: Vec<Vec<u32>> = batches
        .iter()
        .zip(&admitted)
        .filter(|&(_, &a)| a)
        .map(|(b, _)| b.seeds.clone())
        .collect();

    // Load every refresh checkpoint up front (never mid-drive — a slow or
    // failing disk must not perturb lane timing), demoting failures to a
    // counter. Events map to admitted-batch boundaries so the applied
    // parameter timeline is a pure function of the trace.
    let d = group.dims();
    let mut events: Vec<RefreshEvent> = Vec::new();
    let mut latest: Option<(u64, Arc<Params>)> = None;
    let mut refreshes_ok = 0u64;
    let mut refreshes_failed = 0u64;
    for (tick, path) in &opts.refreshes {
        let loaded = checkpoint::load(path);
        match loaded {
            Ok(p) if p.rpad == d.rpad && p.f == d.f && p.h == d.h && p.c == d.c => {
                let at_batch = admitted_closes
                    .iter()
                    .position(|&c| c >= *tick)
                    .unwrap_or(admitted_closes.len());
                let params = Arc::new(p);
                if latest.as_ref().map_or(true, |(t, _)| *tick >= *t) {
                    latest = Some((*tick, params.clone()));
                }
                events.push(RefreshEvent { at_batch, params });
                refreshes_ok += 1;
            }
            _ => refreshes_failed += 1,
        }
    }

    let t0 = Instant::now();
    let drive = group.serve_forward_churn(&seed_sets, &events, opts.probation)?;
    let wall = t0.elapsed();
    let mut churn = drive.stats;
    churn.refreshes = refreshes_ok;
    churn.failed_refreshes = refreshes_failed;

    // Sticky refresh: the latest applied model keeps serving after this
    // pass (subsequent drives see it as every lane's base set).
    if let Some((_, p)) = latest {
        for l in 0..group.replicas() {
            group.refresh_lane(l, &p)?;
        }
    }

    let n_lanes = group.replicas().max(1);
    let mut lane_free = vec![0u64; n_lanes];
    let mut predictions: Vec<Option<RequestOutcome>> =
        (0..trace.requests.len()).map(|_| None).collect();
    let mut latencies = vec![0u64; trace.requests.len()];
    let mut hist = LatencyHistogram::default();
    let mut last_done = 0u64;
    // Per-batch slot map, rebuilt by the same first-seen scan the
    // sampler's assign_slot performs: position i of batch.seeds lives in
    // logits row slot_idx[i].
    let mut slots: Vec<u32> = Vec::with_capacity(batch_size);
    let mut slot_idx: Vec<usize> = Vec::with_capacity(batch_size);
    // `si` indexes the admitted (served) batches — the order the drive
    // saw them and the index its churn-resolved lane schedule used.
    let mut si = 0usize;
    for (b, adm) in batches.iter().zip(&admitted) {
        if !*adm {
            for m in &b.members {
                ensure!(
                    predictions[m.req].is_none(),
                    "request {} demuxed twice",
                    m.req
                );
                predictions[m.req] = Some(RequestOutcome::Shed);
                hist.record_shed();
            }
            continue;
        }
        let (logits, dur) = &drive.stepped[si];
        let lane = drive.primary_lane[si];
        si += 1;
        let shape = logits.shape();
        ensure!(shape.len() == 2, "forward logits must be [NS, C], got {shape:?}");
        let c = shape[1];
        let rows = logits.as_f32()?;
        slots.clear();
        slot_idx.clear();
        for &s in &b.seeds {
            match slots.iter().position(|&x| x == s) {
                Some(k) => slot_idx.push(k),
                None => {
                    slot_idx.push(slots.len());
                    slots.push(s);
                }
            }
        }
        let service = (dur.as_micros() as u64).max(1);
        let start = b.close_tick.max(lane_free[lane]);
        let done = start + service;
        lane_free[lane] = done;
        last_done = last_done.max(done);
        for m in &b.members {
            let mut data = Vec::with_capacity(m.len * c);
            for k in 0..m.len {
                let slot = slot_idx[m.offset + k];
                data.extend_from_slice(&rows[slot * c..(slot + 1) * c]);
            }
            ensure!(
                predictions[m.req].is_none(),
                "request {} demuxed twice",
                m.req
            );
            predictions[m.req] = Some(RequestOutcome::Served(HostTensor::f32(data, &[m.len, c])));
            let lat = done - trace.requests[m.req].arrival_tick;
            latencies[m.req] = lat;
            hist.record(lat);
        }
    }
    let first_arrival = trace.requests[0].arrival_tick;
    let predictions = predictions
        .into_iter()
        .enumerate()
        .map(|(i, p)| p.ok_or_else(|| anyhow::anyhow!("request {i} never coalesced")))
        .collect::<Result<Vec<_>>>()?;
    Ok(ServeOutcome {
        predictions,
        latencies,
        batches,
        hist,
        wall,
        span_ticks: last_done.saturating_sub(first_arrival),
        shed,
        max_backlog,
        mean_queue_depth,
        churn,
        suspect_lanes: drive.suspect_lanes,
    })
}
