//! Arrival traces: open- and closed-loop request schedules in **virtual
//! time**, plus the record/replay codec (DESIGN.md §8, §10).
//!
//! A trace is the serving subsystem's unit of determinism: request ids,
//! per-request seed-vertex sets, and integer *arrival ticks* (1 tick =
//! 1 µs of virtual time). Generation is a pure function of
//! `(seed, rate, n_requests)` — no wall clock anywhere — so a generated
//! schedule, a recorded file, and a replayed file all coalesce
//! identically on any machine at any parallelism (`tests/serve_parity.rs`).
//!
//! [`generate`] produces the open-loop form (arrivals ignore completions);
//! [`generate_closed_loop`] produces the closed-loop form (`--closed-loop
//! N`): `N` virtual clients that each re-issue only after their previous
//! response completes under the virtual service model, so offered load is
//! a pure function of `(seed, N, service times)` rather than a free-running
//! rate. Both forms emit plain [`Trace`]s — coalescing, admission control,
//! and the latency histogram downstream are loop-shape-agnostic.
//!
//! The on-disk format follows `models/checkpoint.rs`: a magic tag, a
//! version word, then length-prefixed little-endian payloads — small,
//! self-describing, and serde-free.

use std::fmt;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::graph::HeteroGraph;
use crate::util::Rng;

/// Fork stream of the arrival-schedule generator: disjoint from every
/// training stream (`sampler::EPOCH_PERM_STREAM`, the per-batch forks) so
/// serving traffic never perturbs a training trajectory run from the same
/// root seed.
const TRACE_STREAM: u64 = 0xA221_7A1E;

/// Fork stream of the closed-loop generator — disjoint from
/// [`TRACE_STREAM`] so open- and closed-loop schedules from one root seed
/// never share draws.
const CLOSED_LOOP_STREAM: u64 = 0xC105_ED10;

/// Mean virtual think time between a closed-loop client's response and its
/// next request, in ticks: gaps are drawn uniformly from
/// `[1, 2·CLOSED_LOOP_THINK_MEAN]`. A constant (not a flag) so the
/// tail-latency-vs-concurrency curve has exactly one independent variable,
/// the client count.
pub const CLOSED_LOOP_THINK_MEAN: usize = 50;

const MAGIC: &[u8; 8] = b"HIFUSEtr";
const VERSION: u32 = 1;

/// One inference request: a client-visible id, its virtual arrival tick,
/// and the target-type seed vertices it asks predictions for.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    pub id: u32,
    /// Virtual arrival time in ticks (1 µs); non-decreasing across a trace.
    pub arrival_tick: u64,
    /// Target-type vertex ids (≥ 1; duplicates allowed — the sampler
    /// dedups them into slots, the demux fans the shared row back out).
    pub seeds: Vec<u32>,
}

/// An open-loop arrival schedule: the whole input of a serve run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Trace {
    pub requests: Vec<Request>,
}

/// Generate a seeded open-loop trace: inter-arrival gaps drawn uniformly
/// from `[1, 2·mean]` ticks (mean = 1e6/`rate`, so the expected offered
/// load matches `--rate` requests/s of virtual time), and each request
/// carrying `1..=max_seeds` seed vertices drawn from the graph's labeled
/// target set. Pure in its arguments — the record/replay contract's
/// "generate" half.
pub fn generate(
    graph: &HeteroGraph,
    seed: u64,
    rate: f64,
    n_requests: usize,
    max_seeds: usize,
) -> Trace {
    assert!(rate > 0.0, "--rate must be positive");
    assert!(max_seeds >= 1, "a request carries at least one seed");
    let pool = &graph.train_idx;
    assert!(!pool.is_empty(), "graph has no labeled target vertices to serve");
    let mut rng = Rng::new(seed).fork(TRACE_STREAM);
    let mean = (1_000_000.0 / rate).max(1.0) as usize;
    let mut tick = 0u64;
    let mut requests = Vec::with_capacity(n_requests);
    for id in 0..n_requests {
        tick += (1 + rng.below(2 * mean)) as u64;
        let n = 1 + rng.below(max_seeds);
        let seeds = (0..n).map(|_| pool[rng.below(pool.len())]).collect();
        requests.push(Request { id: id as u32, arrival_tick: tick, seeds });
    }
    Trace { requests }
}

/// Generate a seeded **closed-loop** trace (`--closed-loop N`,
/// DESIGN.md §10): `clients` virtual clients each keep exactly one request
/// in flight — a client re-issues only after its previous response
/// completes under the virtual response model (a single server at
/// [`super::VIRT_SERVICE_PER_BATCH`] ticks per request, the same constant
/// the admission model uses), plus a think gap drawn uniformly from
/// `[1, 2·`[`CLOSED_LOOP_THINK_MEAN`]`]`. Offered load is therefore a pure
/// function of `(seed, clients, service times)`: adding clients raises
/// concurrency until the virtual server saturates, which is what makes
/// tail-latency-vs-concurrency sweeps well-defined. Arrival ticks are
/// non-decreasing by construction (each emission is the minimum pending
/// issue time, and every re-issue lands strictly later), so the result
/// coalesces, records, and replays exactly like an open-loop trace.
pub fn generate_closed_loop(
    graph: &HeteroGraph,
    seed: u64,
    clients: usize,
    n_requests: usize,
    max_seeds: usize,
) -> Trace {
    assert!(clients >= 1, "--closed-loop needs at least one client");
    assert!(max_seeds >= 1, "a request carries at least one seed");
    let pool = &graph.train_idx;
    assert!(!pool.is_empty(), "graph has no labeled target vertices to serve");
    let mut rng = Rng::new(seed).fork(CLOSED_LOOP_STREAM);
    // Staggered starts (client c issues first at tick c+1) so the initial
    // burst is ordered without an arbitrary tie-break.
    let mut next: Vec<u64> = (0..clients as u64).map(|c| 1 + c).collect();
    let mut server_free = 0u64;
    let mut requests = Vec::with_capacity(n_requests);
    for id in 0..n_requests {
        // Deterministic argmin over (next issue tick, client index).
        let mut c = 0usize;
        for k in 1..clients {
            if next[k] < next[c] {
                c = k;
            }
        }
        let arrival_tick = next[c];
        let n = 1 + rng.below(max_seeds);
        let seeds = (0..n).map(|_| pool[rng.below(pool.len())]).collect();
        requests.push(Request { id: id as u32, arrival_tick, seeds });
        // Virtual response: FIFO on one server at the admission-model rate.
        let done = arrival_tick.max(server_free) + super::VIRT_SERVICE_PER_BATCH;
        server_free = done;
        next[c] = done + 1 + rng.below(2 * CLOSED_LOOP_THINK_MEAN) as u64;
    }
    Trace { requests }
}

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

/// Everything that can be wrong with a trace file, as data (mirrors
/// `models::checkpoint::CheckpointError`); callers and the negative tests
/// match the variant via `err.downcast_ref::<TraceError>()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceError {
    /// The file does not start with the trace magic.
    BadMagic,
    /// Recognized magic but a version this build cannot read.
    UnsupportedVersion(u32),
    /// The file ends before the named field is complete (including a
    /// request-count or seed-count header claiming more payload than the
    /// file holds).
    Truncated { what: &'static str },
    /// Arrival ticks must be non-decreasing for the coalescer's single
    /// pass to be well-defined.
    OutOfOrder { index: usize, tick: u64, prev: u64 },
    /// Every request carries at least one seed vertex.
    EmptyRequest { index: usize },
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceError::BadMagic => write!(f, "not a hifuse arrival trace (bad magic)"),
            TraceError::UnsupportedVersion(v) => write!(f, "unsupported trace version {v}"),
            TraceError::Truncated { what } => {
                write!(f, "trace truncated while reading {what}")
            }
            TraceError::OutOfOrder { index, tick, prev } => write!(
                f,
                "request {index} arrives at tick {tick}, before its predecessor at {prev}"
            ),
            TraceError::EmptyRequest { index } => {
                write!(f, "request {index} has no seeds")
            }
        }
    }
}

impl std::error::Error for TraceError {}

/// Bounds-checked little-endian reader over the raw trace bytes; every
/// out-of-bounds read is a typed [`TraceError::Truncated`].
struct Reader<'a> {
    data: &'a [u8],
    at: usize,
}

impl Reader<'_> {
    fn take(&mut self, n: usize, what: &'static str) -> Result<&[u8], TraceError> {
        let end = self.at.checked_add(n).ok_or(TraceError::Truncated { what })?;
        if end > self.data.len() {
            return Err(TraceError::Truncated { what });
        }
        let s = &self.data[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, TraceError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, TraceError> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().expect("eight bytes")))
    }

    fn remaining(&self) -> usize {
        self.data.len() - self.at
    }
}

/// Serialize a trace (`--record-trace`).
pub fn save(trace: &Trace, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_u32(&mut w, VERSION)?;
    write_u32(&mut w, trace.requests.len() as u32)?;
    for r in &trace.requests {
        write_u32(&mut w, r.id)?;
        write_u64(&mut w, r.arrival_tick)?;
        write_u32(&mut w, r.seeds.len() as u32)?;
        for &s in &r.seeds {
            write_u32(&mut w, s)?;
        }
    }
    Ok(())
}

/// Deserialize and validate a trace (`--replay-trace`): the arrival order
/// must be non-decreasing and every request non-empty, so the coalescer's
/// single-pass scan is well-defined on anything this returns. Malformed
/// input — wrong magic/version, truncation anywhere (including length
/// headers claiming more payload than the file holds), out-of-order
/// ticks, zero-seed requests — fails with a typed [`TraceError`]; no
/// allocation is ever sized from an unvalidated length field.
pub fn load(path: &Path) -> Result<Trace> {
    let data = std::fs::read(path).with_context(|| format!("opening {path:?}"))?;
    decode(&data).with_context(|| format!("loading trace {path:?}"))
}

fn decode(data: &[u8]) -> Result<Trace> {
    let mut r = Reader { data, at: 0 };
    if r.take(MAGIC.len(), "magic")? != MAGIC {
        return Err(TraceError::BadMagic.into());
    }
    let ver = r.u32("version")?;
    if ver != VERSION {
        return Err(TraceError::UnsupportedVersion(ver).into());
    }
    let n = r.u32("request count")? as usize;
    // Each record is ≥ 16 bytes (id + tick + seed count), so a count
    // claiming more records than the remaining bytes could hold is
    // corrupt; checking now keeps the preallocation honest.
    if n > r.remaining() / 16 {
        return Err(TraceError::Truncated { what: "request count" }.into());
    }
    let mut requests = Vec::with_capacity(n);
    let mut last_tick = 0u64;
    for i in 0..n {
        let id = r.u32("request id")?;
        let arrival_tick = r.u64("arrival tick")?;
        if arrival_tick < last_tick {
            return Err(
                TraceError::OutOfOrder { index: i, tick: arrival_tick, prev: last_tick }.into()
            );
        }
        last_tick = arrival_tick;
        let k = r.u32("seed count")? as usize;
        if k == 0 {
            return Err(TraceError::EmptyRequest { index: i }.into());
        }
        // Bounds-check the whole seed payload before building the vector.
        let bytes = r.take(k * 4, "seeds")?;
        let seeds = bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        requests.push(Request { id, arrival_tick, seeds });
    }
    Ok(Trace { requests })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny_graph;

    #[test]
    fn generation_is_pure_in_its_arguments() {
        let g = tiny_graph(1);
        let a = generate(&g, 42, 1000.0, 16, 3);
        let b = generate(&g, 42, 1000.0, 16, 3);
        assert_eq!(a, b);
        assert_eq!(a.requests.len(), 16);
        let c = generate(&g, 43, 1000.0, 16, 3);
        assert_ne!(a, c, "seed must steer the schedule");
        for w in a.requests.windows(2) {
            assert!(w[1].arrival_tick >= w[0].arrival_tick);
        }
        for r in &a.requests {
            assert!((1..=3).contains(&r.seeds.len()));
            assert!(r.seeds.iter().all(|s| g.train_idx.contains(s)));
        }
    }

    #[test]
    fn closed_loop_generation_is_pure_ordered_and_seed_sensitive() {
        let g = tiny_graph(1);
        let a = generate_closed_loop(&g, 42, 4, 32, 3);
        let b = generate_closed_loop(&g, 42, 4, 32, 3);
        assert_eq!(a, b, "closed-loop generation must be pure in its arguments");
        assert_eq!(a.requests.len(), 32);
        let c = generate_closed_loop(&g, 43, 4, 32, 3);
        assert_ne!(a, c, "seed must steer the schedule");
        for w in a.requests.windows(2) {
            assert!(w[1].arrival_tick >= w[0].arrival_tick, "arrivals out of order");
        }
        for r in &a.requests {
            assert!((1..=3).contains(&r.seeds.len()));
            assert!(r.seeds.iter().all(|s| g.train_idx.contains(s)));
        }
    }

    #[test]
    fn closed_loop_concurrency_compresses_the_schedule() {
        // One client paces at service + think per request; eight clients
        // saturate the virtual server, so the same request count spans
        // far fewer ticks. The exact spans are seed-deterministic; the
        // ordering between them is the model's defining property.
        let g = tiny_graph(1);
        let span = |clients: usize| -> u64 {
            let t = generate_closed_loop(&g, 42, clients, 64, 3);
            t.requests.last().unwrap().arrival_tick - t.requests[0].arrival_tick
        };
        assert!(
            span(8) < span(1),
            "more closed-loop clients must compress the arrival span \
             (got span(8)={} >= span(1)={})",
            span(8),
            span(1)
        );
    }

    #[test]
    fn closed_loop_traces_roundtrip_the_codec() {
        let g = tiny_graph(2);
        let t = generate_closed_loop(&g, 7, 3, 20, 4);
        let path = std::env::temp_dir().join("hifuse_trace_closed_roundtrip.bin");
        save(&t, &path).unwrap();
        let u = load(&path).unwrap();
        assert_eq!(t, u);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn codec_roundtrips_bitwise() {
        let g = tiny_graph(2);
        let t = generate(&g, 7, 500.0, 12, 4);
        let path = std::env::temp_dir().join("hifuse_trace_roundtrip.bin");
        save(&t, &path).unwrap();
        let u = load(&path).unwrap();
        assert_eq!(t, u);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_garbage_and_disorder() {
        let path = std::env::temp_dir().join("hifuse_trace_garbage.bin");
        std::fs::write(&path, b"not a trace at all........").unwrap();
        let err = load(&path).unwrap_err();
        assert_eq!(err.downcast_ref::<TraceError>(), Some(&TraceError::BadMagic));
        // A syntactically valid file with decreasing ticks must be refused.
        let bad = Trace {
            requests: vec![
                Request { id: 0, arrival_tick: 100, seeds: vec![1] },
                Request { id: 1, arrival_tick: 50, seeds: vec![2] },
            ],
        };
        save(&bad, &path).unwrap();
        let err = load(&path).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TraceError>(),
                Some(TraceError::OutOfOrder { index: 1, tick: 50, prev: 100 })
            ),
            "expected out-of-order tick, got {err:#}"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_truncation_mid_record() {
        let g = tiny_graph(1);
        let t = generate(&g, 11, 800.0, 6, 3);
        let path = std::env::temp_dir().join("hifuse_trace_trunc.bin");
        save(&t, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Cut inside the header, inside a record, and inside a seed list:
        // every prefix must fail typed, never panic or misparse.
        for cut in [bytes.len() - 3, bytes.len() / 2, 14, 9] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            let err = load(&path).unwrap_err();
            assert!(
                matches!(err.downcast_ref::<TraceError>(), Some(TraceError::Truncated { .. })),
                "cut at {cut}: expected truncation, got {err:#}"
            );
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_zero_seed_requests() {
        let path = std::env::temp_dir().join("hifuse_trace_noseeds.bin");
        let bad = Trace { requests: vec![Request { id: 0, arrival_tick: 5, seeds: vec![] }] };
        save(&bad, &path).unwrap();
        let err = load(&path).unwrap_err();
        assert_eq!(
            err.downcast_ref::<TraceError>(),
            Some(&TraceError::EmptyRequest { index: 0 })
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_never_preallocates_from_a_lying_count() {
        // A header claiming u32::MAX requests on a 40-byte file must fail
        // fast as truncation — not attempt a giant Vec::with_capacity.
        let path = std::env::temp_dir().join("hifuse_trace_lying.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 24]);
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<TraceError>(), Some(TraceError::Truncated { .. })),
            "expected truncation, got {err:#}"
        );
        std::fs::remove_file(path).ok();
    }
}
