//! Arrival traces: open-loop request schedules in **virtual time**, plus
//! the record/replay codec (DESIGN.md §8).
//!
//! A trace is the serving subsystem's unit of determinism: request ids,
//! per-request seed-vertex sets, and integer *arrival ticks* (1 tick =
//! 1 µs of virtual time). Generation is a pure function of
//! `(seed, rate, n_requests)` — no wall clock anywhere — so a generated
//! schedule, a recorded file, and a replayed file all coalesce
//! identically on any machine at any parallelism (`tests/serve_parity.rs`).
//!
//! The on-disk format follows `models/checkpoint.rs`: a magic tag, a
//! version word, then length-prefixed little-endian payloads — small,
//! self-describing, and serde-free.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::graph::HeteroGraph;
use crate::util::Rng;

/// Fork stream of the arrival-schedule generator: disjoint from every
/// training stream (`sampler::EPOCH_PERM_STREAM`, the per-batch forks) so
/// serving traffic never perturbs a training trajectory run from the same
/// root seed.
const TRACE_STREAM: u64 = 0xA221_7A1E;

const MAGIC: &[u8; 8] = b"HIFUSEtr";
const VERSION: u32 = 1;

/// One inference request: a client-visible id, its virtual arrival tick,
/// and the target-type seed vertices it asks predictions for.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    pub id: u32,
    /// Virtual arrival time in ticks (1 µs); non-decreasing across a trace.
    pub arrival_tick: u64,
    /// Target-type vertex ids (≥ 1; duplicates allowed — the sampler
    /// dedups them into slots, the demux fans the shared row back out).
    pub seeds: Vec<u32>,
}

/// An open-loop arrival schedule: the whole input of a serve run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Trace {
    pub requests: Vec<Request>,
}

/// Generate a seeded open-loop trace: inter-arrival gaps drawn uniformly
/// from `[1, 2·mean]` ticks (mean = 1e6/`rate`, so the expected offered
/// load matches `--rate` requests/s of virtual time), and each request
/// carrying `1..=max_seeds` seed vertices drawn from the graph's labeled
/// target set. Pure in its arguments — the record/replay contract's
/// "generate" half.
pub fn generate(
    graph: &HeteroGraph,
    seed: u64,
    rate: f64,
    n_requests: usize,
    max_seeds: usize,
) -> Trace {
    assert!(rate > 0.0, "--rate must be positive");
    assert!(max_seeds >= 1, "a request carries at least one seed");
    let pool = &graph.train_idx;
    assert!(!pool.is_empty(), "graph has no labeled target vertices to serve");
    let mut rng = Rng::new(seed).fork(TRACE_STREAM);
    let mean = (1_000_000.0 / rate).max(1.0) as usize;
    let mut tick = 0u64;
    let mut requests = Vec::with_capacity(n_requests);
    for id in 0..n_requests {
        tick += (1 + rng.below(2 * mean)) as u64;
        let n = 1 + rng.below(max_seeds);
        let seeds = (0..n).map(|_| pool[rng.below(pool.len())]).collect();
        requests.push(Request { id: id as u32, arrival_tick: tick, seeds });
    }
    Trace { requests }
}

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Serialize a trace (`--record-trace`).
pub fn save(trace: &Trace, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_u32(&mut w, VERSION)?;
    write_u32(&mut w, trace.requests.len() as u32)?;
    for r in &trace.requests {
        write_u32(&mut w, r.id)?;
        write_u64(&mut w, r.arrival_tick)?;
        write_u32(&mut w, r.seeds.len() as u32)?;
        for &s in &r.seeds {
            write_u32(&mut w, s)?;
        }
    }
    Ok(())
}

/// Deserialize and validate a trace (`--replay-trace`): the arrival order
/// must be non-decreasing and every request non-empty, so the coalescer's
/// single-pass scan is well-defined on anything this returns.
pub fn load(path: &Path) -> Result<Trace> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut r = std::io::BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a hifuse arrival trace");
    }
    let ver = read_u32(&mut r)?;
    if ver != VERSION {
        bail!("{path:?}: unsupported trace version {ver}");
    }
    let n = read_u32(&mut r)? as usize;
    let mut requests = Vec::with_capacity(n);
    let mut last_tick = 0u64;
    for i in 0..n {
        let id = read_u32(&mut r)?;
        let arrival_tick = read_u64(&mut r)?;
        ensure!(
            arrival_tick >= last_tick,
            "{path:?}: request {i} arrives at tick {arrival_tick}, before its predecessor"
        );
        last_tick = arrival_tick;
        let k = read_u32(&mut r)? as usize;
        ensure!(k >= 1, "{path:?}: request {i} has no seeds");
        let mut seeds = Vec::with_capacity(k);
        for _ in 0..k {
            seeds.push(read_u32(&mut r)?);
        }
        requests.push(Request { id, arrival_tick, seeds });
    }
    Ok(Trace { requests })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny_graph;

    #[test]
    fn generation_is_pure_in_its_arguments() {
        let g = tiny_graph(1);
        let a = generate(&g, 42, 1000.0, 16, 3);
        let b = generate(&g, 42, 1000.0, 16, 3);
        assert_eq!(a, b);
        assert_eq!(a.requests.len(), 16);
        let c = generate(&g, 43, 1000.0, 16, 3);
        assert_ne!(a, c, "seed must steer the schedule");
        for w in a.requests.windows(2) {
            assert!(w[1].arrival_tick >= w[0].arrival_tick);
        }
        for r in &a.requests {
            assert!((1..=3).contains(&r.seeds.len()));
            assert!(r.seeds.iter().all(|s| g.train_idx.contains(s)));
        }
    }

    #[test]
    fn codec_roundtrips_bitwise() {
        let g = tiny_graph(2);
        let t = generate(&g, 7, 500.0, 12, 4);
        let path = std::env::temp_dir().join("hifuse_trace_roundtrip.bin");
        save(&t, &path).unwrap();
        let u = load(&path).unwrap();
        assert_eq!(t, u);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_garbage_and_disorder() {
        let path = std::env::temp_dir().join("hifuse_trace_garbage.bin");
        std::fs::write(&path, b"not a trace at all........").unwrap();
        assert!(load(&path).is_err());
        // A syntactically valid file with decreasing ticks must be refused.
        let bad = Trace {
            requests: vec![
                Request { id: 0, arrival_tick: 100, seeds: vec![1] },
                Request { id: 1, arrival_tick: 50, seeds: vec![2] },
            ],
        };
        save(&bad, &path).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
