//! Streaming latency histogram: fixed-footprint log₂ buckets over virtual
//! ticks (DESIGN.md §8).
//!
//! Bucket `k` holds every value whose bit length is `k` — bucket 0 is
//! exactly `{0}`, bucket `k ≥ 1` spans `[2^(k-1), 2^k)` — so recording is
//! one `leading_zeros` and one increment: no allocation, no sort, and the
//! structure's size is independent of the request count (the serving
//! loop's zero-alloc discipline extends to its metrology). Percentiles
//! are nearest-rank over the cumulative bucket walk, reported at the
//! bucket's inclusive upper bound (clamped to the observed extremes), so
//! `p50 ≤ p95 ≤ p99` holds by construction.

/// 64 possible bit lengths of a non-zero `u64`, plus bucket 0 for zero.
const BUCKETS: usize = 65;

/// Fixed-size streaming histogram of `u64` latencies (virtual ticks).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: [u64; BUCKETS],
    total: u64,
    min: u64,
    max: u64,
    sum: u128,
    /// Requests shed by admission control: counted here so the histogram
    /// stays the single serving scoreboard, but **never** folded into the
    /// latency buckets — a shed request has no completion time, and mixing
    /// zeros in would corrupt the percentiles.
    shed: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: [0; BUCKETS],
            total: 0,
            min: u64::MAX,
            max: 0,
            sum: 0,
            shed: 0,
        }
    }
}

impl LatencyHistogram {
    fn bucket(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    /// Record one latency sample.
    pub fn record(&mut self, ticks: u64) {
        self.counts[Self::bucket(ticks)] += 1;
        self.total += 1;
        self.min = self.min.min(ticks);
        self.max = self.max.max(ticks);
        self.sum += ticks as u128;
    }

    /// Count one request shed by admission control (no latency sample).
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    /// Requests shed by admission control (disjoint from [`count`](Self::count)).
    pub fn shed(&self) -> u64 {
        self.shed
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Smallest recorded latency in ticks (`None` when empty). Exact —
    /// not bucket-resolution — so closed-loop concurrency sweeps can
    /// report true best-case service time next to the tail percentiles.
    pub fn observed_min(&self) -> Option<u64> {
        (self.total > 0).then_some(self.min)
    }

    /// Largest recorded latency in ticks (`None` when empty). Exact.
    pub fn observed_max(&self) -> Option<u64> {
        (self.total > 0).then_some(self.max)
    }

    /// Mean latency in ticks (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    /// Nearest-rank percentile (`p` in `[0, 100]`), at bucket resolution:
    /// the inclusive upper bound of the bucket holding the ranked sample,
    /// clamped to the observed `[min, max]`. Monotone in `p`.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (k, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                let hi = if k == 0 { 0 } else { (1u64 << k) - 1 };
                return hi.clamp(self.min, self.max);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_split_at_powers_of_two() {
        assert_eq!(LatencyHistogram::bucket(0), 0);
        assert_eq!(LatencyHistogram::bucket(1), 1);
        assert_eq!(LatencyHistogram::bucket(2), 2);
        assert_eq!(LatencyHistogram::bucket(3), 2);
        assert_eq!(LatencyHistogram::bucket(4), 3);
        assert_eq!(LatencyHistogram::bucket(u64::MAX), 64);
    }

    #[test]
    fn percentiles_are_monotone_and_bracketed() {
        let mut h = LatencyHistogram::default();
        for v in [3u64, 5, 9, 17, 33, 65, 129, 1025] {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        let (p50, p95, p99) = (h.percentile(50.0), h.percentile(95.0), h.percentile(99.0));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert!(p50 >= 3 && p99 <= 1025, "clamped to observed extremes");
        assert!((h.mean() - 1286.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LatencyHistogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.observed_min(), None);
        assert_eq!(h.observed_max(), None);
    }

    #[test]
    fn observed_extremes_are_exact_not_bucketed() {
        let mut h = LatencyHistogram::default();
        for v in [7u64, 1000, 13] {
            h.record(v);
        }
        assert_eq!(h.observed_min(), Some(7));
        assert_eq!(h.observed_max(), Some(1000));
    }

    #[test]
    fn sheds_count_separately_from_samples() {
        let mut h = LatencyHistogram::default();
        h.record(10);
        h.record_shed();
        h.record_shed();
        assert_eq!(h.count(), 1, "sheds are not latency samples");
        assert_eq!(h.shed(), 2);
        assert_eq!(h.percentile(50.0), 10, "percentiles ignore sheds");
    }

    #[test]
    fn constant_stream_collapses_to_its_bucket() {
        let mut h = LatencyHistogram::default();
        for _ in 0..100 {
            h.record(1000);
        }
        // Bucket [512, 1024) clamps to the observed value on both ends.
        assert_eq!(h.percentile(50.0), 1000);
        assert_eq!(h.percentile(99.0), 1000);
    }
}
