//! Performance accounting: analytic FLOP/byte cost model per module,
//! machine-peak calibration, and the roofline rows behind Fig. 3(b) and
//! Table 3.
//!
//! Peaks are *measured on this machine* (a dense matmul for compute, a
//! large memcpy for bandwidth, a minimal dispatch for launch overhead), so
//! "throughput %" numbers are relative to the same substrate the kernels
//! run on — the CPU-PJRT analogue of Nsight Compute's SOL metrics.
//!
//! [`module_cost`] prices each manifest module analytically (algorithmic
//! FLOPs and bytes, the convention roofline studies use); combined with
//! the measured [`Peaks`], every dispatch event classifies as compute- or
//! memory-bound ([`roofline_rows`], feeding Fig. 3b and Table 3). The
//! work/span [`parallel_model`] is the shared model behind
//! `semantic::modeled_parallel_speedup` — the multi-core CPU-stage scaling
//! a single-core container cannot measure (DESIGN.md §1).

use std::time::Instant;

use anyhow::Result;

use crate::models::step::Dims;
use crate::runtime::{Event, ExecBackend, Phase, Stage};
use crate::util::HostTensor;

/// Calibrated machine peaks.
#[derive(Clone, Copy, Debug)]
pub struct Peaks {
    pub gflops: f64,
    pub membw_gbs: f64,
    pub dispatch_us: f64,
}

/// Analytic cost of one dispatch of `module`: (flops, bytes moved).
/// Algorithmic costs (what the op must do), not implementation costs — the
/// same convention roofline studies use.
pub fn module_cost(module: &str, d: &Dims) -> (f64, f64) {
    let (ns, ep, rp, tp, f, h, c, elp) = (
        d.ns as f64,
        d.ep as f64,
        d.rpad as f64,
        d.tpad as f64,
        d.f as f64,
        d.h as f64,
        d.c as f64,
        d.elp as f64,
    );
    let fd = |sfx: &str| if sfx.ends_with('h') { h } else { c };
    let b = 4.0; // f32/i32 bytes
    match module {
        "edge_select" => (elp * 16.0, 2.0 * elp * b), // compare + bitonic-ish sort
        "proj_fwd_l0" => (2.0 * ns * f * h, (ns * f + f * h + ns * h) * b),
        "proj_fwd_l1" => (2.0 * ns * h * c, (ns * h + h * c + ns * c) * b),
        "proj_bwd_l0" => (4.0 * ns * f * h, (2.0 * ns * f + 2.0 * f * h + ns * h) * b),
        "proj_bwd_l1" => (4.0 * ns * h * c, (2.0 * ns * h + 2.0 * h * c + ns * c) * b),
        "proj_stacked_fwd_l0" => (2.0 * rp * ns * f * h, (tp * ns * f + rp * f * h + rp * ns * h) * b),
        "proj_stacked_fwd_l1" => (2.0 * rp * ns * h * c, (tp * ns * h + rp * h * c + rp * ns * c) * b),
        "proj_stacked_bwd_l0" => (4.0 * rp * ns * f * h, (tp * ns * f + 2.0 * rp * f * h + rp * ns * h) * b),
        "proj_stacked_bwd_l1" => (4.0 * rp * ns * h * c, (tp * ns * h + 2.0 * rp * h * c + rp * ns * c) * b),
        m if m.starts_with("agg_mean_fwd") => {
            let fd = fd(m);
            (2.0 * ep * fd + ns * fd, (ns * fd + ep * fd + 3.0 * ep + ns * fd) * b)
        }
        m if m.starts_with("agg_mean_bwd") => {
            let fd = fd(m);
            (2.0 * ep * fd + ns * fd, (2.0 * ns * fd + ep * fd + 3.0 * ep) * b)
        }
        m if m.starts_with("agg_merged_fwd") => {
            let fd = fd(m);
            (rp * (2.0 * ep * fd + ns * fd), rp * (2.0 * ns * fd + ep * fd + 3.0 * ep) * b)
        }
        m if m.starts_with("agg_merged_bwd") => {
            let fd = fd(m);
            (rp * (2.0 * ep * fd + ns * fd), rp * (2.0 * ns * fd + ep * fd + 3.0 * ep) * b)
        }
        m if m.starts_with("att_agg_fwd") => {
            let fd = fd(m);
            (4.0 * ns * fd + 10.0 * ep + 2.0 * ep * fd, (2.0 * ns * fd + ep * fd + 3.0 * ep + ns * fd) * b)
        }
        m if m.starts_with("att_agg_bwd") => {
            let fd = fd(m);
            (2.0 * (4.0 * ns * fd + 10.0 * ep + 2.0 * ep * fd), 2.0 * (3.0 * ns * fd + ep * fd + 3.0 * ep) * b)
        }
        m if m.starts_with("att_merged_fwd") => {
            let fd = fd(m);
            (rp * (4.0 * ns * fd + 10.0 * ep + 2.0 * ep * fd), rp * (3.0 * ns * fd + ep * fd + 3.0 * ep) * b)
        }
        m if m.starts_with("att_merged_bwd") => {
            let fd = fd(m);
            (2.0 * rp * (4.0 * ns * fd + 10.0 * ep + 2.0 * ep * fd), 2.0 * rp * (3.0 * ns * fd + ep * fd + 3.0 * ep) * b)
        }
        m if m.starts_with("fuse_relu") || m.starts_with("fuse_lin") => {
            // Segment scatter-add over relations (dst_type-indexed).
            let fd = if m.contains("_h") { h } else { c };
            (rp * ns * fd, (rp * ns * fd + rp + tp * ns * fd) * b)
        }
        "head" => (10.0 * ns * c, (2.0 * ns * c + 2.0 * ns) * b),
        // Pure data movement: read one source row per slot (cache or miss)
        // plus the index vector, write the fused slab.
        "feature_gather" => (0.0, (2.0 * tp * ns * f + tp * ns) * b),
        _ => (0.0, 0.0),
    }
}

/// Calibrate machine peaks. Compute peak via the biggest matmul module in
/// the profile; bandwidth via a 64 MB memcpy; dispatch overhead via the
/// backend's probe. Works on any backend — on the sim backend the numbers
/// characterize the interpreter substrate, which is exactly what its
/// dispatched kernels run on.
pub fn calibrate<B: ExecBackend>(eng: &B) -> Result<Peaks> {
    let d = Dims::from_backend(eng);
    // -- compute peak: stacked projection is the densest matmul we ship.
    // Nonzero operands: the sim interpreter short-circuits zero rows, so
    // an all-zeros probe would overstate the peak by ~the output dim.
    let xs = HostTensor::f32(vec![1.0; d.tpad * d.ns * d.f], &[d.tpad, d.ns, d.f]);
    let w = HostTensor::f32(vec![1.0; d.rpad * d.f * d.h], &[d.rpad, d.f, d.h]);
    let st = HostTensor::i32(vec![0; d.rpad], &[d.rpad]);
    eng.run("proj_stacked_fwd_l0", Stage::Calib, Phase::Fwd, &[&xs, &w, &st])?; // warm+compile
    let (flops, _) = module_cost("proj_stacked_fwd_l0", &d);
    let reps = 3;
    let t0 = Instant::now();
    for _ in 0..reps {
        eng.run("proj_stacked_fwd_l0", Stage::Calib, Phase::Fwd, &[&xs, &w, &st])?;
    }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    let gflops = flops / dt / 1e9;

    // -- memory bandwidth: big out-of-cache copy.
    let n = 16 * 1024 * 1024; // 64 MB of f32
    let src = vec![1.0f32; n];
    let mut dst = vec![0.0f32; n];
    let t0 = Instant::now();
    dst.copy_from_slice(&src);
    let bw = (2.0 * n as f64 * 4.0) / t0.elapsed().as_secs_f64() / 1e9;
    std::hint::black_box(&dst);

    let dispatch_us = eng.measure_dispatch_overhead(20)?.as_secs_f64() * 1e6;
    Ok(Peaks { gflops: gflops.max(1e-9), membw_gbs: bw.max(1e-9), dispatch_us })
}

/// Work/span model of a parallel CPU stage (Brent's bound): the predicted
/// wall time of `work_s` serial seconds spread over `threads` workers when
/// the largest indivisible chunk costs `span_s` seconds —
/// `max(span, work/threads)`. Single-core containers use this to report
/// the multi-core selection/collection time they cannot measure
/// (DESIGN.md §1); it ignores scheduling overhead, so it is a lower bound.
pub fn parallel_model(work_s: f64, span_s: f64, threads: usize) -> f64 {
    (work_s / threads.max(1) as f64).max(span_s)
}

/// Steady-state per-batch wall time of the multi-producer pipeline
/// (DESIGN.md §4): `producers` CPU workers each take `produce_s` per batch
/// while the consumer takes `consume_s`, so throughput is limited by
/// `max(consume, produce / producers)` — Brent's bound with the consumer
/// step as the indivisible span. This is the model column of
/// `results/producer_scaling.md` (EXPERIMENTS.md §Perf #6): producer
/// scaling pays off exactly until the consumer becomes the bottleneck.
pub fn pipeline_model(produce_s: f64, consume_s: f64, producers: usize) -> f64 {
    parallel_model(produce_s, consume_s, producers)
}

/// One roofline point (Fig. 3b): a dispatched kernel's arithmetic
/// intensity vs achieved compute, plus its bound classification.
#[derive(Clone, Debug)]
pub struct RooflineRow {
    pub module: &'static str,
    pub stage: Stage,
    pub ai: f64,
    pub achieved_gflops: f64,
    pub compute_pct: f64,
    pub memory_pct: f64,
    pub memory_bound: bool,
    pub dur_us: f64,
}

pub fn roofline_rows(events: &[Event], d: &Dims, peaks: &Peaks) -> Vec<RooflineRow> {
    events
        .iter()
        .filter(|e| e.stage != Stage::Calib)
        .map(|e| {
            let (flops, bytes) = module_cost(e.module, d);
            let secs = e.dur.as_secs_f64().max(1e-9);
            let achieved = flops / secs / 1e9;
            let achieved_bw = bytes / secs / 1e9;
            let ai = flops / bytes.max(1.0);
            // Roofline knee: memory-bound iff AI < peak_flops / peak_bw.
            let knee = peaks.gflops / peaks.membw_gbs;
            RooflineRow {
                module: e.module,
                stage: e.stage,
                ai,
                achieved_gflops: achieved,
                compute_pct: 100.0 * achieved / peaks.gflops,
                memory_pct: 100.0 * achieved_bw / peaks.membw_gbs,
                memory_bound: ai < knee,
                dur_us: e.dur.as_secs_f64() * 1e6,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> Dims {
        Dims { ns: 512, ep: 256, rpad: 128, tpad: 32, f: 32, h: 64, c: 16, elp: 32768 }
    }

    #[test]
    fn parallel_model_is_brents_bound() {
        // Perfectly divisible work scales linearly ...
        assert_eq!(parallel_model(8.0, 0.5, 8), 1.0);
        // ... until the span dominates ...
        assert_eq!(parallel_model(8.0, 2.0, 8), 2.0);
        // ... and zero threads degrade to serial.
        assert_eq!(parallel_model(8.0, 0.5, 0), 8.0);
    }

    #[test]
    fn pipeline_model_saturates_at_the_consumer() {
        // Producer-bound: doubling producers halves the step time ...
        assert_eq!(pipeline_model(8.0, 1.0, 2), 4.0);
        assert_eq!(pipeline_model(8.0, 1.0, 4), 2.0);
        // ... until the consumer is the bottleneck.
        assert_eq!(pipeline_model(8.0, 3.0, 4), 3.0);
        assert_eq!(pipeline_model(8.0, 3.0, 64), 3.0);
    }

    #[test]
    fn aggregation_is_low_intensity_projection_is_high() {
        let d = dims();
        let (af, ab) = module_cost("agg_mean_fwd_h", &d);
        let (pf, pb) = module_cost("proj_fwd_l0", &d);
        let agg_ai = af / ab;
        let proj_ai = pf / pb;
        // The paper's Fig. 3b: scatter/gather kernels are memory-bound
        // (AI << 1), dense projection is much denser.
        assert!(agg_ai < 0.5, "agg AI {agg_ai}");
        assert!(proj_ai > 5.0 * agg_ai, "proj AI {proj_ai} vs agg {agg_ai}");
    }

    #[test]
    fn merged_cost_is_rpad_times_per_relation() {
        let d = dims();
        let (mf, mb) = module_cost("agg_merged_fwd_h", &d);
        let (sf, _) = module_cost("agg_mean_fwd_h", &d);
        assert!((mf / sf - d.rpad as f64).abs() < 1.0);
        assert!(mb > 0.0);
    }

    #[test]
    fn every_shipping_module_has_a_cost() {
        let d = dims();
        for m in [
            "edge_select", "head", "proj_fwd_l0", "proj_fwd_l1", "proj_bwd_l0",
            "proj_bwd_l1", "proj_stacked_fwd_l0", "proj_stacked_bwd_l1",
            "agg_mean_fwd_h", "agg_mean_bwd_c", "agg_merged_fwd_h", "agg_merged_bwd_c",
            "att_agg_fwd_h", "att_agg_bwd_c", "att_merged_fwd_h", "att_merged_bwd_c",
            "fuse_relu_fwd_h", "fuse_lin_bwd_c",
        ] {
            let (f, b) = module_cost(m, &d);
            assert!(f > 0.0 && b > 0.0, "{m} has no cost model");
        }
    }
}
