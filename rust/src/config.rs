//! Run configuration + a dependency-free CLI argument parser (clap is not
//! available offline).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::coordinator::{OptConfig, TrainCfg, DEFAULT_PROBATION};
use crate::graph::{self, HeteroGraph};
use crate::models::ModelKind;
use crate::util::FaultPlan;

/// Which `ExecBackend` implementation a run executes on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust reference interpreter (default): no artifacts, no Python.
    Sim,
    /// PJRT engine over AOT HLO artifacts (requires `--features pjrt`).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sim" => Some(BackendKind::Sim),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Sim => "sim",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Everything a training / benchmark run needs.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: String,
    pub model: ModelKind,
    pub mode_name: String,
    pub opt: OptConfig,
    pub train: TrainCfg,
    /// Dataset scale factor (DESIGN.md §2: schema never scales).
    pub scale: f64,
    /// Profile directory for the PJRT backend, e.g. `artifacts/bench`.
    pub artifacts: PathBuf,
    /// Execution backend (default: the self-contained sim interpreter).
    pub backend: BackendKind,
    /// Built-in profile for the sim backend (`tiny`|`bench`); `None` picks
    /// by dataset (tiny dataset -> tiny profile, everything else -> bench).
    pub profile: Option<String>,
    /// Simulated per-dispatch launch overhead for the sim backend, in
    /// microseconds — the "CUDA launch cost" knob of the reproduction.
    pub sim_overhead_us: f64,
    /// `Some(n)`: train data-parallel over `n` backend replicas
    /// (`coordinator::ReplicaGroup`, sim backend only). `None` (default):
    /// classic single-backend per-batch SGD. The two differ semantically —
    /// replica rounds update once per `DEFAULT_ROUND` batches — which is
    /// why `--replicas 1` still selects the replica path: the trajectory
    /// must be identical for every `--replicas` value (DESIGN.md §4).
    pub replicas: Option<usize>,
    /// Device-resident feature-cache budget in `[0, 1]` (`--cache-frac`):
    /// the fraction of each vertex type pinned on the device by the
    /// deterministic presampling pass (DESIGN.md §7). `0` (default) = off;
    /// the trajectory is bitwise identical for every value. Train + serve,
    /// sim backend only (the PJRT path bails).
    pub cache_frac: f64,
    /// Serve: offered load of the generated arrival stream, requests per
    /// second of *virtual* time (1 tick = 1 µs; DESIGN.md §8).
    pub rate: f64,
    /// Serve: number of requests to generate when not replaying a trace.
    pub requests: usize,
    /// Serve: coalescing window in virtual ticks — how long a batch keeps
    /// accepting requests past its first arrival.
    pub coalesce_window: u64,
    /// Serve: write the arrival schedule (ids, seed sets, ticks) here.
    pub record_trace: Option<PathBuf>,
    /// Serve: replay this schedule instead of generating one — same
    /// coalescing and bitwise-identical predictions at any parallelism.
    pub replay_trace: Option<PathBuf>,
    /// Load model parameters from this checkpoint before running
    /// (first-class form of `HIFUSE_LOAD_CKPT`, which remains a fallback).
    pub load_ckpt: Option<PathBuf>,
    /// Save model parameters to this checkpoint after running
    /// (first-class form of `HIFUSE_SAVE_CKPT`, which remains a fallback).
    pub save_ckpt: Option<PathBuf>,
    /// Deterministic fault-injection schedule (`--fault-spec`, DESIGN.md
    /// §9): comma-separated `site@EPOCH:SEQ[xN]` / `site~PERIOD` entries
    /// over the crash sites `dispatch`, `producer`, `lane`, `lane!` and
    /// the corruption sites `flip!`, `nan!`, `wire!` (DESIGN.md §11).
    /// `None` (default) = the fault plane is off and zero-cost.
    pub fault_spec: Option<String>,
    /// Seed steering `site~PERIOD` sprinkle rules in `--fault-spec`; inert
    /// without one.
    pub fault_seed: u64,
    /// Serve: admission-control bound on the virtual batch queue
    /// (`--max-queue`, DESIGN.md §9). Batches arriving while this many are
    /// pending are shed deterministically. `None` (default) = unbounded.
    pub max_queue: Option<usize>,
    /// Serve: hot model refreshes (`--refresh-at TICK[:PATH]`, repeatable
    /// via comma-separated entries; DESIGN.md §10). At the first admitted
    /// batch closing at or after `TICK`, every lane swaps to the
    /// checkpoint at `PATH` (`None` falls back to `--load-ckpt`). A failed
    /// load is counted, never fatal.
    pub refresh_at: Vec<(u64, Option<PathBuf>)>,
    /// Serve: `Some(n)` replaces the open-loop Poisson arrival stream with
    /// `n` closed-loop virtual clients, each re-issuing only after its
    /// previous response completes (`--closed-loop`, DESIGN.md §10).
    pub closed_loop: Option<usize>,
    /// Serve: shadow batches a quarantined lane must complete before
    /// re-admission (`--probation`, DESIGN.md §10).
    pub probation: usize,
    /// Per-batch numeric guard rails (`--guard`, DESIGN.md §11): verify
    /// staged features against their digest before the step and require
    /// a finite loss + gradient after it; a violation enters the
    /// recompute-or-rollback ladder. Bare flag — an optional
    /// `0|1|true|false` value is also accepted. Off (default) = zero
    /// extra dispatches and a bitwise-unchanged trajectory.
    pub guard: bool,
    /// Audit the parameter state (plus cache slab and replica lane
    /// overrides) every N batches (`--audit-every N`, train only;
    /// DESIGN.md §11). `0` (default) = no audits.
    pub audit_every: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "aifb".into(),
            model: ModelKind::Rgcn,
            mode_name: "hifuse".into(),
            opt: OptConfig::hifuse(),
            train: TrainCfg::default(),
            scale: 1.0,
            artifacts: PathBuf::from("artifacts/bench"),
            backend: BackendKind::Sim,
            profile: None,
            sim_overhead_us: 0.0,
            replicas: None,
            cache_frac: 0.0,
            rate: 1000.0,
            requests: 64,
            coalesce_window: 1000,
            record_trace: None,
            replay_trace: None,
            load_ckpt: None,
            save_ckpt: None,
            fault_spec: None,
            fault_seed: 0,
            max_queue: None,
            refresh_at: Vec::new(),
            closed_loop: None,
            probation: DEFAULT_PROBATION,
            guard: false,
            audit_every: 0,
        }
    }
}

/// Parse a `--refresh-at` value: comma-separated `TICK[:PATH]` entries
/// (the repeatable form — the parser is last-wins per flag, so repeats go
/// in one value, same as `--fault-spec`).
fn parse_refresh_at(v: &str) -> Result<Vec<(u64, Option<PathBuf>)>> {
    let mut out = Vec::new();
    for entry in v.split(',').map(str::trim) {
        if entry.is_empty() {
            bail!("--refresh-at has an empty entry (expected TICK[:PATH])");
        }
        let (tick, path) = match entry.split_once(':') {
            Some((t, p)) if !p.is_empty() => (t, Some(PathBuf::from(p))),
            Some((t, _)) => (t, None),
            None => (entry, None),
        };
        let tick: u64 = tick
            .parse()
            .with_context(|| format!("--refresh-at entry {entry:?}: bad tick"))?;
        out.push((tick, path));
    }
    Ok(out)
}

impl RunConfig {
    /// Parse `--key value` style flags.
    pub fn from_args(args: &[String]) -> Result<RunConfig> {
        let mut kv = HashMap::new();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {a:?}"))?;
            // `--guard` is a bare flag: consume a value only when the
            // next token is an explicit boolean, so `--guard --epochs 3`
            // does not swallow `--epochs`.
            let val = if key == "guard" {
                match it.peek().map(|s| s.as_str()) {
                    Some(v @ ("0" | "1" | "true" | "false")) => {
                        let v = v.to_string();
                        it.next();
                        v
                    }
                    _ => "true".to_string(),
                }
            } else {
                it.next()
                    .with_context(|| format!("--{key} needs a value"))?
                    .clone()
            };
            kv.insert(key.to_string(), val);
        }
        let mut cfg = RunConfig::default();
        for (k, v) in kv {
            match k.as_str() {
                "dataset" => cfg.dataset = v,
                "model" => {
                    cfg.model = ModelKind::parse(&v)
                        .with_context(|| format!("unknown model {v:?} (rgcn|rgat)"))?
                }
                "mode" => {
                    cfg.opt = OptConfig::parse(&v)
                        .with_context(|| format!("unknown mode {v:?}"))?;
                    cfg.mode_name = v;
                }
                "epochs" => cfg.train.epochs = v.parse().context("--epochs")?,
                "batch-size" => cfg.train.batch_size = v.parse().context("--batch-size")?,
                "fanout" => cfg.train.fanout = v.parse().context("--fanout")?,
                "lr" => cfg.train.lr = v.parse().context("--lr")?,
                "seed" => cfg.train.seed = v.parse().context("--seed")?,
                "threads" => cfg.train.threads = v.parse().context("--threads")?,
                "producers" => {
                    let n: usize = v.parse().context("--producers")?;
                    if n == 0 {
                        bail!("--producers must be >= 1 (omit the flag to derive from --threads)");
                    }
                    cfg.train.producers = n;
                }
                "scale" => cfg.scale = v.parse().context("--scale")?,
                "artifacts" => cfg.artifacts = PathBuf::from(v),
                "backend" => {
                    cfg.backend = BackendKind::parse(&v)
                        .with_context(|| format!("unknown backend {v:?} (sim|pjrt)"))?
                }
                "profile" => cfg.profile = Some(v),
                "sim-overhead-us" => {
                    cfg.sim_overhead_us = v.parse().context("--sim-overhead-us")?
                }
                "replicas" => {
                    let n: usize = v.parse().context("--replicas")?;
                    if n == 0 {
                        bail!("--replicas must be >= 1");
                    }
                    cfg.replicas = Some(n);
                }
                "cache-frac" => {
                    let f: f64 = v.parse().context("--cache-frac")?;
                    if !(0.0..=1.0).contains(&f) {
                        bail!("--cache-frac must be in [0, 1], got {f}");
                    }
                    cfg.cache_frac = f;
                }
                "rate" => {
                    let r: f64 = v.parse().context("--rate")?;
                    if r.is_nan() || r <= 0.0 {
                        bail!("--rate must be positive, got {r}");
                    }
                    cfg.rate = r;
                }
                "requests" => {
                    let n: usize = v.parse().context("--requests")?;
                    if n == 0 {
                        bail!("--requests must be >= 1");
                    }
                    cfg.requests = n;
                }
                "coalesce-window" => {
                    cfg.coalesce_window = v.parse().context("--coalesce-window")?
                }
                "record-trace" => cfg.record_trace = Some(PathBuf::from(v)),
                "replay-trace" => cfg.replay_trace = Some(PathBuf::from(v)),
                "load-ckpt" => cfg.load_ckpt = Some(PathBuf::from(v)),
                "save-ckpt" => cfg.save_ckpt = Some(PathBuf::from(v)),
                "fault-seed" => cfg.fault_seed = v.parse().context("--fault-seed")?,
                "fault-spec" => {
                    // Validate eagerly (seed 0 — the grammar is seed-free)
                    // so a typo bails at the CLI, not mid-run.
                    FaultPlan::parse(&v, 0)
                        .with_context(|| format!("--fault-spec {v:?}"))?;
                    cfg.fault_spec = Some(v);
                }
                "max-queue" => {
                    let n: usize = v.parse().context("--max-queue")?;
                    if n == 0 {
                        bail!("--max-queue must be >= 1 (omit the flag for an unbounded queue)");
                    }
                    cfg.max_queue = Some(n);
                }
                "refresh-at" => cfg.refresh_at = parse_refresh_at(&v)?,
                "closed-loop" => {
                    let n: usize = v.parse().context("--closed-loop")?;
                    if n == 0 {
                        bail!("--closed-loop needs at least one client");
                    }
                    cfg.closed_loop = Some(n);
                }
                "probation" => {
                    let n: usize = v.parse().context("--probation")?;
                    if n == 0 {
                        bail!("--probation must be >= 1 (a lane must prove itself on something)");
                    }
                    cfg.probation = n;
                }
                "guard" => {
                    // The flag loop normalised a bare `--guard` to "true".
                    cfg.guard = match v.as_str() {
                        "1" | "true" => true,
                        "0" | "false" => false,
                        other => bail!("--guard takes no value (or 0|1|true|false), got {other:?}"),
                    };
                }
                "audit-every" => {
                    let n: u64 = v.parse().context("--audit-every")?;
                    if n == 0 {
                        bail!("--audit-every must be >= 1 (omit the flag to disable audits)");
                    }
                    cfg.audit_every = n;
                }
                other => bail!("unknown flag --{other}"),
            }
        }
        // Cross-flag checks live after the loop: `kv` is a HashMap, so
        // arm order within it is arbitrary.
        if cfg.record_trace.is_some() && cfg.replay_trace.is_some() {
            bail!(
                "--record-trace and --replay-trace conflict: a replayed run \
                 would just re-record its own input (pick one)"
            );
        }
        if cfg.closed_loop.is_some() && cfg.replay_trace.is_some() {
            bail!(
                "--closed-loop and --replay-trace conflict: a replayed schedule \
                 already fixes every arrival tick (pick one)"
            );
        }
        if (cfg.guard || cfg.audit_every > 0) && cfg.backend == BackendKind::Pjrt {
            bail!(
                "--guard/--audit-every need the sim backend: the integrity plane \
                 instruments the host-staged step (DESIGN.md §11)"
            );
        }
        if (cfg.guard || cfg.audit_every > 0) && cfg.opt.dev_resident {
            bail!(
                "--guard/--audit-every need the host-staged step: the fused device \
                 SGD cannot split the gradient check from the parameter apply \
                 (pick a non-resident --mode)"
            );
        }
        Ok(cfg)
    }

    /// The fault plan this config describes: `Some` only when
    /// `--fault-spec` was given (`--fault-seed` alone is inert). Parsing
    /// here cannot fail for configs built by [`from_args`] (the spec was
    /// validated there), but hand-built configs get the same typed error.
    pub fn fault_plan(&self) -> Result<Option<FaultPlan>> {
        match &self.fault_spec {
            Some(spec) => Ok(Some(FaultPlan::parse(spec, self.fault_seed)?)),
            None => Ok(None),
        }
    }

    /// Sim-backend profile: explicit `--profile` wins; otherwise the tiny
    /// dataset gets the tiny profile and every Table 2 dataset gets bench.
    pub fn resolved_profile(&self) -> &str {
        match &self.profile {
            Some(p) => p,
            None if self.dataset == "tiny" => "tiny",
            None => "bench",
        }
    }

    /// Build the dataset this config names. `feat_dim` must equal the
    /// profile's F (checked by `Trainer::new`).
    pub fn load_graph(&self, feat_dim: usize) -> Result<HeteroGraph> {
        if self.dataset == "tiny" {
            return Ok(graph::datasets::tiny_graph(self.train.seed));
        }
        let spec = graph::datasets::spec_by_name(&self.dataset)
            .with_context(|| format!("unknown dataset {:?}", self.dataset))?;
        Ok(graph::datasets::generate(&spec, feat_dim, self.scale, self.train.seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_flags() {
        let c = RunConfig::from_args(&argv(
            "--dataset bgs --model rgat --mode base --epochs 3 --batch-size 16 --scale 0.5",
        ))
        .unwrap();
        assert_eq!(c.dataset, "bgs");
        assert_eq!(c.model, ModelKind::Rgat);
        assert_eq!(c.opt, OptConfig::baseline());
        assert_eq!(c.train.epochs, 3);
        assert_eq!(c.train.batch_size, 16);
        assert_eq!(c.scale, 0.5);
    }

    #[test]
    fn rejects_unknown_flags_and_values() {
        assert!(RunConfig::from_args(&argv("--nope 1")).is_err());
        assert!(RunConfig::from_args(&argv("--model nope")).is_err());
        assert!(RunConfig::from_args(&argv("--epochs")).is_err());
        assert!(RunConfig::from_args(&argv("positional")).is_err());
    }

    #[test]
    fn defaults_are_hifuse_aifb_on_sim() {
        let c = RunConfig::from_args(&[]).unwrap();
        assert_eq!(c.dataset, "aifb");
        assert_eq!(c.opt, OptConfig::hifuse());
        assert_eq!(c.backend, BackendKind::Sim);
        assert_eq!(c.resolved_profile(), "bench");
        assert_eq!(c.sim_overhead_us, 0.0);
    }

    #[test]
    fn backend_and_profile_flags_parse() {
        let c = RunConfig::from_args(&argv("--backend pjrt --artifacts a/b")).unwrap();
        assert_eq!(c.backend, BackendKind::Pjrt);
        assert_eq!(c.artifacts, PathBuf::from("a/b"));
        let c = RunConfig::from_args(&argv("--dataset tiny --sim-overhead-us 50")).unwrap();
        assert_eq!(c.backend, BackendKind::Sim);
        assert_eq!(c.resolved_profile(), "tiny");
        assert_eq!(c.sim_overhead_us, 50.0);
        let c = RunConfig::from_args(&argv("--dataset tiny --profile bench")).unwrap();
        assert_eq!(c.resolved_profile(), "bench");
        assert!(RunConfig::from_args(&argv("--backend gpu")).is_err());
    }

    #[test]
    fn producers_flag_parses_and_rejects_zero() {
        assert_eq!(RunConfig::from_args(&[]).unwrap().train.producers, 0);
        let c = RunConfig::from_args(&argv("--producers 4 --threads 8")).unwrap();
        assert_eq!(c.train.producers, 4);
        assert_eq!(c.train.threads, 8);
        assert!(RunConfig::from_args(&argv("--producers 0")).is_err());
        assert!(RunConfig::from_args(&argv("--producers x")).is_err());
    }

    #[test]
    fn cache_frac_flag_parses_and_rejects_out_of_range() {
        assert_eq!(RunConfig::from_args(&[]).unwrap().cache_frac, 0.0);
        let c = RunConfig::from_args(&argv("--cache-frac 0.25")).unwrap();
        assert_eq!(c.cache_frac, 0.25);
        let c = RunConfig::from_args(&argv("--cache-frac 1.0")).unwrap();
        assert_eq!(c.cache_frac, 1.0);
        assert!(RunConfig::from_args(&argv("--cache-frac 1.5")).is_err());
        assert!(RunConfig::from_args(&argv("--cache-frac -0.1")).is_err());
        assert!(RunConfig::from_args(&argv("--cache-frac x")).is_err());
    }

    #[test]
    fn serve_flags_parse_and_reject_bad_values() {
        let c = RunConfig::from_args(&[]).unwrap();
        assert_eq!(c.rate, 1000.0);
        assert_eq!(c.requests, 64);
        assert_eq!(c.coalesce_window, 1000);
        assert_eq!(c.record_trace, None);
        assert_eq!(c.replay_trace, None);
        let c = RunConfig::from_args(&argv(
            "--rate 250.5 --requests 128 --coalesce-window 5000 --record-trace /tmp/t.bin",
        ))
        .unwrap();
        assert_eq!(c.rate, 250.5);
        assert_eq!(c.requests, 128);
        assert_eq!(c.coalesce_window, 5000);
        assert_eq!(c.record_trace, Some(PathBuf::from("/tmp/t.bin")));
        let c = RunConfig::from_args(&argv("--replay-trace /tmp/u.bin")).unwrap();
        assert_eq!(c.replay_trace, Some(PathBuf::from("/tmp/u.bin")));
        assert!(RunConfig::from_args(&argv("--rate 0")).is_err());
        assert!(RunConfig::from_args(&argv("--rate -5")).is_err());
        assert!(RunConfig::from_args(&argv("--requests 0")).is_err());
        assert!(RunConfig::from_args(&argv("--coalesce-window x")).is_err());
    }

    #[test]
    fn record_and_replay_trace_conflict() {
        let err = RunConfig::from_args(&argv(
            "--record-trace /tmp/t.bin --replay-trace /tmp/u.bin",
        ))
        .unwrap_err();
        assert!(err.to_string().contains("conflict"), "{err}");
    }

    #[test]
    fn fault_flags_parse_and_reject_bad_specs() {
        let c = RunConfig::from_args(&[]).unwrap();
        assert_eq!(c.fault_spec, None);
        assert_eq!(c.fault_seed, 0);
        assert!(c.fault_plan().unwrap().is_none(), "no spec => no plan");

        let c = RunConfig::from_args(&argv(
            "--fault-spec dispatch@0:3x2,lane~7 --fault-seed 99",
        ))
        .unwrap();
        assert_eq!(c.fault_spec.as_deref(), Some("dispatch@0:3x2,lane~7"));
        assert_eq!(c.fault_seed, 99);
        let plan = c.fault_plan().unwrap().expect("spec => plan");
        assert_eq!(plan.fires(crate::util::FaultSite::Dispatch, 0, 3), 2);

        // Seed without a spec is inert, not an error.
        let c = RunConfig::from_args(&argv("--fault-seed 7")).unwrap();
        assert!(c.fault_plan().unwrap().is_none());

        assert!(RunConfig::from_args(&argv("--fault-spec gpu@0:0")).is_err());
        assert!(RunConfig::from_args(&argv("--fault-spec dispatch@0")).is_err());
        assert!(RunConfig::from_args(&argv("--fault-seed x")).is_err());
    }

    #[test]
    fn max_queue_flag_parses_and_rejects_zero() {
        assert_eq!(RunConfig::from_args(&[]).unwrap().max_queue, None);
        let c = RunConfig::from_args(&argv("--max-queue 3")).unwrap();
        assert_eq!(c.max_queue, Some(3));
        assert!(RunConfig::from_args(&argv("--max-queue 0")).is_err());
        assert!(RunConfig::from_args(&argv("--max-queue x")).is_err());
    }

    #[test]
    fn refresh_at_flag_parses_ticks_paths_and_rejects_garbage() {
        assert!(RunConfig::from_args(&[]).unwrap().refresh_at.is_empty());
        let c = RunConfig::from_args(&argv("--refresh-at 2000")).unwrap();
        assert_eq!(c.refresh_at, vec![(2000, None)]);
        let c = RunConfig::from_args(&argv("--refresh-at 2000:/tmp/a.ckpt,4000:/tmp/b.ckpt"))
            .unwrap();
        assert_eq!(
            c.refresh_at,
            vec![
                (2000, Some(PathBuf::from("/tmp/a.ckpt"))),
                (4000, Some(PathBuf::from("/tmp/b.ckpt"))),
            ]
        );
        let c = RunConfig::from_args(&argv("--refresh-at 500:,1000:/x.ckpt")).unwrap();
        assert_eq!(c.refresh_at[0], (500, None));
        assert!(RunConfig::from_args(&argv("--refresh-at x")).is_err());
        assert!(RunConfig::from_args(&argv("--refresh-at 5,,7")).is_err());
        assert!(RunConfig::from_args(&argv("--refresh-at /tmp/a.ckpt")).is_err());
    }

    #[test]
    fn closed_loop_flag_parses_and_rejects_zero_and_replay() {
        assert_eq!(RunConfig::from_args(&[]).unwrap().closed_loop, None);
        let c = RunConfig::from_args(&argv("--closed-loop 8")).unwrap();
        assert_eq!(c.closed_loop, Some(8));
        assert!(RunConfig::from_args(&argv("--closed-loop 0")).is_err());
        assert!(RunConfig::from_args(&argv("--closed-loop x")).is_err());
        let err = RunConfig::from_args(&argv("--closed-loop 4 --replay-trace /tmp/t.bin"))
            .unwrap_err();
        assert!(err.to_string().contains("conflict"), "{err}");
    }

    #[test]
    fn probation_flag_parses_and_rejects_zero() {
        assert_eq!(RunConfig::from_args(&[]).unwrap().probation, DEFAULT_PROBATION);
        let c = RunConfig::from_args(&argv("--probation 5")).unwrap();
        assert_eq!(c.probation, 5);
        assert!(RunConfig::from_args(&argv("--probation 0")).is_err());
        assert!(RunConfig::from_args(&argv("--probation x")).is_err());
    }

    #[test]
    fn guard_and_audit_flags_parse() {
        let c = RunConfig::from_args(&[]).unwrap();
        assert!(!c.guard);
        assert_eq!(c.audit_every, 0);
        // Bare flag, with and without trailing flags to swallow.
        let c = RunConfig::from_args(&argv("--guard")).unwrap();
        assert!(c.guard);
        let c = RunConfig::from_args(&argv("--guard --epochs 3 --audit-every 4")).unwrap();
        assert!(c.guard);
        assert_eq!(c.train.epochs, 3);
        assert_eq!(c.audit_every, 4);
        // Explicit boolean values are consumed.
        let c = RunConfig::from_args(&argv("--guard 0 --epochs 2")).unwrap();
        assert!(!c.guard);
        assert_eq!(c.train.epochs, 2);
        let c = RunConfig::from_args(&argv("--guard true")).unwrap();
        assert!(c.guard);
        assert!(RunConfig::from_args(&argv("--audit-every 0")).is_err());
        assert!(RunConfig::from_args(&argv("--audit-every x")).is_err());
        // The integrity plane is sim-only and host-staged-only.
        assert!(RunConfig::from_args(&argv("--guard --backend pjrt")).is_err());
        assert!(RunConfig::from_args(&argv("--audit-every 2 --backend pjrt")).is_err());
        assert!(RunConfig::from_args(&argv("--guard --mode resident")).is_err());
    }

    #[test]
    fn corruption_sites_parse_in_fault_spec() {
        let c = RunConfig::from_args(&argv("--fault-spec flip!@0:2,nan!~5,wire!@1:0x2"))
            .unwrap();
        let plan = c.fault_plan().unwrap().expect("spec => plan");
        assert_eq!(plan.fires(crate::util::FaultSite::Flip, 0, 2), 1);
        assert_eq!(plan.fires(crate::util::FaultSite::Wire, 1, 0), 2);
        assert!(plan.has_integrity_site());
        assert!(RunConfig::from_args(&argv("--fault-spec flip@0:0")).is_err());
    }

    #[test]
    fn ckpt_flags_parse() {
        let c = RunConfig::from_args(&[]).unwrap();
        assert_eq!(c.load_ckpt, None);
        assert_eq!(c.save_ckpt, None);
        let c = RunConfig::from_args(&argv("--load-ckpt a.ckpt --save-ckpt b.ckpt")).unwrap();
        assert_eq!(c.load_ckpt, Some(PathBuf::from("a.ckpt")));
        assert_eq!(c.save_ckpt, Some(PathBuf::from("b.ckpt")));
    }

    #[test]
    fn replicas_flag_parses_and_rejects_zero() {
        assert_eq!(RunConfig::from_args(&[]).unwrap().replicas, None);
        let c = RunConfig::from_args(&argv("--replicas 4")).unwrap();
        assert_eq!(c.replicas, Some(4));
        let c = RunConfig::from_args(&argv("--replicas 1")).unwrap();
        assert_eq!(c.replicas, Some(1));
        assert!(RunConfig::from_args(&argv("--replicas 0")).is_err());
        assert!(RunConfig::from_args(&argv("--replicas x")).is_err());
    }
}
