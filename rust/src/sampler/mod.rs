//! Mini-batch heterogeneous neighbor sampling (workflow step ①, Fig. 2).
//!
//! Standard PyG-style layered sampling with *nested frontiers*: seeds (of
//! the target type) form the output frontier; for each GNN layer, walking
//! output-to-input, every relation samples up to `fanout` incoming edges
//! for each frontier vertex of its destination type, and the sources join
//! the frontier. Nesting (lower layers aggregate into every vertex known so
//! far) keeps one node-slot assignment valid across layers, which is what
//! lets the AOT modules use a single static `[NS]` slab per type.
//!
//! Static-shape discipline: per-type slots are capped at `ns`, per-relation
//! per-layer edges at `ep`; overflow is *dropped and counted* (the
//! `dropped_*` fields), mirroring the bucket-padding contract in DESIGN.md
//! §6. The caps come from the AOT profile, so the sampler can never emit a
//! batch the compiled modules cannot hold.
//!
//! **Zero-alloc hot path.** [`NeighborSampler::sample_into`] writes into a
//! caller-owned [`MiniBatch`] using a reusable [`SamplerScratch`]: the
//! epoch permutation is computed once per *epoch* (not per batch), the
//! per-type slot maps are generation-stamped dense arrays instead of
//! `HashMap`s, and every intermediate (`sample_indices` picks, the tagged
//! shuffle permutation, the pre-shuffle COO staging list) lives in pooled
//! buffers — so steady-state sampling performs no heap allocation while
//! producing **bit-identical** batches (same RNG fork discipline; pinned by
//! `scratch_reuse_is_bit_identical`). [`NeighborSampler::sample`] remains
//! as the one-shot convenience wrapper.

pub mod collect;

use std::sync::Arc;

use crate::graph::HeteroGraph;
use crate::util::Rng;

/// Fork stream of the per-epoch train-split shuffle — shared by the lazy
/// in-scratch path and [`epoch_perm`], so both derive identical bytes.
const EPOCH_PERM_STREAM: u64 = 0xE90C;

/// Fork stream of serve-path coalesced batches
/// ([`NeighborSampler::sample_request_into`]): keyed on the coalesced-batch
/// index alone, disjoint from the training `(epoch, batch)` streams, so a
/// trace replay expands identical neighborhoods no matter how the batch is
/// scheduled (DESIGN.md §8).
const SERVE_BATCH_STREAM: u64 = 0x5E11_EB47;

/// The epoch permutation of the train split: exactly the bytes
/// `sample_into` would derive lazily (`train_idx` shuffled by
/// `rng.fork(EPOCH_PERM_STREAM ^ epoch)`), computed once and shared via
/// `Arc` across all of an epoch's producers — replacing the per-producer
/// byte-identical shuffles (DESIGN.md §5; the slot maps stay per-producer,
/// the permutation need not). Install with
/// [`SamplerScratch::install_epoch_perm`].
pub fn epoch_perm(g: &HeteroGraph, rng: &Rng, epoch: u64) -> Arc<Vec<u32>> {
    let mut v = g.train_idx.clone();
    let mut r = rng.fork(EPOCH_PERM_STREAM ^ epoch);
    r.shuffle(&mut v);
    Arc::new(v)
}

/// Per-relation edges of one layer, in *slot* coordinates.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RelEdges {
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
}

impl RelEdges {
    pub fn len(&self) -> usize {
        self.src.len()
    }
    pub fn is_empty(&self) -> bool {
        self.src.is_empty()
    }
    fn clear(&mut self) {
        self.src.clear();
        self.dst.clear();
    }
    /// Held heap capacity in elements (allocation-growth witness).
    fn capacity_footprint(&self) -> usize {
        self.src.capacity() + self.dst.capacity()
    }
}

/// The shuffled, type-tagged edge list of one layer — the COO tensor the
/// semantic-graph-build stage selects from (paper §4.3: "edge indices are
/// stored in a 2xN tensor in coordinate format ... for all relations").
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TaggedEdges {
    pub rel: Vec<u32>,
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
}

impl TaggedEdges {
    pub fn len(&self) -> usize {
        self.rel.len()
    }
    pub fn is_empty(&self) -> bool {
        self.rel.is_empty()
    }
    fn clear(&mut self) {
        self.rel.clear();
        self.src.clear();
        self.dst.clear();
    }
    fn capacity_footprint(&self) -> usize {
        self.rel.capacity() + self.src.capacity() + self.dst.capacity()
    }
}

/// A sampled mini-batch. Reusable: [`NeighborSampler::sample_into`] clears
/// and refills an existing instance, retaining its buffer capacities.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MiniBatch {
    /// Seed vertices (type-local ids of the target type); slot i of the
    /// target type holds `seeds[i]`.
    pub seeds: Vec<u32>,
    /// Per type: slot -> type-local vertex id.
    pub slots: Vec<Vec<u32>>,
    /// Per layer: the tagged COO edge list (input to semantic-graph build).
    pub tagged: Vec<TaggedEdges>,
    /// Per layer, per relation: ground-truth per-relation edges (the
    /// sampler knows them; used as the selection oracle in tests — the
    /// trainer must derive them through `semantic::*`).
    pub oracle_edges: Vec<Vec<RelEdges>>,
    pub dropped_nodes: usize,
    pub dropped_edges: usize,
}

impl MiniBatch {
    /// Clear all contents (keeping capacities), size the nested structure
    /// for `n_types` / `layers` / `n_rel`, and reserve every buffer to its
    /// **static cap** (`batch_size`, `ns`, `ep` — the same profile bounds
    /// the sampler enforces). Reserving to the caps up front is what makes
    /// the steady state *deterministically* allocation-free: no later batch
    /// can exceed a high-water mark, because the caps are the high-water
    /// mark. All reserves are no-ops after the first use of a buffer set.
    /// `pub(crate)` so the producer pool can construct fully-reserved
    /// batches up front (a virgin buffer must never grow on first use).
    pub(crate) fn reset(&mut self, cfg: &SamplerCfg, n_types: usize, n_rel: usize) {
        let layers = cfg.layers;
        self.seeds.clear();
        self.seeds.reserve(cfg.batch_size);
        self.slots.resize_with(n_types, Vec::new);
        for s in &mut self.slots {
            s.clear();
            s.reserve(cfg.ns);
        }
        let layer_cap = n_rel * cfg.ep;
        self.tagged.resize_with(layers, TaggedEdges::default);
        for t in &mut self.tagged {
            t.clear();
            t.rel.reserve(layer_cap);
            t.src.reserve(layer_cap);
            t.dst.reserve(layer_cap);
        }
        self.oracle_edges.resize_with(layers, Vec::new);
        for layer in &mut self.oracle_edges {
            layer.resize_with(n_rel, RelEdges::default);
            for e in layer.iter_mut() {
                e.clear();
                e.src.reserve(cfg.ep);
                e.dst.reserve(cfg.ep);
            }
        }
        self.dropped_nodes = 0;
        self.dropped_edges = 0;
    }

    /// Total heap capacity held, in elements (not bytes): the
    /// allocation-growth witness behind the producer zero-alloc tests — a
    /// `produce` call that left this number unchanged performed no heap
    /// allocation in the mini-batch buffers.
    pub fn capacity_footprint(&self) -> usize {
        self.seeds.capacity()
            + self.slots.capacity()
            + self.slots.iter().map(|s| s.capacity()).sum::<usize>()
            + self.tagged.capacity()
            + self.tagged.iter().map(|t| t.capacity_footprint()).sum::<usize>()
            + self.oracle_edges.capacity()
            + self
                .oracle_edges
                .iter()
                .map(|l| l.capacity() + l.iter().map(|e| e.capacity_footprint()).sum::<usize>())
                .sum::<usize>()
    }
}

/// Sampler configuration: caps come from the AOT profile.
#[derive(Clone, Copy, Debug)]
pub struct SamplerCfg {
    pub batch_size: usize,
    /// Incoming-edge fanout per (vertex, relation), per layer.
    pub fanout: usize,
    pub layers: usize,
    /// Node-slot cap per type (profile NS).
    pub ns: usize,
    /// Edge cap per relation per layer (profile EP).
    pub ep: usize,
}

/// Reusable sampling state (one per producer worker). Holds everything
/// `sample_into` needs beyond the output `MiniBatch`:
///
/// * the **epoch permutation** of the train split, recomputed only when the
///   epoch changes (O(train) per epoch instead of per batch);
/// * **generation-stamped dense slot maps**: `slot_of[t][v]` is valid iff
///   `stamp[t][v]` equals the current generation, so "clearing" the map
///   between batches is a single counter bump — no `HashMap`, no rehashing,
///   no per-batch zeroing;
/// * pooled scratch for the fanout picks (`idx`), the tagged-shuffle
///   permutation (`perm`), the pre-shuffle COO staging list (`tag_tmp`) and
///   the per-layer frontier snapshot.
pub struct SamplerScratch {
    /// The epoch permutation — `Arc` so the feed-spawning paths can share
    /// one read-only copy across every producer of an epoch instead of
    /// each producer shuffling its own byte-identical vector. The lazy
    /// single-owner path refills it in place (`Arc::get_mut`), keeping the
    /// inline producers allocation-free at epoch boundaries too.
    order: Arc<Vec<u32>>,
    /// `(rng fork key, epoch)` the cached permutation was computed for —
    /// keyed on the generator too, so reusing one scratch across
    /// differently-seeded runs can never serve a stale permutation.
    order_key: Option<(u64, u64)>,
    slot_of: Vec<Vec<u32>>,
    stamp: Vec<Vec<u32>>,
    gen: u32,
    idx: Vec<usize>,
    perm: Vec<usize>,
    tag_tmp: TaggedEdges,
    frontier: Vec<usize>,
}

impl SamplerScratch {
    /// Scratch sized for `g`: the dense slot maps span every vertex, and
    /// the fanout-pick buffer is reserved to the graph's maximum in-degree
    /// (its only data-dependent bound), so steady-state sampling never
    /// grows it.
    pub fn new(g: &HeteroGraph) -> Self {
        let max_indeg = g
            .relations
            .iter()
            .flat_map(|r| r.indptr.windows(2).map(|w| (w[1] - w[0]) as usize))
            .max()
            .unwrap_or(0);
        SamplerScratch {
            order: Arc::new(Vec::with_capacity(g.train_idx.len())),
            order_key: None,
            slot_of: g.num_nodes.iter().map(|&n| vec![0u32; n]).collect(),
            stamp: g.num_nodes.iter().map(|&n| vec![0u32; n]).collect(),
            gen: 0,
            idx: Vec::with_capacity(max_indeg),
            perm: Vec::new(),
            tag_tmp: TaggedEdges::default(),
            frontier: Vec::with_capacity(g.n_types()),
        }
    }

    /// Install a precomputed shared epoch permutation (one [`epoch_perm`]
    /// `Arc` handed to every producer of an epoch — the slot maps stay
    /// per-producer, the permutation need not; DESIGN.md §5). The cache
    /// key matches the lazy path's, so a scratch driven with a different
    /// `(rng, epoch)` afterwards reshuffles as usual.
    pub fn install_epoch_perm(&mut self, perm: Arc<Vec<u32>>, rng: &Rng, epoch: u64) {
        self.order = perm;
        self.order_key = Some((rng.fork_key(), epoch));
    }

    /// Reserve the cfg-dependent pooled buffers (shuffle permutation, COO
    /// staging list) to the per-layer edge cap, clearing any stale
    /// contents first. Idempotent; called once when a producer adopts the
    /// scratch, so even a scratch that sat idle an epoch never grows on
    /// its first use.
    pub fn reserve_for(&mut self, n_rel: usize, ep: usize) {
        let cap = n_rel * ep;
        self.perm.clear();
        self.perm.reserve(cap);
        self.tag_tmp.clear();
        self.tag_tmp.rel.reserve(cap);
        self.tag_tmp.src.reserve(cap);
        self.tag_tmp.dst.reserve(cap);
    }

    /// Total heap capacity held, in elements; see
    /// [`MiniBatch::capacity_footprint`].
    pub fn capacity_footprint(&self) -> usize {
        self.order.capacity()
            + self.slot_of.iter().map(|s| s.capacity()).sum::<usize>()
            + self.stamp.iter().map(|s| s.capacity()).sum::<usize>()
            + self.idx.capacity()
            + self.perm.capacity()
            + self.tag_tmp.capacity_footprint()
            + self.frontier.capacity()
    }
}

/// Assign `v` (type `t`) a slot, reusing an existing one if this batch
/// already placed it. Generation-stamped: a stale `slot_of` entry from an
/// earlier batch is invisible because its stamp differs.
#[allow(clippy::too_many_arguments)]
fn assign_slot(
    t: usize,
    v: u32,
    ns: usize,
    gen: u32,
    slots: &mut [Vec<u32>],
    slot_of: &mut [Vec<u32>],
    stamp: &mut [Vec<u32>],
    dropped: &mut usize,
) -> Option<u32> {
    let vi = v as usize;
    if stamp[t][vi] == gen {
        return Some(slot_of[t][vi]);
    }
    if slots[t].len() >= ns {
        *dropped += 1;
        return None;
    }
    let s = slots[t].len() as u32;
    slots[t].push(v);
    slot_of[t][vi] = s;
    stamp[t][vi] = gen;
    Some(s)
}

/// Fill `idx` with `0..n` and partially Fisher-Yates the first `k` entries
/// into a uniform k-subset (read `&idx[..k]`). Identical RNG consumption to
/// the historical allocate-per-call version: zero draws when `k == n`,
/// otherwise exactly `k` `below` calls.
fn sample_indices_into(n: usize, k: usize, rng: &mut Rng, idx: &mut Vec<usize>) {
    debug_assert!(k <= n);
    idx.clear();
    idx.extend(0..n);
    if k == n {
        return;
    }
    for i in 0..k {
        let j = i + rng.below(n - i);
        idx.swap(i, j);
    }
}

pub struct NeighborSampler<'g> {
    pub graph: &'g HeteroGraph,
    pub cfg: SamplerCfg,
}

impl<'g> NeighborSampler<'g> {
    pub fn new(graph: &'g HeteroGraph, cfg: SamplerCfg) -> Self {
        assert!(cfg.batch_size <= cfg.ns, "batch larger than node slab");
        NeighborSampler { graph, cfg }
    }

    /// Number of batches per epoch over the train split.
    pub fn batches_per_epoch(&self) -> usize {
        self.graph.train_idx.len().div_ceil(self.cfg.batch_size)
    }

    /// Sample the `batch_idx`-th mini-batch of an epoch. Deterministic in
    /// (`rng` seed, batch_idx) so baseline and HiFuse runs see identical
    /// batches. One-shot convenience over [`NeighborSampler::sample_into`]
    /// (allocates a fresh scratch; the training paths keep one per
    /// producer).
    pub fn sample(&self, rng: &Rng, epoch: u64, batch_idx: usize) -> MiniBatch {
        let mut scratch = SamplerScratch::new(self.graph);
        let mut mb = MiniBatch::default();
        self.sample_into(rng, epoch, batch_idx, &mut scratch, &mut mb);
        mb
    }

    /// Sample into a caller-owned batch, reusing `scratch`. Bit-identical
    /// to [`NeighborSampler::sample`] for any reuse pattern: all randomness
    /// is forked from `rng` per (epoch, batch) exactly as before, and the
    /// cached epoch permutation is a pure function of (`rng`, epoch).
    pub fn sample_into(
        &self,
        rng: &Rng,
        epoch: u64,
        batch_idx: usize,
        scratch: &mut SamplerScratch,
        out: &mut MiniBatch,
    ) {
        let g = self.graph;
        let cfg = self.cfg;
        debug_assert_eq!(scratch.slot_of.len(), g.n_types(), "scratch built for another graph");
        out.reset(&cfg, g.n_types(), g.n_relations());

        // Epoch-shuffled train split: derived from (base rng, epoch) ONLY,
        // so every batch of an epoch agrees on the permutation — computed
        // once per (rng, epoch) and cached (or installed pre-shared via
        // `install_epoch_perm`). Keying on the rng's fork key keeps scratch
        // reuse safe across differently-seeded runs. A uniquely-owned Arc
        // is refilled in place (no allocation); one still shared from a
        // previous epoch's install is replaced.
        if scratch.order_key != Some((rng.fork_key(), epoch)) {
            if Arc::get_mut(&mut scratch.order).is_none() {
                scratch.order = Arc::new(Vec::with_capacity(g.train_idx.len()));
            }
            let v = Arc::get_mut(&mut scratch.order).expect("epoch permutation uniquely owned");
            v.clear();
            v.extend_from_slice(&g.train_idx);
            let mut epoch_rng = rng.fork(EPOCH_PERM_STREAM ^ epoch);
            epoch_rng.shuffle(v);
            scratch.order_key = Some((rng.fork_key(), epoch));
        }
        // Everything below is per-(epoch, batch) randomness.
        let rng = rng.fork(epoch.wrapping_mul(1_000_003) + batch_idx as u64 + 1);
        let start = batch_idx * cfg.batch_size;
        // Wrap the tail batch to keep the batch size static; modular
        // indexing into the cached permutation (a cycled iterator would
        // pay an O(start) skip walk per batch).
        if !scratch.order.is_empty() {
            let len = scratch.order.len();
            out.seeds.extend((0..cfg.batch_size).map(|i| scratch.order[(start + i) % len]));
        }
        self.sample_core(rng, scratch, out);
    }

    /// Sample a serving batch from an **explicit seed set** (the
    /// coalescer's merge of pending request seeds, DESIGN.md §8). Seeds are
    /// installed verbatim — first-seen order, so distinct seeds occupy the
    /// leading target-type slots exactly as in training batches (the
    /// seed-mask contract in [`collect`]) — and the layered expansion draws
    /// all randomness from a stream forked purely on `batch_idx` (the
    /// coalesced-batch index). Deterministic in (`rng` seed, `batch_idx`,
    /// `seeds`) and independent of worker/replica scheduling: the serve
    /// replay contract, pinned by `tests/serve_parity.rs`.
    pub fn sample_request_into(
        &self,
        rng: &Rng,
        batch_idx: u64,
        seeds: &[u32],
        scratch: &mut SamplerScratch,
        out: &mut MiniBatch,
    ) {
        let g = self.graph;
        let cfg = self.cfg;
        assert!(seeds.len() <= cfg.batch_size, "coalesced batch exceeds batch_size");
        debug_assert_eq!(scratch.slot_of.len(), g.n_types(), "scratch built for another graph");
        out.reset(&cfg, g.n_types(), g.n_relations());
        out.seeds.extend_from_slice(seeds);
        let rng = rng.fork(SERVE_BATCH_STREAM ^ batch_idx);
        self.sample_core(rng, scratch, out);
    }

    /// The seed-independent sampling core shared by the training and serve
    /// entries: slot assignment, nested-frontier layer expansion, and the
    /// shuffled tagged COO build, driven entirely by the already-forked
    /// per-batch `rng`. `out` must be reset and `out.seeds` filled (at most
    /// `batch_size` entries); everything else is produced here.
    fn sample_core(&self, rng: Rng, scratch: &mut SamplerScratch, out: &mut MiniBatch) {
        let g = self.graph;
        let cfg = self.cfg;
        let SamplerScratch { slot_of, stamp, gen, idx, perm, tag_tmp, frontier, .. } = scratch;
        let MiniBatch { seeds, slots, tagged, oracle_edges, dropped_nodes, dropped_edges } = out;

        // New slot-map generation; on (unlikely) wrap, reset the stamps so
        // generation 1 can never collide with a stale entry.
        if *gen == u32::MAX {
            for s in stamp.iter_mut() {
                s.fill(0);
            }
            *gen = 0;
        }
        *gen += 1;
        let gen = *gen;

        for (i, &v) in seeds.iter().enumerate() {
            let s =
                assign_slot(g.target_type, v, cfg.ns, gen, slots, slot_of, stamp, dropped_nodes)
                    .expect("batch_size <= ns");
            debug_assert!(s as usize <= i);
        }

        // Sample top layer first (aggregates into seeds), then lower layers
        // (aggregate into everything sampled so far). Iteration `li` fills
        // oracle layer `layers - 1 - li`, so storage stays input-layer-first
        // without the historical push-then-reverse.
        for li in 0..cfg.layers {
            let layer = cfg.layers - 1 - li;
            // Snapshot frontier sizes: vertices present before this layer.
            frontier.clear();
            frontier.extend(slots.iter().map(|s| s.len()));
            for (ri, rel) in g.relations.iter().enumerate() {
                let dt = rel.dst_type;
                let mut srng = rng.fork((ri as u64) << 8);
                for dslot in 0..frontier[dt] {
                    let dv = slots[dt][dslot] as usize;
                    let neigh = rel.in_neighbors(dv);
                    if neigh.is_empty() {
                        continue;
                    }
                    // Sample up to fanout without replacement (index set).
                    let k = cfg.fanout.min(neigh.len());
                    sample_indices_into(neigh.len(), k, &mut srng, idx);
                    for &p in &idx[..k] {
                        if oracle_edges[layer][ri].len() >= cfg.ep {
                            *dropped_edges += 1;
                            continue;
                        }
                        let sv = neigh[p];
                        match assign_slot(
                            rel.src_type,
                            sv,
                            cfg.ns,
                            gen,
                            slots,
                            slot_of,
                            stamp,
                            dropped_nodes,
                        ) {
                            Some(ss) => {
                                let e = &mut oracle_edges[layer][ri];
                                e.src.push(ss);
                                e.dst.push(dslot as u32);
                            }
                            None => *dropped_edges += 1,
                        }
                    }
                }
            }
        }

        // Build the shuffled tagged COO list per layer: stage the edges in
        // discovery order, then gather through a shuffled permutation —
        // both through pooled buffers reserved to the per-layer edge cap.
        let layer_cap = g.n_relations() * cfg.ep;
        perm.clear();
        perm.reserve(layer_cap);
        tag_tmp.clear();
        tag_tmp.rel.reserve(layer_cap);
        tag_tmp.src.reserve(layer_cap);
        tag_tmp.dst.reserve(layer_cap);
        for l in 0..cfg.layers {
            tag_tmp.clear();
            for (ri, e) in oracle_edges[l].iter().enumerate() {
                for i in 0..e.len() {
                    tag_tmp.rel.push(ri as u32);
                    tag_tmp.src.push(e.src[i]);
                    tag_tmp.dst.push(e.dst[i]);
                }
            }
            // Shuffle to a realistic mixed order (the sampler on CPU
            // emits edges in discovery order; PyG's COO is not grouped).
            perm.clear();
            perm.extend(0..tag_tmp.len());
            rng.fork(0xBEEF + l as u64).shuffle(perm);
            let t = &mut tagged[l];
            t.clear();
            t.rel.extend(perm.iter().map(|&i| tag_tmp.rel[i]));
            t.src.extend(perm.iter().map(|&i| tag_tmp.src[i]));
            t.dst.extend(perm.iter().map(|&i| tag_tmp.dst[i]));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny_graph;

    fn cfg() -> SamplerCfg {
        SamplerCfg { batch_size: 8, fanout: 3, layers: 2, ns: 32, ep: 16 }
    }

    #[test]
    fn batch_is_deterministic() {
        let g = tiny_graph(1);
        let s = NeighborSampler::new(&g, cfg());
        let rng = Rng::new(42);
        let a = s.sample(&rng, 0, 0);
        let b = s.sample(&rng, 0, 0);
        assert_eq!(a.seeds, b.seeds);
        assert_eq!(a.slots, b.slots);
        for (x, y) in a.tagged.iter().zip(&b.tagged) {
            assert_eq!(x.rel, y.rel);
            assert_eq!(x.src, y.src);
            assert_eq!(x.dst, y.dst);
        }
    }

    #[test]
    fn different_batches_differ() {
        let g = tiny_graph(1);
        let s = NeighborSampler::new(&g, cfg());
        let rng = Rng::new(42);
        let a = s.sample(&rng, 0, 0);
        let b = s.sample(&rng, 0, 1);
        assert_ne!(a.seeds, b.seeds);
    }

    #[test]
    fn caps_respected_and_slots_unique() {
        let g = tiny_graph(2);
        let s = NeighborSampler::new(&g, cfg());
        let mb = s.sample(&Rng::new(7), 0, 0);
        for (t, sl) in mb.slots.iter().enumerate() {
            assert!(sl.len() <= 32, "type {t} exceeds ns");
            let mut u = sl.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), sl.len(), "duplicate slot for type {t}");
            for &v in sl {
                assert!((v as usize) < g.num_nodes[t]);
            }
        }
        for layer in &mb.oracle_edges {
            for e in layer {
                assert!(e.len() <= 16, "relation exceeds ep");
            }
        }
    }

    #[test]
    fn sampled_edges_exist_in_graph() {
        let g = tiny_graph(3);
        let s = NeighborSampler::new(&g, cfg());
        let mb = s.sample(&Rng::new(9), 0, 0);
        for layer in &mb.oracle_edges {
            for (ri, e) in layer.iter().enumerate() {
                let rel = &g.relations[ri];
                for i in 0..e.len() {
                    let sv = mb.slots[rel.src_type][e.src[i] as usize];
                    let dv = mb.slots[rel.dst_type][e.dst[i] as usize];
                    assert!(
                        rel.in_neighbors(dv as usize).contains(&sv),
                        "edge ({sv}->{dv}) of rel {ri} not in graph"
                    );
                }
            }
        }
    }

    #[test]
    fn tagged_list_is_permutation_of_oracle() {
        let g = tiny_graph(4);
        let s = NeighborSampler::new(&g, cfg());
        let mb = s.sample(&Rng::new(11), 0, 0);
        for (l, t) in mb.tagged.iter().enumerate() {
            let total: usize = mb.oracle_edges[l].iter().map(|e| e.len()).sum();
            assert_eq!(t.len(), total);
            // Multiset equality by sorting triples.
            let mut a: Vec<(u32, u32, u32)> =
                (0..t.len()).map(|i| (t.rel[i], t.src[i], t.dst[i])).collect();
            let mut b = Vec::new();
            for (ri, e) in mb.oracle_edges[l].iter().enumerate() {
                for i in 0..e.len() {
                    b.push((ri as u32, e.src[i], e.dst[i]));
                }
            }
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn seeds_occupy_leading_target_slots() {
        let g = tiny_graph(5);
        let s = NeighborSampler::new(&g, cfg());
        let mb = s.sample(&Rng::new(13), 0, 0);
        let tt = g.target_type;
        // Each distinct seed appears in the leading slots, in first-seen order.
        let mut expect = Vec::new();
        for &v in &mb.seeds {
            if !expect.contains(&v) {
                expect.push(v);
            }
        }
        assert_eq!(&mb.slots[tt][..expect.len()], &expect[..]);
    }

    #[test]
    fn epoch_reshuffles_seed_order() {
        let g = tiny_graph(6);
        let s = NeighborSampler::new(&g, cfg());
        let rng = Rng::new(21);
        let a = s.sample(&rng, 0, 0);
        let b = s.sample(&rng, 1, 0);
        assert_ne!(a.seeds, b.seeds, "epoch shuffle had no effect");
    }

    /// The zero-alloc path is bit-identical to the one-shot path for any
    /// reuse pattern: one scratch + one MiniBatch driven across a grid of
    /// (epoch, batch) — including epoch changes, which exercise the cached
    /// permutation — always reproduces a fresh `sample` exactly.
    #[test]
    fn scratch_reuse_is_bit_identical() {
        let g = tiny_graph(1);
        let s = NeighborSampler::new(&g, cfg());
        let rng = Rng::new(42);
        let mut scratch = SamplerScratch::new(&g);
        let mut mb = MiniBatch::default();
        for epoch in 0..3u64 {
            for b in 0..s.batches_per_epoch() {
                s.sample_into(&rng, epoch, b, &mut scratch, &mut mb);
                let fresh = s.sample(&rng, epoch, b);
                assert_eq!(mb, fresh, "epoch {epoch} batch {b} diverged under reuse");
            }
        }
        // Revisiting an earlier epoch (replica lanes replay schedules out of
        // lockstep with each other) must also agree.
        s.sample_into(&rng, 0, 1, &mut scratch, &mut mb);
        assert_eq!(mb, s.sample(&rng, 0, 1));
    }

    /// A pre-shared `epoch_perm` Arc installed into several scratches is
    /// byte-identical to each producer's own lazy shuffle — the identity
    /// the multi-producer feed relies on when it shares one permutation
    /// across workers — and a later epoch (or rng) correctly invalidates
    /// the install.
    #[test]
    fn installed_shared_perm_matches_lazy_shuffle() {
        let g = tiny_graph(1);
        let s = NeighborSampler::new(&g, cfg());
        let rng = Rng::new(42);
        let perm = epoch_perm(&g, &rng, 1);
        let mut shared_a = SamplerScratch::new(&g);
        let mut shared_b = SamplerScratch::new(&g);
        shared_a.install_epoch_perm(perm.clone(), &rng, 1);
        shared_b.install_epoch_perm(perm, &rng, 1);
        let mut lazy = SamplerScratch::new(&g);
        let (mut ma, mut mb, mut ml) =
            (MiniBatch::default(), MiniBatch::default(), MiniBatch::default());
        for b in 0..s.batches_per_epoch() {
            s.sample_into(&rng, 1, b, &mut shared_a, &mut ma);
            s.sample_into(&rng, 1, b, &mut shared_b, &mut mb);
            s.sample_into(&rng, 1, b, &mut lazy, &mut ml);
            assert_eq!(ma, ml, "shared perm diverged from lazy at batch {b}");
            assert_eq!(mb, ml, "second sharer diverged at batch {b}");
        }
        // Moving on to the next epoch reshuffles despite the install.
        s.sample_into(&rng, 2, 0, &mut shared_a, &mut ma);
        assert_eq!(ma, s.sample(&rng, 2, 0), "stale shared perm served for epoch 2");
    }

    /// The permutation cache is keyed on the generator, not just the
    /// epoch: driving one scratch with a *different* rng must reshuffle,
    /// never serve the previous run's permutation.
    #[test]
    fn scratch_reuse_across_different_rngs_is_safe() {
        let g = tiny_graph(1);
        let s = NeighborSampler::new(&g, cfg());
        let mut scratch = SamplerScratch::new(&g);
        let mut mb = MiniBatch::default();
        s.sample_into(&Rng::new(1), 0, 0, &mut scratch, &mut mb);
        let b = Rng::new(2);
        s.sample_into(&b, 0, 0, &mut scratch, &mut mb);
        assert_eq!(mb, s.sample(&b, 0, 0), "stale epoch permutation served across rngs");
    }

    /// Serve-path request sampling is a pure function of
    /// (rng seed, coalesced-batch index, seed set): two fresh scratches
    /// produce bitwise-identical batches, and the installed seeds survive
    /// verbatim.
    #[test]
    fn request_sampling_is_deterministic_and_seed_driven() {
        let g = tiny_graph(1);
        let s = NeighborSampler::new(&g, cfg());
        let rng = Rng::new(42);
        let seeds: Vec<u32> = g.train_idx.iter().take(5).copied().collect();
        let mut sc1 = SamplerScratch::new(&g);
        let mut sc2 = SamplerScratch::new(&g);
        let (mut a, mut b) = (MiniBatch::default(), MiniBatch::default());
        s.sample_request_into(&rng, 3, &seeds, &mut sc1, &mut a);
        s.sample_request_into(&rng, 3, &seeds, &mut sc2, &mut b);
        assert_eq!(a, b, "request batch not deterministic");
        assert_eq!(a.seeds, seeds, "installed seeds were altered");
        // Scratch reuse across interleaved training batches stays safe:
        // the serve entry never touches the epoch-permutation cache.
        s.sample_into(&rng, 0, 0, &mut sc1, &mut b);
        s.sample_request_into(&rng, 3, &seeds, &mut sc1, &mut b);
        assert_eq!(a, b, "request batch diverged after scratch reuse");
    }

    /// Explicit (possibly duplicated) request seeds land in the leading
    /// target-type slots in first-seen order — the same contract training
    /// batches satisfy, which the seed-mask build in `collect` depends on.
    #[test]
    fn request_seeds_occupy_leading_target_slots() {
        let g = tiny_graph(5);
        let s = NeighborSampler::new(&g, cfg());
        let (v0, v1) = (g.train_idx[0], g.train_idx[1]);
        let seeds = vec![v0, v1, v0]; // duplicate seed across two requests
        let mut sc = SamplerScratch::new(&g);
        let mut mb = MiniBatch::default();
        s.sample_request_into(&Rng::new(13), 0, &seeds, &mut sc, &mut mb);
        assert_eq!(mb.seeds, seeds);
        assert_eq!(&mb.slots[g.target_type][..2], &[v0, v1]);
    }

    /// Request sampling keeps the zero-alloc contract: after a warm-up
    /// call, repeated coalesced batches grow no scratch or batch buffer.
    #[test]
    fn request_sampling_footprint_is_flat_after_warmup() {
        let g = tiny_graph(2);
        let s = NeighborSampler::new(&g, cfg());
        let rng = Rng::new(7);
        let seeds: Vec<u32> = g.train_idx.iter().take(8).copied().collect();
        let mut sc = SamplerScratch::new(&g);
        let mut mb = MiniBatch::default();
        s.sample_request_into(&rng, 0, &seeds, &mut sc, &mut mb);
        let warm = sc.capacity_footprint() + mb.capacity_footprint();
        for b in 1..20u64 {
            let take = 1 + (b as usize % seeds.len());
            s.sample_request_into(&rng, b, &seeds[..take], &mut sc, &mut mb);
            let now = sc.capacity_footprint() + mb.capacity_footprint();
            assert_eq!(now, warm, "request batch {b} grew a buffer");
        }
    }

    /// After one warm epoch, further sampling grows no buffer: the scratch
    /// and batch capacity footprints are flat — the sampler half of the
    /// producer zero-alloc contract.
    #[test]
    fn scratch_footprint_reaches_steady_state() {
        let g = tiny_graph(2);
        let s = NeighborSampler::new(&g, cfg());
        let rng = Rng::new(7);
        let mut scratch = SamplerScratch::new(&g);
        let mut mb = MiniBatch::default();
        for b in 0..s.batches_per_epoch() {
            s.sample_into(&rng, 0, b, &mut scratch, &mut mb);
        }
        let warm = scratch.capacity_footprint() + mb.capacity_footprint();
        for epoch in 1..3u64 {
            for b in 0..s.batches_per_epoch() {
                s.sample_into(&rng, epoch, b, &mut scratch, &mut mb);
                let now = scratch.capacity_footprint() + mb.capacity_footprint();
                assert_eq!(now, warm, "epoch {epoch} batch {b} grew a buffer");
            }
        }
    }
}
