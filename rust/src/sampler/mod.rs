//! Mini-batch heterogeneous neighbor sampling (workflow step ①, Fig. 2).
//!
//! Standard PyG-style layered sampling with *nested frontiers*: seeds (of
//! the target type) form the output frontier; for each GNN layer, walking
//! output-to-input, every relation samples up to `fanout` incoming edges
//! for each frontier vertex of its destination type, and the sources join
//! the frontier. Nesting (lower layers aggregate into every vertex known so
//! far) keeps one node-slot assignment valid across layers, which is what
//! lets the AOT modules use a single static `[NS]` slab per type.
//!
//! Static-shape discipline: per-type slots are capped at `ns`, per-relation
//! per-layer edges at `ep`; overflow is *dropped and counted* (the
//! `dropped_*` fields), mirroring the bucket-padding contract in DESIGN.md
//! §6. The caps come from the AOT profile, so the sampler can never emit a
//! batch the compiled modules cannot hold.

pub mod collect;

use crate::graph::HeteroGraph;
use crate::util::Rng;

/// Per-relation edges of one layer, in *slot* coordinates.
#[derive(Clone, Debug, Default)]
pub struct RelEdges {
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
}

impl RelEdges {
    pub fn len(&self) -> usize {
        self.src.len()
    }
    pub fn is_empty(&self) -> bool {
        self.src.is_empty()
    }
}

/// The shuffled, type-tagged edge list of one layer — the COO tensor the
/// semantic-graph-build stage selects from (paper §4.3: "edge indices are
/// stored in a 2xN tensor in coordinate format ... for all relations").
#[derive(Clone, Debug, Default)]
pub struct TaggedEdges {
    pub rel: Vec<u32>,
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
}

impl TaggedEdges {
    pub fn len(&self) -> usize {
        self.rel.len()
    }
    pub fn is_empty(&self) -> bool {
        self.rel.is_empty()
    }
}

/// A sampled mini-batch.
pub struct MiniBatch {
    /// Seed vertices (type-local ids of the target type); slot i of the
    /// target type holds `seeds[i]`.
    pub seeds: Vec<u32>,
    /// Per type: slot -> type-local vertex id.
    pub slots: Vec<Vec<u32>>,
    /// Per layer: the tagged COO edge list (input to semantic-graph build).
    pub tagged: Vec<TaggedEdges>,
    /// Per layer, per relation: ground-truth per-relation edges (the
    /// sampler knows them; used as the selection oracle in tests — the
    /// trainer must derive them through `semantic::*`).
    pub oracle_edges: Vec<Vec<RelEdges>>,
    pub dropped_nodes: usize,
    pub dropped_edges: usize,
}

/// Sampler configuration: caps come from the AOT profile.
#[derive(Clone, Copy, Debug)]
pub struct SamplerCfg {
    pub batch_size: usize,
    /// Incoming-edge fanout per (vertex, relation), per layer.
    pub fanout: usize,
    pub layers: usize,
    /// Node-slot cap per type (profile NS).
    pub ns: usize,
    /// Edge cap per relation per layer (profile EP).
    pub ep: usize,
}

pub struct NeighborSampler<'g> {
    pub graph: &'g HeteroGraph,
    pub cfg: SamplerCfg,
}

impl<'g> NeighborSampler<'g> {
    pub fn new(graph: &'g HeteroGraph, cfg: SamplerCfg) -> Self {
        assert!(cfg.batch_size <= cfg.ns, "batch larger than node slab");
        NeighborSampler { graph, cfg }
    }

    /// Number of batches per epoch over the train split.
    pub fn batches_per_epoch(&self) -> usize {
        self.graph.train_idx.len().div_ceil(self.cfg.batch_size)
    }

    /// Sample the `batch_idx`-th mini-batch of an epoch. Deterministic in
    /// (`rng` seed, batch_idx) so baseline and HiFuse runs see identical
    /// batches.
    pub fn sample(&self, rng: &Rng, epoch: u64, batch_idx: usize) -> MiniBatch {
        let g = self.graph;
        let cfg = self.cfg;
        // Epoch-shuffled train split: derived from (base rng, epoch) ONLY,
        // so every batch of an epoch agrees on the permutation.
        let mut order: Vec<u32> = g.train_idx.clone();
        let mut epoch_rng = rng.fork(0xE90C ^ epoch);
        epoch_rng.shuffle(&mut order);
        // Everything below is per-(epoch, batch) randomness.
        let rng = rng.fork(epoch.wrapping_mul(1_000_003) + batch_idx as u64 + 1);
        let start = batch_idx * cfg.batch_size;
        let seeds: Vec<u32> = order
            .iter()
            .copied()
            .cycle() // wrap the tail batch to keep batch size static
            .skip(start)
            .take(cfg.batch_size)
            .collect();

        // Slot maps: per type, vertex -> slot. HashMap per type.
        let mut slots: Vec<Vec<u32>> = vec![Vec::new(); g.n_types()];
        let mut slot_of: Vec<std::collections::HashMap<u32, u32>> =
            vec![std::collections::HashMap::new(); g.n_types()];
        let mut dropped_nodes = 0usize;
        let assign = |t: usize,
                          v: u32,
                          slots: &mut Vec<Vec<u32>>,
                          slot_of: &mut Vec<std::collections::HashMap<u32, u32>>,
                          dropped: &mut usize|
         -> Option<u32> {
            if let Some(&s) = slot_of[t].get(&v) {
                return Some(s);
            }
            if slots[t].len() >= cfg.ns {
                *dropped += 1;
                return None;
            }
            let s = slots[t].len() as u32;
            slots[t].push(v);
            slot_of[t].insert(v, s);
            Some(s)
        };

        for (i, &v) in seeds.iter().enumerate() {
            let s = assign(g.target_type, v, &mut slots, &mut slot_of, &mut dropped_nodes)
                .expect("batch_size <= ns");
            debug_assert!(s as usize <= i);
        }

        let mut dropped_edges = 0usize;
        let mut layers_rel: Vec<Vec<RelEdges>> = Vec::with_capacity(cfg.layers);
        // Sample top layer first (aggregates into seeds), then lower layers
        // (aggregate into everything sampled so far).
        for _layer in (0..cfg.layers).rev() {
            // Snapshot frontier sizes: vertices present before this layer.
            let frontier: Vec<usize> = slots.iter().map(|s| s.len()).collect();
            let mut rel_edges: Vec<RelEdges> = vec![RelEdges::default(); g.n_relations()];
            for (ri, rel) in g.relations.iter().enumerate() {
                let dt = rel.dst_type;
                let mut srng = rng.fork((ri as u64) << 8);
                for dslot in 0..frontier[dt] {
                    let dv = slots[dt][dslot] as usize;
                    let neigh = rel.in_neighbors(dv);
                    if neigh.is_empty() {
                        continue;
                    }
                    // Sample up to fanout without replacement (index set).
                    let k = cfg.fanout.min(neigh.len());
                    let picks = sample_indices(neigh.len(), k, &mut srng);
                    for p in picks {
                        if rel_edges[ri].len() >= cfg.ep {
                            dropped_edges += 1;
                            continue;
                        }
                        let sv = neigh[p];
                        match assign(rel.src_type, sv, &mut slots, &mut slot_of, &mut dropped_nodes)
                        {
                            Some(ss) => {
                                rel_edges[ri].src.push(ss);
                                rel_edges[ri].dst.push(dslot as u32);
                            }
                            None => dropped_edges += 1,
                        }
                    }
                }
            }
            layers_rel.push(rel_edges);
        }
        // We sampled top-down; store input-layer-first (layer 0 first).
        layers_rel.reverse();

        // Build the shuffled tagged COO list per layer.
        let tagged = layers_rel
            .iter()
            .enumerate()
            .map(|(l, rels)| {
                let total: usize = rels.iter().map(|e| e.len()).sum();
                let mut t = TaggedEdges {
                    rel: Vec::with_capacity(total),
                    src: Vec::with_capacity(total),
                    dst: Vec::with_capacity(total),
                };
                for (ri, e) in rels.iter().enumerate() {
                    for i in 0..e.len() {
                        t.rel.push(ri as u32);
                        t.src.push(e.src[i]);
                        t.dst.push(e.dst[i]);
                    }
                }
                // Shuffle to a realistic mixed order (the sampler on CPU
                // emits edges in discovery order; PyG's COO is not grouped).
                let mut perm: Vec<usize> = (0..total).collect();
                rng.fork(0xBEEF + l as u64).shuffle(&mut perm);
                TaggedEdges {
                    rel: perm.iter().map(|&i| t.rel[i]).collect(),
                    src: perm.iter().map(|&i| t.src[i]).collect(),
                    dst: perm.iter().map(|&i| t.dst[i]).collect(),
                }
            })
            .collect();

        MiniBatch { seeds, slots, tagged, oracle_edges: layers_rel, dropped_nodes, dropped_edges }
    }
}

/// k distinct indices from [0,n) (partial Fisher-Yates over a scratch vec —
/// n is a vertex in-degree, small).
fn sample_indices(n: usize, k: usize, rng: &mut Rng) -> Vec<usize> {
    debug_assert!(k <= n);
    if k == n {
        return (0..n).collect();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    for i in 0..k {
        let j = i + rng.below(n - i);
        idx.swap(i, j);
    }
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny_graph;

    fn cfg() -> SamplerCfg {
        SamplerCfg { batch_size: 8, fanout: 3, layers: 2, ns: 32, ep: 16 }
    }

    #[test]
    fn batch_is_deterministic() {
        let g = tiny_graph(1);
        let s = NeighborSampler::new(&g, cfg());
        let rng = Rng::new(42);
        let a = s.sample(&rng, 0, 0);
        let b = s.sample(&rng, 0, 0);
        assert_eq!(a.seeds, b.seeds);
        assert_eq!(a.slots, b.slots);
        for (x, y) in a.tagged.iter().zip(&b.tagged) {
            assert_eq!(x.rel, y.rel);
            assert_eq!(x.src, y.src);
            assert_eq!(x.dst, y.dst);
        }
    }

    #[test]
    fn different_batches_differ() {
        let g = tiny_graph(1);
        let s = NeighborSampler::new(&g, cfg());
        let rng = Rng::new(42);
        let a = s.sample(&rng, 0, 0);
        let b = s.sample(&rng, 0, 1);
        assert_ne!(a.seeds, b.seeds);
    }

    #[test]
    fn caps_respected_and_slots_unique() {
        let g = tiny_graph(2);
        let s = NeighborSampler::new(&g, cfg());
        let mb = s.sample(&Rng::new(7), 0, 0);
        for (t, sl) in mb.slots.iter().enumerate() {
            assert!(sl.len() <= 32, "type {t} exceeds ns");
            let mut u = sl.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), sl.len(), "duplicate slot for type {t}");
            for &v in sl {
                assert!((v as usize) < g.num_nodes[t]);
            }
        }
        for layer in &mb.oracle_edges {
            for e in layer {
                assert!(e.len() <= 16, "relation exceeds ep");
            }
        }
    }

    #[test]
    fn sampled_edges_exist_in_graph() {
        let g = tiny_graph(3);
        let s = NeighborSampler::new(&g, cfg());
        let mb = s.sample(&Rng::new(9), 0, 0);
        for layer in &mb.oracle_edges {
            for (ri, e) in layer.iter().enumerate() {
                let rel = &g.relations[ri];
                for i in 0..e.len() {
                    let sv = mb.slots[rel.src_type][e.src[i] as usize];
                    let dv = mb.slots[rel.dst_type][e.dst[i] as usize];
                    assert!(
                        rel.in_neighbors(dv as usize).contains(&sv),
                        "edge ({sv}->{dv}) of rel {ri} not in graph"
                    );
                }
            }
        }
    }

    #[test]
    fn tagged_list_is_permutation_of_oracle() {
        let g = tiny_graph(4);
        let s = NeighborSampler::new(&g, cfg());
        let mb = s.sample(&Rng::new(11), 0, 0);
        for (l, t) in mb.tagged.iter().enumerate() {
            let total: usize = mb.oracle_edges[l].iter().map(|e| e.len()).sum();
            assert_eq!(t.len(), total);
            // Multiset equality by sorting triples.
            let mut a: Vec<(u32, u32, u32)> =
                (0..t.len()).map(|i| (t.rel[i], t.src[i], t.dst[i])).collect();
            let mut b = Vec::new();
            for (ri, e) in mb.oracle_edges[l].iter().enumerate() {
                for i in 0..e.len() {
                    b.push((ri as u32, e.src[i], e.dst[i]));
                }
            }
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn seeds_occupy_leading_target_slots() {
        let g = tiny_graph(5);
        let s = NeighborSampler::new(&g, cfg());
        let mb = s.sample(&Rng::new(13), 0, 0);
        let tt = g.target_type;
        // Each distinct seed appears in the leading slots, in first-seen order.
        let mut expect = Vec::new();
        for &v in &mb.seeds {
            if !expect.contains(&v) {
                expect.push(v);
            }
        }
        assert_eq!(&mb.slots[tt][..expect.len()], &expect[..]);
    }

    #[test]
    fn epoch_reshuffles_seed_order() {
        let g = tiny_graph(6);
        let s = NeighborSampler::new(&g, cfg());
        let rng = Rng::new(21);
        let a = s.sample(&rng, 0, 0);
        let b = s.sample(&rng, 1, 0);
        assert_ne!(a.seeds, b.seeds, "epoch shuffle had no effect");
    }
}
