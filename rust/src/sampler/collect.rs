//! Feature collection (workflow step ②, Fig. 2): gather the raw features of
//! every sampled vertex into the padded `[TPAD, NS, F]` slab tensor the AOT
//! modules consume.
//!
//! The collector is layout-*aware*: on the type-major layout (HiFuse's
//! reorganization, Fig. 4b) consecutive slot ids map to physically
//! contiguous rows, so maximal runs of consecutive ids are copied with one
//! `copy_from_slice` each instead of row by row; index-major (Fig. 4a)
//! falls back to per-row `copy_row`, chasing interleaved global ids across
//! the whole feature buffer — exactly the cache-hostile access pattern the
//! paper profiles. Per-type slabs are independent, so collection is
//! partitioned across the [`WorkerPool`] (`TrainCfg::threads`), overlapping
//! the memory streams the way the paper's OpenMP collection stage does.

use crate::graph::{HeteroGraph, Layout};
use crate::runtime::ResidentStore;
use crate::sampler::MiniBatch;
use crate::util::{HostTensor, WorkerPool};

/// Collected batch tensors, ready for upload. Reusable: [`collect_into`]
/// refills an existing instance in place (the shapes are profile constants,
/// so a recycled `Collected` never reallocates).
///
/// With a device-resident feature cache (DESIGN.md §7) the collector skips
/// `xs` entirely: it fills `gather_idx` (per-slot scatter indices) and
/// `miss_rows` (the CPU-gathered non-resident rows, packed) instead, and
/// `assemble_batch` dispatches the `feature_gather` module to build the
/// slab on-device. The `xs` buffer still travels with the set so the
/// recycle loop keeps a constant buffer population either way.
pub struct Collected {
    /// `[TPAD, NS, F]` raw-feature slabs, zero-padded. Stale (recycled
    /// bytes) when the cache path filled `miss_rows`/`gather_idx` instead.
    pub xs: HostTensor,
    /// `[NS]` i32 labels of target-type slots (0 where unused).
    pub labels: HostTensor,
    /// `[NS]` f32, 1.0 on seed rows of the target type.
    pub seed_mask: HostTensor,
    /// Number of distinct seeds (mask population).
    pub n_seed: usize,
    /// Cache path only: `[TPAD*NS, F]` miss-row staging (leading `n_miss`
    /// rows valid — only those bytes upload). Empty when built uncached.
    pub miss_rows: HostTensor,
    /// Cache path only: `[TPAD, NS]` i32 scatter indices (>= 0: cache slot,
    /// -1: zero padding, <= -2: miss row `-idx - 2`). Empty when uncached.
    pub gather_idx: HostTensor,
    /// Cache path: slot reads served by the resident store this batch.
    pub n_hit: usize,
    /// Cache path: slot reads gathered on CPU into `miss_rows` this batch.
    pub n_miss: usize,
}

impl Collected {
    /// Zeroed tensors at the profile shapes (one-time allocation; the
    /// producer recycling loop keeps them alive across batches). `cached`
    /// additionally sizes the miss-staging and scatter-index buffers, so a
    /// cache-path buffer set never grows on first use either.
    pub fn new(tpad: usize, ns: usize, f: usize, cached: bool) -> Self {
        let (miss_rows, gather_idx) = if cached {
            (
                HostTensor::zeros_f32(&[tpad * ns, f]),
                HostTensor::i32(vec![-1i32; tpad * ns], &[tpad, ns]),
            )
        } else {
            (HostTensor::f32(Vec::new(), &[0]), HostTensor::i32(Vec::new(), &[0]))
        };
        Collected {
            xs: HostTensor::zeros_f32(&[tpad, ns, f]),
            labels: HostTensor::i32(vec![0i32; ns], &[ns]),
            seed_mask: HostTensor::zeros_f32(&[ns]),
            n_seed: 0,
            miss_rows,
            gather_idx,
            n_hit: 0,
            n_miss: 0,
        }
    }
}

/// Fill one type's `[NS, F]` slab: run-length `copy_from_slice` on the
/// type-major layout, per-row gather otherwise.
fn collect_type_rows(g: &HeteroGraph, t: usize, slot_list: &[u32], f: usize, out: &mut [f32]) {
    if g.features.layout() == Layout::IndexMajor {
        for (s, &v) in slot_list.iter().enumerate() {
            g.features.copy_row(t, v as usize, &mut out[s * f..(s + 1) * f]);
        }
        return;
    }
    let mut s = 0usize;
    while s < slot_list.len() {
        let v0 = slot_list[s] as usize;
        let mut run = 1usize;
        while s + run < slot_list.len() && slot_list[s + run] as usize == v0 + run {
            run += 1;
        }
        // Type-major guarantees contiguity (the index-major fallback
        // returned above), so a whole run is one memcpy.
        let src = g.features.rows(t, v0, run).expect("type-major rows are contiguous");
        out[s * f..(s + run) * f].copy_from_slice(src);
        s += run;
    }
}

/// Cache path of [`collect_into`]: write per-slot scatter indices and pack
/// the non-resident rows into the miss staging buffer. Misses keep the
/// run-length discipline: a run of consecutive miss slots whose vertex ids
/// are also consecutive copies with one `memcpy` on the type-major layout
/// (index-major falls back to `copy_row`, exactly like the full gather).
///
/// Serial across types, unlike the full-slab gather: each type's miss rows
/// pack densely after the previous type's, so the write regions are
/// data-dependent rather than row-uniform — and with any useful hit rate
/// there is far less to copy than the full gather parallelizes over.
fn split_hits_and_misses(
    g: &HeteroGraph,
    mb: &MiniBatch,
    tpad: usize,
    ns: usize,
    f: usize,
    store: &ResidentStore,
    out: &mut Collected,
) {
    let idx = out.gather_idx.as_i32_mut().expect("gather_idx is i32");
    assert_eq!(idx.len(), tpad * ns, "recycled Collected was built without cache buffers");
    let miss = out.miss_rows.as_f32_mut().expect("miss_rows is f32");
    assert_eq!(miss.len(), tpad * ns * f, "recycled miss staging has a different shape");
    idx.fill(-1);
    let mut n_hit = 0usize;
    let mut n_miss = 0usize;
    for (t, slot_list) in mb.slots.iter().enumerate() {
        let mut s = 0usize;
        while s < slot_list.len() {
            let v0 = slot_list[s] as usize;
            let cs = store.slot(t, v0);
            if cs >= 0 {
                idx[t * ns + s] = cs;
                n_hit += 1;
                s += 1;
                continue;
            }
            // Maximal run of consecutive-id misses starting at slot s.
            let mut run = 1usize;
            while s + run < slot_list.len()
                && slot_list[s + run] as usize == v0 + run
                && store.slot(t, v0 + run) < 0
            {
                run += 1;
            }
            for r in 0..run {
                idx[t * ns + s + r] = -2 - (n_miss + r) as i32;
            }
            let dst = &mut miss[n_miss * f..(n_miss + run) * f];
            match g.features.rows(t, v0, run) {
                Some(src) => dst.copy_from_slice(src),
                None => {
                    for r in 0..run {
                        g.features.copy_row(t, v0 + r, &mut dst[r * f..(r + 1) * f]);
                    }
                }
            }
            n_miss += run;
            s += run;
        }
    }
    out.n_hit = n_hit;
    out.n_miss = n_miss;
}

/// Gather raw features + labels + seed mask for a mini-batch (cache-off).
///
/// `tpad`/`ns` are the profile paddings; `f` is the raw feature dim;
/// `pool` partitions the per-type slab fills across workers. One-shot
/// convenience over [`collect_into`].
pub fn collect(
    g: &HeteroGraph,
    mb: &MiniBatch,
    tpad: usize,
    ns: usize,
    f: usize,
    pool: &WorkerPool,
) -> Collected {
    let mut out = Collected::new(tpad, ns, f, false);
    collect_into(g, mb, tpad, ns, f, pool, None, &mut out);
    out
}

/// Zero-alloc variant of [`collect`]: refill `out` (a recycled
/// [`Collected`] of the same profile shapes) in place.
///
/// With `cache` present, the full-slab gather is replaced by the hit/miss
/// split: resident rows become scatter indices into the device store, and
/// only the miss rows are gathered on the CPU (packed into
/// `out.miss_rows`, reusing the run-length memcpy path on consecutive-id
/// miss runs). `out` must have been built with `cached = true`.
pub fn collect_into(
    g: &HeteroGraph,
    mb: &MiniBatch,
    tpad: usize,
    ns: usize,
    f: usize,
    pool: &WorkerPool,
    cache: Option<&ResidentStore>,
    out: &mut Collected,
) {
    assert!(g.n_types() <= tpad, "graph has more types than TPAD");
    assert_eq!(g.feat_dim, f);
    let n_types = mb.slots.len();
    match cache {
        None => {
            let xs = out.xs.as_f32_mut().expect("xs is f32");
            assert_eq!(xs.len(), tpad * ns * f, "recycled xs has a different profile shape");
            xs.fill(0.0);
            pool.for_row_chunks(&mut xs[..n_types * ns * f], n_types, 1, |t0, t1, slab| {
                for t in t0..t1 {
                    let out = &mut slab[(t - t0) * ns * f..(t - t0 + 1) * ns * f];
                    collect_type_rows(g, t, &mb.slots[t], f, out);
                }
            });
            out.n_hit = 0;
            out.n_miss = 0;
        }
        Some(store) => {
            split_hits_and_misses(g, mb, tpad, ns, f, store, out);
        }
    }

    let labels = out.labels.as_i32_mut().expect("labels is i32");
    assert_eq!(labels.len(), ns, "recycled labels has a different profile shape");
    labels.fill(0);
    for (s, &v) in mb.slots[g.target_type].iter().enumerate() {
        labels[s] = g.labels[v as usize] as i32;
    }

    // Seeds occupy the leading target-type slots in first-seen order
    // (sampler contract, pinned by `seeds_occupy_leading_target_slots`), so
    // walking the seed list against that slot prefix identifies each
    // first occurrence in O(1) — a duplicate can never equal the *next*
    // unclaimed slot, because it already owns an earlier one. This replaces
    // the per-batch HashSet (and its allocations) the collector used to
    // build.
    let tslots = &mb.slots[g.target_type];
    let seed_mask = out.seed_mask.as_f32_mut().expect("seed_mask is f32");
    assert_eq!(seed_mask.len(), ns, "recycled seed_mask has a different profile shape");
    seed_mask.fill(0.0);
    let mut n_seed = 0usize;
    for &v in &mb.seeds {
        if n_seed < tslots.len() && tslots[n_seed] == v {
            seed_mask[n_seed] = 1.0;
            n_seed += 1;
        }
    }
    #[cfg(debug_assertions)]
    {
        let mut seen = std::collections::HashSet::new();
        let distinct = mb.seeds.iter().filter(|v| seen.insert(**v)).count();
        debug_assert_eq!(n_seed, distinct, "slot-prefix dedup diverged from HashSet");
    }
    out.n_seed = n_seed;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny_graph;
    use crate::sampler::{NeighborSampler, SamplerCfg};
    use crate::util::Rng;

    fn setup() -> (HeteroGraph, MiniBatch) {
        let g = tiny_graph(17);
        let s = NeighborSampler::new(
            &g,
            SamplerCfg { batch_size: 8, fanout: 3, layers: 2, ns: 32, ep: 16 },
        );
        let mb = s.sample(&Rng::new(5), 0, 0);
        (g, mb)
    }

    fn serial() -> WorkerPool {
        WorkerPool::new(1)
    }

    #[test]
    fn slab_rows_match_store() {
        let (g, mb) = setup();
        let c = collect(&g, &mb, 8, 32, 8, &serial());
        let xs = c.xs.as_f32().unwrap();
        let mut row = vec![0.0f32; 8];
        for (t, slots) in mb.slots.iter().enumerate() {
            for (s, &v) in slots.iter().enumerate() {
                g.features.copy_row(t, v as usize, &mut row);
                let got = &xs[t * 32 * 8 + s * 8..t * 32 * 8 + (s + 1) * 8];
                assert_eq!(got, &row[..], "row mismatch ({t},{s})");
            }
            // Padding rows are zero.
            for s in slots.len()..32 {
                let got = &xs[t * 32 * 8 + s * 8..t * 32 * 8 + (s + 1) * 8];
                assert!(got.iter().all(|&x| x == 0.0));
            }
        }
    }

    /// Run-length (type-major) and row-wise (index-major) collection agree,
    /// serial and threaded.
    #[test]
    fn both_layouts_collect_identically() {
        let (mut g, mb) = setup();
        let a = collect(&g, &mb, 8, 32, 8, &serial());
        let a4 = collect(&g, &mb, 8, 32, 8, &WorkerPool::new(4));
        assert_eq!(a.xs, a4.xs, "threaded type-major collect diverged");
        g.features.ensure_layout(Layout::IndexMajor);
        let b = collect(&g, &mb, 8, 32, 8, &serial());
        let b4 = collect(&g, &mb, 8, 32, 8, &WorkerPool::new(4));
        assert_eq!(a.xs, b.xs);
        assert_eq!(b.xs, b4.xs, "threaded index-major collect diverged");
        assert_eq!(a.labels, b.labels);
    }

    /// Force slot lists with mixed run shapes (runs, singletons, reversed
    /// pairs) through the run-length path and compare to copy_row.
    #[test]
    fn run_length_path_matches_row_wise_on_crafted_runs() {
        let (g, _) = setup();
        let f = 8;
        let slots: Vec<u32> = vec![5, 6, 7, 2, 1, 0, 10, 12, 13, 3];
        let mut run_out = vec![0.0f32; slots.len() * f];
        collect_type_rows(&g, 0, &slots, f, &mut run_out);
        let mut row = vec![0.0f32; f];
        for (s, &v) in slots.iter().enumerate() {
            g.features.copy_row(0, v as usize, &mut row);
            assert_eq!(&run_out[s * f..(s + 1) * f], &row[..], "slot {s} (vertex {v})");
        }
    }

    /// IndexMajor vs TypeMajor gather is bitwise identical for crafted
    /// slot lists covering every run shape: singleton runs, runs touching
    /// a type slab's first and last vertex (the type-boundary rows, where
    /// a run-length overshoot would read the neighboring type's memory),
    /// and a maximal whole-type run.
    #[test]
    fn layout_parity_on_type_boundary_and_singleton_runs() {
        let (mut g, _) = setup();
        let f = 8;
        for t in 0..g.n_types() {
            let n = g.num_nodes[t] as u32;
            let cases: Vec<Vec<u32>> = vec![
                vec![0],                                  // first row singleton
                vec![n - 1],                              // last row singleton
                vec![n - 2, n - 1],                       // run ending exactly at the boundary
                vec![0, 1, 2.min(n - 1)],                 // run starting at the boundary
                vec![n - 1, 0],                           // wrap: two boundary singletons
                (0..n).collect(),                         // the whole type as one run
                vec![1, 3, 4, 5, n - 1, 0, 2],            // mixed singletons + interior run
            ];
            for (ci, slots) in cases.iter().enumerate() {
                g.features.ensure_layout(Layout::TypeMajor);
                let mut tm = vec![0.0f32; slots.len() * f];
                collect_type_rows(&g, t, slots, f, &mut tm);
                g.features.ensure_layout(Layout::IndexMajor);
                let mut im = vec![0.0f32; slots.len() * f];
                collect_type_rows(&g, t, slots, f, &mut im);
                assert_eq!(
                    tm.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    im.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "type {t} case {ci}: layouts disagree bitwise"
                );
            }
        }
    }

    /// Full-batch layout parity through `collect_into`, bitwise: the slabs
    /// (not just values — the exact bit patterns) agree between layouts and
    /// between serial and threaded pools.
    #[test]
    fn collect_layout_parity_is_bitwise_over_full_batches() {
        let (mut g, mb) = setup();
        let bits = |c: &Collected| -> Vec<u32> {
            c.xs.as_f32().unwrap().iter().map(|x| x.to_bits()).collect()
        };
        g.features.ensure_layout(Layout::TypeMajor);
        let tm = collect(&g, &mb, 8, 32, 8, &serial());
        g.features.ensure_layout(Layout::IndexMajor);
        let im = collect(&g, &mb, 8, 32, 8, &serial());
        let im4 = collect(&g, &mb, 8, 32, 8, &WorkerPool::new(4));
        assert_eq!(bits(&tm), bits(&im), "layout parity broke bitwise");
        assert_eq!(bits(&im), bits(&im4), "threading broke bitwise parity");
    }

    /// The cache split partitions every occupied slot into exactly one of
    /// {hit, miss}, packs miss rows densely in slot order, and the
    /// reassembled slab (cache row for hits, miss row for misses, zeros
    /// for padding) equals the cache-off gather bit for bit — on both
    /// layouts.
    #[test]
    fn cache_split_reassembles_the_uncached_slab_bitwise() {
        let (mut g, mb) = setup();
        let (tpad, ns, f) = (8usize, 32usize, 8usize);
        let reference = collect(&g, &mb, tpad, ns, f, &serial());
        for frac in [0.25f64, 1.0] {
            let store = ResidentStore::build(&g, frac, 160, 42);
            for layout in [Layout::TypeMajor, Layout::IndexMajor] {
                g.features.ensure_layout(layout);
                let mut c = Collected::new(tpad, ns, f, true);
                collect_into(&g, &mb, tpad, ns, f, &serial(), Some(&store), &mut c);
                let occupied: usize = mb.slots.iter().map(|s| s.len()).sum();
                assert_eq!(c.n_hit + c.n_miss, occupied, "frac {frac}: split lost slots");
                if frac == 1.0 {
                    assert_eq!(c.n_miss, 0, "full cache still missed");
                }
                // Reassemble on the CPU exactly like the gather kernel.
                let idx = c.gather_idx.as_i32().unwrap();
                let miss = c.miss_rows.as_f32().unwrap();
                let mut slab = vec![0.0f32; tpad * ns * f];
                for (s, &ix) in idx.iter().enumerate() {
                    let dst = &mut slab[s * f..(s + 1) * f];
                    if ix >= 0 {
                        dst.copy_from_slice(store.row(ix as usize));
                    } else if ix <= -2 {
                        let m = (-ix - 2) as usize;
                        assert!(m < c.n_miss, "miss index past the packed rows");
                        dst.copy_from_slice(&miss[m * f..(m + 1) * f]);
                    }
                }
                let want: Vec<u32> =
                    reference.xs.as_f32().unwrap().iter().map(|x| x.to_bits()).collect();
                let got: Vec<u32> = slab.iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "frac {frac} {layout:?}: reassembly diverged");
                // Labels/mask are unaffected by the cache path.
                assert_eq!(c.labels, reference.labels);
                assert_eq!(c.seed_mask, reference.seed_mask);
                assert_eq!(c.n_seed, reference.n_seed);
            }
        }
    }

    #[test]
    fn labels_and_mask_line_up_with_seeds() {
        let (g, mb) = setup();
        let c = collect(&g, &mb, 8, 32, 8, &serial());
        let labels = c.labels.as_i32().unwrap();
        let mask = c.seed_mask.as_f32().unwrap();
        assert_eq!(c.n_seed, 8); // tiny graph train split > batch, no dups
        for s in 0..c.n_seed {
            assert_eq!(mask[s], 1.0);
            let v = mb.slots[g.target_type][s] as usize;
            assert_eq!(labels[s], g.labels[v] as i32);
        }
        assert!(mask[c.n_seed..].iter().all(|&x| x == 0.0));
    }

    /// Refilling a recycled `Collected` (already holding another batch's
    /// data) reproduces a fresh collection exactly — stale rows, labels and
    /// mask bits are all overwritten or re-zeroed.
    #[test]
    fn collect_into_reuse_matches_fresh() {
        let (g, mb) = setup();
        let s = NeighborSampler::new(
            &g,
            SamplerCfg { batch_size: 8, fanout: 3, layers: 2, ns: 32, ep: 16 },
        );
        let other = s.sample(&Rng::new(99), 1, 2);
        let mut recycled = Collected::new(8, 32, 8, false);
        collect_into(&g, &other, 8, 32, 8, &serial(), None, &mut recycled);
        collect_into(&g, &mb, 8, 32, 8, &serial(), None, &mut recycled);
        let fresh = collect(&g, &mb, 8, 32, 8, &serial());
        assert_eq!(recycled.xs, fresh.xs);
        assert_eq!(recycled.labels, fresh.labels);
        assert_eq!(recycled.seed_mask, fresh.seed_mask);
        assert_eq!(recycled.n_seed, fresh.n_seed);
    }

    /// Duplicate seeds (wrapped tail batch) are counted once by the
    /// slot-prefix dedup, matching the old HashSet semantics.
    #[test]
    fn duplicate_seeds_are_deduplicated() {
        let g = tiny_graph(3);
        // batch_size 32 > train split (24): the tail wraps and repeats seeds.
        let s = NeighborSampler::new(
            &g,
            SamplerCfg { batch_size: 32, fanout: 2, layers: 2, ns: 32, ep: 16 },
        );
        let mb = s.sample(&Rng::new(9), 0, 0);
        let mut seen = std::collections::HashSet::new();
        let distinct = mb.seeds.iter().filter(|v| seen.insert(**v)).count();
        assert!(distinct < mb.seeds.len(), "expected wrapped duplicates");
        let c = collect(&g, &mb, 8, 32, 8, &serial());
        assert_eq!(c.n_seed, distinct);
        let mask = c.seed_mask.as_f32().unwrap();
        assert_eq!(mask.iter().filter(|&&m| m == 1.0).count(), distinct);
    }
}
