//! Feature collection (workflow step ②, Fig. 2): gather the raw features of
//! every sampled vertex into the padded `[TPAD, NS, F]` slab tensor the AOT
//! modules consume.
//!
//! The collector is layout-agnostic (it reads through
//! `FeatureStore::copy_row`), so the paper's *reorganization* ablation is
//! purely a question of which layout the store materializes: index-major
//! collection chases interleaved global ids across the whole feature buffer
//! (cache-hostile, Fig. 4a), type-major collection streams per-type regions
//! (Fig. 4b).

use crate::graph::HeteroGraph;
use crate::sampler::MiniBatch;
use crate::util::HostTensor;

/// Collected batch tensors, ready for upload.
pub struct Collected {
    /// `[TPAD, NS, F]` raw-feature slabs, zero-padded.
    pub xs: HostTensor,
    /// `[NS]` i32 labels of target-type slots (0 where unused).
    pub labels: HostTensor,
    /// `[NS]` f32, 1.0 on seed rows of the target type.
    pub seed_mask: HostTensor,
    /// Number of distinct seeds (mask population).
    pub n_seed: usize,
}

/// Gather raw features + labels + seed mask for a mini-batch.
///
/// `tpad`/`ns` are the profile paddings; `f` is the raw feature dim.
pub fn collect(g: &HeteroGraph, mb: &MiniBatch, tpad: usize, ns: usize, f: usize) -> Collected {
    assert!(g.n_types() <= tpad, "graph has more types than TPAD");
    assert_eq!(g.feat_dim, f);
    let mut xs = vec![0.0f32; tpad * ns * f];
    for (t, slot_list) in mb.slots.iter().enumerate() {
        let base = t * ns * f;
        for (s, &v) in slot_list.iter().enumerate() {
            let out = &mut xs[base + s * f..base + (s + 1) * f];
            g.features.copy_row(t, v as usize, out);
        }
    }

    let mut labels = vec![0i32; ns];
    for (s, &v) in mb.slots[g.target_type].iter().enumerate() {
        labels[s] = g.labels[v as usize] as i32;
    }

    // Seeds occupy the leading target-type slots (sampler contract); the
    // batch may contain duplicate seeds when the train split wraps, so the
    // mask population is the number of *distinct* seeds.
    let mut seed_mask = vec![0.0f32; ns];
    let mut n_seed = 0usize;
    let mut seen = std::collections::HashSet::new();
    for &v in &mb.seeds {
        if seen.insert(v) {
            seed_mask[n_seed] = 1.0;
            n_seed += 1;
        }
    }

    Collected {
        xs: HostTensor::f32(xs, &[tpad, ns, f]),
        labels: HostTensor::i32(labels, &[ns]),
        seed_mask: HostTensor::f32(seed_mask, &[ns]),
        n_seed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny_graph;
    use crate::graph::Layout;
    use crate::sampler::{NeighborSampler, SamplerCfg};
    use crate::util::Rng;

    fn setup() -> (HeteroGraph, MiniBatch) {
        let g = tiny_graph(17);
        let s = NeighborSampler::new(
            &g,
            SamplerCfg { batch_size: 8, fanout: 3, layers: 2, ns: 32, ep: 16 },
        );
        let mb = s.sample(&Rng::new(5), 0, 0);
        (g, mb)
    }

    #[test]
    fn slab_rows_match_store() {
        let (g, mb) = setup();
        let c = collect(&g, &mb, 8, 32, 8);
        let xs = c.xs.as_f32().unwrap();
        let mut row = vec![0.0f32; 8];
        for (t, slots) in mb.slots.iter().enumerate() {
            for (s, &v) in slots.iter().enumerate() {
                g.features.copy_row(t, v as usize, &mut row);
                let got = &xs[t * 32 * 8 + s * 8..t * 32 * 8 + (s + 1) * 8];
                assert_eq!(got, &row[..], "row mismatch ({t},{s})");
            }
            // Padding rows are zero.
            for s in slots.len()..32 {
                let got = &xs[t * 32 * 8 + s * 8..t * 32 * 8 + (s + 1) * 8];
                assert!(got.iter().all(|&x| x == 0.0));
            }
        }
    }

    #[test]
    fn both_layouts_collect_identically() {
        let (mut g, mb) = setup();
        let a = collect(&g, &mb, 8, 32, 8);
        g.features.ensure_layout(Layout::IndexMajor);
        let b = collect(&g, &mb, 8, 32, 8);
        assert_eq!(a.xs, b.xs);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn labels_and_mask_line_up_with_seeds() {
        let (g, mb) = setup();
        let c = collect(&g, &mb, 8, 32, 8);
        let labels = c.labels.as_i32().unwrap();
        let mask = c.seed_mask.as_f32().unwrap();
        assert_eq!(c.n_seed, 8); // tiny graph train split > batch, no dups
        for s in 0..c.n_seed {
            assert_eq!(mask[s], 1.0);
            let v = mb.slots[g.target_type][s] as usize;
            assert_eq!(labels[s], g.labels[v] as i32);
        }
        assert!(mask[c.n_seed..].iter().all(|&x| x == 0.0));
    }
}
