//! Feature collection (workflow step ②, Fig. 2): gather the raw features of
//! every sampled vertex into the padded `[TPAD, NS, F]` slab tensor the AOT
//! modules consume.
//!
//! The collector is layout-*aware*: on the type-major layout (HiFuse's
//! reorganization, Fig. 4b) consecutive slot ids map to physically
//! contiguous rows, so maximal runs of consecutive ids are copied with one
//! `copy_from_slice` each instead of row by row; index-major (Fig. 4a)
//! falls back to per-row `copy_row`, chasing interleaved global ids across
//! the whole feature buffer — exactly the cache-hostile access pattern the
//! paper profiles. Per-type slabs are independent, so collection is
//! partitioned across the [`WorkerPool`] (`TrainCfg::threads`), overlapping
//! the memory streams the way the paper's OpenMP collection stage does.

use crate::graph::{HeteroGraph, Layout};
use crate::sampler::MiniBatch;
use crate::util::{HostTensor, WorkerPool};

/// Collected batch tensors, ready for upload. Reusable: [`collect_into`]
/// refills an existing instance in place (the shapes are profile constants,
/// so a recycled `Collected` never reallocates).
pub struct Collected {
    /// `[TPAD, NS, F]` raw-feature slabs, zero-padded.
    pub xs: HostTensor,
    /// `[NS]` i32 labels of target-type slots (0 where unused).
    pub labels: HostTensor,
    /// `[NS]` f32, 1.0 on seed rows of the target type.
    pub seed_mask: HostTensor,
    /// Number of distinct seeds (mask population).
    pub n_seed: usize,
}

impl Collected {
    /// Zeroed tensors at the profile shapes (one-time allocation; the
    /// producer recycling loop keeps them alive across batches).
    pub fn new(tpad: usize, ns: usize, f: usize) -> Self {
        Collected {
            xs: HostTensor::zeros_f32(&[tpad, ns, f]),
            labels: HostTensor::i32(vec![0i32; ns], &[ns]),
            seed_mask: HostTensor::zeros_f32(&[ns]),
            n_seed: 0,
        }
    }
}

/// Fill one type's `[NS, F]` slab: run-length `copy_from_slice` on the
/// type-major layout, per-row gather otherwise.
fn collect_type_rows(g: &HeteroGraph, t: usize, slot_list: &[u32], f: usize, out: &mut [f32]) {
    if g.features.layout() == Layout::IndexMajor {
        for (s, &v) in slot_list.iter().enumerate() {
            g.features.copy_row(t, v as usize, &mut out[s * f..(s + 1) * f]);
        }
        return;
    }
    let mut s = 0usize;
    while s < slot_list.len() {
        let v0 = slot_list[s] as usize;
        let mut run = 1usize;
        while s + run < slot_list.len() && slot_list[s + run] as usize == v0 + run {
            run += 1;
        }
        // Type-major guarantees contiguity (the index-major fallback
        // returned above), so a whole run is one memcpy.
        let src = g.features.rows(t, v0, run).expect("type-major rows are contiguous");
        out[s * f..(s + run) * f].copy_from_slice(src);
        s += run;
    }
}

/// Gather raw features + labels + seed mask for a mini-batch.
///
/// `tpad`/`ns` are the profile paddings; `f` is the raw feature dim;
/// `pool` partitions the per-type slab fills across workers. One-shot
/// convenience over [`collect_into`].
pub fn collect(
    g: &HeteroGraph,
    mb: &MiniBatch,
    tpad: usize,
    ns: usize,
    f: usize,
    pool: &WorkerPool,
) -> Collected {
    let mut out = Collected::new(tpad, ns, f);
    collect_into(g, mb, tpad, ns, f, pool, &mut out);
    out
}

/// Zero-alloc variant of [`collect`]: refill `out` (a recycled
/// [`Collected`] of the same profile shapes) in place.
pub fn collect_into(
    g: &HeteroGraph,
    mb: &MiniBatch,
    tpad: usize,
    ns: usize,
    f: usize,
    pool: &WorkerPool,
    out: &mut Collected,
) {
    assert!(g.n_types() <= tpad, "graph has more types than TPAD");
    assert_eq!(g.feat_dim, f);
    let xs = out.xs.as_f32_mut().expect("xs is f32");
    assert_eq!(xs.len(), tpad * ns * f, "recycled xs has a different profile shape");
    xs.fill(0.0);
    let n_types = mb.slots.len();
    pool.for_row_chunks(&mut xs[..n_types * ns * f], n_types, 1, |t0, t1, slab| {
        for t in t0..t1 {
            let out = &mut slab[(t - t0) * ns * f..(t - t0 + 1) * ns * f];
            collect_type_rows(g, t, &mb.slots[t], f, out);
        }
    });

    let labels = out.labels.as_i32_mut().expect("labels is i32");
    assert_eq!(labels.len(), ns, "recycled labels has a different profile shape");
    labels.fill(0);
    for (s, &v) in mb.slots[g.target_type].iter().enumerate() {
        labels[s] = g.labels[v as usize] as i32;
    }

    // Seeds occupy the leading target-type slots in first-seen order
    // (sampler contract, pinned by `seeds_occupy_leading_target_slots`), so
    // walking the seed list against that slot prefix identifies each
    // first occurrence in O(1) — a duplicate can never equal the *next*
    // unclaimed slot, because it already owns an earlier one. This replaces
    // the per-batch HashSet (and its allocations) the collector used to
    // build.
    let tslots = &mb.slots[g.target_type];
    let seed_mask = out.seed_mask.as_f32_mut().expect("seed_mask is f32");
    assert_eq!(seed_mask.len(), ns, "recycled seed_mask has a different profile shape");
    seed_mask.fill(0.0);
    let mut n_seed = 0usize;
    for &v in &mb.seeds {
        if n_seed < tslots.len() && tslots[n_seed] == v {
            seed_mask[n_seed] = 1.0;
            n_seed += 1;
        }
    }
    #[cfg(debug_assertions)]
    {
        let mut seen = std::collections::HashSet::new();
        let distinct = mb.seeds.iter().filter(|v| seen.insert(**v)).count();
        debug_assert_eq!(n_seed, distinct, "slot-prefix dedup diverged from HashSet");
    }
    out.n_seed = n_seed;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny_graph;
    use crate::sampler::{NeighborSampler, SamplerCfg};
    use crate::util::Rng;

    fn setup() -> (HeteroGraph, MiniBatch) {
        let g = tiny_graph(17);
        let s = NeighborSampler::new(
            &g,
            SamplerCfg { batch_size: 8, fanout: 3, layers: 2, ns: 32, ep: 16 },
        );
        let mb = s.sample(&Rng::new(5), 0, 0);
        (g, mb)
    }

    fn serial() -> WorkerPool {
        WorkerPool::new(1)
    }

    #[test]
    fn slab_rows_match_store() {
        let (g, mb) = setup();
        let c = collect(&g, &mb, 8, 32, 8, &serial());
        let xs = c.xs.as_f32().unwrap();
        let mut row = vec![0.0f32; 8];
        for (t, slots) in mb.slots.iter().enumerate() {
            for (s, &v) in slots.iter().enumerate() {
                g.features.copy_row(t, v as usize, &mut row);
                let got = &xs[t * 32 * 8 + s * 8..t * 32 * 8 + (s + 1) * 8];
                assert_eq!(got, &row[..], "row mismatch ({t},{s})");
            }
            // Padding rows are zero.
            for s in slots.len()..32 {
                let got = &xs[t * 32 * 8 + s * 8..t * 32 * 8 + (s + 1) * 8];
                assert!(got.iter().all(|&x| x == 0.0));
            }
        }
    }

    /// Run-length (type-major) and row-wise (index-major) collection agree,
    /// serial and threaded.
    #[test]
    fn both_layouts_collect_identically() {
        let (mut g, mb) = setup();
        let a = collect(&g, &mb, 8, 32, 8, &serial());
        let a4 = collect(&g, &mb, 8, 32, 8, &WorkerPool::new(4));
        assert_eq!(a.xs, a4.xs, "threaded type-major collect diverged");
        g.features.ensure_layout(Layout::IndexMajor);
        let b = collect(&g, &mb, 8, 32, 8, &serial());
        let b4 = collect(&g, &mb, 8, 32, 8, &WorkerPool::new(4));
        assert_eq!(a.xs, b.xs);
        assert_eq!(b.xs, b4.xs, "threaded index-major collect diverged");
        assert_eq!(a.labels, b.labels);
    }

    /// Force slot lists with mixed run shapes (runs, singletons, reversed
    /// pairs) through the run-length path and compare to copy_row.
    #[test]
    fn run_length_path_matches_row_wise_on_crafted_runs() {
        let (g, _) = setup();
        let f = 8;
        let slots: Vec<u32> = vec![5, 6, 7, 2, 1, 0, 10, 12, 13, 3];
        let mut run_out = vec![0.0f32; slots.len() * f];
        collect_type_rows(&g, 0, &slots, f, &mut run_out);
        let mut row = vec![0.0f32; f];
        for (s, &v) in slots.iter().enumerate() {
            g.features.copy_row(0, v as usize, &mut row);
            assert_eq!(&run_out[s * f..(s + 1) * f], &row[..], "slot {s} (vertex {v})");
        }
    }

    #[test]
    fn labels_and_mask_line_up_with_seeds() {
        let (g, mb) = setup();
        let c = collect(&g, &mb, 8, 32, 8, &serial());
        let labels = c.labels.as_i32().unwrap();
        let mask = c.seed_mask.as_f32().unwrap();
        assert_eq!(c.n_seed, 8); // tiny graph train split > batch, no dups
        for s in 0..c.n_seed {
            assert_eq!(mask[s], 1.0);
            let v = mb.slots[g.target_type][s] as usize;
            assert_eq!(labels[s], g.labels[v] as i32);
        }
        assert!(mask[c.n_seed..].iter().all(|&x| x == 0.0));
    }

    /// Refilling a recycled `Collected` (already holding another batch's
    /// data) reproduces a fresh collection exactly — stale rows, labels and
    /// mask bits are all overwritten or re-zeroed.
    #[test]
    fn collect_into_reuse_matches_fresh() {
        let (g, mb) = setup();
        let s = NeighborSampler::new(
            &g,
            SamplerCfg { batch_size: 8, fanout: 3, layers: 2, ns: 32, ep: 16 },
        );
        let other = s.sample(&Rng::new(99), 1, 2);
        let mut recycled = Collected::new(8, 32, 8);
        collect_into(&g, &other, 8, 32, 8, &serial(), &mut recycled);
        collect_into(&g, &mb, 8, 32, 8, &serial(), &mut recycled);
        let fresh = collect(&g, &mb, 8, 32, 8, &serial());
        assert_eq!(recycled.xs, fresh.xs);
        assert_eq!(recycled.labels, fresh.labels);
        assert_eq!(recycled.seed_mask, fresh.seed_mask);
        assert_eq!(recycled.n_seed, fresh.n_seed);
    }

    /// Duplicate seeds (wrapped tail batch) are counted once by the
    /// slot-prefix dedup, matching the old HashSet semantics.
    #[test]
    fn duplicate_seeds_are_deduplicated() {
        let g = tiny_graph(3);
        // batch_size 32 > train split (24): the tail wraps and repeats seeds.
        let s = NeighborSampler::new(
            &g,
            SamplerCfg { batch_size: 32, fanout: 2, layers: 2, ns: 32, ep: 16 },
        );
        let mb = s.sample(&Rng::new(9), 0, 0);
        let mut seen = std::collections::HashSet::new();
        let distinct = mb.seeds.iter().filter(|v| seen.insert(**v)).count();
        assert!(distinct < mb.seeds.len(), "expected wrapped duplicates");
        let c = collect(&g, &mb, 8, 32, 8, &serial());
        assert_eq!(c.n_seed, distinct);
        let mask = c.seed_mask.as_f32().unwrap();
        assert_eq!(mask.iter().filter(|&&m| m == 1.0).count(), distinct);
    }
}
