//! Result writers: CSV + markdown tables into `results/` (the bench
//! harness regenerates every paper table/figure as one of these files),
//! plus the small numeric formatting helpers the tables share.
//!
//! The output directory defaults to `./results` and is overridable via the
//! `HIFUSE_RESULTS_DIR` environment variable (used by tests and CI).
//! Markdown tables are echoed to stdout as they are written, so a bench
//! run doubles as a human-readable report.

use std::fmt::Write as _;
use std::path::PathBuf;

use anyhow::{Context, Result};

/// Results directory (created on demand), honoring `HIFUSE_RESULTS_DIR`.
pub fn results_dir() -> Result<PathBuf> {
    let dir = std::env::var("HIFUSE_RESULTS_DIR").unwrap_or_else(|_| "results".to_string());
    let p = PathBuf::from(dir);
    std::fs::create_dir_all(&p).with_context(|| format!("creating {p:?}"))?;
    Ok(p)
}

/// Write a CSV file under results/.
pub fn write_csv(name: &str, header: &[&str], rows: &[Vec<String>]) -> Result<PathBuf> {
    let path = results_dir()?.join(name);
    let mut out = String::new();
    writeln!(out, "{}", header.join(","))?;
    for r in rows {
        writeln!(out, "{}", r.join(","))?;
    }
    std::fs::write(&path, out).with_context(|| format!("writing {path:?}"))?;
    Ok(path)
}

/// Write a markdown table under results/ and echo it to stdout.
pub fn write_md_table(
    name: &str,
    title: &str,
    header: &[&str],
    rows: &[Vec<String>],
) -> Result<PathBuf> {
    let mut out = String::new();
    writeln!(out, "# {title}\n")?;
    writeln!(out, "| {} |", header.join(" | "))?;
    writeln!(out, "|{}|", header.iter().map(|_| "---").collect::<Vec<_>>().join("|"))?;
    for r in rows {
        writeln!(out, "| {} |", r.join(" | "))?;
    }
    let path = results_dir()?.join(name);
    std::fs::write(&path, &out).with_context(|| format!("writing {path:?}"))?;
    println!("{out}");
    Ok(path)
}

/// Geometric mean (the paper's GM bars).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Format helper: fixed 2-decimals.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_matches_hand_calc() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-9);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn csv_roundtrip() {
        std::env::set_var("HIFUSE_RESULTS_DIR", std::env::temp_dir().join("hifuse_test_results"));
        let p = write_csv(
            "unit_test.csv",
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
        std::env::remove_var("HIFUSE_RESULTS_DIR");
    }
}
