//! Semantic-graph build: **edge index selection** (the paper's §4.3,
//! Algorithm 2).
//!
//! Given the mixed, edge-type-tagged COO list of a sampled layer, split it
//! into per-relation edge lists. The paper's observation is that doing this
//! on GPU costs `R` pairs of tiny `compare` + `index_select` kernels; HiFuse
//! *offloads* it to CPU (it is control-intensive integer work) and
//! *parallelizes* it across relations with OpenMP. Here:
//!
//! * [`select_serial`] — Algorithm 2 verbatim: one compare+gather pass per
//!   relation (what a single CPU thread does).
//! * [`select_parallel`] — the relations partitioned across a scoped
//!   `std::thread` pool (the OpenMP analogue). NOTE: this container has one
//!   core, so the measured gain is ≈1x; [`modeled_parallel_speedup`] (on
//!   `perf::parallel_model`) gives the work/span-modeled multi-core
//!   scaling instead (DESIGN.md §1).
//! * [`select_bucketed`] — a single-pass counting-sort variant (O(E) instead
//!   of O(R·E)); our perf-pass extension beyond the paper (§Perf).
//!
//! The baseline-on-GPU path lives in `models::step` (it dispatches the
//! `edge_select` HLO module per relation); its results must match these —
//! covered by integration tests.

use crate::sampler::{RelEdges, TaggedEdges};

/// Algorithm 2, one relation: positions of edges with `rel == r`, in
/// order, appended to a cleared `out` (capacity retained for reuse).
#[inline]
fn select_one_into(t: &TaggedEdges, r: u32, out: &mut RelEdges) {
    out.src.clear();
    out.dst.clear();
    for i in 0..t.len() {
        if t.rel[i] == r {
            out.src.push(t.src[i]);
            out.dst.push(t.dst[i]);
        }
    }
}

/// Serial CPU edge-index selection: R compare+gather passes (Algorithm 2).
pub fn select_serial(t: &TaggedEdges, n_rel: usize) -> Vec<RelEdges> {
    let mut out = Vec::new();
    select_serial_into(t, n_rel, &mut out);
    out
}

/// Zero-alloc variant of [`select_serial`]: refills a recycled per-relation
/// vector in place, retaining every inner buffer's capacity.
pub fn select_serial_into(t: &TaggedEdges, n_rel: usize, out: &mut Vec<RelEdges>) {
    out.resize_with(n_rel, RelEdges::default);
    for (r, e) in out.iter_mut().enumerate() {
        select_one_into(t, r as u32, e);
    }
}

/// Parallel CPU edge-index selection: relations are independent, so they
/// are partitioned across `n_threads` scoped threads (OpenMP
/// `parallel for` analogue from the paper).
pub fn select_parallel(t: &TaggedEdges, n_rel: usize, n_threads: usize) -> Vec<RelEdges> {
    let mut out = Vec::new();
    select_parallel_into(t, n_rel, n_threads, &mut out);
    out
}

/// Zero-alloc variant of [`select_parallel`]: each worker refills its
/// contiguous slice of the recycled output in place.
pub fn select_parallel_into(
    t: &TaggedEdges,
    n_rel: usize,
    n_threads: usize,
    out: &mut Vec<RelEdges>,
) {
    let n_threads = n_threads.max(1).min(n_rel.max(1));
    if n_threads <= 1 || n_rel == 0 {
        select_serial_into(t, n_rel, out);
        return;
    }
    out.resize_with(n_rel, RelEdges::default);
    let chunk = n_rel.div_ceil(n_threads);
    std::thread::scope(|s| {
        let mut rest: &mut [RelEdges] = out;
        let mut r0 = 0usize;
        let mut handles = Vec::new();
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let base = r0;
            handles.push(s.spawn(move || {
                for (i, slot) in head.iter_mut().enumerate() {
                    select_one_into(t, (base + i) as u32, slot);
                }
            }));
            r0 += take;
        }
        for h in handles {
            h.join().expect("selection worker panicked");
        }
    });
}

/// Single-pass bucketed selection: O(E + R). Two passes over the tagged
/// list (count, then fill) with exact preallocation. Perf-pass extension;
/// produces identical output to Algorithm 2 because the tagged list is
/// scanned in order.
pub fn select_bucketed(t: &TaggedEdges, n_rel: usize) -> Vec<RelEdges> {
    let mut counts = vec![0usize; n_rel];
    for &r in &t.rel {
        counts[r as usize] += 1;
    }
    let mut out: Vec<RelEdges> = counts
        .iter()
        .map(|&c| RelEdges { src: Vec::with_capacity(c), dst: Vec::with_capacity(c) })
        .collect();
    for i in 0..t.len() {
        let r = t.rel[i] as usize;
        out[r].src.push(t.src[i]);
        out[r].dst.push(t.dst[i]);
    }
    out
}

/// Work/span accounting for the parallel selection, used to model the
/// multi-core speedup this 1-core container cannot measure (DESIGN.md §1):
/// serial work = R·E compares; with `p` threads the span is
/// `ceil(R/p)·E`, so modeled time = measured_serial / min(p, R).
/// Expressed through the shared [`crate::perf::parallel_model`] (one unit
/// of work per relation, a one-relation span).
pub fn modeled_parallel_speedup(n_rel: usize, n_threads: usize) -> f64 {
    let work = n_rel.max(1) as f64;
    work / crate::perf::parallel_model(work, 1.0, n_threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tagged(n: usize, n_rel: usize, seed: u64) -> TaggedEdges {
        let mut rng = Rng::new(seed);
        let mut t = TaggedEdges::default();
        for _ in 0..n {
            t.rel.push(rng.below(n_rel) as u32);
            t.src.push(rng.below(64) as u32);
            t.dst.push(rng.below(64) as u32);
        }
        t
    }

    fn flatten(v: &[RelEdges]) -> Vec<(usize, u32, u32)> {
        let mut out = Vec::new();
        for (r, e) in v.iter().enumerate() {
            for i in 0..e.len() {
                out.push((r, e.src[i], e.dst[i]));
            }
        }
        out
    }

    #[test]
    fn serial_matches_brute_force() {
        let t = tagged(500, 7, 1);
        let got = select_serial(&t, 7);
        for r in 0..7u32 {
            let expect: Vec<(u32, u32)> = (0..t.len())
                .filter(|&i| t.rel[i] == r)
                .map(|i| (t.src[i], t.dst[i]))
                .collect();
            let e = &got[r as usize];
            let pairs: Vec<(u32, u32)> = e.src.iter().copied().zip(e.dst.iter().copied()).collect();
            assert_eq!(pairs, expect);
        }
    }

    #[test]
    fn parallel_equals_serial_any_thread_count() {
        let t = tagged(2000, 13, 2);
        let serial = flatten(&select_serial(&t, 13));
        for p in [1, 2, 3, 7, 13, 64] {
            assert_eq!(flatten(&select_parallel(&t, 13, p)), serial, "p={p}");
        }
    }

    #[test]
    fn bucketed_equals_serial() {
        for seed in 0..5 {
            let t = tagged(777, 9, seed);
            assert_eq!(flatten(&select_bucketed(&t, 9)), flatten(&select_serial(&t, 9)));
        }
    }

    #[test]
    fn empty_input_gives_empty_relations() {
        let t = TaggedEdges::default();
        for v in [select_serial(&t, 4), select_parallel(&t, 4, 2), select_bucketed(&t, 4)] {
            assert_eq!(v.len(), 4);
            assert!(v.iter().all(|e| e.is_empty()));
        }
    }

    #[test]
    fn preserves_within_relation_order() {
        // Selection must be stable (original COO order within a relation)
        // so aggregation sees the same edge order on every path.
        let mut t = TaggedEdges::default();
        for i in 0..10u32 {
            t.rel.push(i % 2);
            t.src.push(i);
            t.dst.push(100 + i);
        }
        for sel in [select_serial(&t, 2), select_bucketed(&t, 2), select_parallel(&t, 2, 2)] {
            assert_eq!(sel[0].src, vec![0, 2, 4, 6, 8]);
            assert_eq!(sel[1].src, vec![1, 3, 5, 7, 9]);
        }
    }

    /// Refilling recycled output vectors (already holding another list's
    /// selection, with a different relation count) matches a fresh pass.
    #[test]
    fn into_variants_reuse_matches_fresh() {
        let a = tagged(700, 9, 3);
        let b = tagged(400, 5, 4);
        let mut out = Vec::new();
        select_serial_into(&a, 9, &mut out);
        select_serial_into(&b, 5, &mut out);
        assert_eq!(out, select_serial(&b, 5));
        select_parallel_into(&a, 9, 3, &mut out);
        assert_eq!(out, select_parallel(&a, 9, 3));
        assert_eq!(flatten(&out), flatten(&select_serial(&a, 9)));
    }

    #[test]
    fn modeled_speedup_clamps_to_relations() {
        assert_eq!(modeled_parallel_speedup(4, 16), 4.0);
        assert_eq!(modeled_parallel_speedup(100, 8), 8.0);
        assert_eq!(modeled_parallel_speedup(0, 8), 1.0);
    }
}
