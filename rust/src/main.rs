//! `repro` — the HiFuse-RS launcher.
//!
//! Subcommands:
//!   datasets                     print Table 2 (generator statistics)
//!   train [flags]                train a model, print per-epoch metrics
//!   counts [flags]               measured vs predicted kernel counts
//!   calibrate [--artifacts DIR]  machine peaks (compute / bandwidth / launch)
//!
//! Common flags: --dataset aifb|mutag|bgs|am|tiny --model rgcn|rgat
//!   --mode base|R|R+M|R+O+P|hifuse|hifuse+stacked --epochs N
//!   --batch-size N --fanout N --lr F --seed N --threads N --scale F
//!   --artifacts DIR (default artifacts/bench)

use anyhow::{bail, Result};

use hifuse::config::RunConfig;
use hifuse::coordinator::{prepare_graph_layout, Trainer};
use hifuse::graph::datasets::DATASETS;
use hifuse::models::plan;
use hifuse::perf;
use hifuse::runtime::Engine;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        return Ok(());
    };
    match cmd.as_str() {
        "datasets" => cmd_datasets(),
        "train" => cmd_train(rest),
        "counts" => cmd_counts(rest),
        "calibrate" => cmd_calibrate(rest),
        "profile" => cmd_profile(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `repro help`)"),
    }
}

fn print_usage() {
    println!(
        "repro — HiFuse-RS launcher\n\
         usage: repro <datasets|train|counts|calibrate> [--flag value ...]\n\
         see `rust/src/main.rs` header or README.md for flags"
    );
}

/// Table 2: regenerate the dataset statistics from the generators.
fn cmd_datasets() -> Result<()> {
    println!("Table 2 — benchmark datasets (synthetic stand-ins, schema-exact):");
    for spec in DATASETS {
        // Generate at small scale for speed but report spec numbers (the
        // generator matches them at scale=1.0; covered by unit tests).
        println!(
            "{:8} | {:>9} nodes | {:>9} edges | {:>2} types | {:>3} relations | {:>2} classes",
            spec.name, spec.nodes, spec.edges, spec.n_types, spec.n_relations, spec.num_classes
        );
    }
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let eng = Engine::load(&cfg.artifacts)?;
    let d = hifuse::models::step::Dims::from_engine(&eng);
    let mut graph = cfg.load_graph(d.f)?;
    prepare_graph_layout(&mut graph, &cfg.opt);
    println!(
        "dataset={} model={} mode={} ({}) profile={} batches/epoch={}",
        cfg.dataset,
        cfg.model.name(),
        cfg.mode_name,
        cfg.opt.label(),
        eng.profile(),
        graph.train_idx.len().div_ceil(cfg.train.batch_size),
    );
    let mut tr = Trainer::new(&eng, &graph, cfg.model, cfg.opt, cfg.train)?;
    if let Ok(path) = std::env::var("HIFUSE_LOAD_CKPT") {
        tr.params = hifuse::models::checkpoint::load(std::path::Path::new(&path))?;
        println!("loaded checkpoint {path}");
    }
    for epoch in 0..cfg.train.epochs as u64 {
        let m = tr.train_epoch(epoch)?;
        println!(
            "epoch {epoch:>3} | loss {:.4} | acc {:.3} | wall {:>8.1?} | cpu {:>8.1?} | gpu {:>8.1?} | kernels {}",
            m.loss, m.acc, m.wall, m.cpu_time, m.gpu_time, m.kernels_total
        );
    }
    if let Ok(path) = std::env::var("HIFUSE_SAVE_CKPT") {
        hifuse::models::checkpoint::save(&tr.params, std::path::Path::new(&path))?;
        println!("saved checkpoint {path}");
    }
    Ok(())
}

/// Measured vs predicted kernel counts for one training step.
fn cmd_counts(args: &[String]) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let eng = Engine::load(&cfg.artifacts)?;
    let d = hifuse::models::step::Dims::from_engine(&eng);
    let mut graph = cfg.load_graph(d.f)?;
    prepare_graph_layout(&mut graph, &cfg.opt);
    let mut tr = Trainer::new(&eng, &graph, cfg.model, cfg.opt, cfg.train)?;
    let m = tr.train_epoch(0)?;
    let per_step = m.kernels_total as f64 / m.batches as f64;
    println!(
        "{} {} mode={}: {} kernels / {} batches = {per_step:.1} per step",
        cfg.dataset,
        cfg.model.name(),
        cfg.opt.label(),
        m.kernels_total,
        m.batches
    );
    for (s, c) in &m.kernels_by_stage {
        println!("  {:15} {c}", s.name());
    }
    // Prediction needs live-relation counts; report the model formula for
    // the all-live upper bound as a cross-check.
    let r = graph.n_relations();
    let pred = plan::expected_counts(cfg.model, &cfg.opt, r, &[r, r]);
    println!("upper-bound prediction (all relations live): {} per step", pred.total());
    Ok(())
}

/// Per-module time breakdown of one training step (perf-pass tool):
/// runs a warm step, then a profiled step with event logging, and prints
/// modules ranked by total dispatch time.
fn cmd_profile(args: &[String]) -> Result<()> {
    use std::collections::HashMap;
    let cfg = RunConfig::from_args(args)?;
    let eng = Engine::load(&cfg.artifacts)?;
    let d = hifuse::models::step::Dims::from_engine(&eng);
    let mut graph = cfg.load_graph(d.f)?;
    prepare_graph_layout(&mut graph, &cfg.opt);
    let mut tr = Trainer::new(&eng, &graph, cfg.model, cfg.opt, cfg.train)?;
    let scfg = hifuse::sampler::SamplerCfg {
        batch_size: cfg.train.batch_size,
        fanout: cfg.train.fanout,
        layers: 2,
        ns: d.ns,
        ep: d.ep,
    };
    let rng = hifuse::util::Rng::new(cfg.train.seed);
    let prep = Trainer::prepare_cpu(&graph, scfg, &d, &cfg.opt, cfg.train.threads, &rng, 0, 0);
    tr.compute_batch(prep)?; // warm (compiles)
    eng.reset_counters(true);
    let t0 = std::time::Instant::now();
    let prep = Trainer::prepare_cpu(&graph, scfg, &d, &cfg.opt, cfg.train.threads, &rng, 0, 1);
    tr.compute_batch(prep)?;
    let step_wall = t0.elapsed();
    let counters = eng.counters.borrow();
    let mut agg: HashMap<&str, (usize, f64)> = HashMap::new();
    for e in &counters.events {
        let ent = agg.entry(e.module).or_insert((0, 0.0));
        ent.0 += 1;
        ent.1 += e.dur.as_secs_f64() * 1e3;
    }
    let mut rows: Vec<_> = agg.into_iter().collect();
    rows.sort_by(|a, b| b.1 .1.partial_cmp(&a.1 .1).unwrap());
    println!(
        "one {} {} step ({}): wall {:.1} ms, {} dispatches, gpu {:.1} ms",
        cfg.dataset,
        cfg.model.name(),
        cfg.opt.label(),
        step_wall.as_secs_f64() * 1e3,
        counters.total(),
        counters.gpu_time.as_secs_f64() * 1e3
    );
    println!("{:26} {:>6} {:>12} {:>10}", "module", "calls", "total ms", "ms/call");
    for (m, (n, ms)) in rows.iter().take(15) {
        println!("{m:26} {n:>6} {ms:>12.2} {:>10.3}", ms / *n as f64);
    }
    Ok(())
}

fn cmd_calibrate(args: &[String]) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let eng = Engine::load(&cfg.artifacts)?;
    let p = perf::calibrate(&eng)?;
    println!(
        "machine peaks: {:.1} GFLOP/s compute, {:.1} GB/s bandwidth, {:.1} us dispatch overhead",
        p.gflops, p.membw_gbs, p.dispatch_us
    );
    println!("roofline knee at AI = {:.2} FLOP/byte", p.gflops / p.membw_gbs);
    Ok(())
}
