//! `repro` — the HiFuse-RS launcher.
//!
//! Subcommands:
//!   datasets                       print Table 2 (generator statistics)
//!   train `[flags]`                train a model, print per-epoch metrics
//!   serve `[flags]`                online inference: coalesce an open- or
//!                                  closed-loop request stream into batches,
//!                                  report latency percentiles; survives
//!                                  churn — hot model refresh + lane
//!                                  quarantine (DESIGN.md §8, §10)
//!   counts `[flags]`               measured vs predicted kernel counts
//!   calibrate `[flags]`            machine peaks (compute / bandwidth / launch)
//!   profile `[flags]`              per-module time breakdown of one step
//!   verify-ckpt PATH               audit a checkpoint offline: CRC, header,
//!                                  shape table, params digest — no graph
//!                                  or backend is loaded (DESIGN.md §11)
//!
//! Common flags: --dataset aifb|mutag|bgs|am|tiny --model rgcn|rgat
//!   --mode base|R|R+M|R+O+P|hifuse|hifuse+stacked|resident --epochs N
//!   --batch-size N --fanout N --lr F --seed N --threads N --scale F
//!   --producers M (pipelined modes: CPU sampling workers feeding the
//!   reorder buffer; default max(1, threads/2) — trajectory bit-identical
//!   for every M)
//!   --backend sim|pjrt (default sim) --profile tiny|bench (sim backend)
//!   --sim-overhead-us F (simulated launch cost, sim backend)
//!   --artifacts DIR (pjrt backend artifact dir, default artifacts/bench)
//!   --replicas N (train only, sim backend: data-parallel replica rounds
//!   with a bit-identical trajectory for every N — DESIGN.md §4)
//!   --cache-frac F (train + serve, sim backend: pin the hottest F of
//!   each vertex type on the device and assemble batch slabs with the
//!   feature_gather kernel; trajectory bit-identical for every F —
//!   DESIGN.md §7)
//!   --load-ckpt P / --save-ckpt P (train + serve: parameter checkpoint
//!   to load before / save after the run; the HIFUSE_LOAD_CKPT /
//!   HIFUSE_SAVE_CKPT env vars remain as fallbacks)
//!   --rate F --requests N --coalesce-window T (serve: offered load in
//!   req/s of virtual time, request count, and the batch coalescing
//!   window in ticks — 1 tick = 1 µs)
//!   --record-trace P / --replay-trace P (serve: serialize the arrival
//!   schedule / replay one — same coalescing, bitwise-identical
//!   predictions at any --replicas/--producers/--threads/pipeline)
//!   --fault-spec S / --fault-seed N (train + serve, sim backend: the
//!   deterministic fault plane — seeded dispatch/producer/lane faults
//!   with bounded retry, standby re-derivation, and lane failover; the
//!   recovered trajectory stays bit-identical — DESIGN.md §9)
//!   --max-queue N (serve: admission-control bound on the virtual batch
//!   queue; overflowing batches are shed deterministically)
//!   --refresh-at TICK[:PATH][,TICK[:PATH]...] (serve: hot model refresh —
//!   at the first admitted batch closing at or after TICK, every lane
//!   swaps to the checkpoint at PATH (default: the --load-ckpt path); a
//!   failed load is counted, never fatal — DESIGN.md §10)
//!   --closed-loop N (serve: N virtual clients re-issuing only after
//!   their previous response completes, instead of the open-loop Poisson
//!   stream; offered load becomes a pure function of (seed, N))
//!   --probation N (serve: shadow batches a lane quarantined by a `lane!`
//!   fault must complete before re-admission; default 2)
//!   --guard (train + serve, sim backend: per-batch numeric guard rails —
//!   feature-digest check before the step, finite loss/grad after it;
//!   violations enter the recompute-or-rollback ladder — DESIGN.md §11)
//!   --audit-every N (train, sim backend: periodic FNV-1a digest audits of
//!   params, cache slab, and replica lane overrides every N batches;
//!   a failed audit rolls back to the last good snapshot and replays)
//!
//! The default `sim` backend is fully self-contained (no AOT artifacts, no
//! Python); `--backend pjrt` needs a build with `--features pjrt` plus
//! `make artifacts`. See README.md.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use hifuse::config::{BackendKind, RunConfig};
use hifuse::coordinator::{
    prepare_graph_layout, replica_thread_budget, CpuProducer, ReplicaGroup, Trainer,
};
use hifuse::graph::datasets::DATASETS;
use hifuse::graph::HeteroGraph;
use hifuse::models::plan;
use hifuse::models::step::Dims;
use hifuse::perf;
use hifuse::runtime::{ExecBackend, ResidentStore, SimBackend};
use hifuse::serving;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        return Ok(());
    };
    match cmd.as_str() {
        "datasets" => cmd_datasets(),
        "train" => dispatch(rest, Action::Train),
        "serve" => dispatch(rest, Action::Serve),
        "counts" => dispatch(rest, Action::Counts),
        "calibrate" => dispatch(rest, Action::Calibrate),
        "profile" => dispatch(rest, Action::Profile),
        "verify-ckpt" => cmd_verify_ckpt(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `repro help`)"),
    }
}

fn print_usage() {
    println!(
        "repro — HiFuse-RS launcher\n\
         usage: repro <datasets|train|serve|counts|calibrate|profile> [--flag value ...]\n\
         \x20      repro verify-ckpt PATH\n\
         \n\
         subcommands:\n\
         \x20 datasets    print Table 2 (generator statistics)\n\
         \x20 train       train a model, print per-epoch metrics\n\
         \x20 serve       online inference over an open- or closed-loop\n\
         \x20             request stream: coalesced batches, latency\n\
         \x20             p50/p95/p99, trace replay, hot refresh, quarantine\n\
         \x20 counts      measured vs predicted kernel counts\n\
         \x20 calibrate   machine peaks (compute / bandwidth / launch overhead)\n\
         \x20 profile     per-module time breakdown of one training step\n\
         \x20 verify-ckpt audit a checkpoint offline: CRC, header, shape\n\
         \x20             table, params digest — no graph load\n\
         \n\
         common flags:\n\
         \x20 --dataset aifb|mutag|bgs|am|tiny    --model rgcn|rgat\n\
         \x20 --mode base|R|R+M|R+O+P|hifuse|hifuse+stacked|resident\n\
         \x20        (resident: device-resident step — activations, grads\n\
         \x20        and params stay on-device; sim backend — DESIGN.md §7)\n\
         \x20 --backend sim|pjrt (default sim)    --profile tiny|bench (sim)\n\
         \x20 --sim-overhead-us F                 --artifacts DIR (pjrt)\n\
         \x20 --epochs N --batch-size N --fanout N --lr F --seed N\n\
         \x20 --threads N --producers M --scale F\n\
         \x20 --replicas N (train + serve, sim: data-parallel lanes;\n\
         \x20               results bit-identical for every N)\n\
         \x20 --cache-frac F (train + serve, sim: device-resident cache;\n\
         \x20               results bit-identical for every F)\n\
         \x20 --load-ckpt P --save-ckpt P (train + serve: parameter\n\
         \x20               checkpoints; env vars remain as fallback)\n\
         \x20 --fault-spec S --fault-seed N (train + serve, sim: seeded\n\
         \x20               fault injection — site@E:S[xN] / site~P over\n\
         \x20               crash sites dispatch|producer|lane|lane! and\n\
         \x20               corruption sites flip!|nan!|wire!; recovery\n\
         \x20               keeps the trajectory bit-identical — DESIGN.md\n\
         \x20               §9, §11)\n\
         \x20 --guard (train + serve, sim: per-batch numeric guard rails —\n\
         \x20               digest-checked staging, finite loss/grad;\n\
         \x20               violations recompute, then roll back)\n\
         \x20 --audit-every N (train, sim: periodic digest audits of\n\
         \x20               params / cache slab / replica lanes; failed\n\
         \x20               audits roll back to the last good snapshot)\n\
         serve flags:\n\
         \x20 --rate F (virtual req/s)  --requests N  --coalesce-window T\n\
         \x20 --record-trace P  --replay-trace P (deterministic replay:\n\
         \x20               same coalescing + bitwise predictions at any\n\
         \x20               parallelism — DESIGN.md §8)\n\
         \x20 --max-queue N (admission control: deterministically shed\n\
         \x20               batches beyond this virtual-queue depth)\n\
         \x20 --refresh-at TICK[:PATH],... (hot model refresh at a trace\n\
         \x20               tick; PATH defaults to --load-ckpt; failed\n\
         \x20               loads counted, never fatal — DESIGN.md §10)\n\
         \x20 --closed-loop N (N virtual clients, each re-issuing only\n\
         \x20               after its previous response completes)\n\
         \x20 --probation N (shadow batches a `lane!`-quarantined lane\n\
         \x20               completes before re-admission; default 2)\n\
         see README.md and DESIGN.md for details"
    );
}

/// What each backend-using subcommand does once a backend exists.
#[derive(Clone, Copy)]
enum Action {
    Train,
    Serve,
    Counts,
    Calibrate,
    Profile,
}

/// Build the configured backend, then run the action against it. The match
/// is the single place backend selection happens; everything below it is
/// generic over `ExecBackend`.
fn dispatch(args: &[String], action: Action) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    if cfg.cache_frac > 0.0 {
        if !matches!(action, Action::Train | Action::Serve) {
            bail!("--cache-frac is only supported by the `train` and `serve` subcommands");
        }
        if cfg.backend != BackendKind::Sim {
            bail!(
                "--cache-frac requires the sim backend (the PJRT artifact \
                 manifests predate the feature_gather module / CSLOTS profile \
                 constant)"
            );
        }
    }
    if cfg.opt.dev_resident && cfg.backend != BackendKind::Sim {
        bail!(
            "--mode resident requires the sim backend (the PJRT artifact \
             manifests predate the device-resident modules — head_full, \
             proj_resident_bwd, sgd_rgcn/sgd_rgat)"
        );
    }
    if cfg.replicas.is_some() {
        if !matches!(action, Action::Train | Action::Serve) {
            bail!("--replicas is only supported by the `train` and `serve` subcommands");
        }
        if cfg.backend != BackendKind::Sim {
            bail!(
                "--replicas requires the sim backend (replica lanes need a \
                 Send backend; the PJRT client is Rc-based)"
            );
        }
    }
    if (cfg.record_trace.is_some() || cfg.replay_trace.is_some())
        && !matches!(action, Action::Serve)
    {
        bail!("--record-trace/--replay-trace are only supported by the `serve` subcommand");
    }
    if cfg.fault_spec.is_some() {
        if !matches!(action, Action::Train | Action::Serve) {
            bail!("--fault-spec is only supported by the `train` and `serve` subcommands");
        }
        if cfg.backend != BackendKind::Sim {
            bail!(
                "--fault-spec requires the sim backend (the fault plane hooks \
                 its dispatch path; PJRT dispatches are opaque)"
            );
        }
    }
    if cfg.guard && !matches!(action, Action::Train | Action::Serve) {
        bail!("--guard is only supported by the `train` and `serve` subcommands");
    }
    if cfg.audit_every > 0 && !matches!(action, Action::Train) {
        bail!("--audit-every is only supported by the `train` subcommand");
    }
    if cfg.max_queue.is_some() && !matches!(action, Action::Serve) {
        bail!("--max-queue is only supported by the `serve` subcommand");
    }
    if !cfg.refresh_at.is_empty() && !matches!(action, Action::Serve) {
        bail!("--refresh-at is only supported by the `serve` subcommand");
    }
    if cfg.closed_loop.is_some() && !matches!(action, Action::Serve) {
        bail!("--closed-loop is only supported by the `serve` subcommand");
    }
    if cfg.probation != hifuse::coordinator::DEFAULT_PROBATION
        && !matches!(action, Action::Serve)
    {
        bail!("--probation is only supported by the `serve` subcommand");
    }
    if matches!(action, Action::Serve) {
        if cfg.backend != BackendKind::Sim {
            bail!(
                "serve requires the sim backend (forward lanes need a Send \
                 backend; the PJRT client is Rc-based)"
            );
        }
        return cmd_serve(&cfg);
    }
    if let Some(n) = cfg.replicas {
        return cmd_train_replicas(&cfg, n);
    }
    match cfg.backend {
        BackendKind::Sim => {
            // --threads governs both the CPU stages (selection, collection)
            // and the sim backend's intra-kernel row parallelism.
            let mut eng =
                SimBackend::builtin_threaded(cfg.resolved_profile(), cfg.train.threads)?;
            if cfg.sim_overhead_us > 0.0 {
                eng.set_launch_overhead(Duration::from_secs_f64(cfg.sim_overhead_us * 1e-6));
            }
            run_action(&eng, &cfg, action)
        }
        BackendKind::Pjrt => pjrt_dispatch(&cfg, action),
    }
}

/// Data-parallel `train` over `n` sim-backend replicas: one backend (own
/// arena + counters) per replica, sharing the `--threads` budget, merged by
/// the deterministic fixed-order all-reduce (DESIGN.md §4).
fn cmd_train_replicas(cfg: &RunConfig, n: usize) -> Result<()> {
    // A lane beyond the round width would never receive a batch (rounds
    // hold DEFAULT_ROUND batches) yet still shrink every working lane's
    // thread share. Clamping is invisible to the numerics — the trajectory
    // is replica-count-invariant — and strictly faster.
    let round = hifuse::coordinator::DEFAULT_ROUND;
    if n > round {
        eprintln!(
            "note: clamping --replicas {n} to the round width {round} (extra lanes would idle)"
        );
    }
    let probe = SimBackend::builtin(cfg.resolved_profile())?;
    let d = Dims::from_backend(&probe);
    let cfg = &clamped(cfg, &d);
    let mut graph = cfg.load_graph(d.f)?;
    prepare_graph_layout(&mut graph, &cfg.opt);
    let overhead = Duration::from_secs_f64(cfg.sim_overhead_us.max(0.0) * 1e-6);
    let mut group = ReplicaGroup::builtin(
        cfg.resolved_profile(),
        n,
        overhead,
        &graph,
        cfg.model,
        cfg.opt,
        cfg.train,
        round,
    )?;
    if cfg.cache_frac > 0.0 {
        let store = build_cache(cfg, &graph, probe.cst("CSLOTS"));
        group.attach_cache(store)?;
    }
    if let Some(plan) = cfg.fault_plan()? {
        group.set_fault_plan(Arc::new(plan));
    }
    if cfg.guard {
        group.set_guard(true)?;
    }
    if cfg.audit_every > 0 {
        group.set_audit_every(cfg.audit_every)?;
    }
    let integrity_on = cfg.guard
        || cfg.audit_every > 0
        || cfg.fault_plan()?.is_some_and(|p| p.has_integrity_site());
    let threads_per = replica_thread_budget(cfg.train.threads, group.replicas());
    load_ckpt(cfg.load_ckpt.as_deref(), &mut group.params)?;
    println!(
        "dataset={} model={} mode={} ({}) backend=sim profile={} replicas={} \
         round={} threads/replica={} batches/epoch={}",
        cfg.dataset,
        cfg.model.name(),
        cfg.mode_name,
        cfg.opt.label(),
        group.engines()[0].profile(),
        group.replicas(),
        group.round(),
        threads_per,
        graph.train_idx.len().div_ceil(cfg.train.batch_size),
    );
    for epoch in 0..cfg.train.epochs as u64 {
        let m = group.train_epoch(epoch)?;
        let per_rep: Vec<String> =
            m.per_replica.iter().map(|r| r.kernels_total.to_string()).collect();
        let cache_note = if cfg.cache_frac > 0.0 {
            format!(" | hit {:.2}", m.group.cache_hit_rate())
        } else {
            String::new()
        };
        // Resident lanes broadcast params and return gradients peer-to-peer
        // (device-to-device), not over the host PCIe counters.
        let p2p_note = if cfg.opt.dev_resident {
            format!(" | p2p {:.1} MiB", m.group.p2p_bytes as f64 / (1024.0 * 1024.0))
        } else {
            String::new()
        };
        if cfg.fault_spec.is_some() {
            println!(
                "  faults: dispatch retries {} | producer recoveries {} | lane failovers {}",
                m.group.dispatch_retries, m.group.producer_recoveries, m.group.lane_failovers,
            );
        }
        if integrity_on {
            println!(
                "  integrity: violations {} | retransmits {} | recomputes {} | rollbacks {} | audits {}",
                m.group.integrity_violations,
                m.group.integrity_retransmits,
                m.group.integrity_recomputes,
                m.group.integrity_rollbacks,
                m.group.audits,
            );
        }
        println!(
            "epoch {epoch:>3} | loss {:.4} | acc {:.3} | wall {:>8.1?} | cpu {:>8.1?} | gpu {:>8.1?} | h2d {:.1} MiB | d2h {:.1} MiB{} | params 0x{:016x} | kernels {} (per replica: {})",
            m.group.loss,
            m.group.acc,
            m.group.wall,
            m.group.cpu_time,
            m.group.gpu_time,
            m.group.h2d_bytes as f64 / (1024.0 * 1024.0),
            m.group.d2h_bytes as f64 / (1024.0 * 1024.0),
            format!("{cache_note}{p2p_note}"),
            group.params.digest(),
            m.group.kernels_total,
            per_rep.join("/"),
        );
    }
    println!("final params digest 0x{:016x}", group.params.digest());
    save_ckpt(cfg.save_ckpt.as_deref(), &group.params)?;
    Ok(())
}

/// Online inference over an open- or closed-loop request stream
/// (DESIGN.md §8, §10): generate or replay an arrival trace, coalesce it
/// into static-shape batches, run them forward-only across the replica
/// lanes — hot-refreshing parameters at `--refresh-at` boundaries and
/// quarantining `lane!`-faulted lanes — and report per-request latency
/// percentiles, queue-depth accounting, churn counters, and a prediction
/// digest. Always the replica path (`--replicas` defaults to 1) so
/// serving and replica training share one execution engine.
fn cmd_serve(cfg: &RunConfig) -> Result<()> {
    let round = hifuse::coordinator::DEFAULT_ROUND;
    let n = cfg.replicas.unwrap_or(1);
    if n > round {
        eprintln!(
            "note: clamping --replicas {n} to the round width {round} (extra lanes would idle)"
        );
    }
    let probe = SimBackend::builtin(cfg.resolved_profile())?;
    let d = Dims::from_backend(&probe);
    let cfg = &clamped(cfg, &d);
    let mut graph = cfg.load_graph(d.f)?;
    prepare_graph_layout(&mut graph, &cfg.opt);
    let overhead = Duration::from_secs_f64(cfg.sim_overhead_us.max(0.0) * 1e-6);
    let mut group = ReplicaGroup::builtin(
        cfg.resolved_profile(),
        n,
        overhead,
        &graph,
        cfg.model,
        cfg.opt,
        cfg.train,
        round,
    )?;
    if cfg.cache_frac > 0.0 {
        let store = build_cache(cfg, &graph, probe.cst("CSLOTS"));
        group.attach_cache(store)?;
    }
    if let Some(plan) = cfg.fault_plan()? {
        group.set_fault_plan(Arc::new(plan));
    }
    if cfg.guard {
        group.set_guard(true)?;
    }
    load_ckpt(cfg.load_ckpt.as_deref(), &mut group.params)?;
    let trace = match &cfg.replay_trace {
        Some(p) => {
            let t = serving::trace::load(p)?;
            println!("replaying {} requests from {}", t.requests.len(), p.display());
            t
        }
        // Requests carry 1..=min(4, batch_size) seeds: small like real
        // point queries, large enough to exercise multi-seed demux.
        None => match cfg.closed_loop {
            Some(clients) => serving::trace::generate_closed_loop(
                &graph,
                cfg.train.seed,
                clients,
                cfg.requests,
                cfg.train.batch_size.clamp(1, 4),
            ),
            None => serving::trace::generate(
                &graph,
                cfg.train.seed,
                cfg.rate,
                cfg.requests,
                cfg.train.batch_size.clamp(1, 4),
            ),
        },
    };
    if let Some(p) = &cfg.record_trace {
        serving::trace::save(&trace, p)?;
        println!("recorded trace -> {}", p.display());
    }
    println!(
        "dataset={} model={} mode={} ({}) backend=sim profile={} replicas={} \
         rate={} req/s window={} ticks requests={}",
        cfg.dataset,
        cfg.model.name(),
        cfg.mode_name,
        cfg.opt.label(),
        group.engines()[0].profile(),
        group.replicas(),
        cfg.rate,
        cfg.coalesce_window,
        trace.requests.len(),
    );
    if let Some(clients) = cfg.closed_loop {
        println!(
            "closed-loop: {clients} virtual clients, think time ~{} ticks",
            serving::trace::CLOSED_LOOP_THINK_MEAN,
        );
    }
    // Resolve every refresh event to a concrete checkpoint path now, so a
    // missing fallback is a CLI error, not a silent failed refresh.
    let mut refreshes: Vec<(u64, PathBuf)> = Vec::with_capacity(cfg.refresh_at.len());
    for (tick, path) in &cfg.refresh_at {
        match path.clone().or_else(|| cfg.load_ckpt.clone()) {
            Some(p) => refreshes.push((*tick, p)),
            None => bail!(
                "--refresh-at {tick} names no checkpoint and there is no \
                 --load-ckpt to fall back to"
            ),
        }
    }
    let opts = serving::ServeOptions {
        max_queue: cfg.max_queue,
        refreshes,
        probation: cfg.probation,
    };
    let out = serving::serve_churn(
        &mut group,
        &trace,
        cfg.train.batch_size,
        cfg.coalesce_window,
        &opts,
    )?;
    let (mut h2d, mut d2h, mut retries) = (0u64, 0u64, 0u64);
    for e in group.engines() {
        let c = e.counters().borrow();
        h2d += c.h2d_bytes;
        d2h += c.d2h_bytes;
        retries += c.dispatch_retries;
    }
    let ps = group.producer_stats();
    let h = &out.hist;
    let shed_note = if cfg.max_queue.is_some() {
        format!(" | shed {} requests (peak backlog {})", h.shed(), out.max_backlog)
    } else {
        String::new()
    };
    println!(
        "served {} requests as {} coalesced batches{} | mean queue depth {:.2} | wall {:>8.1?}",
        h.count(),
        out.batches.len(),
        shed_note,
        out.mean_queue_depth,
        out.wall,
    );
    if cfg.fault_spec.is_some() {
        println!("faults: dispatch retries {retries}");
    }
    if !out.churn.is_quiet() || !cfg.refresh_at.is_empty() || cfg.fault_spec.is_some() {
        let s = &out.churn;
        println!(
            "churn: refreshes {} | failed refreshes {} | lane_quarantines {} | \
             readmissions {} | shadow batches {} | redispatches {}",
            s.refreshes,
            s.failed_refreshes,
            s.lane_quarantines,
            s.lane_readmissions,
            s.shadow_batches,
            s.lane_redispatches,
        );
    }
    if cfg.guard || out.churn.integrity_violations > 0 {
        println!(
            "integrity: violations {} | recomputes {} | suspect lanes {:?}",
            out.churn.integrity_violations,
            out.churn.integrity_recomputes,
            out.suspect_lanes,
        );
    }
    println!("predictions digest 0x{:016x}", out.prediction_digest()?);
    println!(
        "latency p50 {:.3} ms | p95 {:.3} ms | p99 {:.3} ms | mean {:.3} ms | {:.0} req/s (virtual)",
        h.percentile(50.0) as f64 / 1e3,
        h.percentile(95.0) as f64 / 1e3,
        h.percentile(99.0) as f64 / 1e3,
        h.mean() / 1e3,
        out.virtual_throughput(),
    );
    println!(
        "h2d {:.1} MiB | d2h {:.1} MiB | producer bufs fresh/reused/grown {}/{}/{}",
        h2d as f64 / (1024.0 * 1024.0),
        d2h as f64 / (1024.0 * 1024.0),
        ps.fresh,
        ps.reused,
        ps.grown,
    );
    save_ckpt(cfg.save_ckpt.as_deref(), &group.params)?;
    Ok(())
}

/// `repro verify-ckpt PATH` — offline checkpoint audit (DESIGN.md §11):
/// the exact validation a load runs (magic, version, truncation, shapes,
/// CRC) plus the header, shape table, and params digest, with no graph or
/// backend construction. Exits nonzero on any corruption.
fn cmd_verify_ckpt(args: &[String]) -> Result<()> {
    let [path] = args else {
        bail!("usage: repro verify-ckpt PATH (exactly one path, no flags)");
    };
    let r = hifuse::models::checkpoint::inspect(Path::new(path))?;
    let (rpad, f, h, c) = r.dims;
    println!(
        "checkpoint {path}: v{} | {} bytes | crc {}",
        r.version,
        r.bytes,
        if r.crc_checked { "ok" } else { "absent (v1 predates the trailer)" },
    );
    println!("cursor: epoch {} batch {}", r.cursor.epoch, r.cursor.batch);
    println!("dims: rpad {rpad} | f {f} | h {h} | c {c}");
    for (name, len) in &r.tensors {
        println!("  {name:8} {len:>10} f32");
    }
    println!("params digest 0x{:016x}", r.params_digest);
    Ok(())
}

/// Build the resident feature store for `--cache-frac` (a pure function of
/// graph/frac/cslots/seed — every replica and producer shares the result),
/// printing the presampling outcome.
fn build_cache(cfg: &RunConfig, graph: &HeteroGraph, cslots: usize) -> Arc<ResidentStore> {
    let store = Arc::new(ResidentStore::build(graph, cfg.cache_frac, cslots, cfg.train.seed));
    println!(
        "feature cache: frac {} -> {} rows resident ({} slots), {:.1} KiB pinned",
        cfg.cache_frac,
        store.rows_cached(),
        store.cslots(),
        (store.rows_cached() * store.feat_dim() * 4) as f64 / 1024.0,
    );
    store
}

/// Load a parameter checkpoint before a run: the `--load-ckpt` flag wins,
/// the `HIFUSE_LOAD_CKPT` env var remains as a fallback — one
/// implementation for the single-backend, replica, and serve paths.
fn load_ckpt(flag: Option<&Path>, params: &mut hifuse::models::Params) -> Result<()> {
    let path = match flag {
        Some(p) => Some(p.to_path_buf()),
        None => std::env::var("HIFUSE_LOAD_CKPT").ok().map(PathBuf::from),
    };
    if let Some(path) = path {
        *params = hifuse::models::checkpoint::load(&path)?;
        println!("loaded checkpoint {}", path.display());
    }
    Ok(())
}

/// Counterpart of [`load_ckpt`]: `--save-ckpt`, falling back to
/// `HIFUSE_SAVE_CKPT`.
fn save_ckpt(flag: Option<&Path>, params: &hifuse::models::Params) -> Result<()> {
    let path = match flag {
        Some(p) => Some(p.to_path_buf()),
        None => std::env::var("HIFUSE_SAVE_CKPT").ok().map(PathBuf::from),
    };
    if let Some(path) = path {
        hifuse::models::checkpoint::save(params, &path)?;
        println!("saved checkpoint {}", path.display());
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_dispatch(cfg: &RunConfig, action: Action) -> Result<()> {
    let mut eng = hifuse::runtime::Engine::load(&cfg.artifacts)?;
    if cfg.sim_overhead_us > 0.0 {
        // Same knob as the sim backend: extra busy-wait per dispatch, so
        // dispatch-bound comparisons mean the same thing on both backends.
        eng.extra_launch_overhead = Duration::from_secs_f64(cfg.sim_overhead_us * 1e-6);
    }
    run_action(&eng, cfg, action)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_dispatch(_cfg: &RunConfig, _action: Action) -> Result<()> {
    bail!(
        "this build has no PJRT support; run `make artifacts`, then rebuild \
         with `cargo build --release --features pjrt` (see rust/Cargo.toml)"
    )
}

fn run_action<B: ExecBackend>(eng: &B, cfg: &RunConfig, action: Action) -> Result<()> {
    match action {
        Action::Train => cmd_train(eng, cfg),
        // Serve is routed to `cmd_serve` in `dispatch` (it always runs the
        // replica path and is sim-only), never through a generic backend.
        Action::Serve => unreachable!("serve dispatches before backend selection"),
        Action::Counts => cmd_counts(eng, cfg),
        Action::Calibrate => cmd_calibrate(eng),
        Action::Profile => cmd_profile(eng, cfg),
    }
}

/// Clamp the batch size to the profile's node-slab capacity so e.g.
/// `repro train --dataset tiny` works with the default --batch-size on the
/// tiny profile (NS=32) instead of tripping the sampler's capacity assert.
fn clamped(cfg: &RunConfig, d: &Dims) -> RunConfig {
    let mut cfg = cfg.clone();
    if cfg.train.batch_size > d.ns {
        eprintln!(
            "note: clamping --batch-size {} to profile NS={}",
            cfg.train.batch_size, d.ns
        );
        cfg.train.batch_size = d.ns;
    }
    cfg
}

/// Table 2: regenerate the dataset statistics from the generators.
fn cmd_datasets() -> Result<()> {
    println!("Table 2 — benchmark datasets (synthetic stand-ins, schema-exact):");
    for spec in DATASETS {
        // Generate at small scale for speed but report spec numbers (the
        // generator matches them at scale=1.0; covered by unit tests).
        println!(
            "{:8} | {:>9} nodes | {:>9} edges | {:>2} types | {:>3} relations | {:>2} classes",
            spec.name, spec.nodes, spec.edges, spec.n_types, spec.n_relations, spec.num_classes
        );
    }
    Ok(())
}

fn cmd_train<B: ExecBackend>(eng: &B, cfg: &RunConfig) -> Result<()> {
    let d = Dims::from_backend(eng);
    let cfg = &clamped(cfg, &d);
    let mut graph = cfg.load_graph(d.f)?;
    prepare_graph_layout(&mut graph, &cfg.opt);
    println!(
        "dataset={} model={} mode={} ({}) backend={} profile={} batches/epoch={}",
        cfg.dataset,
        cfg.model.name(),
        cfg.mode_name,
        cfg.opt.label(),
        cfg.backend.name(),
        eng.profile(),
        graph.train_idx.len().div_ceil(cfg.train.batch_size),
    );
    let mut tr = Trainer::new(eng, &graph, cfg.model, cfg.opt, cfg.train)?;
    if cfg.cache_frac > 0.0 {
        let store = build_cache(cfg, &graph, eng.cst("CSLOTS"));
        tr.attach_cache(store)?;
    }
    if let Some(plan) = cfg.fault_plan()? {
        tr.set_fault_plan(Arc::new(plan));
    }
    if cfg.guard {
        tr.set_guard(true)?;
    }
    if cfg.audit_every > 0 {
        tr.set_audit_every(cfg.audit_every)?;
    }
    let integrity_on = cfg.guard
        || cfg.audit_every > 0
        || cfg.fault_plan()?.is_some_and(|p| p.has_integrity_site());
    load_ckpt(cfg.load_ckpt.as_deref(), &mut tr.params)?;
    for epoch in 0..cfg.train.epochs as u64 {
        let m = tr.train_epoch(epoch)?;
        let cache_note = if cfg.cache_frac > 0.0 {
            format!(" | hit {:.2}", m.cache_hit_rate())
        } else {
            String::new()
        };
        // Resident runs keep authoritative params on-device; the host
        // mirror is stale mid-run, so the per-epoch digest would lie.
        let digest_note = if cfg.opt.dev_resident {
            String::new()
        } else {
            format!(" | params 0x{:016x}", tr.params.digest())
        };
        if cfg.fault_spec.is_some() {
            println!(
                "  faults: dispatch retries {} | producer recoveries {}",
                m.dispatch_retries, m.producer_recoveries,
            );
        }
        if integrity_on {
            println!(
                "  integrity: violations {} | retransmits {} | recomputes {} | rollbacks {} | audits {}",
                m.integrity_violations,
                m.integrity_retransmits,
                m.integrity_recomputes,
                m.integrity_rollbacks,
                m.audits,
            );
        }
        println!(
            "epoch {epoch:>3} | loss {:.4} | acc {:.3} | wall {:>8.1?} | cpu {:>8.1?} (s/s/c {:.1?}/{:.1?}/{:.1?}) | gpu {:>8.1?} | h2d {:.1} MiB | d2h {:.1} MiB{}{} | kernels {}",
            m.loss,
            m.acc,
            m.wall,
            m.cpu_time,
            m.cpu_by_stage.sample,
            m.cpu_by_stage.select,
            m.cpu_by_stage.collect,
            m.gpu_time,
            m.h2d_bytes as f64 / (1024.0 * 1024.0),
            m.d2h_bytes as f64 / (1024.0 * 1024.0),
            cache_note,
            digest_note,
            m.kernels_total
        );
    }
    // Device-resident runs keep the authoritative parameters on-device;
    // read them back before checkpointing (no-op in host-staged modes).
    tr.sync_params()?;
    println!("final params digest 0x{:016x}", tr.params.digest());
    save_ckpt(cfg.save_ckpt.as_deref(), &tr.params)?;
    Ok(())
}

/// Measured vs predicted kernel counts for one training epoch.
fn cmd_counts<B: ExecBackend>(eng: &B, cfg: &RunConfig) -> Result<()> {
    let d = Dims::from_backend(eng);
    let cfg = &clamped(cfg, &d);
    let mut graph = cfg.load_graph(d.f)?;
    prepare_graph_layout(&mut graph, &cfg.opt);
    let mut tr = Trainer::new(eng, &graph, cfg.model, cfg.opt, cfg.train)?;
    let m = tr.train_epoch(0)?;
    let per_step = m.kernels_total as f64 / m.batches as f64;
    println!(
        "{} {} mode={}: {} kernels / {} batches = {per_step:.1} per step",
        cfg.dataset,
        cfg.model.name(),
        cfg.opt.label(),
        m.kernels_total,
        m.batches
    );
    for (s, c) in &m.kernels_by_stage {
        println!("  {:15} {c}", s.name());
    }
    // Prediction needs live-relation counts; report the model formula for
    // the all-live upper bound as a cross-check.
    let r = graph.n_relations();
    let pred = plan::expected_counts(cfg.model, &cfg.opt, r, &[r, r]);
    println!("upper-bound prediction (all relations live): {} per step", pred.total());
    Ok(())
}

/// Per-module time breakdown of one training step (perf-pass tool):
/// runs a warm step, then a profiled step with event logging, and prints
/// modules ranked by total dispatch time.
fn cmd_profile<B: ExecBackend>(eng: &B, cfg: &RunConfig) -> Result<()> {
    use std::collections::HashMap;
    let d = Dims::from_backend(eng);
    let cfg = &clamped(cfg, &d);
    let mut graph = cfg.load_graph(d.f)?;
    prepare_graph_layout(&mut graph, &cfg.opt);
    let mut tr = Trainer::new(eng, &graph, cfg.model, cfg.opt, cfg.train)?;
    let scfg = hifuse::sampler::SamplerCfg {
        batch_size: cfg.train.batch_size,
        fanout: cfg.train.fanout,
        layers: 2,
        ns: d.ns,
        ep: d.ep,
    };
    let rng = hifuse::util::Rng::new(cfg.train.seed);
    let pool = tr.pool;
    // One persistent producer, constructed before the timed region: its
    // scratch allocation (dense slot maps spanning the graph) is run-level
    // setup the training loops amortize, not per-step work.
    let mut producer = CpuProducer::new(&graph, scfg, d, cfg.opt, pool, rng);
    let prep = producer.produce(0, 0);
    tr.compute_batch(prep)?; // warm (compiles on PJRT)
    eng.reset_counters(true);
    let t0 = std::time::Instant::now();
    let prep = producer.produce(0, 1);
    tr.compute_batch(prep)?;
    let step_wall = t0.elapsed();
    let counters = eng.counters().borrow();
    let mut agg: HashMap<&str, (usize, f64)> = HashMap::new();
    for e in &counters.events {
        let ent = agg.entry(e.module).or_insert((0, 0.0));
        ent.0 += 1;
        ent.1 += e.dur.as_secs_f64() * 1e3;
    }
    let mut rows: Vec<_> = agg.into_iter().collect();
    rows.sort_by(|a, b| b.1 .1.partial_cmp(&a.1 .1).unwrap());
    println!(
        "one {} {} step ({}): wall {:.1} ms, {} dispatches, gpu {:.1} ms",
        cfg.dataset,
        cfg.model.name(),
        cfg.opt.label(),
        step_wall.as_secs_f64() * 1e3,
        counters.total(),
        counters.gpu_time.as_secs_f64() * 1e3
    );
    println!("{:26} {:>6} {:>12} {:>10}", "module", "calls", "total ms", "ms/call");
    for (m, (n, ms)) in rows.iter().take(15) {
        println!("{m:26} {n:>6} {ms:>12.2} {:>10.3}", ms / *n as f64);
    }
    Ok(())
}

fn cmd_calibrate<B: ExecBackend>(eng: &B) -> Result<()> {
    let p = perf::calibrate(eng)?;
    println!(
        "machine peaks: {:.1} GFLOP/s compute, {:.1} GB/s bandwidth, {:.1} us dispatch overhead",
        p.gflops, p.membw_gbs, p.dispatch_us
    );
    println!("roofline knee at AI = {:.2} FLOP/byte", p.gflops / p.membw_gbs);
    Ok(())
}
