//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Drives dataset generation, mini-batch sampling and synthetic features.
//! Determinism matters: the baseline and HiFuse execution modes must see
//! *identical* mini-batches so speedup comparisons are apples-to-apples and
//! loss curves are bit-reproducible across runs.

/// xoshiro256** (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 state expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (e.g. one per mini-batch index).
    pub fn fork(&self, stream: u64) -> Self {
        Rng::new(self.s[0] ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// The state word that fully determines every [`Rng::fork`] of this
    /// generator. Two generators with equal fork keys produce identical
    /// forked streams — the identity the sampler's epoch-permutation cache
    /// is keyed on.
    pub fn fork_key(&self) -> u64 {
        self.s[0]
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`; unbiased via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal (Box-Muller; one value per call, simple over fast).
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()).max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf-like weights: `w_i = 1/(i+1)^alpha`, normalized to sum to 1.
    pub fn zipf_weights(n: usize, alpha: f64) -> Vec<f64> {
        let mut w: Vec<f64> = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(alpha)).collect();
        let s: f64 = w.iter().sum();
        for x in &mut w {
            *x /= s;
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let base = Rng::new(7);
        let (mut a, mut b) = (base.fork(1), base.fork(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(42);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            counts[v] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "count {c} far from uniform");
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_weights_normalized_and_decreasing() {
        let w = Rng::zipf_weights(20, 1.1);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        for i in 1..w.len() {
            assert!(w[i] <= w[i - 1]);
        }
    }
}
