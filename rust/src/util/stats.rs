//! Timing statistics for the hand-rolled benchmark harness (criterion is
//! not available offline): mean / stddev / percentiles over sample sets.
//!
//! Samples are stored in microseconds as `f64`; percentile queries sort a
//! copy on demand, so pushing stays O(1) on the measurement path. Used by
//! `perf::calibrate` (machine peaks) and the bench harness's per-cell
//! timing loops.

use std::time::Duration;

/// Accumulates duration samples and reports summary statistics.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    samples_us: Vec<f64>,
}

impl Stats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, d: Duration) {
        self.samples_us.push(d.as_secs_f64() * 1e6);
    }

    pub fn push_us(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    pub fn total_us(&self) -> f64 {
        self.samples_us.iter().sum()
    }

    pub fn stddev_us(&self) -> f64 {
        let n = self.samples_us.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean_us();
        (self.samples_us.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// p in [0,100]; nearest-rank on the sorted samples.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_us.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0 * s.len() as f64).ceil() as usize).clamp(1, s.len());
        s[idx - 1]
    }

    pub fn min_us(&self) -> f64 {
        self.samples_us.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us sd={:.1}us p50={:.1}us p99={:.1}us",
            self.len(),
            self.mean_us(),
            self.stddev_us(),
            self.percentile_us(50.0),
            self.percentile_us(99.0)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentiles() {
        let mut s = Stats::new();
        for i in 1..=100 {
            s.push_us(i as f64);
        }
        assert!((s.mean_us() - 50.5).abs() < 1e-9);
        assert_eq!(s.percentile_us(50.0), 50.0);
        assert_eq!(s.percentile_us(99.0), 99.0);
        assert_eq!(s.min_us(), 1.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = Stats::new();
        assert_eq!(s.mean_us(), 0.0);
        assert_eq!(s.percentile_us(50.0), 0.0);
    }

    #[test]
    fn stddev_of_constant_is_zero() {
        let mut s = Stats::new();
        for _ in 0..10 {
            s.push_us(5.0);
        }
        assert!(s.stddev_us() < 1e-12);
    }
}
