//! Host-side tensors exchanged with the PJRT runtime.
//!
//! Deliberately minimal: flat storage + shape, f32 and i32 only (the dtypes
//! the AOT modules use). Conversion to/from `xla::Literal` lives in
//! `runtime::literal` so this module stays dependency-free and easily
//! testable.

use anyhow::{bail, Result};

/// Flat host tensor: row-major data + shape.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32(data, shape.to_vec())
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32(vec![v], vec![])
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        HostTensor::F32(vec![0.0; shape.iter().product()], shape.to_vec())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(d, _) => d.len(),
            HostTensor::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> usize {
        self.len() * 4
    }

    pub fn dtype_str(&self) -> &'static str {
        match self {
            HostTensor::F32(..) => "f32",
            HostTensor::I32(..) => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            _ => bail!("expected i32 tensor, got f32"),
        }
    }

    /// Mutable storage access (in-place refill of recycled tensors — the
    /// producer-side buffer reuse keeps the shape, so only data changes).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("expected f32 tensor, got i32"),
        }
    }

    /// Mutable storage access; see [`HostTensor::as_f32_mut`].
    pub fn as_i32_mut(&mut self) -> Result<&mut [i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            _ => bail!("expected i32 tensor, got f32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            _ => bail!("expected i32 tensor, got f32"),
        }
    }

    /// Scalar extraction (rank-0 or single-element tensors).
    pub fn scalar(&self) -> Result<f32> {
        match self {
            HostTensor::F32(d, _) if d.len() == 1 => Ok(d[0]),
            HostTensor::I32(d, _) if d.len() == 1 => Ok(d[0] as f32),
            _ => bail!("tensor is not a scalar: shape {:?}", self.shape()),
        }
    }
}

/// Row-major offset of `row` in a `[rows, cols]` matrix slice.
#[inline]
pub fn row(data: &[f32], r: usize, cols: usize) -> &[f32] {
    &data[r * cols..(r + 1) * cols]
}

/// `a += b` elementwise (gradient accumulation on the host).
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x += *y;
    }
}

/// `a -= lr * g` (host-side SGD update).
pub fn sgd_step(a: &mut [f32], g: &[f32], lr: f32) {
    debug_assert_eq!(a.len(), g.len());
    for (x, y) in a.iter_mut().zip(g) {
        *x -= lr * *y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_bytes() {
        let t = HostTensor::zeros_f32(&[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.len(), 12);
        assert_eq!(t.size_bytes(), 48);
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(HostTensor::scalar_f32(2.5).scalar().unwrap(), 2.5);
        assert_eq!(HostTensor::scalar_i32(7).scalar().unwrap(), 7.0);
        assert!(HostTensor::zeros_f32(&[2]).scalar().is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = HostTensor::i32(vec![1, 2], &[2]);
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
    }

    #[test]
    fn sgd_and_accumulate() {
        let mut a = vec![1.0, 2.0];
        add_assign(&mut a, &[0.5, 0.5]);
        assert_eq!(a, vec![1.5, 2.5]);
        sgd_step(&mut a, &[1.0, 1.0], 0.5);
        assert_eq!(a, vec![1.0, 2.0]);
    }
}
