//! The shared worker pool behind every parallel hot-path loop: Sim kernel
//! row partitioning, per-relation merged aggregation, and run-length
//! feature collection.
//!
//! Design: a *scoped-thread* pool (the OpenMP `parallel for` analogue the
//! paper uses for CPU stages, same idiom as `semantic::select_parallel`).
//! Work is partitioned into contiguous row chunks, one per worker, each
//! worker receiving a disjoint `&mut` window of the output — so the
//! partitioning is race-free by construction and, because every element is
//! still computed by the exact same scalar instruction sequence, results
//! are **bit-identical** to a serial run for any thread count.
//!
//! The pool is a value type (`Copy`): handles are threaded through
//! `SimBackend`, `Trainer`, and `prepare_cpu` without lifetime plumbing,
//! and a `threads == 1` pool degrades to a plain serial call with zero
//! spawn overhead.

use anyhow::Result;

/// Scoped-thread worker pool; `threads` is the maximum worker count per
/// parallel region (clamped at construction to at least 1).
#[derive(Clone, Copy, Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::new(1)
    }
}

impl WorkerPool {
    pub fn new(threads: usize) -> Self {
        WorkerPool { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// How many workers a region over `rows` items actually uses, given a
    /// minimum chunk size (small problems stay serial).
    fn workers(&self, rows: usize, min_rows: usize) -> usize {
        self.threads.min(rows.div_ceil(min_rows.max(1))).max(1)
    }

    /// Partition `out` (treated as `rows` equal-width rows) into contiguous
    /// chunks and run `f(row_start, row_end, chunk)` on scoped threads.
    pub fn for_row_chunks<T: Send>(
        &self,
        out: &mut [T],
        rows: usize,
        min_rows: usize,
        f: impl Fn(usize, usize, &mut [T]) + Sync,
    ) {
        self.try_for_row_chunks(out, rows, min_rows, |r0, r1, chunk| {
            f(r0, r1, chunk);
            Ok(())
        })
        .expect("infallible worker closure");
    }

    /// Fallible variant of [`WorkerPool::for_row_chunks`]: the first worker
    /// error (in row order) is propagated.
    pub fn try_for_row_chunks<T: Send>(
        &self,
        out: &mut [T],
        rows: usize,
        min_rows: usize,
        f: impl Fn(usize, usize, &mut [T]) -> Result<()> + Sync,
    ) -> Result<()> {
        if rows == 0 {
            return Ok(());
        }
        let width = out.len() / rows;
        debug_assert_eq!(width * rows, out.len(), "out is not rows x width");
        let workers = self.workers(rows, min_rows);
        if workers <= 1 {
            return f(0, rows, out);
        }
        let chunk = rows.div_ceil(workers);
        let mut results: Vec<Result<()>> = Vec::new();
        std::thread::scope(|s| {
            let f = &f;
            let mut handles = Vec::new();
            let mut rest = out;
            let mut r0 = 0usize;
            while r0 < rows {
                let take = chunk.min(rows - r0);
                let (head, tail) = rest.split_at_mut(take * width);
                rest = tail;
                handles.push(s.spawn(move || f(r0, r0 + take, head)));
                r0 += take;
            }
            results = handles
                .into_iter()
                .map(|h| h.join().expect("pool worker panicked"))
                .collect();
        });
        for r in results {
            r?;
        }
        Ok(())
    }

    /// Like [`WorkerPool::try_for_row_chunks`] but partitions **two**
    /// row-aligned slices in lockstep (an output plus its per-row scratch):
    /// worker `i` gets rows `[r0, r1)` of both.
    pub fn try_for_row_chunks2<T: Send, U: Send>(
        &self,
        a: &mut [T],
        b: &mut [U],
        rows: usize,
        min_rows: usize,
        f: impl Fn(usize, usize, &mut [T], &mut [U]) -> Result<()> + Sync,
    ) -> Result<()> {
        if rows == 0 {
            return Ok(());
        }
        let wa = a.len() / rows;
        let wb = b.len() / rows;
        debug_assert_eq!(wa * rows, a.len(), "a is not rows x width");
        debug_assert_eq!(wb * rows, b.len(), "b is not rows x width");
        let workers = self.workers(rows, min_rows);
        if workers <= 1 {
            return f(0, rows, a, b);
        }
        let chunk = rows.div_ceil(workers);
        let mut results: Vec<Result<()>> = Vec::new();
        std::thread::scope(|s| {
            let f = &f;
            let mut handles = Vec::new();
            let mut rest_a = a;
            let mut rest_b = b;
            let mut r0 = 0usize;
            while r0 < rows {
                let take = chunk.min(rows - r0);
                let (ha, ta) = rest_a.split_at_mut(take * wa);
                let (hb, tb) = rest_b.split_at_mut(take * wb);
                rest_a = ta;
                rest_b = tb;
                handles.push(s.spawn(move || f(r0, r0 + take, ha, hb)));
                r0 += take;
            }
            results = handles
                .into_iter()
                .map(|h| h.join().expect("pool worker panicked"))
                .collect();
        });
        for r in results {
            r?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_all_rows_disjointly_any_thread_count() {
        for threads in [1, 2, 3, 5, 8] {
            let pool = WorkerPool::new(threads);
            let rows = 13;
            let width = 4;
            let mut out = vec![0u32; rows * width];
            pool.for_row_chunks(&mut out, rows, 1, |r0, r1, chunk| {
                assert_eq!(chunk.len(), (r1 - r0) * width);
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (r0 * width + i) as u32;
                }
            });
            let expect: Vec<u32> = (0..(rows * width) as u32).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn min_rows_keeps_small_problems_serial() {
        let pool = WorkerPool::new(8);
        assert_eq!(pool.workers(4, 16), 1);
        assert_eq!(pool.workers(64, 16), 4);
        assert_eq!(pool.workers(1000, 1), 8);
    }

    #[test]
    fn errors_propagate_from_workers() {
        let pool = WorkerPool::new(4);
        let mut out = vec![0f32; 16];
        let err = pool.try_for_row_chunks(&mut out, 16, 1, |r0, _, _| {
            if r0 >= 8 {
                anyhow::bail!("boom at {r0}")
            }
            Ok(())
        });
        assert!(err.is_err());
    }

    #[test]
    fn lockstep_partitions_align() {
        let pool = WorkerPool::new(3);
        let rows = 7;
        let mut a = vec![0u32; rows * 2];
        let mut b = vec![0u32; rows * 5];
        pool.try_for_row_chunks2(&mut a, &mut b, rows, 1, |r0, r1, ca, cb| {
            assert_eq!(ca.len(), (r1 - r0) * 2);
            assert_eq!(cb.len(), (r1 - r0) * 5);
            for v in ca.iter_mut() {
                *v = r0 as u32;
            }
            for v in cb.iter_mut() {
                *v = r0 as u32;
            }
            Ok(())
        })
        .unwrap();
        // Every row was visited exactly once (each chunk stamped its r0).
        assert!(a.iter().all(|&v| (v as usize) < rows));
        assert!(b.iter().all(|&v| (v as usize) < rows));
    }

    #[test]
    fn zero_rows_is_a_no_op() {
        let pool = WorkerPool::new(4);
        let mut out: Vec<f32> = Vec::new();
        pool.for_row_chunks(&mut out, 0, 1, |_, _, _| panic!("should not run"));
    }
}
