//! Deterministic fault-injection plane (DESIGN.md §9).
//!
//! A [`FaultPlan`] maps named sites × (epoch, global batch sequence) to an
//! injected-failure count. The plan is a pure function of
//! `--fault-spec`/`--fault-seed`: the same flags produce the same plan, so
//! every injected failure — and therefore every recovery path — replays
//! exactly. The recovery contract the plan exists to pin (DESIGN.md §5,
//! extended by §9): *a recovered run is bitwise identical to a fault-free
//! one*; only the retry/failover/shed counters differ.
//!
//! Sites:
//! * [`FaultSite::Dispatch`] — a transient backend dispatch error on the
//!   first kernel launch of the addressed batch. Recovered by the
//!   backend's bounded retry-with-backoff ([`MAX_DISPATCH_RETRIES`]).
//! * [`FaultSite::Producer`] — a sampling producer dies before delivering
//!   the addressed sequence number. The reorder ring reports the missing
//!   sequence and the consumer re-derives the batch from
//!   `(epoch_perm, seq)` on a standby producer.
//! * [`FaultSite::Lane`] — a replica lane's engine dies before computing
//!   the addressed batch. Surviving lanes absorb its remaining slots; the
//!   fixed-order all-reduce keeps the trajectory bitwise fault-free.
//! * [`FaultSite::LaneHard`] (spelled `lane!`) — a *persistent* lane
//!   failure on the serve path (DESIGN.md §10): the lane owning the
//!   addressed coalesced batch exhausts its dispatch retry budget and is
//!   quarantined. The batch re-dispatches to the next healthy lane in
//!   global batch order (predictions are lane-independent, so re-dispatch
//!   is bitwise invisible); the quarantined lane shadows subsequent
//!   batches and is re-admitted after a probation of successes. `xN`
//!   cascades the failure across `N` successive lanes at that batch.
//!   Consumed by the serve scheduler, never by the engine dispatch path.
//!
//! Data-corruption sites (DESIGN.md §11) — these inject *wrong bytes*, not
//! scheduling failures, and exist to exercise the integrity plane
//! (`--guard`, `--audit-every`):
//! * [`FaultSite::Flip`] (spelled `flip!`) — flip one mantissa bit of one
//!   f32 in the addressed batch's collected feature slab between produce
//!   and consume. The value stays finite: only the source checksum
//!   (`--guard`) can catch it. `xN` re-corrupts the first `N`
//!   re-derivations too (recompute → rollback → bail ladder).
//! * [`FaultSite::Nan`] (spelled `nan!`) — poison the addressed batch's
//!   gradient with a NaN after the backward pass. Caught pre-apply by the
//!   `--guard` non-finite scan, or post-apply by the `--audit-every`
//!   parameter audit (the rollback exerciser).
//! * [`FaultSite::Wire`] (spelled `wire!`) — flip one mantissa bit of the
//!   first f32 H2D/p2p payload uploaded after the addressed cursor
//!   (miss-row slabs, parameter broadcasts). With the backend integrity
//!   guard on, the copy is digest-verified against its source and
//!   retransmitted (`Counters::integrity_retransmits`); without it the
//!   corrupt payload lands silently. `xN` corrupts `N` successive
//!   transmissions (past [`MAX_DISPATCH_RETRIES`] = hard error).
//!
//! Spec grammar (comma-separated entries):
//! * `site@EPOCH:SEQ` — one failure at that address.
//! * `site@EPOCH:SEQxN` — `N` back-to-back failures at that address
//!   (e.g. to exercise the retry bound).
//! * `site~PERIOD` — a seeded pseudo-random sprinkle: the site fails once
//!   at every `(epoch, seq)` whose keyed hash is `0 (mod PERIOD)`. Pure in
//!   `--fault-seed`, so the sprinkle is schedule-addressed without knowing
//!   the schedule length.
//!
//! With no plan attached (the default) every probe site is a single
//! `Option` check — the plane is zero-cost when off.

use anyhow::{bail, Context, Result};

/// Upper bound on back-to-back dispatch retries before the error is
/// surfaced to the caller (the "bounded" in bounded retry).
pub const MAX_DISPATCH_RETRIES: u32 = 3;

/// A named injection point (see module docs for recovery semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultSite {
    Dispatch,
    Producer,
    Lane,
    /// Persistent lane failure (`lane!`): serve-path quarantine trigger.
    LaneHard,
    /// Feature-slab bit flip (`flip!`): silent host-buffer corruption.
    Flip,
    /// Gradient NaN poisoning (`nan!`): numeric-divergence injection.
    Nan,
    /// H2D/p2p payload corruption (`wire!`): transfer-channel bit flip.
    Wire,
}

impl FaultSite {
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::Dispatch => "dispatch",
            FaultSite::Producer => "producer",
            FaultSite::Lane => "lane",
            FaultSite::LaneHard => "lane!",
            FaultSite::Flip => "flip!",
            FaultSite::Nan => "nan!",
            FaultSite::Wire => "wire!",
        }
    }

    fn tag(self) -> u64 {
        match self {
            FaultSite::Dispatch => 0xD15B,
            FaultSite::Producer => 0xB0D0,
            FaultSite::Lane => 0x1A9E,
            FaultSite::LaneHard => 0x1AFE,
            FaultSite::Flip => 0xF11B,
            FaultSite::Nan => 0x7FC0, // the quiet-NaN exponent bits

            FaultSite::Wire => 0x3157,
        }
    }

    /// Every site, in grammar-table order (docs and round-trip tests).
    pub const ALL: [FaultSite; 7] = [
        FaultSite::Dispatch,
        FaultSite::Producer,
        FaultSite::Lane,
        FaultSite::LaneHard,
        FaultSite::Flip,
        FaultSite::Nan,
        FaultSite::Wire,
    ];

    /// `true` for the data-corruption sites (`flip!`/`nan!`/`wire!`) —
    /// the sites the integrity plane (DESIGN.md §11) injects and recovers.
    pub fn is_integrity(self) -> bool {
        matches!(self, FaultSite::Flip | FaultSite::Nan | FaultSite::Wire)
    }

    fn parse(s: &str) -> Result<Self> {
        match s {
            "dispatch" => Ok(FaultSite::Dispatch),
            "producer" => Ok(FaultSite::Producer),
            "lane" => Ok(FaultSite::Lane),
            "lane!" => Ok(FaultSite::LaneHard),
            "flip!" => Ok(FaultSite::Flip),
            "nan!" => Ok(FaultSite::Nan),
            "wire!" => Ok(FaultSite::Wire),
            other => bail!(
                "unknown fault site {other:?} (expected dispatch, producer, lane, lane!, \
                 flip!, nan!, or wire!)"
            ),
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
enum Rule {
    /// Fail `count` times at exactly `(epoch, seq)`.
    At { site: FaultSite, epoch: u64, seq: u64, count: u32 },
    /// Fail once at every `(epoch, seq)` whose seeded hash ≡ 0 (mod period).
    Every { site: FaultSite, period: u64 },
}

/// The full injection schedule. Addressing is by `(site, epoch, seq)`
/// where `seq` is the global batch sequence number within the epoch
/// (serve runs address epoch 0, seq = coalesced batch index).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    rules: Vec<Rule>,
    seed: u64,
}

/// SplitMix64 finalizer over the (seed, site, epoch, seq) address — the
/// pure hash behind `site~PERIOD` rules.
fn mix(seed: u64, tag: u64, epoch: u64, seq: u64) -> u64 {
    let mut z = seed
        .wrapping_add(tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(epoch.wrapping_mul(0xBF58_476D_1CE4_E5B9))
        .wrapping_add(seq.wrapping_mul(0x94D0_49BB_1331_11EB));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// Parse a `--fault-spec` string under a `--fault-seed`. Empty specs
    /// are rejected — "no plan" is expressed by not attaching one.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut rules = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            rules.push(Self::parse_entry(entry).with_context(|| {
                format!("bad --fault-spec entry {entry:?}")
            })?);
        }
        if rules.is_empty() {
            bail!("--fault-spec {spec:?} contains no entries");
        }
        Ok(FaultPlan { rules, seed })
    }

    fn parse_entry(entry: &str) -> Result<Rule> {
        if let Some((site, addr)) = entry.split_once('@') {
            let site = FaultSite::parse(site)?;
            let (addr, count) = match addr.split_once('x') {
                Some((a, n)) => {
                    (a, n.parse::<u32>().context("count after 'x' must be a u32")?)
                }
                None => (addr, 1),
            };
            if count == 0 {
                bail!("count must be >= 1");
            }
            let (e, s) = addr
                .split_once(':')
                .context("expected site@EPOCH:SEQ (e.g. dispatch@0:3)")?;
            Ok(Rule::At {
                site,
                epoch: e.parse().context("epoch must be a u64")?,
                seq: s.parse().context("seq must be a u64")?,
                count,
            })
        } else if let Some((site, period)) = entry.split_once('~') {
            let site = FaultSite::parse(site)?;
            let period: u64 = period.parse().context("period must be a u64")?;
            if period == 0 {
                bail!("period must be >= 1");
            }
            Ok(Rule::Every { site, period })
        } else {
            bail!("expected site@EPOCH:SEQ[xN] or site~PERIOD");
        }
    }

    /// How many injected failures fire for `site` at `(epoch, seq)`.
    /// Pure: same plan, same address → same answer, every call.
    pub fn fires(&self, site: FaultSite, epoch: u64, seq: u64) -> u32 {
        let mut n = 0u32;
        for r in &self.rules {
            match *r {
                Rule::At { site: s, epoch: e, seq: q, count }
                    if s == site && e == epoch && q == seq =>
                {
                    n += count;
                }
                Rule::Every { site: s, period }
                    if s == site && mix(self.seed, s.tag(), epoch, seq) % period == 0 =>
                {
                    n += 1;
                }
                _ => {}
            }
        }
        n
    }

    /// Whether the plan contains any rule for `site` at all — lets callers
    /// skip standby setup entirely when a site is never exercised.
    pub fn has_site(&self, site: FaultSite) -> bool {
        self.rules.iter().any(|r| match *r {
            Rule::At { site: s, .. } | Rule::Every { site: s, .. } => s == site,
        })
    }

    /// Whether the plan carries any data-corruption site (`flip!`/`nan!`/
    /// `wire!`) — the integrity plane arms its consume-time injection and
    /// standby producers only when this is true, so plans without
    /// corruption sites keep the classic zero-cost paths.
    pub fn has_integrity_site(&self) -> bool {
        FaultSite::ALL.iter().any(|&s| s.is_integrity() && self.has_site(s))
    }

    /// Deterministic corruption-target selector for `site` at
    /// `(epoch, seq)`: which element / which bit a `flip!`/`wire!` rule
    /// perturbs is derived from this hash, so the corruption — like the
    /// schedule — is a pure function of `(--fault-spec, --fault-seed)`.
    /// Salted away from the firing hash so target choice never correlates
    /// with `site~PERIOD` selection.
    pub fn target_hash(&self, site: FaultSite, epoch: u64, seq: u64) -> u64 {
        mix(self.seed ^ 0x7A26_E7B1_D00D_FEED, site.tag(), epoch, seq)
    }

    /// Total explicit (`site@e:s`) failures planned for `site` — the
    /// expected counter value when only explicit rules are used.
    pub fn planned(&self, site: FaultSite) -> u64 {
        self.rules
            .iter()
            .map(|r| match *r {
                Rule::At { site: s, count, .. } if s == site => count as u64,
                _ => 0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_entries_address_exactly() {
        let p = FaultPlan::parse("dispatch@0:3x2,producer@1:5,lane@0:4", 7).unwrap();
        assert_eq!(p.fires(FaultSite::Dispatch, 0, 3), 2);
        assert_eq!(p.fires(FaultSite::Dispatch, 0, 4), 0);
        assert_eq!(p.fires(FaultSite::Dispatch, 1, 3), 0);
        assert_eq!(p.fires(FaultSite::Producer, 1, 5), 1);
        assert_eq!(p.fires(FaultSite::Producer, 0, 5), 0);
        assert_eq!(p.fires(FaultSite::Lane, 0, 4), 1);
        assert!(p.has_site(FaultSite::Dispatch));
        assert_eq!(p.planned(FaultSite::Dispatch), 2);
        assert_eq!(p.planned(FaultSite::Lane), 1);
    }

    #[test]
    fn seeded_sprinkle_is_pure_and_seed_sensitive() {
        let a = FaultPlan::parse("dispatch~4", 1).unwrap();
        let b = FaultPlan::parse("dispatch~4", 1).unwrap();
        let c = FaultPlan::parse("dispatch~4", 2).unwrap();
        let hits = |p: &FaultPlan| -> Vec<(u64, u64)> {
            let mut v = Vec::new();
            for e in 0..4u64 {
                for s in 0..64u64 {
                    if p.fires(FaultSite::Dispatch, e, s) > 0 {
                        v.push((e, s));
                    }
                }
            }
            v
        };
        let (ha, hb, hc) = (hits(&a), hits(&b), hits(&c));
        assert_eq!(ha, hb, "same seed must give the same sprinkle");
        assert!(!ha.is_empty(), "period 4 over 256 addresses should fire");
        assert_ne!(ha, hc, "different seeds should move the sprinkle");
        // The sprinkle never bleeds across sites.
        assert!(hits(&a)
            .iter()
            .all(|&(e, s)| a.fires(FaultSite::Producer, e, s) == 0));
    }

    #[test]
    fn lane_hard_parses_distinctly_from_lane() {
        let p = FaultPlan::parse("lane!@0:2x2,lane@0:2", 0).unwrap();
        assert_eq!(p.fires(FaultSite::LaneHard, 0, 2), 2);
        assert_eq!(p.fires(FaultSite::Lane, 0, 2), 1);
        assert_eq!(p.planned(FaultSite::LaneHard), 2);
        assert!(p.has_site(FaultSite::LaneHard));
        let q = FaultPlan::parse("lane@0:2", 0).unwrap();
        assert!(!q.has_site(FaultSite::LaneHard), "lane must not imply lane!");
        // The sprinkle form works for lane! too, and stays site-disjoint.
        let r = FaultPlan::parse("lane!~4", 9).unwrap();
        let hard: Vec<u64> =
            (0..64).filter(|&s| r.fires(FaultSite::LaneHard, 0, s) > 0).collect();
        assert!(!hard.is_empty(), "period 4 over 64 addresses should fire");
        assert!(hard.iter().all(|&s| r.fires(FaultSite::Lane, 0, s) == 0));
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            " , ",
            "dispatch",
            "dispatch@3",
            "dispatch@0:1x0",
            "dispatch~0",
            "gpu@0:1",
            "dispatch@a:b",
            "lane~x",
        ] {
            assert!(
                FaultPlan::parse(bad, 0).is_err(),
                "spec {bad:?} should be rejected"
            );
        }
    }

    #[test]
    fn default_plan_fires_nowhere() {
        let p = FaultPlan::default();
        assert_eq!(p.fires(FaultSite::Dispatch, 0, 0), 0);
        assert!(!p.has_site(FaultSite::Lane));
        assert!(!p.has_integrity_site());
    }

    /// Every documented site round-trips through both grammar forms: its
    /// printed name parses back to the same site, addresses exactly, and
    /// never bleeds into another site (the README grammar table's
    /// contract).
    #[test]
    fn every_site_round_trips_through_both_grammar_forms() {
        for &site in &FaultSite::ALL {
            let name = site.name();
            // Explicit form, with a count.
            let spec = format!("{name}@2:7x3");
            let p = FaultPlan::parse(&spec, 11).unwrap();
            assert_eq!(p.fires(site, 2, 7), 3, "{name}: explicit address");
            assert_eq!(p.fires(site, 2, 6), 0, "{name}: wrong seq");
            assert_eq!(p.fires(site, 1, 7), 0, "{name}: wrong epoch");
            assert_eq!(p.planned(site), 3, "{name}: planned count");
            assert!(p.has_site(site), "{name}: has_site");
            for &other in &FaultSite::ALL {
                if other != site {
                    assert_eq!(p.fires(other, 2, 7), 0, "{name} bled into {}", other.name());
                    assert!(!p.has_site(other));
                }
            }
            // Sprinkle form fires somewhere in a modest address window.
            let q = FaultPlan::parse(&format!("{name}~4"), 5).unwrap();
            let hits = (0..4u64)
                .flat_map(|e| (0..64u64).map(move |s| (e, s)))
                .filter(|&(e, s)| q.fires(site, e, s) > 0)
                .count();
            assert!(hits > 0, "{name}~4 never fired over 256 addresses");
            assert!(q.has_site(site));
        }
    }

    #[test]
    fn integrity_sites_are_flagged_and_target_hash_is_pure() {
        let p = FaultPlan::parse("flip!@0:2,nan!~8,wire!@1:0x2", 3).unwrap();
        assert!(p.has_integrity_site());
        assert!(FaultSite::Flip.is_integrity());
        assert!(FaultSite::Nan.is_integrity());
        assert!(FaultSite::Wire.is_integrity());
        assert!(!FaultSite::Dispatch.is_integrity());
        assert!(!FaultSite::LaneHard.is_integrity());
        let q = FaultPlan::parse("dispatch~4,lane!@0:1", 3).unwrap();
        assert!(!q.has_integrity_site(), "scheduling sites must not arm integrity");
        // Target selection: pure in (plan, address), distinct across
        // addresses and sites, and stable across calls.
        let a = p.target_hash(FaultSite::Flip, 0, 2);
        assert_eq!(a, p.target_hash(FaultSite::Flip, 0, 2));
        assert_ne!(a, p.target_hash(FaultSite::Flip, 0, 3));
        assert_ne!(a, p.target_hash(FaultSite::Wire, 0, 2));
    }
}
