//! FNV-1a digests over f32 bit patterns (DESIGN.md §11).
//!
//! The integrity plane compares *bytes*, not values: a digest folds every
//! element's `f32::to_bits()` into a 64-bit FNV-1a state, so two buffers
//! digest equal iff they are bitwise equal — `-0.0` vs `0.0` and NaN
//! payloads all count. The same constants back the serve plane's
//! `prediction_digest` (two report lines compare equal iff the runs are
//! bitwise identical), and every consumer (`Params::digest`, the cache
//! slab audit, the collected-slab source checksum) goes through these two
//! helpers so "params digest" means one thing everywhere.

/// FNV-1a offset basis (64-bit).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime (64-bit).
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold a slice of f32 bit patterns into an existing digest state.
#[inline]
pub fn fnv1a_extend(mut h: u64, xs: &[f32]) -> u64 {
    for &v in xs {
        h = (h ^ v.to_bits() as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Digest one f32 slice from the offset basis.
#[inline]
pub fn fnv1a_f32(xs: &[f32]) -> u64 {
    fnv1a_extend(FNV_OFFSET, xs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_is_bit_sensitive_and_order_sensitive() {
        let a = [1.0f32, 2.0, 3.0];
        assert_eq!(fnv1a_f32(&a), fnv1a_f32(&[1.0, 2.0, 3.0]));
        assert_ne!(fnv1a_f32(&a), fnv1a_f32(&[1.0, 3.0, 2.0]), "order must matter");
        // One mantissa bit moves the digest (the flip!/wire! detection
        // primitive: value-near, bitwise-far).
        let mut b = a;
        b[1] = f32::from_bits(b[1].to_bits() ^ 1);
        assert_ne!(fnv1a_f32(&a), fnv1a_f32(&b));
        // Sign of zero is a bit pattern too.
        assert_ne!(fnv1a_f32(&[0.0]), fnv1a_f32(&[-0.0]));
    }

    #[test]
    fn extend_chains_like_one_pass() {
        let xs = [4.0f32, -1.5, 0.25, 9.0];
        let whole = fnv1a_f32(&xs);
        let split = fnv1a_extend(fnv1a_extend(FNV_OFFSET, &xs[..2]), &xs[2..]);
        assert_eq!(whole, split);
    }
}
