//! Small self-contained utilities (the offline environment has no `rand`,
//! `serde`, or `criterion`, so the PRNG, stats, and timing helpers live
//! here).

pub mod digest;
pub mod fault;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod tensor;

pub use digest::{fnv1a_extend, fnv1a_f32};
pub use fault::{FaultPlan, FaultSite, MAX_DISPATCH_RETRIES};
pub use pool::WorkerPool;
pub use rng::Rng;
pub use stats::Stats;
pub use tensor::HostTensor;
