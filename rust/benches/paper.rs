//! Paper benchmark harness (criterion is unavailable offline; this is a
//! hand-rolled `harness = false` bench): regenerates **every table and
//! figure** of the paper's evaluation into `results/`:
//!
//!   Table 2  dataset statistics                    -> table2_datasets.md
//!   Fig. 3   kernel timeline + roofline            -> fig3_timeline.csv / fig3_roofline.csv
//!   Table 1  CPU vs GPU time per epoch             -> table1_cpu_gpu.md
//!   Fig. 7   HiFuse vs PyG speedup (8 combos + GM) -> fig7_speedup.{md,csv}
//!   Fig. 8   kernels/epoch + reduction ratio       -> fig8_kernels.{md,csv}
//!   Fig. 9   ablation ladder speedups              -> fig9_ablation.{md,csv}
//!   Fig. 10  CPU:GPU time ratio, PyG vs HiFuse     -> fig10_ratio.{md,csv}
//!   Fig. 11  fwd-stage kernel reduction            -> fig11_stage_kernels.{md,csv}
//!   Table 3  scatter-kernel throughput             -> table3_throughput.md
//!
//! Dataset scales: schema (types/relations) is NEVER scaled; node/edge
//! counts are scaled per the table below so the full matrix finishes on
//! one core in minutes (absolute times therefore differ from the paper's
//! T4; the *shape* — who wins, by what factor — is the reproduction
//! target). Override with HIFUSE_BENCH_SCALE=<f> or HIFUSE_BENCH_QUICK=1.

use std::collections::HashMap;
use std::time::Instant;

use hifuse::coordinator::{
    prepare_graph_layout, producer_count, replica_thread_budget, CpuProducer, OptConfig,
    ReplicaGroup, TrainCfg, Trainer, DEFAULT_ROUND,
};
use hifuse::graph::datasets::{generate, spec_by_name, DATASETS};
use hifuse::graph::HeteroGraph;
use hifuse::models::step::Dims;
use hifuse::models::ModelKind;
use hifuse::perf;
use hifuse::report::{f2, geomean, results_dir, write_csv, write_md_table};
use hifuse::runtime::{ExecBackend, Phase, SimBackend, Stage};
use hifuse::sampler::SamplerCfg;
use hifuse::util::{Rng, WorkerPool};

/// Per-dataset node/edge scale used by the measured matrix (documented in
/// EXPERIMENTS.md; schema is never scaled).
fn dataset_scale(name: &str, quick: bool) -> f64 {
    let base = match name {
        "aifb" => 1.0,
        "mutag" => 0.5,
        "bgs" => 0.2,
        "am" => 0.02,
        _ => 1.0,
    };
    let mult: f64 = std::env::var("HIFUSE_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    if quick {
        (base * mult * 0.25).min(1.0)
    } else {
        (base * mult).min(1.0)
    }
}

#[derive(Clone, Debug)]
struct RunRow {
    dataset: &'static str,
    model: ModelKind,
    mode: String,
    wall_ms: f64,
    cpu_ms: f64,
    gpu_ms: f64,
    kernels: usize,
    fwd_semantic: usize,
    fwd_agg: usize,
    loss: f64,
    /// Per-stage dispatch time, ms (name, ms).
    gpu_ms_by_stage: Vec<(&'static str, f64)>,
    /// Per-stage kernel counts (name, count).
    kernels_by_stage: Vec<(&'static str, usize)>,
    /// Arena misses per training step over the measured epoch (~0 when the
    /// buffer pool is warm; includes warm-up allocations in quick mode).
    allocs_per_step: f64,
    /// Per-stage CPU producer time, ms: (sample, select, collect).
    cpu_stage_ms: (f64, f64, f64),
    /// Host→device bytes over the measured epoch (dispatch argument
    /// uploads + the explicit feature channel).
    h2d_bytes: u64,
    /// Device→host bytes over the measured epoch (loss/accuracy scalar
    /// readbacks in training; the serve path's logits readback).
    d2h_bytes: u64,
    /// Feature-cache hit rate over the measured epoch (0.0 = cache off;
    /// the main matrix runs cache-off, the cache_sweep bench varies it).
    cache_hit_rate: f64,
}

/// One measured epoch. Full mode runs a warm-up epoch first (compiles
/// every module, fills the buffer arena); HIFUSE_BENCH_QUICK=1 skips the
/// warm-up epoch too, not just the dataset scale.
fn run_one<B: ExecBackend>(
    eng: &B,
    graph: &mut HeteroGraph,
    dataset: &'static str,
    model: ModelKind,
    mode: &str,
    cfg: TrainCfg,
    quick: bool,
) -> RunRow {
    let opt = OptConfig::parse(mode).unwrap();
    prepare_graph_layout(graph, &opt);
    let mut tr = Trainer::new(eng, graph, model, opt, cfg).unwrap();
    let misses0 = if quick {
        eng.counters().borrow().arena.misses
    } else {
        tr.train_epoch(0).unwrap().arena.misses
    };
    let m = tr.train_epoch(if quick { 0 } else { 1 }).unwrap();
    RunRow {
        dataset,
        model,
        mode: mode.to_string(),
        wall_ms: m.wall.as_secs_f64() * 1e3,
        cpu_ms: m.cpu_time.as_secs_f64() * 1e3,
        gpu_ms: m.gpu_time.as_secs_f64() * 1e3,
        kernels: m.kernels_total,
        fwd_semantic: m.kernels_fwd_semantic,
        fwd_agg: m.kernels_fwd_agg,
        loss: m.loss,
        gpu_ms_by_stage: m
            .time_by_stage
            .iter()
            .map(|&(s, t)| (s.name(), t.as_secs_f64() * 1e3))
            .collect(),
        kernels_by_stage: m.kernels_by_stage.iter().map(|&(s, c)| (s.name(), c)).collect(),
        allocs_per_step: (m.arena.misses.saturating_sub(misses0)) as f64
            / m.batches.max(1) as f64,
        cpu_stage_ms: (
            m.cpu_by_stage.sample.as_secs_f64() * 1e3,
            m.cpu_by_stage.select.as_secs_f64() * 1e3,
            m.cpu_by_stage.collect.as_secs_f64() * 1e3,
        ),
        h2d_bytes: m.h2d_bytes,
        d2h_bytes: m.d2h_bytes,
        cache_hit_rate: m.cache_hit_rate(),
    }
}

fn combo_label(r: &RunRow) -> String {
    format!("{}-{}", r.model.name().to_uppercase(), r.dataset.to_uppercase())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("HIFUSE_BENCH_QUICK").is_ok();
    let t0 = Instant::now();
    // The full figure matrix runs on the self-contained sim backend (the
    // dispatch counts are backend-invariant; wall-clock shape is preserved
    // because every dispatch pays the same measured launch overhead).
    // threads=4 drives CPU stages AND sim kernel row-parallelism.
    let cfg = TrainCfg {
        epochs: 2,
        batch_size: 64,
        fanout: 4,
        lr: 0.05,
        seed: 42,
        threads: 4,
        producers: 0,
    };
    let eng = SimBackend::builtin_threaded("bench", cfg.threads)?;
    let d = Dims::from_backend(&eng);

    // ---------------- Table 2: dataset statistics --------------------------
    let rows: Vec<Vec<String>> = DATASETS
        .iter()
        .map(|s| {
            vec![
                s.name.to_string(),
                s.nodes.to_string(),
                s.edges.to_string(),
                s.n_types.to_string(),
                s.n_relations.to_string(),
            ]
        })
        .collect();
    write_md_table(
        "table2_datasets.md",
        "Table 2 — benchmark datasets (schema-exact synthetic stand-ins)",
        &["dataset", "#nodes", "#edges", "#node types", "#edge relations"],
        &rows,
    )?;

    // ---------------- main matrix: 4 datasets x 2 models x 2 modes ---------
    let mut matrix: Vec<RunRow> = Vec::new();
    let mut graphs: HashMap<&'static str, HeteroGraph> = HashMap::new();
    for spec in DATASETS {
        let scale = dataset_scale(spec.name, quick);
        eprintln!("[bench] generating {} at scale {scale} ...", spec.name);
        graphs.insert(spec.name, generate(&spec, d.f, scale, cfg.seed));
    }
    for spec in DATASETS {
        for model in [ModelKind::Rgcn, ModelKind::Rgat] {
            for mode in ["base", "hifuse"] {
                eprintln!("[bench] {} {} {} ...", spec.name, model.name(), mode);
                let g = graphs.get_mut(spec.name).unwrap();
                matrix.push(run_one(&eng, g, spec.name, model, mode, cfg, quick));
            }
        }
    }
    let get = |ds: &str, m: ModelKind, mode: &str| -> &RunRow {
        matrix
            .iter()
            .find(|r| r.dataset == ds && r.model == m && r.mode == mode)
            .unwrap()
    };

    // ---------------- Fig. 7: speedup over the PyG baseline ----------------
    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    for spec in DATASETS {
        for model in [ModelKind::Rgcn, ModelKind::Rgat] {
            let b = get(spec.name, model, "base");
            let h = get(spec.name, model, "hifuse");
            let s = b.wall_ms / h.wall_ms;
            speedups.push(s);
            rows.push(vec![
                combo_label(b),
                f2(b.wall_ms),
                f2(h.wall_ms),
                f2(s),
            ]);
        }
    }
    rows.push(vec!["GM".into(), "".into(), "".into(), f2(geomean(&speedups))]);
    write_md_table(
        "fig7_speedup.md",
        "Fig. 7 — speedup of HiFuse over the PyG-style baseline (per epoch)",
        &["workload", "baseline ms", "hifuse ms", "speedup x"],
        &rows,
    )?;
    write_csv(
        "fig7_speedup.csv",
        &["workload", "baseline_ms", "hifuse_ms", "speedup"],
        &rows,
    )?;

    // ---------------- Fig. 8: kernel counts + reduction ratio --------------
    let mut rows = Vec::new();
    for spec in DATASETS {
        for model in [ModelKind::Rgcn, ModelKind::Rgat] {
            let b = get(spec.name, model, "base");
            let h = get(spec.name, model, "hifuse");
            let red = 100.0 * (1.0 - h.kernels as f64 / b.kernels as f64);
            rows.push(vec![
                combo_label(b),
                b.kernels.to_string(),
                h.kernels.to_string(),
                f2(red),
            ]);
        }
    }
    write_md_table(
        "fig8_kernels.md",
        "Fig. 8 — kernel launches per epoch and reduction ratio",
        &["workload", "baseline kernels", "hifuse kernels", "reduction %"],
        &rows,
    )?;
    write_csv("fig8_kernels.csv", &["workload", "base", "hifuse", "reduction_pct"], &rows)?;

    // ---------------- Table 1 + Fig. 10: CPU vs GPU time -------------------
    let mut t1 = Vec::new();
    for model in [ModelKind::Rgcn, ModelKind::Rgat] {
        let b = get("am", model, "base");
        t1.push(vec![
            format!("{}-AM", model.name().to_uppercase()),
            f2(b.cpu_ms),
            f2(b.gpu_ms),
            format!("{:.4}", b.cpu_ms / b.gpu_ms),
        ]);
    }
    write_md_table(
        "table1_cpu_gpu.md",
        "Table 1 — baseline CPU and GPU execution time per epoch",
        &["workload", "CPU ms", "GPU ms", "CPU/GPU ratio"],
        &t1,
    )?;

    let mut rows = Vec::new();
    for spec in DATASETS {
        for model in [ModelKind::Rgcn, ModelKind::Rgat] {
            let b = get(spec.name, model, "base");
            let h = get(spec.name, model, "hifuse");
            rows.push(vec![
                combo_label(b),
                f2(b.cpu_ms / b.gpu_ms),
                f2(h.cpu_ms / h.gpu_ms),
            ]);
        }
    }
    write_md_table(
        "fig10_ratio.md",
        "Fig. 10 — ratio of CPU time to GPU time (closer to 1 = better balance)",
        &["workload", "baseline ratio", "hifuse ratio"],
        &rows,
    )?;
    write_csv("fig10_ratio.csv", &["workload", "base_ratio", "hifuse_ratio"], &rows)?;

    // ---------------- Fig. 11: per-stage forward kernel reduction ----------
    let mut rows = Vec::new();
    for spec in DATASETS {
        for model in [ModelKind::Rgcn, ModelKind::Rgat] {
            let b = get(spec.name, model, "base");
            let h = get(spec.name, model, "hifuse");
            let sel = 100.0 * (b.fwd_semantic - h.fwd_semantic) as f64 / b.kernels as f64;
            let agg = 100.0 * (b.fwd_agg - h.fwd_agg) as f64 / b.kernels as f64;
            rows.push(vec![combo_label(b), f2(sel), f2(agg)]);
        }
    }
    write_md_table(
        "fig11_stage_kernels.md",
        "Fig. 11 — kernel reduction by stage (share of baseline kernels, fwd pass)",
        &["workload", "edge-index selection %", "neighbor aggregation %"],
        &rows,
    )?;
    write_csv("fig11_stage_kernels.csv", &["workload", "select_pct", "agg_pct"], &rows)?;

    // ---------------- Fig. 9: ablation ladder ------------------------------
    // Extra configs beyond base/hifuse already measured; keep the ladder on
    // every workload like the paper (quick mode: aifb only).
    let mut rows = Vec::new();
    let lad: Vec<(&str, OptConfig)> = OptConfig::ablation_ladder();
    for spec in DATASETS {
        if quick && spec.name != "aifb" {
            continue;
        }
        for model in [ModelKind::Rgcn, ModelKind::Rgat] {
            let mut walls = Vec::new();
            for (mode, _) in &lad {
                let r = if *mode == "base" || *mode == "HiFuse" {
                    let m = if *mode == "base" { "base" } else { "hifuse" };
                    get(spec.name, model, m).clone()
                } else {
                    let g = graphs.get_mut(spec.name).unwrap();
                    run_one(&eng, g, spec.name, model, mode, cfg, quick)
                };
                walls.push(r.wall_ms);
            }
            let base = walls[0];
            let mut row = vec![format!("{}-{}", model.name().to_uppercase(), spec.name.to_uppercase())];
            row.extend(walls.iter().map(|w| f2(base / w)));
            rows.push(row);
        }
    }
    write_md_table(
        "fig9_ablation.md",
        "Fig. 9 — speedup over baseline per optimization config",
        &["workload", "base", "R", "R+M", "R+O+P", "HiFuse"],
        &rows,
    )?;
    write_csv("fig9_ablation.csv", &["workload", "base", "R", "R_M", "R_O_P", "HiFuse"], &rows)?;

    // ---------------- Fig. 3 + Table 3: profile one am batch ---------------
    let peaks = perf::calibrate(&eng)?;
    let g = graphs.get_mut("am").unwrap();
    let scfg = SamplerCfg { batch_size: 64, fanout: 4, layers: 2, ns: d.ns, ep: d.ep };
    let mut t3 = Vec::new();
    let mut fig3_rows = Vec::new();
    let mut roof_rows = Vec::new();
    for model in [ModelKind::Rgcn, ModelKind::Rgat] {
        let mut agg_stats: HashMap<&str, (f64, f64, f64)> = HashMap::new(); // mode -> (dur_s, flops, bytes)
        for mode in ["base", "hifuse"] {
            let opt = OptConfig::parse(mode).unwrap();
            prepare_graph_layout(g, &opt);
            let mut tr = Trainer::new(&eng, g, model, opt, cfg)?;
            // Persistent producer so the Fig. 3 timeline window measures
            // batch preparation, not the scratch's one-time construction.
            let mut producer = CpuProducer::new(g, scfg, d, opt, WorkerPool::new(1), Rng::new(1));
            let prep = producer.produce(0, 0);
            tr.compute_batch(prep)?; // warm
            eng.reset_counters(true);
            let prep = producer.produce(0, 1);
            tr.compute_batch(prep)?;
            let counters = eng.counters().borrow();
            // Fig 3 artifacts come from the RGCN baseline batch (paper's setup).
            if model == ModelKind::Rgcn && mode == "base" {
                for e in &counters.events {
                    fig3_rows.push(vec![
                        format!("{:.1}", e.t_start.as_secs_f64() * 1e6),
                        format!("{:.1}", e.dur.as_secs_f64() * 1e6),
                        e.module.to_string(),
                        e.stage.name().to_string(),
                    ]);
                }
                for r in perf::roofline_rows(&counters.events, &d, &peaks) {
                    roof_rows.push(vec![
                        r.module.to_string(),
                        format!("{:.4}", r.ai),
                        format!("{:.3}", r.achieved_gflops),
                        format!("{:.2}", r.compute_pct),
                        format!("{:.2}", r.memory_pct),
                        r.memory_bound.to_string(),
                    ]);
                }
            }
            // Table 3: the aggregation-forward ("scatter") kernels.
            let (mut dur, mut fl, mut by) = (0.0, 0.0, 0.0);
            for e in counters.events.iter().filter(|e| {
                e.stage == Stage::Aggregation && e.phase == Phase::Fwd
            }) {
                let (f, b) = perf::module_cost(e.module, &d);
                dur += e.dur.as_secs_f64();
                fl += f;
                by += b;
            }
            agg_stats.insert(mode, (dur, fl, by));
        }
        let (bd, bf, bb) = agg_stats["base"];
        let (hd, hf, hb) = agg_stats["hifuse"];
        let bc = 100.0 * (bf / bd) / (peaks.gflops * 1e9);
        let bm = 100.0 * (bb / bd) / (peaks.membw_gbs * 1e9);
        let hc = 100.0 * (hf / hd) / (peaks.gflops * 1e9);
        let hm = 100.0 * (hb / hd) / (peaks.membw_gbs * 1e9);
        t3.push(vec![
            format!("{}-AM", model.name().to_uppercase()),
            format!("{bc:.2}%"),
            format!("{bm:.2}%"),
            format!("{hc:.2}%"),
            format!("{hm:.2}%"),
            f2(hc / bc.max(1e-9)),
            f2(hm / bm.max(1e-9)),
        ]);
    }
    write_csv("fig3_timeline.csv", &["t_us", "dur_us", "module", "stage"], &fig3_rows)?;
    write_csv(
        "fig3_roofline.csv",
        &["module", "ai", "gflops", "compute_pct", "memory_pct", "memory_bound"],
        &roof_rows,
    )?;
    write_md_table(
        "table3_throughput.md",
        "Table 3 — aggregation ('scatter') kernel compute/memory throughput",
        &["workload", "base compute", "base memory", "hifuse compute", "hifuse memory",
          "compute improv x", "memory improv x"],
        &t3,
    )?;

    // ---------------- replica scaling: data-parallel epoch walls -----------
    // RGCN/aifb with the full HiFuse plan, fanned out over 1/2/4 replica
    // backends sharing the same thread budget (DESIGN.md §4). The loss
    // column is the replica-parity witness: it must be identical in every
    // row (pinned bitwise by tests/replica_parity.rs).
    let mut rows = Vec::new();
    {
        let g = graphs.get_mut("aifb").unwrap();
        let opt = OptConfig::hifuse();
        prepare_graph_layout(g, &opt);
        for replicas in [1usize, 2, 4] {
            eprintln!("[bench] replicas={replicas} aifb rgcn hifuse ...");
            let mut group = ReplicaGroup::builtin(
                "bench",
                replicas,
                std::time::Duration::ZERO,
                g,
                ModelKind::Rgcn,
                opt,
                cfg,
                DEFAULT_ROUND,
            )?;
            if !quick {
                group.train_epoch(0)?; // warm the per-replica arenas
            }
            let m = group.train_epoch(if quick { 0 } else { 1 })?;
            let per = replica_thread_budget(cfg.threads, group.replicas());
            rows.push(vec![
                replicas.to_string(),
                per.to_string(),
                f2(m.group.wall.as_secs_f64() * 1e3),
                m.group.kernels_total.to_string(),
                format!("{:.6}", m.group.loss),
            ]);
        }
    }
    write_md_table(
        "replica_scaling.md",
        "Replica scaling — data-parallel epoch wall (loss identical by contract)",
        &["replicas", "threads/replica", "wall ms", "kernels", "loss"],
        &rows,
    )?;
    write_csv(
        "replica_scaling.csv",
        &["replicas", "threads_per_replica", "wall_ms", "kernels", "loss"],
        &rows,
    )?;

    // ---------------- producer scaling: multi-producer pipeline walls ------
    // RGCN/aifb with the full HiFuse plan (pipeline on), varying the CPU
    // sampling-worker count. The loss column is the parity witness — the
    // trajectory is bit-identical for every producer count
    // (tests/producer_parity.rs) — and the modeled column is the
    // work/span pipeline bound (perf::pipeline_model) fed with the
    // 1-producer row's measured CPU/GPU split (EXPERIMENTS.md §Perf #6).
    let mut rows = Vec::new();
    {
        let g = graphs.get_mut("aifb").unwrap();
        let opt = OptConfig::hifuse();
        prepare_graph_layout(g, &opt);
        let mut base_split: Option<(f64, f64, f64)> = None; // (cpu_s, gpu_s, wall_ms)
        for producers in [1usize, 2, 4] {
            eprintln!("[bench] producers={producers} aifb rgcn hifuse ...");
            let pcfg = TrainCfg { producers, ..cfg };
            let mut tr = Trainer::new(&eng, g, ModelKind::Rgcn, opt, pcfg)?;
            if !quick {
                tr.train_epoch(0)?; // warm the arena + producer pools
            }
            let m = tr.train_epoch(if quick { 0 } else { 1 })?;
            let wall_ms = m.wall.as_secs_f64() * 1e3;
            let (cpu_s, gpu_s) = (
                m.cpu_time.as_secs_f64() / m.batches.max(1) as f64,
                m.gpu_time.as_secs_f64() / m.batches.max(1) as f64,
            );
            if base_split.is_none() {
                base_split = Some((cpu_s, gpu_s, wall_ms));
            }
            let (b_cpu, b_gpu, b_wall) = base_split.unwrap();
            let modeled_x = perf::pipeline_model(b_cpu, b_gpu, 1)
                / perf::pipeline_model(b_cpu, b_gpu, producers);
            rows.push(vec![
                producers.to_string(),
                f2(wall_ms),
                f2(b_wall / wall_ms),
                f2(modeled_x),
                f2(m.cpu_by_stage.sample.as_secs_f64() * 1e3),
                f2(m.cpu_by_stage.select.as_secs_f64() * 1e3),
                f2(m.cpu_by_stage.collect.as_secs_f64() * 1e3),
                format!("{:.6}", m.loss),
            ]);
        }
    }
    write_md_table(
        "producer_scaling.md",
        "Producer scaling — multi-producer pipeline epoch wall (loss identical by contract)",
        &["producers", "wall ms", "speedup x", "modeled x", "sample ms", "select ms",
          "collect ms", "loss"],
        &rows,
    )?;
    write_csv(
        "producer_scaling.csv",
        &["producers", "wall_ms", "speedup", "modeled", "sample_ms", "select_ms", "collect_ms",
          "loss"],
        &rows,
    )?;

    // ---------------- BENCH_2.json: machine-readable perf trajectory -------
    let json_path = write_bench_json(&matrix, &cfg, quick, geomean(&speedups))?;
    eprintln!("[bench] wrote {json_path}");

    eprintln!("[bench] total {:?}; results in results/", t0.elapsed());
    Ok(())
}

/// Emit the perf-trajectory record: per-workload wall/cpu/gpu ms, per-stage
/// gpu **and** cpu-producer ms + kernel counts, and arena allocs-per-step,
/// plus an optional comparison against a pre-change baseline wall time
/// supplied via `HIFUSE_PRE_PR_WALL_MS` (the RGCN/aifb hifuse epoch wall of
/// the build being compared against, measured in the same environment).
/// Path: `HIFUSE_BENCH_JSON`, else `results/BENCH_2.json`.
fn write_bench_json(
    matrix: &[RunRow],
    cfg: &TrainCfg,
    quick: bool,
    geomean_speedup: f64,
) -> anyhow::Result<String> {
    let threads = cfg.threads;
    let mut runs = Vec::new();
    for r in matrix {
        let stages_ms: Vec<String> = r
            .gpu_ms_by_stage
            .iter()
            .map(|(s, ms)| format!("\"{s}\": {ms:.3}"))
            .collect();
        let stages_k: Vec<String> = r
            .kernels_by_stage
            .iter()
            .map(|(s, c)| format!("\"{s}\": {c}"))
            .collect();
        let (smp, sel, col) = r.cpu_stage_ms;
        runs.push(format!(
            "    {{\"dataset\": \"{}\", \"model\": \"{}\", \"mode\": \"{}\", \
             \"wall_ms\": {:.3}, \"cpu_ms\": {:.3}, \"gpu_ms\": {:.3}, \
             \"kernels\": {}, \"allocs_per_step\": {:.3}, \
             \"h2d_bytes\": {}, \"d2h_bytes\": {}, \"cache_hit_rate\": {:.4}, \
             \"cpu_ms_by_stage\": {{\"sample\": {smp:.3}, \"select\": {sel:.3}, \
             \"collect\": {col:.3}}}, \
             \"gpu_ms_by_stage\": {{{}}}, \"kernels_by_stage\": {{{}}}}}",
            r.dataset,
            r.model.name(),
            r.mode,
            r.wall_ms,
            r.cpu_ms,
            r.gpu_ms,
            r.kernels,
            r.allocs_per_step,
            r.h2d_bytes,
            r.d2h_bytes,
            r.cache_hit_rate,
            stages_ms.join(", "),
            stages_k.join(", ")
        ));
    }
    let hifuse_aifb_rgcn = matrix
        .iter()
        .find(|r| r.dataset == "aifb" && r.model == ModelKind::Rgcn && r.mode == "hifuse")
        .map(|r| r.wall_ms);
    let pre_pr: Option<f64> = std::env::var("HIFUSE_PRE_PR_WALL_MS")
        .ok()
        .and_then(|s| s.parse().ok());
    let speedup_vs_pre_pr = match (pre_pr, hifuse_aifb_rgcn) {
        (Some(pre), Some(now)) if now > 0.0 => format!("{:.3}", pre / now),
        _ => "null".to_string(),
    };
    let json = format!(
        "{{\n  \"schema\": \"hifuse-bench-2\",\n  \"profile\": \"bench\",\n  \
         \"threads\": {threads},\n  \"producers\": {},\n  \"quick\": {quick},\n  \
         \"measured\": true,\n  \
         \"geomean_speedup_hifuse_over_base\": {:.3},\n  \
         \"pre_pr_baseline_wall_ms\": {},\n  \
         \"epoch_wall_speedup_vs_pre_pr\": {},\n  \"runs\": [\n{}\n  ]\n}}\n",
        producer_count(cfg),
        geomean_speedup,
        pre_pr.map(|v| format!("{v:.3}")).unwrap_or_else(|| "null".to_string()),
        speedup_vs_pre_pr,
        runs.join(",\n")
    );
    let path = match std::env::var("HIFUSE_BENCH_JSON") {
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => results_dir()?.join("BENCH_2.json"),
    };
    std::fs::write(&path, json)?;
    Ok(path.display().to_string())
}
