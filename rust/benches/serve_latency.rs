//! Serve latency sweep (`make bench-serve`): offered load vs latency
//! percentiles + throughput for `repro serve` on RGCN/aifb with the full
//! HiFuse plan over 2 replica lanes, written to
//! `results/serve_latency.{md,csv}`.
//!
//! One table, two load families, keyed by the leading `load` column:
//! `open@RATE` rows sweep an open-loop Poisson arrival rate (req/s of
//! virtual time); `closed@N` rows sweep N closed-loop virtual clients,
//! each re-issuing only after its previous response completes — the
//! tail-latency-vs-concurrency view the open-loop sweep cannot show
//! (ROADMAP serving item (b), DESIGN.md §10).
//!
//! Latency lives on the virtual clock (1 tick = 1 µs): each batch's
//! measured service time is replayed onto the arrival schedule, so the
//! sweep shows the coalescing/queueing trade-off — low load pays the
//! coalescing window, high load pays lane queueing — while predictions
//! stay bitwise load-independent (DESIGN.md §8).
//!
//! HIFUSE_BENCH_QUICK=1 shrinks the dataset, request count, and sweep.

use std::time::Duration;

use hifuse::coordinator::{prepare_graph_layout, OptConfig, ReplicaGroup, TrainCfg, DEFAULT_ROUND};
use hifuse::graph::datasets::{generate, spec_by_name};
use hifuse::models::step::Dims;
use hifuse::models::ModelKind;
use hifuse::report::{f2, write_csv, write_md_table};
use hifuse::runtime::{ExecBackend, SimBackend};
use hifuse::serving;

/// One point of the sweep: an open-loop arrival rate or a closed-loop
/// client count.
enum Load {
    Open(f64),
    Closed(usize),
}

impl Load {
    fn label(&self) -> String {
        match self {
            Load::Open(rate) => format!("open@{rate}"),
            Load::Closed(clients) => format!("closed@{clients}"),
        }
    }
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("HIFUSE_BENCH_QUICK").is_ok();
    let cfg = TrainCfg {
        epochs: 1,
        batch_size: 64,
        fanout: 4,
        lr: 0.05,
        seed: 42,
        threads: 4,
        producers: 0,
    };
    let opt = OptConfig::hifuse();
    let spec = spec_by_name("aifb").unwrap();
    let scale = if quick { 0.25 } else { 1.0 };
    let requests = if quick { 64 } else { 512 };
    let window = 1_000u64; // 1 ms coalescing window

    let mut points: Vec<Load> =
        [250.0f64, 1000.0, 4000.0, 16000.0].into_iter().map(Load::Open).collect();
    let clients: &[usize] = if quick { &[4, 16] } else { &[4, 16, 64, 256] };
    points.extend(clients.iter().map(|&c| Load::Closed(c)));

    let mut rows = Vec::new();
    for point in &points {
        let label = point.label();
        eprintln!("[serve-latency] {label} ...");
        // Fresh lanes per point: independent arenas/counters per load.
        let probe = SimBackend::builtin("bench")?;
        let d = Dims::from_backend(&probe);
        let mut g = generate(&spec, d.f, scale, cfg.seed);
        prepare_graph_layout(&mut g, &opt);
        let mut group = ReplicaGroup::builtin(
            "bench",
            2,
            Duration::ZERO,
            &g,
            ModelKind::Rgcn,
            opt,
            cfg,
            DEFAULT_ROUND,
        )?;
        let trace = match point {
            Load::Open(rate) => serving::trace::generate(&g, cfg.seed, *rate, requests, 4),
            Load::Closed(clients) => {
                serving::trace::generate_closed_loop(&g, cfg.seed, *clients, requests, 4)
            }
        };
        let out = serving::serve(&mut group, &trace, cfg.batch_size, window)?;
        let mut h2d = 0u64;
        for e in group.engines() {
            h2d += e.counters().borrow().h2d_bytes;
        }
        let h = &out.hist;
        rows.push(vec![
            label,
            out.batches.len().to_string(),
            format!("{:.3}", h.percentile(50.0) as f64 / 1e3),
            format!("{:.3}", h.percentile(95.0) as f64 / 1e3),
            format!("{:.3}", h.percentile(99.0) as f64 / 1e3),
            f2(out.virtual_throughput()),
            f2(out.mean_queue_depth),
            f2(h2d as f64 / (1024.0 * 1024.0)),
        ]);
    }
    write_md_table(
        "serve_latency.md",
        "Serve latency — open-loop rate + closed-loop client sweep \
         (RGCN/aifb, hifuse, 2 lanes, 1 ms window)",
        &["load", "batches", "p50 ms", "p95 ms", "p99 ms", "throughput req/s",
          "mean queue", "h2d MiB"],
        &rows,
    )?;
    write_csv(
        "serve_latency.csv",
        &["load", "batches", "p50_ms", "p95_ms", "p99_ms", "throughput_rps",
          "mean_queue_depth", "h2d_mib"],
        &rows,
    )?;
    eprintln!("[serve-latency] wrote results/serve_latency.{{md,csv}}");
    Ok(())
}
