//! Feature-cache sweep (`make bench-cache`): hit rate vs H2D bytes vs
//! epoch wall for `--cache-frac` ∈ {0, 0.25, 0.5, 1.0} on RGCN/aifb with
//! the full HiFuse plan, written to `results/cache_sweep.{md,csv}`.
//!
//! The loss column is the bit-exactness witness: it must be identical in
//! every row (pinned bitwise by `tests/cache_parity.rs` on the tiny
//! profile; this sweep shows the same holds at bench scale while the H2D
//! column shrinks roughly with the hit rate — DESIGN.md §7).
//!
//! HIFUSE_BENCH_QUICK=1 shrinks the dataset and skips the warm-up epoch
//! (quick numbers then include first-touch arena/cache costs).

use std::sync::Arc;

use hifuse::coordinator::{prepare_graph_layout, OptConfig, TrainCfg, Trainer};
use hifuse::graph::datasets::{generate, spec_by_name};
use hifuse::models::step::Dims;
use hifuse::models::ModelKind;
use hifuse::report::{f2, write_csv, write_md_table};
use hifuse::runtime::{ExecBackend, ResidentStore, SimBackend};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("HIFUSE_BENCH_QUICK").is_ok();
    let cfg = TrainCfg {
        epochs: 2,
        batch_size: 64,
        fanout: 4,
        lr: 0.05,
        seed: 42,
        threads: 4,
        producers: 0,
    };
    let scale = if quick { 0.25 } else { 1.0 };
    let opt = OptConfig::hifuse();
    let spec = spec_by_name("aifb").unwrap();

    let mut rows = Vec::new();
    for frac in [0.0f64, 0.25, 0.5, 1.0] {
        eprintln!("[cache-sweep] frac {frac} ...");
        // Fresh backend + graph per point: independent arenas/counters, and
        // the layout prepared exactly as a training run would.
        let eng = SimBackend::builtin_threaded("bench", cfg.threads)?;
        let d = Dims::from_backend(&eng);
        let mut g = generate(&spec, d.f, scale, cfg.seed);
        prepare_graph_layout(&mut g, &opt);
        let mut tr = Trainer::new(&eng, &g, ModelKind::Rgcn, opt, cfg)?;
        let resident = if frac > 0.0 {
            let store =
                Arc::new(ResidentStore::build(&g, frac, eng.cst("CSLOTS"), cfg.seed));
            let rows_cached = store.rows_cached();
            tr.attach_cache(store)?;
            rows_cached
        } else {
            0
        };
        if !quick {
            tr.train_epoch(0)?; // warm the arena + producer pools
        }
        let m = tr.train_epoch(if quick { 0 } else { 1 })?;
        rows.push(vec![
            format!("{frac}"),
            resident.to_string(),
            format!("{:.4}", m.cache_hit_rate()),
            f2(m.h2d_bytes as f64 / (1024.0 * 1024.0)),
            (m.h2d_bytes / m.batches.max(1) as u64).to_string(),
            f2(m.wall.as_secs_f64() * 1e3),
            format!("{:.6}", m.loss),
        ]);
    }
    write_md_table(
        "cache_sweep.md",
        "Feature-cache sweep — hit rate vs H2D bytes vs wall (loss identical by contract)",
        &["cache frac", "resident rows", "hit rate", "h2d MiB/epoch", "h2d B/batch",
          "wall ms", "loss"],
        &rows,
    )?;
    write_csv(
        "cache_sweep.csv",
        &["cache_frac", "resident_rows", "hit_rate", "h2d_mib", "h2d_bytes_per_batch",
          "wall_ms", "loss"],
        &rows,
    )?;
    eprintln!("[cache-sweep] wrote results/cache_sweep.{{md,csv}}");
    Ok(())
}
