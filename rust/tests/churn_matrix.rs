//! Churn matrix (DESIGN.md §10): the serving analog of `fault_matrix.rs`.
//! Serving churn — hot model refresh, lane quarantine with re-admission,
//! closed-loop clients — is *metrology*, never semantics. Across the grid
//! {refresh, quarantine, closed-loop} × replicas {1, 2} × pipeline on/off
//! × cache-frac {0, 0.25}:
//!
//! * per-request predictions are bitwise identical to the quiescent run
//!   (same trace, no churn) — quarantine re-dispatch preserves global
//!   batch order, refresh boundaries are global-batch-indexed, and a
//!   same-bits refresh is a no-op;
//! * churn counters account for exactly the injected events: one `lane!`
//!   firing is one quarantine, one re-dispatch, `probation` shadow
//!   batches, one re-admission;
//! * refresh failure is atomic: a truncated / bit-flipped / garbage
//!   checkpoint leaves the old parameters serving bitwise-identically and
//!   lands in `failed_refreshes`;
//! * dispatch-fault retry accounting is churn-invariant (shadow batches
//!   never arm the fault cursor);
//! * all lanes quarantined at once is the typed [`NoHealthyLanes`] error;
//! * the zero-allocation steady state survives churn.

use std::path::PathBuf;
use std::sync::Arc;

use hifuse::coordinator::{
    prepare_graph_layout, replica_thread_budget, ChurnStats, NoHealthyLanes, OptConfig,
    ReplicaGroup, TrainCfg, DEFAULT_ROUND,
};
use hifuse::graph::datasets::tiny_graph;
use hifuse::models::{checkpoint, ModelKind, Params};
use hifuse::runtime::{ExecBackend, ResidentStore, SimBackend};
use hifuse::serving::{self, ServeOptions, ServeOutcome, Trace};
use hifuse::util::FaultPlan;

const WINDOW: u64 = 2_000;

fn cfg() -> TrainCfg {
    TrainCfg { epochs: 1, batch_size: 4, fanout: 3, lr: 0.05, seed: 42, threads: 4, producers: 2 }
}

/// Open-loop burst: 24 requests of 1..=3 seeds — a dozen-odd coalesced
/// batches, enough to put churn events mid-trace with quiet batches on
/// both sides.
fn test_trace() -> Trace {
    serving::trace::generate(&tiny_graph(1), 42, 1000.0, 24, 3)
}

fn plan(spec: &str) -> Arc<FaultPlan> {
    Arc::new(FaultPlan::parse(spec, 0).unwrap())
}

fn group_for(
    g: &hifuse::graph::HeteroGraph,
    replicas: usize,
    pipeline: bool,
    frac: f64,
    spec: Option<&str>,
) -> ReplicaGroup<'_, SimBackend> {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let t = replica_thread_budget(4, replicas);
    let engines: Vec<SimBackend> =
        (0..replicas).map(|_| SimBackend::builtin_threaded("tiny", t).unwrap()).collect();
    let mut grp =
        ReplicaGroup::new(engines, g, ModelKind::Rgcn, opt, cfg(), DEFAULT_ROUND).unwrap();
    if frac > 0.0 {
        grp.attach_cache(Arc::new(ResidentStore::build(g, frac, 160, 42))).unwrap();
    }
    if let Some(s) = spec {
        grp.set_fault_plan(plan(s));
    }
    grp
}

/// One serve pass; returns the outcome plus the summed engine dispatch
/// retries (churn must not perturb them).
fn serve_once(
    trace: &Trace,
    replicas: usize,
    pipeline: bool,
    frac: f64,
    spec: Option<&str>,
    opts: &ServeOptions,
) -> (ServeOutcome, u64) {
    let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &opt);
    let mut grp = group_for(&g, replicas, pipeline, frac, spec);
    let out = serving::serve_churn(&mut grp, trace, cfg().batch_size, WINDOW, opts).unwrap();
    let retries: u64 =
        grp.engines().iter().map(|e| e.counters().borrow().dispatch_retries).sum();
    (out, retries)
}

fn quiescent() -> ServeOptions {
    ServeOptions::quiescent()
}

/// A scratch checkpoint path unique to this test binary + name.
fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hifuse_churn_{}_{}.ckpt", std::process::id(), name))
}

/// A parameter set provably different from the serving group's (same
/// profile dims, different init stream).
fn other_params() -> Params {
    let d = dims();
    Params::init(d.0, d.1, d.2, d.3, 0xA1FA)
}

/// (rpad, f, h, c) of the tiny profile, read off a probe group.
fn dims() -> (usize, usize, usize, usize) {
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &OptConfig::hifuse());
    let grp = group_for(&g, 1, false, 0.0, None);
    let d = grp.dims();
    (d.rpad, d.f, d.h, d.c)
}

// ----------------------------------------------------------- quarantine --

/// The headline contract, quarantine edition: a `lane!` firing mid-trace
/// moves work, not bits. Predictions match the quiescent run across the
/// whole grid and the counters account for exactly one quarantine cycle.
#[test]
fn quarantine_keeps_predictions_bitwise_quiescent() {
    let trace = test_trace();
    let (reference, _) = serve_once(&trace, 1, false, 0.0, None, &quiescent());
    assert!(reference.churn.is_quiet());
    for pipeline in [false, true] {
        for frac in [0.0f64, 0.25] {
            let (out, _) =
                serve_once(&trace, 2, pipeline, frac, Some("lane!@0:1"), &quiescent());
            assert_eq!(
                out.predictions, reference.predictions,
                "pipeline={pipeline} frac={frac}: quarantined serve diverged"
            );
            assert_eq!(out.batches, reference.batches);
            assert_eq!(
                out.churn,
                ChurnStats {
                    lane_quarantines: 1,
                    lane_readmissions: 1,
                    shadow_batches: 2, // DEFAULT_PROBATION
                    lane_redispatches: 1,
                    ..ChurnStats::default()
                },
                "pipeline={pipeline} frac={frac}: counter accounting"
            );
        }
    }
}

/// A longer probation stretches the shadow phase and delays re-admission
/// by exactly the configured count — nothing else moves.
#[test]
fn probation_length_is_respected_exactly() {
    let trace = test_trace();
    let (reference, _) = serve_once(&trace, 2, false, 0.0, None, &quiescent());
    let opts = ServeOptions { probation: 4, ..ServeOptions::quiescent() };
    let (out, _) = serve_once(&trace, 2, false, 0.0, Some("lane!@0:1"), &opts);
    assert_eq!(out.predictions, reference.predictions, "probation=4: predictions diverged");
    assert_eq!(out.churn.shadow_batches, 4);
    assert_eq!(out.churn.lane_readmissions, 1);
}

/// Dispatch-fault retry accounting is churn-invariant: a batch that
/// re-dispatches to another lane carries its dispatch fault with it (the
/// address is the global batch index), and shadow batches never arm the
/// cursor — so total retries match the quarantine-free run exactly.
#[test]
fn dispatch_fault_accounting_is_churn_invariant() {
    let trace = test_trace();
    let spec_dispatch = "dispatch@0:1x2,dispatch@0:2";
    let (base, base_retries) = serve_once(&trace, 2, true, 0.0, Some(spec_dispatch), &quiescent());
    assert_eq!(base_retries, 3, "two faults at seq 1, one at seq 2");
    let spec_both = "dispatch@0:1x2,dispatch@0:2,lane!@0:1";
    let (out, retries) = serve_once(&trace, 2, true, 0.0, Some(spec_both), &quiescent());
    assert_eq!(out.predictions, base.predictions, "churned serve diverged");
    assert_eq!(retries, base_retries, "quarantine perturbed dispatch retry accounting");
    assert_eq!(out.churn.lane_quarantines, 1);
}

/// Every lane quarantined at once is the typed error, not a hang or a
/// generic failure.
#[test]
fn all_lanes_quarantined_is_a_typed_error() {
    let trace = test_trace();
    for (replicas, spec) in [(2usize, "lane!@0:0x2"), (1, "lane!@0:0")] {
        let opt = OptConfig::hifuse();
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut grp = group_for(&g, replicas, false, 0.0, Some(spec));
        let err = serving::serve_churn(&mut grp, &trace, cfg().batch_size, WINDOW, &quiescent())
            .unwrap_err();
        let no = err.downcast_ref::<NoHealthyLanes>().unwrap_or_else(|| {
            panic!("replicas={replicas}: expected NoHealthyLanes, got {err:#}")
        });
        assert_eq!(*no, NoHealthyLanes { batch: 0, lanes: replicas });
    }
}

// -------------------------------------------------------------- refresh --

/// Refreshing with a bitwise-identical checkpoint is invisible: the swap
/// machinery runs (counted) but every prediction matches the quiescent
/// run on every grid cell.
#[test]
fn same_bits_refresh_is_invisible() {
    let trace = test_trace();
    let (reference, _) = serve_once(&trace, 1, false, 0.0, None, &quiescent());
    // The group's initial params are Params::init(seed) — write exactly
    // those to the refresh checkpoint.
    let mut g = tiny_graph(1);
    prepare_graph_layout(&mut g, &OptConfig::hifuse());
    let grp = group_for(&g, 1, false, 0.0, None);
    let path = tmp("same_bits");
    checkpoint::save(&grp.params, &path).unwrap();
    drop(grp);
    let mid = reference.batches[reference.batches.len() / 2].close_tick;
    let opts =
        ServeOptions { refreshes: vec![(mid, path.clone())], ..ServeOptions::quiescent() };
    for replicas in [1usize, 2] {
        for pipeline in [false, true] {
            for frac in [0.0f64, 0.25] {
                let (out, _) = serve_once(&trace, replicas, pipeline, frac, None, &opts);
                assert_eq!(
                    out.predictions, reference.predictions,
                    "replicas={replicas} pipeline={pipeline} frac={frac}: \
                     same-bits refresh changed predictions"
                );
                assert_eq!(out.churn.refreshes, 1);
                assert_eq!(out.churn.failed_refreshes, 0);
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

/// A real refresh applies at its global batch boundary, identically on
/// every grid cell: requests coalesced before the boundary serve the old
/// model, requests at or after it serve the new one — bitwise equal to
/// the runs that used each model exclusively.
#[test]
fn refresh_applies_at_the_batch_boundary_for_any_schedule() {
    let trace = test_trace();
    let (old, _) = serve_once(&trace, 1, false, 0.0, None, &quiescent());
    let path = tmp("new_model");
    checkpoint::save(&other_params(), &path).unwrap();
    // Refresh from tick 0: every batch serves the new model.
    let all_opts =
        ServeOptions { refreshes: vec![(0, path.clone())], ..ServeOptions::quiescent() };
    let (new, _) = serve_once(&trace, 1, false, 0.0, None, &all_opts);
    assert_ne!(
        new.predictions, old.predictions,
        "a different parameter set must (generically) change predictions"
    );
    // Boundary = close tick of the middle batch.
    let mid = old.batches.len() / 2;
    let boundary = old.batches[mid].close_tick;
    let opts =
        ServeOptions { refreshes: vec![(boundary, path.clone())], ..ServeOptions::quiescent() };
    let (reference, _) = serve_once(&trace, 1, false, 0.0, None, &opts);
    // Each request takes old/new according to its batch's position.
    for (bi, b) in old.batches.iter().enumerate() {
        let want = if b.close_tick < boundary { &old } else { &new };
        for m in &b.members {
            assert_eq!(
                reference.predictions[m.req], want.predictions[m.req],
                "batch {bi} request {}: wrong side of the refresh boundary",
                m.req
            );
        }
    }
    assert!(old.batches[mid].close_tick >= boundary && mid > 0, "boundary must split the trace");
    // And the split run itself is schedule-invariant across the grid.
    for replicas in [1usize, 2] {
        for pipeline in [false, true] {
            for frac in [0.0f64, 0.25] {
                let (out, _) = serve_once(&trace, replicas, pipeline, frac, None, &opts);
                assert_eq!(
                    out.predictions, reference.predictions,
                    "replicas={replicas} pipeline={pipeline} frac={frac}: \
                     refreshed serve diverged"
                );
                assert_eq!(out.churn.refreshes, 1);
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Refresh + quarantine in one trace: the composed churn still serves
/// bitwise-identically to the refresh-only run, and both counter families
/// account independently.
#[test]
fn refresh_and_quarantine_compose() {
    let trace = test_trace();
    let path = tmp("compose");
    checkpoint::save(&other_params(), &path).unwrap();
    let (old, _) = serve_once(&trace, 1, false, 0.0, None, &quiescent());
    let boundary = old.batches[old.batches.len() / 2].close_tick;
    let opts =
        ServeOptions { refreshes: vec![(boundary, path.clone())], ..ServeOptions::quiescent() };
    let (reference, _) = serve_once(&trace, 1, false, 0.0, None, &opts);
    let (out, _) = serve_once(&trace, 2, true, 0.25, Some("lane!@0:1"), &opts);
    assert_eq!(out.predictions, reference.predictions, "composed churn diverged");
    assert_eq!(out.churn.refreshes, 1);
    assert_eq!(out.churn.lane_quarantines, 1);
    assert_eq!(out.churn.lane_readmissions, 1);
    std::fs::remove_file(&path).ok();
}

/// Hot-swap failure atomicity: corrupt refresh checkpoints — truncated,
/// bit-flipped payload (CRC mismatch), garbage magic, wrong-shape params —
/// leave the old parameters serving bitwise-identically, with each
/// failure counted and none fatal.
#[test]
fn refresh_failure_is_atomic_and_counted() {
    let trace = test_trace();
    let (reference, _) = serve_once(&trace, 1, false, 0.0, None, &quiescent());
    let good = tmp("atomic_good");
    checkpoint::save(&other_params(), &good).unwrap();
    let bytes = std::fs::read(&good).unwrap();

    // Truncated mid-tensor.
    let truncated = tmp("atomic_trunc");
    std::fs::write(&truncated, &bytes[..bytes.len() / 2]).unwrap();
    // One payload bit flipped: decodes structurally but fails the CRC.
    let flipped = tmp("atomic_flip");
    let mut fb = bytes.clone();
    let mid = fb.len() / 2;
    fb[mid] ^= 0x40;
    std::fs::write(&flipped, &fb).unwrap();
    // Garbage magic.
    let garbage = tmp("atomic_garbage");
    std::fs::write(&garbage, b"not a checkpoint at all").unwrap();
    // Wrong dims: a structurally valid checkpoint for a different profile.
    let wrong_shape = tmp("atomic_shape");
    let (rpad, f, h, c) = dims();
    checkpoint::save(&Params::init(rpad + 8, f, h, c, 1), &wrong_shape).unwrap();

    let boundary = reference.batches[reference.batches.len() / 2].close_tick;
    let corrupt = [&truncated, &flipped, &garbage, &wrong_shape];
    for path in corrupt {
        let opts = ServeOptions {
            refreshes: vec![(boundary, path.clone())],
            ..ServeOptions::quiescent()
        };
        let (out, _) = serve_once(&trace, 2, true, 0.0, None, &opts);
        assert_eq!(
            out.predictions, reference.predictions,
            "{path:?}: a failed refresh must leave the old params serving"
        );
        assert_eq!(out.churn.refreshes, 0, "{path:?}: failed refresh counted as applied");
        assert_eq!(out.churn.failed_refreshes, 1, "{path:?}: failure not counted");
    }
    // All four at once: still never fatal, still bitwise old-model.
    let opts = ServeOptions {
        refreshes: corrupt.iter().map(|p| (boundary, (*p).clone())).collect(),
        ..ServeOptions::quiescent()
    };
    let (out, _) = serve_once(&trace, 1, false, 0.0, None, &opts);
    assert_eq!(out.predictions, reference.predictions);
    assert_eq!(out.churn.failed_refreshes, 4);

    for p in [&good, &truncated, &flipped, &garbage, &wrong_shape] {
        std::fs::remove_file(p).ok();
    }
}

// ---------------------------------------------------------- closed loop --

/// Closed-loop serving is as deterministic as open-loop: the generated
/// schedule is a pure function of (seed, clients), and serving it is
/// parallelism-invariant across the grid.
#[test]
fn closed_loop_serve_is_parallelism_invariant() {
    let g = tiny_graph(1);
    let trace = serving::trace::generate_closed_loop(&g, 42, 4, 24, 3);
    assert_eq!(trace, serving::trace::generate_closed_loop(&g, 42, 4, 24, 3));
    let (reference, _) = serve_once(&trace, 1, false, 0.0, None, &quiescent());
    assert_eq!(reference.hist.count(), trace.requests.len() as u64);
    for replicas in [1usize, 2] {
        for pipeline in [false, true] {
            for frac in [0.0f64, 0.25] {
                let (out, _) = serve_once(&trace, replicas, pipeline, frac, None, &quiescent());
                assert_eq!(
                    out.predictions, reference.predictions,
                    "replicas={replicas} pipeline={pipeline} frac={frac}: \
                     closed-loop serve diverged"
                );
                assert_eq!(out.batches, reference.batches);
            }
        }
    }
}

/// Closed-loop + churn: quarantine under a closed-loop schedule still
/// matches the quiescent closed-loop run bit for bit.
#[test]
fn closed_loop_survives_quarantine() {
    let g = tiny_graph(1);
    let trace = serving::trace::generate_closed_loop(&g, 42, 4, 24, 3);
    let (reference, _) = serve_once(&trace, 2, true, 0.0, None, &quiescent());
    let (out, _) = serve_once(&trace, 2, true, 0.0, Some("lane!@0:1"), &quiescent());
    assert_eq!(out.predictions, reference.predictions, "closed-loop quarantine diverged");
    assert_eq!(out.churn.lane_quarantines, 1);
}

// ------------------------------------------------------------ zero alloc --

/// The zero-allocation steady state survives churn: with a quarantine
/// cycle and a hot refresh in *every* pass, post-warm-up serves still
/// miss the arena zero times and construct/grow zero producer buffers.
#[test]
fn churn_steady_state_allocates_nothing() {
    let path = tmp("steady");
    checkpoint::save(&other_params(), &path).unwrap();
    for pipeline in [false, true] {
        let opt = OptConfig { pipeline, ..OptConfig::hifuse() };
        let mut g = tiny_graph(1);
        prepare_graph_layout(&mut g, &opt);
        let mut grp = group_for(&g, 2, pipeline, 0.25, Some("lane!@0:1"));
        let trace = test_trace();
        let opts =
            ServeOptions { refreshes: vec![(1_000, path.clone())], ..ServeOptions::quiescent() };
        let snapshot = |grp: &ReplicaGroup<'_, SimBackend>| -> (u64, u64, u64, u64) {
            let arena: u64 =
                grp.engines().iter().map(|e| e.counters().borrow().arena.misses).sum();
            let p = grp.producer_stats();
            (arena, p.fresh, p.grown, p.reused)
        };
        serving::serve_churn(&mut grp, &trace, cfg().batch_size, WINDOW, &opts).unwrap();
        let warm = snapshot(&grp);
        let out = serving::serve_churn(&mut grp, &trace, cfg().batch_size, WINDOW, &opts).unwrap();
        let steady = snapshot(&grp);
        assert_eq!(out.churn.lane_quarantines, 1, "churn must actually run in steady state");
        assert_eq!(out.churn.refreshes, 1);
        assert_eq!(steady.0, warm.0, "pipeline {pipeline}: churned serve missed the arena");
        assert_eq!(steady.1, warm.1, "pipeline {pipeline}: churned serve built a buffer set");
        assert_eq!(steady.2, warm.2, "pipeline {pipeline}: churned serve grew a pooled buffer");
        assert!(steady.3 > warm.3, "pipeline {pipeline}: churned serve never reused the pool");
    }
    std::fs::remove_file(&path).ok();
}
